//! Sparsity-sweep ablation: how does the speedup of each scheme scale
//! with the activation-sparsity level, and where does output sparsity
//! overtake input sparsity? (The design-choice sweep DESIGN.md calls out:
//! the paper's §3.2 intuition, quantified on our model.)
//!
//! Run with: `cargo run --release --example sparsity_explorer`

use agos::config::{AcceleratorConfig, Scheme, SimOptions};
use agos::sim::{simulate_layer, LayerTask};
use agos::util::rng::Pcg32;

fn main() {
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions::default();

    // A representative mid-network conv: 128ch 28x28, 3x3 filters.
    let mk = |s: f64| LayerTask {
        name: "sweep".into(),
        m: 128,
        u: 28,
        v: 28,
        crs: 1152.0,
        in_sparsity: Some(s),
        out_sparsity: Some(s),
        input_elems: 128.0 * 30.0 * 30.0,
        weight_elems: 128.0 * 1152.0,
        geom: Default::default(),
    };

    println!(
        "{:>9} {:>10} {:>10} {:>10} {:>14}",
        "sparsity", "IN", "IN+OUT", "IN+OUT+WR", "OUT-only gain"
    );
    for pct in (10..=90).step_by(10) {
        let s = pct as f64 / 100.0;
        let task = mk(s);
        let mut cycles = std::collections::BTreeMap::new();
        for scheme in Scheme::ALL {
            let mut rng = Pcg32::new(99);
            let r = simulate_layer(&task, &cfg, &opts, scheme, &mut rng);
            cycles.insert(scheme.label(), r.cycles);
        }
        let dc = cycles["DC"];
        println!(
            "{:>8}% {:>10.2} {:>10.2} {:>10.2} {:>14.2}",
            pct,
            dc / cycles["IN"],
            dc / cycles["IN+OUT"],
            dc / cycles["IN+OUT+WR"],
            cycles["IN"] / cycles["IN+OUT"],
        );
    }

    println!("\nBN-network scenario (gradient input is dense, only OUT applies):");
    println!("{:>9} {:>10} {:>10}", "sparsity", "IN(=DC)", "OUT");
    for pct in (10..=90).step_by(20) {
        let s = pct as f64 / 100.0;
        let task = LayerTask { in_sparsity: None, ..mk(s) };
        let mut rng = Pcg32::new(99);
        let dc = simulate_layer(&task, &cfg, &opts, Scheme::Dense, &mut rng).cycles;
        let mut rng = Pcg32::new(99);
        let inp = simulate_layer(&task, &cfg, &opts, Scheme::In, &mut rng).cycles;
        let mut rng = Pcg32::new(99);
        let out = simulate_layer(&task, &cfg, &opts, Scheme::InOut, &mut rng).cycles;
        println!("{:>8}% {:>10.2} {:>10.2}", pct, dc / inp, dc / out);
    }
}
