//! Regenerate every paper figure and table into `results/` and print the
//! paper-vs-measured summary used by EXPERIMENTS.md.
//!
//! Run with: `cargo run --release --example paper_figures [-- batch]`

use std::path::Path;

use agos::report::{generate, ReportCtx};

fn main() -> anyhow::Result<()> {
    let batch = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16);
    let ctx = ReportCtx::with_batch(batch);
    let out = Path::new("results");
    std::fs::create_dir_all(out)?;

    for fig in generate("all", &ctx)? {
        print!("{}", fig.render());
        fig.save(out)?;
        println!("-> results/{}.json\n", fig.id);
    }

    // Headline summary (paper band vs ours).
    let fig15 = &generate("fig15", &ctx)?[0];
    println!("== headline check (paper Fig 15 overall speedups) ==");
    let expected = [
        ("vgg16", 2.00),
        ("googlenet", 2.18),
        ("resnet18", 1.66),
        ("densenet121", 1.70),
        ("mobilenet_v1", 2.13),
    ];
    for (net, paper) in expected {
        let ours = fig15.value(net, "speedup").unwrap_or(f64::NAN);
        println!("  {net:<14} paper {paper:.2}x   ours {ours:.2}x");
    }
    Ok(())
}
