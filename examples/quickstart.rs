//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load the AOT artifacts (JAX/Pallas, compiled once by `make
//!    artifacts`) into the rust PJRT runtime.
//! 2. Execute the Pallas GEMM from rust — no python on the request path.
//! 3. Run one real training step of the small CNN.
//! 4. Simulate the paper's accelerator on a VGG-16 backward pass.
//!
//! Run with: `cargo run --release --example quickstart`

use agos::config::{AcceleratorConfig, Scheme, SimOptions};
use agos::nn::{zoo, Phase};
use agos::runtime::{HostTensor, Runtime};
use agos::sim::simulate_network;
use agos::sparsity::SparsityModel;
use agos::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    // ---- 1+2: PJRT runtime executes the Pallas GEMM artifact ------------
    let mut rt = Runtime::load(std::path::Path::new("artifacts"))?;
    println!("PJRT platform: {}", rt.platform());
    let n = 64;
    let a: Vec<f32> = (0..n * n).map(|i| ((i % 7) as f32) * 0.25).collect();
    let b: Vec<f32> = (0..n * n).map(|i| ((i % 5) as f32) * 0.5).collect();
    let out = rt.run(
        "gemm_demo",
        &[
            HostTensor::f32(vec![n, n], a)?,
            HostTensor::f32(vec![n, n], b)?,
        ],
    )?;
    println!(
        "pallas GEMM: {}x{} result, first element {:.3}",
        out[0].shape()[0],
        out[0].shape()[1],
        out[0].as_f32()?[0]
    );

    // ---- 3: one real training step ---------------------------------------
    let params = rt.manifest.load_initial_params()?;
    let (batch, img, ch) = (rt.manifest.batch, rt.manifest.img, rt.manifest.in_ch);
    let mut rng = Pcg32::new(1);
    let x: Vec<f32> = (0..batch * img * img * ch).map(|_| rng.gauss() as f32).collect();
    let labels: Vec<i32> =
        (0..batch).map(|_| rng.below(rt.manifest.num_classes as u32) as i32).collect();
    let mut inputs = params.clone();
    inputs.push(HostTensor::f32(vec![batch, img, img, ch], x)?);
    inputs.push(HostTensor::i32(vec![batch], labels)?);
    let step_out = rt.run("train_step", &inputs)?;
    println!(
        "train_step: loss {:.4} ({} params updated)",
        step_out[params.len()].as_f32()?[0],
        params.len()
    );

    // ---- 4: accelerator simulation ---------------------------------------
    let net = zoo::vgg16();
    let cfg = AcceleratorConfig::default();
    let opts = SimOptions { batch: 4, ..SimOptions::default() };
    let model = SparsityModel::synthetic(opts.seed);
    let dc = simulate_network(&net, &cfg, &opts, &model, Scheme::Dense);
    let best = simulate_network(&net, &cfg, &opts, &model, Scheme::InOutWr);
    println!(
        "VGG-16 BP on the accelerator: {:.2}x speedup from IN+OUT+WR \
         ({:.0} -> {:.0} kcycles)",
        dc.phase(Phase::Backward).cycles / best.phase(Phase::Backward).cycles,
        dc.phase(Phase::Backward).cycles / 1e3,
        best.phase(Phase::Backward).cycles / 1e3,
    );
    Ok(())
}
