//! End-to-end validation (DESIGN.md E13): train the small CNN for a few
//! hundred steps on the synthetic dataset *through the rust PJRT runtime*
//! (python never runs), logging the loss curve; extract real sparsity
//! traces along the way; verify the paper's sparsity-identity law on
//! every trace; then co-simulate the accelerator on the *measured*
//! sparsity and report the speedups.
//!
//! Run with:
//!   cargo run --release --example train_cnn            (300 steps)
//!   cargo run --release --example train_cnn -- 50 10   (steps, trace-every)

use std::path::Path;

use agos::config::{AcceleratorConfig, SimOptions, TrainOptions};
use agos::coordinator::{cosim_from_traces, run_training_pipeline};
use agos::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let trace_every = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(50);

    let opts = TrainOptions {
        steps,
        trace_every,
        log_every: (steps / 20).max(1),
        ..TrainOptions::default()
    };
    println!("training agos_cnn for {steps} steps (traces every {trace_every})...");
    let log = run_training_pipeline(&opts)?;

    // ---- loss curve -------------------------------------------------------
    println!("\nloss curve ({:.2} steps/s):", log.steps_per_sec);
    let first = log.losses.first().map(|(_, l)| *l).unwrap_or(f64::NAN);
    let last = log.losses.last().map(|(_, l)| *l).unwrap_or(f64::NAN);
    for (step, loss) in &log.losses {
        let bar = "#".repeat((loss * 20.0).min(60.0) as usize);
        println!("  step {step:>5} {loss:>8.4} {bar}");
    }
    anyhow::ensure!(
        last < first,
        "training did not learn: first {first:.4} vs last {last:.4}"
    );
    println!("loss {first:.4} -> {last:.4}  ✓ model learns");

    // ---- sparsity identity -------------------------------------------------
    anyhow::ensure!(log.traces.identity_holds(), "sparsity identity violated!");
    println!(
        "\nsparsity identity (gradient zeros ⊇ activation zeros): HOLDS on all {} traced steps",
        log.traces.steps.len()
    );
    println!("measured activation sparsity per layer (mean over traced steps):");
    for (name, s) in log.traces.mean_act_sparsity() {
        println!("  {name}: {s:.3}");
    }

    // ---- co-simulation on measured sparsity --------------------------------
    let cfg = AcceleratorConfig::default();
    let sim_opts = SimOptions { batch: 16, ..SimOptions::default() };
    let report = cosim_from_traces(&log.traces, &cfg, &sim_opts, false, 0)?;
    println!("\naccelerator co-simulation on the measured traces:");
    for (scheme, total, bp, energy) in &report.rows {
        println!("  {scheme:<10} total {total:>12.0} cycles  BP {bp:>12.0} cycles  {energy:.4} J");
    }
    println!(
        "  speedup from measured sparsity: total {:.2}x, backward pass {:.2}x",
        report.total_speedup, report.bp_speedup
    );

    // ---- persist -----------------------------------------------------------
    std::fs::create_dir_all("results")?;
    let mut j = Json::obj();
    j.set(
        "losses",
        Json::Arr(
            log.losses.iter().map(|(s, l)| Json::Arr(vec![(*s).into(), (*l).into()])).collect(),
        ),
    );
    j.set("steps_per_sec", log.steps_per_sec.into());
    j.set("cosim", report.to_json());
    j.write_file(Path::new("results/train_cnn.json"))?;
    log.traces.save(Path::new("results/traces.json"))?;
    println!("\nwrote results/train_cnn.json and results/traces.json");
    Ok(())
}
