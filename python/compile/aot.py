"""AOT-lower the L2 entry points to HLO text for the rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's bundled XLA (xla_extension 0.5.1) rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly.

Outputs (under ``--out-dir``, default ``../artifacts``):

    train_step.hlo.txt    -- (10 params, x, labels) -> (10 params, loss)
    step_traces.hlo.txt   -- (10 params, x, labels) -> (loss, a1..a4, g1..g4)
    gemm_demo.hlo.txt     -- (a, b) -> (a @ b,)    [quickstart]
    params/<name>.bin     -- initial parameters, raw little-endian f32
    manifest.json         -- entry metadata: inputs/outputs, shapes,
                             dtypes, hyper-parameters

Run via ``make artifacts`` (python is never on the rust request path).
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_of(x) -> dict:
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


def lower_entry(fn, example_args, path: pathlib.Path) -> dict:
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path.write_text(text)
    out_tree = jax.eval_shape(fn, *example_args)
    flat_out = jax.tree_util.tree_leaves(out_tree)
    return {
        "file": path.name,
        "inputs": [spec_of(a) for a in example_args],
        "outputs": [spec_of(o) for o in flat_out],
        "hlo_bytes": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = pathlib.Path(args.out_dir)
    (out / "params").mkdir(parents=True, exist_ok=True)

    params = M.init_params(args.seed)
    flat = M.params_list(params)
    x, labels = M.example_batch(M.BATCH, args.seed)

    manifest = {
        "format": "hlo-text",
        "hyperparams": {
            "img": M.IMG,
            "in_ch": M.IN_CH,
            "num_classes": M.NUM_CLASSES,
            "batch": M.BATCH,
            "lr": M.LR,
            "seed": args.seed,
            "param_order": M.PARAM_ORDER,
            "conv_specs": [
                {"name": n, "rscm": list(spec), "stride": s}
                for (n, spec, s) in M.CONV_SPECS
            ],
        },
        "entries": {},
        "params": {},
    }

    # --- initial parameters -------------------------------------------------
    for name, arr in zip(M.PARAM_ORDER, flat):
        arr_np = np.asarray(arr, dtype=np.float32)
        fname = f"params/{name}.bin"
        (out / fname).write_bytes(arr_np.astype("<f4").tobytes())
        manifest["params"][name] = {"file": fname, "shape": list(arr_np.shape)}

    # --- entries -------------------------------------------------------------
    spec_args = tuple(flat) + (x, labels)

    def train_step_entry(*a):
        return M.train_step(*a)

    def step_traces_entry(*a):
        return M.step_traces(*a)

    manifest["entries"]["train_step"] = lower_entry(
        train_step_entry, spec_args, out / "train_step.hlo.txt"
    )
    manifest["entries"]["step_traces"] = lower_entry(
        step_traces_entry, spec_args, out / "step_traces.hlo.txt"
    )

    a = jnp.zeros((64, 64), jnp.float32)
    b = jnp.zeros((64, 64), jnp.float32)
    manifest["entries"]["gemm_demo"] = lower_entry(
        M.gemm_demo, (a, b), out / "gemm_demo.hlo.txt"
    )

    (out / "manifest.json").write_text(json.dumps(manifest, indent=2, sort_keys=True))
    total = sum(e["hlo_bytes"] for e in manifest["entries"].values())
    print(f"wrote {len(manifest['entries'])} entries ({total} HLO bytes) to {out}")


if __name__ == "__main__":
    main()
