"""L2 — the JAX CNN whose training step is AOT-lowered for the rust runtime.

A small ReLU CNN (NHWC) in the image of the paper's workloads: every
convolution and fully-connected layer executes through the L1 Pallas GEMM
(`kernels.gemm.matmul`), ReLU runs through the fused mask-emitting kernel,
and — the point of the paper — the hand-written backward pass computes
every conv's input gradient with `kernels.masked_bwd_gemm.masked_bwd_matmul`,
fusing the next ReLU's Hadamard into the GEMM so that *output sparsity*
(the a-priori-known zero footprint) is exploited structurally.

The backward pass is validated against `jax.grad` of a pure-jnp reference
model in `python/tests/test_model.py`.

Architecture (32x32x3 inputs, 10 classes):

    conv1 3->16  3x3 s1 + ReLU          (32x32)
    conv2 16->32 3x3 s2 + ReLU          (16x16)
    conv3 32->32 3x3 s1 + ReLU          (16x16)
    conv4 32->64 3x3 s2 + ReLU          (8x8)
    global-avg-pool -> fc 64->10 -> softmax cross-entropy
"""

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.gemm import matmul
from .kernels.masked_bwd_gemm import masked_bwd_matmul
from .kernels.relu import relu_with_mask

# ----------------------------------------------------------------------------
# Hyper-parameters baked into the AOT artifacts.
# ----------------------------------------------------------------------------
IMG = 32
IN_CH = 3
NUM_CLASSES = 10
BATCH = 16
LR = 0.05

# (name, (R, S, Cin, Cout), stride)
CONV_SPECS = [
    ("conv1", (3, 3, IN_CH, 16), 1),
    ("conv2", (3, 3, 16, 32), 2),
    ("conv3", (3, 3, 32, 32), 1),
    ("conv4", (3, 3, 32, 64), 2),
]
FC_IN = 64
PARAM_ORDER: List[str] = [
    "w1", "b1", "w2", "b2", "w3", "b3", "w4", "b4", "wf", "bf",
]


def init_params(seed: int = 0) -> Dict[str, jnp.ndarray]:
    """He-initialized parameters, deterministic from `seed`."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for i, (_, (r, s, cin, cout), _stride) in enumerate(CONV_SPECS, start=1):
        key, k = jax.random.split(key)
        fan_in = r * s * cin
        params[f"w{i}"] = (
            jax.random.normal(k, (r, s, cin, cout), jnp.float32)
            * jnp.sqrt(2.0 / fan_in)
        )
        params[f"b{i}"] = jnp.zeros((cout,), jnp.float32)
    key, k = jax.random.split(key)
    params["wf"] = jax.random.normal(k, (FC_IN, NUM_CLASSES), jnp.float32) * jnp.sqrt(
        2.0 / FC_IN
    )
    params["bf"] = jnp.zeros((NUM_CLASSES,), jnp.float32)
    return params


def params_list(params: Dict[str, jnp.ndarray]) -> List[jnp.ndarray]:
    return [params[k] for k in PARAM_ORDER]


def params_dict(flat) -> Dict[str, jnp.ndarray]:
    return dict(zip(PARAM_ORDER, flat))


# ----------------------------------------------------------------------------
# im2col convolution through the Pallas GEMM.
# ----------------------------------------------------------------------------
def _out_size(h: int, r: int, stride: int, pad: int) -> int:
    return (h + 2 * pad - r) // stride + 1


def im2col(x: jnp.ndarray, r: int, s: int, stride: int, pad: int) -> jnp.ndarray:
    """(N,H,W,C) -> (N,Ho,Wo,r*s*C) patches, feature order (r, s, c)."""
    n, h, w, c = x.shape
    ho = _out_size(h, r, stride, pad)
    wo = _out_size(w, s, stride, pad)
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    cols = []
    for dr in range(r):
        for ds in range(s):
            cols.append(
                xp[:, dr : dr + (ho - 1) * stride + 1 : stride,
                   ds : ds + (wo - 1) * stride + 1 : stride, :]
            )
    return jnp.concatenate(cols, axis=-1)


def conv2d(x, w, b, stride: int):
    """SAME-padded conv through the Pallas GEMM. Returns (y, cols)."""
    r, s, cin, cout = w.shape
    pad = r // 2
    cols = im2col(x, r, s, stride, pad)
    n, ho, wo, rsc = cols.shape
    y = matmul(cols.reshape(n * ho * wo, rsc), w.reshape(rsc, cout))
    y = y.reshape(n, ho, wo, cout) + b
    return y, cols


def _dilate(dy: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Insert stride-1 zeros between gradient rows/cols (stride>1 bwd)."""
    if stride == 1:
        return dy
    n, h, w, c = dy.shape
    out = jnp.zeros((n, (h - 1) * stride + 1, (w - 1) * stride + 1, c), dy.dtype)
    return out.at[:, ::stride, ::stride, :].set(dy)


def conv2d_bwd_input(dy, w, stride: int, in_hw: Tuple[int, int], mask=None):
    """Gradient w.r.t. the conv input.

    Computed as a *forward* convolution of the dilated gradient with the
    spatially-flipped, channel-transposed filter — which is again an
    im2col GEMM. When `mask` (the ReLU zero-footprint of the layer below)
    is given, the GEMM is the masked output-sparsity kernel: output rows
    that ReLU will zero are skipped at block granularity and the Hadamard
    is fused (paper section 3.2 / Fig 5).
    """
    r, s, cin, cout = w.shape
    pad = r // 2
    h_in, w_in = in_hw
    dyd = _dilate(dy, stride)
    n, hd, wd, _ = dyd.shape
    # Asymmetric padding so the backward conv lands exactly on (h_in, w_in).
    lo_h = r - 1 - pad
    hi_h = h_in - (hd + lo_h - r + 1)
    lo_w = s - 1 - pad
    hi_w = w_in - (wd + lo_w - s + 1)
    dyp = jnp.pad(dyd, ((0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    # Flip spatially, swap in/out channels: (r,s,cout,cin).
    wflip = w[::-1, ::-1, :, :].transpose(0, 1, 3, 2)
    cols = im2col(dyp, r, s, 1, 0)
    rows = cols.reshape(n * h_in * w_in, r * s * cout)
    wmat = wflip.reshape(r * s * cout, cin)
    if mask is None:
        dx = matmul(rows, wmat)
    else:
        dx = masked_bwd_matmul(rows, wmat, mask.reshape(n * h_in * w_in, cin))
    return dx.reshape(n, h_in, w_in, cin)


def conv2d_bwd_weights(cols, dy):
    """Gradient w.r.t. the filter: colsᵀ @ dy, through the Pallas GEMM."""
    n, ho, wo, rsc = cols.shape
    cout = dy.shape[-1]
    a = cols.reshape(n * ho * wo, rsc).T
    bmat = dy.reshape(n * ho * wo, cout)
    return matmul(a, bmat)  # (rsc, cout)


# ----------------------------------------------------------------------------
# Forward pass with intermediate capture.
# ----------------------------------------------------------------------------
def forward(params: Dict[str, jnp.ndarray], x: jnp.ndarray):
    """Run the network, returning logits plus everything backward needs."""
    acts = {}  # post-ReLU activations a_i
    masks = {}  # ReLU zero-footprints m_i
    cols_cache = {}
    cur = x
    for i, (_, _spec, stride) in enumerate(CONV_SPECS, start=1):
        z, cols = conv2d(cur, params[f"w{i}"], params[f"b{i}"], stride)
        a, m = relu_with_mask(z)
        acts[i], masks[i], cols_cache[i] = a, m, cols
        cur = a
    pooled = cur.mean(axis=(1, 2))  # (N, FC_IN)
    logits = matmul(pooled, params["wf"]) + params["bf"]
    return logits, acts, masks, cols_cache, pooled


def softmax_xent(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, NUM_CLASSES, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def _dlogits(logits, labels):
    onehot = jax.nn.one_hot(labels, NUM_CLASSES, dtype=logits.dtype)
    return (jax.nn.softmax(logits) - onehot) / logits.shape[0]


# ----------------------------------------------------------------------------
# Hand-written backward pass (the paper's BP, with output sparsity).
# ----------------------------------------------------------------------------
def backward(params, x, labels, logits, acts, masks, cols_cache, pooled):
    """Gradients for every parameter + the masked gradient maps g_i.

    g_i is the error gradient at the *output* of ReLU_i in the backward
    pass — the tensor whose zero footprint is provably a superset of
    act_i's zero footprint (paper section 3.2). The conv input-gradient
    GEMMs use the masked output-sparsity kernel.
    """
    grads = {}
    dlog = _dlogits(logits, labels)
    grads["wf"] = matmul(pooled.T, dlog)
    grads["bf"] = dlog.sum(axis=0)
    dpooled = matmul(dlog, params["wf"].T)

    # Un-pool: gradient of mean over HxW broadcasts evenly.
    n, h4, w4, c4 = acts[4].shape
    da = jnp.broadcast_to(
        dpooled[:, None, None, :] / (h4 * w4), (n, h4, w4, c4)
    )

    gmaps = {}
    for i in range(len(CONV_SPECS), 0, -1):
        _, (r, s, cin, cout), stride = CONV_SPECS[i - 1]
        # Through ReLU_i: Hadamard with the recorded mask. For the topmost
        # layer this is explicit; for lower layers it was fused into the
        # masked GEMM that produced `da` (footprints match, so applying
        # the mask again is the identity — asserted in tests).
        dz = da * masks[i]
        gmaps[i] = dz
        grads[f"w{i}"] = conv2d_bwd_weights(cols_cache[i], dz).reshape(r, s, cin, cout)
        grads[f"b{i}"] = dz.sum(axis=(0, 1, 2))
        if i > 1:
            below = acts[i - 1]
            da = conv2d_bwd_input(
                dz,
                params[f"w{i}"],
                stride,
                (below.shape[1], below.shape[2]),
                mask=masks[i - 1],
            )
        # i == 1: input gradient of the image is not needed.
    return grads, gmaps


# ----------------------------------------------------------------------------
# AOT entry points.
# ----------------------------------------------------------------------------
def loss_fn(params, x, labels):
    logits, *_ = forward(params, x)
    return softmax_xent(logits, labels)


def train_step(*args):
    """One SGD step. Inputs: 10 params in `PARAM_ORDER`, then x, labels.
    Returns (updated params..., loss)."""
    flat_params, x, labels = list(args[:-2]), args[-2], args[-1]
    params = params_dict(flat_params)
    logits, acts, masks, cols_cache, pooled = forward(params, x)
    loss = softmax_xent(logits, labels)
    grads, _ = backward(params, x, labels, logits, acts, masks, cols_cache, pooled)
    new = [params[k] - LR * grads[k] for k in PARAM_ORDER]
    return tuple(new) + (loss,)


def step_traces(*args):
    """Loss + per-layer activations and masked gradient maps.

    Used by the rust coordinator to extract *real* sparsity traces: the
    a_i give forward feature sparsity, the g_i give backward gradient
    sparsity, and footprint(g_i) ⊆ footprint(a_i) is the paper's identity.
    Output order: (loss, a1..a4, g1..g4).
    """
    flat_params, x, labels = list(args[:-2]), args[-2], args[-1]
    params = params_dict(flat_params)
    logits, acts, masks, cols_cache, pooled = forward(params, x)
    loss = softmax_xent(logits, labels)
    _, gmaps = backward(params, x, labels, logits, acts, masks, cols_cache, pooled)
    k = len(CONV_SPECS)
    return (loss,) + tuple(acts[i] for i in range(1, k + 1)) + tuple(
        gmaps[i] for i in range(1, k + 1)
    )


def gemm_demo(a, b):
    """Tiny standalone GEMM entry for the quickstart example."""
    return (matmul(a, b),)


# ----------------------------------------------------------------------------
# Pure-jnp reference model (no Pallas) for gradient validation.
# ----------------------------------------------------------------------------
def loss_ref(params, x, labels):
    """Same network in textbook jnp ops; `jax.grad` of this is the oracle
    for the hand-written backward pass."""
    cur = x
    for i, (_, _spec, stride) in enumerate(CONV_SPECS, start=1):
        w = params[f"w{i}"]
        pad = w.shape[0] // 2
        z = jax.lax.conv_general_dilated(
            cur,
            w,
            window_strides=(stride, stride),
            padding=((pad, pad), (pad, pad)),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + params[f"b{i}"]
        cur = jax.nn.relu(z)
    pooled = cur.mean(axis=(1, 2))
    logits = pooled @ params["wf"] + params["bf"]
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, NUM_CLASSES, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def example_batch(batch: int = BATCH, seed: int = 0):
    key = jax.random.PRNGKey(seed + 1000)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (batch, IMG, IMG, IN_CH), jnp.float32)
    labels = jax.random.randint(ky, (batch,), 0, NUM_CLASSES, jnp.int32)
    return x, labels
