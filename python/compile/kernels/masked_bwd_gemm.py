"""Masked backward GEMM — the paper's *output sparsity*, TPU-adapted.

Paper setting (section 3.2, Fig 5): in the chain ``CONV1 -> ReLU -> CONV2``
the backward pass computes ``delta2 = delta3 @ W2^T`` and then
``delta1 = delta2 * relu_mask`` where ``relu_mask`` is the zero footprint
of the *forward* activation. Every output location where the mask is zero
is wasted work — it can be skipped *before* it is computed, because the
mask is known from the forward pass.

The ASIC skips individual output neurons per computation lane. A TPU has
no per-lane skip, so the insight is re-tiled (DESIGN.md Hardware-
Adaptation): the mask is consulted at *block* granularity. For each
(bm, bn) output tile we reduce its mask block to an occupancy bit; dead
tiles skip the whole K-loop of MXU passes and write zeros. Live tiles
compute densely and apply the mask element-wise on the final K step —
per-element skipping does not pay on a systolic array, but a skipped tile
saves both the MXU passes and (with scalar-prefetch grid filtering on real
hardware) the HBM->VMEM transfers of its operand tiles.

The occupancy test uses the mask block already mapped into VMEM; the MXU
work inside a dead tile is fully elided by ``pl.when``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .gemm import _pad_to, auto_blocks


def _masked_kernel(dy_ref, wt_ref, mask_ref, o_ref):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Occupancy of this output tile: any surviving (non-masked) element?
    occupied = jnp.any(mask_ref[...] != 0)

    @pl.when(occupied)
    def _compute():
        o_ref[...] += jnp.dot(
            dy_ref[...], wt_ref[...], preferred_element_type=o_ref.dtype
        )

    @pl.when(k == nk - 1)
    def _apply_mask():
        # Element-wise ReLU-derivative application (Hadamard with the
        # 0/1 mask). Dead tiles stay at their zero initialization.
        o_ref[...] *= mask_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def masked_bwd_matmul(dy, wt, mask, *, bm: int = 0, bn: int = 0, bk: int = 0):
    """``(dy @ wt) * mask`` with block-granular output skipping.

    Args:
        dy:   (M, K) incoming gradient (may itself be sparse — input
              sparsity; on TPU that is exploited upstream, not here).
        wt:   (K, N) transposed weights.
        mask: (M, N) 0/1 ReLU zero-footprint from the forward pass.

    Returns:
        (M, N) f32 gradient with the mask applied.
    """
    if dy.ndim != 2 or wt.ndim != 2 or mask.ndim != 2:
        raise ValueError("masked_bwd_matmul expects 2-D operands")
    m, k = dy.shape
    k2, n = wt.shape
    if k != k2 or mask.shape != (m, n):
        raise ValueError(f"shape mismatch dy={dy.shape} wt={wt.shape} mask={mask.shape}")
    abm, abn, abk = auto_blocks(m, k, n)
    bm, bn, bk = bm or abm, bn or abn, bk or abk
    dyp = _pad_to(_pad_to(dy, bm, 0), bk, 1)
    wtp = _pad_to(_pad_to(wt, bk, 0), bn, 1)
    maskp = _pad_to(_pad_to(mask, bm, 0), bn, 1)
    mp, kp = dyp.shape
    _, np_ = wtp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _masked_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(dyp, wtp, maskp)
    return out[:m, :n]


def block_skip_fraction(mask, bm: int = 128, bn: int = 128):
    """Fraction of (bm, bn) output tiles that are entirely dead.

    This is the structural speedup the TPU adaptation realizes (the ASIC
    realizes the *element*-level fraction; see sim/ for that model).
    """
    mask = jnp.asarray(mask)
    m, n = mask.shape
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    padded = jnp.pad(mask, ((0, mp - m), (0, np_ - n)))
    blocks = padded.reshape(mp // bm, bm, np_ // bn, bn)
    occupancy = jnp.any(blocks != 0, axis=(1, 3))
    return 1.0 - jnp.mean(occupancy.astype(jnp.float32))
