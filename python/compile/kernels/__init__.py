"""L1 Pallas kernels for the AGOS reproduction.

Every kernel is authored for TPU-style execution (VMEM tiles, MXU matmul)
but lowered with ``interpret=True`` so the CPU PJRT client can execute the
resulting HLO -- see DESIGN.md "Hardware-Adaptation".

Modules:
    gemm             -- tiled dense GEMM (the workhorse behind conv/fc)
    masked_bwd_gemm  -- the paper's contribution at kernel level: backward
                        GEMM with ReLU-mask *output sparsity* block skipping
    relu             -- fused ReLU forward + zero-footprint mask emission
    ref              -- pure-jnp oracles for all of the above
"""
