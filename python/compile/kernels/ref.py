"""Pure-jnp oracles for every L1 kernel.

These are the correctness ground truth: `python/tests/` asserts the Pallas
kernels match these within float tolerance across hypothesis-driven
shape/dtype/sparsity sweeps.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Dense GEMM oracle (f32 accumulation, like the kernel)."""
    return jnp.matmul(
        x.astype(jnp.float32), y.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def masked_bwd_matmul_ref(dy, wt, mask):
    """(dy @ wt) * mask -- what output sparsity must be numerically
    indistinguishable from."""
    return matmul_ref(dy, wt) * mask.astype(jnp.float32)


def relu_with_mask_ref(x):
    mask = (x > 0).astype(x.dtype)
    return x * mask, mask
