"""Fused ReLU forward + zero-footprint mask emission, as a Pallas kernel.

The forward pass must record *where* activations were zeroed: that
footprint is exactly the backward-pass output-sparsity oracle (paper
section 3.2). Fusing the mask emission into the ReLU avoids a second pass
over the activation tensor — on the ASIC this is the "pool and encoder
unit" attached to the PE register array; on TPU it is a second VMEM output
written in the same grid step.

The mask is emitted as f32 0/1 (not bool) so it feeds the Hadamard in
``masked_bwd_gemm`` and the NZ-encoder path downstream without a cast.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _relu_mask_kernel(x_ref, y_ref, m_ref):
    x = x_ref[...]
    mask = (x > 0).astype(y_ref.dtype)
    y_ref[...] = x * mask
    m_ref[...] = mask


def _flat_block(n: int) -> int:
    for cand in (4096, 2048, 1024, 512, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if n % cand == 0:
            return cand
    return 1


@jax.jit
def relu_with_mask(x):
    """ReLU(x) and its 0/1 zero-footprint mask, any shape.

    Returns ``(y, mask)`` with ``y = max(x, 0)`` and
    ``mask = (x > 0)`` as the same dtype as ``y``.
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    block = _flat_block(n)
    grid = (n // block,)
    y, m = pl.pallas_call(
        _relu_mask_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=True,
    )(flat)
    return y.reshape(shape), m.reshape(shape)
