"""Tiled dense GEMM as a Pallas kernel.

This is the MXU-shaped building block behind every conv (via im2col) and
fully-connected layer in the L2 model. The grid is (M/bm, N/bn, K/bk) with
a VMEM accumulator tile revisited across the K axis — the canonical TPU
matmul schedule. Block shapes default to (128, 128, 128)-capped tiles so a
double-buffered pair of input tiles plus the accumulator stays well under
VMEM (see DESIGN.md section 8 for the footprint arithmetic).

Lowered with ``interpret=True``: on CPU the same HLO executes through the
PJRT CPU client; on a real TPU the identical kernel body would lower to a
Mosaic custom call.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bk) x (bk, bn) contribution into the (bm, bn) output tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU path: bf16/f32 matmul with f32 accumulation.
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _pick_block(dim: int, cap: int) -> int:
    """Largest divisor of ``dim`` that is <= cap (prefers powers of two)."""
    for cand in (cap, 256, 128, 64, 32, 16, 8, 4, 2, 1):
        if cand <= cap and dim % cand == 0:
            return cand
    return 1


def _pad_to(x, multiple, axis):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, multiple - rem)
    return jnp.pad(x, pad)


def _ceil_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def auto_blocks(m: int, k: int, n: int, cap: int = 128):
    """Block shapes adapted to the problem: never pad an axis beyond the
    next power of two (a 27-deep im2col GEMM must not be padded to 128 —
    that inflated CPU interpret-mode work ~5x; see EXPERIMENTS.md §Perf)."""
    return (
        min(cap, _ceil_pow2(m)),
        min(cap, _ceil_pow2(n)),
        min(cap, _ceil_pow2(k)),
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm: int = 0, bn: int = 0, bk: int = 0):
    """``x @ y`` via the Pallas kernel.

    Arbitrary (M, K) x (K, N) shapes: inputs are zero-padded up to block
    multiples (zero rows/cols contribute nothing) and the result is sliced
    back. Accumulation is always f32. Block sizes default to
    `auto_blocks` (pass explicit values to override).
    """
    if x.ndim != 2 or y.ndim != 2 or x.shape[1] != y.shape[0]:
        raise ValueError(f"matmul shapes {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    abm, abn, abk = auto_blocks(m, k, n)
    bm, bn, bk = bm or abm, bn or abn, bk or abk
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    yp = _pad_to(_pad_to(y, bk, 0), bn, 1)
    mp, kp = xp.shape
    _, np_ = yp.shape
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n].astype(jnp.promote_types(x.dtype, jnp.float32))


def vmem_footprint_bytes(bm: int = 128, bn: int = 128, bk: int = 128,
                         bytes_per_elem: int = 4, double_buffered: bool = True):
    """Estimated VMEM bytes for the chosen block shapes (for DESIGN.md §8).

    Two input tiles + one accumulator tile; double buffering doubles the
    *input* tiles only (the accumulator is revisited, not re-fetched).
    """
    inputs = (bm * bk + bk * bn) * bytes_per_elem
    acc = bm * bn * 4  # f32 accumulator
    return inputs * (2 if double_buffered else 1) + acc
