"""L2 model: hand-written backward vs jax.grad, shapes, training signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

BATCH = 4  # small batch keeps interpret-mode tests quick


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(0)
    x, labels = M.example_batch(BATCH, 0)
    logits, acts, masks, cols, pooled = M.forward(params, x)
    return params, x, labels, logits, acts, masks, cols, pooled


def test_forward_shapes(setup):
    params, x, labels, logits, acts, masks, cols, pooled = setup
    assert logits.shape == (BATCH, M.NUM_CLASSES)
    assert acts[1].shape == (BATCH, 32, 32, 16)
    assert acts[2].shape == (BATCH, 16, 16, 32)
    assert acts[3].shape == (BATCH, 16, 16, 32)
    assert acts[4].shape == (BATCH, 8, 8, 64)
    assert pooled.shape == (BATCH, M.FC_IN)
    for i in range(1, 5):
        assert masks[i].shape == acts[i].shape


def test_loss_matches_reference_model(setup):
    params, x, labels, *_ = setup
    l1 = float(M.loss_fn(params, x, labels))
    l2 = float(M.loss_ref(params, x, labels))
    assert abs(l1 - l2) < 1e-4, (l1, l2)


def test_handwritten_grads_match_autodiff(setup):
    params, x, labels, logits, acts, masks, cols, pooled = setup
    grads, _ = M.backward(params, x, labels, logits, acts, masks, cols, pooled)
    ref = jax.grad(M.loss_ref)(params, x, labels)
    for k in M.PARAM_ORDER:
        a, b = np.asarray(grads[k]), np.asarray(ref[k])
        denom = np.max(np.abs(b)) + 1e-8
        assert np.max(np.abs(a - b)) / denom < 1e-4, k


def test_masks_record_zero_footprint(setup):
    _, _, _, _, acts, masks, _, _ = setup
    for i in range(1, 5):
        a, m = np.asarray(acts[i]), np.asarray(masks[i])
        assert np.all((a > 0) == (m == 1.0))
        assert np.all(a[m == 0] == 0)


def test_train_step_decreases_loss():
    params = M.init_params(0)
    flat = M.params_list(params)
    x, labels = M.example_batch(BATCH, 1)
    out = M.train_step(*flat, x, labels)
    loss0 = float(out[-1])
    flat = list(out[:-1])
    # a few more steps on the same batch must reduce the loss
    for _ in range(3):
        out = M.train_step(*flat, x, labels)
        flat = list(out[:-1])
    loss3 = float(out[-1])
    assert loss3 < loss0, (loss0, loss3)


def test_step_traces_output_contract():
    params = M.init_params(0)
    flat = M.params_list(params)
    x, labels = M.example_batch(BATCH, 2)
    out = M.step_traces(*flat, x, labels)
    assert len(out) == 1 + 4 + 4
    loss = out[0]
    assert loss.shape == ()
    for i in range(1, 5):
        assert out[i].shape == out[i + 4].shape  # a_i matches g_i


def test_im2col_feature_order():
    """im2col feature order must be (r, s, c) to match W.reshape."""
    x = jnp.arange(2 * 4 * 4 * 3, dtype=jnp.float32).reshape(2, 4, 4, 3)
    cols = M.im2col(x, 3, 3, 1, 1)
    assert cols.shape == (2, 4, 4, 27)
    # centre tap (r=1,s=1) of the patch at (1,1) is x[:,1,1,:]
    np.testing.assert_array_equal(
        np.asarray(cols[:, 1, 1, 4 * 3 : 5 * 3]), np.asarray(x[:, 1, 1, :])
    )


@pytest.mark.parametrize("stride", [1, 2])
def test_conv_matches_lax(stride):
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 5))
    y, _ = M.conv2d(x, w, jnp.zeros(5), stride)
    ref = jax.lax.conv_general_dilated(
        x, w, (stride, stride), ((1, 1), (1, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("stride", [1, 2])
def test_conv_bwd_input_matches_vjp(stride):
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(3), (3, 3, 3, 5))
    f = lambda xx: jax.lax.conv_general_dilated(
        xx, w, (stride, stride), ((1, 1), (1, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = f(x)
    dy = jax.random.normal(jax.random.PRNGKey(4), y.shape)
    _, vjp = jax.vjp(f, x)
    want = vjp(dy)[0]
    got = M.conv2d_bwd_input(dy, w, stride, (8, 8))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("stride", [1, 2])
def test_conv_bwd_weights_matches_vjp(stride):
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(6), (3, 3, 3, 5))
    f = lambda ww: jax.lax.conv_general_dilated(
        x, ww, (stride, stride), ((1, 1), (1, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = f(w)
    dy = jax.random.normal(jax.random.PRNGKey(7), y.shape)
    _, vjp = jax.vjp(f, w)
    want = vjp(dy)[0]
    _, cols = M.conv2d(x, w, jnp.zeros(5), stride)
    got = M.conv2d_bwd_weights(cols, dy).reshape(3, 3, 3, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
