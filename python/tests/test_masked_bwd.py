"""Output-sparsity masked backward GEMM vs oracle + skip-accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.masked_bwd_gemm import masked_bwd_matmul, block_skip_fraction
from compile.kernels.ref import masked_bwd_matmul_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def rand_mask(key, shape, sparsity):
    u = jax.random.uniform(jax.random.PRNGKey(key), shape)
    return (u >= sparsity).astype(jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 64),
    n=st.integers(1, 80),
    sparsity=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_masked_matches_ref(m, k, n, sparsity, seed):
    dy = rand(seed, (m, k))
    wt = rand(seed + 1, (k, n))
    mask = rand_mask(seed + 2, (m, n), sparsity)
    got = masked_bwd_matmul(dy, wt, mask, bm=16, bn=16, bk=16)
    want = masked_bwd_matmul_ref(dy, wt, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_all_dead_mask_yields_zero():
    dy, wt = rand(0, (64, 32)), rand(1, (32, 64))
    mask = jnp.zeros((64, 64))
    out = masked_bwd_matmul(dy, wt, mask, bm=16, bn=16, bk=16)
    assert float(jnp.abs(out).max()) == 0.0


def test_all_live_mask_equals_dense():
    dy, wt = rand(2, (48, 32)), rand(3, (32, 48))
    mask = jnp.ones((48, 48))
    out = masked_bwd_matmul(dy, wt, mask, bm=16, bn=16, bk=16)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(dy @ wt), rtol=1e-5, atol=1e-5
    )


def test_footprint_containment():
    """Output zero-footprint must contain the mask's zero-footprint."""
    dy, wt = rand(4, (64, 16)), rand(5, (16, 64))
    mask = rand_mask(6, (64, 64), 0.6)
    out = np.asarray(masked_bwd_matmul(dy, wt, mask, bm=16, bn=16, bk=16))
    assert np.all(out[np.asarray(mask) == 0] == 0.0)


def test_rejects_bad_shapes():
    with pytest.raises(ValueError):
        masked_bwd_matmul(jnp.zeros((4, 4)), jnp.zeros((5, 4)), jnp.zeros((4, 4)))
    with pytest.raises(ValueError):
        masked_bwd_matmul(jnp.zeros((4, 4)), jnp.zeros((4, 4)), jnp.zeros((3, 4)))


@pytest.mark.parametrize("sparsity,expect_lo,expect_hi", [
    (0.0, 0.0, 0.0),
    (1.0, 1.0, 1.0),
])
def test_block_skip_extremes(sparsity, expect_lo, expect_hi):
    mask = rand_mask(7, (256, 256), sparsity)
    frac = float(block_skip_fraction(mask, 16, 16))
    assert expect_lo <= frac <= expect_hi


def test_block_skip_structured():
    """A mask dead in exactly half its tiles reports 0.5 skip."""
    mask = jnp.ones((64, 64))
    mask = mask.at[:32, :].set(0.0)
    assert abs(float(block_skip_fraction(mask, 32, 32)) - 0.5) < 1e-6


@settings(max_examples=10, deadline=None)
@given(sparsity=st.floats(0.1, 0.9), seed=st.integers(0, 1000))
def test_block_skip_monotone_in_block_size(sparsity, seed):
    """Smaller tiles can only skip more (finer granularity)."""
    mask = rand_mask(seed, (128, 128), sparsity)
    small = float(block_skip_fraction(mask, 8, 8))
    large = float(block_skip_fraction(mask, 64, 64))
    assert small >= large - 1e-6
