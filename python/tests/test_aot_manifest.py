"""AOT artifact contract: manifest matches the files and the model config."""

import json
import pathlib

import pytest

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(),
    reason="artifacts not built (run `make artifacts`)",
)


@pytest.fixture(scope="module")
def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_entries_present_with_files(manifest):
    for name in ("train_step", "step_traces", "gemm_demo"):
        entry = manifest["entries"][name]
        f = ART / entry["file"]
        assert f.exists() and f.stat().st_size == entry["hlo_bytes"]


def test_hlo_is_text_not_proto(manifest):
    head = (ART / manifest["entries"]["train_step"]["file"]).read_text()[:200]
    assert "HloModule" in head


def test_train_step_signature(manifest):
    from compile import model as M

    e = manifest["entries"]["train_step"]
    # 10 params + x + labels
    assert len(e["inputs"]) == len(M.PARAM_ORDER) + 2
    # 10 params + loss
    assert len(e["outputs"]) == len(M.PARAM_ORDER) + 1
    assert e["outputs"][-1]["shape"] == []
    x_spec = e["inputs"][-2]
    assert x_spec["shape"] == [M.BATCH, M.IMG, M.IMG, M.IN_CH]


def test_step_traces_signature(manifest):
    e = manifest["entries"]["step_traces"]
    assert len(e["outputs"]) == 9
    # a_i and g_i shapes pair up
    for i in range(1, 5):
        assert e["outputs"][i]["shape"] == e["outputs"][i + 4]["shape"]


def test_params_files_match_shapes(manifest):
    for name, meta in manifest["params"].items():
        f = ART / meta["file"]
        n = 1
        for d in meta["shape"]:
            n *= d
        assert f.stat().st_size == 4 * n, name
