"""Fused ReLU+mask kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.relu import relu_with_mask
from compile.kernels.ref import relu_with_mask_ref

jax.config.update("jax_platform_name", "cpu")


@settings(max_examples=25, deadline=None)
@given(
    shape=st.sampled_from([(7,), (4, 5), (2, 3, 4), (2, 8, 8, 3), (1, 1)]),
    seed=st.integers(0, 2**16),
)
def test_relu_mask_matches_ref(shape, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    y, m = relu_with_mask(x)
    yr, mr = relu_with_mask_ref(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))


def test_mask_is_zero_footprint():
    x = jnp.array([[-1.0, 0.0, 2.0], [3.0, -4.0, 0.0]])
    y, m = relu_with_mask(x)
    np.testing.assert_array_equal(np.asarray(m), [[0, 0, 1], [1, 0, 0]])
    assert np.all(np.asarray(y)[np.asarray(m) == 0] == 0)


def test_mask_dtype_follows_input():
    x = jnp.ones((8,), jnp.bfloat16)
    y, m = relu_with_mask(x)
    assert y.dtype == jnp.bfloat16 and m.dtype == jnp.bfloat16
