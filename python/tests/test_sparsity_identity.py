"""The paper's core law (section 3.2): the backward gradient after ReLU has
the *identical* zero footprint as the forward activation.

Exactly: footprint(g_i) == footprint(a_i) up to elements where the
incoming gradient happens to be exactly zero (a measure-zero event for
continuous inputs, plus structurally-zero rows from upstream masking).
We therefore assert containment footprint(a_i)==0 => g_i == 0 exactly,
and near-equality of the sparsity fractions.
"""

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M

jax.config.update("jax_platform_name", "cpu")


def run_traces(seed, batch=4):
    params = M.init_params(seed)
    flat = M.params_list(params)
    x, labels = M.example_batch(batch, seed)
    out = M.step_traces(*flat, x, labels)
    acts = [np.asarray(a) for a in out[1:5]]
    gmaps = [np.asarray(g) for g in out[5:9]]
    return acts, gmaps


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 50))
def test_gradient_zero_wherever_activation_zero(seed):
    acts, gmaps = run_traces(seed)
    for a, g in zip(acts, gmaps):
        assert np.all(g[a == 0] == 0.0)


def test_sparsity_fractions_nearly_identical():
    acts, gmaps = run_traces(0)
    for i, (a, g) in enumerate(zip(acts, gmaps)):
        sa = (a == 0).mean()
        sg = (g == 0).mean()
        # g can only be MORE sparse (numerically-zero gradients)
        assert sg >= sa - 1e-6, (i, sa, sg)
        assert sg - sa < 0.05, f"layer {i}: act {sa:.3f} vs grad {sg:.3f}"


def test_sparsity_in_papers_observed_band():
    """Fig 3d: dynamic sparsity of ReLU CNNs sits in the ~30-70% band."""
    acts, _ = run_traces(0, batch=8)
    for i, a in enumerate(acts):
        s = (a == 0).mean()
        assert 0.2 < s < 0.8, f"layer {i} sparsity {s:.3f} outside band"
