"""Pallas GEMM kernel vs the pure-jnp oracle, hypothesis-driven."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gemm import matmul, vmem_footprint_bytes, _pick_block, _pad_to
from compile.kernels.ref import matmul_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref_f32(m, k, n, seed):
    x = rand(seed, (m, k), jnp.float32)
    y = rand(seed + 1, (k, n), jnp.float32)
    got = matmul(x, y, bm=32, bn=32, bk=32)
    want = matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 17, 64]),
    k=st.sampled_from([8, 33, 64]),
    n=st.sampled_from([8, 19, 64]),
    seed=st.integers(0, 100),
)
def test_matmul_matches_ref_bf16(m, k, n, seed):
    x = rand(seed, (m, k), jnp.bfloat16)
    y = rand(seed + 7, (k, n), jnp.bfloat16)
    got = matmul(x, y, bm=32, bn=32, bk=32)
    want = matmul_ref(x, y)
    # bf16 inputs, f32 accumulation: tolerance driven by input rounding.
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("blocks", [(8, 8, 8), (16, 32, 8), (64, 64, 64)])
def test_matmul_block_shape_independent(blocks):
    bm, bn, bk = blocks
    x = rand(3, (40, 24), jnp.float32)
    y = rand(4, (24, 56), jnp.float32)
    got = matmul(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(x, y)), rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_shapes():
    x = jnp.zeros((3, 4))
    y = jnp.zeros((5, 6))
    with pytest.raises(ValueError):
        matmul(x, y)
    with pytest.raises(ValueError):
        matmul(jnp.zeros((3,)), y)


def test_pick_block_divides():
    for dim in [1, 7, 32, 96, 100, 1024]:
        b = _pick_block(dim, 128)
        assert dim % b == 0 and b <= 128


def test_pad_to_shapes():
    x = jnp.ones((5, 7))
    assert _pad_to(x, 8, 0).shape == (8, 7)
    assert _pad_to(x, 7, 1).shape == (5, 7)
    # padded region is zero
    assert float(_pad_to(x, 8, 0)[5:].sum()) == 0.0


def test_vmem_footprint_under_budget():
    # default blocks with double buffering must fit a 16 MB VMEM easily
    assert vmem_footprint_bytes() < 16 * 1024 * 1024
    assert vmem_footprint_bytes(double_buffered=False) < vmem_footprint_bytes()
