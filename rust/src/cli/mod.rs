//! The `agos` command-line interface.
//!
//! ```text
//! agos train     --steps 300 --trace-every 50 --out results/train.json
//! agos trace     --network agos_cnn --steps 4 --out results/traces.json
//! agos simulate  --network vgg16 --scheme in+out+wr --batch 16
//! agos sweep     --networks all --schemes all --jobs 8 --out results/sweep.json
//! agos figure    all --jobs 8 --out results/
//! agos table     table2
//! agos sparsity  --network resnet18
//! agos cosim     --traces results/traces.json --replay --backend exact
//! agos info
//! ```

use std::path::{Path, PathBuf};

use crate::config::{
    AcceleratorConfig, BitmapPattern, ExecBackend, GatherMode, Scheme, SimOptions, TraceFormat,
    TrainOptions,
};
use crate::coordinator::{cosim_from_traces_owned, run_training_pipeline, PreparedCosim};
use crate::nn::{zoo, Network, Phase};
use crate::report::{benchmarks_from_scenario, benchmarks_from_trace, generate, ReportCtx};
use crate::scenario::{
    adversarial_trace, scenario_report_json, trajectory_figure, AdversarialPattern, ScenarioFile,
};
use crate::sim::{simulate_network, sweep_report_json, SweepPlan, SweepRunner};
use crate::sparsity::{analyze_network, capture_synthetic_trace_images, SparsityModel};
use crate::trace::TraceFile;
use crate::util::cli::{App, Args, Command, OptSpec};
use crate::util::json::Json;

fn opt(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, takes_value: true, help }
}

fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, takes_value: false, help }
}

fn app() -> App {
    App {
        name: "agos",
        about: "activation-based gradient output sparsity accelerator (paper reproduction)",
        commands: vec![
            Command {
                name: "train",
                about: "train the small CNN through the AOT artifacts (PJRT)",
                opts: vec![
                    opt("steps", "optimizer steps (default 300)"),
                    opt("trace-every", "extract sparsity traces every N steps (default 50)"),
                    opt(
                        "trace-images",
                        "images captured per traced step, each its own trace step (default 1)",
                    ),
                    opt(
                        "trace-format",
                        "trace payload encoding: v2|v3|v4 (default v3 delta/RLE; v4 streams \
a binary <out>.trace.bin sidecar with bounded memory)",
                    ),
                    opt("seed", "dataset seed (default 7)"),
                    opt("artifacts", "artifacts directory (default artifacts)"),
                    opt("out", "write loss curve + traces JSON here"),
                ],
            },
            Command {
                name: "trace",
                about: "synthesize a trace file with packed bitmap payloads (no PJRT needed)",
                opts: vec![
                    opt("network", "network to capture (default agos_cnn)"),
                    opt("steps", "traced steps to synthesize (default 4)"),
                    opt(
                        "trace-images",
                        "images captured per traced step, each its own trace step (default 1)",
                    ),
                    opt(
                        "trace-format",
                        "trace payload encoding: v2|v3|v4 binary (default v3 delta/RLE)",
                    ),
                    opt("seed", "sparsity model seed"),
                    opt("pattern", "iid|blobs bitmap structure (default iid)"),
                    opt("blob-radius", "blob radius for --pattern blobs (default 2)"),
                    opt("out", "trace JSON path (default results/traces.json)"),
                    opt(
                        "scenario",
                        "scenario JSON file: capture one trace per expanded point into --out \
(a directory; the file owns --network/--seed — see docs/SCENARIOS.md)",
                    ),
                ],
            },
            Command {
                name: "simulate",
                about: "simulate a network on the accelerator",
                opts: vec![
                    opt("network", "vgg16|resnet18|googlenet|densenet121|mobilenet|agos_cnn"),
                    opt("scheme", "DC|IN|IN+OUT|IN+OUT+WR (default IN+OUT+WR)"),
                    opt("batch", "batch size (default 16)"),
                    opt("seed", "sparsity model seed"),
                    opt("config", "accelerator config JSON file"),
                    opt("backend", "analytic|exact execution backend (default analytic)"),
                    opt("exact-cap", "exact backend: sampled outputs per tile (default 4096)"),
                    opt("pattern", "exact backend: iid|blobs sampled-bitmap structure"),
                    opt("blob-radius", "blob radius for --pattern blobs (default 2)"),
                ],
            },
            Command {
                name: "sweep",
                about: "parallel cached (networks x schemes) simulation sweep",
                opts: vec![
                    opt("networks", "comma-separated names or 'all' (default all)"),
                    opt("schemes", "comma-separated schemes or 'all' (default all)"),
                    opt(
                        "scenario",
                        "scenario JSON file: expand a generated family x sparsity phases through \
the cached runner (the file owns --networks/--schemes/--seed — see docs/SCENARIOS.md)",
                    ),
                    opt("batch", "batch size (default 16)"),
                    opt("seed", "sparsity model seed"),
                    opt("jobs", "worker threads (default: all cores)"),
                    opt("config", "accelerator config JSON file"),
                    opt("backend", "analytic|exact execution backend (default analytic)"),
                    opt("exact-cap", "exact backend: sampled outputs per tile (default 4096)"),
                    opt("pattern", "exact backend: iid|blobs sampled-bitmap structure"),
                    opt("blob-radius", "blob radius for --pattern blobs (default 2)"),
                    opt("cache", "sweep cache file, or 'none' (default results/sweep-cache.json)"),
                    opt("out", "write sweep results JSON here"),
                ],
            },
            Command {
                name: "figure",
                about: "regenerate a paper figure (fig3b fig3d fig11a fig11b fig12a fig12b \
fig13 fig15 fig16 fig17 figval platforms | ablations | all)",
                opts: vec![
                    opt("out", "also write results JSON into this directory"),
                    opt("batch", "batch size (default 16)"),
                    opt("seed", "sparsity model seed"),
                    opt("jobs", "sweep worker threads (default: all cores)"),
                    opt("backend", "analytic|exact execution backend (default analytic)"),
                    opt("exact-cap", "exact backend: sampled outputs per tile (default 4096)"),
                    opt("pattern", "exact backend: iid|blobs sampled-bitmap structure"),
                    opt("blob-radius", "blob radius for --pattern blobs (default 2)"),
                    opt("cache", "sweep cache file, or 'none' (default results/sweep-cache.json)"),
                    opt(
                        "traces",
                        "platform comparison: benchmark the trace's network under its \
measured sparsity (table2/platforms)",
                    ),
                    opt(
                        "scenario",
                        "platform comparison: one benchmark per expanded scenario point \
(the file owns --seed — see docs/SCENARIOS.md)",
                    ),
                    flag("replay", "with --traces: drive the comparison from the packed bitmaps"),
                ],
            },
            Command {
                name: "table",
                about: "regenerate a paper table (table1 | table2)",
                opts: vec![
                    opt("out", "also write results JSON into this directory"),
                    opt("batch", "batch size (default 16)"),
                    opt("seed", "sparsity model seed"),
                    opt("jobs", "sweep worker threads (default: all cores)"),
                    opt("backend", "analytic|exact execution backend (default analytic)"),
                    opt("exact-cap", "exact backend: sampled outputs per tile (default 4096)"),
                    opt("pattern", "exact backend: iid|blobs sampled-bitmap structure"),
                    opt("blob-radius", "blob radius for --pattern blobs (default 2)"),
                    opt("cache", "sweep cache file, or 'none' (default results/sweep-cache.json)"),
                    opt(
                        "traces",
                        "platform comparison: benchmark the trace's network under its \
measured sparsity (table2)",
                    ),
                    opt(
                        "scenario",
                        "platform comparison: one benchmark per expanded scenario point \
(the file owns --seed — see docs/SCENARIOS.md)",
                    ),
                    flag("replay", "with --traces: drive the comparison from the packed bitmaps"),
                ],
            },
            Command {
                name: "sparsity",
                about: "print the per-layer sparsity-opportunity analysis",
                opts: vec![opt("network", "network name"), opt("seed", "model seed")],
            },
            Command {
                name: "cosim",
                about: "co-simulate measured traces on the accelerator",
                opts: vec![
                    opt("traces", "trace JSON from `agos train --out` or `agos trace`"),
                    opt("batch", "batch size (default 16)"),
                    opt("backend", "analytic|exact execution backend (default analytic)"),
                    opt("exact-cap", "exact backend: sampled outputs per tile (default 4096)"),
                    opt("pattern", "exact backend: iid|blobs sampled-bitmap structure"),
                    opt("blob-radius", "blob radius for --pattern blobs (default 2)"),
                    opt("gather", "replay window assembly: geometry|streaming (default geometry)"),
                    opt("jobs", "worker threads (default: all cores; results identical)"),
                    opt("out", "write the co-simulation report JSON here"),
                    flag(
                        "replay",
                        "replay the trace's packed v2 bitmaps: geometry-exact patterns (exact) \
or measured per-tile densities (analytic)",
                    ),
                    flag(
                        "verbose",
                        "also print gather-plan skip-effectiveness counters (exact backend; \
diagnostics only, never written to --out)",
                    ),
                ],
            },
            Command {
                name: "serve",
                about: "run the resident sweep/replay service on a Unix socket",
                opts: vec![
                    opt("socket", "Unix socket path (default results/agos.sock)"),
                    opt("jobs", "sweep worker threads per request (default: all cores)"),
                    opt("workers", "concurrent request handlers (default 4)"),
                    opt("cache", "sweep cache file, or 'none' (default results/sweep-cache.json)"),
                ],
            },
            Command {
                name: "request",
                about: "send one JSON request to a running `agos serve`",
                opts: vec![
                    opt("socket", "Unix socket path (default results/agos.sock)"),
                    opt("json", "inline request document, e.g. '{\"cmd\":\"ping\"}'"),
                    opt("file", "read the request document from this file"),
                    opt("out", "write the response's result here (same bytes as the cold --out)"),
                    opt("timeout", "seconds to wait for the server socket (default 10)"),
                    flag("ping", "shorthand for '{\"cmd\":\"ping\"}'"),
                    flag("shutdown", "shorthand for '{\"cmd\":\"shutdown\"}'"),
                ],
            },
            Command {
                name: "bench-check",
                about: "gate bench output against the committed perf baseline",
                opts: vec![
                    opt("current", "bench output JSON (default BENCH_sweep.json)"),
                    opt("baseline", "committed baseline JSON (default BENCH_baseline.json)"),
                    flag("bless", "rewrite the baseline from the current measurements"),
                ],
            },
            Command {
                name: "info",
                about: "show artifact manifest and design-point summary",
                opts: vec![opt("artifacts", "artifacts directory (default artifacts)")],
            },
        ],
    }
}

/// CLI entry point; returns the exit code.
pub fn run(argv: &[String]) -> anyhow::Result<i32> {
    let parsed = match app().parse(argv) {
        Ok(Some(p)) => p,
        Ok(None) => return Ok(0), // help shown
        Err(msg) => {
            eprintln!("{msg}");
            return Ok(2);
        }
    };
    let args = &parsed.args;
    match parsed.command.as_str() {
        "train" => cmd_train(args),
        "trace" => cmd_trace(args),
        "simulate" => cmd_simulate(args),
        "sweep" => cmd_sweep(args),
        "figure" => cmd_figure(args),
        "table" => cmd_figure(args), // same dispatch: ids disambiguate
        "sparsity" => cmd_sparsity(args),
        "cosim" => cmd_cosim(args),
        "serve" => cmd_serve(args),
        "request" => cmd_request(args),
        "bench-check" => cmd_bench_check(args),
        "info" => cmd_info(args),
        other => anyhow::bail!("unhandled command {other}"),
    }
}

/// Default on-disk spill location for the sweep cache.
const SWEEP_CACHE_PATH: &str = "results/sweep-cache.json";

/// Apply the shared `--backend`/`--exact-cap`/`--pattern`/`--blob-radius`
/// selectors to sim options.
fn apply_backend_opts(opts: &mut SimOptions, args: &Args) -> anyhow::Result<()> {
    if let Some(b) = args.opt("backend") {
        opts.backend = ExecBackend::parse(b)?;
    }
    opts.exact_outputs_per_tile =
        args.opt_usize("exact-cap", opts.exact_outputs_per_tile)?;
    if let Some(p) = args.opt("pattern") {
        opts.pattern = BitmapPattern::parse(p)?;
    }
    opts.blob_radius = args.opt_usize("blob-radius", opts.blob_radius)?;
    if let Some(g) = args.opt("gather") {
        opts.gather = GatherMode::parse(g)?;
    }
    Ok(())
}

/// Resolve `--cache` (default `results/sweep-cache.json`; "none" disables).
fn sweep_cache_path(args: &Args) -> Option<PathBuf> {
    match args.opt_or("cache", SWEEP_CACHE_PATH) {
        "none" | "off" => None,
        p => Some(PathBuf::from(p)),
    }
}

/// Warm a runner from the on-disk spill; a corrupt file only warns so a
/// stale cache can never block a sweep.
fn load_sweep_cache(runner: &SweepRunner, path: &Option<PathBuf>) {
    if let Some(p) = path {
        match runner.cache().load_file(p) {
            Ok(n) if n > 0 => println!("sweep cache: loaded {n} results from {}", p.display()),
            Ok(_) => {}
            Err(e) => eprintln!("sweep cache: ignoring {}: {e}", p.display()),
        }
    }
}

fn save_sweep_cache(runner: &SweepRunner, path: &Option<PathBuf>) {
    if let Some(p) = path {
        // Nothing simulated → nothing new to spill; don't create
        // results/ (or rewrite the file) as a side effect of a pure
        // cache-hit or simulation-free command.
        if runner.cache().misses() == 0 {
            return;
        }
        match runner.cache().save_file(p) {
            Ok(()) => println!(
                "sweep cache: {} results spilled to {}",
                runner.cache().len(),
                p.display()
            ),
            Err(e) => eprintln!("sweep cache: failed to write {}: {e}", p.display()),
        }
    }
}

fn ctx_from(args: &Args) -> anyhow::Result<ReportCtx> {
    let mut ctx = ReportCtx::default();
    ctx.opts.batch = args.opt_usize("batch", 16)?;
    ctx.opts.seed = args.opt_u64("seed", ctx.opts.seed)?;
    apply_backend_opts(&mut ctx.opts, args)?;
    ctx.model = SparsityModel::synthetic(ctx.opts.seed);
    ctx.sweep = SweepRunner::new(args.opt_usize("jobs", 0)?);
    // Platform-comparison benchmark overrides (table2 / the `platforms`
    // figure): a scenario expands one benchmark per point; a trace file
    // benchmarks its network under the measured model, with `--replay`
    // additionally arming the packed bitmaps — the same arming as cosim.
    if let Some(path) = args.opt("scenario") {
        anyhow::ensure!(
            args.opt("traces").is_none() && !args.flag("replay"),
            "--scenario and --traces/--replay are mutually exclusive"
        );
        reject_scenario_owned(args, &["seed"])?;
        let scenario = ScenarioFile::load(Path::new(path))?;
        let ex = scenario.expand(&ctx.cfg, &ctx.opts)?;
        ctx.benchmarks = Some(benchmarks_from_scenario(&ex));
    } else if let Some(path) = args.opt("traces") {
        let (traces, warnings) = crate::trace::TraceFile::load_lenient(Path::new(path))?;
        for w in &warnings {
            eprintln!("figure: trace warning: {w}");
        }
        let replay = args.flag("replay");
        let prep = PreparedCosim::new_owned(traces, replay)?;
        ctx.benchmarks = Some(benchmarks_from_trace(&prep, &ctx.opts, replay)?);
    } else if args.flag("replay") {
        anyhow::bail!("--replay needs --traces");
    }
    load_sweep_cache(&ctx.sweep, &sweep_cache_path(args));
    Ok(ctx)
}

fn cmd_train(args: &Args) -> anyhow::Result<i32> {
    let mut opts = TrainOptions {
        steps: args.opt_usize("steps", 300)?,
        trace_every: args.opt_usize("trace-every", 50)?,
        trace_images: args.opt_usize("trace-images", 1)?,
        trace_format: TraceFormat::parse(args.opt_or("trace-format", "v3"))?,
        seed: args.opt_u64("seed", 7)?,
        artifacts_dir: PathBuf::from(args.opt_or("artifacts", "artifacts")),
        ..TrainOptions::default()
    };
    // v4 captures stream into a binary sidecar next to --out as steps
    // happen (bounded memory — the whole point of the container); the
    // JSON report then references the sidecar instead of embedding a
    // trace it never held in memory.
    let sidecar = match (args.opt("out"), opts.trace_format) {
        (Some(out), TraceFormat::V4) => {
            let p = PathBuf::from(format!("{out}.trace.bin"));
            opts.stream_path = Some(p.clone());
            Some(p)
        }
        _ => None,
    };
    let log = run_training_pipeline(&opts)?;
    println!("trained {} steps at {:.2} steps/s", opts.steps, log.steps_per_sec);
    for (step, loss) in &log.losses {
        println!("  step {step:>5}  loss {loss:.4}");
    }
    match (&sidecar, log.streamed_steps) {
        (Some(p), n) => println!("traces: {n} steps streamed to {}", p.display()),
        (None, _) => println!(
            "traces: {} steps, identity holds: {}",
            log.traces.steps.len(),
            log.traces.identity_holds()
        ),
    }
    if let Some(out) = args.opt("out") {
        let path = Path::new(out);
        let mut j = Json::obj();
        j.set(
            "losses",
            Json::Arr(
                log.losses
                    .iter()
                    .map(|(s, l)| Json::Arr(vec![(*s).into(), (*l).into()]))
                    .collect(),
            ),
        );
        j.set("steps_per_sec", log.steps_per_sec.into());
        match &sidecar {
            Some(p) => {
                j.set("traces_file", p.to_string_lossy().to_string().into());
                j.set("traces_streamed", log.streamed_steps.into());
            }
            None => j.set("traces", log.traces.to_json()),
        }
        j.write_file(path)?;
        println!("wrote {}", path.display());
    }
    Ok(0)
}

/// Synthesize a payload-bearing trace file (v3 delta/RLE by default,
/// incl. post-Add footprints on residual nets) from the calibrated
/// sparsity model — the capture path that needs no PJRT artifacts, and
/// the producer side of the capture→replay smoke
/// (`agos trace … && agos cosim --replay --backend exact …`). With
/// artifacts built, `agos train --out` captures *real* payloads instead.
fn cmd_trace(args: &Args) -> anyhow::Result<i32> {
    if let Some(path) = args.opt("scenario") {
        return cmd_trace_scenario(args, path);
    }
    let net = zoo::by_name(args.opt_or("network", "agos_cnn"))?;
    let steps = args.opt_usize("steps", 4)?;
    let images = args.opt_usize("trace-images", 1)?;
    let format = TraceFormat::parse(args.opt_or("trace-format", "v3"))?;
    let seed = args.opt_u64("seed", 0xA605)?;
    let pattern = BitmapPattern::parse(args.opt_or("pattern", "iid"))?;
    let blob_radius = args.opt_usize("blob-radius", 2)?;
    let model = SparsityModel::synthetic(seed);
    let mut trace =
        capture_synthetic_trace_images(&net, &model, steps, images, pattern, blob_radius);
    trace.format = format;

    let path = PathBuf::from(args.opt_or("out", "results/traces.json"));
    trace.save(&path)?;
    let payload_bits: usize = trace
        .steps
        .iter()
        .flat_map(|s| &s.layers)
        .flat_map(|l| [&l.act_bitmap, &l.grad_bitmap])
        .filter_map(|b| b.as_ref().map(|b| b.shape.len()))
        .sum();
    let means = trace.mean_act_sparsity();
    println!(
        "captured {} steps x {} traced layers of '{}' [{} pattern, {} format] -> {}",
        trace.steps.len(),
        trace.steps.first().map_or(0, |s| s.layers.len()),
        net.name,
        pattern.label(),
        format.label(),
        path.display()
    );
    for (name, s) in &means {
        println!("  {name:<20} mean act sparsity {s:.3}");
    }
    println!(
        "  payloads: {payload_bits} bits packed ({:.1} KiB), identity holds: {}, \
         fingerprint {:016x}",
        payload_bits as f64 / 8.0 / 1024.0,
        trace.identity_holds(),
        trace.fingerprint()
    );
    let (zero_w, one_w, total_w) = trace.payload_run_stats();
    if total_w > 0 {
        println!(
            "  run structure: {:.1}% all-zero words (zero-skip potential), \
{:.1}% all-ones words, {total_w} words total",
            100.0 * zero_w as f64 / total_w as f64,
            100.0 * one_w as f64 / total_w as f64,
        );
    }
    Ok(0)
}

fn cmd_simulate(args: &Args) -> anyhow::Result<i32> {
    let net = zoo::by_name(args.opt_or("network", "vgg16"))?;
    let scheme = Scheme::parse(args.opt_or("scheme", "IN+OUT+WR"))?;
    let cfg = match args.opt("config") {
        Some(path) => AcceleratorConfig::from_json(&Json::parse_file(Path::new(path))?)?,
        None => AcceleratorConfig::default(),
    };
    let mut opts = SimOptions { batch: args.opt_usize("batch", 16)?, ..SimOptions::default() };
    opts.seed = args.opt_u64("seed", opts.seed)?;
    apply_backend_opts(&mut opts, args)?;
    let model = SparsityModel::synthetic(opts.seed);

    let dc = simulate_network(&net, &cfg, &opts, &model, Scheme::Dense);
    let r = simulate_network(&net, &cfg, &opts, &model, scheme);
    println!(
        "network {} scheme {} batch {} backend {}",
        net.name,
        scheme.label(),
        opts.batch,
        opts.backend.label()
    );
    for phase in Phase::ALL {
        let t = r.phase(phase);
        let d = dc.phase(phase);
        println!(
            "  {}: {:>14.0} cycles  ({:.2}x vs DC)  {:.3} J",
            phase.label(),
            t.cycles,
            d.cycles / t.cycles.max(1.0),
            t.energy.total()
        );
    }
    println!(
        "  total: {:>11.0} cycles  ({:.2}x vs DC)  iteration {:.2} ms",
        r.total_cycles(),
        dc.total_cycles() / r.total_cycles(),
        r.iteration_seconds(&cfg) * 1e3
    );
    Ok(0)
}

fn cmd_sweep(args: &Args) -> anyhow::Result<i32> {
    if let Some(path) = args.opt("scenario") {
        return cmd_sweep_scenario(args, path);
    }
    let nets: Vec<Network> = zoo::by_list(args.opt_or("networks", "all"))?;
    let schemes: Vec<Scheme> = Scheme::parse_list(args.opt_or("schemes", "all"))?;
    let cfg = match args.opt("config") {
        Some(path) => AcceleratorConfig::from_json(&Json::parse_file(Path::new(path))?)?,
        None => AcceleratorConfig::default(),
    };
    let mut opts = SimOptions { batch: args.opt_usize("batch", 16)?, ..SimOptions::default() };
    opts.seed = args.opt_u64("seed", opts.seed)?;
    apply_backend_opts(&mut opts, args)?;
    let model = SparsityModel::synthetic(opts.seed);
    let runner = SweepRunner::new(args.opt_usize("jobs", 0)?);
    let cache_path = sweep_cache_path(args);
    load_sweep_cache(&runner, &cache_path);

    let plan = SweepPlan::grid(&nets, &schemes, &cfg, &opts);
    let t0 = std::time::Instant::now();
    let results = runner.run(&plan, &model);
    let elapsed = t0.elapsed().as_secs_f64();

    for (ni, net) in nets.iter().enumerate() {
        println!("network {} (batch {}):", net.name, opts.batch);
        let dense = schemes
            .iter()
            .position(|s| *s == Scheme::Dense)
            .map(|si| results[ni * schemes.len() + si].total_cycles());
        for (si, scheme) in schemes.iter().enumerate() {
            let r = &results[ni * schemes.len() + si];
            match dense {
                Some(d) => println!(
                    "  {:<10} {:>15.0} cycles  ({:.2}x vs DC)  {:.3} J",
                    scheme.label(),
                    r.total_cycles(),
                    d / r.total_cycles(),
                    r.total_energy_j()
                ),
                None => println!(
                    "  {:<10} {:>15.0} cycles  {:.3} J",
                    scheme.label(),
                    r.total_cycles(),
                    r.total_energy_j()
                ),
            }
        }
    }
    println!(
        "sweep: {} combos ({} simulated, {} cache hits) on {} threads [{}] in {elapsed:.2}s",
        plan.len(),
        runner.cache().misses(),
        runner.cache().hits(),
        runner.jobs,
        opts.backend.label(),
    );
    save_sweep_cache(&runner, &cache_path);
    if let Some(out) = args.opt("out") {
        // The report is a pure function of the request — no jobs or
        // elapsed fields — so a served `sweep` response can be diffed
        // against it byte-for-byte. Timings stay on stdout above.
        let path = Path::new(out);
        sweep_report_json(&nets, &schemes, &results, &opts).write_file(path)?;
        println!("wrote {}", path.display());
    }
    Ok(0)
}

/// Reject flags whose axis the scenario file owns — a scenario is
/// self-contained (same file ⇒ same results, whoever runs it), so the
/// CLI must not be able to silently bend its expansion.
fn reject_scenario_owned(args: &Args, owned: &[&str]) -> anyhow::Result<()> {
    for name in owned {
        anyhow::ensure!(
            args.opt(name).is_none(),
            "--scenario owns --{name}: the file is self-contained, edit it instead"
        );
    }
    Ok(())
}

/// `agos sweep --scenario <file>`: expand the file into its (network ×
/// phase × scheme) plan, run it through the cached runner, print the
/// per-phase speedup trajectory, and write the scenario report at
/// `--out` (a pure function of the file + request knobs — byte-identical
/// at any `--jobs` level and to a served scenario `sweep` request).
fn cmd_sweep_scenario(args: &Args, path: &str) -> anyhow::Result<i32> {
    reject_scenario_owned(args, &["networks", "schemes", "seed"])?;
    let scenario = ScenarioFile::load(Path::new(path))?;
    let cfg = match args.opt("config") {
        Some(path) => AcceleratorConfig::from_json(&Json::parse_file(Path::new(path))?)?,
        None => AcceleratorConfig::default(),
    };
    let mut opts = SimOptions { batch: args.opt_usize("batch", 16)?, ..SimOptions::default() };
    apply_backend_opts(&mut opts, args)?;
    let ex = scenario.expand(&cfg, &opts)?;
    let runner = SweepRunner::new(args.opt_usize("jobs", 0)?);
    let cache_path = sweep_cache_path(args);
    load_sweep_cache(&runner, &cache_path);

    let t0 = std::time::Instant::now();
    let results = ex.run(&runner);
    let elapsed = t0.elapsed().as_secs_f64();

    print!("{}", trajectory_figure(&ex, &results).render());
    println!();
    println!(
        "scenario '{}' [{:016x}]: {} points x {} schemes = {} combos \
({} simulated, {} cache hits) on {} threads [{}] in {elapsed:.2}s",
        ex.name,
        ex.fingerprint,
        ex.points.len(),
        ex.schemes.len(),
        ex.plan.len(),
        runner.cache().misses(),
        runner.cache().hits(),
        runner.jobs,
        ex.opts.backend.label(),
    );
    save_sweep_cache(&runner, &cache_path);
    if let Some(out) = args.opt("out") {
        // Same contract as the plain sweep report: no jobs/elapsed
        // fields in the file, timings stay on stdout above.
        let path = Path::new(out);
        scenario_report_json(&ex, &results).write_file(path)?;
        println!("wrote {}", path.display());
    }
    Ok(0)
}

/// `agos trace --scenario <file>`: one trace file per expanded point,
/// written into `--out` as a directory (`<network>_<phase>.json`, or
/// `.trace.bin` under `--trace-format v4`). Synthetic points capture
/// with the phase's scaled model; adversarial points write their
/// pattern's exact map.
fn cmd_trace_scenario(args: &Args, path: &str) -> anyhow::Result<i32> {
    reject_scenario_owned(args, &["network", "seed"])?;
    let scenario = ScenarioFile::load(Path::new(path))?;
    let steps = args.opt_usize("steps", 4)?;
    let images = args.opt_usize("trace-images", 1)?;
    let format = TraceFormat::parse(args.opt_or("trace-format", "v3"))?;
    let pattern = BitmapPattern::parse(args.opt_or("pattern", "iid"))?;
    let blob_radius = args.opt_usize("blob-radius", 2)?;
    let dir = PathBuf::from(args.opt_or("out", "results/scenario-traces"));
    let points = scenario.points()?;
    for p in &points {
        let mut trace = match &p.replay {
            // The point's phase *is* the pattern label for adversarial
            // points — regenerate the exact map rather than unpacking
            // the replay bank.
            Some(_) => adversarial_trace(&p.network, AdversarialPattern::parse(&p.phase)?),
            None => capture_synthetic_trace_images(
                &p.network,
                &p.model,
                steps,
                images,
                pattern,
                blob_radius,
            ),
        };
        trace.format = format;
        let ext = if format == TraceFormat::V4 { "trace.bin" } else { "json" };
        let file = dir.join(format!("{}.{ext}", p.label.replace('@', "_")));
        trace.save(&file)?;
        println!(
            "  {:<28} {} steps, fingerprint {:016x} -> {}",
            p.label,
            trace.steps.len(),
            trace.fingerprint(),
            file.display()
        );
    }
    println!(
        "scenario '{}': {} trace files in {}",
        scenario.name,
        points.len(),
        dir.display()
    );
    Ok(0)
}

fn cmd_figure(args: &Args) -> anyhow::Result<i32> {
    let ids = args.positional();
    anyhow::ensure!(!ids.is_empty(), "give a figure/table id (or 'all')");
    let ctx = ctx_from(args)?;
    let emit = || -> anyhow::Result<()> {
        for id in ids {
            for fig in generate(id, &ctx)? {
                print!("{}", fig.render());
                println!();
                if let Some(dir) = args.opt("out") {
                    fig.save(Path::new(dir))?;
                    println!("wrote {}/{}.json", dir, fig.id);
                }
            }
        }
        Ok(())
    };
    // Spill whatever simulated even when a later id fails — a bad id or
    // unwritable --out must not discard an expensive (exact) sweep.
    let outcome = emit();
    save_sweep_cache(&ctx.sweep, &sweep_cache_path(args));
    outcome.map(|()| 0)
}

fn cmd_sparsity(args: &Args) -> anyhow::Result<i32> {
    let net = zoo::by_name(args.opt_or("network", "vgg16"))?;
    let model = SparsityModel::synthetic(args.opt_u64("seed", 0xA605)?);
    let fwd = model.assign(&net);
    let opps = analyze_network(&net, &fwd);
    println!(
        "{:<28} {:>8} {:>8} {:>8} {:>10}",
        "layer", "FP-in", "BP-in", "BP-out", "BP kind"
    );
    let fmt = |o: Option<f64>| o.map(|v| format!("{v:.2}")).unwrap_or_else(|| "-".into());
    for o in &opps {
        println!(
            "{:<28} {:>8} {:>8} {:>8} {:>10}",
            o.name,
            fmt(o.fp_input),
            fmt(o.bp_input),
            fmt(o.bp_output),
            format!("{:?}", o.bp_kind())
        );
    }
    Ok(0)
}

fn cmd_cosim(args: &Args) -> anyhow::Result<i32> {
    let path = args.opt("traces").ok_or_else(|| anyhow::anyhow!("--traces required"))?;
    // Lenient load: a corrupt/truncated bitmap payload is dropped with a
    // layer/step-contexted warning instead of killing the run — a
    // damaged capture degrades, it does not panic. Structural damage
    // (bad JSON, missing scalars) still errors.
    let (traces, warnings) = TraceFile::load_lenient(Path::new(path))?;
    for w in &warnings {
        eprintln!("cosim: trace warning: {w}");
    }
    let mut replay = args.flag("replay");
    if replay && !warnings.is_empty() && !traces.has_bitmaps() {
        // Every payload was corrupt: fall back to the scalar cosim the
        // surviving fractions still support. (A trace that never had
        // payloads stays a hard error below — that is a usage mistake,
        // not data damage.)
        eprintln!("cosim: all bitmap payloads dropped — falling back to scalar co-simulation");
        replay = false;
    }
    let mut opts = SimOptions { batch: args.opt_usize("batch", 16)?, ..SimOptions::default() };
    apply_backend_opts(&mut opts, args)?;
    let jobs = args.opt_usize("jobs", 0)?;
    // By-value entry: the freshly-loaded trace moves its bitmaps straight
    // into the replay bank instead of being cloned map-by-map.
    let report =
        cosim_from_traces_owned(traces, &AcceleratorConfig::default(), &opts, replay, jobs)?;
    println!(
        "co-simulation of '{}' [{} backend{}] (mean measured sparsity {:.2})",
        report.network,
        report.backend,
        if report.replayed { ", pattern replay" } else { "" },
        report.mean_sparsity
    );
    for (scheme, total, bp, energy) in &report.rows {
        println!("  {scheme:<10} total {total:>14.0} cycles  BP {bp:>12.0}  {energy:.4} J");
    }
    println!(
        "  speedup: total {:.2}x, BP {:.2}x",
        report.total_speedup, report.bp_speedup
    );
    if args.flag("verbose") {
        // Diagnostics only: the counters stay out of the --out JSON so
        // the report is byte-identical with plans/skip on or off.
        match &report.skip {
            Some(s) => {
                let denom = (s.words_gathered + s.words_skipped).max(1);
                println!(
                    "  gather plans: {} words gathered, {} skipped ({:.1}% of planned), \
{} windows short-circuited dense",
                    s.words_gathered,
                    s.words_skipped,
                    100.0 * s.words_skipped as f64 / denom as f64,
                    s.windows_shortcircuited,
                );
            }
            None => println!("  gather plans: disabled"),
        }
    }
    if let Some(out) = args.opt("out") {
        // The report carries no timing or thread-count fields, so two
        // invocations at different --jobs must write byte-identical
        // files — the CI determinism cross-check diffs exactly this.
        let path = Path::new(out);
        report.to_json().write_file(path)?;
        println!("wrote {}", path.display());
    }
    Ok(0)
}

/// Default Unix socket the service listens on.
#[cfg(unix)]
const SERVE_SOCKET_PATH: &str = "results/agos.sock";

/// `agos serve`: run the resident service until a `shutdown` request.
#[cfg(unix)]
fn cmd_serve(args: &Args) -> anyhow::Result<i32> {
    use crate::serve::{ServeOptions, Server};
    let opts = ServeOptions {
        socket: PathBuf::from(args.opt_or("socket", SERVE_SOCKET_PATH)),
        jobs: args.opt_usize("jobs", 0)?,
        workers: args.opt_usize("workers", 4)?,
        cache_path: sweep_cache_path(args),
    };
    let server = Server::bind(opts)?;
    println!(
        "agos serve: listening on {} ({} handlers x {} sweep threads, sim rev {})",
        server.socket().display(),
        server.workers(),
        server.state().jobs(),
        crate::sim::SIM_REVISION,
    );
    server.run()?;
    println!("agos serve: shut down");
    Ok(0)
}

#[cfg(not(unix))]
fn cmd_serve(_args: &Args) -> anyhow::Result<i32> {
    anyhow::bail!("agos serve needs Unix domain sockets (unavailable on this platform)")
}

/// `agos request`: one-shot client for a running `agos serve`. Writes
/// the response's `result` document — with `--out`, byte-identical to
/// the file the equivalent cold CLI invocation would have written.
#[cfg(unix)]
fn cmd_request(args: &Args) -> anyhow::Result<i32> {
    use crate::serve::Client;
    let req = if args.flag("shutdown") {
        Json::from_pairs(vec![("cmd", "shutdown".into())])
    } else if args.flag("ping") {
        Json::from_pairs(vec![("cmd", "ping".into())])
    } else if let Some(text) = args.opt("json") {
        Json::parse(text)?
    } else if let Some(file) = args.opt("file") {
        Json::parse_file(Path::new(file))?
    } else {
        anyhow::bail!("give a request: --json, --file, --ping or --shutdown");
    };
    let socket = PathBuf::from(args.opt_or("socket", SERVE_SOCKET_PATH));
    let timeout = std::time::Duration::from_secs(args.opt_u64("timeout", 10)?);
    let mut client = Client::connect_retry(&socket, timeout)?;
    let result = client.request(&req)?;
    match args.opt("out") {
        Some(out) => {
            let path = Path::new(out);
            result.write_file(path)?;
            println!("wrote {}", path.display());
        }
        None => print!("{}", result.pretty()),
    }
    Ok(0)
}

#[cfg(not(unix))]
fn cmd_request(_args: &Args) -> anyhow::Result<i32> {
    anyhow::bail!("agos request needs Unix domain sockets (unavailable on this platform)")
}

/// Gate `BENCH_sweep.json` against the committed `BENCH_baseline.json`:
/// exit 1 when any tracked row regresses past its tolerance (the CI
/// `bench` job's teeth). `--bless` rewrites the baseline from the
/// current measurements instead.
fn cmd_bench_check(args: &Args) -> anyhow::Result<i32> {
    use crate::util::bench_gate::BenchGate;
    let baseline_path = PathBuf::from(args.opt_or("baseline", "BENCH_baseline.json"));
    let current_path = PathBuf::from(args.opt_or("current", "BENCH_sweep.json"));
    let gate = BenchGate::load(&baseline_path)?;
    let current = Json::parse_file(&current_path)?;
    if args.flag("bless") {
        let blessed = gate.bless(&current)?;
        blessed.write_file(&baseline_path)?;
        println!(
            "blessed {} rows of {} from {}",
            gate.rows.len(),
            baseline_path.display(),
            current_path.display()
        );
        return Ok(0);
    }
    let outcomes = gate.check(&current);
    println!(
        "bench-check '{}': {} vs baseline {}",
        gate.bench,
        current_path.display(),
        baseline_path.display()
    );
    let mut failed = 0usize;
    for o in &outcomes {
        let current_s =
            o.current.map_or_else(|| "missing".to_string(), |v| format!("{v:.4}"));
        println!(
            "  {} {:<32} current {:>10}  baseline {:>10.4}  allowed {:>10.4}",
            if o.regressed { "FAIL" } else { "ok  " },
            o.name,
            current_s,
            o.baseline,
            o.allowed,
        );
        failed += o.regressed as usize;
    }
    if failed > 0 {
        eprintln!("bench-check: {failed} tracked row(s) regressed past tolerance");
        return Ok(1);
    }
    println!("bench-check: all {} tracked rows within tolerance", outcomes.len());
    Ok(0)
}

fn cmd_info(args: &Args) -> anyhow::Result<i32> {
    let cfg = AcceleratorConfig::default();
    println!(
        "design point: {}x{} PEs, {} lanes, {:.0} MHz",
        cfg.tx,
        cfg.ty,
        cfg.lanes,
        cfg.freq_hz / 1e6
    );
    println!(
        "  peak {:.0} GFLOPs/s, {:.1} W node power, PE capacity {}",
        cfg.peak_flops() / 1e9,
        cfg.node_power_w(),
        cfg.pe_capacity()
    );
    let dir = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    match crate::runtime::ArtifactManifest::load(&dir) {
        Ok(m) => {
            println!("artifacts at {}:", dir.display());
            for (name, e) in &m.entries {
                println!(
                    "  {name}: {} inputs -> {} outputs ({})",
                    e.inputs.len(),
                    e.outputs.len(),
                    e.file.file_name().unwrap().to_string_lossy()
                );
            }
            println!("  model: batch {}, {}x{}x{} input", m.batch, m.img, m.img, m.in_ch);
        }
        Err(e) => println!("artifacts not available: {e} (run `make artifacts`)"),
    }
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn unknown_command_is_exit_2() {
        assert_eq!(run(&sv(&["bogus"])).unwrap(), 2);
    }

    #[test]
    fn sparsity_command_runs() {
        assert_eq!(run(&sv(&["sparsity", "--network", "resnet18"])).unwrap(), 0);
    }

    #[test]
    fn simulate_small_network_runs() {
        assert_eq!(
            run(&sv(&["simulate", "--network", "agos_cnn", "--batch", "2"])).unwrap(),
            0
        );
    }

    #[test]
    fn figure_requires_id() {
        assert!(run(&sv(&["figure"])).is_err());
        assert!(run(&sv(&["figure", "fig99"])).is_err());
    }

    #[test]
    fn fig16_fast_path_runs() {
        assert_eq!(
            run(&sv(&["figure", "fig16", "--batch", "1", "--cache", "none"])).unwrap(),
            0
        );
    }

    #[test]
    fn sweep_command_runs_small_grid() {
        assert_eq!(
            run(&sv(&[
                "sweep",
                "--networks",
                "agos_cnn",
                "--schemes",
                "dc,in+out+wr",
                "--batch",
                "1",
                "--jobs",
                "2",
                "--cache",
                "none",
            ]))
            .unwrap(),
            0
        );
    }

    #[test]
    fn sweep_rejects_unknown_network_and_scheme() {
        assert!(run(&sv(&["sweep", "--networks", "lenet", "--batch", "1"])).is_err());
        assert!(run(&sv(&["sweep", "--schemes", "bogus", "--batch", "1"])).is_err());
        assert!(run(&sv(&[
            "sweep", "--networks", "agos_cnn", "--batch", "1", "--backend", "fpga"
        ]))
        .is_err());
    }

    #[test]
    fn simulate_exact_backend_runs() {
        assert_eq!(
            run(&sv(&[
                "simulate",
                "--network",
                "agos_cnn",
                "--batch",
                "1",
                "--backend",
                "exact",
                "--exact-cap",
                "8",
            ]))
            .unwrap(),
            0
        );
    }

    #[test]
    fn sweep_exact_backend_runs_and_spills_cache() {
        let dir = std::env::temp_dir().join("agos_cli_cache_test");
        std::fs::remove_dir_all(&dir).ok();
        let cache = dir.join("sweep-cache.json");
        let cache_s = cache.to_string_lossy().to_string();
        let argv = sv(&[
            "sweep",
            "--networks",
            "agos_cnn",
            "--schemes",
            "dc,in+out+wr",
            "--batch",
            "1",
            "--backend",
            "exact",
            "--exact-cap",
            "8",
            "--cache",
            &cache_s,
        ]);
        assert_eq!(run(&argv).unwrap(), 0);
        assert!(cache.exists(), "sweep must spill its cache");
        // Second invocation reloads the spill (still exit 0).
        assert_eq!(run(&argv).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cosim_exact_backend_runs_from_trace_file() {
        use crate::trace::{LayerTrace, StepTrace, TraceFile};
        let dir = std::env::temp_dir().join("agos_cli_cosim_test");
        let path = dir.join("traces.json");
        let traces = TraceFile {
            network: "agos_cnn".into(),
            steps: vec![StepTrace {
                step: 0,
                loss: 1.0,
                layers: (1..=4)
                    .map(|i| LayerTrace::scalar(&format!("relu{i}"), 0.5, 0.5, true))
                    .collect(),
            }],
            ..TraceFile::default()
        };
        traces.save(&path).unwrap();
        let path_s = path.to_string_lossy().to_string();
        assert_eq!(
            run(&sv(&[
                "cosim",
                "--traces",
                &path_s,
                "--batch",
                "1",
                "--backend",
                "exact",
                "--exact-cap",
                "8",
            ]))
            .unwrap(),
            0
        );
        // A scalar-only trace cannot replay.
        assert!(run(&sv(&[
            "cosim", "--traces", &path_s, "--batch", "1", "--backend", "exact", "--replay",
        ]))
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_capture_then_replay_cosim_roundtrip() {
        use crate::trace::TraceFile;
        // The CI smoke in miniature: synthesize a v2 trace, then consume
        // it pattern-exactly through the exact backend.
        let dir = std::env::temp_dir().join("agos_cli_trace_replay_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("traces.json");
        let path_s = path.to_string_lossy().to_string();
        assert_eq!(
            run(&sv(&[
                "trace",
                "--network",
                "agos_cnn",
                "--steps",
                "2",
                "--pattern",
                "blobs",
                "--out",
                &path_s,
            ]))
            .unwrap(),
            0
        );
        let trace = TraceFile::load(&path).unwrap();
        assert!(trace.has_bitmaps(), "agos trace must write v2 payloads");
        assert_eq!(
            run(&sv(&[
                "cosim",
                "--traces",
                &path_s,
                "--batch",
                "2",
                "--backend",
                "exact",
                "--exact-cap",
                "8",
                "--replay",
                "--verbose",
            ]))
            .unwrap(),
            0
        );
        // Bad pattern names are rejected at the CLI boundary.
        assert!(run(&sv(&["trace", "--pattern", "plaid", "--out", &path_s])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cosim_replay_reports_are_identical_across_jobs_levels() {
        // The CI determinism cross-check in miniature: the same replay
        // cosim at --jobs 1 and --jobs 4 writes byte-identical reports,
        // for both backends and both gather modes.
        let dir = std::env::temp_dir().join("agos_cli_cosim_jobs_test");
        std::fs::remove_dir_all(&dir).ok();
        let traces = dir.join("traces.json");
        let traces_s = traces.to_string_lossy().to_string();
        assert_eq!(
            run(&sv(&["trace", "--network", "agos_cnn", "--steps", "2", "--out", &traces_s]))
                .unwrap(),
            0
        );
        for (backend, gather) in
            [("exact", "geometry"), ("exact", "streaming"), ("analytic", "geometry")]
        {
            let out = |jobs: &str| dir.join(format!("cosim-{backend}-{gather}-j{jobs}.json"));
            for jobs in ["1", "4"] {
                let out_s = out(jobs).to_string_lossy().to_string();
                assert_eq!(
                    run(&sv(&[
                        "cosim", "--traces", &traces_s, "--batch", "2", "--backend", backend,
                        "--gather", gather, "--exact-cap", "8", "--replay", "--jobs", jobs,
                        "--out", &out_s,
                    ]))
                    .unwrap(),
                    0,
                    "{backend}/{gather} jobs {jobs}"
                );
            }
            let a = std::fs::read(out("1")).unwrap();
            let b = std::fs::read(out("4")).unwrap();
            assert_eq!(a, b, "{backend}/{gather}: jobs must not change the report");
        }
        // Geometry and streaming gathers are genuinely different models.
        let geo = std::fs::read(dir.join("cosim-exact-geometry-j1.json")).unwrap();
        let stream = std::fs::read(dir.join("cosim-exact-streaming-j1.json")).unwrap();
        assert_ne!(geo, stream, "gather mode must reach the replay path");
        // Bad gather names are rejected at the CLI boundary.
        assert!(run(&sv(&[
            "cosim", "--traces", &traces_s, "--backend", "exact", "--replay", "--gather",
            "teleport",
        ]))
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_format_and_images_flags_flow_through() {
        use crate::trace::{TraceFile, TraceFormat};
        let dir = std::env::temp_dir().join("agos_cli_trace_v3_test");
        std::fs::remove_dir_all(&dir).ok();
        let v2 = dir.join("v2.json");
        let v3 = dir.join("v3.json");
        let v4 = dir.join("v4.trace.bin");
        for (path, fmt) in [(&v2, "v2"), (&v3, "v3"), (&v4, "v4")] {
            let path_s = path.to_string_lossy().to_string();
            assert_eq!(
                run(&sv(&[
                    "trace",
                    "--network",
                    "agos_resnet",
                    "--steps",
                    "1",
                    "--trace-images",
                    "2",
                    "--trace-format",
                    fmt,
                    "--out",
                    &path_s,
                ]))
                .unwrap(),
                0
            );
        }
        let t2 = TraceFile::load(&v2).unwrap();
        let t3 = TraceFile::load(&v3).unwrap();
        let t4 = TraceFile::load(&v4).unwrap();
        assert_eq!(t2.format, TraceFormat::V2);
        assert_eq!(t3.format, TraceFormat::V3);
        assert_eq!(t4.format, TraceFormat::V4);
        assert_eq!(t2.steps, t3.steps, "same content under both encodings");
        assert_eq!(t3.steps, t4.steps, "the binary container carries identical content");
        assert_eq!(t3.steps.len(), 2, "one StepTrace per captured image");
        assert!(
            std::fs::metadata(&v3).unwrap().len() < std::fs::metadata(&v2).unwrap().len(),
            "v3 files are smaller"
        );
        assert!(
            std::fs::metadata(&v4).unwrap().len() <= std::fs::metadata(&v3).unwrap().len(),
            "v4 files are never larger than v3"
        );
        // Both the v3 JSON and the v4 binary residual captures replay
        // through the same cosim entry point.
        for path in [&v3, &v4] {
            let path_s = path.to_string_lossy().to_string();
            assert_eq!(
                run(&sv(&[
                    "cosim", "--traces", &path_s, "--batch", "2", "--backend", "exact",
                    "--exact-cap", "8", "--replay",
                ]))
                .unwrap(),
                0
            );
        }
        // Bad format names are rejected at the CLI boundary.
        let v3_s = v3.to_string_lossy().to_string();
        assert!(run(&sv(&["trace", "--trace-format", "v9", "--out", &v3_s])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_out_report_is_identical_across_jobs_levels() {
        // The served-vs-cold byte-identity contract starts here: the
        // sweep report must be a pure function of the request, so the
        // same grid at --jobs 1 and --jobs 4 writes identical bytes
        // (no elapsed/thread-count fields in the file).
        let dir = std::env::temp_dir().join("agos_cli_sweep_out_test");
        std::fs::remove_dir_all(&dir).ok();
        let out = |jobs: &str| dir.join(format!("sweep-j{jobs}.json"));
        for jobs in ["1", "4"] {
            let out_s = out(jobs).to_string_lossy().to_string();
            assert_eq!(
                run(&sv(&[
                    "sweep", "--networks", "agos_cnn", "--schemes", "dc,in+out+wr", "--batch",
                    "1", "--jobs", jobs, "--cache", "none", "--out", &out_s,
                ]))
                .unwrap(),
                0
            );
        }
        let a = std::fs::read(out("1")).unwrap();
        let b = std::fs::read(out("4")).unwrap();
        assert_eq!(a, b, "sweep --out must not depend on --jobs");
        let text = String::from_utf8(a).unwrap();
        assert!(text.contains("\"combos\""), "report carries the combo rows");
        assert!(!text.contains("elapsed"), "timings belong on stdout, not in the report");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[cfg(unix)]
    #[test]
    fn request_without_a_document_is_an_error() {
        assert!(run(&sv(&["request", "--socket", "/nonexistent/agos.sock"])).is_err());
        // A malformed inline document fails before any connection attempt.
        assert!(run(&sv(&["request", "--json", "{not json", "--timeout", "0"])).is_err());
    }

    #[test]
    fn cosim_falls_back_to_scalars_when_every_payload_is_corrupt() {
        let dir = std::env::temp_dir().join("agos_cli_cosim_corrupt_test");
        std::fs::remove_dir_all(&dir).ok();
        let path = dir.join("traces.json");
        let path_s = path.to_string_lossy().to_string();
        assert_eq!(
            run(&sv(&["trace", "--network", "agos_cnn", "--steps", "1", "--out", &path_s]))
                .unwrap(),
            0
        );
        // Corrupt every payload's word stream in place.
        let mut j = Json::parse_file(&path).unwrap();
        let Json::Obj(top) = &mut j else { unreachable!() };
        let Json::Arr(steps) = top.get_mut("steps").unwrap() else { unreachable!() };
        for s in steps {
            let Json::Obj(step) = s else { unreachable!() };
            let Json::Arr(layers) = step.get_mut("layers").unwrap() else { unreachable!() };
            for l in layers {
                for slot in ["act_bitmap", "grad_bitmap"] {
                    if let Json::Obj(layer) = l {
                        if let Some(Json::Obj(bm)) = layer.get_mut(slot) {
                            bm.insert("words".into(), Json::Str("!!".into()));
                        }
                    }
                }
            }
        }
        j.write_file(&path).unwrap();
        // --replay on the damaged file warns and falls back, exit 0.
        assert_eq!(
            run(&sv(&["cosim", "--traces", &path_s, "--batch", "1", "--replay"])).unwrap(),
            0,
            "corrupt payloads must degrade, not die"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A small, fast scenario: one zoo network, two phases, two schemes.
    const TEST_SCENARIO: &str = r#"{
        "version": 1, "name": "cli_test", "seed": 11,
        "generators": [{"kind": "zoo", "networks": "agos_cnn"}],
        "schedule": {"phases": [
            {"name": "early", "scale": 0.6}, {"name": "late", "scale": 1.3}]},
        "schemes": "dc,in+out+wr"
    }"#;

    #[test]
    fn scenario_sweep_out_is_identical_across_jobs_levels() {
        // The scenario report is a pure function of the file + request
        // knobs: the same file at --jobs 1 and --jobs 4 writes
        // byte-identical bytes (the CI smoke diffs exactly this).
        let dir = std::env::temp_dir().join("agos_cli_scenario_sweep_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let scn = dir.join("scenario.json");
        std::fs::write(&scn, TEST_SCENARIO).unwrap();
        let scn_s = scn.to_string_lossy().to_string();
        let out = |jobs: &str| dir.join(format!("scn-j{jobs}.json"));
        for jobs in ["1", "4"] {
            let out_s = out(jobs).to_string_lossy().to_string();
            assert_eq!(
                run(&sv(&[
                    "sweep", "--scenario", &scn_s, "--batch", "1", "--jobs", jobs, "--cache",
                    "none", "--out", &out_s,
                ]))
                .unwrap(),
                0
            );
        }
        let a = std::fs::read(out("1")).unwrap();
        let b = std::fs::read(out("4")).unwrap();
        assert_eq!(a, b, "scenario --out must not depend on --jobs");
        let text = String::from_utf8(a).unwrap();
        assert!(text.contains("\"trajectory\""), "report carries the trajectory figure");
        assert!(text.contains("\"early\"") && text.contains("\"late\""), "both phases ran");
        assert!(!text.contains("elapsed"), "timings belong on stdout, not in the report");

        // The file owns the axes the flags would bend.
        for owned in [["--networks", "agos_cnn"], ["--schemes", "dc"], ["--seed", "7"]] {
            assert!(
                run(&sv(&["sweep", "--scenario", &scn_s, owned[0], owned[1]])).is_err(),
                "{} must conflict with --scenario",
                owned[0]
            );
        }
        // A missing or malformed scenario file is a loud error.
        assert!(run(&sv(&["sweep", "--scenario", "/nonexistent/s.json"])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scenario_trace_writes_one_file_per_point() {
        use crate::trace::TraceFile;
        let dir = std::env::temp_dir().join("agos_cli_scenario_trace_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let scn = dir.join("scenario.json");
        // One synthetic point (zoo, default single-phase schedule) plus
        // three adversarial pattern points.
        std::fs::write(
            &scn,
            r#"{"version": 1, "seed": 11, "generators": [
                {"kind": "zoo", "networks": "agos_cnn"},
                {"kind": "adversarial", "network": "agos_cnn"}]}"#,
        )
        .unwrap();
        let scn_s = scn.to_string_lossy().to_string();
        let out_dir = dir.join("traces");
        let out_s = out_dir.to_string_lossy().to_string();
        assert_eq!(
            run(&sv(&[
                "trace", "--scenario", &scn_s, "--steps", "1", "--out", &out_s,
            ]))
            .unwrap(),
            0
        );
        let expected = [
            "agos_cnn_base.json",
            "agos_cnn_all_dense.json",
            "agos_cnn_checkerboard.json",
            "agos_cnn_channel_collapsed.json",
        ];
        for name in expected {
            let t = TraceFile::load(&out_dir.join(name))
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(t.has_bitmaps(), "{name} must carry payloads");
            assert!(t.identity_holds(), "{name}");
        }
        assert_eq!(
            std::fs::read_dir(&out_dir).unwrap().count(),
            expected.len(),
            "exactly one file per expanded point"
        );
        // --network conflicts with --scenario here too.
        assert!(run(&sv(&["trace", "--scenario", &scn_s, "--network", "vgg16"])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_check_gates_and_blesses() {
        let dir = std::env::temp_dir().join("agos_cli_bench_check_test");
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let baseline = dir.join("BENCH_baseline.json");
        let current = dir.join("BENCH_sweep.json");
        std::fs::write(
            &baseline,
            r#"{"bench": "sim_hotpath", "tolerance": 0.25, "rows": [
                {"name": "speedup", "baseline": 2.0, "better": "higher"},
                {"name": "backend_exact_slowdown", "baseline": 100.0, "better": "lower"}
            ]}"#,
        )
        .unwrap();
        let baseline_s = baseline.to_string_lossy().to_string();
        let current_s = current.to_string_lossy().to_string();
        let argv = sv(&["bench-check", "--baseline", &baseline_s, "--current", &current_s]);

        // Within tolerance: exit 0.
        std::fs::write(&current, r#"{"speedup": 1.8, "backend_exact_slowdown": 110.0}"#).unwrap();
        assert_eq!(run(&argv).unwrap(), 0);
        // A >25% regression on a tracked row: exit 1 (the CI gate).
        std::fs::write(&current, r#"{"speedup": 1.2, "backend_exact_slowdown": 110.0}"#).unwrap();
        assert_eq!(run(&argv).unwrap(), 1);
        // A missing tracked row also fails.
        std::fs::write(&current, r#"{"speedup": 1.8}"#).unwrap();
        assert_eq!(run(&argv).unwrap(), 1);
        // --bless rewrites the baseline from the measurements.
        std::fs::write(&current, r#"{"speedup": 3.0, "backend_exact_slowdown": 80.0}"#).unwrap();
        let mut bless = argv.clone();
        bless.push("--bless".into());
        assert_eq!(run(&bless).unwrap(), 0);
        assert_eq!(run(&argv).unwrap(), 0, "freshly blessed baseline must pass");
        let re_read = std::fs::read_to_string(&baseline).unwrap();
        assert!(re_read.contains("3"), "blessed baseline carries the new value");
        // Missing files are loud errors, not silent passes.
        assert!(run(&sv(&["bench-check", "--baseline", "/nonexistent.json"])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
