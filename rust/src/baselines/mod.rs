//! Baseline platforms for the Table 2 comparison.
//!
//! Three families:
//!
//! * **Simulator-backed** — DaDianNao (dense) and CNVLUTIN (input-sparse)
//!   are modeled by running *our* simulator under the matching scheme and
//!   applying their published clock and a mapping-efficiency penalty
//!   (§6: "dense variants of our architecture perform 1.9×/1.7× better
//!   than DaDianNao … primarily due to efficient mapping strategies").
//! * **Measured-sparsity** — SparseTrain, TensorDash and SparseNN model
//!   each design's published *skip mechanism* against the per-layer,
//!   per-phase densities the sweep engine measures (`measured`), so
//!   their latency and energy move with the sparsity model and, under
//!   `--replay`, with real trace bitmaps.
//! * **Analytic** — CPU, GPU, LNPU, SparTANN and Selective-Grad are
//!   modeled from their published peak throughput, utilization and the
//!   sparsity phases they support (Table 2 footnotes).

mod measured;
mod platforms;

pub use measured::{measured_latency_ms, measured_summaries, scale_to_total, SkipMechanism};
pub use platforms::{
    all_platforms, iteration_latency_ms, platform_cost, Platform, PlatformCost, PlatformKind,
};
