//! Baseline platforms for the Table 2 comparison.
//!
//! Two families:
//!
//! * **Simulator-backed** — DaDianNao (dense) and CNVLUTIN (input-sparse)
//!   are modeled by running *our* simulator under the matching scheme and
//!   applying their published clock and a mapping-efficiency penalty
//!   (§6: "dense variants of our architecture perform 1.9×/1.7× better
//!   than DaDianNao … primarily due to efficient mapping strategies").
//! * **Analytic** — CPU, GPU, LNPU, SparTANN and Selective-Grad are
//!   modeled from their published peak throughput, utilization and the
//!   sparsity phases they support (Table 2 footnotes).

mod platforms;

pub use platforms::{all_platforms, iteration_latency_ms, Platform, PlatformKind};
