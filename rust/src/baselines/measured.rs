//! Measured-sparsity platform models: skip mechanisms of related
//! accelerators driven by the per-layer, per-phase densities our sweep
//! engine measures, instead of hand-set utilization constants.
//!
//! Each mechanism describes what its hardware can *actually* exploit
//! from a sparsity map:
//!
//! * **SparseTrain** (arXiv 2007.13595) — a dataflow that skips zero
//!   activations in FP/WG and prunes ReLU-masked gradients in BP, so FP
//!   and WG run at the measured input density and BP at the joint
//!   input×output density.
//! * **TensorDash** (arXiv 2009.00748) — a 4:1 sparse operand
//!   multiplexer in front of each MAC: one operand side's zeros can be
//!   skipped, but never more than four slots collapse into one cycle,
//!   so the effective density is the measured input density floored at
//!   1/4.
//! * **SparseNN** (arXiv 1711.01263) — an input+output sparsity engine:
//!   effective density is the joint input×output density our `IN+OUT`
//!   scheme measures.
//!
//! The densities come from [`DensitySummary`] extractions of cached
//! [`SweepRunner`] results, so the same (network, config, options)
//! combo is simulated at most once per context — and a `--replay` run
//! feeds the mechanisms *real trace bitmaps* through the identical
//! path, because the replay bank is armed on the options the summaries
//! are simulated under.

use std::collections::BTreeMap;

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::nn::{Network, Phase};
use crate::sim::{DensitySummary, EnergyBreakdown, SweepRunner};
use crate::sparsity::SparsityModel;

/// A related accelerator's sparsity-skip mechanism, evaluated against
/// measured density maps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SkipMechanism {
    /// Dataflow sparsity: FP/WG skip zero activations, BP prunes
    /// ReLU-masked gradients.
    SparseTrain,
    /// 4:1 sparse operand multiplexing — input-side zeros, ≤4× per group.
    TensorDash,
    /// Input + output sparsity engine (like our `IN+OUT` scheme).
    SparseNN,
}

impl SkipMechanism {
    pub fn label(&self) -> &'static str {
        match self {
            SkipMechanism::SparseTrain => "sparsetrain",
            SkipMechanism::TensorDash => "tensordash",
            SkipMechanism::SparseNN => "sparsenn",
        }
    }

    /// Lower bound on the effective density the mechanism can reach: a
    /// 4:1 multiplexer collapses at most four operand slots into one
    /// cycle no matter how sparse the map is.
    pub fn density_floor(&self) -> f64 {
        match self {
            SkipMechanism::TensorDash => 0.25,
            _ => 0.0,
        }
    }

    /// Effective (performed/dense) density for one (layer, phase) given
    /// the measured input density `d_in` (from `Scheme::In`) and joint
    /// input×output density `d_inout` (from `Scheme::InOut`).
    pub fn effective_density(&self, phase: Phase, d_in: f64, d_inout: f64) -> f64 {
        let d = match self {
            SkipMechanism::TensorDash => d_in,
            SkipMechanism::SparseNN => d_inout,
            SkipMechanism::SparseTrain => match phase {
                Phase::Forward | Phase::WeightGrad => d_in,
                Phase::Backward => d_inout,
            },
        };
        d.max(self.density_floor())
    }

    /// Which of our schemes' measured energy mixes best approximates the
    /// mechanism's component breakdown.
    pub fn energy_mix_scheme(&self) -> Scheme {
        match self {
            SkipMechanism::TensorDash => Scheme::In,
            _ => Scheme::InOut,
        }
    }
}

/// The two measured density summaries every mechanism consumes, pulled
/// from the shared (cached) sweep runner.
pub fn measured_summaries(
    net: &Network,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    model: &SparsityModel,
    runner: &SweepRunner,
) -> (DensitySummary, DensitySummary) {
    let r_in = runner.one(net, cfg, opts, model, Scheme::In);
    let r_io = runner.one(net, cfg, opts, model, Scheme::InOut);
    (DensitySummary::from_result(&r_in), DensitySummary::from_result(&r_io))
}

/// Iteration latency (ms) of a mechanism at a platform's published peak
/// throughput: per (layer, phase), the dense FLOPs are scaled by the
/// effective density the mechanism extracts from the *measured* maps,
/// then a §6-style mapping-efficiency penalty covers the utilization
/// gap between ideal skipping and the platform's real dataflow.
pub fn measured_latency_ms(
    mechanism: SkipMechanism,
    mapping_penalty: f64,
    peak_gops: f64,
    d_in: &DensitySummary,
    d_inout: &DensitySummary,
) -> f64 {
    // Join the joint densities by (layer, phase); the accumulation order
    // is the In summary's deterministic per_layer order.
    let io: BTreeMap<(&str, &str), f64> = d_inout
        .layers
        .iter()
        .map(|l| ((l.name.as_str(), l.phase.label()), l.density))
        .collect();
    let mut seconds = 0.0;
    for l in &d_in.layers {
        let joint = io.get(&(l.name.as_str(), l.phase.label())).copied().unwrap_or(l.density);
        let eff = mechanism.effective_density(l.phase, l.density, joint);
        seconds += 2.0 * l.dense_macs * eff / (peak_gops * 1e9);
    }
    seconds * mapping_penalty * 1e3
}

/// A measured breakdown rescaled so its total matches `total_j`: the
/// component *mix* stays measured while the envelope comes from the
/// platform's published power × its measured iteration time.
pub fn scale_to_total(b: EnergyBreakdown, total_j: f64) -> EnergyBreakdown {
    let t = b.total();
    if t > 0.0 {
        b.scaled(total_j / t)
    } else {
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn summaries() -> (DensitySummary, DensitySummary) {
        let net = zoo::agos_cnn();
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 1, ..SimOptions::default() };
        let model = SparsityModel::synthetic(17);
        measured_summaries(&net, &cfg, &opts, &model, &SweepRunner::new(0))
    }

    #[test]
    fn tensordash_floor_binds_at_extreme_sparsity() {
        let m = SkipMechanism::TensorDash;
        assert_eq!(m.effective_density(Phase::Forward, 0.01, 0.001), 0.25);
        assert_eq!(m.effective_density(Phase::Forward, 0.6, 0.3), 0.6);
    }

    #[test]
    fn sparsetrain_prunes_bp_deeper_than_fp() {
        let m = SkipMechanism::SparseTrain;
        // BP reads the joint density, FP only the input density.
        assert_eq!(m.effective_density(Phase::Backward, 0.5, 0.3), 0.3);
        assert_eq!(m.effective_density(Phase::Forward, 0.5, 0.3), 0.5);
        assert_eq!(m.effective_density(Phase::WeightGrad, 0.5, 0.3), 0.5);
    }

    #[test]
    fn sparsenn_tracks_joint_density() {
        let m = SkipMechanism::SparseNN;
        for p in Phase::ALL {
            assert_eq!(m.effective_density(p, 0.7, 0.4), 0.4);
        }
    }

    #[test]
    fn measured_latency_orders_mechanisms_sensibly() {
        let (din, dio) = summaries();
        let at = |m| measured_latency_ms(m, 1.0, 1000.0, &din, &dio);
        let dense_s = 2.0 * din.total_dense_macs() / (1000.0 * 1e9) * 1e3;
        let td = at(SkipMechanism::TensorDash);
        let st = at(SkipMechanism::SparseTrain);
        let nn = at(SkipMechanism::SparseNN);
        // Every mechanism beats dense execution at the same peak, and
        // the joint-density engine prunes at least as much as the
        // input-only mux (same maps, no floor bound at these densities).
        for v in [td, st, nn] {
            assert!(v < dense_s, "{v} vs dense {dense_s}");
            assert!(v > 0.0);
        }
        assert!(nn <= st + 1e-12, "in+out prunes ≥ sparsetrain: {nn} vs {st}");
        assert!(st <= td + 1e-12, "bp pruning helps: {st} vs {td}");
    }

    #[test]
    fn mapping_penalty_scales_linearly() {
        let (din, dio) = summaries();
        let base = measured_latency_ms(SkipMechanism::SparseNN, 1.0, 500.0, &din, &dio);
        let pen = measured_latency_ms(SkipMechanism::SparseNN, 1.5, 500.0, &din, &dio);
        assert!((pen / base - 1.5).abs() < 1e-9);
    }

    #[test]
    fn scale_to_total_preserves_mix() {
        let b = EnergyBreakdown { mac_j: 3.0, sram_j: 1.0, ..EnergyBreakdown::default() };
        let s = scale_to_total(b, 8.0);
        assert!((s.total() - 8.0).abs() < 1e-12);
        assert!((s.mac_j / s.sram_j - 3.0).abs() < 1e-12);
        // A zero-total breakdown passes through rather than dividing by 0.
        let z = scale_to_total(EnergyBreakdown::default(), 5.0);
        assert_eq!(z.total(), 0.0);
    }
}
