//! Platform models with the paper's Table 2 specifications.

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::nn::{network_macs, Network, Phase};
use crate::sim::SweepRunner;
use crate::sparsity::SparsityModel;

/// How a platform's iteration latency is obtained.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlatformKind {
    /// Published spec sheet + utilization model (CPU/GPU/small accs).
    Analytic {
        /// Achievable fraction of peak on conv workloads.
        utilization: f64,
        /// Execution-time reduction from the sparsity the platform
        /// supports (1.0 = dense execution).
        sparsity_gain: f64,
    },
    /// Run our simulator under this scheme with a mapping-efficiency
    /// penalty (relative PE utilization vs our design).
    SimulatorBacked { scheme: Scheme, mapping_penalty: f64 },
    /// This work: our simulator, full scheme, no penalty.
    ThisWork,
}

/// One row of Table 2.
#[derive(Clone, Debug)]
pub struct Platform {
    pub name: &'static str,
    pub tech_nm: u32,
    pub freq_mhz: f64,
    pub area_mm2: f64,
    pub power_w: f64,
    pub peak_gops: f64,
    pub energy_eff_gops_w: f64,
    pub exec_mode: &'static str,
    pub kind: PlatformKind,
}

/// The Table 2 platform list, in the paper's row order.
pub fn all_platforms() -> Vec<Platform> {
    vec![
        Platform {
            name: "Dual Xeon E5 2560 v3",
            tech_nm: 22,
            freq_mhz: 2400.0,
            area_mm2: f64::NAN,
            power_w: 85.0,
            peak_gops: 614.4,
            energy_eff_gops_w: 7.22,
            exec_mode: "CPU, Dense",
            // Calibrated to the paper's published 8495 ms VGG-16 iteration.
            kind: PlatformKind::Analytic { utilization: 0.29, sparsity_gain: 1.0 },
        },
        Platform {
            name: "NVidia GTX 1080 Ti",
            tech_nm: 16,
            freq_mhz: 706.0,
            area_mm2: 400.0,
            power_w: 225.0,
            peak_gops: 11000.0,
            energy_eff_gops_w: 48.8,
            exec_mode: "GPU, Dense",
            // Calibrated to the published 128 ms VGG-16 iteration — the
            // effective rate is near peak because cuDNN's Winograd path
            // reduces the arithmetic the GPU actually performs.
            kind: PlatformKind::Analytic { utilization: 0.95, sparsity_gain: 1.0 },
        },
        Platform {
            name: "DaDianNao",
            tech_nm: 65,
            freq_mhz: 606.0,
            area_mm2: 67.3,
            power_w: 16.3,
            peak_gops: 4964.0,
            energy_eff_gops_w: 304.0,
            exec_mode: "Acc, Dense",
            kind: PlatformKind::SimulatorBacked { scheme: Scheme::Dense, mapping_penalty: 1.8 },
        },
        Platform {
            name: "CNVLUTIN",
            tech_nm: 65,
            freq_mhz: 606.0,
            area_mm2: 70.1,
            power_w: 17.4,
            peak_gops: 4964.0,
            energy_eff_gops_w: 304.0,
            exec_mode: "Acc, Input Sparse",
            kind: PlatformKind::SimulatorBacked { scheme: Scheme::In, mapping_penalty: 1.8 },
        },
        Platform {
            name: "LNPU",
            tech_nm: 65,
            freq_mhz: 200.0,
            area_mm2: 16.0,
            power_w: 0.367,
            peak_gops: 638.0,
            energy_eff_gops_w: 25800.0,
            exec_mode: "Acc, Input Sparse",
            // Tiny on-chip buffer (320 KB vs our 32 MB) forces repeated
            // DRAM traffic; application-level utilization collapses (§6).
            kind: PlatformKind::Analytic { utilization: 0.35, sparsity_gain: 1.55 },
        },
        Platform {
            name: "SparTANN",
            tech_nm: 65,
            freq_mhz: 250.0,
            area_mm2: 4.32,
            power_w: 0.59,
            peak_gops: 380.0,
            energy_eff_gops_w: 648.0,
            exec_mode: "Acc, Input Sparse (BP & WG)",
            kind: PlatformKind::Analytic { utilization: 0.55, sparsity_gain: 1.45 },
        },
        Platform {
            name: "Selective Grad",
            tech_nm: 65,
            freq_mhz: 606.0,
            area_mm2: 67.3,
            power_w: 16.3,
            peak_gops: 4964.0,
            energy_eff_gops_w: 304.0,
            exec_mode: "Acc, Output Sparse (BP)",
            // DaDianNao-class datapath; skips ReLU-masked gradient outputs
            // in BP but ignores input sparsity everywhere (§6 ≈2.6× gap).
            kind: PlatformKind::Analytic { utilization: 0.57, sparsity_gain: 1.25 },
        },
        Platform {
            name: "This Work",
            tech_nm: 32,
            freq_mhz: 667.0,
            area_mm2: 292.0,
            power_w: 19.2,
            peak_gops: 5466.0,
            energy_eff_gops_w: 325.0,
            exec_mode: "Acc, In + Out Sparse",
            kind: PlatformKind::ThisWork,
        },
    ]
}

/// Training-iteration latency (ms) of `platform` on `net` at `batch`.
///
/// Simulator-backed rows route through the shared sweep `runner`, so a
/// (network, scheme, config) combo already simulated by another figure —
/// or another platform row — is served from cache.
pub fn iteration_latency_ms(
    platform: &Platform,
    net: &Network,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    model: &SparsityModel,
    runner: &SweepRunner,
) -> f64 {
    match platform.kind {
        PlatformKind::Analytic { utilization, sparsity_gain } => {
            let macs: u64 = Phase::ALL.iter().map(|p| network_macs(net, *p)).sum();
            let flops = 2.0 * macs as f64 * opts.batch as f64;
            let secs = flops / (platform.peak_gops * 1e9 * utilization * sparsity_gain);
            secs * 1e3
        }
        PlatformKind::SimulatorBacked { scheme, mapping_penalty } => {
            let r = runner.one(net, cfg, opts, model, scheme);
            let cycles = r.total_cycles() * mapping_penalty;
            cycles / (platform.freq_mhz * 1e6) * 1e3
        }
        PlatformKind::ThisWork => {
            let r = runner.one(net, cfg, opts, model, Scheme::InOutWr);
            r.total_cycles() / cfg.freq_hz * 1e3
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn setup() -> (AcceleratorConfig, SimOptions, SparsityModel, SweepRunner) {
        (
            AcceleratorConfig::default(),
            SimOptions { batch: 16, ..SimOptions::default() },
            SparsityModel::synthetic(2021),
            SweepRunner::new(0),
        )
    }

    #[test]
    fn cpu_latency_matches_published_order() {
        let (cfg, opts, model, runner) = setup();
        let net = zoo::vgg16();
        let cpu = &all_platforms()[0];
        let ms = iteration_latency_ms(cpu, &net, &cfg, &opts, &model, &runner);
        // Paper: 8495 ms. Same order of magnitude required.
        assert!((5000.0..14000.0).contains(&ms), "CPU VGG {ms} ms");
    }

    #[test]
    fn gpu_latency_matches_published_order() {
        let (cfg, opts, model, runner) = setup();
        let net = zoo::vgg16();
        let gpu = &all_platforms()[1];
        let ms = iteration_latency_ms(gpu, &net, &cfg, &opts, &model, &runner);
        // Paper: 128 ms.
        assert!((80.0..200.0).contains(&ms), "GPU VGG {ms} ms");
    }

    #[test]
    fn this_work_beats_dense_baselines() {
        let (cfg, opts, model, runner) = setup();
        let net = zoo::resnet18();
        let platforms = all_platforms();
        let ours =
            iteration_latency_ms(platforms.last().unwrap(), &net, &cfg, &opts, &model, &runner);
        let ddn = iteration_latency_ms(&platforms[2], &net, &cfg, &opts, &model, &runner);
        let cnv = iteration_latency_ms(&platforms[3], &net, &cfg, &opts, &model, &runner);
        // Paper: 2.65× vs DaDianNao, 2.07× vs CNVLUTIN on ResNet-18.
        let vs_ddn = ddn / ours;
        let vs_cnv = cnv / ours;
        assert!((1.8..4.5).contains(&vs_ddn), "vs DaDianNao {vs_ddn:.2}");
        assert!((1.4..3.8).contains(&vs_cnv), "vs CNVLUTIN {vs_cnv:.2}");
        assert!(vs_ddn > vs_cnv, "input-sparse baseline must sit between");
    }

    #[test]
    fn energy_efficiency_order_of_magnitude_vs_gpu() {
        // Paper: ~7× energy-efficiency vs the GPU on these benchmarks.
        let platforms = all_platforms();
        let ours = platforms.last().unwrap();
        let gpu = &platforms[1];
        assert!(ours.energy_eff_gops_w / gpu.energy_eff_gops_w > 5.0);
    }

    #[test]
    fn table_has_eight_rows_in_order() {
        let p = all_platforms();
        assert_eq!(p.len(), 8);
        assert_eq!(p[0].exec_mode, "CPU, Dense");
        assert_eq!(p.last().unwrap().name, "This Work");
    }
}
