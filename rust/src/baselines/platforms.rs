//! Platform models with the paper's Table 2 specifications.
//!
//! Three row families:
//!
//! * **Analytic** — published spec sheet + hand-calibrated utilization
//!   and sparsity-gain constants (CPU, GPU, small accelerators whose
//!   dataflow we do not model).
//! * **SimulatorBacked / ThisWork** — our cycle simulator runs the row's
//!   scheme; latency comes from simulated cycles.
//! * **MeasuredSparse** — the row's *skip mechanism* (`baselines::
//!   measured`) is evaluated against the per-layer, per-phase densities
//!   the sweep engine measures, so SparseTrain/TensorDash/SparseNN
//!   latencies move with the sparsity model — and with real trace
//!   bitmaps under `--replay`.

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::nn::{network_macs, Network, Phase};
use crate::sim::{EnergyBreakdown, SweepRunner};
use crate::sparsity::SparsityModel;

use super::measured::{measured_latency_ms, measured_summaries, scale_to_total, SkipMechanism};

/// How a platform's iteration latency is obtained.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlatformKind {
    /// Published spec sheet + utilization model (CPU/GPU/small accs).
    Analytic {
        /// Achievable fraction of peak on conv workloads.
        utilization: f64,
        /// Execution-time reduction from the sparsity the platform
        /// supports (1.0 = dense execution).
        sparsity_gain: f64,
    },
    /// Run our simulator under this scheme with a mapping-efficiency
    /// penalty (relative PE utilization vs our design).
    SimulatorBacked { scheme: Scheme, mapping_penalty: f64 },
    /// The row's published skip mechanism evaluated on *measured*
    /// per-layer, per-phase density maps from the sweep engine, with a
    /// §6-style mapping-efficiency penalty over ideal skipping.
    MeasuredSparse { mechanism: SkipMechanism, mapping_penalty: f64 },
    /// This work: our simulator, full scheme, no penalty.
    ThisWork,
}

/// One row of Table 2.
#[derive(Clone, Debug)]
pub struct Platform {
    pub name: &'static str,
    pub tech_nm: u32,
    pub freq_mhz: f64,
    /// Die area when published; `None` for rows (the CPU) where no
    /// meaningful figure exists — serialized as `null`, rendered `n/a`.
    pub area_mm2: Option<f64>,
    pub power_w: f64,
    pub peak_gops: f64,
    pub energy_eff_gops_w: f64,
    pub exec_mode: &'static str,
    pub kind: PlatformKind,
}

/// Measured cost of one training iteration on one platform.
#[derive(Clone, Debug)]
pub struct PlatformCost {
    pub latency_ms: f64,
    /// Total energy for the iteration. Analytic rows: published power ×
    /// latency. Simulator-consuming rows: same envelope, but the
    /// component mix comes from the measured breakdown (This Work uses
    /// its measured breakdown directly).
    pub energy_j: f64,
    /// Component breakdown when a measured mix backs the row; `None`
    /// for analytic rows (power × time carries no component detail).
    pub breakdown: Option<EnergyBreakdown>,
}

/// The Table 2 platform list, in row order. `This Work`'s rate-relevant
/// specs (clock, peak throughput, node power) are derived from `cfg` so
/// the row can never disagree with the simulator that produces its
/// latency column.
pub fn all_platforms(cfg: &AcceleratorConfig) -> Vec<Platform> {
    vec![
        Platform {
            name: "Dual Xeon E5 2560 v3",
            tech_nm: 22,
            freq_mhz: 2400.0,
            area_mm2: None,
            power_w: 85.0,
            peak_gops: 614.4,
            energy_eff_gops_w: 7.22,
            exec_mode: "CPU, Dense",
            // Calibrated to the paper's published 8495 ms VGG-16 iteration.
            kind: PlatformKind::Analytic { utilization: 0.29, sparsity_gain: 1.0 },
        },
        Platform {
            name: "NVidia GTX 1080 Ti",
            tech_nm: 16,
            freq_mhz: 706.0,
            area_mm2: Some(400.0),
            power_w: 225.0,
            peak_gops: 11000.0,
            energy_eff_gops_w: 48.8,
            exec_mode: "GPU, Dense",
            // Calibrated to the published 128 ms VGG-16 iteration — the
            // effective rate is near peak because cuDNN's Winograd path
            // reduces the arithmetic the GPU actually performs.
            kind: PlatformKind::Analytic { utilization: 0.95, sparsity_gain: 1.0 },
        },
        Platform {
            name: "DaDianNao",
            tech_nm: 65,
            freq_mhz: 606.0,
            area_mm2: Some(67.3),
            power_w: 16.3,
            peak_gops: 4964.0,
            energy_eff_gops_w: 304.0,
            exec_mode: "Acc, Dense",
            kind: PlatformKind::SimulatorBacked { scheme: Scheme::Dense, mapping_penalty: 1.8 },
        },
        Platform {
            name: "CNVLUTIN",
            tech_nm: 65,
            freq_mhz: 606.0,
            area_mm2: Some(70.1),
            power_w: 17.4,
            peak_gops: 4964.0,
            energy_eff_gops_w: 304.0,
            exec_mode: "Acc, Input Sparse",
            kind: PlatformKind::SimulatorBacked { scheme: Scheme::In, mapping_penalty: 1.8 },
        },
        Platform {
            name: "LNPU",
            tech_nm: 65,
            freq_mhz: 200.0,
            area_mm2: Some(16.0),
            power_w: 0.367,
            peak_gops: 638.0,
            energy_eff_gops_w: 25800.0,
            exec_mode: "Acc, Input Sparse",
            // Tiny on-chip buffer (320 KB vs our 32 MB) forces repeated
            // DRAM traffic; application-level utilization collapses (§6).
            kind: PlatformKind::Analytic { utilization: 0.35, sparsity_gain: 1.55 },
        },
        Platform {
            name: "SparTANN",
            tech_nm: 65,
            freq_mhz: 250.0,
            area_mm2: Some(4.32),
            power_w: 0.59,
            peak_gops: 380.0,
            energy_eff_gops_w: 648.0,
            exec_mode: "Acc, Input Sparse (BP & WG)",
            kind: PlatformKind::Analytic { utilization: 0.55, sparsity_gain: 1.45 },
        },
        Platform {
            name: "Selective Grad",
            tech_nm: 65,
            freq_mhz: 606.0,
            area_mm2: Some(67.3),
            power_w: 16.3,
            peak_gops: 4964.0,
            energy_eff_gops_w: 304.0,
            exec_mode: "Acc, Output Sparse (BP)",
            // DaDianNao-class datapath; skips ReLU-masked gradient outputs
            // in BP but ignores input sparsity everywhere (§6 ≈2.6× gap).
            kind: PlatformKind::Analytic { utilization: 0.57, sparsity_gain: 1.25 },
        },
        // The three measured-sparsity rows. Spec figures are spec-sheet
        // approximations of the published designs (the papers report
        // different technology/benchmark combinations); what the model
        // actually measures is how much of *our* sparsity maps each skip
        // mechanism can exploit.
        Platform {
            name: "SparseNN",
            tech_nm: 65,
            freq_mhz: 300.0,
            area_mm2: Some(2.0),
            power_w: 0.30,
            peak_gops: 76.8,
            energy_eff_gops_w: 256.0,
            exec_mode: "Acc, In + Out Sparse (engine)",
            // Small engine; mapping penalty covers its serial
            // index-matching front-end vs ideal joint skipping.
            kind: PlatformKind::MeasuredSparse {
                mechanism: SkipMechanism::SparseNN,
                mapping_penalty: 1.9,
            },
        },
        Platform {
            name: "SparseTrain",
            tech_nm: 28,
            freq_mhz: 800.0,
            area_mm2: Some(7.3),
            power_w: 2.6,
            peak_gops: 1024.0,
            energy_eff_gops_w: 394.0,
            exec_mode: "Acc, Dataflow Sparse (FP+BP)",
            // Skips zero activations in FP/WG; prunes ReLU-masked
            // gradients in BP per its dataflow.
            kind: PlatformKind::MeasuredSparse {
                mechanism: SkipMechanism::SparseTrain,
                mapping_penalty: 1.6,
            },
        },
        Platform {
            name: "TensorDash",
            tech_nm: 65,
            freq_mhz: 500.0,
            area_mm2: Some(58.1),
            power_w: 14.8,
            peak_gops: 4096.0,
            energy_eff_gops_w: 277.0,
            exec_mode: "Acc, 4:1 Operand Mux",
            // Bounded by the 4:1 sparse operand multiplexer: effective
            // density floors at 1/4 however sparse the measured map is.
            kind: PlatformKind::MeasuredSparse {
                mechanism: SkipMechanism::TensorDash,
                mapping_penalty: 1.5,
            },
        },
        Platform {
            name: "This Work",
            tech_nm: 32,
            freq_mhz: cfg.freq_hz / 1e6,
            area_mm2: Some(292.0),
            power_w: cfg.node_power_w(),
            peak_gops: cfg.peak_flops() / 1e9,
            energy_eff_gops_w: 325.0,
            exec_mode: "Acc, In + Out Sparse",
            kind: PlatformKind::ThisWork,
        },
    ]
}

/// Training-iteration latency (ms) of `platform` on `net` at `batch`.
///
/// Simulator-backed rows route through the shared sweep `runner`, so a
/// (network, scheme, config) combo already simulated by another figure —
/// or another platform row — is served from cache.
pub fn iteration_latency_ms(
    platform: &Platform,
    net: &Network,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    model: &SparsityModel,
    runner: &SweepRunner,
) -> f64 {
    platform_cost(platform, net, cfg, opts, model, runner).latency_ms
}

/// Full measured cost (latency + energy) of one training iteration.
///
/// Energy model per row family:
/// * Analytic: published power × latency, no component breakdown.
/// * SimulatorBacked / MeasuredSparse: same power × latency envelope,
///   with the component *mix* taken from the measured breakdown of the
///   closest scheme (Dense/In for the sim-backed rows, the mechanism's
///   mix scheme for measured rows) rescaled to that envelope.
/// * ThisWork: the simulator's measured breakdown, verbatim.
pub fn platform_cost(
    platform: &Platform,
    net: &Network,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    model: &SparsityModel,
    runner: &SweepRunner,
) -> PlatformCost {
    match platform.kind {
        PlatformKind::Analytic { utilization, sparsity_gain } => {
            let macs: u64 = Phase::ALL.iter().map(|p| network_macs(net, *p)).sum();
            let flops = 2.0 * macs as f64 * opts.batch as f64;
            let secs = flops / (platform.peak_gops * 1e9 * utilization * sparsity_gain);
            PlatformCost {
                latency_ms: secs * 1e3,
                energy_j: platform.power_w * secs,
                breakdown: None,
            }
        }
        PlatformKind::SimulatorBacked { scheme, mapping_penalty } => {
            let r = runner.one(net, cfg, opts, model, scheme);
            let cycles = r.total_cycles() * mapping_penalty;
            let latency_ms = cycles / (platform.freq_mhz * 1e6) * 1e3;
            let energy_j = platform.power_w * latency_ms * 1e-3;
            PlatformCost {
                latency_ms,
                energy_j,
                breakdown: Some(scale_to_total(r.energy_breakdown(), energy_j)),
            }
        }
        PlatformKind::MeasuredSparse { mechanism, mapping_penalty } => {
            let (d_in, d_inout) = measured_summaries(net, cfg, opts, model, runner);
            let latency_ms =
                measured_latency_ms(mechanism, mapping_penalty, platform.peak_gops, &d_in, &d_inout);
            let energy_j = platform.power_w * latency_ms * 1e-3;
            // Mix scheme is In or InOut — both already simulated for the
            // density summaries, so this is a cache hit.
            let mix = runner
                .one(net, cfg, opts, model, mechanism.energy_mix_scheme())
                .energy_breakdown();
            PlatformCost { latency_ms, energy_j, breakdown: Some(scale_to_total(mix, energy_j)) }
        }
        PlatformKind::ThisWork => {
            let r = runner.one(net, cfg, opts, model, Scheme::InOutWr);
            let breakdown = r.energy_breakdown();
            PlatformCost {
                latency_ms: r.total_cycles() / cfg.freq_hz * 1e3,
                energy_j: breakdown.total(),
                breakdown: Some(breakdown),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn setup() -> (AcceleratorConfig, SimOptions, SparsityModel, SweepRunner) {
        (
            AcceleratorConfig::default(),
            SimOptions { batch: 16, ..SimOptions::default() },
            SparsityModel::synthetic(2021),
            SweepRunner::new(0),
        )
    }

    #[test]
    fn cpu_latency_matches_published_order() {
        let (cfg, opts, model, runner) = setup();
        let net = zoo::vgg16();
        let cpu = &all_platforms(&cfg)[0];
        let ms = iteration_latency_ms(cpu, &net, &cfg, &opts, &model, &runner);
        // Paper: 8495 ms. Same order of magnitude required.
        assert!((5000.0..14000.0).contains(&ms), "CPU VGG {ms} ms");
    }

    #[test]
    fn gpu_latency_matches_published_order() {
        let (cfg, opts, model, runner) = setup();
        let net = zoo::vgg16();
        let gpu = &all_platforms(&cfg)[1];
        let ms = iteration_latency_ms(gpu, &net, &cfg, &opts, &model, &runner);
        // Paper: 128 ms.
        assert!((80.0..200.0).contains(&ms), "GPU VGG {ms} ms");
    }

    #[test]
    fn this_work_beats_dense_baselines() {
        let (cfg, opts, model, runner) = setup();
        let net = zoo::resnet18();
        let platforms = all_platforms(&cfg);
        let ours =
            iteration_latency_ms(platforms.last().unwrap(), &net, &cfg, &opts, &model, &runner);
        let ddn = iteration_latency_ms(&platforms[2], &net, &cfg, &opts, &model, &runner);
        let cnv = iteration_latency_ms(&platforms[3], &net, &cfg, &opts, &model, &runner);
        // Paper: 2.65× vs DaDianNao, 2.07× vs CNVLUTIN on ResNet-18.
        let vs_ddn = ddn / ours;
        let vs_cnv = cnv / ours;
        assert!((1.8..4.5).contains(&vs_ddn), "vs DaDianNao {vs_ddn:.2}");
        assert!((1.4..3.8).contains(&vs_cnv), "vs CNVLUTIN {vs_cnv:.2}");
        assert!(vs_ddn > vs_cnv, "input-sparse baseline must sit between");
    }

    #[test]
    fn energy_efficiency_order_of_magnitude_vs_gpu() {
        // Paper: ~7× energy-efficiency vs the GPU on these benchmarks.
        let cfg = AcceleratorConfig::default();
        let platforms = all_platforms(&cfg);
        let ours = platforms.last().unwrap();
        let gpu = &platforms[1];
        assert!(ours.energy_eff_gops_w / gpu.energy_eff_gops_w > 5.0);
    }

    #[test]
    fn table_has_eleven_rows_in_order() {
        let cfg = AcceleratorConfig::default();
        let p = all_platforms(&cfg);
        assert_eq!(p.len(), 11);
        assert_eq!(p[0].exec_mode, "CPU, Dense");
        assert_eq!(p[0].area_mm2, None, "CPU publishes no die area");
        assert_eq!(p[7].name, "SparseNN");
        assert_eq!(p[8].name, "SparseTrain");
        assert_eq!(p[9].name, "TensorDash");
        assert_eq!(p.last().unwrap().name, "This Work");
        assert!(p.iter().skip(1).all(|r| r.area_mm2.is_some()));
    }

    #[test]
    fn this_work_specs_derive_from_config() {
        let cfg = AcceleratorConfig::default();
        let p = all_platforms(&cfg);
        let ours = p.last().unwrap();
        // The published row can never disagree with the simulator's
        // rate parameters: 667 MHz clock, ~5.47 TFLOPs peak, ~19.2 W.
        assert!((ours.freq_mhz * 1e6 - cfg.freq_hz).abs() < 1.0, "{}", ours.freq_mhz);
        assert!((ours.peak_gops * 1e9 - cfg.peak_flops()).abs() < 1.0, "{}", ours.peak_gops);
        assert!((ours.power_w - cfg.node_power_w()).abs() < 1e-9, "{}", ours.power_w);
        assert!((600.0..800.0).contains(&ours.freq_mhz));
        assert!((5000.0..6000.0).contains(&ours.peak_gops));
    }

    #[test]
    fn measured_rows_move_with_the_sparsity_model() {
        let (cfg, _, _, runner) = setup();
        let opts = SimOptions { batch: 2, ..SimOptions::default() };
        let net = zoo::agos_cnn();
        let platforms = all_platforms(&cfg);
        let sparse = SparsityModel::synthetic(7);
        // Same draws, ReLU sparsity scaled down ⇒ denser maps.
        let denser = SparsityModel::synthetic(7).with_scale(0.4);
        for row in &platforms[7..10] {
            let a = iteration_latency_ms(row, &net, &cfg, &opts, &sparse, &runner);
            let b = iteration_latency_ms(row, &net, &cfg, &opts, &denser, &runner);
            assert!(a > 0.0 && b > 0.0);
            assert!(
                (a - b).abs() / b > 0.02,
                "{} must respond to the sparsity model: {a} vs {b}",
                row.name
            );
        }
    }

    #[test]
    fn platform_cost_energy_envelope_and_mix() {
        let (cfg, opts, model, runner) = setup();
        let net = zoo::resnet18();
        let platforms = all_platforms(&cfg);
        for row in &platforms {
            let c = platform_cost(row, &net, &cfg, &opts, &model, &runner);
            assert!(c.latency_ms > 0.0 && c.energy_j > 0.0, "{}", row.name);
            match row.kind {
                PlatformKind::Analytic { .. } => {
                    assert!(c.breakdown.is_none(), "{}", row.name);
                    let expect = row.power_w * c.latency_ms * 1e-3;
                    assert!((c.energy_j - expect).abs() < 1e-9, "{}", row.name);
                }
                PlatformKind::ThisWork => {
                    let b = c.breakdown.as_ref().unwrap();
                    assert!((b.total() - c.energy_j).abs() < 1e-9);
                }
                _ => {
                    let b = c.breakdown.as_ref().unwrap();
                    // Envelope is power × time; mix rescaled to match it.
                    let expect = row.power_w * c.latency_ms * 1e-3;
                    assert!((c.energy_j - expect).abs() < 1e-9, "{}", row.name);
                    assert!((b.total() - c.energy_j).abs() < 1e-6 * c.energy_j, "{}", row.name);
                }
            }
        }
    }
}
