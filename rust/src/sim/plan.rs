//! Reusable gather plans for the exact backend's replayed operand
//! windows — pure execution strategy, bit-identical by construction.
//!
//! `Bitmap::gather_window_words` re-derives the same word-index +
//! shift/mask schedule for every output of every tile of every image:
//! the schedule depends only on the map's shape, the task geometry and
//! the output's spatial position — none of which change across images,
//! steps, channels (channels translate the source by a whole plane) or
//! schemes. A [`GatherPlan`] runs that derivation **once** per
//! `(map shape, TaskGeom, output plane)` and records the resulting
//! segment list; execution is a tight copy loop over precomputed
//! `(src, dst, n)` segments.
//!
//! On top of the plan, the run structure replayed maps carry
//! (`sparsity::RunIndex`) enables SparseTrain/TensorDash-style operand
//! skipping in the *simulator itself*: a segment whose source words are
//! all zero leaves the pre-zeroed scratch untouched, so it is skipped
//! outright; a padding-free window whose every source word is all-ones
//! *is* the dense pattern, so the PE walk is served from a per-tile
//! dense memo instead of being re-gathered and re-counted.
//!
//! None of this may change a reported cycle: plans replicate the exact
//! splitting of the direct gather (`tests in sim::backend` and
//! `tests/exact_perf.rs` pin equality), skipping only elides writes of
//! zero bits, and the dense short-circuit only fires when the gathered
//! pattern provably equals `OperandPattern::dense(len)`. Accordingly the
//! cache is **not** part of any fingerprint or sweep-cache key
//! (`SimOptions::fingerprint` ignores it), exactly like `SweepCache`
//! membership itself.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::nn::Shape;
use crate::sparsity::{or_bits, Bitmap, RunIndex};

use super::backend::TaskGeom;

/// One precomputed copy segment: `n` bits from channel-plane-relative
/// source bit `src` into channel-block-relative destination bit `dst`.
/// Segments are split exactly like the direct gather splits its row
/// runs (≤64 bits, stepped from the in-map row start), so executing
/// them reproduces its `extract_bits`/`or_bits` calls verbatim.
#[derive(Clone, Copy, Debug)]
struct Seg {
    src: u32,
    dst: u32,
    n: u16,
}

/// Per-output-position schedule: the window's per-channel bit length,
/// whether every window bit maps to an in-map source bit (no structural
/// padding), and the segment range in the shared pool.
#[derive(Clone, Copy, Debug)]
struct OutPlan {
    per_chan: u32,
    full: bool,
    seg_lo: u32,
    seg_hi: u32,
}

/// Outcome of one planned gather.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannedGather {
    /// The pattern was assembled into the caller's scratch buffer
    /// (`len == 0`: a structurally empty window, nothing to simulate).
    Words { len: usize },
    /// Every operand bit is provably set: the caller can serve the PE
    /// result from a dense pattern of this length without gathering.
    AllOnes { len: usize },
}

/// Skip-effectiveness counters for one batch of planned gathers. Plain
/// sums, so aggregation is order-independent — totals are identical at
/// any `--jobs` level.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SkipStats {
    /// Source words actually read by executed segments.
    pub words_gathered: u64,
    /// Source words elided because their run was all-zero.
    pub words_skipped: u64,
    /// Whole windows served from the dense memo (all-ones runs).
    pub windows_shortcircuited: u64,
}

impl SkipStats {
    /// Component-wise difference (for before/after snapshots).
    pub fn delta_from(&self, before: &SkipStats) -> SkipStats {
        SkipStats {
            words_gathered: self.words_gathered - before.words_gathered,
            words_skipped: self.words_skipped - before.words_skipped,
            windows_shortcircuited: self.windows_shortcircuited
                - before.windows_shortcircuited,
        }
    }
}

/// The word-index/shift/mask schedule for one `(map shape, TaskGeom,
/// u × v output plane)` — every tile, channel, image and step with that
/// signature shares one plan.
#[derive(Debug)]
pub struct GatherPlan {
    v: usize,
    dw: bool,
    channels: usize,
    /// Bits per channel plane of the source map (`h · w`).
    plane_bits: usize,
    outs: Vec<OutPlan>,
    segs: Vec<Seg>,
}

impl GatherPlan {
    /// Build the plan for every spatial output position of a `u × v`
    /// plane under `tg` against maps of `shape`. Only windowed
    /// geometries plan; `Full` keeps its one-walk fast path and
    /// `Streaming`/`Wg` never reach the gathered source.
    fn build(shape: Shape, tg: TaskGeom, u: usize, v: usize) -> Option<GatherPlan> {
        let (dw, windows): (bool, Box<dyn Fn(usize, usize) -> Option<(isize, isize, usize, usize)>>) =
            match tg {
                TaskGeom::Conv { r, s, stride, pad, dw } => (
                    dw,
                    Box::new(move |y, x| {
                        Some((
                            (y * stride) as isize - pad as isize,
                            (x * stride) as isize - pad as isize,
                            r,
                            s,
                        ))
                    }),
                ),
                TaskGeom::ConvT { r, s, stride, pad, dw } => (
                    dw,
                    Box::new(move |y, x| {
                        // Same floor-division tap math as the direct
                        // gather: the contiguous run of gradient rows
                        // whose strided window covers (y, x).
                        let sd = stride.max(1) as isize;
                        let (yp, xp) = ((y + pad) as isize, (x + pad) as isize);
                        let u_min = (yp - r as isize).div_euclid(sd) + 1;
                        let u_max = yp.div_euclid(sd);
                        let v_min = (xp - s as isize).div_euclid(sd) + 1;
                        let v_max = xp.div_euclid(sd);
                        if u_max < u_min || v_max < v_min {
                            return None; // structurally empty window
                        }
                        Some((
                            u_min,
                            v_min,
                            (u_max - u_min + 1) as usize,
                            (v_max - v_min + 1) as usize,
                        ))
                    }),
                ),
                TaskGeom::Full | TaskGeom::Streaming | TaskGeom::Wg { .. } => return None,
            };
        let mut plan = GatherPlan {
            v,
            dw,
            channels: shape.c,
            plane_bits: shape.h * shape.w,
            outs: Vec::with_capacity(u * v),
            segs: Vec::new(),
        };
        for y in 0..u {
            for x in 0..v {
                let seg_lo = plan.segs.len() as u32;
                let (per_chan, full) = match windows(y, x) {
                    Some((ay, ax, wh, ww)) => {
                        let in_map = plan.plan_window(shape, ay, ax, wh, ww);
                        ((wh * ww) as u32, in_map == wh * ww)
                    }
                    None => (0, false),
                };
                plan.outs.push(OutPlan {
                    per_chan,
                    full,
                    seg_lo,
                    seg_hi: plan.segs.len() as u32,
                });
            }
        }
        Some(plan)
    }

    /// Emit one window's segments — the same control flow as
    /// `Bitmap::gather_window_words`, with offsets made channel-relative
    /// (source: bits into one channel plane; destination: bits into one
    /// channel block of the pattern). Returns the in-map bit count.
    fn plan_window(&mut self, shape: Shape, ay: isize, ax: isize, wh: usize, ww: usize) -> usize {
        let (h, w) = (shape.h as isize, shape.w as isize);
        let mut pos = 0usize;
        let mut in_map = 0usize;
        for ky in 0..wh {
            let y = ay + ky as isize;
            if y < 0 || y >= h {
                pos += ww; // whole row out of bounds: structural zeros
                continue;
            }
            let x_lo = ax.max(0);
            let x_hi = (ax + ww as isize).min(w);
            if x_lo >= x_hi {
                pos += ww;
                continue;
            }
            pos += (x_lo - ax) as usize;
            let mut base = (y as usize) * shape.w + x_lo as usize;
            let mut left = (x_hi - x_lo) as usize;
            in_map += left;
            while left > 0 {
                let take = left.min(64);
                self.segs.push(Seg { src: base as u32, dst: pos as u32, n: take as u16 });
                pos += take;
                base += take;
                left -= take;
            }
            pos += (ax + ww as isize - x_hi) as usize;
        }
        debug_assert_eq!(pos, wh * ww);
        in_map
    }

    /// Pattern length at spatial position `(y, x)` (same value the
    /// direct gather would return).
    pub fn pattern_len(&self, y: usize, x: usize) -> usize {
        let op = &self.outs[y * self.v + x];
        op.per_chan as usize * if self.dw { 1 } else { self.channels }
    }

    /// Execute the plan for output `(ch, y, x)` against `map`, filling
    /// `out` with the packed pattern exactly as the direct gather would.
    /// With `runs`, all-zero segments are skipped (the scratch is
    /// pre-zeroed, so eliding a zero write changes nothing) and
    /// padding-free all-ones windows short-circuit to
    /// [`PlannedGather::AllOnes`].
    pub fn gather(
        &self,
        map: &Bitmap,
        runs: Option<&RunIndex>,
        ch: usize,
        y: usize,
        x: usize,
        stats: &mut SkipStats,
        out: &mut Vec<u64>,
    ) -> PlannedGather {
        let op = &self.outs[y * self.v + x];
        let nch = if self.dw { 1 } else { self.channels };
        let len = op.per_chan as usize * nch;
        out.clear();
        if len == 0 {
            return PlannedGather::Words { len: 0 };
        }
        let segs = &self.segs[op.seg_lo as usize..op.seg_hi as usize];
        if let Some(runs) = runs {
            if op.full && self.window_all_ones(runs, ch, nch, segs) {
                stats.windows_shortcircuited += 1;
                return PlannedGather::AllOnes { len };
            }
        }
        out.resize(len.div_ceil(64), 0);
        for ci in 0..nch {
            let c = if self.dw { ch } else { ci };
            let src_base = c * self.plane_bits;
            let dst_base = ci * op.per_chan as usize;
            for seg in segs {
                let lo = src_base + seg.src as usize;
                let n = seg.n as usize;
                let (wlo, whi) = (lo / 64, (lo + n - 1) / 64 + 1);
                if let Some(runs) = runs {
                    if runs.all_zero(wlo, whi) {
                        stats.words_skipped += (whi - wlo) as u64;
                        continue;
                    }
                }
                stats.words_gathered += (whi - wlo) as u64;
                or_bits(out, dst_base + seg.dst as usize, map.extract_bits(lo, n), n);
            }
        }
        PlannedGather::Words { len }
    }

    /// Fail-fast check that every source word any segment touches (for
    /// every channel) lies in an all-ones run — in which case the
    /// gathered pattern of a padding-free window is exactly dense.
    fn window_all_ones(&self, runs: &RunIndex, ch: usize, nch: usize, segs: &[Seg]) -> bool {
        for ci in 0..nch {
            let c = if self.dw { ch } else { ci };
            let src_base = c * self.plane_bits;
            for seg in segs {
                let lo = src_base + seg.src as usize;
                let whi = (lo + seg.n as usize - 1) / 64 + 1;
                if !runs.all_ones(lo / 64, whi) {
                    return false;
                }
            }
        }
        true
    }
}

/// Everything that determines a plan's schedule.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct PlanKey {
    c: usize,
    h: usize,
    w: usize,
    u: usize,
    v: usize,
    tg: TaskGeom,
}

/// Process-shareable plan cache (threaded through `SimOptions` behind
/// `Arc`, like `SweepCache`), plus the skip-effectiveness counters the
/// cosim report surfaces. Plans are keyed by content — two layers with
/// the same geometry against same-shaped maps share one plan across
/// images, steps, schemes and worker threads.
#[derive(Debug, Default)]
pub struct GatherPlanCache {
    plans: Mutex<HashMap<PlanKey, Arc<GatherPlan>>>,
    /// When false, plans execute without consulting run indices — the
    /// bench's isolation knob for `exact_zero_skip_speedup`.
    zero_skip: bool,
    words_gathered: AtomicU64,
    words_skipped: AtomicU64,
    windows_shortcircuited: AtomicU64,
}

impl GatherPlanCache {
    /// Plans + RLE-run zero-skip (the production configuration).
    pub fn new() -> GatherPlanCache {
        GatherPlanCache { zero_skip: true, ..GatherPlanCache::default() }
    }

    /// Plans only, zero-skip disabled — isolates the plan speedup.
    pub fn plans_only() -> GatherPlanCache {
        GatherPlanCache::default()
    }

    pub fn zero_skip(&self) -> bool {
        self.zero_skip
    }

    /// The plan for `(shape, tg)` over a `u × v` output plane, building
    /// it on first request. `None` for geometries that don't plan
    /// (`Full`'s one-walk fast path, streamed/pair sources).
    pub fn plan_for(&self, shape: Shape, tg: TaskGeom, u: usize, v: usize) -> Option<Arc<GatherPlan>> {
        let key = PlanKey { c: shape.c, h: shape.h, w: shape.w, u, v, tg };
        let mut plans = self.plans.lock().unwrap();
        if let Some(p) = plans.get(&key) {
            return Some(p.clone());
        }
        let built = Arc::new(GatherPlan::build(shape, tg, u, v)?);
        plans.insert(key, built.clone());
        Some(built)
    }

    /// Distinct plans built so far.
    pub fn len(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fold one tile's locally-accumulated counters in (three atomic
    /// adds per tile, not per segment).
    pub fn absorb(&self, stats: &SkipStats) {
        self.words_gathered.fetch_add(stats.words_gathered, Ordering::Relaxed);
        self.words_skipped.fetch_add(stats.words_skipped, Ordering::Relaxed);
        self.windows_shortcircuited
            .fetch_add(stats.windows_shortcircuited, Ordering::Relaxed);
    }

    /// Counter snapshot (sums — identical at any `--jobs` level).
    pub fn stats(&self) -> SkipStats {
        SkipStats {
            words_gathered: self.words_gathered.load(Ordering::Relaxed),
            words_skipped: self.words_skipped.load(Ordering::Relaxed),
            windows_shortcircuited: self.windows_shortcircuited.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn conv() -> TaskGeom {
        TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 1, dw: false }
    }

    /// Plan-driven gather == direct gather, bit for bit, across
    /// geometries, positions and channels (the module's core contract;
    /// `tests/exact_perf.rs` widens this across patterns).
    #[test]
    fn planned_gather_matches_direct_gather() {
        let shape = Shape::new(5, 11, 13); // ragged rows on purpose
        let mut rng = Pcg32::new(77);
        let map = Bitmap::sample(shape, 0.4, &mut rng);
        let cache = GatherPlanCache::new();
        let runs = map.run_index();
        let geoms = [
            conv(),
            TaskGeom::Conv { r: 5, s: 5, stride: 2, pad: 2, dw: false },
            TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 1, dw: true },
            TaskGeom::ConvT { r: 3, s: 3, stride: 2, pad: 1, dw: false },
            TaskGeom::ConvT { r: 1, s: 1, stride: 2, pad: 0, dw: false },
        ];
        let (u, v) = (8usize, 9usize);
        let mut direct = Vec::new();
        let mut planned = Vec::new();
        for tg in geoms {
            let plan = cache.plan_for(shape, tg, u, v).expect("windowed geometry plans");
            let mut stats = SkipStats::default();
            for ch in [0usize, 4] {
                for y in 0..u {
                    for x in 0..v {
                        let dlen = super::super::backend::gather_operand_words(
                            &map, tg, ch, y, x, &mut direct,
                        );
                        // Both with and without run skipping.
                        for runs in [None, Some(&runs)] {
                            match plan.gather(&map, runs, ch, y, x, &mut stats, &mut planned) {
                                PlannedGather::Words { len } => {
                                    assert_eq!(len, dlen, "{tg:?}@({ch},{y},{x})");
                                    if len > 0 {
                                        assert_eq!(
                                            planned, direct,
                                            "{tg:?}@({ch},{y},{x}) runs={}",
                                            runs.is_some()
                                        );
                                    }
                                }
                                PlannedGather::AllOnes { .. } => {
                                    unreachable!("0.4-density map has no all-ones window")
                                }
                            }
                        }
                    }
                }
            }
            assert_eq!(plan.pattern_len(0, 0), {
                let mut s = Vec::new();
                super::super::backend::gather_operand_words(&map, tg, 0, 0, 0, &mut s)
            });
        }
        // One plan per (shape, geom, plane); repeat lookups share it.
        assert_eq!(cache.len(), geoms.len());
        let again = cache.plan_for(shape, conv(), u, v).unwrap();
        assert!(Arc::ptr_eq(&again, &cache.plan_for(shape, conv(), u, v).unwrap()));
        assert_eq!(cache.len(), geoms.len());
    }

    #[test]
    fn zero_skip_elides_dark_words_without_changing_bits() {
        let shape = Shape::new(4, 16, 16);
        // Channels 0-1 dark, 2-3 sparse: plenty of zero words.
        let mut map = Bitmap::zeros(shape);
        let mut rng = Pcg32::new(3);
        for c in 2..4 {
            for y in 0..16 {
                for x in 0..16 {
                    if rng.bernoulli(0.2) {
                        map.set(c, y, x, true);
                    }
                }
            }
        }
        let runs = map.run_index();
        let cache = GatherPlanCache::new();
        let plan = cache.plan_for(shape, conv(), 16, 16).unwrap();
        let (mut with, mut without) = (Vec::new(), Vec::new());
        let mut stats = SkipStats::default();
        for y in 0..16 {
            for x in 0..16 {
                let a = plan.gather(&map, Some(&runs), 0, y, x, &mut stats, &mut with);
                let b = plan.gather(&map, None, 0, y, x, &mut stats, &mut without);
                assert_eq!(a, b);
                assert_eq!(with, without, "skip must be invisible at ({y},{x})");
            }
        }
        cache.absorb(&stats);
        assert!(cache.stats().words_skipped > 0, "dark channels must be skipped");
        assert_eq!(cache.stats().windows_shortcircuited, 0);
    }

    #[test]
    fn padding_free_all_ones_windows_shortcircuit() {
        let shape = Shape::new(2, 12, 12);
        let map = Bitmap::ones(shape);
        let runs = map.run_index();
        let cache = GatherPlanCache::new();
        let plan = cache.plan_for(shape, conv(), 12, 12).unwrap();
        let mut out = Vec::new();
        let mut stats = SkipStats::default();
        // Interior positions have no padding taps: dense short-circuit.
        let r = plan.gather(&map, Some(&runs), 0, 5, 5, &mut stats, &mut out);
        assert_eq!(r, PlannedGather::AllOnes { len: 2 * 9 });
        assert_eq!(stats.windows_shortcircuited, 1);
        // Edge positions carry structural zero padding — they must NOT
        // short-circuit (the pattern is not dense) and must still match
        // the direct gather.
        let mut direct = Vec::new();
        let dlen = super::super::backend::gather_operand_words(
            &map,
            conv(),
            0,
            0,
            0,
            &mut direct,
        );
        let r = plan.gather(&map, Some(&runs), 0, 0, 0, &mut stats, &mut out);
        assert_eq!(r, PlannedGather::Words { len: dlen });
        assert_eq!(out, direct, "padded windows take the gathered path");
        // Without runs the same interior window gathers normally.
        let r = plan.gather(&map, None, 0, 5, 5, &mut stats, &mut out);
        assert_eq!(r, PlannedGather::Words { len: 18 });
        assert_eq!(out.iter().map(|w| w.count_ones()).sum::<u32>(), 18);
    }

    #[test]
    fn unplannable_geometries_return_none() {
        let cache = GatherPlanCache::plans_only();
        assert!(!cache.zero_skip());
        let shape = Shape::new(2, 4, 4);
        assert!(cache.plan_for(shape, TaskGeom::Full, 1, 1).is_none());
        assert!(cache.plan_for(shape, TaskGeom::Streaming, 4, 4).is_none());
        let wg = TaskGeom::Wg { r: 3, s: 3, stride: 1, pad: 1, gu: 4, gv: 4, dw: false };
        assert!(cache.plan_for(shape, wg, 4, 4).is_none());
        assert!(cache.is_empty());
    }

    #[test]
    fn convt_empty_windows_plan_to_zero_length() {
        // r < stride: odd positions have structurally no taps.
        let shape = Shape::new(3, 4, 4);
        let map = Bitmap::ones(shape);
        let cache = GatherPlanCache::new();
        let tg = TaskGeom::ConvT { r: 1, s: 1, stride: 2, pad: 0, dw: false };
        let plan = cache.plan_for(shape, tg, 8, 8).unwrap();
        let mut out = Vec::new();
        let mut stats = SkipStats::default();
        assert_eq!(
            plan.gather(&map, None, 0, 1, 0, &mut stats, &mut out),
            PlannedGather::Words { len: 0 }
        );
        assert_eq!(plan.pattern_len(1, 0), 0);
        match plan.gather(&map, None, 0, 2, 2, &mut stats, &mut out) {
            PlannedGather::Words { len } => assert_eq!(len, 3),
            other => panic!("expected a 3-tap window, got {other:?}"),
        }
    }
}
