//! Cycle-level simulator of the proposed accelerator (§4–§5).
//!
//! Granularity: lane-group analytic (DESIGN.md §5) — per output neuron the
//! model computes expected lane-maximum cycles from the operand sparsity,
//! aggregates per PE tile with spatial sparsity variation, then runs the
//! WDU redistribution event loop over tile timelines. MAC/skip counts are
//! exact in expectation; the stochastic per-tile jitter reproduces the
//! load-imbalance phenomena of Fig 17.
//!
//! Execution is split into pure task construction and per-image
//! stochastic execution (`engine`), with a parallel cached sweep layer on
//! top (`sweep`) that every report generator and the CLI route through.
//! Tile costing is pluggable (`backend`): the analytic expected-value
//! model above is the default, and the cycle-accurate bitmap-driven
//! `ExactPe` (`exact`) runs the same engine→sweep→cosim→CLI stack when
//! `SimOptions::backend` selects it.

mod pe;
mod adder_tree;
mod backend;
mod blocking;
mod density;
mod tile;
mod wdu;
mod memory;
mod energy;
mod layer_exec;
mod engine;
mod exact;
mod plan;
mod replay;
mod sweep;

pub use adder_tree::{tree_utilization, ReconfigMode};
pub use backend::{exact_tile_cost, BitmapSource, ExecBackend, TaskGeom, TileGeom};
pub use exact::{count_bits_range, random_bitmap, ExactOutput, ExactPe, OperandPattern};
pub use plan::{GatherPlan, GatherPlanCache, PlannedGather, SkipStats};
pub use replay::{PairMaps, ReplayBank, ReplayMap, StepMaps, TaskMaps};
pub use blocking::synapse_passes;
pub use density::{DensitySummary, LayerDensity};
pub use energy::{layer_energy, EnergyBreakdown};
pub use engine::{
    build_image_tasks, build_task, image_stream, simulate_image, simulate_network,
    simulate_network_jobs, ImageTask, LayerAgg, NetworkSimResult, PhaseTotals,
};
pub use layer_exec::{simulate_layer, simulate_layer_replay, LayerSimResult, LayerTask};
pub use memory::{layer_traffic, MemoryModel};
pub use pe::{expected_lane_max, expected_max_std_normal, PeModel};
pub use sweep::{
    sweep_report_json, SweepCache, SweepCombo, SweepKey, SweepPlan, SweepRunner, SIM_REVISION,
};
pub use tile::{tile_outputs, tile_windows, TileState};
pub use wdu::{redistribute, WduOutcome};
