//! Re-configurable adder tree (§4.5, Fig 10/16).
//!
//! When an output's receptive field occupies fewer than all lanes, the
//! de-mux stages let several independent outputs reduce simultaneously —
//! but only at power-of-two lane groups. *Direct* reconfiguration packs
//! `2^⌊log2(lanes/occ)⌋` outputs; *hierarchical* reconfiguration
//! additionally blocks the filter kernels to the nearest aligned size and
//! schedules the remainder in later iterations, recovering (almost) full
//! lane utilization for awkward occupancies such as 9/16 (the paper's
//! [3×3×64] example, Fig 16, ≈1.75× over direct).

/// Adder-tree operating mode (Fig 16 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReconfigMode {
    /// No reconfiguration: one output at a time regardless of occupancy.
    None,
    /// Power-of-two packing only.
    Direct,
    /// Hierarchical packing with remainder scheduling (§4.5).
    Hierarchical,
}

/// Residual overhead of hierarchical remainder scheduling (extra passes'
/// control + partial writeback), calibrated so the Fig 16 ratio holds.
const HIER_EFFICIENCY: f64 = 0.98;

/// Fraction of the PE's MAC slots a single output stream keeps busy,
/// given its lane occupancy. The PE model divides per-output cycles by
/// `lanes/occ · util` to account for packing.
pub fn tree_utilization(occ: usize, lanes: usize, mode: ReconfigMode) -> f64 {
    assert!(occ >= 1 && occ <= lanes, "occupancy {occ} of {lanes}");
    if occ == lanes {
        return 1.0;
    }
    match mode {
        ReconfigMode::None => occ as f64 / lanes as f64,
        ReconfigMode::Direct => {
            let par = (lanes / occ).next_power_of_two() / 2;
            let par = if lanes / occ >= 1 && (lanes / occ).is_power_of_two() {
                lanes / occ
            } else {
                par.max(1)
            };
            (occ * par) as f64 / lanes as f64
        }
        ReconfigMode::Hierarchical => HIER_EFFICIENCY,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_occupancy_is_unity_in_all_modes() {
        for mode in [ReconfigMode::None, ReconfigMode::Direct, ReconfigMode::Hierarchical] {
            assert_eq!(tree_utilization(16, 16, mode), 1.0);
        }
    }

    #[test]
    fn none_mode_wastes_idle_lanes() {
        assert!((tree_utilization(1, 16, ReconfigMode::None) - 1.0 / 16.0).abs() < 1e-12);
        assert!((tree_utilization(9, 16, ReconfigMode::None) - 9.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn direct_packs_powers_of_two() {
        // occ=2 → 8 outputs in parallel → full utilization.
        assert!((tree_utilization(2, 16, ReconfigMode::Direct) - 1.0).abs() < 1e-12);
        // occ=4 → 4 outputs → full.
        assert!((tree_utilization(4, 16, ReconfigMode::Direct) - 1.0).abs() < 1e-12);
        // occ=3 → par 4 would need 12 lanes: 3·4/16 = 0.75.
        assert!((tree_utilization(3, 16, ReconfigMode::Direct) - 0.75).abs() < 1e-12);
        // occ=9 → par 1 → 9/16 (the Fig 16 worst case).
        assert!((tree_utilization(9, 16, ReconfigMode::Direct) - 0.5625).abs() < 1e-12);
    }

    #[test]
    fn fig16_hierarchical_ratio() {
        // Fig 16: [3×3×64] improves ≈1.75× with hierarchical reconfig.
        let direct = tree_utilization(9, 16, ReconfigMode::Direct);
        let hier = tree_utilization(9, 16, ReconfigMode::Hierarchical);
        let ratio = hier / direct;
        assert!((1.6..1.9).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn hierarchical_dominates_direct_dominates_none() {
        for occ in 1..=16 {
            let n = tree_utilization(occ, 16, ReconfigMode::None);
            let d = tree_utilization(occ, 16, ReconfigMode::Direct);
            let h = tree_utilization(occ, 16, ReconfigMode::Hierarchical);
            assert!(d >= n - 1e-12, "occ {occ}");
            assert!(h >= d - 0.03, "occ {occ}: hier {h} direct {d}");
        }
    }

    #[test]
    #[should_panic]
    fn zero_occupancy_panics() {
        tree_utilization(0, 16, ReconfigMode::None);
    }
}
