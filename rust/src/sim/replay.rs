//! Pattern replay: turn a trace file's captured bitmaps (v2/v3) into
//! the per-(layer, phase) operand/output maps the exact backend slices
//! its tile patterns from — the bridge that makes co-simulation
//! *pattern-exact* instead of fraction-exact.
//!
//! Mapping (per traced step), derived from the same §2.1/§3 reasoning as
//! `sparsity::analyze`:
//!
//! * **FP operand** of layer `l` — the activation bitmap of `l`'s
//!   producing ReLU (zeros in the input feature map).
//! * **BP operand** of `l` — the ReLU-masked *gradient* bitmap of the
//!   ReLU consuming `l`'s output (the gradient arriving at `l`'s
//!   output), resolved **through residual Adds**: Add backward is the
//!   identity into every branch, so a conv feeding an Add whose (only)
//!   consumer chain ends at a ReLU replays that ReLU's gradient map —
//!   the Add-fed BP tail of BN-free residual networks. Dense when `l`
//!   feeds BatchNorm, or when gradients from several consumers sum.
//! * **BP output mask** of `l` — the activation bitmap of `l`'s
//!   producing ReLU (the §3.2 identity: the input-gradient footprint is
//!   contained in the forward activation footprint, known a priori).
//! * **WG** tasks carry a *pair*: the producer activation footprint and
//!   the consumer gradient map (same Add-aware resolution), joined
//!   tap-by-tap by the exact backend (`sim::backend::BitmapSource::
//!   Pair`) — the dominant WG phase replays instead of sampling. A
//!   missing side (raw-image activations, BatchNorm-densified
//!   gradients) is structurally dense.
//!
//! Activation footprints additionally propagate *exactly* through
//! pooling and concatenation: ReLU outputs are non-negative, so a
//! max/avg-pool output is non-zero iff any window element is — an OR
//! over the window — and GAP reduces to a per-channel any. Convs fed
//! through pool/GAP/concat therefore still replay measured operands
//! (the scheme gates in `sim::layer_exec` decide, as before, whether a
//! map is *exploitable*; a MaxPool producer still yields no BP output
//! sparsity). Add outputs are the one place derivation stops — conv
//! summands can be negative, so the footprint is knowable only at
//! capture time — which is exactly what the v3 trace format's
//! **post-Add footprints** (act-only Add entries) provide; a captured
//! map always takes precedence over re-derivation.
//!
//! Images map onto traced steps round-robin (`image % steps`), so a
//! batch replays across every captured step deterministically — the
//! per-image independence the parallel engine's bit-identical contract
//! rests on is untouched, because the mapping depends on the image index
//! only.

use std::collections::{HashMap, HashSet};
use std::sync::Arc;

use crate::nn::{LayerId, LayerKind, Network, Phase, Shape};
use crate::sparsity::{Bitmap, RunIndex};
use crate::trace::TraceFile;

/// One captured map plus its precomputed zero fraction (the memory and
/// energy accounting wants the fraction without re-popcounting the map
/// for every image) and word-run structure (`runs`) — the zero/one run
/// index the exact backend's planned gathers skip through. Both are
/// computed once per resolved map, here, and shared across every image
/// and tile that replays it.
#[derive(Clone, Debug)]
pub struct ReplayMap {
    pub map: Arc<Bitmap>,
    pub sparsity: f64,
    pub runs: Arc<RunIndex>,
}

impl ReplayMap {
    /// Resolve a captured map for replay. The run index is scanned from
    /// the *reconstructed* words on purpose: a v3 trace's on-disk RLE
    /// runs describe the delta payload, not the map it decodes to.
    pub fn new(map: Arc<Bitmap>) -> ReplayMap {
        let sparsity = map.sparsity();
        let runs = Arc::new(map.run_index());
        ReplayMap { map, sparsity, runs }
    }
}

/// Joint activation×gradient payload of a weight-gradient task. A
/// missing side is structurally dense (raw-image activations, or a
/// BatchNorm-densified gradient); at least one side is always present.
#[derive(Clone, Debug)]
pub struct PairMaps {
    /// Producer activation footprint (the conv's input map).
    pub act: Option<ReplayMap>,
    /// Consumer-ReLU gradient map (the conv's output gradient).
    pub grad: Option<ReplayMap>,
}

impl PairMaps {
    /// Measured joint zero fraction: a WG MAC survives only when both
    /// operands are non-zero (the two maps live at different positions,
    /// so independence is the right combination rule — the same one
    /// `engine::build_task` applies to the modeled fractions).
    pub fn joint_sparsity(&self) -> f64 {
        let sa = self.act.as_ref().map_or(0.0, |m| m.sparsity);
        let sg = self.grad.as_ref().map_or(0.0, |m| m.sparsity);
        1.0 - (1.0 - sa) * (1.0 - sg)
    }
}

/// The replay payloads one (layer, phase) task consumes.
#[derive(Clone, Debug, Default)]
pub struct TaskMaps {
    /// Operand (input) pattern the PE lanes drain (FP/BP).
    pub operand: Option<ReplayMap>,
    /// A-priori output mask (BP only, Fig 5c).
    pub output: Option<ReplayMap>,
    /// Joint activation×gradient operand (WG only).
    pub pair: Option<PairMaps>,
}

impl TaskMaps {
    pub fn is_empty(&self) -> bool {
        self.operand.is_none() && self.output.is_none() && self.pair.is_none()
    }
}

#[derive(Clone, Debug, Default)]
struct LayerMaps {
    fp: TaskMaps,
    bp: TaskMaps,
    wg: TaskMaps,
}

/// Every task's replay maps for one traced step.
#[derive(Debug, Default)]
pub struct StepMaps {
    by_layer: HashMap<String, LayerMaps>,
}

impl StepMaps {
    /// The maps a (layer, phase) task replays, if any were captured.
    pub fn task_maps(&self, layer: &str, phase: Phase) -> Option<&TaskMaps> {
        let lm = self.by_layer.get(layer)?;
        let tm = match phase {
            Phase::Forward => &lm.fp,
            Phase::Backward => &lm.bp,
            Phase::WeightGrad => &lm.wg,
        };
        (!tm.is_empty()).then_some(tm)
    }

    /// Bitmap words resident in this step's resolved maps, counting each
    /// shared map once (the fp/bp/wg slots alias the same `Arc`s by
    /// construction).
    fn resident_words(&self) -> usize {
        let mut seen: HashSet<*const Bitmap> = HashSet::new();
        let mut words = 0usize;
        let mut tally = |m: Option<&ReplayMap>| {
            if let Some(m) = m {
                if seen.insert(Arc::as_ptr(&m.map)) {
                    words += m.map.words().len();
                }
            }
        };
        for lm in self.by_layer.values() {
            for tm in [&lm.fp, &lm.bp, &lm.wg] {
                tally(tm.operand.as_ref());
                tally(tm.output.as_ref());
                if let Some(pair) = &tm.pair {
                    tally(pair.act.as_ref());
                    tally(pair.grad.as_ref());
                }
            }
        }
        words
    }
}

/// OR-pool a footprint: the pooled output is non-zero iff any window
/// element is — exact for max/avg pooling of non-negative (post-ReLU)
/// values, which is the only place pooling appears in these networks.
fn pooled_footprint(src: &Bitmap, out: Shape, k: usize, stride: usize, pad: usize) -> Bitmap {
    debug_assert_eq!(src.shape.c, out.c);
    let mut b = Bitmap::zeros(out);
    for c in 0..out.c {
        for oy in 0..out.h {
            for ox in 0..out.w {
                'win: for ky in 0..k {
                    for kx in 0..k {
                        let y = (oy * stride + ky) as isize - pad as isize;
                        let x = (ox * stride + kx) as isize - pad as isize;
                        if y >= 0
                            && x >= 0
                            && (y as usize) < src.shape.h
                            && (x as usize) < src.shape.w
                            && src.get(c, y as usize, x as usize)
                        {
                            b.set(c, oy, ox, true);
                            break 'win;
                        }
                    }
                }
            }
        }
    }
    b
}

/// A-priori non-zero footprint at layer `id`'s output, derived from one
/// step's captured activation maps. A *captured* map for this layer —
/// a ReLU's bitmap, or a v3 trace's post-Add footprint — always wins;
/// otherwise the footprint propagates exactly through
/// Max/Avg/GlobalAvgPool and Concat, and is `None` for anything whose
/// footprint is not known a priori (conv/fc/bn outputs can be non-zero
/// anywhere, and an *uncaptured* Add stops derivation because its
/// summands' signs are unknown — the v2-era limitation the post-Add
/// capture removes).
fn derive_footprint(
    net: &Network,
    id: LayerId,
    acts: &HashMap<String, Arc<Bitmap>>,
    memo: &mut HashMap<LayerId, Option<Arc<Bitmap>>>,
) -> Option<Arc<Bitmap>> {
    if let Some(hit) = memo.get(&id) {
        return hit.clone();
    }
    let l = net.layer(id);
    let got: Option<Arc<Bitmap>> = if let Some(m) = acts.get(l.name.as_str()) {
        Some(m.clone())
    } else {
        match l.kind {
            LayerKind::MaxPool { k, stride, pad } | LayerKind::AvgPool { k, stride, pad } => {
                derive_footprint(net, l.inputs[0], acts, memo)
                    .map(|src| Arc::new(pooled_footprint(&src, l.out, k, stride, pad)))
            }
            LayerKind::GlobalAvgPool => {
                derive_footprint(net, l.inputs[0], acts, memo).map(|src| {
                    let mut b = Bitmap::zeros(l.out);
                    for c in 0..l.out.c {
                        if src.wc_nz(c) > 0 {
                            b.set(c, 0, 0, true);
                        }
                    }
                    Arc::new(b)
                })
            }
            LayerKind::Concat => {
                let srcs: Option<Vec<Arc<Bitmap>>> = l
                    .inputs
                    .iter()
                    .map(|&i| derive_footprint(net, i, acts, memo))
                    .collect();
                srcs.map(|srcs| {
                    let mut b = Bitmap::zeros(l.out);
                    let mut c0 = 0usize;
                    for src in &srcs {
                        for c in 0..src.shape.c {
                            for y in 0..src.shape.h {
                                for x in 0..src.shape.w {
                                    if src.get(c, y, x) {
                                        b.set(c0 + c, y, x, true);
                                    }
                                }
                            }
                        }
                        c0 += src.shape.c;
                    }
                    Arc::new(b)
                })
            }
            _ => None,
        }
    };
    memo.insert(id, got.clone());
    got
}

/// Gradient map arriving at layer `id`'s output, resolved through the
/// graph: the masked gradient bitmap of a directly-consuming ReLU, or
/// the same map passed *unchanged through a residual Add* (Add backward
/// is the identity into every branch) — the resolution that lets the
/// Add-fed BP tail of BN-free residual networks replay. A layer with
/// more than one consumer sums gradient contributions, so no single
/// captured map describes it (`None`, structurally dense/unknown);
/// BatchNorm/conv/pool consumers densify or scatter and yield `None`
/// exactly as before.
fn derive_grad(
    net: &Network,
    consumers: &[Vec<LayerId>],
    id: LayerId,
    grads: &HashMap<String, Arc<Bitmap>>,
) -> Option<Arc<Bitmap>> {
    let cs = &consumers[id];
    if cs.len() != 1 {
        return None;
    }
    let k = net.layer(cs[0]);
    match k.kind {
        LayerKind::ReLU => grads.get(k.name.as_str()).cloned(),
        LayerKind::Add => derive_grad(net, consumers, k.id, grads),
        _ => None,
    }
}

/// All replayable steps of one trace, resolved against a network.
#[derive(Debug)]
pub struct ReplayBank {
    steps: Vec<StepMaps>,
    fingerprint: u64,
    network: String,
}

/// Validate one traced layer's payload shapes against the network.
fn check_traced_shapes(
    net: &Network,
    name: &str,
    act: Option<&Bitmap>,
    grad: Option<&Bitmap>,
) -> anyhow::Result<()> {
    let traced_layer = net
        .by_name(name)
        .ok_or_else(|| anyhow::anyhow!("traced layer '{name}' not in '{}'", net.name))?;
    for (what, bm) in [("act", act), ("grad", grad)] {
        if let Some(b) = bm {
            anyhow::ensure!(
                b.shape == traced_layer.out,
                "{what} bitmap of '{name}' is {} but the layer produces {}",
                b.shape,
                traced_layer.out
            );
        }
    }
    Ok(())
}

/// Resolve one step's captured act/grad maps against the graph: the
/// footprint/gradient derivations above, fanned over every compute
/// layer. Shared by the borrowing and owning bank constructors.
fn resolve_step(
    net: &Network,
    consumers: &[Vec<LayerId>],
    acts: &HashMap<String, Arc<Bitmap>>,
    grads: &HashMap<String, Arc<Bitmap>>,
) -> StepMaps {
    let mut memo: HashMap<LayerId, Option<Arc<Bitmap>>> = HashMap::new();
    let mut by_layer = HashMap::new();
    for layer in net.compute_layers() {
        // Producer footprint: the captured map (ReLU or post-Add),
        // or its exact OR-propagation through pooling/concat.
        let act = derive_footprint(net, layer.inputs[0], acts, &mut memo).map(ReplayMap::new);
        // Gradient at this layer's output: a consuming ReLU's
        // masked map, resolved through residual Adds.
        let grad = derive_grad(net, consumers, layer.id, grads).map(ReplayMap::new);
        let pair = (act.is_some() || grad.is_some())
            .then(|| PairMaps { act: act.clone(), grad: grad.clone() });
        let lm = LayerMaps {
            fp: TaskMaps { operand: act.clone(), ..TaskMaps::default() },
            bp: TaskMaps { operand: grad, output: act, pair: None },
            wg: TaskMaps { pair, ..TaskMaps::default() },
        };
        if !lm.fp.is_empty() || !lm.bp.is_empty() || !lm.wg.is_empty() {
            by_layer.insert(layer.name.clone(), lm);
        }
    }
    StepMaps { by_layer }
}

impl ReplayBank {
    /// Resolve a trace's bitmap payloads against the network's graph.
    /// Errors when the trace carries no payloads at all, or when a
    /// payload's shape contradicts the named ReLU's output shape (a
    /// mis-paired trace/network is a caller bug, not a fallback case).
    pub fn from_trace(net: &Network, trace: &TraceFile) -> anyhow::Result<ReplayBank> {
        anyhow::ensure!(
            trace.has_bitmaps(),
            "trace file for '{}' carries no bitmap payloads (v1 or scalar-only v2); \
             capture one with `agos trace` or a payload-capturing `agos train`",
            trace.network
        );
        let consumers = net.consumer_map();
        let mut steps = Vec::new();
        for s in &trace.steps {
            // traced layer name -> act/grad map for this step — ReLU
            // act+grad pairs, plus act-only post-Add footprints.
            let mut acts: HashMap<String, Arc<Bitmap>> = HashMap::new();
            let mut grads: HashMap<String, Arc<Bitmap>> = HashMap::new();
            for lt in &s.layers {
                if !lt.has_bitmaps() {
                    continue;
                }
                check_traced_shapes(
                    net,
                    &lt.name,
                    lt.act_bitmap.as_ref(),
                    lt.grad_bitmap.as_ref(),
                )?;
                if let Some(b) = &lt.act_bitmap {
                    acts.insert(lt.name.clone(), Arc::new(b.clone()));
                }
                if let Some(b) = &lt.grad_bitmap {
                    grads.insert(lt.name.clone(), Arc::new(b.clone()));
                }
            }
            if acts.is_empty() && grads.is_empty() {
                continue; // scalar-only step: nothing to replay
            }
            steps.push(resolve_step(net, &consumers, &acts, &grads));
        }
        anyhow::ensure!(!steps.is_empty(), "no replayable step resolved against '{}'", net.name);
        Ok(ReplayBank {
            steps,
            fingerprint: trace.fingerprint(),
            network: net.name.clone(),
        })
    }

    /// [`ReplayBank::from_trace`], but *consuming* the trace: every
    /// captured bitmap moves into its bank `Arc` instead of being
    /// cloned — the decode-into-bank path for callers that own their
    /// freshly-loaded trace (`agos cosim` does), where a v4 load
    /// becomes file bytes → words → bank with no payload copied twice.
    pub fn from_trace_owned(net: &Network, mut trace: TraceFile) -> anyhow::Result<ReplayBank> {
        anyhow::ensure!(
            trace.has_bitmaps(),
            "trace file for '{}' carries no bitmap payloads (v1 or scalar-only v2); \
             capture one with `agos trace` or a payload-capturing `agos train`",
            trace.network
        );
        // The fingerprint covers the payloads, so take it before they
        // move out.
        let fingerprint = trace.fingerprint();
        let consumers = net.consumer_map();
        let mut steps = Vec::new();
        for s in std::mem::take(&mut trace.steps) {
            let mut acts: HashMap<String, Arc<Bitmap>> = HashMap::new();
            let mut grads: HashMap<String, Arc<Bitmap>> = HashMap::new();
            for lt in s.layers {
                if !lt.has_bitmaps() {
                    continue;
                }
                check_traced_shapes(
                    net,
                    &lt.name,
                    lt.act_bitmap.as_ref(),
                    lt.grad_bitmap.as_ref(),
                )?;
                if let Some(b) = lt.act_bitmap {
                    acts.insert(lt.name.clone(), Arc::new(b));
                }
                if let Some(b) = lt.grad_bitmap {
                    grads.insert(lt.name, Arc::new(b));
                }
            }
            if acts.is_empty() && grads.is_empty() {
                continue; // scalar-only step: nothing to replay
            }
            steps.push(resolve_step(net, &consumers, &acts, &grads));
        }
        anyhow::ensure!(!steps.is_empty(), "no replayable step resolved against '{}'", net.name);
        Ok(ReplayBank { steps, fingerprint, network: net.name.clone() })
    }

    /// The step image `i` replays (round-robin over captured steps).
    pub fn step_maps(&self, image: usize) -> &StepMaps {
        &self.steps[image % self.steps.len()]
    }

    pub fn steps(&self) -> usize {
        self.steps.len()
    }

    pub fn network(&self) -> &str {
        &self.network
    }

    /// The underlying trace's content fingerprint — folded into
    /// `SimOptions::fingerprint` so replayed runs can never alias sampled
    /// runs (or replays of a different trace) in the sweep cache.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Resident payload footprint across every step, in 64-bit words —
    /// what one shared bank actually pins in memory. `agos serve`'s
    /// `ping` reports this per resident bank; it is also the cost a
    /// second concurrent request *avoids* by sharing the `Arc` instead
    /// of re-decoding the trace.
    pub fn resident_words(&self) -> usize {
        self.steps.iter().map(StepMaps::resident_words).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{zoo, Shape};
    use crate::trace::{LayerTrace, StepTrace};
    use crate::util::rng::Pcg32;

    fn traced_pair(shape: Shape, density: f64, rng: &mut Pcg32) -> (Bitmap, Bitmap) {
        let act = Bitmap::sample(shape, density, rng);
        let keep = Bitmap::sample(shape, 0.8, rng);
        let grad = act.and(&keep);
        (act, grad)
    }

    fn bitmap_trace() -> TraceFile {
        let net = zoo::agos_cnn();
        let mut rng = Pcg32::new(2);
        let mut t = TraceFile::new("agos_cnn");
        for step in 0..2 {
            let layers = (1..=4)
                .map(|i| {
                    let name = format!("relu{i}");
                    let shape = net.by_name(&name).unwrap().out;
                    let (act, grad) = traced_pair(shape, 0.5, &mut rng);
                    LayerTrace::from_bitmaps(&name, act, grad)
                })
                .collect();
            t.steps.push(StepTrace { step, loss: 2.0 - step as f64, layers });
        }
        t
    }

    #[test]
    fn bank_resolves_fp_bp_maps_against_the_graph() {
        let net = zoo::agos_cnn();
        let trace = bitmap_trace();
        let bank = ReplayBank::from_trace(&net, &trace).unwrap();
        assert_eq!(bank.steps(), 2);
        let s0 = bank.step_maps(0);
        // conv2's producer is relu1, its consumer is relu2.
        let bp = s0.task_maps("conv2", Phase::Backward).unwrap();
        let relu1 = net.by_name("relu1").unwrap().out;
        let relu2 = net.by_name("relu2").unwrap().out;
        assert_eq!(bp.output.as_ref().unwrap().map.shape, relu1);
        assert_eq!(bp.operand.as_ref().unwrap().map.shape, relu2);
        let fp = s0.task_maps("conv2", Phase::Forward).unwrap();
        assert_eq!(fp.operand.as_ref().unwrap().map.shape, relu1);
        assert!(fp.output.is_none(), "FP has no a-priori output mask");
        // conv1 reads the dense image: no FP payload.
        assert!(s0.task_maps("conv1", Phase::Forward).is_none());
        // WG replays the joint pair: conv2's act side is relu1, grad side
        // relu2; conv1's act side is the raw image (dense, absent).
        let wg = s0.task_maps("conv2", Phase::WeightGrad).unwrap();
        let pair = wg.pair.as_ref().unwrap();
        assert_eq!(pair.act.as_ref().unwrap().map.shape, relu1);
        assert_eq!(pair.grad.as_ref().unwrap().map.shape, relu2);
        assert!(pair.joint_sparsity() > pair.grad.as_ref().unwrap().sparsity - 1e-12);
        let wg1 = s0.task_maps("conv1", Phase::WeightGrad).unwrap();
        let pair1 = wg1.pair.as_ref().unwrap();
        assert!(pair1.act.is_none(), "conv1 activations are the raw image");
        assert!(pair1.grad.is_some());
        // Image round-robin wraps over the two steps.
        assert!(!std::ptr::eq(bank.step_maps(0), bank.step_maps(1)));
        assert!(std::ptr::eq(bank.step_maps(0), bank.step_maps(2)));
        assert_eq!(bank.fingerprint(), trace.fingerprint());
    }

    #[test]
    fn footprints_propagate_exactly_through_gap_to_the_fc() {
        // agos_cnn: fc's producer is GAP(relu4). The derived [64,1,1]
        // footprint must be the per-channel any() of relu4's map — exact
        // for non-negative activations — so the fc task replays too.
        let net = zoo::agos_cnn();
        let trace = bitmap_trace();
        let bank = ReplayBank::from_trace(&net, &trace).unwrap();
        let s0 = bank.step_maps(0);
        let fc = s0.task_maps("fc", Phase::Forward).unwrap();
        let derived = &fc.operand.as_ref().unwrap().map;
        assert_eq!(derived.shape, Shape::new(64, 1, 1));
        // Reference against the captured relu4 map of step 0.
        let relu4 = trace.steps[0]
            .layers
            .iter()
            .find(|l| l.name == "relu4")
            .and_then(|l| l.act_bitmap.clone())
            .unwrap();
        for c in 0..64 {
            assert_eq!(derived.get(c, 0, 0), relu4.wc_nz(c) > 0, "channel {c}");
        }
        // fc WG pair: act side is the derived GAP footprint, grad side is
        // absent (softmax consumer).
        let wg = s0.task_maps("fc", Phase::WeightGrad).unwrap();
        let pair = wg.pair.as_ref().unwrap();
        assert_eq!(pair.act.as_ref().unwrap().map.shape, Shape::new(64, 1, 1));
        assert!(pair.grad.is_none());
    }

    #[test]
    fn pooled_footprint_is_the_window_or() {
        let mut src = Bitmap::zeros(Shape::new(1, 4, 4));
        src.set(0, 0, 0, true);
        src.set(0, 3, 3, true);
        let out = pooled_footprint(&src, Shape::new(1, 2, 2), 2, 2, 0);
        assert!(out.get(0, 0, 0));
        assert!(!out.get(0, 0, 1));
        assert!(!out.get(0, 1, 0));
        assert!(out.get(0, 1, 1));
        // Padding windows that reach off the map see only zeros there.
        let padded = pooled_footprint(&src, Shape::new(1, 3, 3), 2, 2, 1);
        assert!(padded.get(0, 0, 0), "(-1,-1)..(0,0) window sees (0,0)");
        assert_eq!(padded.count_nz(), 2);
    }

    #[test]
    fn grad_maps_pass_through_residual_adds() {
        // agos_resnet's b1_conv2 feeds its Add directly; the gradient at
        // its output is b1_relu2's masked map passed through the Add.
        use crate::config::BitmapPattern;
        use crate::sparsity::{capture_synthetic_trace, SparsityModel};
        let net = zoo::agos_resnet();
        let model = SparsityModel::synthetic(7);
        let trace = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Iid, 2);
        let bank = ReplayBank::from_trace(&net, &trace).unwrap();
        let s0 = bank.step_maps(0);

        let relu_grad = |name: &str| {
            trace.steps[0]
                .layers
                .iter()
                .find(|l| l.name == name)
                .and_then(|l| l.grad_bitmap.clone())
                .unwrap()
        };
        let bp = s0.task_maps("b1_conv2", Phase::Backward).unwrap();
        assert_eq!(
            *bp.operand.as_ref().unwrap().map,
            relu_grad("b1_relu2"),
            "Add backward is the identity: the post-add ReLU's grad map replays"
        );
        // The WG pair's grad side resolves through the Add too.
        let wg = s0.task_maps("b1_conv2", Phase::WeightGrad).unwrap();
        let pair = wg.pair.as_ref().unwrap();
        assert_eq!(*pair.grad.as_ref().unwrap().map, relu_grad("b1_relu2"));
        // b2_add has two consumers (post-add ReLU + block 3's shortcut):
        // gradients sum there, so its branches stay structurally dense.
        let bp2 = s0.task_maps("b2_conv2", Phase::Backward).unwrap();
        assert!(bp2.operand.is_none(), "summed gradients have no single map");
        assert!(bp2.output.is_some(), "the output mask still replays");
    }

    #[test]
    fn post_add_footprints_resolve_the_add_fed_head() {
        // b3_add feeds GAP -> fc with no post-add ReLU: the fc operand
        // footprint must derive from the captured post-Add map.
        use crate::config::BitmapPattern;
        use crate::sparsity::{capture_synthetic_trace, SparsityModel};
        let net = zoo::agos_resnet();
        let model = SparsityModel::synthetic(9);
        let trace = capture_synthetic_trace(&net, &model, 1, BitmapPattern::Iid, 2);
        let bank = ReplayBank::from_trace(&net, &trace).unwrap();
        let s0 = bank.step_maps(0);
        let fc = s0.task_maps("fc", Phase::Forward).unwrap();
        let derived = &fc.operand.as_ref().unwrap().map;
        assert_eq!(derived.shape, Shape::new(32, 1, 1));
        // Reference: per-channel any() of the captured b3_add footprint.
        let post_add = trace.steps[0]
            .layers
            .iter()
            .find(|l| l.name == "b3_add")
            .and_then(|l| l.act_bitmap.clone())
            .expect("v3 capture records post-Add footprints");
        for c in 0..32 {
            assert_eq!(derived.get(c, 0, 0), post_add.wc_nz(c) > 0, "channel {c}");
        }
        // Without the post-Add entries (v2-era trace), the head's
        // derivation stops at the Add and the fc task has no FP operand.
        let mut v2_era = trace.clone();
        for s in &mut v2_era.steps {
            s.layers.retain(|l| !l.name.ends_with("_add"));
        }
        let old_bank = ReplayBank::from_trace(&net, &v2_era).unwrap();
        let old_fc = old_bank.step_maps(0).task_maps("fc", Phase::Forward);
        assert!(
            old_fc.is_none() || old_fc.unwrap().operand.is_none(),
            "derivation must stop at an uncaptured Add"
        );
    }

    #[test]
    fn scalar_trace_and_shape_mismatch_are_rejected() {
        let net = zoo::agos_cnn();
        let mut scalar = TraceFile::new("agos_cnn");
        scalar.steps.push(StepTrace {
            step: 0,
            loss: 1.0,
            layers: vec![LayerTrace::scalar("relu1", 0.5, 0.5, true)],
        });
        assert!(ReplayBank::from_trace(&net, &scalar).is_err());

        let mut wrong = bitmap_trace();
        let mut rng = Pcg32::new(3);
        let (act, grad) = traced_pair(Shape::new(2, 2, 2), 0.5, &mut rng);
        wrong.steps[0].layers[0] = LayerTrace::from_bitmaps("relu1", act, grad);
        assert!(ReplayBank::from_trace(&net, &wrong).is_err(), "shape mismatch must error");
    }
}
