//! Pattern replay: turn a v2 trace file's captured per-ReLU bitmaps into
//! the per-(layer, phase) operand/output maps the exact backend slices
//! its tile patterns from — the bridge that makes co-simulation
//! *pattern-exact* instead of fraction-exact.
//!
//! Mapping (per traced step), derived from the same §2.1/§3 reasoning as
//! `sparsity::analyze`:
//!
//! * **FP operand** of layer `l` — the activation bitmap of `l`'s
//!   producing ReLU (zeros in the input feature map).
//! * **BP operand** of `l` — the ReLU-masked *gradient* bitmap of the
//!   ReLU consuming `l`'s output (the gradient arriving at `l`'s output;
//!   dense when `l` feeds BatchNorm instead, so no map is attached).
//! * **BP output mask** of `l` — the activation bitmap of `l`'s
//!   producing ReLU (the §3.2 identity: the input-gradient footprint is
//!   contained in the forward activation footprint, known a priori).
//! * **WG** tasks carry no payload (joint activation×gradient operands
//!   live on two differently-shaped maps) and fall back to sampling.
//!
//! Images map onto traced steps round-robin (`image % steps`), so a
//! batch replays across every captured step deterministically — the
//! per-image independence the parallel engine's bit-identical contract
//! rests on is untouched, because the mapping depends on the image index
//! only.

use std::collections::HashMap;
use std::sync::Arc;

use crate::nn::{Network, Phase};
use crate::sparsity::Bitmap;
use crate::trace::TraceFile;

/// One captured map plus its precomputed zero fraction (the memory and
/// energy accounting wants the fraction without re-popcounting the map
/// for every image).
#[derive(Clone, Debug)]
pub struct ReplayMap {
    pub map: Arc<Bitmap>,
    pub sparsity: f64,
}

impl ReplayMap {
    fn new(map: Arc<Bitmap>) -> ReplayMap {
        let sparsity = map.sparsity();
        ReplayMap { map, sparsity }
    }
}

/// The replay payloads one (layer, phase) task consumes.
#[derive(Clone, Debug, Default)]
pub struct TaskMaps {
    /// Operand (input) pattern the PE lanes drain.
    pub operand: Option<ReplayMap>,
    /// A-priori output mask (BP only, Fig 5c).
    pub output: Option<ReplayMap>,
}

impl TaskMaps {
    pub fn is_empty(&self) -> bool {
        self.operand.is_none() && self.output.is_none()
    }
}

#[derive(Clone, Debug, Default)]
struct LayerMaps {
    fp: TaskMaps,
    bp: TaskMaps,
}

/// Every task's replay maps for one traced step.
#[derive(Debug, Default)]
pub struct StepMaps {
    by_layer: HashMap<String, LayerMaps>,
}

impl StepMaps {
    /// The maps a (layer, phase) task replays, if any were captured.
    pub fn task_maps(&self, layer: &str, phase: Phase) -> Option<&TaskMaps> {
        let lm = self.by_layer.get(layer)?;
        let tm = match phase {
            Phase::Forward => &lm.fp,
            Phase::Backward => &lm.bp,
            Phase::WeightGrad => return None,
        };
        (!tm.is_empty()).then_some(tm)
    }
}

/// All replayable steps of one trace, resolved against a network.
#[derive(Debug)]
pub struct ReplayBank {
    steps: Vec<StepMaps>,
    fingerprint: u64,
    network: String,
}

impl ReplayBank {
    /// Resolve a trace's bitmap payloads against the network's graph.
    /// Errors when the trace carries no payloads at all, or when a
    /// payload's shape contradicts the named ReLU's output shape (a
    /// mis-paired trace/network is a caller bug, not a fallback case).
    pub fn from_trace(net: &Network, trace: &TraceFile) -> anyhow::Result<ReplayBank> {
        anyhow::ensure!(
            trace.has_bitmaps(),
            "trace file for '{}' carries no bitmap payloads (v1 or scalar-only v2); \
             capture one with `agos trace` or a payload-capturing `agos train`",
            trace.network
        );
        let consumers = net.consumer_map();
        let mut steps = Vec::new();
        for s in &trace.steps {
            // relu layer name -> (act map, grad map) for this step.
            let mut relu_maps: HashMap<&str, (Option<Arc<Bitmap>>, Option<Arc<Bitmap>>)> =
                HashMap::new();
            for lt in &s.layers {
                if !lt.has_bitmaps() {
                    continue;
                }
                let relu = net
                    .by_name(&lt.name)
                    .ok_or_else(|| anyhow::anyhow!("traced layer '{}' not in '{}'", lt.name, net.name))?;
                for (what, bm) in [("act", &lt.act_bitmap), ("grad", &lt.grad_bitmap)] {
                    if let Some(b) = bm {
                        anyhow::ensure!(
                            b.shape == relu.out,
                            "{what} bitmap of '{}' is {} but the layer produces {}",
                            lt.name,
                            b.shape,
                            relu.out
                        );
                    }
                }
                relu_maps.insert(
                    lt.name.as_str(),
                    (
                        lt.act_bitmap.clone().map(Arc::new),
                        lt.grad_bitmap.clone().map(Arc::new),
                    ),
                );
            }
            if relu_maps.is_empty() {
                continue; // scalar-only step: nothing to replay
            }
            let mut by_layer = HashMap::new();
            for layer in net.compute_layers() {
                let producer = net.layer(layer.inputs[0]);
                let act = producer
                    .kind
                    .is_relu()
                    .then(|| relu_maps.get(producer.name.as_str()))
                    .flatten()
                    .and_then(|(a, _)| a.clone())
                    .map(ReplayMap::new);
                let grad = consumers[layer.id]
                    .iter()
                    .map(|&k| net.layer(k))
                    .find(|k| k.kind.is_relu())
                    .and_then(|k| relu_maps.get(k.name.as_str()))
                    .and_then(|(_, g)| g.clone())
                    .map(ReplayMap::new);
                let lm = LayerMaps {
                    fp: TaskMaps { operand: act.clone(), output: None },
                    bp: TaskMaps { operand: grad, output: act },
                };
                if !lm.fp.is_empty() || !lm.bp.is_empty() {
                    by_layer.insert(layer.name.clone(), lm);
                }
            }
            steps.push(StepMaps { by_layer });
        }
        anyhow::ensure!(!steps.is_empty(), "no replayable step resolved against '{}'", net.name);
        Ok(ReplayBank {
            steps,
            fingerprint: trace.fingerprint(),
            network: net.name.clone(),
        })
    }

    /// The step image `i` replays (round-robin over captured steps).
    pub fn step_maps(&self, image: usize) -> &StepMaps {
        &self.steps[image % self.steps.len()]
    }

    pub fn steps(&self) -> usize {
        self.steps.len()
    }

    pub fn network(&self) -> &str {
        &self.network
    }

    /// The underlying trace's content fingerprint — folded into
    /// `SimOptions::fingerprint` so replayed runs can never alias sampled
    /// runs (or replays of a different trace) in the sweep cache.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{zoo, Shape};
    use crate::trace::{LayerTrace, StepTrace};
    use crate::util::rng::Pcg32;

    fn traced_pair(shape: Shape, density: f64, rng: &mut Pcg32) -> (Bitmap, Bitmap) {
        let act = Bitmap::sample(shape, density, rng);
        let keep = Bitmap::sample(shape, 0.8, rng);
        let grad = act.and(&keep);
        (act, grad)
    }

    fn bitmap_trace() -> TraceFile {
        let net = zoo::agos_cnn();
        let mut rng = Pcg32::new(2);
        let mut t = TraceFile::new("agos_cnn");
        for step in 0..2 {
            let layers = (1..=4)
                .map(|i| {
                    let name = format!("relu{i}");
                    let shape = net.by_name(&name).unwrap().out;
                    let (act, grad) = traced_pair(shape, 0.5, &mut rng);
                    LayerTrace::from_bitmaps(&name, act, grad)
                })
                .collect();
            t.steps.push(StepTrace { step, loss: 2.0 - step as f64, layers });
        }
        t
    }

    #[test]
    fn bank_resolves_fp_bp_maps_against_the_graph() {
        let net = zoo::agos_cnn();
        let trace = bitmap_trace();
        let bank = ReplayBank::from_trace(&net, &trace).unwrap();
        assert_eq!(bank.steps(), 2);
        let s0 = bank.step_maps(0);
        // conv2's producer is relu1, its consumer is relu2.
        let bp = s0.task_maps("conv2", Phase::Backward).unwrap();
        let relu1 = net.by_name("relu1").unwrap().out;
        let relu2 = net.by_name("relu2").unwrap().out;
        assert_eq!(bp.output.as_ref().unwrap().map.shape, relu1);
        assert_eq!(bp.operand.as_ref().unwrap().map.shape, relu2);
        let fp = s0.task_maps("conv2", Phase::Forward).unwrap();
        assert_eq!(fp.operand.as_ref().unwrap().map.shape, relu1);
        assert!(fp.output.is_none(), "FP has no a-priori output mask");
        // conv1 reads the dense image: no FP payload.
        assert!(s0.task_maps("conv1", Phase::Forward).is_none());
        // WG never replays.
        assert!(s0.task_maps("conv2", Phase::WeightGrad).is_none());
        // Image round-robin wraps over the two steps.
        assert!(!std::ptr::eq(bank.step_maps(0), bank.step_maps(1)));
        assert!(std::ptr::eq(bank.step_maps(0), bank.step_maps(2)));
        assert_eq!(bank.fingerprint(), trace.fingerprint());
    }

    #[test]
    fn scalar_trace_and_shape_mismatch_are_rejected() {
        let net = zoo::agos_cnn();
        let mut scalar = TraceFile::new("agos_cnn");
        scalar.steps.push(StepTrace {
            step: 0,
            loss: 1.0,
            layers: vec![LayerTrace::scalar("relu1", 0.5, 0.5, true)],
        });
        assert!(ReplayBank::from_trace(&net, &scalar).is_err());

        let mut wrong = bitmap_trace();
        let mut rng = Pcg32::new(3);
        let (act, grad) = traced_pair(Shape::new(2, 2, 2), 0.5, &mut rng);
        wrong.steps[0].layers[0] = LayerTrace::from_bitmaps("relu1", act, grad);
        assert!(ReplayBank::from_trace(&net, &wrong).is_err(), "shape mismatch must error");
    }
}
