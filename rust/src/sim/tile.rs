//! Output tiling across the PE grid (§4.2, §4.6).
//!
//! Each PE owns a `U/Tx × V/Ty` slice of the output map (with remainder
//! rows/columns going to the edge tiles) and tracks its progress with the
//! `⟨iter, x, y⟩` state tuple the WDU compares lexicographically.

/// Progress marker of a PE tile (§4.6): blocking-pass iteration plus the
/// output coordinate currently being processed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TileState {
    pub iter: u32,
    pub x: u32,
    pub y: u32,
}

impl TileState {
    pub const DONE: TileState = TileState { iter: u32::MAX, x: u32::MAX, y: u32::MAX };
}

/// Split `u × v` output positions across a `tx × ty` grid; returns the
/// per-tile spatial output count, row-major over tiles. Every position is
/// assigned exactly once (remainders go to the leading tiles).
pub fn tile_outputs(u: usize, v: usize, tx: usize, ty: usize) -> Vec<usize> {
    assert!(tx > 0 && ty > 0);
    let rows = split(u, ty);
    let cols = split(v, tx);
    let mut out = Vec::with_capacity(tx * ty);
    for r in &rows {
        for c in &cols {
            out.push(r * c);
        }
    }
    out
}

/// The spatial window of every tile from [`tile_outputs`]'s split, in
/// the same row-major tile order: `(r0, r1, c0, c1)` half-open row and
/// column ranges of the `u × v` output map owned by that tile. The
/// replay path uses these to slice a tile's real output-mask bits out of
/// a captured bitmap; `windows[t]` always covers exactly
/// `tile_outputs(..)[t]` positions.
pub fn tile_windows(u: usize, v: usize, tx: usize, ty: usize) -> Vec<(usize, usize, usize, usize)> {
    assert!(tx > 0 && ty > 0);
    let rows = split(u, ty);
    let cols = split(v, tx);
    let mut out = Vec::with_capacity(tx * ty);
    let mut r0 = 0;
    for r in &rows {
        let mut c0 = 0;
        for c in &cols {
            out.push((r0, r0 + r, c0, c0 + c));
            c0 += c;
        }
        r0 += r;
    }
    out
}

/// Exact factorization of `n` into `(u, v)` with `u·v == n` and the pair
/// as square as possible — used to spread non-spatial output maps (FC
/// vectors, weight-gradient tensors) across the PE grid without
/// miscounting outputs.
pub fn factor2(n: usize) -> (usize, usize) {
    assert!(n > 0);
    let mut best = 1;
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            best = d;
        }
        d += 1;
    }
    (n / best, best)
}

fn split(n: usize, parts: usize) -> Vec<usize> {
    let base = n / parts;
    let rem = n % parts;
    (0..parts).map(|i| base + usize::from(i < rem)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_state_ordering_is_lexicographic() {
        let a = TileState { iter: 0, x: 5, y: 9 };
        let b = TileState { iter: 0, x: 6, y: 0 };
        let c = TileState { iter: 1, x: 0, y: 0 };
        assert!(a < b && b < c);
        assert!(a < TileState::DONE);
    }

    #[test]
    fn tiles_cover_exactly() {
        for (u, v, tx, ty) in [(224, 224, 16, 16), (7, 7, 16, 16), (28, 28, 4, 4), (1, 1, 16, 16)] {
            let tiles = tile_outputs(u, v, tx, ty);
            assert_eq!(tiles.len(), tx * ty);
            assert_eq!(tiles.iter().sum::<usize>(), u * v, "({u},{v},{tx},{ty})");
        }
    }

    #[test]
    fn small_maps_leave_idle_tiles() {
        // 7×7 output on a 16×16 grid: 49 tiles busy, 207 idle.
        let tiles = tile_outputs(7, 7, 16, 16);
        let busy = tiles.iter().filter(|t| **t > 0).count();
        assert_eq!(busy, 49);
    }

    #[test]
    fn factor2_exact_and_square() {
        for n in [1usize, 2, 7, 64, 1000, 4096, 25088, 4608] {
            let (u, v) = factor2(n);
            assert_eq!(u * v, n, "n={n}");
            assert!(u >= v);
        }
        assert_eq!(factor2(4096), (64, 64));
        assert_eq!(factor2(13), (13, 1)); // prime falls back to a line
    }

    #[test]
    fn balanced_split_is_even() {
        let tiles = tile_outputs(32, 32, 16, 16);
        assert!(tiles.iter().all(|&t| t == 4));
    }

    #[test]
    fn windows_partition_and_match_counts() {
        for (u, v, tx, ty) in [(224, 224, 16, 16), (7, 7, 16, 16), (28, 28, 4, 4), (1, 1, 16, 16)] {
            let counts = tile_outputs(u, v, tx, ty);
            let windows = tile_windows(u, v, tx, ty);
            assert_eq!(counts.len(), windows.len());
            let mut covered = vec![false; u * v];
            for (t, &(r0, r1, c0, c1)) in windows.iter().enumerate() {
                assert_eq!((r1 - r0) * (c1 - c0), counts[t], "tile {t} of ({u},{v},{tx},{ty})");
                for y in r0..r1 {
                    for x in c0..c1 {
                        assert!(!covered[y * v + x], "({y},{x}) assigned twice");
                        covered[y * v + x] = true;
                    }
                }
            }
            assert!(covered.iter().all(|&c| c), "every position owned once");
        }
    }
}
