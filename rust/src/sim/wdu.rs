//! Work-redistribution unit (§4.6).
//!
//! Tiles execute independently; spatial sparsity variation leaves some
//! finishing early. The WDU watches tile progress, and when a tile goes
//! idle it steals **half the remaining work** of the tile with the
//! lexicographically-smallest state tuple (= most work left), provided
//! that victim still has more than the threshold fraction of its original
//! assignment outstanding. Stealing costs transfer+merge overhead on both
//! ends.
//!
//! The event loop here operates on tile *timelines* in cycles: at each
//! completion event the earliest-finishing tile becomes a thief.

/// Result of redistributing one layer's tile work.
#[derive(Clone, Debug)]
pub struct WduOutcome {
    /// Completion time per tile after redistribution (cycles).
    pub completion: Vec<f64>,
    /// Makespan (node latency) after redistribution.
    pub makespan: f64,
    /// Number of steal operations performed.
    pub steals: usize,
    /// Total overhead cycles added by transfers/merges.
    pub overhead: f64,
}

impl WduOutcome {
    pub fn utilization(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 1.0;
        }
        let avg: f64 = self.completion.iter().sum::<f64>() / self.completion.len() as f64;
        avg / self.makespan
    }
}

/// Simulate WDU redistribution over per-tile work (cycles).
///
/// * `work` — initial per-tile busy cycles.
/// * `threshold` — steal only from victims whose remaining fraction of
///   their original assignment exceeds this (§4.6: 0.30).
/// * `overhead_frac` — cycles added per steal, as a fraction of the
///   stolen amount (input transfer + output merge).
pub fn redistribute(work: &[f64], threshold: f64, overhead_frac: f64) -> WduOutcome {
    let n = work.len();
    assert!(n > 0);
    let original: Vec<f64> = work.to_vec();
    let mut now;
    let mut busy_until: Vec<f64> = work.to_vec();
    let mut steals = 0usize;
    let mut overhead_total = 0.0f64;

    // Two lazily-invalidated heaps over tile completion times: ordering
    // running tiles by `busy_until` is identical to ordering them by
    // remaining work (same `now`), so one key serves both the
    // next-completion (min) and victim-selection (max) queries. Entries
    // carry the `busy_until` they were pushed with; stale entries are
    // skipped on pop. This keeps the event loop O((n + steals) log n)
    // instead of the naive O(n) scan per event.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    #[derive(PartialEq, PartialOrd)]
    struct Key(f64);
    impl Eq for Key {}
    #[allow(clippy::derive_ord_xor_partial_ord)]
    impl Ord for Key {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.0.partial_cmp(&other.0).unwrap()
        }
    }
    let mut min_heap: BinaryHeap<(Reverse<Key>, usize)> =
        busy_until.iter().enumerate().map(|(i, t)| (Reverse(Key(*t)), i)).collect();
    let mut max_heap: BinaryHeap<(Key, usize)> =
        busy_until.iter().enumerate().map(|(i, t)| (Key(*t), i)).collect();
    let mut done = vec![false; n];

    // Bounded: each steal halves a victim's remainder, so the loop
    // terminates well before the safety cap.
    let cap = 64 * n;
    for _ in 0..cap {
        // Next completion among still-busy tiles (skip stale entries).
        let idle = loop {
            match min_heap.pop() {
                None => break None,
                Some((Reverse(Key(t)), i)) => {
                    if done[i] || (busy_until[i] - t).abs() > 1e-9 {
                        continue; // stale
                    }
                    break Some((i, t));
                }
            }
        };
        let Some((idle, t_idle)) = idle else { break };
        now = t_idle;
        done[idle] = true;

        // Victim: max busy_until (= max remaining) among running tiles.
        let victim = loop {
            match max_heap.peek() {
                None => break None,
                Some(&(Key(t), i)) => {
                    if done[i] || (busy_until[i] - t).abs() > 1e-9 || busy_until[i] <= now {
                        max_heap.pop(); // stale or finished
                        continue;
                    }
                    break Some(i);
                }
            }
        };
        let Some(v) = victim else { continue };
        let rem_v = busy_until[v] - now;
        if original[v] <= 0.0 || rem_v / original[v] <= threshold {
            continue; // not worth redistributing (§4.6)
        }
        // Steal half; both sides pay overhead proportional to the moved work.
        let moved = rem_v / 2.0;
        let oh = moved * overhead_frac;
        busy_until[v] = now + (rem_v - moved) + oh;
        busy_until[idle] = now + moved + oh;
        done[idle] = false;
        overhead_total += 2.0 * oh;
        steals += 1;
        min_heap.push((Reverse(Key(busy_until[v])), v));
        min_heap.push((Reverse(Key(busy_until[idle])), idle));
        max_heap.push((Key(busy_until[v]), v));
        max_heap.push((Key(busy_until[idle]), idle));
    }

    let makespan = busy_until.iter().cloned().fold(0.0, f64::max);
    WduOutcome { completion: busy_until, makespan, steals, overhead: overhead_total }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_work_needs_no_steals() {
        let work = vec![100.0; 16];
        let out = redistribute(&work, 0.3, 0.02);
        assert_eq!(out.steals, 0);
        assert_eq!(out.makespan, 100.0);
        assert!((out.utilization() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn single_hot_tile_gets_split() {
        let mut work = vec![10.0; 16];
        work[3] = 1000.0;
        let out = redistribute(&work, 0.3, 0.0);
        assert!(out.steals >= 1);
        assert!(out.makespan < 1000.0, "makespan {}", out.makespan);
        // with zero overhead and 15 helpers it should get well below 500
        assert!(out.makespan < 600.0, "makespan {}", out.makespan);
    }

    #[test]
    fn threshold_one_disables_stealing() {
        let mut work = vec![10.0; 8];
        work[0] = 500.0;
        let out = redistribute(&work, 1.0, 0.0);
        assert_eq!(out.steals, 0);
        assert_eq!(out.makespan, 500.0);
    }

    #[test]
    fn overhead_is_accounted() {
        let mut work = vec![10.0; 4];
        work[0] = 400.0;
        let cheap = redistribute(&work, 0.3, 0.0);
        let costly = redistribute(&work, 0.3, 0.5);
        assert!(costly.makespan >= cheap.makespan);
        assert!(costly.overhead > 0.0);
    }

    #[test]
    fn makespan_never_worse_than_no_wdu_with_small_overhead() {
        // Property: WDU with modest overhead should not regress the
        // original makespan for imbalanced inputs.
        let work: Vec<f64> = (1..=32).map(|i| (i * i) as f64).collect();
        let base = work.iter().cloned().fold(0.0, f64::max);
        let out = redistribute(&work, 0.3, 0.05);
        assert!(out.makespan <= base * 1.001, "{} vs {base}", out.makespan);
    }

    #[test]
    fn work_is_conserved_modulo_overhead() {
        let mut work = vec![50.0; 8];
        work[0] = 800.0;
        let total_in: f64 = work.iter().sum();
        let out = redistribute(&work, 0.1, 0.0);
        let total_busy: f64 = out.completion.iter().sum();
        // With zero overhead, total busy time across tiles can only grow
        // by idle gaps, never shrink below the injected work.
        assert!(total_busy >= total_in * 0.99);
    }

    #[test]
    fn utilization_improves_toward_paper_band() {
        // §6 Fig 17: avg/max ratio ~70% without WR, ~83% with.
        let mut rng = crate::util::rng::Pcg32::new(42);
        let work: Vec<f64> = (0..256)
            .map(|_| 1000.0 * (1.0 + 0.35 * rng.gauss()).max(0.1))
            .collect();
        let before_max = work.iter().cloned().fold(0.0, f64::max);
        let before_avg: f64 = work.iter().sum::<f64>() / work.len() as f64;
        let util_before = before_avg / before_max;
        let out = redistribute(&work, 0.3, 0.05);
        assert!(
            out.utilization() > util_before + 0.05,
            "before {util_before:.3} after {:.3}",
            out.utilization()
        );
    }
}
