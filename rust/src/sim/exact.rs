//! Exact event-driven PE simulation — the cycle-accurate reference the
//! analytic model (`sim::pe`) is validated against.
//!
//! Where `PeModel` computes *expected* lane-maximum drain times from the
//! sparsity fraction, this module walks real operand bitmaps group by
//! group: operands are dealt to lanes in contiguous chunks (the SRAM
//! streaming layout of §4.3), each 32-entry group drains at one non-zero
//! per cycle per lane, the group waits for its slowest lane, and double
//! buffering overlaps the next group's fill with the current drain.
//!
//! The walk is **word-level**: operand patterns arrive as packed `u64`
//! words ([`OperandPattern`], or a raw word slice) and every per-lane
//! per-group non-zero count is a masked popcount over a bit range —
//! no per-lane `Vec<bool>` is ever materialized. At replay scale
//! (ImageNet-sized captured maps) the old bool walk *was* the backend's
//! dominant cost.
//!
//! Used two ways:
//! * property tests assert the analytic model tracks this within a
//!   tolerance across random sparsity patterns (DESIGN.md §7);
//! * the exact co-simulation path replays *real* bitmaps extracted from
//!   training traces (`sim::replay`).

use crate::config::AcceleratorConfig;
use crate::util::rng::Pcg32;

use super::adder_tree::{tree_utilization, ReconfigMode};

/// One output's operand non-zero pattern, packed LSB-first into `u64`
/// words — the form the PE drains. Bit `i` set ⇔ operand `i` non-zero.
#[derive(Clone, Debug, PartialEq)]
pub struct OperandPattern {
    len: usize,
    words: Vec<u64>,
}

impl OperandPattern {
    pub fn from_bools(nz: &[bool]) -> OperandPattern {
        let mut words = vec![0u64; nz.len().div_ceil(64)];
        for (i, b) in nz.iter().enumerate() {
            if *b {
                words[i / 64] |= 1 << (i % 64);
            }
        }
        OperandPattern { len: nz.len(), words }
    }

    /// Wrap already-packed words (e.g. a replayed window slice). `words`
    /// must hold at least `ceil(len / 64)` entries.
    ///
    /// Debug builds additionally pin the packing contract every other
    /// constructor upholds: exactly `ceil(len / 64)` words, with every
    /// bit at or beyond `len` zero. A dirty tail used to slip through
    /// silently — the range counts mask it off per call, but pattern
    /// equality, word-level comparisons and any future whole-word
    /// popcount over `words()` would all miscount.
    pub fn from_words(words: Vec<u64>, len: usize) -> OperandPattern {
        assert!(words.len() >= len.div_ceil(64), "word buffer shorter than len");
        debug_assert_eq!(
            words.len(),
            len.div_ceil(64),
            "word buffer longer than ceil(len/64)"
        );
        debug_assert!(
            len % 64 == 0 || words[len / 64] >> (len % 64) == 0,
            "bits beyond len must be masked off"
        );
        OperandPattern { len, words }
    }

    /// Fully dense pattern (every operand non-zero).
    pub fn dense(len: usize) -> OperandPattern {
        let mut words = vec![!0u64; len.div_ceil(64)];
        let tail = len % 64;
        if tail > 0 {
            *words.last_mut().unwrap() &= (1u64 << tail) - 1;
        }
        OperandPattern { len, words }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn words(&self) -> &[u64] {
        &self.words
    }

    pub fn count_nz(&self) -> u64 {
        if self.len == 0 {
            return 0;
        }
        count_bits_range(&self.words, 0, self.len)
    }
}

/// Popcount of four words at once — the unrolled unit of the batched
/// drain walk. With the `simd` feature on an x86-64 host compiled for
/// `popcnt`, the counts go through the hardware instruction directly;
/// everywhere else the scalar `count_ones` path (which LLVM also lowers
/// to `popcnt` under `-C target-cpu`) is used. Both orders sum the same
/// four integers, so the result is identical by construction.
#[inline(always)]
fn popcount4(a: u64, b: u64, c: u64, d: u64) -> u64 {
    #[cfg(all(feature = "simd", target_arch = "x86_64", target_feature = "popcnt"))]
    {
        // SAFETY: gated on `target_feature = "popcnt"` at compile time.
        unsafe {
            use core::arch::x86_64::_popcnt64;
            return (_popcnt64(a as i64)
                + _popcnt64(b as i64)
                + _popcnt64(c as i64)
                + _popcnt64(d as i64)) as u64;
        }
    }
    #[allow(unreachable_code)]
    {
        a.count_ones() as u64
            + b.count_ones() as u64
            + c.count_ones() as u64
            + d.count_ones() as u64
    }
}

/// Popcount of the bit range `[lo, hi)` of packed LSB-first words — the
/// masked u64 walk at the heart of the group drain. Bits outside the
/// range never contribute, so callers need no tail invariant.
///
/// The edge words (a shifted head, a masked tail) are handled once,
/// hoisted out of the interior walk; the interior runs in 4-wide chunks
/// through [`popcount4`] so big ranges (the `Full` geometry's whole-map
/// patterns, `count_nz` over replayed windows) issue batched popcounts
/// instead of a one-word-at-a-time dependency chain.
#[inline]
pub fn count_bits_range(words: &[u64], lo: usize, hi: usize) -> u64 {
    debug_assert!(lo < hi && (hi - 1) / 64 < words.len());
    let (wlo, whi) = (lo / 64, (hi - 1) / 64);
    if wlo == whi {
        // Range inside one word: shift off the low bits, mask the high.
        let w = words[wlo] >> (lo % 64);
        let n = hi - lo;
        let w = if n == 64 { w } else { w & ((1u64 << n) - 1) };
        return w.count_ones() as u64;
    }
    let mut n = (words[wlo] >> (lo % 64)).count_ones() as u64;
    let mid = &words[wlo + 1..whi];
    let mut chunks = mid.chunks_exact(4);
    for q in &mut chunks {
        n += popcount4(q[0], q[1], q[2], q[3]);
    }
    for w in chunks.remainder() {
        n += w.count_ones() as u64;
    }
    let tail = hi - whi * 64; // 1..=64
    let last = if tail == 64 { words[whi] } else { words[whi] & ((1u64 << tail) - 1) };
    n + last.count_ones() as u64
}

/// Exact PE parameters (mirrors `PeModel`).
#[derive(Clone, Debug)]
pub struct ExactPe {
    pub lanes: usize,
    pub group_entries: usize,
    pub groups: usize,
    pub double_buffering: bool,
    pub reconfig: ReconfigMode,
    pub blocking_overhead: u64,
}

impl Default for ExactPe {
    fn default() -> Self {
        ExactPe {
            lanes: 16,
            group_entries: 32,
            groups: 2,
            double_buffering: true,
            reconfig: ReconfigMode::Hierarchical,
            blocking_overhead: 4,
        }
    }
}

/// Result of one exact output-neuron computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExactOutput {
    pub cycles: u64,
    pub macs: u64,
    /// Cycles lanes sat idle waiting for the slowest lane (the stall the
    /// double-buffering/§4.3 discussion is about).
    pub lane_stall_cycles: u64,
}

impl ExactPe {
    /// Mirror of `PeModel::from_config`: the same lane geometry and
    /// blocking overhead, so the two backends cost identical hardware.
    pub fn from_config(cfg: &AcceleratorConfig) -> ExactPe {
        ExactPe {
            lanes: cfg.lanes,
            group_entries: cfg.group_entries,
            groups: cfg.groups,
            double_buffering: true,
            reconfig: ReconfigMode::Hierarchical,
            blocking_overhead: 4,
        }
    }

    /// Operand capacity per blocking pass.
    pub fn capacity(&self) -> usize {
        self.lanes * self.group_entries * self.groups
    }

    /// Exactly simulate one output from its packed operand pattern:
    /// `len` operands (= receptive field CRS) in `words`, LSB-first.
    ///
    /// The drain order is the §4.3 SRAM streaming layout: each blocking
    /// pass deals its bits contiguously across the occupied lanes, and
    /// every (lane, group) non-zero count is one masked popcount.
    pub fn simulate_output_words(&self, words: &[u64], len: usize) -> ExactOutput {
        assert!(len > 0, "empty receptive field");
        let cap = self.capacity();
        let mut cycles = 0u64;
        let mut macs = 0u64;
        let mut stall = 0u64;

        let mut pass_lo = 0usize;
        let mut pi = 0usize;
        while pass_lo < len {
            let pass_hi = (pass_lo + cap).min(len);
            let pass_len = pass_hi - pass_lo;
            if pi > 0 {
                cycles += self.blocking_overhead; // partial-sum RMW (§4.4)
            }
            // Each output occupies `occ` lanes of its adder-tree slot
            // (§4.5); operands are dealt contiguously across those lanes.
            let occ_pass = pass_len
                .div_ceil(self.group_entries * self.groups)
                .clamp(1, self.lanes);
            let per_lane = pass_len.div_ceil(occ_pass);
            let lanes_used = occ_pass;
            let groups_per_lane = per_lane.div_ceil(self.group_entries);
            let mut pass_cycles = 0u64;
            let mut prev_drain = 0u64;
            for g in 0..groups_per_lane {
                // Per-lane non-zero count in this group: a masked word
                // popcount per (lane, group) range.
                let mut max_nz = 0u64;
                let mut sum_nz = 0u64;
                for li in 0..lanes_used {
                    let lane_lo = pass_lo + li * per_lane;
                    if lane_lo >= pass_hi {
                        break; // trailing lanes got no operands
                    }
                    let lane_hi = (lane_lo + per_lane).min(pass_hi);
                    let lo = lane_lo + g * self.group_entries;
                    if lo >= lane_hi {
                        continue;
                    }
                    let hi = (lo + self.group_entries).min(lane_hi);
                    let nzc = count_bits_range(words, lo, hi);
                    max_nz = max_nz.max(nzc);
                    sum_nz += nzc;
                }
                let drain = max_nz.max(1); // a group costs >=1 cycle to sequence
                let fill = max_nz; // operands stream in at 1 nz/lane/cycle
                macs += sum_nz;
                stall += (drain * lanes_used as u64).saturating_sub(sum_nz);
                if self.double_buffering {
                    // next group fills while this one drains
                    pass_cycles += if g == 0 { drain } else { drain.max(prev_drain.min(fill)) };
                } else {
                    pass_cycles += drain + fill;
                }
                prev_drain = drain;
            }
            // Adder-tree packing (§4.5): a pass occupying fewer than all
            // lanes shares the PE with other outputs' identical passes.
            let util = tree_utilization(occ_pass, self.lanes, self.reconfig);
            cycles += (pass_cycles as f64 * (occ_pass as f64 / self.lanes as f64) / util)
                .round() as u64;
            pass_lo = pass_hi;
            pi += 1;
        }
        ExactOutput { cycles: cycles.max(1), macs, lane_stall_cycles: stall }
    }

    /// Bool-slice convenience wrapper around [`simulate_output_words`]
    /// (packs once up front; validation tests and callers holding
    /// unpacked patterns use this).
    pub fn simulate_output(&self, nz: &[bool]) -> ExactOutput {
        let p = OperandPattern::from_bools(nz);
        self.simulate_output_words(p.words(), p.len())
    }

    /// Simulate a whole tile: packed receptive-field patterns per output,
    /// with an optional output-sparsity mask saying which outputs are
    /// skipped. The drain stays word-level throughout.
    ///
    /// A mask shorter than `outputs` used to panic on the first
    /// out-of-range output, and a longer one silently ignored its tail —
    /// both are caller bugs, so the lengths are checked up front.
    pub fn simulate_tile(
        &self,
        outputs: &[OperandPattern],
        out_mask: Option<&[bool]>,
    ) -> ExactOutput {
        if let Some(mask) = out_mask {
            assert_eq!(
                mask.len(),
                outputs.len(),
                "output mask length {} != output count {}",
                mask.len(),
                outputs.len()
            );
        }
        let mut total = ExactOutput { cycles: 0, macs: 0, lane_stall_cycles: 0 };
        for (i, p) in outputs.iter().enumerate() {
            if let Some(mask) = out_mask {
                if !mask[i] {
                    continue; // skipped a priori — zero cycles (Fig 5c)
                }
            }
            let r = self.simulate_output_words(p.words(), p.len());
            total.cycles += r.cycles;
            total.macs += r.macs;
            total.lane_stall_cycles += r.lane_stall_cycles;
        }
        total
    }
}

/// Random operand bitmap with the given density (helper for validation
/// tests and synthetic exact runs).
pub fn random_bitmap(crs: usize, density: f64, rng: &mut Pcg32) -> Vec<bool> {
    (0..crs).map(|_| rng.bernoulli(density)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::sim::pe::PeModel;

    #[test]
    fn dense_output_matches_arithmetic() {
        let pe = ExactPe::default();
        // CRS=1024 dense: 16 lanes × 64 entries, 2 groups of 32 → 64 cycles.
        let nz = vec![true; 1024];
        let r = pe.simulate_output(&nz);
        assert_eq!(r.macs, 1024);
        assert_eq!(r.cycles, 64);
        assert_eq!(r.lane_stall_cycles, 0);
    }

    #[test]
    fn empty_pattern_costs_minimum() {
        let pe = ExactPe::default();
        let nz = vec![false; 1024];
        let r = pe.simulate_output(&nz);
        assert_eq!(r.macs, 0);
        assert!(r.cycles <= 4, "all-zero group sequencing {}", r.cycles);
    }

    #[test]
    fn word_walk_matches_bool_walk_reference() {
        // The packed walk must agree with a straightforward per-bool
        // reference count on every (lane, group) range.
        let mut rng = Pcg32::new(13);
        for &crs in &[1usize, 31, 32, 63, 64, 65, 100, 1024, 2309, 4608] {
            for &d in &[0.0, 0.2, 0.5, 0.9, 1.0] {
                let nz = random_bitmap(crs, d, &mut rng);
                let p = OperandPattern::from_bools(&nz);
                assert_eq!(p.len(), crs);
                assert_eq!(
                    p.count_nz(),
                    nz.iter().filter(|b| **b).count() as u64,
                    "crs={crs} d={d}"
                );
                // Arbitrary sub-ranges.
                for (lo, hi) in [(0, crs), (crs / 3, crs), (crs / 2, crs / 2 + 1)] {
                    if lo >= hi {
                        continue;
                    }
                    let expect = nz[lo..hi].iter().filter(|b| **b).count() as u64;
                    assert_eq!(count_bits_range(p.words(), lo, hi), expect, "[{lo},{hi})");
                }
            }
        }
    }

    /// The pre-refactor `Vec<bool>` drain, kept verbatim as an
    /// *independent* reference: the word-level walk must reproduce it
    /// bit-for-bit (comparing `simulate_output` against
    /// `simulate_output_words` would be vacuous — the former is now a
    /// packing wrapper around the latter).
    fn bool_walk_reference(pe: &ExactPe, nz: &[bool]) -> ExactOutput {
        assert!(!nz.is_empty());
        let cap = pe.capacity();
        let mut cycles = 0u64;
        let mut macs = 0u64;
        let mut stall = 0u64;
        for (pi, pass) in nz.chunks(cap).enumerate() {
            if pi > 0 {
                cycles += pe.blocking_overhead;
            }
            let mut pass_cycles = 0u64;
            let occ_pass = pass
                .len()
                .div_ceil(pe.group_entries * pe.groups)
                .clamp(1, pe.lanes);
            let per_lane = pass.len().div_ceil(occ_pass);
            let lanes_used = occ_pass;
            let lane_chunks: Vec<&[bool]> = pass.chunks(per_lane.max(1)).collect();
            let groups_per_lane = per_lane.max(1).div_ceil(pe.group_entries);
            let mut prev_drain = 0u64;
            for g in 0..groups_per_lane {
                let mut max_nz = 0u64;
                let mut sum_nz = 0u64;
                for chunk in &lane_chunks {
                    let lo = g * pe.group_entries;
                    if lo >= chunk.len() {
                        continue;
                    }
                    let hi = (lo + pe.group_entries).min(chunk.len());
                    let nzc = chunk[lo..hi].iter().filter(|b| **b).count() as u64;
                    max_nz = max_nz.max(nzc);
                    sum_nz += nzc;
                }
                let drain = max_nz.max(1);
                let fill = max_nz;
                macs += sum_nz;
                stall += (drain * lanes_used as u64).saturating_sub(sum_nz);
                if pe.double_buffering {
                    pass_cycles += if g == 0 { drain } else { drain.max(prev_drain.min(fill)) };
                } else {
                    pass_cycles += drain + fill;
                }
                prev_drain = drain;
            }
            let util = tree_utilization(occ_pass, pe.lanes, pe.reconfig);
            cycles += (pass_cycles as f64 * (occ_pass as f64 / pe.lanes as f64) / util)
                .round() as u64;
        }
        ExactOutput { cycles: cycles.max(1), macs, lane_stall_cycles: stall }
    }

    #[test]
    fn packed_drain_matches_bool_walk_reference() {
        let mut rng = Pcg32::new(8);
        for pe in [
            ExactPe::default(),
            ExactPe { double_buffering: false, ..ExactPe::default() },
            ExactPe { lanes: 8, group_entries: 16, ..ExactPe::default() },
        ] {
            for &crs in &[1usize, 64, 100, 288, 1024, 2304, 4608] {
                for &d in &[0.0, 0.1, 0.5, 0.9, 1.0] {
                    let nz = random_bitmap(crs, d, &mut rng);
                    let p = OperandPattern::from_bools(&nz);
                    let expect = bool_walk_reference(&pe, &nz);
                    let got = pe.simulate_output_words(p.words(), p.len());
                    assert_eq!(got, expect, "lanes={} crs={crs} d={d}", pe.lanes);
                }
            }
        }
    }

    #[test]
    fn from_words_accepts_well_formed_patterns() {
        // Exact word count with a clean tail round-trips.
        let p = OperandPattern::from_words(vec![!0u64, 0x7], 67);
        assert_eq!(p.len(), 67);
        assert_eq!(p.count_nz(), 67);
        // A 64-aligned length has no tail to check.
        let p = OperandPattern::from_words(vec![!0u64; 2], 128);
        assert_eq!(p.count_nz(), 128);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "bits beyond len must be masked off")]
    fn from_words_rejects_dirty_tail_bits() {
        // Bit 3 lies beyond len=3: a malformed pattern must be caught at
        // construction, not silently tolerated.
        OperandPattern::from_words(vec![0b1111], 3);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "word buffer longer")]
    fn from_words_rejects_oversized_buffers() {
        OperandPattern::from_words(vec![0, 0], 64);
    }

    #[test]
    fn chunked_popcount_matches_naive_reference() {
        // The 4-wide interior chunking must agree with a bit-at-a-time
        // reference on ranges long enough to exercise full chunks, the
        // remainder loop, and both edge words.
        let mut rng = Pcg32::new(21);
        let nz = random_bitmap(64 * 23 + 17, 0.37, &mut rng);
        let p = OperandPattern::from_bools(&nz);
        let naive = |lo: usize, hi: usize| nz[lo..hi].iter().filter(|b| **b).count() as u64;
        for (lo, hi) in [
            (0, nz.len()),      // 23 interior words: 5 chunks + remainder
            (1, nz.len() - 1),  // unaligned edges
            (63, 64 * 18),      // head shift of 63, aligned tail
            (64, 64 * 22 + 5),  // aligned head, masked tail
            (7, 64 * 6),        // exactly one 4-chunk interior
        ] {
            assert_eq!(count_bits_range(p.words(), lo, hi), naive(lo, hi), "[{lo},{hi})");
        }
        assert_eq!(popcount4(!0, 0, 0xF0F0, 1), 64 + 8 + 1);
    }

    #[test]
    fn sparsity_reduces_cycles_and_counts_stall() {
        let pe = ExactPe::default();
        let mut rng = Pcg32::new(3);
        let dense = pe.simulate_output(&vec![true; 1024]);
        let sparse = pe.simulate_output(&random_bitmap(1024, 0.5, &mut rng));
        assert!(sparse.cycles < dense.cycles);
        assert!(sparse.macs < dense.macs);
        assert!(sparse.lane_stall_cycles > 0, "imbalance must show up as stall");
    }

    #[test]
    fn double_buffering_never_hurts() {
        let mut rng = Pcg32::new(9);
        for _ in 0..20 {
            let nz = random_bitmap(2048, rng.range_f64(0.1, 0.9), &mut rng);
            let with = ExactPe::default().simulate_output(&nz);
            let without = ExactPe { double_buffering: false, ..ExactPe::default() }
                .simulate_output(&nz);
            assert!(with.cycles <= without.cycles);
            assert_eq!(with.macs, without.macs);
        }
    }

    #[test]
    fn blocking_pass_overhead_applies() {
        let pe = ExactPe::default();
        let one_pass = pe.simulate_output(&vec![true; 1024]);
        let two_pass = pe.simulate_output(&vec![true; 2048]);
        assert!(two_pass.cycles >= 2 * one_pass.cycles + pe.blocking_overhead);
    }

    #[test]
    fn tile_skips_masked_outputs_entirely() {
        let pe = ExactPe::default();
        let outputs: Vec<OperandPattern> = (0..8).map(|_| OperandPattern::dense(256)).collect();
        let all = pe.simulate_tile(&outputs, None);
        let mask = vec![true, false, true, false, true, false, true, false];
        let half = pe.simulate_tile(&outputs, Some(&mask));
        assert_eq!(half.cycles * 2, all.cycles);
        assert_eq!(half.macs * 2, all.macs);
    }

    #[test]
    #[should_panic(expected = "output mask length")]
    fn mismatched_mask_length_is_rejected() {
        let pe = ExactPe::default();
        let outputs: Vec<OperandPattern> = (0..4).map(|_| OperandPattern::dense(64)).collect();
        let mask = vec![true; 3];
        pe.simulate_tile(&outputs, Some(&mask));
    }

    #[test]
    fn from_config_matches_defaults() {
        let pe = ExactPe::from_config(&AcceleratorConfig::default());
        let d = ExactPe::default();
        assert_eq!(pe.lanes, d.lanes);
        assert_eq!(pe.group_entries, d.group_entries);
        assert_eq!(pe.groups, d.groups);
        assert_eq!(pe.blocking_overhead, d.blocking_overhead);
    }

    /// The headline validation: the analytic `PeModel` must track the
    /// exact simulation across sparsity levels and receptive fields.
    #[test]
    fn analytic_model_tracks_exact_simulation() {
        let cfg = AcceleratorConfig::default();
        let analytic = PeModel::from_config(&cfg);
        let exact = ExactPe::default();
        let mut rng = Pcg32::new(42);
        for &crs in &[256usize, 576, 1024, 2304, 4608] {
            for &s in &[0.0, 0.3, 0.5, 0.7] {
                // average the exact sim over many random patterns
                let trials = 40;
                let mut sum = 0u64;
                for _ in 0..trials {
                    let nz = random_bitmap(crs, 1.0 - s, &mut rng);
                    sum += exact.simulate_output(&nz).cycles;
                }
                let exact_mean = sum as f64 / trials as f64;
                let (model, _) = analytic.cycles_per_output(crs as f64, s);
                let err = (model - exact_mean).abs() / exact_mean;
                assert!(
                    err < 0.20,
                    "crs={crs} s={s}: analytic {model:.1} vs exact {exact_mean:.1} ({:.0}%)",
                    err * 100.0
                );
            }
        }
    }
}
