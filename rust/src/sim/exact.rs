//! Exact event-driven PE simulation — the cycle-accurate reference the
//! analytic model (`sim::pe`) is validated against.
//!
//! Where `PeModel` computes *expected* lane-maximum drain times from the
//! sparsity fraction, this module walks real operand bitmaps group by
//! group: operands are dealt to lanes in contiguous chunks (the SRAM
//! streaming layout of §4.3), each 32-entry group drains at one non-zero
//! per cycle per lane, the group waits for its slowest lane, and double
//! buffering overlaps the next group's fill with the current drain.
//!
//! Used two ways:
//! * property tests assert the analytic model tracks this within a
//!   tolerance across random sparsity patterns (DESIGN.md §7);
//! * the exact co-simulation path replays *real* bitmaps extracted from
//!   training traces.

use crate::config::AcceleratorConfig;
use crate::util::rng::Pcg32;

use super::adder_tree::{tree_utilization, ReconfigMode};

/// Exact PE parameters (mirrors `PeModel`).
#[derive(Clone, Debug)]
pub struct ExactPe {
    pub lanes: usize,
    pub group_entries: usize,
    pub groups: usize,
    pub double_buffering: bool,
    pub reconfig: ReconfigMode,
    pub blocking_overhead: u64,
}

impl Default for ExactPe {
    fn default() -> Self {
        ExactPe {
            lanes: 16,
            group_entries: 32,
            groups: 2,
            double_buffering: true,
            reconfig: ReconfigMode::Hierarchical,
            blocking_overhead: 4,
        }
    }
}

/// Result of one exact output-neuron computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExactOutput {
    pub cycles: u64,
    pub macs: u64,
    /// Cycles lanes sat idle waiting for the slowest lane (the stall the
    /// double-buffering/§4.3 discussion is about).
    pub lane_stall_cycles: u64,
}

impl ExactPe {
    /// Mirror of `PeModel::from_config`: the same lane geometry and
    /// blocking overhead, so the two backends cost identical hardware.
    pub fn from_config(cfg: &AcceleratorConfig) -> ExactPe {
        ExactPe {
            lanes: cfg.lanes,
            group_entries: cfg.group_entries,
            groups: cfg.groups,
            double_buffering: true,
            reconfig: ReconfigMode::Hierarchical,
            blocking_overhead: 4,
        }
    }

    /// Operand capacity per blocking pass.
    pub fn capacity(&self) -> usize {
        self.lanes * self.group_entries * self.groups
    }

    /// Exactly simulate one output whose operand non-zero pattern is
    /// `nz` (length = receptive field CRS).
    pub fn simulate_output(&self, nz: &[bool]) -> ExactOutput {
        assert!(!nz.is_empty(), "empty receptive field");
        let cap = self.capacity();
        let mut cycles = 0u64;
        let mut macs = 0u64;
        let mut stall = 0u64;

        for (pi, pass) in nz.chunks(cap).enumerate() {
            if pi > 0 {
                cycles += self.blocking_overhead; // partial-sum RMW (§4.4)
            }
            let mut pass_cycles = 0u64;
            // Each output occupies `occ` lanes of its adder-tree slot
            // (§4.5); operands are dealt contiguously across those lanes.
            let occ_pass = pass
                .len()
                .div_ceil(self.group_entries * self.groups)
                .clamp(1, self.lanes);
            let per_lane = pass.len().div_ceil(occ_pass);
            let lanes_used = occ_pass;
            // Each lane's chunk is processed in groups of `group_entries`.
            let lane_chunks: Vec<&[bool]> = pass.chunks(per_lane.max(1)).collect();
            let groups_per_lane = per_lane.max(1).div_ceil(self.group_entries);
            let mut prev_drain = 0u64;
            for g in 0..groups_per_lane {
                // Per-lane non-zero count in this group.
                let mut max_nz = 0u64;
                let mut sum_nz = 0u64;
                for chunk in &lane_chunks {
                    let lo = g * self.group_entries;
                    if lo >= chunk.len() {
                        continue;
                    }
                    let hi = (lo + self.group_entries).min(chunk.len());
                    let nzc = chunk[lo..hi].iter().filter(|b| **b).count() as u64;
                    max_nz = max_nz.max(nzc);
                    sum_nz += nzc;
                }
                let drain = max_nz.max(1); // a group costs >=1 cycle to sequence
                let fill = max_nz; // operands stream in at 1 nz/lane/cycle
                macs += sum_nz;
                stall += (drain * lanes_used as u64).saturating_sub(sum_nz);
                if self.double_buffering {
                    // next group fills while this one drains
                    pass_cycles += if g == 0 { drain } else { drain.max(prev_drain.min(fill)) };
                } else {
                    pass_cycles += drain + fill;
                }
                prev_drain = drain;
            }
            // Adder-tree packing (§4.5): a pass occupying fewer than all
            // lanes shares the PE with other outputs' identical passes.
            let util = tree_utilization(occ_pass, self.lanes, self.reconfig);
            cycles += (pass_cycles as f64 * (occ_pass as f64 / self.lanes as f64) / util)
                .round() as u64;
        }
        ExactOutput { cycles: cycles.max(1), macs, lane_stall_cycles: stall }
    }

    /// Simulate a whole tile: `outputs` receptive-field bitmaps, with an
    /// optional output-sparsity mask saying which outputs are skipped.
    ///
    /// A mask shorter than `outputs` used to panic on the first
    /// out-of-range output, and a longer one silently ignored its tail —
    /// both are caller bugs, so the lengths are now checked up front.
    pub fn simulate_tile(&self, outputs: &[Vec<bool>], out_mask: Option<&[bool]>) -> ExactOutput {
        if let Some(mask) = out_mask {
            assert_eq!(
                mask.len(),
                outputs.len(),
                "output mask length {} != output count {}",
                mask.len(),
                outputs.len()
            );
        }
        let mut total = ExactOutput { cycles: 0, macs: 0, lane_stall_cycles: 0 };
        for (i, nz) in outputs.iter().enumerate() {
            if let Some(mask) = out_mask {
                if !mask[i] {
                    continue; // skipped a priori — zero cycles (Fig 5c)
                }
            }
            let r = self.simulate_output(nz);
            total.cycles += r.cycles;
            total.macs += r.macs;
            total.lane_stall_cycles += r.lane_stall_cycles;
        }
        total
    }
}

/// Random operand bitmap with the given density (helper for validation
/// tests and synthetic exact runs).
pub fn random_bitmap(crs: usize, density: f64, rng: &mut Pcg32) -> Vec<bool> {
    (0..crs).map(|_| rng.bernoulli(density)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AcceleratorConfig;
    use crate::sim::pe::PeModel;

    #[test]
    fn dense_output_matches_arithmetic() {
        let pe = ExactPe::default();
        // CRS=1024 dense: 16 lanes × 64 entries, 2 groups of 32 → 64 cycles.
        let nz = vec![true; 1024];
        let r = pe.simulate_output(&nz);
        assert_eq!(r.macs, 1024);
        assert_eq!(r.cycles, 64);
        assert_eq!(r.lane_stall_cycles, 0);
    }

    #[test]
    fn empty_pattern_costs_minimum() {
        let pe = ExactPe::default();
        let nz = vec![false; 1024];
        let r = pe.simulate_output(&nz);
        assert_eq!(r.macs, 0);
        assert!(r.cycles <= 4, "all-zero group sequencing {}", r.cycles);
    }

    #[test]
    fn sparsity_reduces_cycles_and_counts_stall() {
        let pe = ExactPe::default();
        let mut rng = Pcg32::new(3);
        let dense = pe.simulate_output(&vec![true; 1024]);
        let sparse = pe.simulate_output(&random_bitmap(1024, 0.5, &mut rng));
        assert!(sparse.cycles < dense.cycles);
        assert!(sparse.macs < dense.macs);
        assert!(sparse.lane_stall_cycles > 0, "imbalance must show up as stall");
    }

    #[test]
    fn double_buffering_never_hurts() {
        let mut rng = Pcg32::new(9);
        for _ in 0..20 {
            let nz = random_bitmap(2048, rng.range_f64(0.1, 0.9), &mut rng);
            let with = ExactPe::default().simulate_output(&nz);
            let without = ExactPe { double_buffering: false, ..ExactPe::default() }
                .simulate_output(&nz);
            assert!(with.cycles <= without.cycles);
            assert_eq!(with.macs, without.macs);
        }
    }

    #[test]
    fn blocking_pass_overhead_applies() {
        let pe = ExactPe::default();
        let one_pass = pe.simulate_output(&vec![true; 1024]);
        let two_pass = pe.simulate_output(&vec![true; 2048]);
        assert!(two_pass.cycles >= 2 * one_pass.cycles + pe.blocking_overhead);
    }

    #[test]
    fn tile_skips_masked_outputs_entirely() {
        let pe = ExactPe::default();
        let outputs: Vec<Vec<bool>> = (0..8).map(|_| vec![true; 256]).collect();
        let all = pe.simulate_tile(&outputs, None);
        let mask = vec![true, false, true, false, true, false, true, false];
        let half = pe.simulate_tile(&outputs, Some(&mask));
        assert_eq!(half.cycles * 2, all.cycles);
        assert_eq!(half.macs * 2, all.macs);
    }

    #[test]
    #[should_panic(expected = "output mask length")]
    fn mismatched_mask_length_is_rejected() {
        let pe = ExactPe::default();
        let outputs: Vec<Vec<bool>> = (0..4).map(|_| vec![true; 64]).collect();
        let mask = vec![true; 3];
        pe.simulate_tile(&outputs, Some(&mask));
    }

    #[test]
    fn from_config_matches_defaults() {
        let pe = ExactPe::from_config(&AcceleratorConfig::default());
        let d = ExactPe::default();
        assert_eq!(pe.lanes, d.lanes);
        assert_eq!(pe.group_entries, d.group_entries);
        assert_eq!(pe.groups, d.groups);
        assert_eq!(pe.blocking_overhead, d.blocking_overhead);
    }

    /// The headline validation: the analytic `PeModel` must track the
    /// exact simulation across sparsity levels and receptive fields.
    #[test]
    fn analytic_model_tracks_exact_simulation() {
        let cfg = AcceleratorConfig::default();
        let analytic = PeModel::from_config(&cfg);
        let exact = ExactPe::default();
        let mut rng = Pcg32::new(42);
        for &crs in &[256usize, 576, 1024, 2304, 4608] {
            for &s in &[0.0, 0.3, 0.5, 0.7] {
                // average the exact sim over many random patterns
                let trials = 40;
                let mut sum = 0u64;
                for _ in 0..trials {
                    let nz = random_bitmap(crs, 1.0 - s, &mut rng);
                    sum += exact.simulate_output(&nz).cycles;
                }
                let exact_mean = sum as f64 / trials as f64;
                let (model, _) = analytic.cycles_per_output(crs as f64, s);
                let err = (model - exact_mean).abs() / exact_mean;
                assert!(
                    err < 0.20,
                    "crs={crs} s={s}: analytic {model:.1} vs exact {exact_mean:.1} ({:.0}%)",
                    err * 100.0
                );
            }
        }
    }
}
