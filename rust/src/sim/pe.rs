//! Processing-element model (§4.3): 16 computation lanes, each with a
//! 32-entry double-buffered operand group, a MAC per lane, feeding the
//! reconfigurable adder tree.
//!
//! The quantity the whole simulator turns on is *cycles per output
//! neuron*. Dense, an output with receptive field CRS costs
//! `ceil(CRS / lanes)` MAC cycles (every lane streams its share). With
//! input sparsity, each lane only visits its non-zero operands, but the
//! group must wait for its slowest lane — the expected lane-maximum of
//! binomially-thinned counts. Double buffering overlaps the next group's
//! fill with the current drain; the residual exposure is modeled as a
//! warm-up plus the fill/drain imbalance.

use crate::config::AcceleratorConfig;

use super::adder_tree::{tree_utilization, ReconfigMode};

/// Expected maximum of `l` iid Binomial(n, p) draws, via the normal
/// order-statistic approximation `μ + σ·c_l` (exact at the extremes).
/// `c_16 ≈ 1.766` is the expected maximum of 16 standard normals.
pub fn expected_lane_max(n: f64, p: f64, lanes: usize) -> f64 {
    if p <= 0.0 || n <= 0.0 {
        return 0.0;
    }
    if p >= 1.0 {
        return n;
    }
    let mu = n * p;
    let var = n * p * (1.0 - p);
    let c = expected_max_std_normal(lanes);
    (mu + var.sqrt() * c).min(n)
}

/// E[max of k standard normals] (Blom's approximation via the inverse
/// normal CDF at (k − π/8 + ...)/(k − π/4 + 1) — tabulated for the small
/// k the hardware uses, interpolated otherwise).
pub fn expected_max_std_normal(k: usize) -> f64 {
    const TABLE: [(usize, f64); 8] = [
        (1, 0.0),
        (2, 0.5642),
        (4, 1.0294),
        (8, 1.4236),
        (16, 1.7660),
        (32, 2.0697),
        (64, 2.3440),
        (256, 2.8029),
    ];
    if k <= 1 {
        return 0.0;
    }
    for w in TABLE.windows(2) {
        let (k0, v0) = w[0];
        let (k1, v1) = w[1];
        if k <= k1 {
            if k == k1 {
                return v1;
            }
            // interpolate in log k
            let t = ((k as f64).ln() - (k0 as f64).ln()) / ((k1 as f64).ln() - (k0 as f64).ln());
            return v0 + t * (v1 - v0);
        }
    }
    // k > 256: asymptotic √(2 ln k)
    (2.0 * (k as f64).ln()).sqrt()
}

/// Per-output-neuron cycle model.
#[derive(Clone, Debug)]
pub struct PeModel {
    pub lanes: usize,
    pub group_entries: usize,
    pub groups: usize,
    pub reconfig: ReconfigMode,
    /// Extra cycles per synapse-blocking pass for the partial-sum
    /// read-modify-write (§4.4).
    pub blocking_overhead: f64,
    /// Whether double buffering is enabled (§4.3; ablation knob).
    pub double_buffering: bool,
}

impl PeModel {
    pub fn from_config(cfg: &AcceleratorConfig) -> PeModel {
        PeModel {
            lanes: cfg.lanes,
            group_entries: cfg.group_entries,
            groups: cfg.groups,
            reconfig: ReconfigMode::Hierarchical,
            blocking_overhead: 4.0,
            double_buffering: true,
        }
    }

    /// PE operand capacity per double-buffered pass (1024 by default).
    pub fn capacity(&self) -> usize {
        self.lanes * self.group_entries * self.groups
    }

    /// Expected cycles to produce one output neuron whose receptive field
    /// is `crs`, under operand sparsity `s_in` (0 = dense execution).
    ///
    /// Returns (cycles, macs_performed).
    pub fn cycles_per_output(&self, crs: f64, s_in: f64) -> (f64, f64) {
        assert!(crs > 0.0, "receptive field must be positive");
        let p = (1.0 - s_in).clamp(0.0, 1.0);
        let cap = self.capacity() as f64;
        // Synapse blocking (§4.4): full capacity-sized passes plus a tail.
        let n_full = (crs / cap).floor();
        let tail = crs - n_full * cap;
        let mut cycles = n_full * self.pass_cycles(cap, p);
        if tail > 0.5 {
            cycles += self.pass_cycles(tail, p);
        }
        let passes = n_full + if tail > 0.5 { 1.0 } else { 0.0 };
        cycles += (passes - 1.0).max(0.0) * self.blocking_overhead;
        let macs = crs * p;
        // Floor: the adder tree completes at most `lanes` packed outputs
        // per cycle.
        (cycles.max(1.0 / self.lanes as f64), macs)
    }

    /// Expected cycles for one blocking pass over `chunk` operands at
    /// density `p`, including the adder-tree packing discount for passes
    /// that occupy fewer than all lanes (§4.5).
    fn pass_cycles(&self, chunk: f64, p: f64) -> f64 {
        let entries_per_lane_pass = (self.group_entries * self.groups) as f64;
        let occ = (chunk / entries_per_lane_pass).ceil().clamp(1.0, self.lanes as f64);
        let util = tree_utilization(occ as usize, self.lanes, self.reconfig);
        let n_group = self.group_entries as f64;
        let lane_entries = chunk / occ;
        let lane_groups = (lane_entries / n_group).ceil().max(1.0);
        let group_fill = (lane_entries / lane_groups).min(n_group).max(1.0);
        // Per-group drain: expected max over occupied lanes; fill streams
        // non-zeros only. Double buffering overlaps them.
        let drain = expected_lane_max(group_fill, p, occ as usize).max(1.0);
        let fill = group_fill * p;
        let per_group = if self.double_buffering { drain.max(fill) } else { drain + fill };
        lane_groups * per_group * (occ / self.lanes as f64) / util.max(1e-9)
    }

    /// Dense-baseline cycles per output (DC scheme): every operand pair
    /// is processed.
    pub fn dense_cycles_per_output(&self, crs: f64) -> f64 {
        self.cycles_per_output(crs, 0.0).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pe() -> PeModel {
        PeModel::from_config(&AcceleratorConfig::default())
    }

    #[test]
    fn lane_max_bounds() {
        // p=0 → 0; p=1 → n; monotone in p.
        assert_eq!(expected_lane_max(32.0, 0.0, 16), 0.0);
        assert_eq!(expected_lane_max(32.0, 1.0, 16), 32.0);
        let lo = expected_lane_max(32.0, 0.3, 16);
        let hi = expected_lane_max(32.0, 0.6, 16);
        assert!(hi > lo && lo > 32.0 * 0.3, "max must exceed the mean");
        assert!(hi <= 32.0);
    }

    #[test]
    fn max_std_normal_table_monotone() {
        let mut prev = -1.0;
        for k in [1usize, 2, 3, 4, 8, 12, 16, 32, 64, 256, 1024] {
            let v = expected_max_std_normal(k);
            assert!(v >= prev, "k={k}: {v} < {prev}");
            prev = v;
        }
        assert!((expected_max_std_normal(16) - 1.766).abs() < 1e-3);
    }

    #[test]
    fn dense_cycles_match_capacity_arithmetic() {
        let pe = pe();
        // CRS = 1024 exactly fills the PE: 16 lanes × 64 entries; dense
        // drain = 32 per group, 2 groups → 64 cycles (steady state, the
        // ideal 1024/16 — dense mode has no imbalance, §4.3).
        let d = pe.dense_cycles_per_output(1024.0);
        assert!((d - 64.0).abs() < 1.0, "1024-CRS dense cycles {d}");
        // CRS = 2048: two blocking passes, roughly twice + overhead.
        let d2 = pe.dense_cycles_per_output(2048.0);
        assert!(d2 > 1.9 * d && d2 < 2.4 * d, "{d2} vs {d}");
    }

    #[test]
    fn sparsity_reduces_cycles_monotonically() {
        let pe = pe();
        let mut prev = f64::MAX;
        for s in [0.0, 0.2, 0.4, 0.6, 0.8] {
            let (c, m) = pe.cycles_per_output(1024.0, s);
            assert!(c < prev, "s={s}: {c} !< {prev}");
            assert!((m - 1024.0 * (1.0 - s)).abs() < 1e-9);
            prev = c;
        }
    }

    #[test]
    fn imbalance_costs_over_ideal() {
        // With sparsity, cycles must exceed the perfectly-balanced ideal
        // (mean work per lane) — that's the lane-stall phenomenon.
        let pe = pe();
        let s = 0.5;
        let (c, _) = pe.cycles_per_output(1024.0, s);
        let ideal = 1024.0 * (1.0 - s) / 16.0;
        assert!(c > ideal * 0.99, "c={c} ideal={ideal}");
    }

    #[test]
    fn double_buffering_helps() {
        let mut pe_db = pe();
        let mut pe_nodb = pe();
        pe_nodb.double_buffering = false;
        let (with_db, _) = pe_db.cycles_per_output(1024.0, 0.4);
        let (without, _) = pe_nodb.cycles_per_output(1024.0, 0.4);
        assert!(without > with_db, "db {with_db} vs no-db {without}");
        let _ = &mut pe_db;
    }

    #[test]
    fn small_receptive_field_uses_reconfig() {
        // CRS=64 occupies 1/16 lanes; hierarchical reconfig packs 16
        // outputs → per-output cost ~1/16 of the unpacked cost.
        let mut pe_h = pe();
        pe_h.reconfig = ReconfigMode::Hierarchical;
        let mut pe_n = pe();
        pe_n.reconfig = ReconfigMode::None;
        let ch = pe_h.dense_cycles_per_output(64.0);
        let cn = pe_n.dense_cycles_per_output(64.0);
        assert!(cn / ch > 8.0, "hier {ch} vs none {cn}");
    }
}
