//! Parallel, cached (network × scheme × configuration) simulation sweeps.
//!
//! The paper's headline artifacts (Figs 11–17, Table 2) are all grids of
//! independent whole-network simulations. This module is the one shared
//! execution layer for those grids:
//!
//! * [`SweepPlan`] — a declarative list of (network, scheme, config)
//!   combos; [`SweepPlan::grid`] builds the common cross product.
//! * [`SweepRunner`] — executes a plan on the shared indexed worker
//!   pool (`util::pool`; no external crates) with a `jobs` knob, fanning
//!   spare threads out across batch images when the plan is small.
//! * [`SweepCache`] — keyed by `(network name, scheme, config
//!   fingerprint)`, so every distinct combo simulates **at most once per
//!   process**, no matter how many figures, tables or ablation points ask
//!   for it.
//!
//! Results are bit-identical to running `simulate_network` sequentially:
//! the engine derives an independent RNG stream per image
//! (`engine::image_stream`), so a combo's result does not depend on when
//! or where it executed, and plan outputs are assembled in plan order.

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::nn::{Network, Phase};
use crate::sparsity::SparsityModel;
use crate::util::json::Json;
use crate::util::pool::run_indexed;

use super::engine::{simulate_network_jobs, NetworkSimResult};

/// Simulator-semantics revision, stamped into on-disk cache spills. The
/// cache key fingerprints every *input* of a simulation but nothing
/// about the *algorithm*; bump this whenever simulation semantics change
/// so stale spills from older code are rejected instead of silently
/// served. (rev 3: the exact backend's draw sequence changed — masked
/// outputs no longer consume operand draws — and replayed/patterned
/// sources were added. rev 4: geometry-exact replay — strided
/// receptive-field gather, replayed WG pairs, measured per-tile analytic
/// densities — changed every replayed result and the options identity
/// grew the gather mode. rev 5: trace fingerprints fold the on-disk
/// format (v2/v3), post-Add footprints and Add-pass-through gradient
/// maps changed replayed residual-network results, and the WG strided
/// row gather was word-rewritten. rev 6: sampled exact-backend tasks
/// under geometry gathering synthesize one task-wide operand map and
/// gather planned windows from it instead of drawing per-output
/// patterns — every sampled exact result's draw sequence changed — and
/// the v4 binary trace container folds a new format tag into trace
/// fingerprints. rev 7: the options identity grew a presence-tagged
/// scenario fingerprint — every `SimOptions::fingerprint()` value moved,
/// so spills minted at rev ≤ 6 would never match and are rejected
/// outright.)
pub const SIM_REVISION: u64 = 7;

/// Cache identity of one simulation: everything that can change the
/// result — the network (name *and* structure), the scheme, and the
/// fingerprints of the hardware config, the sim options and the sparsity
/// model (see the `fingerprint()` methods on `AcceleratorConfig`,
/// `SimOptions`, `SparsityModel` and `Network`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SweepKey {
    pub network: String,
    pub scheme: Scheme,
    pub fingerprint: u64,
}

impl SweepKey {
    pub fn new(
        net: &Network,
        scheme: Scheme,
        cfg: &AcceleratorConfig,
        opts: &SimOptions,
        model: &SparsityModel,
    ) -> SweepKey {
        let mut h = crate::util::fnv::Fnv1a::new();
        h.put(net.fingerprint())
            .put(cfg.fingerprint())
            .put(opts.fingerprint())
            .put(model.fingerprint());
        SweepKey { network: net.name.clone(), scheme, fingerprint: h.finish() }
    }
}

/// One simulation the plan requests. Carries the network by value so
/// workers need no registry lookup (custom networks work too).
#[derive(Clone, Debug)]
pub struct SweepCombo {
    pub network: Network,
    pub scheme: Scheme,
    pub cfg: AcceleratorConfig,
    pub opts: SimOptions,
    /// Per-combo sparsity-model override. `None` (every pre-scenario
    /// caller) falls back to the plan-wide model handed to
    /// [`SweepRunner::run`]; scenario plans set it so one plan can carry
    /// many schedule phases — each phase a differently-scaled model —
    /// through a single cached run. The override participates in the
    /// cache key exactly as the plan-wide model would.
    pub model: Option<SparsityModel>,
}

impl SweepCombo {
    fn key(&self, model: &SparsityModel) -> SweepKey {
        let model = self.model.as_ref().unwrap_or(model);
        SweepKey::new(&self.network, self.scheme, &self.cfg, &self.opts, model)
    }
}

/// A declarative sweep: the combos to simulate, in output order.
#[derive(Clone, Debug, Default)]
pub struct SweepPlan {
    pub combos: Vec<SweepCombo>,
}

impl SweepPlan {
    pub fn new() -> SweepPlan {
        SweepPlan { combos: Vec::new() }
    }

    /// Cross product `networks × schemes` at one configuration, ordered
    /// network-major (combo `i` is `networks[i / schemes.len()]` under
    /// `schemes[i % schemes.len()]`).
    pub fn grid(
        networks: &[Network],
        schemes: &[Scheme],
        cfg: &AcceleratorConfig,
        opts: &SimOptions,
    ) -> SweepPlan {
        let mut plan = SweepPlan::new();
        for net in networks {
            for &scheme in schemes {
                plan.push(net.clone(), scheme, cfg, opts);
            }
        }
        plan
    }

    pub fn push(
        &mut self,
        network: Network,
        scheme: Scheme,
        cfg: &AcceleratorConfig,
        opts: &SimOptions,
    ) {
        self.combos.push(SweepCombo {
            network,
            scheme,
            cfg: cfg.clone(),
            opts: opts.clone(),
            model: None,
        });
    }

    /// [`SweepPlan::push`] with a per-combo sparsity-model override (see
    /// [`SweepCombo::model`]) — how scenario schedule phases enter a plan.
    pub fn push_with_model(
        &mut self,
        network: Network,
        scheme: Scheme,
        cfg: &AcceleratorConfig,
        opts: &SimOptions,
        model: SparsityModel,
    ) {
        self.combos.push(SweepCombo {
            network,
            scheme,
            cfg: cfg.clone(),
            opts: opts.clone(),
            model: Some(model),
        });
    }

    pub fn len(&self) -> usize {
        self.combos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.combos.is_empty()
    }
}

/// Process-wide result cache keyed by [`SweepKey`].
#[derive(Debug, Default)]
pub struct SweepCache {
    map: Mutex<HashMap<SweepKey, Arc<NetworkSimResult>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl SweepCache {
    pub fn new() -> SweepCache {
        SweepCache::default()
    }

    /// Look a result up without touching the hit/miss counters.
    pub fn peek(&self, key: &SweepKey) -> Option<Arc<NetworkSimResult>> {
        self.map.lock().unwrap().get(key).cloned()
    }

    pub fn insert(&self, key: SweepKey, result: Arc<NetworkSimResult>) {
        self.map.lock().unwrap().insert(key, result);
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Requests served from the cache (or deduplicated within a plan).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Requests that required a fresh simulation.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    fn note_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    fn note_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }

    /// Serialize every cached result. Entries are emitted in sorted key
    /// order and fingerprints as hex strings (u64 does not survive JSON's
    /// f64 numbers above 2^53), so cache files diff cleanly.
    pub fn to_json(&self) -> Json {
        let map = self.map.lock().unwrap();
        let mut entries: Vec<(&SweepKey, &Arc<NetworkSimResult>)> = map.iter().collect();
        entries.sort_by_key(|(k, _)| (k.network.clone(), k.scheme.label(), k.fingerprint));
        let entries: Vec<Json> = entries
            .into_iter()
            .map(|(k, r)| {
                Json::from_pairs(vec![
                    ("network", k.network.as_str().into()),
                    ("scheme", k.scheme.label().into()),
                    ("fingerprint", format!("{:016x}", k.fingerprint).into()),
                    ("result", r.to_json()),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("version", 1u64.into()),
            ("sim_rev", SIM_REVISION.into()),
            ("entries", Json::Arr(entries)),
        ])
    }

    /// Insert every entry of a serialized cache; returns how many were
    /// loaded. Counts neither hits nor misses — loaded entries only pay
    /// off when a later request peeks them.
    pub fn merge_json(&self, j: &Json) -> anyhow::Result<usize> {
        anyhow::ensure!(
            j.get("version").as_u64() == Some(1),
            "unsupported sweep cache version"
        );
        anyhow::ensure!(
            j.get("sim_rev").as_u64() == Some(SIM_REVISION),
            "sweep cache was written by a different simulator revision \
             (file {:?}, current {SIM_REVISION})",
            j.get("sim_rev").as_u64()
        );
        let entries = j
            .get("entries")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("sweep cache: entries array"))?;
        let mut n = 0;
        for e in entries {
            let network = e
                .get("network")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("cache entry network"))?
                .to_string();
            let scheme = Scheme::parse(
                e.get("scheme").as_str().ok_or_else(|| anyhow::anyhow!("cache entry scheme"))?,
            )?;
            let fp = e
                .get("fingerprint")
                .as_str()
                .and_then(|s| u64::from_str_radix(s, 16).ok())
                .ok_or_else(|| anyhow::anyhow!("cache entry fingerprint"))?;
            let result = NetworkSimResult::from_json(e.get("result"))?;
            self.insert(SweepKey { network, scheme, fingerprint: fp }, Arc::new(result));
            n += 1;
        }
        Ok(n)
    }

    /// Load a cache file written by [`SweepCache::save_file`]; a missing
    /// file is an empty cache (returns 0), a corrupt one an error.
    pub fn load_file(&self, path: &Path) -> anyhow::Result<usize> {
        if !path.exists() {
            return Ok(0);
        }
        self.merge_json(&Json::parse_file(path)?)
    }

    /// Persist the cache atomically (write-then-rename), so a concurrent
    /// reader never sees a half-written file. The temp name is
    /// per-process so two concurrent writers cannot clobber each other's
    /// in-flight file.
    ///
    /// The spill is **merge-on-save**: the file is re-read first and its
    /// entries unioned under ours (ours win on key collision — a cached
    /// result for a key is bit-identical wherever it was computed, so
    /// "winning" only matters for freshness of the bytes written). With
    /// plain last-rename-wins, two concurrent *processes* — say a
    /// resident `agos serve` and a stray one-shot CLI — would interleave
    /// load → simulate → save and silently drop whichever entries the
    /// other computed after their load. A stale or corrupt existing file
    /// is ignored (overwritten), matching `load_file`'s tolerance for a
    /// missing one. The in-memory cache is not mutated.
    pub fn save_file(&self, path: &Path) -> anyhow::Result<()> {
        let merged = SweepCache::new();
        if path.exists() {
            if let Ok(j) = Json::parse_file(path) {
                let _ = merged.merge_json(&j);
            }
        }
        for (k, v) in self.map.lock().unwrap().iter() {
            merged.insert(k.clone(), v.clone());
        }
        let tmp = path.with_extension(format!("json.tmp.{}", std::process::id()));
        merged.to_json().write_file(&tmp)?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }
}

/// Worker-pool sweep executor over a shared [`SweepCache`].
///
/// The cache sits behind an `Arc` so any number of runners — one per
/// served request in `agos serve`, or the single runner of a one-shot
/// CLI invocation — can share one resident result store. The runner
/// itself owns no per-run mutable state beyond its thread budget;
/// everything it reads during a sweep (`ReplayBank`, `GatherPlanCache`,
/// the model) is immutable or internally synchronized.
#[derive(Debug)]
pub struct SweepRunner {
    /// Worker threads used per `run` call (resolved; never 0).
    pub jobs: usize,
    cache: Arc<SweepCache>,
}

impl SweepRunner {
    /// Runner over a fresh private cache. `jobs == 0` selects the
    /// host's available parallelism.
    pub fn new(jobs: usize) -> SweepRunner {
        SweepRunner::with_cache(jobs, Arc::new(SweepCache::new()))
    }

    /// Runner over an existing shared cache (the `agos serve` path:
    /// every request's runner points at the same resident cache).
    /// `jobs == 0` selects the host's available parallelism.
    pub fn with_cache(jobs: usize, cache: Arc<SweepCache>) -> SweepRunner {
        let jobs = if jobs == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            jobs
        };
        SweepRunner { jobs, cache }
    }

    pub fn cache(&self) -> &SweepCache {
        &self.cache
    }

    /// The shared cache handle itself (for spilling after the runner is
    /// handed off, or for wiring another runner to the same store).
    pub fn cache_arc(&self) -> Arc<SweepCache> {
        self.cache.clone()
    }

    /// Cached single simulation at an explicit configuration. A miss
    /// fans the batch's images out across the runner's worker budget
    /// (bit-identical to sequential execution; see `engine`).
    pub fn one(
        &self,
        net: &Network,
        cfg: &AcceleratorConfig,
        opts: &SimOptions,
        model: &SparsityModel,
        scheme: Scheme,
    ) -> Arc<NetworkSimResult> {
        let key = SweepKey::new(net, scheme, cfg, opts, model);
        if let Some(r) = self.cache.peek(&key) {
            self.cache.note_hit();
            return r;
        }
        self.cache.note_miss();
        let r = Arc::new(simulate_network_jobs(net, cfg, opts, model, scheme, self.jobs));
        self.cache.insert(key, r.clone());
        r
    }

    /// Execute a plan: deduplicate against the cache and within the plan,
    /// simulate the remaining combos on up to `jobs` worker threads, and
    /// return one result per combo in plan order. Bit-identical to
    /// sequential execution (see module docs).
    pub fn run(&self, plan: &SweepPlan, model: &SparsityModel) -> Vec<Arc<NetworkSimResult>> {
        let keys: Vec<SweepKey> = plan.combos.iter().map(|c| c.key(model)).collect();

        // Combo indices that actually need a fresh simulation.
        let mut leaders: Vec<usize> = Vec::new();
        {
            let mut seen: HashSet<&SweepKey> = HashSet::new();
            for (i, key) in keys.iter().enumerate() {
                if self.cache.peek(key).is_some() || !seen.insert(key) {
                    self.cache.note_hit();
                } else {
                    self.cache.note_miss();
                    leaders.push(i);
                }
            }
        }

        if !leaders.is_empty() {
            // Per-image fan-out: when the plan has fewer fresh combos
            // than worker threads, the spare threads split each combo's
            // batch instead of idling (bit-identical either way — the
            // per-image streams don't care who runs them). Essential for
            // the exact backend, which is far slower per image. The ceil
            // split mildly oversubscribes when combos don't divide the
            // budget evenly — better than idling cores on the long-tail
            // combo; there is no dynamic rebalancing.
            let inner_jobs = self.jobs.div_ceil(leaders.len());
            let results = run_indexed(leaders.len(), self.jobs, |w| {
                let c = &plan.combos[leaders[w]];
                let m = c.model.as_ref().unwrap_or(model);
                simulate_network_jobs(&c.network, &c.cfg, &c.opts, m, c.scheme, inner_jobs)
            });
            for (w, r) in results.into_iter().enumerate() {
                self.cache.insert(keys[leaders[w]].clone(), Arc::new(r));
            }
        }

        keys.iter()
            .map(|k| self.cache.peek(k).expect("every plan combo was simulated or cached"))
            .collect()
    }
}

/// The sweep's report document — what `agos sweep --out` writes and what
/// a served `sweep` request returns: the options that define the grid
/// plus one row per (network, scheme) combo in grid order.
///
/// Deliberately carries **no** wall-clock or thread-count fields: the
/// determinism contract promises a served response byte-identical to a
/// cold CLI run at any `--jobs` level, so everything in this document
/// must be a pure function of the request. Timings belong on stdout.
pub fn sweep_report_json(
    networks: &[Network],
    schemes: &[Scheme],
    results: &[Arc<NetworkSimResult>],
    opts: &SimOptions,
) -> Json {
    assert_eq!(networks.len() * schemes.len(), results.len(), "results must be grid-shaped");
    let mut combos = Vec::new();
    for (ni, net) in networks.iter().enumerate() {
        for (si, scheme) in schemes.iter().enumerate() {
            let r = &results[ni * schemes.len() + si];
            combos.push(Json::from_pairs(vec![
                ("network", net.name.as_str().into()),
                ("scheme", scheme.label().into()),
                ("total_cycles", r.total_cycles().into()),
                ("bp_cycles", r.phase(Phase::Backward).cycles.into()),
                ("energy_j", r.total_energy_j().into()),
            ]));
        }
    }
    Json::from_pairs(vec![
        ("batch", opts.batch.into()),
        ("seed", opts.seed.into()),
        ("backend", opts.backend.label().into()),
        ("combos", Json::Arr(combos)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;
    use crate::sim::simulate_network;

    fn small_opts() -> SimOptions {
        SimOptions { batch: 1, ..SimOptions::default() }
    }

    #[test]
    fn grid_orders_network_major() {
        let nets = [zoo::agos_cnn()];
        let plan =
            SweepPlan::grid(&nets, &Scheme::ALL, &AcceleratorConfig::default(), &small_opts());
        assert_eq!(plan.len(), 4);
        assert_eq!(plan.combos[0].scheme, Scheme::Dense);
        assert_eq!(plan.combos[3].scheme, Scheme::InOutWr);
        assert!(plan.combos.iter().all(|c| c.network.name == "agos_cnn"));
    }

    #[test]
    fn key_tracks_every_input_of_a_simulation() {
        let cfg = AcceleratorConfig::default();
        let opts = small_opts();
        let model = SparsityModel::synthetic(opts.seed);
        let net = zoo::agos_cnn();
        let a = SweepKey::new(&net, Scheme::Dense, &cfg, &opts, &model);
        assert_eq!(a, SweepKey::new(&net, Scheme::Dense, &cfg, &opts, &model));
        assert_ne!(a, SweepKey::new(&zoo::resnet18(), Scheme::Dense, &cfg, &opts, &model));
        assert_ne!(a, SweepKey::new(&net, Scheme::In, &cfg, &opts, &model));
        let opts2 = SimOptions { batch: 2, ..opts.clone() };
        assert_ne!(a, SweepKey::new(&net, Scheme::Dense, &cfg, &opts2, &model));
        let cfg2 = AcceleratorConfig { tx: 8, ..cfg.clone() };
        assert_ne!(a, SweepKey::new(&net, Scheme::Dense, &cfg2, &opts, &model));
        // A different sparsity model (measured vs synthetic, same seed)
        // must never be served the synthetic result.
        let mut measured = std::collections::BTreeMap::new();
        measured.insert("relu1".to_string(), 0.5);
        let model2 = SparsityModel::measured(opts.seed, measured);
        assert_ne!(a, SweepKey::new(&net, Scheme::Dense, &cfg, &opts, &model2));
        // A structurally different network sharing the name must miss.
        let mut alias = crate::nn::Network::new("agos_cnn");
        let x = alias.input(3, 32, 32);
        let c = alias.conv("conv1", x, 8, 3, 1, 1);
        let r = alias.relu("relu1", c);
        alias.softmax("prob", r);
        assert_ne!(a, SweepKey::new(&alias, Scheme::Dense, &cfg, &opts, &model));
        // The gather-plan cache is execution strategy, not an input:
        // plans on, off, or a different instance all HIT the same entry
        // (their results are bit-identical by the engine's contract).
        let no_plans = SimOptions { gather_plans: None, ..opts.clone() };
        assert_eq!(a, SweepKey::new(&net, Scheme::Dense, &cfg, &no_plans, &model));
        let other_cache = SimOptions {
            gather_plans: Some(Arc::new(crate::sim::GatherPlanCache::plans_only())),
            ..opts.clone()
        };
        assert_eq!(a, SweepKey::new(&net, Scheme::Dense, &cfg, &other_cache, &model));
    }

    #[test]
    fn duplicate_combos_simulate_once() {
        let runner = SweepRunner::new(2);
        let cfg = AcceleratorConfig::default();
        let opts = small_opts();
        let model = SparsityModel::synthetic(opts.seed);
        let mut plan = SweepPlan::new();
        plan.push(zoo::agos_cnn(), Scheme::Dense, &cfg, &opts);
        plan.push(zoo::agos_cnn(), Scheme::Dense, &cfg, &opts);
        let out = runner.run(&plan, &model);
        assert_eq!(out.len(), 2);
        assert!(Arc::ptr_eq(&out[0], &out[1]), "duplicates must share one result");
        assert_eq!(runner.cache().misses(), 1);
        assert_eq!(runner.cache().hits(), 1);

        // A second run of the same plan is served entirely from cache.
        let again = runner.run(&plan, &model);
        assert_eq!(runner.cache().misses(), 1);
        assert_eq!(runner.cache().hits(), 3);
        assert!(Arc::ptr_eq(&again[0], &out[0]));
    }

    #[test]
    fn per_combo_model_override_keys_and_executes_like_the_plan_model() {
        let cfg = AcceleratorConfig::default();
        let opts = small_opts();
        let base = SparsityModel::synthetic(opts.seed);
        let scaled = base.clone().with_scale(0.5);

        // Reference: the scaled model as the *plan-wide* model.
        let reference = SweepRunner::new(1);
        let mut ref_plan = SweepPlan::new();
        ref_plan.push(zoo::agos_cnn(), Scheme::InOut, &cfg, &opts);
        let want = reference.run(&ref_plan, &scaled);

        // Same model as a *per-combo override*, run under the base model:
        // identical result, and the cache key is the override's.
        let runner = SweepRunner::new(2);
        let mut plan = SweepPlan::new();
        plan.push(zoo::agos_cnn(), Scheme::InOut, &cfg, &opts);
        plan.push_with_model(zoo::agos_cnn(), Scheme::InOut, &cfg, &opts, scaled.clone());
        let out = runner.run(&plan, &base);
        assert_eq!(runner.cache().misses(), 2, "base and override must not share a key");
        assert_eq!(out[1].total_cycles(), want[0].total_cycles());
        assert_eq!(out[1].total_energy_j(), want[0].total_energy_j());
        assert_ne!(out[0].total_cycles(), out[1].total_cycles());

        // An override equal to the plan model dedups against plain combos.
        let mut dup = SweepPlan::new();
        dup.push(zoo::agos_cnn(), Scheme::InOut, &cfg, &opts);
        dup.push_with_model(zoo::agos_cnn(), Scheme::InOut, &cfg, &opts, base.clone());
        let two = runner.run(&dup, &base);
        assert!(Arc::ptr_eq(&two[0], &two[1]));
    }

    #[test]
    fn one_is_cached_and_matches_engine() {
        let runner = SweepRunner::new(1);
        let net = zoo::agos_cnn();
        let cfg = AcceleratorConfig::default();
        let opts = small_opts();
        let model = SparsityModel::synthetic(opts.seed);
        let a = runner.one(&net, &cfg, &opts, &model, Scheme::InOut);
        let b = runner.one(&net, &cfg, &opts, &model, Scheme::InOut);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(runner.cache().misses(), 1);
        let direct = simulate_network(&net, &cfg, &opts, &model, Scheme::InOut);
        assert_eq!(a.total_cycles(), direct.total_cycles());
        assert_eq!(a.total_energy_j(), direct.total_energy_j());
    }

    #[test]
    fn zero_jobs_resolves_to_host_parallelism() {
        assert!(SweepRunner::new(0).jobs >= 1);
        assert_eq!(SweepRunner::new(3).jobs, 3);
    }

    #[test]
    fn cache_spills_to_disk_and_reloads_bit_exact() {
        let dir = std::env::temp_dir().join("agos_sweep_cache_test");
        let path = dir.join("sweep-cache.json");
        std::fs::remove_file(&path).ok();

        let cfg = AcceleratorConfig::default();
        let opts = small_opts();
        let model = SparsityModel::synthetic(opts.seed);
        let plan = SweepPlan::grid(
            &[zoo::agos_cnn()],
            &[Scheme::Dense, Scheme::InOutWr],
            &cfg,
            &opts,
        );

        let first = SweepRunner::new(2);
        // A missing file loads as an empty cache.
        assert_eq!(first.cache().load_file(&path).unwrap(), 0);
        let out1 = first.run(&plan, &model);
        assert_eq!(first.cache().misses(), 2);
        first.cache().save_file(&path).unwrap();

        // A fresh process (runner) reloads the spill and simulates nothing.
        let second = SweepRunner::new(2);
        assert_eq!(second.cache().load_file(&path).unwrap(), 2);
        let out2 = second.run(&plan, &model);
        assert_eq!(second.cache().misses(), 0, "disk-cached combos must not re-simulate");
        assert_eq!(second.cache().hits(), 2);
        for (a, b) in out1.iter().zip(&out2) {
            assert_eq!(a.scheme, b.scheme);
            assert_eq!(a.total_cycles(), b.total_cycles());
            assert_eq!(a.total_energy_j(), b.total_energy_j());
            assert_eq!(a.per_layer.len(), b.per_layer.len());
            for (la, lb) in a.per_layer.iter().zip(&b.per_layer) {
                assert_eq!(la.cycles, lb.cycles, "{} {}", la.name, la.phase.label());
                assert_eq!(la.tile_mean, lb.tile_mean);
            }
        }

        // A stale entry for different options must not be served: a new
        // seed misses even with the spill loaded.
        let third = SweepRunner::new(1);
        third.cache().load_file(&path).unwrap();
        let other = SimOptions { seed: 999, ..small_opts() };
        let model2 = SparsityModel::synthetic(other.seed);
        let plan2 = SweepPlan::grid(&[zoo::agos_cnn()], &[Scheme::Dense], &cfg, &other);
        third.run(&plan2, &model2);
        assert_eq!(third.cache().misses(), 1);

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_file_merges_with_entries_already_on_disk() {
        let dir = std::env::temp_dir().join("agos_sweep_cache_merge_test");
        let path = dir.join("sweep-cache.json");
        std::fs::remove_file(&path).ok();

        let cfg = AcceleratorConfig::default();
        let opts = small_opts();
        let model = SparsityModel::synthetic(opts.seed);

        // Two runners that never saw each other's work: each simulates a
        // disjoint combo and saves to the same spill, second save last.
        // Before merge-on-save, the second save clobbered the first.
        let a = SweepRunner::new(1);
        a.run(&SweepPlan::grid(&[zoo::agos_cnn()], &[Scheme::Dense], &cfg, &opts), &model);
        a.cache().save_file(&path).unwrap();

        let b = SweepRunner::new(1);
        b.run(&SweepPlan::grid(&[zoo::agos_cnn()], &[Scheme::InOutWr], &cfg, &opts), &model);
        assert_eq!(b.cache().len(), 1, "runner b never loaded the spill");
        b.cache().save_file(&path).unwrap();

        // The union survives: a fresh load serves both combos.
        let fresh = SweepRunner::new(1);
        assert_eq!(fresh.cache().load_file(&path).unwrap(), 2);
        let plan =
            SweepPlan::grid(&[zoo::agos_cnn()], &[Scheme::Dense, Scheme::InOutWr], &cfg, &opts);
        fresh.run(&plan, &model);
        assert_eq!(fresh.cache().misses(), 0, "merge-on-save must keep both runners' entries");
        assert_eq!(fresh.cache().hits(), 2);

        // Saving the in-memory cache never mutates it.
        assert_eq!(b.cache().len(), 1);

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_cache_file_is_an_error_not_a_panic() {
        let dir = std::env::temp_dir().join("agos_sweep_cache_corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.json");
        std::fs::write(&path, "{ not json").unwrap();
        assert!(SweepCache::new().load_file(&path).is_err());
        std::fs::write(&path, "{\"version\": 2, \"entries\": []}").unwrap();
        assert!(SweepCache::new().load_file(&path).is_err());
        // A spill from another simulator revision must be rejected too.
        std::fs::write(&path, "{\"version\": 1, \"sim_rev\": 0, \"entries\": []}").unwrap();
        assert!(SweepCache::new().load_file(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
