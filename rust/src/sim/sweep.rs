//! Parallel, cached (network × scheme × configuration) simulation sweeps.
//!
//! The paper's headline artifacts (Figs 11–17, Table 2) are all grids of
//! independent whole-network simulations. This module is the one shared
//! execution layer for those grids:
//!
//! * [`SweepPlan`] — a declarative list of (network, scheme, config)
//!   combos; [`SweepPlan::grid`] builds the common cross product.
//! * [`SweepRunner`] — executes a plan on a worker pool
//!   (`std::thread::scope` + mpsc, the same idiom as
//!   `coordinator::pipeline`; no external crates) with a `jobs` knob.
//! * [`SweepCache`] — keyed by `(network name, scheme, config
//!   fingerprint)`, so every distinct combo simulates **at most once per
//!   process**, no matter how many figures, tables or ablation points ask
//!   for it.
//!
//! Results are bit-identical to running `simulate_network` sequentially:
//! the engine derives an independent RNG stream per image
//! (`engine::image_stream`), so a combo's result does not depend on when
//! or where it executed, and plan outputs are assembled in plan order.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::nn::Network;
use crate::sparsity::SparsityModel;

use super::engine::{simulate_network, NetworkSimResult};

/// Cache identity of one simulation: everything that can change the
/// result — the network (name *and* structure), the scheme, and the
/// fingerprints of the hardware config, the sim options and the sparsity
/// model (see the `fingerprint()` methods on `AcceleratorConfig`,
/// `SimOptions`, `SparsityModel` and `Network`).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SweepKey {
    pub network: String,
    pub scheme: Scheme,
    pub fingerprint: u64,
}

impl SweepKey {
    pub fn new(
        net: &Network,
        scheme: Scheme,
        cfg: &AcceleratorConfig,
        opts: &SimOptions,
        model: &SparsityModel,
    ) -> SweepKey {
        let mut h = crate::util::fnv::Fnv1a::new();
        h.put(net.fingerprint())
            .put(cfg.fingerprint())
            .put(opts.fingerprint())
            .put(model.fingerprint());
        SweepKey { network: net.name.clone(), scheme, fingerprint: h.finish() }
    }
}

/// One simulation the plan requests. Carries the network by value so
/// workers need no registry lookup (custom networks work too).
#[derive(Clone, Debug)]
pub struct SweepCombo {
    pub network: Network,
    pub scheme: Scheme,
    pub cfg: AcceleratorConfig,
    pub opts: SimOptions,
}

impl SweepCombo {
    fn key(&self, model: &SparsityModel) -> SweepKey {
        SweepKey::new(&self.network, self.scheme, &self.cfg, &self.opts, model)
    }
}

/// A declarative sweep: the combos to simulate, in output order.
#[derive(Clone, Debug, Default)]
pub struct SweepPlan {
    pub combos: Vec<SweepCombo>,
}

impl SweepPlan {
    pub fn new() -> SweepPlan {
        SweepPlan { combos: Vec::new() }
    }

    /// Cross product `networks × schemes` at one configuration, ordered
    /// network-major (combo `i` is `networks[i / schemes.len()]` under
    /// `schemes[i % schemes.len()]`).
    pub fn grid(
        networks: &[Network],
        schemes: &[Scheme],
        cfg: &AcceleratorConfig,
        opts: &SimOptions,
    ) -> SweepPlan {
        let mut plan = SweepPlan::new();
        for net in networks {
            for &scheme in schemes {
                plan.push(net.clone(), scheme, cfg, opts);
            }
        }
        plan
    }

    pub fn push(
        &mut self,
        network: Network,
        scheme: Scheme,
        cfg: &AcceleratorConfig,
        opts: &SimOptions,
    ) {
        self.combos.push(SweepCombo { network, scheme, cfg: cfg.clone(), opts: opts.clone() });
    }

    pub fn len(&self) -> usize {
        self.combos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.combos.is_empty()
    }
}

/// Process-wide result cache keyed by [`SweepKey`].
#[derive(Debug, Default)]
pub struct SweepCache {
    map: Mutex<HashMap<SweepKey, Arc<NetworkSimResult>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl SweepCache {
    pub fn new() -> SweepCache {
        SweepCache::default()
    }

    /// Look a result up without touching the hit/miss counters.
    pub fn peek(&self, key: &SweepKey) -> Option<Arc<NetworkSimResult>> {
        self.map.lock().unwrap().get(key).cloned()
    }

    pub fn insert(&self, key: SweepKey, result: Arc<NetworkSimResult>) {
        self.map.lock().unwrap().insert(key, result);
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Requests served from the cache (or deduplicated within a plan).
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Requests that required a fresh simulation.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    fn note_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    fn note_miss(&self) {
        self.misses.fetch_add(1, Ordering::Relaxed);
    }
}

/// Worker-pool sweep executor with a shared [`SweepCache`].
#[derive(Debug)]
pub struct SweepRunner {
    /// Worker threads used per `run` call (resolved; never 0).
    pub jobs: usize,
    cache: SweepCache,
}

impl SweepRunner {
    /// `jobs == 0` selects the host's available parallelism.
    pub fn new(jobs: usize) -> SweepRunner {
        let jobs = if jobs == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            jobs
        };
        SweepRunner { jobs, cache: SweepCache::new() }
    }

    pub fn cache(&self) -> &SweepCache {
        &self.cache
    }

    /// Cached single simulation at an explicit configuration.
    pub fn one(
        &self,
        net: &Network,
        cfg: &AcceleratorConfig,
        opts: &SimOptions,
        model: &SparsityModel,
        scheme: Scheme,
    ) -> Arc<NetworkSimResult> {
        let key = SweepKey::new(net, scheme, cfg, opts, model);
        if let Some(r) = self.cache.peek(&key) {
            self.cache.note_hit();
            return r;
        }
        self.cache.note_miss();
        let r = Arc::new(simulate_network(net, cfg, opts, model, scheme));
        self.cache.insert(key, r.clone());
        r
    }

    /// Execute a plan: deduplicate against the cache and within the plan,
    /// simulate the remaining combos on up to `jobs` worker threads, and
    /// return one result per combo in plan order. Bit-identical to
    /// sequential execution (see module docs).
    pub fn run(&self, plan: &SweepPlan, model: &SparsityModel) -> Vec<Arc<NetworkSimResult>> {
        let keys: Vec<SweepKey> = plan.combos.iter().map(|c| c.key(model)).collect();

        // Combo indices that actually need a fresh simulation.
        let mut leaders: Vec<usize> = Vec::new();
        {
            let mut seen: HashSet<&SweepKey> = HashSet::new();
            for (i, key) in keys.iter().enumerate() {
                if self.cache.peek(key).is_some() || !seen.insert(key) {
                    self.cache.note_hit();
                } else {
                    self.cache.note_miss();
                    leaders.push(i);
                }
            }
        }

        if !leaders.is_empty() {
            let jobs = self.jobs.clamp(1, leaders.len());
            let next = AtomicUsize::new(0);
            let (tx, rx) = mpsc::channel::<(usize, NetworkSimResult)>();
            thread::scope(|s| {
                for _ in 0..jobs {
                    let tx = tx.clone();
                    let next = &next;
                    let leaders = &leaders;
                    s.spawn(move || loop {
                        let w = next.fetch_add(1, Ordering::Relaxed);
                        let Some(&i) = leaders.get(w) else { break };
                        let c = &plan.combos[i];
                        let r = simulate_network(&c.network, &c.cfg, &c.opts, model, c.scheme);
                        if tx.send((i, r)).is_err() {
                            break;
                        }
                    });
                }
                drop(tx);
                while let Ok((i, r)) = rx.recv() {
                    self.cache.insert(keys[i].clone(), Arc::new(r));
                }
            });
        }

        keys.iter()
            .map(|k| self.cache.peek(k).expect("every plan combo was simulated or cached"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn small_opts() -> SimOptions {
        SimOptions { batch: 1, ..SimOptions::default() }
    }

    #[test]
    fn grid_orders_network_major() {
        let nets = [zoo::agos_cnn()];
        let plan =
            SweepPlan::grid(&nets, &Scheme::ALL, &AcceleratorConfig::default(), &small_opts());
        assert_eq!(plan.len(), 4);
        assert_eq!(plan.combos[0].scheme, Scheme::Dense);
        assert_eq!(plan.combos[3].scheme, Scheme::InOutWr);
        assert!(plan.combos.iter().all(|c| c.network.name == "agos_cnn"));
    }

    #[test]
    fn key_tracks_every_input_of_a_simulation() {
        let cfg = AcceleratorConfig::default();
        let opts = small_opts();
        let model = SparsityModel::synthetic(opts.seed);
        let net = zoo::agos_cnn();
        let a = SweepKey::new(&net, Scheme::Dense, &cfg, &opts, &model);
        assert_eq!(a, SweepKey::new(&net, Scheme::Dense, &cfg, &opts, &model));
        assert_ne!(a, SweepKey::new(&zoo::resnet18(), Scheme::Dense, &cfg, &opts, &model));
        assert_ne!(a, SweepKey::new(&net, Scheme::In, &cfg, &opts, &model));
        let opts2 = SimOptions { batch: 2, ..opts.clone() };
        assert_ne!(a, SweepKey::new(&net, Scheme::Dense, &cfg, &opts2, &model));
        let cfg2 = AcceleratorConfig { tx: 8, ..cfg.clone() };
        assert_ne!(a, SweepKey::new(&net, Scheme::Dense, &cfg2, &opts, &model));
        // A different sparsity model (measured vs synthetic, same seed)
        // must never be served the synthetic result.
        let mut measured = std::collections::BTreeMap::new();
        measured.insert("relu1".to_string(), 0.5);
        let model2 = SparsityModel::measured(opts.seed, measured);
        assert_ne!(a, SweepKey::new(&net, Scheme::Dense, &cfg, &opts, &model2));
        // A structurally different network sharing the name must miss.
        let mut alias = crate::nn::Network::new("agos_cnn");
        let x = alias.input(3, 32, 32);
        let c = alias.conv("conv1", x, 8, 3, 1, 1);
        let r = alias.relu("relu1", c);
        alias.softmax("prob", r);
        assert_ne!(a, SweepKey::new(&alias, Scheme::Dense, &cfg, &opts, &model));
    }

    #[test]
    fn duplicate_combos_simulate_once() {
        let runner = SweepRunner::new(2);
        let cfg = AcceleratorConfig::default();
        let opts = small_opts();
        let model = SparsityModel::synthetic(opts.seed);
        let mut plan = SweepPlan::new();
        plan.push(zoo::agos_cnn(), Scheme::Dense, &cfg, &opts);
        plan.push(zoo::agos_cnn(), Scheme::Dense, &cfg, &opts);
        let out = runner.run(&plan, &model);
        assert_eq!(out.len(), 2);
        assert!(Arc::ptr_eq(&out[0], &out[1]), "duplicates must share one result");
        assert_eq!(runner.cache().misses(), 1);
        assert_eq!(runner.cache().hits(), 1);

        // A second run of the same plan is served entirely from cache.
        let again = runner.run(&plan, &model);
        assert_eq!(runner.cache().misses(), 1);
        assert_eq!(runner.cache().hits(), 3);
        assert!(Arc::ptr_eq(&again[0], &out[0]));
    }

    #[test]
    fn one_is_cached_and_matches_engine() {
        let runner = SweepRunner::new(1);
        let net = zoo::agos_cnn();
        let cfg = AcceleratorConfig::default();
        let opts = small_opts();
        let model = SparsityModel::synthetic(opts.seed);
        let a = runner.one(&net, &cfg, &opts, &model, Scheme::InOut);
        let b = runner.one(&net, &cfg, &opts, &model, Scheme::InOut);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(runner.cache().misses(), 1);
        let direct = simulate_network(&net, &cfg, &opts, &model, Scheme::InOut);
        assert_eq!(a.total_cycles(), direct.total_cycles());
        assert_eq!(a.total_energy_j(), direct.total_energy_j());
    }

    #[test]
    fn zero_jobs_resolves_to_host_parallelism() {
        assert!(SweepRunner::new(0).jobs >= 1);
        assert_eq!(SweepRunner::new(3).jobs, 3);
    }
}
