//! Energy model seeded with the paper's Table 1 component figures.
//!
//! Dynamic component energies are derived from the reported powers at the
//! design clock (e.g. 10.56 mW for 16 fp16 MACs at 667 MHz ⇒ ≈0.99 pJ per
//! MAC); SRAM access energy uses the CACTI per-line figures; static power
//! accrues over the makespan.

use crate::config::AcceleratorConfig;
use crate::util::json::Json;

/// Joules spent by one layer execution, by component.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub mac_j: f64,
    pub regfile_j: f64,
    pub adder_tree_j: f64,
    pub encoder_j: f64,
    pub sram_j: f64,
    pub dram_j: f64,
    pub static_j: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.mac_j
            + self.regfile_j
            + self.adder_tree_j
            + self.encoder_j
            + self.sram_j
            + self.dram_j
            + self.static_j
    }

    /// Every component scaled by `f` — the building block for deriving a
    /// foreign platform's breakdown from a measured one (the *mix* stays
    /// measured; the caller sets the total via the scale factor).
    pub fn scaled(&self, f: f64) -> EnergyBreakdown {
        EnergyBreakdown {
            mac_j: self.mac_j * f,
            regfile_j: self.regfile_j * f,
            adder_tree_j: self.adder_tree_j * f,
            encoder_j: self.encoder_j * f,
            sram_j: self.sram_j * f,
            dram_j: self.dram_j * f,
            static_j: self.static_j * f,
        }
    }

    pub fn add(&mut self, other: &EnergyBreakdown) {
        self.mac_j += other.mac_j;
        self.regfile_j += other.regfile_j;
        self.adder_tree_j += other.adder_tree_j;
        self.encoder_j += other.encoder_j;
        self.sram_j += other.sram_j;
        self.dram_j += other.dram_j;
        self.static_j += other.static_j;
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("mac_j", self.mac_j.into()),
            ("regfile_j", self.regfile_j.into()),
            ("adder_tree_j", self.adder_tree_j.into()),
            ("encoder_j", self.encoder_j.into()),
            ("sram_j", self.sram_j.into()),
            ("dram_j", self.dram_j.into()),
            ("static_j", self.static_j.into()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<EnergyBreakdown> {
        let f = |key: &str| {
            j.get(key)
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("energy breakdown field '{key}': f64"))
        };
        Ok(EnergyBreakdown {
            mac_j: f("mac_j")?,
            regfile_j: f("regfile_j")?,
            adder_tree_j: f("adder_tree_j")?,
            encoder_j: f("encoder_j")?,
            sram_j: f("sram_j")?,
            dram_j: f("dram_j")?,
            static_j: f("static_j")?,
        })
    }
}

/// Energy for one layer execution.
///
/// * `macs` — multiply-accumulates actually performed.
/// * `encoded_elems` — neurons run through the NZ encoder (once per
///   generated output map, §4.2).
/// * `sram_bytes` — operand bytes staged through the lane buffers.
/// * `dram_bytes` — off-chip traffic.
/// * `busy_cycles` — sum of per-PE busy cycles (dynamic window).
/// * `makespan_cycles` — node latency (static window).
pub fn layer_energy(
    cfg: &AcceleratorConfig,
    macs: f64,
    encoded_elems: f64,
    sram_bytes: f64,
    dram_bytes: f64,
    busy_cycles: f64,
    makespan_cycles: f64,
) -> EnergyBreakdown {
    let e = &cfg.energy;
    let lane_macs_per_cycle = cfg.lanes as f64;
    // Per-unit energies derived from Table 1 powers at the design clock.
    let e_mac = e.mac_power_w / (lane_macs_per_cycle * cfg.freq_hz);
    let e_reg = e.regfile_power_w / (lane_macs_per_cycle * cfg.freq_hz);
    let e_idx = e.idx_regfile_power_w / (lane_macs_per_cycle * cfg.freq_hz);
    let e_tree_cycle = e.adder_tree_power_w / cfg.freq_hz; // per PE busy cycle
    let e_enc = e.encoder_power_w / cfg.freq_hz; // per encoded group-cycle
    let line = cfg.memory.sram_line_bytes as f64;

    let static_w_node =
        (e.sram_static_w + e.control_power_w) * cfg.pe_count() as f64;

    EnergyBreakdown {
        mac_j: macs * e_mac,
        regfile_j: macs * (e_reg + e_idx),
        adder_tree_j: busy_cycles * e_tree_cycle,
        // encoder processes GROUP(32) elems/cycle
        encoder_j: encoded_elems / 32.0 * e_enc,
        sram_j: sram_bytes / line * (e.sram_read_j + e.sram_write_j * 0.5),
        dram_j: dram_bytes * e.dram_j_per_byte,
        static_j: makespan_cycles / cfg.freq_hz * static_w_node,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_mac_energy_matches_table1() {
        let cfg = AcceleratorConfig::default();
        let e = layer_energy(&cfg, 1e9, 0.0, 0.0, 0.0, 0.0, 0.0);
        // 10.56 mW / (16 MACs × 667 MHz) ≈ 0.99 pJ/MAC ⇒ 1e9 MACs ≈ 0.99 mJ
        assert!((e.mac_j - 0.99e-3).abs() < 0.05e-3, "{}", e.mac_j);
    }

    #[test]
    fn fewer_macs_less_energy() {
        let cfg = AcceleratorConfig::default();
        let dense = layer_energy(&cfg, 1e9, 1e6, 1e8, 1e8, 1e6, 1e6);
        let sparse = layer_energy(&cfg, 4e8, 1e6, 0.6e8, 0.6e8, 0.5e6, 0.6e6);
        assert!(sparse.total() < dense.total());
    }

    #[test]
    fn static_power_tracks_makespan() {
        let cfg = AcceleratorConfig::default();
        let fast = layer_energy(&cfg, 0.0, 0.0, 0.0, 0.0, 0.0, 1e6);
        let slow = layer_energy(&cfg, 0.0, 0.0, 0.0, 0.0, 0.0, 2e6);
        assert!((slow.static_j / fast.static_j - 2.0).abs() < 1e-9);
    }

    #[test]
    fn breakdown_json_roundtrips_bit_exact() {
        let cfg = AcceleratorConfig::default();
        let e = layer_energy(&cfg, 1e7, 1e5, 1e6, 1e6, 1e5, 1e5);
        let e2 = EnergyBreakdown::from_json(&Json::parse(&e.to_json().dump()).unwrap()).unwrap();
        assert_eq!(e, e2);
        assert!(EnergyBreakdown::from_json(&Json::obj()).is_err());
    }

    #[test]
    fn scaled_multiplies_every_component() {
        let cfg = AcceleratorConfig::default();
        let e = layer_energy(&cfg, 1e7, 1e5, 1e6, 1e6, 1e5, 1e5);
        let s = e.scaled(0.5);
        assert!((s.total() - 0.5 * e.total()).abs() < 1e-15);
        assert!((s.mac_j - 0.5 * e.mac_j).abs() < 1e-18);
        assert!((s.static_j - 0.5 * e.static_j).abs() < 1e-18);
    }

    #[test]
    fn breakdown_sums() {
        let cfg = AcceleratorConfig::default();
        let e = layer_energy(&cfg, 1e7, 1e5, 1e6, 1e6, 1e5, 1e5);
        let total = e.mac_j + e.regfile_j + e.adder_tree_j + e.encoder_j + e.sram_j + e.dram_j + e.static_j;
        assert!((e.total() - total).abs() < 1e-18);
        let mut acc = EnergyBreakdown::default();
        acc.add(&e);
        acc.add(&e);
        assert!((acc.total() - 2.0 * e.total()).abs() < 1e-15);
    }
}
