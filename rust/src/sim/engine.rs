//! Whole-network simulation, split into two stages:
//!
//! 1. **Task construction** ([`build_image_tasks`]) — pure: derives the
//!    per-(layer, phase) [`LayerTask`]s for one image from the graph and
//!    its sparsity analysis. No randomness, no ordering constraints.
//! 2. **Execution** ([`simulate_image`]) — stochastic: runs each task
//!    through the PE/tile/WDU models, drawing per-tile jitter from a
//!    *per-image* RNG stream derived from `(seed, image index)` only
//!    ([`image_stream`]).
//!
//! Because every image owns an independent derived stream, per-image
//! simulations are embarrassingly parallel and results are independent of
//! batch iteration order and thread count — the determinism contract the
//! parallel sweep executor (`sim::sweep`) is built on. Aggregation in
//! [`simulate_network`] always folds images in index order, so totals are
//! bit-identical however the work was scheduled.

use std::collections::BTreeMap;

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::nn::{Layer, LayerKind, Network, Phase};
use crate::sparsity::{analyze_network, LayerOpportunity, SparsityModel};
use crate::util::json::Json;
use crate::util::rng::{Pcg32, SplitMix64};

use super::backend::TaskGeom;
use super::energy::EnergyBreakdown;
use super::tile::factor2;
use super::layer_exec::{simulate_layer_replay, LayerSimResult, LayerTask};

/// Aggregated totals for one phase.
#[derive(Clone, Debug, Default)]
pub struct PhaseTotals {
    pub cycles: f64,
    pub dense_macs: f64,
    pub performed_macs: f64,
    pub energy: EnergyBreakdown,
}

/// One layer × phase entry aggregated over the batch.
#[derive(Clone, Debug)]
pub struct LayerAgg {
    pub name: String,
    pub phase: Phase,
    pub cycles: f64,
    pub dense_macs: f64,
    pub performed_macs: f64,
    /// Batch-mean tile utilization (avg/max, Fig 17 metric).
    pub tile_utilization: f64,
    /// Min/mean/max tile completion across tiles (batch-summed timeline).
    pub tile_min: f64,
    pub tile_mean: f64,
    pub tile_max: f64,
}

/// Result of simulating a network under one scheme.
#[derive(Clone, Debug)]
pub struct NetworkSimResult {
    pub network: String,
    pub scheme: Scheme,
    pub batch: usize,
    pub per_layer: Vec<LayerAgg>,
    pub totals: BTreeMap<&'static str, PhaseTotals>,
}

impl NetworkSimResult {
    pub fn phase(&self, phase: Phase) -> &PhaseTotals {
        &self.totals[phase.label()]
    }

    /// Total cycles across all three phases.
    pub fn total_cycles(&self) -> f64 {
        self.totals.values().map(|t| t.cycles).sum()
    }

    pub fn total_energy_j(&self) -> f64 {
        self.totals.values().map(|t| t.energy.total()).sum()
    }

    /// Component-wise energy summed across all three phases — the
    /// measured per-iteration [`EnergyBreakdown`] the platform
    /// comparison's simulator-consuming rows start from.
    pub fn energy_breakdown(&self) -> EnergyBreakdown {
        let mut acc = EnergyBreakdown::default();
        for t in self.totals.values() {
            acc.add(&t.energy);
        }
        acc
    }

    /// Wall-clock per training iteration at the configured frequency.
    pub fn iteration_seconds(&self, cfg: &AcceleratorConfig) -> f64 {
        self.total_cycles() / cfg.freq_hz
    }

    pub fn layer(&self, name: &str, phase: Phase) -> Option<&LayerAgg> {
        self.per_layer.iter().find(|l| l.name == name && l.phase == phase)
    }

    /// Serialize everything an aggregated result carries — the payload of
    /// the on-disk sweep cache (`sim::sweep`). f64 values survive the
    /// JSON round-trip bit-exactly (shortest-round-trip formatting).
    pub fn to_json(&self) -> Json {
        let mut totals = Json::obj();
        for (label, t) in &self.totals {
            totals.set(
                label,
                Json::from_pairs(vec![
                    ("cycles", t.cycles.into()),
                    ("dense_macs", t.dense_macs.into()),
                    ("performed_macs", t.performed_macs.into()),
                    ("energy", t.energy.to_json()),
                ]),
            );
        }
        let per_layer: Vec<Json> = self
            .per_layer
            .iter()
            .map(|l| {
                Json::from_pairs(vec![
                    ("name", l.name.as_str().into()),
                    ("phase", l.phase.label().into()),
                    ("cycles", l.cycles.into()),
                    ("dense_macs", l.dense_macs.into()),
                    ("performed_macs", l.performed_macs.into()),
                    ("tile_utilization", l.tile_utilization.into()),
                    ("tile_min", l.tile_min.into()),
                    ("tile_mean", l.tile_mean.into()),
                    ("tile_max", l.tile_max.into()),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("network", self.network.as_str().into()),
            ("scheme", self.scheme.label().into()),
            ("batch", self.batch.into()),
            ("totals", totals),
            ("per_layer", Json::Arr(per_layer)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<NetworkSimResult> {
        let f64_of = |j: &Json, key: &str| {
            j.get(key).as_f64().ok_or_else(|| anyhow::anyhow!("result field '{key}': f64"))
        };
        let network = j
            .get("network")
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("result.network"))?
            .to_string();
        let scheme = Scheme::parse(
            j.get("scheme").as_str().ok_or_else(|| anyhow::anyhow!("result.scheme"))?,
        )?;
        let batch =
            j.get("batch").as_usize().ok_or_else(|| anyhow::anyhow!("result.batch"))?;
        let mut totals: BTreeMap<&'static str, PhaseTotals> = BTreeMap::new();
        let tobj =
            j.get("totals").as_obj().ok_or_else(|| anyhow::anyhow!("result.totals"))?;
        for (label, t) in tobj {
            let phase = Phase::from_label(label)
                .ok_or_else(|| anyhow::anyhow!("unknown phase label '{label}'"))?;
            totals.insert(
                phase.label(),
                PhaseTotals {
                    cycles: f64_of(t, "cycles")?,
                    dense_macs: f64_of(t, "dense_macs")?,
                    performed_macs: f64_of(t, "performed_macs")?,
                    energy: EnergyBreakdown::from_json(t.get("energy"))?,
                },
            );
        }
        // Every phase must be present: `phase()` indexes the map, and a
        // truncated totals object would otherwise load as "good" data.
        for phase in Phase::ALL {
            anyhow::ensure!(
                totals.contains_key(phase.label()),
                "result.totals missing phase '{}'",
                phase.label()
            );
        }
        let mut per_layer = Vec::new();
        for l in j.get("per_layer").as_arr().ok_or_else(|| anyhow::anyhow!("per_layer"))? {
            let phase_label =
                l.get("phase").as_str().ok_or_else(|| anyhow::anyhow!("layer.phase"))?;
            per_layer.push(LayerAgg {
                name: l
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("layer.name"))?
                    .to_string(),
                phase: Phase::from_label(phase_label)
                    .ok_or_else(|| anyhow::anyhow!("unknown phase label '{phase_label}'"))?,
                cycles: f64_of(l, "cycles")?,
                dense_macs: f64_of(l, "dense_macs")?,
                performed_macs: f64_of(l, "performed_macs")?,
                tile_utilization: f64_of(l, "tile_utilization")?,
                tile_min: f64_of(l, "tile_min")?,
                tile_mean: f64_of(l, "tile_mean")?,
                tile_max: f64_of(l, "tile_max")?,
            });
        }
        Ok(NetworkSimResult { network, scheme, batch, per_layer, totals })
    }
}

/// Build the GEMM task a (layer, phase) pair puts on the accelerator.
///
/// Output-shape conventions follow §4.2: FP produces `[M,U,V]`; BP
/// produces the input gradient `[C,H,W]` (M and C swap roles); WG
/// produces `[M,C,R,S]` with the output map `U·V` as the reduction axis.
pub fn build_task(
    net: &Network,
    layer: &Layer,
    phase: Phase,
    opp: &LayerOpportunity,
) -> Option<LayerTask> {
    if !layer.kind.is_compute() {
        return None;
    }
    let in_shape = net.input_shape(layer.id);
    let out = layer.out;
    let (r, s) = match layer.kind {
        LayerKind::Conv { r, s, .. } => (r, s),
        LayerKind::DwConv { r, s, .. } => (r, s),
        LayerKind::Fc { .. } => (1, 1),
        _ => unreachable!(),
    };
    let weight_elems = match layer.kind {
        LayerKind::Conv { m, r, s, .. } => (m * in_shape.c * r * s) as f64,
        LayerKind::DwConv { r, s, .. } => (in_shape.c * r * s) as f64,
        LayerKind::Fc { out } => (out * in_shape.len()) as f64,
        _ => unreachable!(),
    };
    // Conv geometry for the replay gather (kernel, stride, padding and
    // whether the operand gather is per-channel). FC layers read their
    // whole input per output.
    let (stride, pad, dw) = match layer.kind {
        LayerKind::Conv { stride, pad, .. } => (stride, pad, false),
        LayerKind::DwConv { stride, pad, .. } => (stride, pad, true),
        LayerKind::Fc { .. } => (1, 0, false),
        _ => unreachable!(),
    };
    let task = match phase {
        Phase::Forward => {
            // FC outputs are a vector; spread them 2-D across the PE grid
            // (a [4096] map would otherwise land on a single PE tile).
            let (m, u, v, geom) = if matches!(layer.kind, LayerKind::Fc { .. }) {
                let (u, v) = factor2(out.c);
                (1, u, v, TaskGeom::Full)
            } else {
                (out.c, out.h, out.w, TaskGeom::Conv { r, s, stride, pad, dw })
            };
            LayerTask {
                name: layer.name.clone(),
                m,
                u,
                v,
                crs: layer.receptive_field(in_shape).unwrap() as f64,
                in_sparsity: opp.fp_input,
                out_sparsity: None, // output sparsity exists only in BP
                input_elems: in_shape.len() as f64,
                weight_elems,
                geom,
                op_chans: in_shape.c,
            }
        }
        Phase::Backward => {
            if !opp.has_bp {
                return None;
            }
            // Per-input-gradient work: the BP GEMM performs exactly the
            // forward pass's MAC pairings, so per-output work is the
            // forward total divided by the input-gradient element count
            // (= M·R·S/stride² on average for strided convs).
            let fwd_macs = crate::nn::layer_macs(net, layer, Phase::Forward) as f64;
            let crs = fwd_macs / in_shape.len() as f64;
            let (m, u, v, geom) = if matches!(layer.kind, LayerKind::Fc { .. }) {
                let (u, v) = factor2(in_shape.len());
                (1, u, v, TaskGeom::Full)
            } else {
                (
                    in_shape.c,
                    in_shape.h,
                    in_shape.w,
                    TaskGeom::ConvT { r, s, stride, pad, dw },
                )
            };
            LayerTask {
                name: layer.name.clone(),
                m,
                u,
                v,
                crs,
                in_sparsity: opp.bp_input,
                out_sparsity: opp.bp_output,
                input_elems: out.len() as f64, // incoming gradient map
                weight_elems,
                geom,
                op_chans: out.c, // BP gathers from the gradient map
            }
        }
        Phase::WeightGrad => {
            // dW[m, c, r, s] reduces over the U·V output positions; the
            // (c·r·s) weight plane is spread squarely across the PE grid.
            let (wm, wu, wv, crs, geom) = match layer.kind {
                LayerKind::Conv { m, .. } => {
                    let (u, v) = factor2(in_shape.c * r * s);
                    let geom =
                        TaskGeom::Wg { r, s, stride, pad, gu: out.h, gv: out.w, dw: false };
                    (m, u, v, out.h * out.w, geom)
                }
                LayerKind::DwConv { .. } => {
                    let geom =
                        TaskGeom::Wg { r, s, stride, pad, gu: out.h, gv: out.w, dw: true };
                    (in_shape.c, r, s, out.h * out.w, geom)
                }
                LayerKind::Fc { out: o } => {
                    let (u, v) = factor2(in_shape.len());
                    // dW[o, (c, h, w)]: the single "output position" pairs
                    // grad[o] with act[c, h, w] — a 1-position Wg whose
                    // kernel is the whole input plane.
                    let geom = TaskGeom::Wg {
                        r: in_shape.h,
                        s: in_shape.w,
                        stride: 1,
                        pad: 0,
                        gu: 1,
                        gv: 1,
                        dw: false,
                    };
                    (o, u, v, 1, geom)
                }
                _ => unreachable!(),
            };
            // Both operands (activations × gradients) can be sparse; a MAC
            // survives only when both are non-zero.
            let s_a = opp.wg_act.unwrap_or(0.0);
            let s_g = opp.wg_grad.unwrap_or(0.0);
            let joint = 1.0 - (1.0 - s_a) * (1.0 - s_g);
            LayerTask {
                name: layer.name.clone(),
                m: wm,
                u: wu,
                v: wv,
                crs: crs as f64,
                in_sparsity: (joint > 1e-9).then_some(joint),
                out_sparsity: None, // dW is dense
                input_elems: in_shape.len() as f64 + out.len() as f64,
                weight_elems: 0.0, // no weight streaming in WG
                geom,
                op_chans: in_shape.c, // unused: Wg pairs, it never gathers
            }
        }
    };
    Some(task)
}

/// One (layer, phase) unit of accelerator work for a single image —
/// the pure output of task construction.
#[derive(Clone, Debug)]
pub struct ImageTask {
    pub layer: String,
    pub phase: Phase,
    pub task: LayerTask,
}

/// Pure task construction: every `LayerTask` one image puts on the
/// accelerator, in deterministic (layer, phase) order. `fwd` is the
/// image's per-layer forward-sparsity assignment.
pub fn build_image_tasks(net: &Network, fwd: &[f64]) -> Vec<ImageTask> {
    let opps = analyze_network(net, fwd);
    let mut tasks = Vec::new();
    for opp in &opps {
        let layer = net.layer(opp.layer);
        for phase in Phase::ALL {
            if let Some(task) = build_task(net, layer, phase, opp) {
                tasks.push(ImageTask { layer: layer.name.clone(), phase, task });
            }
        }
    }
    tasks
}

/// Independent RNG stream for one image, derived from `(seed, image)`
/// only — *not* from any shared mutable generator. This is what makes
/// per-image simulations order-independent: image `k` draws the same
/// jitter sequence whether it runs first, last, or on another thread.
///
/// The per-image offset multiplier must NOT be SplitMix64's own
/// increment (0x9E37…7C15): with that constant, image `k+1`'s SplitMix
/// state equals image `k`'s state after one draw, so adjacent images'
/// (state, stream) words overlap instead of being independent.
pub fn image_stream(seed: u64, image: usize) -> Pcg32 {
    let mut sm = SplitMix64::new(
        (seed ^ 0x51AB).wrapping_add((image as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9)),
    );
    Pcg32::with_stream(sm.next_u64(), sm.next_u64())
}

/// Stochastic execution of one image's tasks; returns one result per
/// task, parallel to the input slice. `rng` should come from
/// [`image_stream`] with the same `image` index, so the draw sequence
/// belongs to this image alone. When `opts.replay` carries a bank, the
/// image replays its round-robin traced step (`image % steps`) — a pure
/// function of the index, so the per-image independence (and with it the
/// any-`--jobs` bit-identical contract) is untouched.
pub fn simulate_image(
    tasks: &[ImageTask],
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    scheme: Scheme,
    image: usize,
    rng: &mut Pcg32,
) -> Vec<LayerSimResult> {
    let step = opts.replay.as_deref().map(|bank| bank.step_maps(image));
    tasks
        .iter()
        .map(|t| {
            let maps = step.and_then(|s| s.task_maps(&t.layer, t.phase));
            simulate_layer_replay(&t.task, cfg, opts, scheme, maps, rng)
        })
        .collect()
}

/// Simulate a network for a whole batch under one scheme.
///
/// Equivalent to building and executing each image independently with its
/// derived stream, then aggregating in image order — which is exactly
/// what it does, so the result is reproducible bit-for-bit regardless of
/// how callers distribute images or (network, scheme) combos over
/// threads.
pub fn simulate_network(
    net: &Network,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    model: &SparsityModel,
    scheme: Scheme,
) -> NetworkSimResult {
    simulate_network_jobs(net, cfg, opts, model, scheme, 1)
}

/// [`simulate_network`] with per-image fan-out: up to `jobs` worker
/// threads simulate images concurrently. Because every image draws from
/// its own `(seed, image)`-derived stream and aggregation folds the
/// collected results in image-index order, the outcome is bit-identical
/// to the sequential engine at any `jobs` level — this is how the sweep
/// executor keeps cores busy when a plan has fewer combos than workers
/// (essential for the much slower exact backend).
pub fn simulate_network_jobs(
    net: &Network,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    model: &SparsityModel,
    scheme: Scheme,
    jobs: usize,
) -> NetworkSimResult {
    let batch_fwd = model.assign_batch(net, opts.batch);
    let n_images = batch_fwd.len();

    // Per-image (tasks, results), indexed by image so the fold below is
    // independent of completion order.
    let per_image = crate::util::pool::run_indexed(n_images, jobs, |image| {
        let tasks = build_image_tasks(net, &batch_fwd[image]);
        let mut rng = image_stream(opts.seed, image);
        let results = simulate_image(&tasks, cfg, opts, scheme, image, &mut rng);
        (tasks, results)
    });

    // name×phase → accumulated results, folded in image order.
    let mut agg: BTreeMap<(String, &'static str), Vec<LayerSimResult>> = BTreeMap::new();
    for (tasks, results) in per_image {
        for (t, r) in tasks.iter().zip(results) {
            agg.entry((t.layer.clone(), t.phase.label())).or_default().push(r);
        }
    }

    let mut per_layer = Vec::new();
    let mut totals: BTreeMap<&'static str, PhaseTotals> = BTreeMap::new();
    for phase in Phase::ALL {
        totals.insert(phase.label(), PhaseTotals::default());
    }
    for ((name, phase_label), results) in &agg {
        let phase = Phase::ALL.into_iter().find(|p| p.label() == *phase_label).unwrap();
        let cycles: f64 = results.iter().map(|r| r.cycles).sum();
        let dense: f64 = results.iter().map(|r| r.dense_macs).sum();
        let performed: f64 = results.iter().map(|r| r.performed_macs).sum();
        let util =
            results.iter().map(|r| r.tile_utilization()).sum::<f64>() / results.len() as f64;
        // Tile timeline summed over the batch (the per-layer Fig 17 view).
        let tiles = results[0].completion.len();
        let mut tile_total = vec![0.0f64; tiles];
        for r in results {
            for (t, c) in tile_total.iter_mut().zip(&r.completion) {
                *t += c;
            }
        }
        let busy: Vec<f64> = tile_total.iter().cloned().filter(|c| *c > 0.0).collect();
        let (tmin, tmax) = busy.iter().fold((f64::MAX, 0.0f64), |(lo, hi), &c| {
            (lo.min(c), hi.max(c))
        });
        let tmean =
            if busy.is_empty() { 0.0 } else { busy.iter().sum::<f64>() / busy.len() as f64 };

        per_layer.push(LayerAgg {
            name: name.clone(),
            phase,
            cycles,
            dense_macs: dense,
            performed_macs: performed,
            tile_utilization: util,
            tile_min: if busy.is_empty() { 0.0 } else { tmin },
            tile_mean: tmean,
            tile_max: tmax,
        });
        let t = totals.get_mut(phase_label).unwrap();
        t.cycles += cycles;
        t.dense_macs += dense;
        t.performed_macs += performed;
        for r in results {
            t.energy.add(&r.energy);
        }
    }

    NetworkSimResult {
        network: net.name.clone(),
        scheme,
        batch: opts.batch,
        per_layer,
        totals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    fn quick_opts() -> SimOptions {
        SimOptions { batch: 2, ..SimOptions::default() }
    }

    fn sim(net: &Network, scheme: Scheme) -> NetworkSimResult {
        let cfg = AcceleratorConfig::default();
        let model = SparsityModel::synthetic(11);
        simulate_network(net, &cfg, &quick_opts(), &model, scheme)
    }

    #[test]
    fn vgg_bp_speedup_in_paper_band() {
        let net = zoo::vgg16();
        let dc = sim(&net, Scheme::Dense);
        let wr = sim(&net, Scheme::InOutWr);
        let speedup = dc.phase(Phase::Backward).cycles / wr.phase(Phase::Backward).cycles;
        // Paper: BP speedups 1.69–5.43× across networks; VGG ~3–5×.
        assert!((1.6..5.6).contains(&speedup), "VGG BP speedup {speedup:.2}");
    }

    #[test]
    fn overall_speedup_ordering_and_band() {
        let net = zoo::vgg16();
        let dc = sim(&net, Scheme::Dense).total_cycles();
        let in_ = sim(&net, Scheme::In).total_cycles();
        let both = sim(&net, Scheme::InOut).total_cycles();
        let wr = sim(&net, Scheme::InOutWr).total_cycles();
        assert!(dc > in_ && in_ > both && both >= wr * 0.999);
        let overall = dc / wr;
        // Fig 15: overall ≈1.66–2.18× (FP+BP+WG all included).
        assert!((1.3..3.0).contains(&overall), "overall {overall:.2}");
    }

    #[test]
    fn bn_network_gets_no_bp_input_sparsity_gain() {
        // ResNet: IN scheme in BP ≈ DC in BP (BN re-densifies gradients);
        // all its BP gain must come from OUT.
        let net = zoo::resnet18();
        let dc = sim(&net, Scheme::Dense);
        let in_ = sim(&net, Scheme::In);
        let both = sim(&net, Scheme::InOut);
        let bp_dc = dc.phase(Phase::Backward).cycles;
        let bp_in = in_.phase(Phase::Backward).cycles;
        let bp_out = both.phase(Phase::Backward).cycles;
        let gain_in = bp_dc / bp_in;
        let gain_out = bp_dc / bp_out;
        assert!(gain_in < 1.15, "IN-only BP gain on ResNet {gain_in:.2}");
        assert!(gain_out > 1.2, "IN+OUT BP gain on ResNet {gain_out:.2}");
    }

    #[test]
    fn dense_macs_match_flops_module() {
        let net = zoo::mobilenet_v1();
        let r = sim(&net, Scheme::Dense);
        let batch = quick_opts().batch as f64;
        for phase in Phase::ALL {
            let expect: u64 = net
                .layers()
                .iter()
                .map(|l| crate::nn::layer_macs(&net, l, phase))
                .sum();
            let got = r.phase(phase).dense_macs / batch;
            let expect = expect as f64;
            assert!(
                (got - expect).abs() / expect.max(1.0) < 1e-9,
                "{}: {got} vs {expect}",
                phase.label()
            );
        }
    }

    #[test]
    fn per_layer_entries_cover_compute_layers() {
        let net = zoo::googlenet();
        let r = sim(&net, Scheme::InOutWr);
        let fp_layers: Vec<_> =
            r.per_layer.iter().filter(|l| l.phase == Phase::Forward).collect();
        assert_eq!(fp_layers.len(), net.compute_layers().len());
        // first compute layer has no BP entry
        let first = &net.compute_layers()[0].name;
        assert!(r.layer(first, Phase::Backward).is_none());
        assert!(r.layer(first, Phase::WeightGrad).is_some());
    }

    #[test]
    fn energy_drops_with_sparsity() {
        let net = zoo::resnet18();
        let dc = sim(&net, Scheme::Dense).total_energy_j();
        let wr = sim(&net, Scheme::InOutWr).total_energy_j();
        assert!(wr < dc, "energy {wr} !< {dc}");
    }

    #[test]
    fn build_task_registers_the_replay_geometry() {
        let net = zoo::agos_cnn();
        let model = SparsityModel::synthetic(1);
        let fwd = model.assign(&net);
        let tasks = build_image_tasks(&net, &fwd);
        let find = |name: &str, phase: Phase| {
            tasks
                .iter()
                .find(|t| t.layer == name && t.phase == phase)
                .unwrap_or_else(|| panic!("{name} {phase:?}"))
        };
        // conv2: 3x3 stride-2 pad-1 — FP gathers, BP transposes, WG pairs.
        assert_eq!(
            find("conv2", Phase::Forward).task.geom,
            TaskGeom::Conv { r: 3, s: 3, stride: 2, pad: 1, dw: false }
        );
        assert_eq!(
            find("conv2", Phase::Backward).task.geom,
            TaskGeom::ConvT { r: 3, s: 3, stride: 2, pad: 1, dw: false }
        );
        // conv2 reads relu1's 32x32 map and writes 16x16: the WG pair
        // reduces over the 16x16 forward output positions.
        assert_eq!(
            find("conv2", Phase::WeightGrad).task.geom,
            TaskGeom::Wg { r: 3, s: 3, stride: 2, pad: 1, gu: 16, gv: 16, dw: false }
        );
        // fc reads the whole flattened input; its WG kernel is the plane.
        assert_eq!(find("fc", Phase::Forward).task.geom, TaskGeom::Full);
        assert_eq!(
            find("fc", Phase::WeightGrad).task.geom,
            TaskGeom::Wg { r: 1, s: 1, stride: 1, pad: 0, gu: 1, gv: 1, dw: false }
        );
        // Depthwise convs gather per-channel.
        let mnet = zoo::mobilenet_v1();
        let mfwd = model.assign(&mnet);
        let mtasks = build_image_tasks(&mnet, &mfwd);
        let dwt = mtasks
            .iter()
            .find(|t| {
                t.phase == Phase::Forward
                    && matches!(t.task.geom, TaskGeom::Conv { dw: true, .. })
            })
            .expect("mobilenet has depthwise convs");
        assert!(matches!(dwt.task.geom, TaskGeom::Conv { r: 3, s: 3, .. }));
    }

    #[test]
    fn image_streams_are_independent_and_reproducible() {
        let mut a = image_stream(7, 0);
        let mut a2 = image_stream(7, 0);
        let mut b = image_stream(7, 1);
        let va: Vec<u32> = (0..8).map(|_| a.next_u32()).collect();
        let va2: Vec<u32> = (0..8).map(|_| a2.next_u32()).collect();
        let vb: Vec<u32> = (0..8).map(|_| b.next_u32()).collect();
        assert_eq!(va, va2, "same (seed, image) must give the same stream");
        assert_ne!(va, vb, "different images must get distinct streams");
    }

    #[test]
    fn engine_equals_per_image_composition() {
        // The whole-batch engine must be exactly the fold of independent
        // per-image simulations (the parallelism contract).
        let net = zoo::agos_cnn();
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 3, ..SimOptions::default() };
        let model = SparsityModel::synthetic(11);
        let engine = simulate_network(&net, &cfg, &opts, &model, Scheme::InOutWr);

        let batch = model.assign_batch(&net, opts.batch);
        let mut cycles: BTreeMap<(String, &'static str), Vec<f64>> = BTreeMap::new();
        for (image, fwd) in batch.iter().enumerate() {
            let tasks = build_image_tasks(&net, fwd);
            let mut rng = image_stream(opts.seed, image);
            let results = simulate_image(&tasks, &cfg, &opts, Scheme::InOutWr, image, &mut rng);
            for (t, r) in tasks.iter().zip(&results) {
                cycles.entry((t.layer.clone(), t.phase.label())).or_default().push(r.cycles);
            }
        }
        assert_eq!(cycles.len(), engine.per_layer.len());
        for l in &engine.per_layer {
            let sum: f64 = cycles[&(l.name.clone(), l.phase.label())].iter().sum();
            assert_eq!(sum, l.cycles, "{} {}", l.name, l.phase.label());
        }
    }

    #[test]
    fn per_image_fanout_is_bit_identical_to_sequential() {
        let net = zoo::agos_cnn();
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 5, ..SimOptions::default() };
        let model = SparsityModel::synthetic(17);
        for scheme in [Scheme::Dense, Scheme::InOutWr] {
            let seq = simulate_network(&net, &cfg, &opts, &model, scheme);
            let par = simulate_network_jobs(&net, &cfg, &opts, &model, scheme, 4);
            assert_eq!(seq.total_cycles(), par.total_cycles());
            assert_eq!(seq.total_energy_j(), par.total_energy_j());
            assert_eq!(seq.per_layer.len(), par.per_layer.len());
            for (a, b) in seq.per_layer.iter().zip(&par.per_layer) {
                assert_eq!(a.cycles, b.cycles, "{} {}", a.name, a.phase.label());
                assert_eq!(a.performed_macs, b.performed_macs, "{}", a.name);
                assert_eq!(a.tile_mean, b.tile_mean, "{}", a.name);
            }
        }
    }

    #[test]
    fn gather_plans_never_change_a_replayed_result() {
        // The perf campaign's hard contract: the gather-plan cache (with
        // zero-skip and the all-ones short circuit) is pure execution
        // strategy. A replayed exact-backend simulation must produce
        // bit-identical per-layer results with plans on (default cache),
        // plans without zero-skip, and no plans at all — sequentially
        // and under parallel fan-out.
        use std::sync::Arc;
        use crate::config::BitmapPattern;
        use crate::sim::GatherPlanCache;
        use crate::sparsity::capture_synthetic_trace;
        let net = zoo::agos_cnn();
        let cfg = AcceleratorConfig::default();
        let model = SparsityModel::synthetic(19);
        let trace = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Blobs, 2);
        let bank = Arc::new(crate::sim::ReplayBank::from_trace(&net, &trace).unwrap());
        let base = SimOptions {
            batch: 3,
            backend: crate::sim::ExecBackend::Exact,
            replay: Some(bank),
            trace_fingerprint: Some(trace.fingerprint()),
            ..SimOptions::default()
        };
        let variants = [
            SimOptions { gather_plans: None, ..base.clone() },
            SimOptions {
                gather_plans: Some(Arc::new(GatherPlanCache::plans_only())),
                ..base.clone()
            },
            base.clone(), // default cache: plans + zero-skip
        ];
        let reference = simulate_network(&net, &cfg, &variants[0], &model, Scheme::InOutWr);
        for (i, opts) in variants.iter().enumerate() {
            for jobs in [1usize, 4] {
                let r = simulate_network_jobs(&net, &cfg, opts, &model, Scheme::InOutWr, jobs);
                assert_eq!(
                    r.total_cycles(),
                    reference.total_cycles(),
                    "variant {i} jobs {jobs}"
                );
                assert_eq!(r.total_energy_j(), reference.total_energy_j());
                for (a, b) in r.per_layer.iter().zip(&reference.per_layer) {
                    assert_eq!(a.cycles, b.cycles, "variant {i} {} {}", a.name, a.phase.label());
                    assert_eq!(a.performed_macs, b.performed_macs, "variant {i} {}", a.name);
                }
            }
        }
        // The default cache did real planned work on this workload.
        let cache = base.gather_plans.as_ref().unwrap();
        assert!(!cache.is_empty(), "replayed convs must have built plans");
        assert!(cache.stats().words_gathered > 0);
    }

    #[test]
    fn result_json_roundtrips_bit_exact() {
        let net = zoo::agos_cnn();
        let r = sim(&net, Scheme::InOutWr);
        let text = r.to_json().pretty();
        let r2 = NetworkSimResult::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(r.network, r2.network);
        assert_eq!(r.scheme, r2.scheme);
        assert_eq!(r.batch, r2.batch);
        assert_eq!(r.total_cycles(), r2.total_cycles());
        assert_eq!(r.total_energy_j(), r2.total_energy_j());
        assert_eq!(r.per_layer.len(), r2.per_layer.len());
        for (a, b) in r.per_layer.iter().zip(&r2.per_layer) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.phase, b.phase);
            assert_eq!(a.cycles, b.cycles);
            assert_eq!(a.tile_utilization, b.tile_utilization);
        }
    }

    #[test]
    fn image_results_do_not_depend_on_batch_order() {
        let net = zoo::agos_cnn();
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 2, ..SimOptions::default() };
        let model = SparsityModel::synthetic(3);
        let batch = model.assign_batch(&net, opts.batch);
        let t0 = build_image_tasks(&net, &batch[0]);
        let t1 = build_image_tasks(&net, &batch[1]);

        // Image 1 simulated cold vs. after image 0: identical draws.
        let alone =
            simulate_image(&t1, &cfg, &opts, Scheme::InOutWr, 1, &mut image_stream(opts.seed, 1));
        let _ =
            simulate_image(&t0, &cfg, &opts, Scheme::InOutWr, 0, &mut image_stream(opts.seed, 0));
        let after =
            simulate_image(&t1, &cfg, &opts, Scheme::InOutWr, 1, &mut image_stream(opts.seed, 1));
        assert_eq!(alone.len(), after.len());
        for (a, b) in alone.iter().zip(&after) {
            assert_eq!(a.cycles, b.cycles, "{}", a.name);
            assert_eq!(a.performed_macs, b.performed_macs, "{}", a.name);
        }
    }
}
