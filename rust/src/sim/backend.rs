//! Pluggable execution backends: how one tile's worth of outputs is
//! costed.
//!
//! * [`ExecBackend::Analytic`] — the expected-value `PeModel` path
//!   (`sim::pe`): per-output cycles from closed-form lane-maximum
//!   statistics, per-tile sparsity jitter on top. Fast; what every
//!   production figure used before this abstraction existed.
//! * [`ExecBackend::Exact`] — the bitmap-driven `ExactPe` path
//!   (`sim::exact`). Where each tile's operand/output patterns come from
//!   is a [`BitmapSource`]:
//!   - [`BitmapSource::Sampled`] — drawn from the tile's (jittered)
//!     density via the per-image RNG stream, iid or spatially-blobbed
//!     (`BitmapPattern`);
//!   - [`BitmapSource::Streamed`] — a contiguous streaming slice out of a
//!     *captured* map (`sim::replay`), the legacy `--gather streaming`
//!     anchoring: pattern-exact in zero-run structure, geometry-collapsed;
//!   - [`BitmapSource::Gathered`] — the geometry-exact strided
//!     receptive-field gather: every output assembles exactly the operand
//!     bits its (kernel × stride × padding)-mapped input coordinates
//!     name, per [`TaskGeom`];
//!   - [`BitmapSource::Pair`] — the weight-gradient joint operand: the
//!     producer-ReLU activation window ANDed position-by-position with
//!     the consumer-ReLU gradient map, so the dominant WG phase replays
//!     instead of sampling.
//!
//! Both backends draw exclusively from the per-image stream handed down
//! by `engine::simulate_image` (replayed slices draw nothing at all), so
//! the PR 1 determinism contract (bit-identical results at any `--jobs`
//! level) holds for every source.

use std::collections::HashMap;

use crate::config::BitmapPattern;
use crate::nn::Shape;
use crate::sparsity::{or_bits, Bitmap, RunIndex};
use crate::util::rng::Pcg32;

use super::exact::{ExactOutput, ExactPe, OperandPattern};
use super::plan::{GatherPlanCache, PlannedGather, SkipStats};

/// How a task's outputs map onto captured operand bitmaps — the conv
/// geometry that turns a replayed map into per-output operand patterns.
/// Built by `engine::build_task` from the layer's kind and phase; only
/// consulted when the task actually replays (`sim::replay`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum TaskGeom {
    /// No registered geometry: replayed operand windows fall back to the
    /// streaming-slice anchoring ([`BitmapSource::Streamed`]).
    #[default]
    Streaming,
    /// Forward conv: output `(y, x)` reads the `r × s` window anchored at
    /// `(y·stride − pad, x·stride − pad)` of the operand map, across all
    /// operand channels (`dw`: only the output's own channel).
    Conv { r: usize, s: usize, stride: usize, pad: usize, dw: bool },
    /// Backward conv (input-gradient): the transposed gather — the
    /// input-gradient at `(y, x)` reads exactly the gradient taps
    /// `u = (y + pad − i)/stride, i ∈ [0, r)` that are integral, which
    /// collapse to one contiguous `≤⌈r/stride⌉ × ⌈s/stride⌉` window of
    /// the gradient map, across all `m` gradient channels (`dw`: only the
    /// output's own channel).
    ConvT { r: usize, s: usize, stride: usize, pad: usize, dw: bool },
    /// Fully-connected: every output reads the entire operand map.
    Full,
    /// Weight gradient: output `(m, c, i, j)` reduces over the forward
    /// output map's `gu × gv` positions; the joint operand at `(u, v)` is
    /// `grad[m, u, v] ∧ act[c, u·stride − pad + i, v·stride − pad + j]`
    /// (`dw`: act and grad both use the output's own channel). `gu`/`gv`
    /// are carried here so a pair with only one captured side still knows
    /// its reduction extent.
    Wg { r: usize, s: usize, stride: usize, pad: usize, gu: usize, gv: usize, dw: bool },
}

impl TaskGeom {
    /// Does this geometry describe an FP/BP operand window the
    /// geometry-exact gather can assemble (vs the streaming fallback)?
    pub fn gathers(&self) -> bool {
        matches!(self, TaskGeom::Conv { .. } | TaskGeom::ConvT { .. } | TaskGeom::Full)
    }
}

/// Which execution model costs the tiles of a simulation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ExecBackend {
    /// Analytic expected-value `PeModel` (the fast default).
    #[default]
    Analytic,
    /// Cycle-accurate `ExactPe` over sampled or replayed bitmaps.
    Exact,
}

impl ExecBackend {
    pub const ALL: [ExecBackend; 2] = [ExecBackend::Analytic, ExecBackend::Exact];

    pub fn label(&self) -> &'static str {
        match self {
            ExecBackend::Analytic => "analytic",
            ExecBackend::Exact => "exact",
        }
    }

    /// Stable tag folded into `SimOptions::fingerprint` (sweep-cache key).
    pub fn tag(&self) -> u64 {
        match self {
            ExecBackend::Analytic => 1,
            ExecBackend::Exact => 2,
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<ExecBackend> {
        match s.to_ascii_lowercase().as_str() {
            "analytic" | "model" => Ok(ExecBackend::Analytic),
            "exact" | "bitmap" => Ok(ExecBackend::Exact),
            other => anyhow::bail!("unknown backend '{other}' (analytic|exact)"),
        }
    }
}

/// Where a tile's bit patterns come from.
#[derive(Clone, Copy, Debug)]
pub enum BitmapSource<'a> {
    /// Draw from the per-image stream at the given non-zero `density`,
    /// with the configured spatial correlation.
    Sampled { density: f64, pattern: BitmapPattern, blob_radius: usize },
    /// Slice real patterns out of a captured map — no RNG involvement.
    /// For operands this is the contiguous streaming-slice window
    /// (`--gather streaming`, and the fallback for geometry-less tasks);
    /// for output masks it is always the exact per-position slice.
    Streamed { map: &'a Bitmap },
    /// Geometry-exact operand gather: assemble each output's true
    /// strided receptive field from the captured map per `geom`. `runs`
    /// is the map's optional word-run structure (`sparsity::RunIndex`),
    /// consulted only as an execution strategy — planned gathers skip
    /// all-zero source words and short-circuit all-ones windows through
    /// it, without changing a single assembled bit.
    Gathered { map: &'a Bitmap, geom: TaskGeom, runs: Option<&'a RunIndex> },
    /// Weight-gradient joint operand: `act ∧ grad` over the reduction
    /// positions (`TaskGeom::Wg`). A missing side is structurally dense
    /// (e.g. conv1's activations are the raw image).
    Pair { act: Option<&'a Bitmap>, grad: Option<&'a Bitmap>, geom: TaskGeom },
}

/// One PE tile's place in a task's output map: tile `index` owns the
/// half-open spatial `window` `(r0, r1, c0, c1)` of the full `u × v` map
/// and computes all `m` channels of it (`sim::tile::tile_windows`).
#[derive(Clone, Copy, Debug)]
pub struct TileGeom {
    pub index: usize,
    pub m: usize,
    pub u: usize,
    pub v: usize,
    pub window: (usize, usize, usize, usize),
}

impl TileGeom {
    pub fn spatial_outputs(&self) -> usize {
        let (r0, r1, c0, c1) = self.window;
        (r1 - r0) * (c1 - c0)
    }

    pub fn outputs(&self) -> usize {
        self.m * self.spatial_outputs()
    }

    /// Coordinates of the tile's `j`-th output in channel-major drain
    /// order: all spatial positions of channel 0, then channel 1, …
    #[inline]
    fn coords(&self, j: usize) -> (usize, usize, usize) {
        let (r0, _, c0, c1) = self.window;
        let sp = self.spatial_outputs();
        let cols = c1 - c0;
        let rem = j % sp;
        (j / sp, r0 + rem / cols, c0 + rem % cols)
    }
}

/// Start bit of output `j`'s operand window inside a replayed map — the
/// legacy streaming-slice anchoring (`--gather streaming`, and the
/// fallback for tasks with no registered [`TaskGeom`]).
///
/// The window is anchored at the output's spatial position scaled into
/// the operand map's plane (a conv output at `(y, x)` reads a receptive
/// field around the corresponding input location) and runs `crs` bits in
/// within-channel streaming order, wrapping through the channels — so
/// adjacent outputs get overlapping, spatially-local windows and *every
/// channel at one position reads the same window*, exactly as the dense
/// BP/FP GEMM pairs operands. Purely arithmetic: replay costs no RNG
/// state, which is what keeps `--replay` runs bit-identical at any
/// `--jobs` level.
#[inline]
fn operand_window_start(geom: &TileGeom, j: usize, map: &Bitmap) -> usize {
    let (_, y, x) = geom.coords(j);
    let (mh, mw) = (map.shape.h, map.shape.w);
    let yy = ((y * mh) / geom.u.max(1)).min(mh.saturating_sub(1));
    let xx = ((x * mw) / geom.v.max(1)).min(mw.saturating_sub(1));
    yy * mw + xx
}

/// Geometry-exact operand pattern of one output at tile coordinates
/// `(ch, y, x)`: assemble exactly the operand bits the task geometry
/// maps that output to. Returns the pattern length in bits — `0` for a
/// structurally empty window (a strided-BP position no gradient tap
/// reaches), which the caller costs as zero cycles and zero MACs.
pub(crate) fn gather_operand_words(
    map: &Bitmap,
    tg: TaskGeom,
    ch: usize,
    y: usize,
    x: usize,
    out: &mut Vec<u64>,
) -> usize {
    match tg {
        TaskGeom::Conv { r, s, stride, pad, dw } => {
            let ay = (y * stride) as isize - pad as isize;
            let ax = (x * stride) as isize - pad as isize;
            let (c0, c1) = if dw { (ch, ch + 1) } else { (0, map.shape.c) };
            map.gather_window_words(c0, c1, ay, ax, r, s, out)
        }
        TaskGeom::ConvT { r, s, stride, pad, dw } => {
            // Valid taps u satisfy u·stride − pad + i = y for some
            // i ∈ [0, r): a contiguous run of gradient-map rows, computed
            // with floor division so negative anchors stay exact.
            let sd = stride.max(1) as isize;
            let (yp, xp) = ((y + pad) as isize, (x + pad) as isize);
            let u_min = (yp - r as isize).div_euclid(sd) + 1;
            let u_max = yp.div_euclid(sd);
            let v_min = (xp - s as isize).div_euclid(sd) + 1;
            let v_max = xp.div_euclid(sd);
            if u_max < u_min || v_max < v_min {
                out.clear();
                return 0;
            }
            let (c0, c1) = if dw { (ch, ch + 1) } else { (0, map.shape.c) };
            map.gather_window_words(
                c0,
                c1,
                u_min,
                v_min,
                (u_max - u_min + 1) as usize,
                (v_max - v_min + 1) as usize,
                out,
            )
        }
        TaskGeom::Full => {
            out.clear();
            out.extend_from_slice(map.words());
            map.shape.len()
        }
        TaskGeom::Streaming | TaskGeom::Wg { .. } => {
            unreachable!("gathered operands need a window geometry")
        }
    }
}

/// All-ones mask of `n` bits (`1 <= n <= 64`).
#[inline]
fn ones(n: usize) -> u64 {
    if n == 64 {
        !0
    } else {
        (1u64 << n) - 1
    }
}

/// `n` activation taps along one map row for the WG joint pattern: tap
/// `t` reads column `(v0 + t)·sd + off` of row `ya`, channel `ca`;
/// out-of-bounds taps are zero. Stride-1 rows are one word extract;
/// strided rows do a gather-stride-aware word walk — each covering
/// source word is read once and its resident taps are selected in
/// registers, so no per-tap address arithmetic or bounds test survives
/// in the loop (the last per-bit loop on the replay path, pinned
/// against the per-tap reference walk by `strided_act_rows_match_the_
/// per_tap_reference`).
fn act_row_bits(
    a: &Bitmap,
    ca: usize,
    ya: isize,
    v0: usize,
    n: usize,
    sd: usize,
    off: isize,
) -> u64 {
    if ya < 0 || ya >= a.shape.h as isize {
        return 0;
    }
    let y = ya as usize;
    let w = a.shape.w as isize;
    let x0 = (v0 * sd) as isize + off;
    if sd == 1 {
        let lo = x0.max(0);
        let hi = (x0 + n as isize).min(w);
        if lo >= hi {
            return 0;
        }
        let bits = a.extract_bits(a.index(ca, y, lo as usize), (hi - lo) as usize);
        return bits << (lo - x0) as usize;
    }
    // Clamp the tap range to the in-bounds columns: tap `t` reads column
    // `x0 + t·sd`, so the first/last valid taps bracket `[0, w)`.
    let sd_i = sd as isize;
    let t_lo = if x0 >= 0 { 0 } else { (-x0 + sd_i - 1) / sd_i };
    let t_hi = (w - 1 - x0).div_euclid(sd_i).min(n as isize - 1);
    if t_lo > t_hi {
        return 0;
    }
    let row_base = a.index(ca, y, 0) as isize;
    let words = a.words();
    let mut bits = 0u64;
    let mut t = t_lo;
    while t <= t_hi {
        let bit = (row_base + x0 + t * sd_i) as usize;
        let (wi, mut sh) = (bit / 64, bit % 64);
        let w64 = words[wi];
        // Consume every tap resident in this source word.
        while t <= t_hi && sh < 64 {
            bits |= ((w64 >> sh) & 1) << (t as usize);
            t += 1;
            sh += sd;
        }
    }
    bits
}

/// One weight-gradient output's joint operand pattern over the `gu × gv`
/// reduction positions: bit `(u, v)` is
/// `grad[cg, u, v] ∧ act[ca, u·sd + ki − pad, v·sd + kj − pad]`, with a
/// missing side structurally dense and out-of-map activation taps zero
/// (they are the conv's padding). Word-level: gradient rows extract in
/// ≤64-bit runs, activation rows through [`act_row_bits`].
#[allow(clippy::too_many_arguments)]
fn pair_pattern_words(
    act: Option<&Bitmap>,
    grad: Option<&Bitmap>,
    cg: usize,
    ca: usize,
    ki: usize,
    kj: usize,
    sd: usize,
    pad: usize,
    gu: usize,
    gv: usize,
    out: &mut Vec<u64>,
) -> usize {
    let len = gu * gv;
    out.clear();
    out.resize(len.div_ceil(64), 0);
    let off = kj as isize - pad as isize;
    let mut pos = 0usize;
    for u in 0..gu {
        let ya = (u * sd + ki) as isize - pad as isize;
        let mut v0 = 0usize;
        while v0 < gv {
            let n = (gv - v0).min(64);
            let gbits = match grad {
                Some(g) => g.extract_bits(g.index(cg, u, v0), n),
                None => ones(n),
            };
            let abits = match act {
                Some(a) => act_row_bits(a, ca, ya, v0, n, sd, off),
                None => ones(n),
            };
            or_bits(out, pos, gbits & abits, n);
            pos += n;
            v0 += n;
        }
    }
    len
}

/// Sample one operand pattern (packed) into `out`. Degenerate densities
/// are draw-free, preserving the old `sample_pattern` contract.
fn sample_pattern_words(
    crs: usize,
    density: f64,
    pattern: BitmapPattern,
    blob_radius: usize,
    rng: &mut Pcg32,
    out: &mut Vec<u64>,
) {
    out.clear();
    out.resize(crs.div_ceil(64), 0);
    if density <= 0.0 {
        return;
    }
    if density >= 1.0 {
        out.fill(!0);
        let tail = crs % 64;
        if tail > 0 {
            *out.last_mut().unwrap() &= (1u64 << tail) - 1;
        }
        return;
    }
    match pattern {
        BitmapPattern::Iid => {
            for i in 0..crs {
                if rng.bernoulli(density) {
                    out[i / 64] |= 1 << (i % 64);
                }
            }
        }
        BitmapPattern::Blobs => {
            let b = Bitmap::sample_blobs(Shape::new(1, 1, crs), density, blob_radius, rng);
            out.copy_from_slice(b.words());
        }
    }
}

/// Exact cost of one PE tile (`geom`) with receptive field `crs`, its
/// operand and output patterns pulled from the given sources.
///
/// Up to `max_sampled` outputs get a real pattern; the total is scaled
/// to the tile's full output count (`n_out <= max_sampled` simulates the
/// tile output-exactly). Subsampled replayed tiles *stride* their k
/// simulated outputs evenly across the whole output range (`i·n/k`), not
/// the first k — the first k in channel-major order would be the lowest
/// channels only, and real maps' density varies by channel, which would
/// bias the scaled estimate. The output mask is resolved first, before
/// any operand streams — the Fig 5c bitmap is known a priori in DRAM —
/// and a masked output costs zero cycles *and zero pattern work* (its
/// operands are never drawn or sliced). Everything drains word-level
/// through [`ExactPe::simulate_output_words`]; no per-lane bool vectors
/// exist on this path.
///
/// Returns `(cycles, macs)` as the engine's f64 accounting expects.
///
/// `plans` is the optional shared gather-plan cache (`sim::plan`): with
/// it, windowed replayed gathers run plan-driven — precomputed segment
/// schedules, RLE-run zero-skip, all-ones dense short-circuit — instead
/// of re-deriving the window math per output. Strictly an execution
/// strategy: `planned_gathers_cost_identically_to_direct` pins that
/// `Some` vs `None` never changes a returned cycle or MAC, and the
/// cache participates in no fingerprint.
pub fn exact_tile_cost(
    pe: &ExactPe,
    crs: usize,
    geom: &TileGeom,
    max_sampled: usize,
    operands: &BitmapSource<'_>,
    outputs: &BitmapSource<'_>,
    plans: Option<&GatherPlanCache>,
    rng: &mut Pcg32,
) -> (f64, f64) {
    let n_out = geom.outputs();
    if n_out == 0 {
        return (0.0, 0.0);
    }
    let k = n_out.min(max_sampled.max(1));
    // Representative i-th output when subsampling (identity at k == n_out;
    // distinct and strictly increasing for k <= n_out).
    let pick = |i: usize| i * n_out / k;

    // Output mask for the k simulated outputs, packed.
    let mut mask = vec![0u64; k.div_ceil(64)];
    match outputs {
        BitmapSource::Sampled { density, pattern, blob_radius } => {
            let shape = Shape::new(1, 1, k);
            let b = match pattern {
                BitmapPattern::Iid => Bitmap::sample(shape, *density, rng),
                BitmapPattern::Blobs => Bitmap::sample_blobs(shape, *density, *blob_radius, rng),
            };
            mask.copy_from_slice(b.words());
        }
        BitmapSource::Streamed { map } => {
            debug_assert_eq!(map.shape, Shape::new(geom.m, geom.u, geom.v));
            for i in 0..k {
                let (ch, y, x) = geom.coords(pick(i));
                if map.get(ch, y, x) {
                    mask[i / 64] |= 1 << (i % 64);
                }
            }
        }
        BitmapSource::Gathered { .. } | BitmapSource::Pair { .. } => {
            unreachable!("output masks are sliced, not gathered")
        }
    }

    let scale = n_out as f64 / k as f64;

    // FC fast path: under `Full` geometry every output reads the entire
    // operand map, so one PE walk prices all unmasked outputs — running
    // it per output would redo an identical word walk up to `k` times.
    if let BitmapSource::Gathered { map, geom: TaskGeom::Full, .. } = operands {
        let res = pe.simulate_output_words(map.words(), map.shape.len());
        let live: u64 = mask.iter().map(|w| w.count_ones() as u64).sum();
        return ((live * res.cycles) as f64 * scale, (live * res.macs) as f64 * scale);
    }

    // Resolve the reusable gather plan once per tile — every output of a
    // windowed replayed gather shares one precomputed segment schedule.
    let planned = match (plans, operands) {
        (Some(cache), BitmapSource::Gathered { map, geom: tg, .. })
            if matches!(tg, TaskGeom::Conv { .. } | TaskGeom::ConvT { .. }) =>
        {
            cache.plan_for(map.shape, *tg, geom.u, geom.v).map(|p| (p, cache))
        }
        _ => None,
    };
    let mut stats = SkipStats::default();
    let mut dense_memo: HashMap<usize, ExactOutput> = HashMap::new();

    let mut cycles = 0u64;
    let mut macs = 0u64;
    let mut scratch: Vec<u64> = Vec::new();
    for i in 0..k {
        if (mask[i / 64] >> (i % 64)) & 1 == 0 {
            continue; // skipped a priori — zero cycles (Fig 5c)
        }
        let len = match operands {
            BitmapSource::Sampled { density, pattern, blob_radius } => {
                sample_pattern_words(crs, *density, *pattern, *blob_radius, rng, &mut scratch);
                crs
            }
            BitmapSource::Streamed { map } => {
                let start = operand_window_start(geom, pick(i), map);
                map.window_words_into(start, crs, &mut scratch);
                crs
            }
            BitmapSource::Gathered { map, geom: tg, runs } => {
                let (ch, y, x) = geom.coords(pick(i));
                if let Some((plan, cache)) = &planned {
                    let runs = if cache.zero_skip() { *runs } else { None };
                    match plan.gather(map, runs, ch, y, x, &mut stats, &mut scratch) {
                        PlannedGather::Words { len } => len,
                        PlannedGather::AllOnes { len } => {
                            // The gathered pattern is provably dense:
                            // serve the PE walk from a per-length memo.
                            let res = *dense_memo.entry(len).or_insert_with(|| {
                                let p = OperandPattern::dense(len);
                                pe.simulate_output_words(p.words(), len)
                            });
                            cycles += res.cycles;
                            macs += res.macs;
                            continue;
                        }
                    }
                } else {
                    gather_operand_words(map, *tg, ch, y, x, &mut scratch)
                }
            }
            BitmapSource::Pair { act, grad, geom: tg } => {
                let TaskGeom::Wg { r, s, stride, pad, gu, gv, dw } = *tg else {
                    unreachable!("pair operands carry a Wg geometry")
                };
                let (cg, yy, xx) = geom.coords(pick(i));
                // Decode the weight coordinate this output computes:
                // depthwise tiles are (channel, i, j) directly; standard
                // convs spread the flattened (c, i, j) plane over (u, v).
                let (ca, ki, kj) = if dw {
                    (cg, yy, xx)
                } else {
                    let p = yy * geom.v + xx;
                    (p / (r * s), (p % (r * s)) / s, p % s)
                };
                pair_pattern_words(
                    *act,
                    *grad,
                    cg,
                    ca,
                    ki,
                    kj,
                    stride.max(1),
                    pad,
                    gu,
                    gv,
                    &mut scratch,
                )
            }
        };
        if len == 0 {
            continue; // structurally empty window: no taps exist
        }
        let res = pe.simulate_output_words(&scratch, len);
        cycles += res.cycles;
        macs += res.macs;
    }
    if let Some((_, cache)) = planned {
        cache.absorb(&stats); // one batch of atomic adds per tile
    }
    (cycles as f64 * scale, macs as f64 * scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_geom(m: usize, u: usize, v: usize) -> TileGeom {
        TileGeom { index: 0, m, u, v, window: (0, u, 0, v) }
    }

    fn sampled(density: f64) -> BitmapSource<'static> {
        BitmapSource::Sampled { density, pattern: BitmapPattern::Iid, blob_radius: 2 }
    }

    #[test]
    fn labels_roundtrip_through_parse() {
        for b in ExecBackend::ALL {
            assert_eq!(ExecBackend::parse(b.label()).unwrap(), b);
        }
        assert_eq!(ExecBackend::parse("EXACT").unwrap(), ExecBackend::Exact);
        assert!(ExecBackend::parse("fpga").is_err());
        assert_ne!(ExecBackend::Analytic.tag(), ExecBackend::Exact.tag());
        assert_eq!(ExecBackend::default(), ExecBackend::Analytic);
    }

    #[test]
    fn exact_tile_is_deterministic_from_the_stream() {
        let pe = ExactPe::default();
        let geom = full_geom(4, 4, 4);
        let a =
            exact_tile_cost(&pe, 288, &geom, 32, &sampled(0.5), &sampled(0.5), None, &mut Pcg32::new(9));
        let b =
            exact_tile_cost(&pe, 288, &geom, 32, &sampled(0.5), &sampled(0.5), None, &mut Pcg32::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn full_sampling_when_tile_fits_the_cap() {
        // n_out <= cap: no scaling, cycles are an exact tile walk.
        let pe = ExactPe::default();
        let geom = full_geom(8, 1, 1);
        let (cyc, macs) = exact_tile_cost(
            &pe,
            256,
            &geom,
            4096,
            &sampled(1.0),
            &sampled(1.0),
            None,
            &mut Pcg32::new(1),
        );
        // 8 dense 256-wide outputs: deterministic arithmetic.
        let one = pe.simulate_output(&vec![true; 256]);
        assert_eq!(cyc, 8.0 * one.cycles as f64);
        assert_eq!(macs, 8.0 * 256.0);
    }

    #[test]
    fn subsampled_tile_scales_to_full_output_count() {
        let pe = ExactPe::default();
        let geom = full_geom(1, 32, 32);
        let (cyc_full, macs_full) = exact_tile_cost(
            &pe,
            512,
            &geom,
            4096,
            &sampled(1.0),
            &sampled(1.0),
            None,
            &mut Pcg32::new(2),
        );
        let (cyc_sub, macs_sub) =
            exact_tile_cost(&pe, 512, &geom, 64, &sampled(1.0), &sampled(1.0), None, &mut Pcg32::new(2));
        // Dense patterns have zero variance, so scaling is exact.
        assert_eq!(cyc_sub, cyc_full);
        assert_eq!(macs_sub, macs_full);
    }

    #[test]
    fn output_sparsity_skips_work() {
        let pe = ExactPe::default();
        let geom = full_geom(1, 16, 16);
        let (dense_c, dense_m) = exact_tile_cost(
            &pe,
            512,
            &geom,
            4096,
            &sampled(0.7),
            &sampled(1.0),
            None,
            &mut Pcg32::new(5),
        );
        let (masked_c, masked_m) = exact_tile_cost(
            &pe,
            512,
            &geom,
            4096,
            &sampled(0.7),
            &sampled(0.4),
            None,
            &mut Pcg32::new(5),
        );
        assert!(masked_c < dense_c * 0.7, "{masked_c} vs {dense_c}");
        assert!(masked_m < dense_m * 0.7);
        let frac = masked_m / dense_m;
        assert!((0.25..0.55).contains(&frac), "computed fraction {frac}");
    }

    #[test]
    fn replayed_sources_consume_no_rng_state() {
        let pe = ExactPe::default();
        let geom = full_geom(4, 8, 8);
        let mut map_rng = Pcg32::new(11);
        let out_map = Bitmap::sample(Shape::new(4, 8, 8), 0.6, &mut map_rng);
        let in_map = Bitmap::sample(Shape::new(8, 16, 16), 0.5, &mut map_rng);
        let mut rng = Pcg32::new(7);
        let mut untouched = Pcg32::new(7);
        let (cyc, macs) = exact_tile_cost(
            &pe,
            288,
            &geom,
            4096,
            &BitmapSource::Streamed { map: &in_map },
            &BitmapSource::Streamed { map: &out_map },
            None,
            &mut rng,
        );
        assert_eq!(rng.next_u32(), untouched.next_u32(), "replay must not draw");
        assert!(cyc > 0.0 && macs > 0.0);
        // And it is trivially reproducible.
        let mut rng2 = Pcg32::new(999); // seed is irrelevant to replay
        let again = exact_tile_cost(
            &pe,
            288,
            &geom,
            4096,
            &BitmapSource::Streamed { map: &in_map },
            &BitmapSource::Streamed { map: &out_map },
            None,
            &mut rng2,
        );
        assert_eq!((cyc, macs), again);
    }

    #[test]
    fn replayed_output_mask_slices_the_real_map() {
        // A map whose channel 0 is all-zero and channel 1 all-ones: the
        // tile must skip exactly channel 0's outputs.
        let pe = ExactPe::default();
        let geom = full_geom(2, 4, 4);
        let mut out_map = Bitmap::zeros(Shape::new(2, 4, 4));
        for y in 0..4 {
            for x in 0..4 {
                out_map.set(1, y, x, true);
            }
        }
        let mut rng = Pcg32::new(3);
        let (cyc, macs) = exact_tile_cost(
            &pe,
            256,
            &geom,
            4096,
            &sampled(1.0),
            &BitmapSource::Streamed { map: &out_map },
            None,
            &mut rng,
        );
        let one = pe.simulate_output(&vec![true; 256]);
        assert_eq!(macs, 16.0 * 256.0, "only channel 1's 16 outputs computed");
        assert_eq!(cyc, 16.0 * one.cycles as f64);
    }

    #[test]
    fn subsampled_replay_strides_across_channels() {
        // A map whose density varies hard by channel (ch 0-1 dense,
        // ch 2-3 empty): a capped replay that only looked at the first k
        // outputs (= lowest channels) would overestimate 2x after
        // scaling; the strided subsample must reproduce the full walk.
        let pe = ExactPe::default();
        let geom = full_geom(4, 4, 4); // 64 outputs, 16 per channel
        let mut out_map = Bitmap::zeros(Shape::new(4, 4, 4));
        for ch in 0..2 {
            for y in 0..4 {
                for x in 0..4 {
                    out_map.set(ch, y, x, true);
                }
            }
        }
        let replayed = BitmapSource::Streamed { map: &out_map };
        let mut rng = Pcg32::new(1);
        let full = exact_tile_cost(&pe, 256, &geom, 4096, &sampled(1.0), &replayed, None, &mut rng);
        let capped = exact_tile_cost(&pe, 256, &geom, 16, &sampled(1.0), &replayed, None, &mut rng);
        assert_eq!(capped, full, "strided subsample must be channel-unbiased here");
        let one = pe.simulate_output(&vec![true; 256]);
        assert_eq!(full.1, 32.0 * 256.0, "exactly the two dense channels compute");
        assert_eq!(full.0, 32.0 * one.cycles as f64);
    }

    #[test]
    fn replayed_operands_track_the_map_density() {
        let pe = ExactPe::default();
        let geom = full_geom(2, 8, 8);
        let mut map_rng = Pcg32::new(13);
        for target in [0.25, 0.75] {
            let in_map = Bitmap::sample(Shape::new(16, 16, 16), target, &mut map_rng);
            let mut rng = Pcg32::new(1);
            let (_, macs) = exact_tile_cost(
                &pe,
                1024,
                &geom,
                4096,
                &BitmapSource::Streamed { map: &in_map },
                &sampled(1.0),
                None,
                &mut rng,
            );
            let density = macs / (geom.outputs() as f64 * 1024.0);
            assert!(
                (density - target).abs() < 0.05,
                "replayed MAC density {density:.3} vs map density {target}"
            );
        }
    }

    #[test]
    fn blob_pattern_changes_lane_balance_not_density() {
        // Same density, clustered vs iid: MAC counts agree in expectation
        // but clustered operands stall lanes more (higher cycles).
        let pe = ExactPe::default();
        let geom = full_geom(1, 16, 16);
        let iid = BitmapSource::Sampled {
            density: 0.5,
            pattern: BitmapPattern::Iid,
            blob_radius: 0,
        };
        let blobs = BitmapSource::Sampled {
            density: 0.5,
            pattern: BitmapPattern::Blobs,
            blob_radius: 8,
        };
        let (cyc_iid, macs_iid) =
            exact_tile_cost(&pe, 2048, &geom, 4096, &iid, &sampled(1.0), None, &mut Pcg32::new(2));
        let (cyc_blob, macs_blob) =
            exact_tile_cost(&pe, 2048, &geom, 4096, &blobs, &sampled(1.0), None, &mut Pcg32::new(2));
        let mac_err = (macs_blob - macs_iid).abs() / macs_iid;
        assert!(mac_err < 0.02, "same density, same expected MACs ({mac_err:.3})");
        assert!(
            cyc_blob > cyc_iid * 1.02,
            "clustering must cost lane imbalance: blobs {cyc_blob:.0} vs iid {cyc_iid:.0}"
        );
    }

    /// Brute-force reference for the geometry-exact FP gather: the bit
    /// for tap `(c, ky, kx)` of output `(y, x)` is the map bit at
    /// `(c, y·stride − pad + ky, x·stride − pad + kx)` (zero off-map).
    fn fp_reference(
        map: &Bitmap,
        y: usize,
        x: usize,
        r: usize,
        s: usize,
        st: usize,
        pad: usize,
    ) -> Vec<bool> {
        let mut out = Vec::with_capacity(map.shape.c * r * s);
        for c in 0..map.shape.c {
            for ky in 0..r {
                for kx in 0..s {
                    let yy = (y * st + ky) as isize - pad as isize;
                    let xx = (x * st + kx) as isize - pad as isize;
                    out.push(
                        yy >= 0
                            && xx >= 0
                            && (yy as usize) < map.shape.h
                            && (xx as usize) < map.shape.w
                            && map.get(c, yy as usize, xx as usize),
                    );
                }
            }
        }
        out
    }

    #[test]
    fn conv_gather_matches_brute_force_reference() {
        let mut rng = Pcg32::new(17);
        let map = Bitmap::sample(Shape::new(6, 10, 10), 0.5, &mut rng);
        let mut scratch = Vec::new();
        for (r, s, st, pad) in [(3, 3, 1, 1), (3, 3, 2, 1), (1, 1, 1, 0), (5, 5, 2, 2)] {
            let tg = TaskGeom::Conv { r, s, stride: st, pad, dw: false };
            let (u, v) = ((10 + 2 * pad - r) / st + 1, (10 + 2 * pad - s) / st + 1);
            for (y, x) in [(0, 0), (u / 2, v / 2), (u - 1, v - 1)] {
                let len = gather_operand_words(&map, tg, 0, y, x, &mut scratch);
                let expect = fp_reference(&map, y, x, r, s, st, pad);
                assert_eq!(len, expect.len(), "r{r}s{s}st{st}p{pad}@({y},{x})");
                for (j, e) in expect.iter().enumerate() {
                    let got = (scratch[j / 64] >> (j % 64)) & 1 == 1;
                    assert_eq!(got, *e, "bit {j} of r{r}s{s}st{st}p{pad}@({y},{x})");
                }
            }
        }
        // Depthwise: channel ch only.
        let tg = TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 1, dw: true };
        let len = gather_operand_words(&map, tg, 4, 5, 5, &mut scratch);
        assert_eq!(len, 9);
        for (j, (ky, kx)) in (0..3).flat_map(|a| (0..3).map(move |b| (a, b))).enumerate() {
            let got = (scratch[0] >> j) & 1 == 1;
            assert_eq!(got, map.get(4, 5 + ky - 1, 5 + kx - 1), "dw tap {j}");
        }
    }

    #[test]
    fn convt_gather_collects_exactly_the_valid_taps() {
        // Stride-2 3x3 conv, pad 1: input 8x8 -> output 4x4. The
        // input-gradient at (y, x) must read gradient taps
        // {(u, v) : u·2 − 1 + i = y, i ∈ [0,3)} — brute-force the set.
        let (r, s, st, pad) = (3usize, 3usize, 2usize, 1usize);
        let (gu, gv) = (4usize, 4usize);
        let mut rng = Pcg32::new(23);
        let gmap = Bitmap::sample(Shape::new(5, gu, gv), 0.6, &mut rng);
        let tg = TaskGeom::ConvT { r, s, stride: st, pad, dw: false };
        let mut scratch = Vec::new();
        for y in 0..8usize {
            for x in 0..8usize {
                let len = gather_operand_words(&gmap, tg, 0, y, x, &mut scratch);
                // Reference: valid (u, v) pairs in row-major order per channel.
                let valid_axis = |p: usize| -> Vec<isize> {
                    let mut v = Vec::new();
                    for i in 0..r {
                        let num = p as isize + pad as isize - i as isize;
                        if num.rem_euclid(st as isize) == 0 {
                            v.push(num.div_euclid(st as isize));
                        }
                    }
                    v.sort_unstable();
                    v
                };
                let (us, vs) = (valid_axis(y), valid_axis(x));
                assert_eq!(len, gmap.shape.c * us.len() * vs.len(), "({y},{x})");
                let mut expected_macs = 0u64;
                let mut got_macs = 0u64;
                let mut j = 0usize;
                for c in 0..gmap.shape.c {
                    for &u in &us {
                        for &v in &vs {
                            let e = u >= 0
                                && v >= 0
                                && (u as usize) < gu
                                && (v as usize) < gv
                                && gmap.get(c, u as usize, v as usize);
                            let got = (scratch[j / 64] >> (j % 64)) & 1 == 1;
                            assert_eq!(got, e, "({y},{x}) c{c} u{u} v{v}");
                            expected_macs += e as u64;
                            got_macs += got as u64;
                            j += 1;
                        }
                    }
                }
                assert_eq!(got_macs, expected_macs);
            }
        }
        // r < stride leaves some positions with structurally no taps.
        let tg1 = TaskGeom::ConvT { r: 1, s: 1, stride: 2, pad: 0, dw: false };
        assert_eq!(gather_operand_words(&gmap, tg1, 0, 1, 0, &mut scratch), 0);
        assert!(gather_operand_words(&gmap, tg1, 0, 2, 2, &mut scratch) > 0);
    }

    /// The pre-word-extract per-tap walk, kept verbatim as the
    /// independent reference for the strided row gather.
    fn act_row_bits_reference(
        a: &Bitmap,
        ca: usize,
        ya: isize,
        v0: usize,
        n: usize,
        sd: usize,
        off: isize,
    ) -> u64 {
        if ya < 0 || ya >= a.shape.h as isize {
            return 0;
        }
        let y = ya as usize;
        let w = a.shape.w as isize;
        let mut bits = 0u64;
        for t in 0..n {
            let x = ((v0 + t) * sd) as isize + off;
            if x >= 0 && x < w && a.get(ca, y, x as usize) {
                bits |= 1 << t;
            }
        }
        bits
    }

    #[test]
    fn strided_act_rows_match_the_per_tap_reference() {
        // The gather-stride-aware word extract must agree bit-for-bit
        // with the per-tap walk it replaced, across strides, offsets,
        // word-boundary-straddling rows and out-of-bounds tap ranges.
        let mut rng = Pcg32::new(53);
        let maps = [
            Bitmap::sample(Shape::new(3, 9, 70), 0.5, &mut rng), // rows cross words
            Bitmap::sample(Shape::new(5, 16, 16), 0.3, &mut rng),
            Bitmap::sample(Shape::new(1, 4, 130), 0.7, &mut rng), // >2 words per row
        ];
        for a in &maps {
            for sd in [2usize, 3, 4, 7] {
                for off in [-5isize, -1, 0, 1, 3, 64] {
                    for v0 in [0usize, 1, 5] {
                        for n in [1usize, 7, 33, 64] {
                            for ya in [-1isize, 0, 2, a.shape.h as isize - 1, a.shape.h as isize]
                            {
                                let ca = (v0 + n) % a.shape.c;
                                let got = act_row_bits(a, ca, ya, v0, n, sd, off);
                                let expect = act_row_bits_reference(a, ca, ya, v0, n, sd, off);
                                assert_eq!(
                                    got, expect,
                                    "sd={sd} off={off} v0={v0} n={n} ya={ya} shape {}",
                                    a.shape
                                );
                            }
                        }
                    }
                }
            }
            // Stride 1 keeps its single-extract fast path.
            let got = act_row_bits(a, 0, 1, 2, 16, 1, -3);
            let expect = act_row_bits_reference(a, 0, 1, 2, 16, 1, -3);
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn pair_pattern_is_the_joint_and_of_both_maps() {
        // 3x3 stride-1 pad-1 conv, 8x8 maps: the WG operand for weight
        // (m=1, c=2, ki, kj) over all 64 output positions.
        let mut rng = Pcg32::new(29);
        let act = Bitmap::sample(Shape::new(4, 8, 8), 0.5, &mut rng);
        let grad = Bitmap::sample(Shape::new(3, 8, 8), 0.6, &mut rng);
        let mut scratch = Vec::new();
        for (st, ki, kj) in [(1usize, 0usize, 2usize), (1, 2, 0), (2, 1, 1)] {
            let (gu, gv) = (8usize, 8usize);
            let len = pair_pattern_words(
                Some(&act),
                Some(&grad),
                1,
                2,
                ki,
                kj,
                st,
                1,
                gu,
                gv,
                &mut scratch,
            );
            assert_eq!(len, gu * gv);
            for u in 0..gu {
                for v in 0..gv {
                    let j = u * gv + v;
                    let ya = (u * st + ki) as isize - 1;
                    let xa = (v * st + kj) as isize - 1;
                    let a_bit = ya >= 0
                        && xa >= 0
                        && (ya as usize) < 8
                        && (xa as usize) < 8
                        && act.get(2, ya as usize, xa as usize);
                    let g_bit = grad.get(1, u, v);
                    let got = (scratch[j / 64] >> (j % 64)) & 1 == 1;
                    assert_eq!(got, a_bit && g_bit, "st{st} k({ki},{kj}) at ({u},{v})");
                }
            }
        }
        // A missing side is dense: act-only equals act taps, grad-only
        // equals the grad channel slice.
        let len = pair_pattern_words(None, Some(&grad), 0, 0, 0, 0, 1, 0, 8, 8, &mut scratch);
        assert_eq!(len, 64);
        let nz: u32 = scratch.iter().map(|w| w.count_ones()).sum();
        assert_eq!(nz as usize, grad.wc_nz(0));
        let len = pair_pattern_words(Some(&act), None, 0, 3, 1, 1, 1, 1, 8, 8, &mut scratch);
        assert_eq!(len, 64);
        let nz: u32 = scratch.iter().map(|w| w.count_ones()).sum();
        // act taps shifted by (0,0) offset: count the reference.
        let mut expect = 0u32;
        for u in 0..8usize {
            for v in 0..8usize {
                if act.get(3, u, v) {
                    expect += 1; // ya = u·1 + 1 − 1 = u, xa = v
                }
            }
        }
        assert_eq!(nz, expect);
    }

    #[test]
    fn gathered_and_pair_sources_draw_no_rng() {
        let pe = ExactPe::default();
        let mut map_rng = Pcg32::new(41);
        let in_map = Bitmap::sample(Shape::new(8, 16, 16), 0.5, &mut map_rng);
        let act = Bitmap::sample(Shape::new(8, 16, 16), 0.5, &mut map_rng);
        let grad = Bitmap::sample(Shape::new(4, 16, 16), 0.6, &mut map_rng);
        let conv = TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 1, dw: false };
        let wg = TaskGeom::Wg { r: 3, s: 3, stride: 1, pad: 1, gu: 16, gv: 16, dw: false };
        let geom_fp = full_geom(4, 16, 16);
        let geom_wg = full_geom(4, 9, 8); // 4 filters x 72 = 8·3·3 weight plane
        let mut rng = Pcg32::new(7);
        let mut untouched = Pcg32::new(7);
        let a = exact_tile_cost(
            &pe,
            72,
            &geom_fp,
            64,
            &BitmapSource::Gathered { map: &in_map, geom: conv, runs: None },
            &sampled(1.0),
            None,
            &mut rng,
        );
        let b = exact_tile_cost(
            &pe,
            256,
            &geom_wg,
            64,
            &BitmapSource::Pair { act: Some(&act), grad: Some(&grad), geom: wg },
            &sampled(1.0),
            None,
            &mut rng,
        );
        assert_eq!(rng.next_u32(), untouched.next_u32(), "gather/pair must not draw");
        assert!(a.0 > 0.0 && a.1 > 0.0);
        assert!(b.0 > 0.0 && b.1 > 0.0);
        // Seed-independent reproduction.
        let mut rng2 = Pcg32::new(999);
        let b2 = exact_tile_cost(
            &pe,
            256,
            &geom_wg,
            64,
            &BitmapSource::Pair { act: Some(&act), grad: Some(&grad), geom: wg },
            &sampled(1.0),
            None,
            &mut rng2,
        );
        assert_eq!(b, b2);
    }

    #[test]
    fn planned_gathers_cost_identically_to_direct() {
        // The whole point of the plan cache: Some vs None (and zero-skip
        // on vs off) must never change a returned cycle or MAC, across
        // geometries, densities and subsampling.
        let pe = ExactPe::default();
        let mut map_rng = Pcg32::new(61);
        let full = GatherPlanCache::new();
        let plans_only = GatherPlanCache::plans_only();
        for (density, tg) in [
            (0.01, TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 1, dw: false }),
            (0.5, TaskGeom::Conv { r: 5, s: 5, stride: 2, pad: 2, dw: true }),
            (1.0, TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 1, dw: false }),
            (0.4, TaskGeom::ConvT { r: 3, s: 3, stride: 2, pad: 1, dw: false }),
        ] {
            let map = Bitmap::sample(Shape::new(6, 12, 12), density, &mut map_rng);
            let runs = map.run_index();
            let geom = full_geom(3, 12, 12);
            let src = BitmapSource::Gathered { map: &map, geom: tg, runs: Some(&runs) };
            for cap in [4096usize, 40] {
                let direct = exact_tile_cost(
                    &pe,
                    54,
                    &geom,
                    cap,
                    &src,
                    &sampled(1.0),
                    None,
                    &mut Pcg32::new(5),
                );
                for cache in [&full, &plans_only] {
                    let planned = exact_tile_cost(
                        &pe,
                        54,
                        &geom,
                        cap,
                        &src,
                        &sampled(1.0),
                        Some(cache),
                        &mut Pcg32::new(5),
                    );
                    assert_eq!(planned, direct, "{tg:?} d={density} cap={cap}");
                }
            }
        }
        // The dense map exercised the all-ones short circuit; the sparse
        // one the zero-skip — both counters must have moved (on the
        // skip-enabled cache only).
        let s = full.stats();
        assert!(s.windows_shortcircuited > 0, "dense map must short-circuit");
        assert!(s.words_skipped > 0, "0.01-density map must skip words");
        assert!(s.words_gathered > 0);
        assert_eq!(plans_only.stats().words_skipped, 0);
        assert_eq!(plans_only.stats().windows_shortcircuited, 0);
        assert!(plans_only.stats().words_gathered > 0);
    }

    #[test]
    fn gathered_macs_track_map_density_with_padding_zeros() {
        // A dense map gathered through a padded conv performs exactly the
        // in-bounds tap count — padding taps are structural zeros.
        let pe = ExactPe::default();
        let map = Bitmap::sample(Shape::new(2, 6, 6), 1.0, &mut Pcg32::new(1));
        let conv = TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 1, dw: false };
        let geom = full_geom(1, 6, 6);
        let (_, macs) = exact_tile_cost(
            &pe,
            18,
            &geom,
            4096,
            &BitmapSource::Gathered { map: &map, geom: conv, runs: None },
            &sampled(1.0),
            None,
            &mut Pcg32::new(2),
        );
        // Per output: 2 channels × (valid taps of a 3x3 window at pad 1).
        let mut expect = 0.0;
        for y in 0..6i32 {
            for x in 0..6i32 {
                let rows = (0..3).filter(|k| (0..6).contains(&(y + k - 1))).count();
                let cols = (0..3).filter(|k| (0..6).contains(&(x + k - 1))).count();
                expect += (2 * rows * cols) as f64;
            }
        }
        assert_eq!(macs, expect);
    }
}
