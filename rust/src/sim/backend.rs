//! Pluggable execution backends: how one tile's worth of outputs is
//! costed.
//!
//! * [`ExecBackend::Analytic`] — the expected-value `PeModel` path
//!   (`sim::pe`): per-output cycles from closed-form lane-maximum
//!   statistics, per-tile sparsity jitter on top. Fast; what every
//!   production figure used before this abstraction existed.
//! * [`ExecBackend::Exact`] — the bitmap-driven `ExactPe` path
//!   (`sim::exact`). Where each tile's operand/output patterns come from
//!   is a [`BitmapSource`]:
//!   - [`BitmapSource::Sampled`] — drawn from the tile's (jittered)
//!     density via the per-image RNG stream, iid or spatially-blobbed
//!     (`BitmapPattern`);
//!   - [`BitmapSource::Replayed`] — sliced out of a *captured* map
//!     (`sim::replay`), pattern-exact and entirely RNG-free.
//!
//! Both backends draw exclusively from the per-image stream handed down
//! by `engine::simulate_image` (replayed slices draw nothing at all), so
//! the PR 1 determinism contract (bit-identical results at any `--jobs`
//! level) holds for every source.

use crate::config::BitmapPattern;
use crate::nn::Shape;
use crate::sparsity::Bitmap;
use crate::util::rng::Pcg32;

use super::exact::ExactPe;

/// Which execution model costs the tiles of a simulation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ExecBackend {
    /// Analytic expected-value `PeModel` (the fast default).
    #[default]
    Analytic,
    /// Cycle-accurate `ExactPe` over sampled or replayed bitmaps.
    Exact,
}

impl ExecBackend {
    pub const ALL: [ExecBackend; 2] = [ExecBackend::Analytic, ExecBackend::Exact];

    pub fn label(&self) -> &'static str {
        match self {
            ExecBackend::Analytic => "analytic",
            ExecBackend::Exact => "exact",
        }
    }

    /// Stable tag folded into `SimOptions::fingerprint` (sweep-cache key).
    pub fn tag(&self) -> u64 {
        match self {
            ExecBackend::Analytic => 1,
            ExecBackend::Exact => 2,
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<ExecBackend> {
        match s.to_ascii_lowercase().as_str() {
            "analytic" | "model" => Ok(ExecBackend::Analytic),
            "exact" | "bitmap" => Ok(ExecBackend::Exact),
            other => anyhow::bail!("unknown backend '{other}' (analytic|exact)"),
        }
    }
}

/// Where a tile's bit patterns come from.
#[derive(Clone, Copy, Debug)]
pub enum BitmapSource<'a> {
    /// Draw from the per-image stream at the given non-zero `density`,
    /// with the configured spatial correlation.
    Sampled { density: f64, pattern: BitmapPattern, blob_radius: usize },
    /// Slice real patterns out of a captured map — no RNG involvement.
    Replayed { map: &'a Bitmap },
}

/// One PE tile's place in a task's output map: tile `index` owns the
/// half-open spatial `window` `(r0, r1, c0, c1)` of the full `u × v` map
/// and computes all `m` channels of it (`sim::tile::tile_windows`).
#[derive(Clone, Copy, Debug)]
pub struct TileGeom {
    pub index: usize,
    pub m: usize,
    pub u: usize,
    pub v: usize,
    pub window: (usize, usize, usize, usize),
}

impl TileGeom {
    pub fn spatial_outputs(&self) -> usize {
        let (r0, r1, c0, c1) = self.window;
        (r1 - r0) * (c1 - c0)
    }

    pub fn outputs(&self) -> usize {
        self.m * self.spatial_outputs()
    }

    /// Coordinates of the tile's `j`-th output in channel-major drain
    /// order: all spatial positions of channel 0, then channel 1, …
    #[inline]
    fn coords(&self, j: usize) -> (usize, usize, usize) {
        let (r0, _, c0, c1) = self.window;
        let sp = self.spatial_outputs();
        let cols = c1 - c0;
        let rem = j % sp;
        (j / sp, r0 + rem / cols, c0 + rem % cols)
    }
}

/// Start bit of output `j`'s operand window inside a replayed map.
///
/// The window is anchored at the output's spatial position scaled into
/// the operand map's plane (a conv output at `(y, x)` reads a receptive
/// field around the corresponding input location) and runs `crs` bits in
/// within-channel streaming order, wrapping through the channels — so
/// adjacent outputs get overlapping, spatially-local windows and *every
/// channel at one position reads the same window*, exactly as the dense
/// BP/FP GEMM pairs operands. Purely arithmetic: replay costs no RNG
/// state, which is what keeps `--replay` runs bit-identical at any
/// `--jobs` level.
#[inline]
fn operand_window_start(geom: &TileGeom, j: usize, map: &Bitmap) -> usize {
    let (_, y, x) = geom.coords(j);
    let (mh, mw) = (map.shape.h, map.shape.w);
    let yy = ((y * mh) / geom.u.max(1)).min(mh.saturating_sub(1));
    let xx = ((x * mw) / geom.v.max(1)).min(mw.saturating_sub(1));
    yy * mw + xx
}

/// Sample one operand pattern (packed) into `out`. Degenerate densities
/// are draw-free, preserving the old `sample_pattern` contract.
fn sample_pattern_words(
    crs: usize,
    density: f64,
    pattern: BitmapPattern,
    blob_radius: usize,
    rng: &mut Pcg32,
    out: &mut Vec<u64>,
) {
    out.clear();
    out.resize(crs.div_ceil(64), 0);
    if density <= 0.0 {
        return;
    }
    if density >= 1.0 {
        out.fill(!0);
        let tail = crs % 64;
        if tail > 0 {
            *out.last_mut().unwrap() &= (1u64 << tail) - 1;
        }
        return;
    }
    match pattern {
        BitmapPattern::Iid => {
            for i in 0..crs {
                if rng.bernoulli(density) {
                    out[i / 64] |= 1 << (i % 64);
                }
            }
        }
        BitmapPattern::Blobs => {
            let b = Bitmap::sample_blobs(Shape::new(1, 1, crs), density, blob_radius, rng);
            out.copy_from_slice(b.words());
        }
    }
}

/// Exact cost of one PE tile (`geom`) with receptive field `crs`, its
/// operand and output patterns pulled from the given sources.
///
/// Up to `max_sampled` outputs get a real pattern; the total is scaled
/// to the tile's full output count (`n_out <= max_sampled` simulates the
/// tile output-exactly). Subsampled replayed tiles *stride* their k
/// simulated outputs evenly across the whole output range (`i·n/k`), not
/// the first k — the first k in channel-major order would be the lowest
/// channels only, and real maps' density varies by channel, which would
/// bias the scaled estimate. The output mask is resolved first, before
/// any operand streams — the Fig 5c bitmap is known a priori in DRAM —
/// and a masked output costs zero cycles *and zero pattern work* (its
/// operands are never drawn or sliced). Everything drains word-level
/// through [`ExactPe::simulate_output_words`]; no per-lane bool vectors
/// exist on this path.
///
/// Returns `(cycles, macs)` as the engine's f64 accounting expects.
pub fn exact_tile_cost(
    pe: &ExactPe,
    crs: usize,
    geom: &TileGeom,
    max_sampled: usize,
    operands: &BitmapSource<'_>,
    outputs: &BitmapSource<'_>,
    rng: &mut Pcg32,
) -> (f64, f64) {
    let n_out = geom.outputs();
    if n_out == 0 {
        return (0.0, 0.0);
    }
    let k = n_out.min(max_sampled.max(1));
    // Representative i-th output when subsampling (identity at k == n_out;
    // distinct and strictly increasing for k <= n_out).
    let stride = |i: usize| i * n_out / k;

    // Output mask for the k simulated outputs, packed.
    let mut mask = vec![0u64; k.div_ceil(64)];
    match outputs {
        BitmapSource::Sampled { density, pattern, blob_radius } => {
            let shape = Shape::new(1, 1, k);
            let b = match pattern {
                BitmapPattern::Iid => Bitmap::sample(shape, *density, rng),
                BitmapPattern::Blobs => Bitmap::sample_blobs(shape, *density, *blob_radius, rng),
            };
            mask.copy_from_slice(b.words());
        }
        BitmapSource::Replayed { map } => {
            debug_assert_eq!(map.shape, Shape::new(geom.m, geom.u, geom.v));
            for i in 0..k {
                let (ch, y, x) = geom.coords(stride(i));
                if map.get(ch, y, x) {
                    mask[i / 64] |= 1 << (i % 64);
                }
            }
        }
    }

    let mut cycles = 0u64;
    let mut macs = 0u64;
    let mut scratch: Vec<u64> = Vec::new();
    for i in 0..k {
        if (mask[i / 64] >> (i % 64)) & 1 == 0 {
            continue; // skipped a priori — zero cycles (Fig 5c)
        }
        match operands {
            BitmapSource::Sampled { density, pattern, blob_radius } => {
                sample_pattern_words(crs, *density, *pattern, *blob_radius, rng, &mut scratch);
            }
            BitmapSource::Replayed { map } => {
                let start = operand_window_start(geom, stride(i), map);
                map.window_words_into(start, crs, &mut scratch);
            }
        }
        let r = pe.simulate_output_words(&scratch, crs);
        cycles += r.cycles;
        macs += r.macs;
    }
    let scale = n_out as f64 / k as f64;
    (cycles as f64 * scale, macs as f64 * scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_geom(m: usize, u: usize, v: usize) -> TileGeom {
        TileGeom { index: 0, m, u, v, window: (0, u, 0, v) }
    }

    fn sampled(density: f64) -> BitmapSource<'static> {
        BitmapSource::Sampled { density, pattern: BitmapPattern::Iid, blob_radius: 2 }
    }

    #[test]
    fn labels_roundtrip_through_parse() {
        for b in ExecBackend::ALL {
            assert_eq!(ExecBackend::parse(b.label()).unwrap(), b);
        }
        assert_eq!(ExecBackend::parse("EXACT").unwrap(), ExecBackend::Exact);
        assert!(ExecBackend::parse("fpga").is_err());
        assert_ne!(ExecBackend::Analytic.tag(), ExecBackend::Exact.tag());
        assert_eq!(ExecBackend::default(), ExecBackend::Analytic);
    }

    #[test]
    fn exact_tile_is_deterministic_from_the_stream() {
        let pe = ExactPe::default();
        let geom = full_geom(4, 4, 4);
        let a = exact_tile_cost(&pe, 288, &geom, 32, &sampled(0.5), &sampled(0.5), &mut Pcg32::new(9));
        let b = exact_tile_cost(&pe, 288, &geom, 32, &sampled(0.5), &sampled(0.5), &mut Pcg32::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn full_sampling_when_tile_fits_the_cap() {
        // n_out <= cap: no scaling, cycles are an exact tile walk.
        let pe = ExactPe::default();
        let geom = full_geom(8, 1, 1);
        let (cyc, macs) =
            exact_tile_cost(&pe, 256, &geom, 4096, &sampled(1.0), &sampled(1.0), &mut Pcg32::new(1));
        // 8 dense 256-wide outputs: deterministic arithmetic.
        let one = pe.simulate_output(&vec![true; 256]);
        assert_eq!(cyc, 8.0 * one.cycles as f64);
        assert_eq!(macs, 8.0 * 256.0);
    }

    #[test]
    fn subsampled_tile_scales_to_full_output_count() {
        let pe = ExactPe::default();
        let geom = full_geom(1, 32, 32);
        let (cyc_full, macs_full) =
            exact_tile_cost(&pe, 512, &geom, 4096, &sampled(1.0), &sampled(1.0), &mut Pcg32::new(2));
        let (cyc_sub, macs_sub) =
            exact_tile_cost(&pe, 512, &geom, 64, &sampled(1.0), &sampled(1.0), &mut Pcg32::new(2));
        // Dense patterns have zero variance, so scaling is exact.
        assert_eq!(cyc_sub, cyc_full);
        assert_eq!(macs_sub, macs_full);
    }

    #[test]
    fn output_sparsity_skips_work() {
        let pe = ExactPe::default();
        let geom = full_geom(1, 16, 16);
        let (dense_c, dense_m) =
            exact_tile_cost(&pe, 512, &geom, 4096, &sampled(0.7), &sampled(1.0), &mut Pcg32::new(5));
        let (masked_c, masked_m) =
            exact_tile_cost(&pe, 512, &geom, 4096, &sampled(0.7), &sampled(0.4), &mut Pcg32::new(5));
        assert!(masked_c < dense_c * 0.7, "{masked_c} vs {dense_c}");
        assert!(masked_m < dense_m * 0.7);
        let frac = masked_m / dense_m;
        assert!((0.25..0.55).contains(&frac), "computed fraction {frac}");
    }

    #[test]
    fn replayed_sources_consume_no_rng_state() {
        let pe = ExactPe::default();
        let geom = full_geom(4, 8, 8);
        let mut map_rng = Pcg32::new(11);
        let out_map = Bitmap::sample(Shape::new(4, 8, 8), 0.6, &mut map_rng);
        let in_map = Bitmap::sample(Shape::new(8, 16, 16), 0.5, &mut map_rng);
        let mut rng = Pcg32::new(7);
        let mut untouched = Pcg32::new(7);
        let (cyc, macs) = exact_tile_cost(
            &pe,
            288,
            &geom,
            4096,
            &BitmapSource::Replayed { map: &in_map },
            &BitmapSource::Replayed { map: &out_map },
            &mut rng,
        );
        assert_eq!(rng.next_u32(), untouched.next_u32(), "replay must not draw");
        assert!(cyc > 0.0 && macs > 0.0);
        // And it is trivially reproducible.
        let mut rng2 = Pcg32::new(999); // seed is irrelevant to replay
        let again = exact_tile_cost(
            &pe,
            288,
            &geom,
            4096,
            &BitmapSource::Replayed { map: &in_map },
            &BitmapSource::Replayed { map: &out_map },
            &mut rng2,
        );
        assert_eq!((cyc, macs), again);
    }

    #[test]
    fn replayed_output_mask_slices_the_real_map() {
        // A map whose channel 0 is all-zero and channel 1 all-ones: the
        // tile must skip exactly channel 0's outputs.
        let pe = ExactPe::default();
        let geom = full_geom(2, 4, 4);
        let mut out_map = Bitmap::zeros(Shape::new(2, 4, 4));
        for y in 0..4 {
            for x in 0..4 {
                out_map.set(1, y, x, true);
            }
        }
        let mut rng = Pcg32::new(3);
        let (cyc, macs) = exact_tile_cost(
            &pe,
            256,
            &geom,
            4096,
            &sampled(1.0),
            &BitmapSource::Replayed { map: &out_map },
            &mut rng,
        );
        let one = pe.simulate_output(&vec![true; 256]);
        assert_eq!(macs, 16.0 * 256.0, "only channel 1's 16 outputs computed");
        assert_eq!(cyc, 16.0 * one.cycles as f64);
    }

    #[test]
    fn subsampled_replay_strides_across_channels() {
        // A map whose density varies hard by channel (ch 0-1 dense,
        // ch 2-3 empty): a capped replay that only looked at the first k
        // outputs (= lowest channels) would overestimate 2x after
        // scaling; the strided subsample must reproduce the full walk.
        let pe = ExactPe::default();
        let geom = full_geom(4, 4, 4); // 64 outputs, 16 per channel
        let mut out_map = Bitmap::zeros(Shape::new(4, 4, 4));
        for ch in 0..2 {
            for y in 0..4 {
                for x in 0..4 {
                    out_map.set(ch, y, x, true);
                }
            }
        }
        let replayed = BitmapSource::Replayed { map: &out_map };
        let mut rng = Pcg32::new(1);
        let full = exact_tile_cost(&pe, 256, &geom, 4096, &sampled(1.0), &replayed, &mut rng);
        let capped = exact_tile_cost(&pe, 256, &geom, 16, &sampled(1.0), &replayed, &mut rng);
        assert_eq!(capped, full, "strided subsample must be channel-unbiased here");
        let one = pe.simulate_output(&vec![true; 256]);
        assert_eq!(full.1, 32.0 * 256.0, "exactly the two dense channels compute");
        assert_eq!(full.0, 32.0 * one.cycles as f64);
    }

    #[test]
    fn replayed_operands_track_the_map_density() {
        let pe = ExactPe::default();
        let geom = full_geom(2, 8, 8);
        let mut map_rng = Pcg32::new(13);
        for target in [0.25, 0.75] {
            let in_map = Bitmap::sample(Shape::new(16, 16, 16), target, &mut map_rng);
            let mut rng = Pcg32::new(1);
            let (_, macs) = exact_tile_cost(
                &pe,
                1024,
                &geom,
                4096,
                &BitmapSource::Replayed { map: &in_map },
                &sampled(1.0),
                &mut rng,
            );
            let density = macs / (geom.outputs() as f64 * 1024.0);
            assert!(
                (density - target).abs() < 0.05,
                "replayed MAC density {density:.3} vs map density {target}"
            );
        }
    }

    #[test]
    fn blob_pattern_changes_lane_balance_not_density() {
        // Same density, clustered vs iid: MAC counts agree in expectation
        // but clustered operands stall lanes more (higher cycles).
        let pe = ExactPe::default();
        let geom = full_geom(1, 16, 16);
        let iid = BitmapSource::Sampled {
            density: 0.5,
            pattern: BitmapPattern::Iid,
            blob_radius: 0,
        };
        let blobs = BitmapSource::Sampled {
            density: 0.5,
            pattern: BitmapPattern::Blobs,
            blob_radius: 8,
        };
        let (cyc_iid, macs_iid) =
            exact_tile_cost(&pe, 2048, &geom, 4096, &iid, &sampled(1.0), &mut Pcg32::new(2));
        let (cyc_blob, macs_blob) =
            exact_tile_cost(&pe, 2048, &geom, 4096, &blobs, &sampled(1.0), &mut Pcg32::new(2));
        let mac_err = (macs_blob - macs_iid).abs() / macs_iid;
        assert!(mac_err < 0.02, "same density, same expected MACs ({mac_err:.3})");
        assert!(
            cyc_blob > cyc_iid * 1.02,
            "clustering must cost lane imbalance: blobs {cyc_blob:.0} vs iid {cyc_iid:.0}"
        );
    }
}
