//! Pluggable execution backends: how one tile's worth of outputs is
//! costed.
//!
//! * [`ExecBackend::Analytic`] — the expected-value `PeModel` path
//!   (`sim::pe`): per-output cycles from closed-form lane-maximum
//!   statistics, per-tile sparsity jitter on top. Fast; what every
//!   production figure used before this abstraction existed.
//! * [`ExecBackend::Exact`] — the bitmap-driven `ExactPe` path
//!   (`sim::exact`): per-tile operand bitmaps are *sampled* from the
//!   tile's (jittered) density via the per-image RNG stream, an output
//!   mask is sampled the same way (the Fig 5c a-priori-known output
//!   bitmap), and everything drains through the cycle-accurate group
//!   walker. Slow but pattern-level faithful — the validation reference
//!   SparseTrain/TensorDash-style analytic claims are checked against.
//!
//! Both backends draw exclusively from the per-image stream handed down
//! by `engine::simulate_image`, so the PR 1 determinism contract
//! (bit-identical results at any `--jobs` level) holds for both.

use crate::nn::Shape;
use crate::sparsity::Bitmap;
use crate::util::rng::Pcg32;

use super::exact::ExactPe;

/// One output's operand NZ pattern, sampled straight into the lane-drain
/// form `ExactPe` walks. Same bit order (and identical draw sequence) as
/// `Bitmap::sample` over a `[k, 1, crs]` map, without the pack/unpack
/// round-trip — this is the exact backend's innermost loop. Degenerate
/// densities are draw-free, mirroring `Bitmap::sample`.
fn sample_pattern(crs: usize, density: f64, rng: &mut Pcg32) -> Vec<bool> {
    if density <= 0.0 {
        return vec![false; crs];
    }
    if density >= 1.0 {
        return vec![true; crs];
    }
    (0..crs).map(|_| rng.bernoulli(density)).collect()
}

/// Per-`simulate_tile` chunking bound for the exact backend: keeps the
/// transient operand-bitmap expansion under ~1.5 MB at CRS 4608.
const EXACT_CHUNK: usize = 256;

/// Which execution model costs the tiles of a simulation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum ExecBackend {
    /// Analytic expected-value `PeModel` (the fast default).
    #[default]
    Analytic,
    /// Cycle-accurate `ExactPe` over sampled operand/output bitmaps.
    Exact,
}

impl ExecBackend {
    pub const ALL: [ExecBackend; 2] = [ExecBackend::Analytic, ExecBackend::Exact];

    pub fn label(&self) -> &'static str {
        match self {
            ExecBackend::Analytic => "analytic",
            ExecBackend::Exact => "exact",
        }
    }

    /// Stable tag folded into `SimOptions::fingerprint` (sweep-cache key).
    pub fn tag(&self) -> u64 {
        match self {
            ExecBackend::Analytic => 1,
            ExecBackend::Exact => 2,
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<ExecBackend> {
        match s.to_ascii_lowercase().as_str() {
            "analytic" | "model" => Ok(ExecBackend::Analytic),
            "exact" | "bitmap" => Ok(ExecBackend::Exact),
            other => anyhow::bail!("unknown backend '{other}' (analytic|exact)"),
        }
    }
}

/// Exact cost of one PE tile holding `n_out` outputs with receptive
/// field `crs`, under operand sparsity `s_in` and a-priori output
/// sparsity `s_out`.
///
/// Up to `max_sampled` outputs get a real sampled operand pattern; the
/// sampled total is scaled to the tile's full output count. When
/// `n_out <= max_sampled` the tile is simulated output-exactly. The
/// output mask is sampled once per output as a `Bitmap` (the Fig 5c
/// output bitmap the forward pass leaves in DRAM) — a masked output
/// costs zero cycles, exactly as `ExactPe::simulate_tile` models.
///
/// Returns `(cycles, macs)` as the engine's f64 accounting expects.
pub fn exact_tile_cost(
    pe: &ExactPe,
    crs: usize,
    n_out: usize,
    max_sampled: usize,
    s_in: f64,
    s_out: f64,
    rng: &mut Pcg32,
) -> (f64, f64) {
    if n_out == 0 {
        return (0.0, 0.0);
    }
    let k = n_out.min(max_sampled.max(1));
    let mut cycles = 0u64;
    let mut macs = 0u64;
    let mut drawn = 0usize;
    while drawn < k {
        let chunk = (k - drawn).min(EXACT_CHUNK);
        // Output mask first (the Fig 5c bitmap is known a priori, before
        // operands stream — it lives in DRAM as a real `Bitmap`), then
        // the per-output operand patterns.
        let mask_bits = Bitmap::sample(Shape::new(1, 1, chunk), 1.0 - s_out, rng);
        let mask: Vec<bool> = (0..chunk).map(|i| mask_bits.get(0, 0, i)).collect();
        let outputs: Vec<Vec<bool>> =
            (0..chunk).map(|_| sample_pattern(crs, 1.0 - s_in, rng)).collect();
        let r = pe.simulate_tile(&outputs, Some(&mask));
        cycles += r.cycles;
        macs += r.macs;
        drawn += chunk;
    }
    let scale = n_out as f64 / k as f64;
    (cycles as f64 * scale, macs as f64 * scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_roundtrip_through_parse() {
        for b in ExecBackend::ALL {
            assert_eq!(ExecBackend::parse(b.label()).unwrap(), b);
        }
        assert_eq!(ExecBackend::parse("EXACT").unwrap(), ExecBackend::Exact);
        assert!(ExecBackend::parse("fpga").is_err());
        assert_ne!(ExecBackend::Analytic.tag(), ExecBackend::Exact.tag());
        assert_eq!(ExecBackend::default(), ExecBackend::Analytic);
    }

    #[test]
    fn exact_tile_is_deterministic_from_the_stream() {
        let pe = ExactPe::default();
        let a = exact_tile_cost(&pe, 288, 64, 32, 0.5, 0.5, &mut Pcg32::new(9));
        let b = exact_tile_cost(&pe, 288, 64, 32, 0.5, 0.5, &mut Pcg32::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn full_sampling_when_tile_fits_the_cap() {
        // n_out <= cap: no scaling, cycles are an exact tile walk.
        let pe = ExactPe::default();
        let (cyc, macs) = exact_tile_cost(&pe, 256, 8, 4096, 0.0, 0.0, &mut Pcg32::new(1));
        // 8 dense 256-wide outputs: deterministic arithmetic.
        let one = pe.simulate_output(&vec![true; 256]);
        assert_eq!(cyc, 8.0 * one.cycles as f64);
        assert_eq!(macs, 8.0 * 256.0);
    }

    #[test]
    fn subsampled_tile_scales_to_full_output_count() {
        let pe = ExactPe::default();
        let (cyc_full, macs_full) =
            exact_tile_cost(&pe, 512, 1024, 4096, 0.0, 0.0, &mut Pcg32::new(2));
        let (cyc_sub, macs_sub) =
            exact_tile_cost(&pe, 512, 1024, 64, 0.0, 0.0, &mut Pcg32::new(2));
        // Dense patterns have zero variance, so scaling is exact.
        assert_eq!(cyc_sub, cyc_full);
        assert_eq!(macs_sub, macs_full);
    }

    #[test]
    fn output_sparsity_skips_work() {
        let pe = ExactPe::default();
        let (dense_c, dense_m) =
            exact_tile_cost(&pe, 512, 256, 4096, 0.3, 0.0, &mut Pcg32::new(5));
        let (masked_c, masked_m) =
            exact_tile_cost(&pe, 512, 256, 4096, 0.3, 0.6, &mut Pcg32::new(5));
        assert!(masked_c < dense_c * 0.7, "{masked_c} vs {dense_c}");
        assert!(masked_m < dense_m * 0.7);
        let frac = masked_m / dense_m;
        assert!((0.25..0.55).contains(&frac), "computed fraction {frac}");
    }
}
