//! Synapse blocking (§4.4): receptive fields larger than the PE's operand
//! capacity (1024 pairs) are processed in multiple passes, carrying a
//! partial sum between passes.

/// Number of blocking passes needed for a receptive field of `crs`.
pub fn synapse_passes(crs: usize, capacity: usize) -> usize {
    assert!(capacity > 0);
    crs.div_ceil(capacity).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boundary_arithmetic() {
        assert_eq!(synapse_passes(1, 1024), 1);
        assert_eq!(synapse_passes(1024, 1024), 1);
        assert_eq!(synapse_passes(1025, 1024), 2);
        assert_eq!(synapse_passes(4608, 1024), 5); // VGG 512·3·3
        assert_eq!(synapse_passes(2048, 1024), 2);
    }

    #[test]
    fn degenerate_zero_crs() {
        assert_eq!(synapse_passes(0, 1024), 1);
    }
}
