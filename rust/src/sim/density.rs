//! Measured per-layer, per-phase density summaries extracted from
//! simulation results — the bridge between the sweep engine's measured
//! MAC counts and the analytic platform models in `baselines`.
//!
//! A [`NetworkSimResult`] already carries, for every (layer, phase)
//! entry, the dense MAC count the layer's geometry implies and the MACs
//! the simulated scheme actually performed. The ratio is the *measured*
//! density the scheme could exploit: under `Scheme::In` it is the input
//! operand density, under `Scheme::InOut` the joint input×output
//! density. Platform models that describe a concrete skip mechanism
//! (TensorDash's 4:1 operand multiplexer, SparseTrain's BP gradient
//! pruning, SparseNN's input+output engine) consume these summaries
//! instead of hand-set constants — and because the source result comes
//! from the sweep runner, a `--replay` run feeds them real trace
//! bitmaps through the exact same path.

use crate::nn::Phase;

use super::engine::NetworkSimResult;

/// Measured density of one (layer, phase) entry under one scheme.
#[derive(Clone, Debug)]
pub struct LayerDensity {
    pub name: String,
    pub phase: Phase,
    /// Batch-aggregated dense MAC count (geometry, scheme-independent).
    pub dense_macs: f64,
    /// performed/dense under the source scheme, clamped to [0, 1]:
    /// the fraction of dense work the scheme's sparsity left standing.
    pub density: f64,
}

/// Per-layer, per-phase measured densities of one simulation result.
#[derive(Clone, Debug)]
pub struct DensitySummary {
    /// The scheme the densities were measured under.
    pub scheme: crate::config::Scheme,
    pub layers: Vec<LayerDensity>,
}

impl DensitySummary {
    /// Extract the summary from a simulated (possibly replayed) result.
    pub fn from_result(r: &NetworkSimResult) -> DensitySummary {
        let layers = r
            .per_layer
            .iter()
            .map(|l| LayerDensity {
                name: l.name.clone(),
                phase: l.phase,
                dense_macs: l.dense_macs,
                density: if l.dense_macs > 0.0 {
                    (l.performed_macs / l.dense_macs).clamp(0.0, 1.0)
                } else {
                    1.0
                },
            })
            .collect();
        DensitySummary { scheme: r.scheme, layers }
    }

    /// MAC-weighted mean density of one phase.
    pub fn phase_density(&self, phase: Phase) -> f64 {
        let (mut performed, mut dense) = (0.0, 0.0);
        for l in self.layers.iter().filter(|l| l.phase == phase) {
            performed += l.dense_macs * l.density;
            dense += l.dense_macs;
        }
        if dense > 0.0 {
            performed / dense
        } else {
            1.0
        }
    }

    /// MAC-weighted mean density across all phases.
    pub fn overall_density(&self) -> f64 {
        let (mut performed, mut dense) = (0.0, 0.0);
        for l in &self.layers {
            performed += l.dense_macs * l.density;
            dense += l.dense_macs;
        }
        if dense > 0.0 {
            performed / dense
        } else {
            1.0
        }
    }

    /// Total dense MACs across all (layer, phase) entries.
    pub fn total_dense_macs(&self) -> f64 {
        self.layers.iter().map(|l| l.dense_macs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AcceleratorConfig, Scheme, SimOptions};
    use crate::nn::zoo;
    use crate::sim::simulate_network;
    use crate::sparsity::SparsityModel;

    fn summary(scheme: Scheme) -> DensitySummary {
        let net = zoo::agos_cnn();
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 1, ..SimOptions::default() };
        let model = SparsityModel::synthetic(11);
        DensitySummary::from_result(&simulate_network(&net, &cfg, &opts, &model, scheme))
    }

    #[test]
    fn dense_scheme_measures_full_density() {
        let s = summary(Scheme::Dense);
        assert!((s.overall_density() - 1.0).abs() < 1e-9, "{}", s.overall_density());
        for p in Phase::ALL {
            assert!((s.phase_density(p) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn sparser_schemes_measure_lower_density() {
        let d_in = summary(Scheme::In).overall_density();
        let d_io = summary(Scheme::InOut).overall_density();
        assert!(d_in < 1.0, "input sparsity must show up: {d_in}");
        assert!(d_io <= d_in + 1e-12, "in+out prunes at least as much: {d_io} vs {d_in}");
        for d in [d_in, d_io] {
            assert!((0.0..=1.0).contains(&d));
        }
    }

    #[test]
    fn weights_follow_dense_macs() {
        let s = summary(Scheme::In);
        assert!(s.total_dense_macs() > 0.0);
        // The overall density is bounded by the per-phase extremes.
        let phases: Vec<f64> = Phase::ALL.iter().map(|p| s.phase_density(*p)).collect();
        let lo = phases.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = phases.iter().cloned().fold(0.0f64, f64::max);
        let overall = s.overall_density();
        assert!(overall >= lo - 1e-12 && overall <= hi + 1e-12, "{lo} <= {overall} <= {hi}");
    }
}
