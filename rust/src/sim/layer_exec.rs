//! Per-layer execution model: one GEMM-shaped task (a conv/fc in one
//! training phase) mapped onto the PE grid under a sparsity scheme.
//!
//! On the exact backend a task may additionally carry *replay maps*
//! (`sim::replay`): captured operand/output bitmaps that each tile
//! slices its real patterns from instead of sampling — the pattern-exact
//! co-simulation path. Replayed components draw no RNG state and skip
//! the per-tile jitter (real maps carry their own spatial variation).

use crate::config::{AcceleratorConfig, BitmapPattern, GatherMode, Scheme, SimOptions};
use crate::nn::Shape;
use crate::sparsity::Bitmap;
use crate::util::rng::Pcg32;

use super::backend::{exact_tile_cost, BitmapSource, ExecBackend, TaskGeom, TileGeom};
use super::energy::{layer_energy, EnergyBreakdown};
use super::exact::ExactPe;
use super::memory::layer_traffic;
use super::pe::PeModel;
use super::replay::TaskMaps;
use super::tile::{tile_outputs, tile_windows};
use super::wdu::redistribute;

/// One GEMM-shaped unit of accelerator work (per image).
#[derive(Clone, Debug)]
pub struct LayerTask {
    pub name: String,
    /// Output channels produced (filters / gradient maps).
    pub m: usize,
    /// Spatial output extent (the dimensions tiled across the PE grid).
    pub u: usize,
    pub v: usize,
    /// Receptive field per output value (fractional for strided BP).
    pub crs: f64,
    /// Operand (input) sparsity fraction, if exploitable.
    pub in_sparsity: Option<f64>,
    /// A-priori-known output zero fraction, if exploitable (BP only).
    pub out_sparsity: Option<f64>,
    /// Traffic accounting (elements).
    pub input_elems: f64,
    pub weight_elems: f64,
    /// How outputs map onto captured operand bitmaps when this task
    /// replays (`sim::backend::TaskGeom`); `Streaming` when unknown.
    pub geom: TaskGeom,
    /// Channel extent of the operand map `geom` gathers from (the
    /// input-activation channels in FP, the gradient-map channels in
    /// BP). Used to synthesize a task-wide operand map on the *sampled*
    /// exact path, so sampled runs take the same planned-gather route as
    /// replayed ones.
    pub op_chans: usize,
}

impl LayerTask {
    pub fn outputs(&self) -> usize {
        self.m * self.u * self.v
    }

    pub fn dense_macs(&self) -> f64 {
        self.outputs() as f64 * self.crs
    }
}

/// Result of simulating one `LayerTask` under one scheme.
#[derive(Clone, Debug)]
pub struct LayerSimResult {
    pub name: String,
    pub scheme: Scheme,
    /// Node latency including exposed memory stalls (cycles).
    pub cycles: f64,
    /// Compute-only makespan (max tile completion).
    pub compute_cycles: f64,
    /// Exposed memory stall cycles.
    pub mem_stall: f64,
    pub dense_macs: f64,
    pub performed_macs: f64,
    /// Per-tile busy cycles before redistribution.
    pub tile_busy: Vec<f64>,
    /// Per-tile completion after redistribution (== busy when WR off).
    pub completion: Vec<f64>,
    pub wdu_steals: usize,
    pub energy: EnergyBreakdown,
}

impl LayerSimResult {
    /// Average-to-max tile utilization (Fig 17's metric).
    pub fn tile_utilization(&self) -> f64 {
        let max = self.completion.iter().cloned().fold(0.0, f64::max);
        if max <= 0.0 {
            return 1.0;
        }
        let avg: f64 = self.completion.iter().sum::<f64>() / self.completion.len() as f64;
        avg / max
    }
}

/// Per-tile sparsity variation, applied to the *density* `1 − s` so the
/// induced work variation is `±cv` regardless of the sparsity level
/// (jittering `s` itself would blow up the spread at high sparsity).
/// The deviate is clamped so a single tile cannot dominate
/// unrealistically; calibrated so pre-WR avg/max tile utilization lands
/// near the paper's ~70% (Fig 17).
fn jitter(s: f64, cv: f64, rng: &mut Pcg32) -> f64 {
    if s <= 0.0 {
        return 0.0;
    }
    let g = rng.gauss().clamp(-2.5, 2.5);
    let density = ((1.0 - s) * (1.0 + cv * g)).clamp(0.02, 1.0);
    1.0 - density
}

/// Simulate one layer task (one image) under `scheme`, without replay
/// payloads (every exact-backend pattern is sampled).
pub fn simulate_layer(
    task: &LayerTask,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    scheme: Scheme,
    rng: &mut Pcg32,
) -> LayerSimResult {
    simulate_layer_replay(task, cfg, opts, scheme, None, rng)
}

/// Measured non-zero density of a replayed map inside one output tile's
/// window, the tile window scaled into the map's plane when the two
/// differ (operand maps live on the input grid, output tiles on the
/// output grid). Pure arithmetic over the captured words — no RNG.
fn tile_window_density(
    map: &Bitmap,
    window: (usize, usize, usize, usize),
    u: usize,
    v: usize,
) -> f64 {
    let (r0, r1, c0, c1) = window;
    let (mh, mw) = (map.shape.h, map.shape.w);
    let scale = |a: usize, n: usize, m: usize| (a * m / n.max(1)).min(m);
    let (y0, x0) = (scale(r0, u, mh), scale(c0, v, mw));
    let (y1, x1) = (scale(r1, u, mh).max(y0 + 1).min(mh), scale(c1, v, mw).max(x0 + 1).min(mw));
    if y0 >= y1 || x0 >= x1 {
        return 1.0 - map.sparsity();
    }
    let area = map.shape.c * (y1 - y0) * (x1 - x0);
    map.window_nz(y0, y1, x0, x1) as f64 / area as f64
}

/// Shape and geometry of the *synthetic* operand map a sampled exact
/// task gathers from: the smallest map on which every output's window
/// (as `geom` re-maps it) lies fully in bounds, so the gathered window
/// density equals the sampled map density in expectation — exactly the
/// contract the per-output `BitmapSource::Sampled` draw had.
///
/// * `Conv` — output `(y, x)` anchors at `(y·stride − pad, x·stride −
///   pad)`; dropping the padding (`pad: 0`) and sizing the map to the
///   last window `((u−1)·stride + r, …)` keeps every tap real.
/// * `ConvT` — the tap range starts at `(pad − r)·div_euclid(stride) + 1`
///   for output 0, which can be negative; shifting the geometry's pad by
///   `halo` whole strides translates every window in bounds while
///   preserving which positions are structurally empty (`r < stride`).
/// * `Full` — every output reads the whole `crs`-bit map.
fn sampled_gather_geom(
    geom: TaskGeom,
    op_chans: usize,
    u: usize,
    v: usize,
    crs: usize,
) -> (Shape, TaskGeom) {
    match geom {
        TaskGeom::Conv { r, s, stride, pad: _, dw } => (
            Shape::new(op_chans, (u.max(1) - 1) * stride + r, (v.max(1) - 1) * stride + s),
            TaskGeom::Conv { r, s, stride, pad: 0, dw },
        ),
        TaskGeom::ConvT { r, s, stride, pad, dw } => {
            let sd = stride.max(1) as isize;
            // First tap of output 0 along a kernel-k axis; the halo
            // shifts the more negative of the two axes to zero.
            let lo = |k: usize| (pad as isize - k as isize).div_euclid(sd) + 1;
            let halo = (-lo(r).min(lo(s))).max(0) as usize;
            let extent = |n: usize| {
                ((n.max(1) - 1 + pad) as isize).div_euclid(sd) as usize + halo + 1
            };
            (
                Shape::new(op_chans, extent(u), extent(v)),
                TaskGeom::ConvT { r, s, stride, pad: pad + halo * stride, dw },
            )
        }
        TaskGeom::Full => (Shape::new(1, 1, crs), TaskGeom::Full),
        TaskGeom::Streaming | TaskGeom::Wg { .. } => {
            unreachable!("sampled gathers need a window geometry")
        }
    }
}

/// [`simulate_layer`] with optional replay maps for this task
/// (`sim::replay` resolves them per image; `engine::simulate_image`
/// passes them down). On the exact backend, replayed tasks slice/gather
/// real patterns; on the analytic backend they substitute *measured*
/// per-tile densities for the RNG jitter (the pattern-informed fast
/// path), so a replayed task draws no RNG state on either backend.
pub fn simulate_layer_replay(
    task: &LayerTask,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    scheme: Scheme,
    replay: Option<&TaskMaps>,
    rng: &mut Pcg32,
) -> LayerSimResult {
    let pe = PeModel::from_config(cfg);
    let s_in = if scheme.uses_input_sparsity() { task.in_sparsity.unwrap_or(0.0) } else { 0.0 };
    let s_out = if scheme.uses_output_sparsity() { task.out_sparsity.unwrap_or(0.0) } else { 0.0 };

    // Exact backend: bitmap-driven tile costing through the event-driven
    // PE; the receptive field is rounded to whole operands (it is only
    // fractional for strided BP averages).
    let exact_pe = (opts.backend == ExecBackend::Exact).then(|| ExactPe::from_config(cfg));
    let crs_exact = (task.crs.round() as usize).max(1);

    // Replay maps apply only where the scheme exploits the sparsity
    // type: a dense-compute run performs every MAC no matter what the
    // forward pass left in DRAM. The output map must cover the task's
    // output geometry exactly (FC tasks factorize their maps and fall
    // back to sampling).
    let replay_in = replay.and_then(|r| r.operand.as_ref()).filter(|_| s_in > 0.0);
    let replay_out = replay
        .and_then(|r| r.output.as_ref())
        .filter(|rm| s_out > 0.0 && rm.map.shape == Shape::new(task.m, task.u, task.v));
    // The WG pair exists only under geometry gathering: `--gather
    // streaming` is kept as the pre-gather baseline, where WG sampled
    // and windows were streaming slices.
    let geometry = opts.gather == GatherMode::Geometry;
    let replay_pair = replay
        .and_then(|r| r.pair.as_ref())
        .filter(|_| geometry && s_in > 0.0 && matches!(task.geom, TaskGeom::Wg { .. }));

    // Spatial tiling across the PE grid; every PE computes all M channels
    // of its spatial slice (single filter broadcast at a time, §4.2).
    // Windows slice bitmaps (exact) and measured per-tile densities
    // (analytic replay); the plain analytic hot path (every paper
    // figure) still skips building them.
    let spatial = tile_outputs(task.u, task.v, cfg.tx, cfg.ty);
    let windows = (exact_pe.is_some()
        || replay_in.is_some()
        || replay_out.is_some())
    .then(|| tile_windows(task.u, task.v, cfg.tx, cfg.ty));

    // Sampled operands under geometry gathering synthesize ONE task-wide
    // operand map (a single jitter draw, then one `Shape`-true sample)
    // and gather every tile's windows out of it — the *planned* route
    // replayed tasks take (`sim::plan`), with its zero-skip and all-ones
    // short circuits, instead of re-sampling `crs` fresh bits per output.
    // The synthetic map is sized so every window is in bounds
    // ([`sampled_gather_geom`]), so expected window density is unchanged.
    // `--gather streaming` keeps the historical per-output sampling.
    let sampled_gather = (exact_pe.is_some()
        && geometry
        && task.geom.gathers()
        && s_in > 0.0
        && replay_in.is_none()
        && replay_pair.is_none())
    .then(|| {
        let density = 1.0 - jitter(s_in, opts.tile_sparsity_cv, rng);
        let (shape, geom) =
            sampled_gather_geom(task.geom, task.op_chans, task.u, task.v, crs_exact);
        let map = if density >= 1.0 {
            Bitmap::ones(shape)
        } else {
            match opts.pattern {
                BitmapPattern::Iid => Bitmap::sample(shape, density, rng),
                BitmapPattern::Blobs => {
                    Bitmap::sample_blobs(shape, density, opts.blob_radius, rng)
                }
            }
        };
        let runs = map.run_index();
        (map, geom, runs)
    });

    let mut tile_busy = Vec::with_capacity(spatial.len());
    let mut performed = 0.0f64;
    for (t, &sp) in spatial.iter().enumerate() {
        if sp == 0 {
            tile_busy.push(0.0);
            continue;
        }
        match &exact_pe {
            None => {
                // Per-tile sparsity variation. Replayed maps supply the
                // *measured* density of each tile's slice — the captured
                // pattern's real spatial imbalance, no RNG; sampled
                // fractions keep the calibrated stochastic jitter.
                let s_in_t = if let Some(pm) = &replay_pair {
                    pm.joint_sparsity()
                } else if let Some(rm) = &replay_in {
                    let windows = windows.as_ref().expect("windows exist under replay");
                    1.0 - tile_window_density(&rm.map, windows[t], task.u, task.v)
                } else {
                    jitter(s_in, opts.tile_sparsity_cv, rng)
                };
                let s_out_t = if let Some(rm) = &replay_out {
                    let windows = windows.as_ref().expect("windows exist under replay");
                    1.0 - tile_window_density(&rm.map, windows[t], task.u, task.v)
                } else {
                    jitter(s_out, opts.tile_sparsity_cv, rng)
                };
                let outputs_t = (sp * task.m) as f64;
                let computed = outputs_t * (1.0 - s_out_t);
                let (cyc_per_out, macs_per_out) = pe.cycles_per_output(task.crs, s_in_t);
                tile_busy.push(computed * cyc_per_out);
                performed += computed * macs_per_out;
            }
            Some(xpe) => {
                // Sampled components draw their jittered density from the
                // stream; replayed components touch no RNG state — the
                // captured map carries the real per-tile variation.
                let in_src = if let Some(pm) = &replay_pair {
                    BitmapSource::Pair {
                        act: pm.act.as_ref().map(|m| m.map.as_ref()),
                        grad: pm.grad.as_ref().map(|m| m.map.as_ref()),
                        geom: task.geom,
                    }
                } else if let Some(rm) = &replay_in {
                    if geometry && task.geom.gathers() {
                        BitmapSource::Gathered {
                            map: rm.map.as_ref(),
                            geom: task.geom,
                            runs: Some(rm.runs.as_ref()),
                        }
                    } else {
                        BitmapSource::Streamed { map: rm.map.as_ref() }
                    }
                } else if let Some((map, geom, runs)) = &sampled_gather {
                    BitmapSource::Gathered { map, geom: *geom, runs: Some(runs) }
                } else {
                    BitmapSource::Sampled {
                        density: 1.0 - jitter(s_in, opts.tile_sparsity_cv, rng),
                        pattern: opts.pattern,
                        blob_radius: opts.blob_radius,
                    }
                };
                let out_src = match &replay_out {
                    Some(rm) => BitmapSource::Streamed { map: rm.map.as_ref() },
                    None => BitmapSource::Sampled {
                        density: 1.0 - jitter(s_out, opts.tile_sparsity_cv, rng),
                        pattern: opts.pattern,
                        blob_radius: opts.blob_radius,
                    },
                };
                let windows = windows.as_ref().expect("windows exist on the exact path");
                let geom =
                    TileGeom { index: t, m: task.m, u: task.u, v: task.v, window: windows[t] };
                let (cyc, macs) = exact_tile_cost(
                    xpe,
                    crs_exact,
                    &geom,
                    opts.exact_outputs_per_tile,
                    &in_src,
                    &out_src,
                    opts.gather_plans.as_deref(),
                    rng,
                );
                tile_busy.push(cyc);
                performed += macs;
            }
        }
    }

    // Work redistribution.
    let (completion, steals) = if scheme.uses_work_redistribution() {
        let avg_cyc_per_out = {
            let (c, _) = pe.cycles_per_output(task.crs, s_in);
            c
        };
        let overhead_frac =
            (cfg.wr_overhead_cycles_per_output / avg_cyc_per_out).clamp(0.005, 0.5);
        let out = redistribute(&tile_busy, cfg.wr_threshold, overhead_frac);
        (out.completion, out.steals)
    } else {
        (tile_busy.clone(), 0)
    };
    let compute_cycles = completion.iter().cloned().fold(0.0, f64::max);

    // Memory. Replayed layers account traffic at the captured map's
    // *measured* zero fraction (precomputed popcount), not the model's
    // expected one; a WG pair contributes its measured joint fraction.
    let s_in_mem = match (&replay_pair, &replay_in) {
        (Some(pm), _) => pm.joint_sparsity(),
        (None, Some(rm)) => rm.sparsity,
        (None, None) => s_in,
    };
    let s_out_mem = replay_out.map_or(s_out, |rm| rm.sparsity);
    let output_elems = task.outputs() as f64;
    let traffic = layer_traffic(
        task.input_elems,
        task.weight_elems,
        output_elems,
        cfg.operand_bytes as f64,
        s_in_mem,
        s_out_mem,
    );
    let mem_stall = traffic.stall_cycles(cfg, compute_cycles, opts.overlap_dram);
    let cycles = compute_cycles + mem_stall;

    // Energy: operands staged through SRAM per MAC (2 operands × 2 B),
    // outputs encoded once (§4.2).
    let busy: f64 = tile_busy.iter().sum();
    let energy = layer_energy(
        cfg,
        performed,
        output_elems,
        performed * (2.0 * cfg.operand_bytes as f64),
        traffic.dram_read_bytes + traffic.dram_write_bytes,
        busy,
        cycles,
    );

    LayerSimResult {
        name: task.name.clone(),
        scheme,
        cycles,
        compute_cycles,
        mem_stall,
        dense_macs: task.dense_macs(),
        performed_macs: performed,
        tile_busy,
        completion,
        wdu_steals: steals,
        energy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(in_sp: Option<f64>, out_sp: Option<f64>) -> LayerTask {
        LayerTask {
            name: "test".into(),
            m: 128,
            u: 28,
            v: 28,
            crs: 1152.0, // 128·3·3
            in_sparsity: in_sp,
            out_sparsity: out_sp,
            input_elems: 128.0 * 30.0 * 30.0,
            weight_elems: 128.0 * 1152.0,
            geom: TaskGeom::Streaming,
            op_chans: 128,
        }
    }

    fn run(scheme: Scheme, in_sp: Option<f64>, out_sp: Option<f64>) -> LayerSimResult {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions::default();
        let mut rng = Pcg32::new(7);
        simulate_layer(&task(in_sp, out_sp), &cfg, &opts, scheme, &mut rng)
    }

    #[test]
    fn dense_performs_all_macs() {
        let r = run(Scheme::Dense, Some(0.5), Some(0.5));
        assert!((r.performed_macs - r.dense_macs).abs() / r.dense_macs < 1e-9);
        assert_eq!(r.wdu_steals, 0);
    }

    #[test]
    fn scheme_ordering_dc_ge_in_ge_inout_ge_wr() {
        let (si, so) = (Some(0.5), Some(0.5));
        let dc = run(Scheme::Dense, si, so).cycles;
        let inp = run(Scheme::In, si, so).cycles;
        let both = run(Scheme::InOut, si, so).cycles;
        let wr = run(Scheme::InOutWr, si, so).cycles;
        assert!(dc > inp, "DC {dc} !> IN {inp}");
        assert!(inp > both, "IN {inp} !> IN+OUT {both}");
        assert!(wr <= both * 1.001, "WR {wr} !<= IN+OUT {both}");
    }

    #[test]
    fn speedups_in_papers_range() {
        // 50% input + 50% output sparsity → ideal 4×; with imbalance and
        // overheads the model should land in the 2–4× band (Fig 11).
        let dc = run(Scheme::Dense, Some(0.5), Some(0.5)).cycles;
        let wr = run(Scheme::InOutWr, Some(0.5), Some(0.5)).cycles;
        let speedup = dc / wr;
        assert!((1.8..4.2).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn output_sparsity_skips_macs() {
        let r = run(Scheme::InOut, None, Some(0.5));
        // ≈half the outputs skipped entirely
        let frac = r.performed_macs / r.dense_macs;
        assert!((0.4..0.6).contains(&frac), "frac {frac}");
    }

    #[test]
    fn in_scheme_ignores_output_sparsity() {
        let a = run(Scheme::In, Some(0.5), Some(0.9)).cycles;
        let b = run(Scheme::In, Some(0.5), None).cycles;
        assert!((a - b).abs() / b < 1e-9);
    }

    #[test]
    fn wdu_improves_tile_utilization() {
        let cfg = AcceleratorConfig::default();
        let mut opts = SimOptions::default();
        opts.tile_sparsity_cv = 0.35; // strong imbalance
        let mut rng = Pcg32::new(3);
        let t = task(Some(0.5), Some(0.5));
        let no_wr = simulate_layer(&t, &cfg, &opts, Scheme::InOut, &mut rng);
        let mut rng = Pcg32::new(3);
        let wr = simulate_layer(&t, &cfg, &opts, Scheme::InOutWr, &mut rng);
        assert!(
            wr.tile_utilization() > no_wr.tile_utilization(),
            "WR {:.3} !> no-WR {:.3}",
            wr.tile_utilization(),
            no_wr.tile_utilization()
        );
        assert!(wr.compute_cycles <= no_wr.compute_cycles * 1.001);
    }

    #[test]
    fn energy_positive_and_reduced_by_sparsity() {
        let dc = run(Scheme::Dense, Some(0.5), Some(0.5));
        let wr = run(Scheme::InOutWr, Some(0.5), Some(0.5));
        assert!(dc.energy.total() > 0.0);
        assert!(wr.energy.total() < dc.energy.total());
    }

    #[test]
    fn exact_backend_is_deterministic_and_orders_schemes() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions {
            backend: ExecBackend::Exact,
            exact_outputs_per_tile: 8,
            ..SimOptions::default()
        };
        let t = LayerTask {
            name: "exact".into(),
            m: 32,
            u: 16,
            v: 16,
            crs: 288.0,
            in_sparsity: Some(0.5),
            out_sparsity: Some(0.5),
            input_elems: 32.0 * 18.0 * 18.0,
            weight_elems: 32.0 * 288.0,
            geom: TaskGeom::Streaming,
            op_chans: 32,
        };
        let run = |scheme, seed| {
            let mut rng = Pcg32::new(seed);
            simulate_layer(&t, &cfg, &opts, scheme, &mut rng)
        };
        let a = run(Scheme::InOutWr, 7);
        let b = run(Scheme::InOutWr, 7);
        assert_eq!(a.cycles, b.cycles, "exact backend must be stream-deterministic");
        assert_eq!(a.performed_macs, b.performed_macs);
        let dc = run(Scheme::Dense, 7);
        let inp = run(Scheme::In, 7);
        let both = run(Scheme::InOut, 7);
        assert!((dc.performed_macs - dc.dense_macs).abs() / dc.dense_macs < 1e-9);
        assert!(dc.cycles > inp.cycles, "DC {} !> IN {}", dc.cycles, inp.cycles);
        assert!(inp.cycles > both.cycles, "IN {} !> IN+OUT {}", inp.cycles, both.cycles);
    }

    #[test]
    fn synthetic_sampled_maps_cover_every_window_in_bounds() {
        // The synthetic (shape, geom) pair must put every output window
        // fully inside the map with exactly the tap count the geometry
        // names — no clipping, so gathered window density equals the
        // sampled map density in expectation. ConvT additionally keeps
        // its structurally-empty positions (r < stride) empty.
        use crate::sim::backend::gather_operand_words;
        let mut scratch = Vec::new();
        #[rustfmt::skip]
        let cases = [
            (TaskGeom::Conv { r: 3, s: 3, stride: 2, pad: 1, dw: false }, 6usize, 16usize, 16usize, 54usize),
            (TaskGeom::Conv { r: 5, s: 5, stride: 1, pad: 2, dw: false }, 3, 8, 8, 75),
            (TaskGeom::ConvT { r: 3, s: 3, stride: 2, pad: 1, dw: false }, 4, 16, 16, 9),
            (TaskGeom::ConvT { r: 1, s: 1, stride: 2, pad: 0, dw: false }, 2, 8, 8, 1),
            (TaskGeom::Full, 1, 4, 4, 100),
        ];
        for (tg, chans, u, v, crs) in cases {
            let (shape, syn) = sampled_gather_geom(tg, chans, u, v, crs);
            let map = Bitmap::ones(shape);
            for y in 0..u {
                for x in 0..v {
                    // Expected tap count from the *original* geometry,
                    // ignoring map bounds (the whole point: the synthetic
                    // map must not clip any tap the geometry names).
                    let expect = match tg {
                        TaskGeom::Conv { r, s, .. } => chans * r * s,
                        TaskGeom::ConvT { r, s, stride, pad, .. } => {
                            // count of integral taps per axis
                            let axis = |p: usize, k: usize| {
                                (0..k)
                                    .filter(|&i| {
                                        (p as isize + pad as isize - i as isize)
                                            .rem_euclid(stride as isize)
                                            == 0
                                    })
                                    .count()
                            };
                            chans * axis(y, r) * axis(x, s)
                        }
                        TaskGeom::Full => crs,
                        _ => unreachable!(),
                    };
                    let len = gather_operand_words(&map, syn, 0, y, x, &mut scratch);
                    assert_eq!(len, expect, "{tg:?} at ({y},{x})");
                    // In bounds: every tap of an all-ones map is present.
                    let nz = (0..len)
                        .filter(|j| (scratch[j / 64] >> (j % 64)) & 1 == 1)
                        .count();
                    assert_eq!(nz, len, "{tg:?} at ({y},{x}) clipped {} taps", len - nz);
                }
            }
        }
    }

    #[test]
    fn sampled_exact_path_gathers_from_a_shared_map() {
        // Under geometry gathering, a sampled exact conv synthesizes one
        // task-wide operand map and serves every tile from it through
        // the planned-gather route — deterministic per seed, density-
        // true, plan-invariant, and distinct from the legacy streaming
        // per-output sampling.
        let cfg = AcceleratorConfig::default();
        let t = LayerTask {
            name: "sampled".into(),
            m: 16,
            u: 16,
            v: 16,
            crs: 72.0, // 8ch 3x3
            in_sparsity: Some(0.5),
            out_sparsity: None,
            input_elems: 8.0 * 18.0 * 18.0,
            weight_elems: 16.0 * 72.0,
            geom: TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 1, dw: false },
            op_chans: 8,
        };
        let run = |opts: &SimOptions, seed| {
            let mut rng = Pcg32::new(seed);
            simulate_layer(&t, &cfg, opts, Scheme::In, &mut rng)
        };
        let geo = SimOptions {
            backend: ExecBackend::Exact,
            exact_outputs_per_tile: 16,
            ..SimOptions::default()
        };
        let a = run(&geo, 7);
        let b = run(&geo, 7);
        assert_eq!(a.cycles, b.cycles, "sampled gather must be stream-deterministic");
        assert_eq!(a.performed_macs, b.performed_macs);
        assert_ne!(a.cycles, run(&geo, 8).cycles, "different seeds sample different maps");
        // Windows are fully in bounds, so the MAC fraction tracks the
        // (single-jitter-draw) sampled density around 1 − s_in.
        let frac = a.performed_macs / a.dense_macs;
        assert!((0.25..0.75).contains(&frac), "sampled-gather MAC fraction {frac}");
        // The plan cache stays pure execution strategy on this path too.
        let no_plans = SimOptions { gather_plans: None, ..geo.clone() };
        let c = run(&no_plans, 7);
        assert_eq!(a.cycles, c.cycles, "plans must not change a sampled-gather cycle");
        assert_eq!(a.performed_macs, c.performed_macs);
        // `--gather streaming` keeps the historical per-output sampling.
        let streaming = SimOptions { gather: GatherMode::Streaming, ..geo.clone() };
        let s = run(&streaming, 7);
        assert_ne!(a.cycles, s.cycles, "geometry mode reroutes the sampled stream");
    }

    #[test]
    fn fully_replayed_task_draws_no_rng_and_tracks_patterns() {
        use std::sync::Arc;
        use crate::sim::replay::{ReplayMap, TaskMaps};
        use crate::sparsity::Bitmap;
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { backend: ExecBackend::Exact, ..SimOptions::default() };
        let t = LayerTask {
            name: "replayed".into(),
            m: 32,
            u: 16,
            v: 16,
            crs: 288.0,
            in_sparsity: Some(0.5),
            out_sparsity: Some(0.5),
            input_elems: 32.0 * 18.0 * 18.0,
            weight_elems: 32.0 * 288.0,
            // 32ch 18x18 -> 16x16 via 3x3 stride-1 pad-0: the gather
            // geometry the replayed operand map is exercised through.
            geom: TaskGeom::Conv { r: 3, s: 3, stride: 1, pad: 0, dw: false },
            op_chans: 32,
        };
        let mut map_rng = Pcg32::new(11);
        let out_map = Bitmap::sample(crate::nn::Shape::new(32, 16, 16), 0.5, &mut map_rng);
        let in_map = Bitmap::sample(crate::nn::Shape::new(32, 18, 18), 0.5, &mut map_rng);
        let wrap = |b: &Bitmap| ReplayMap::new(Arc::new(b.clone()));
        let maps = TaskMaps {
            operand: Some(wrap(&in_map)),
            output: Some(wrap(&out_map)),
            pair: None,
        };

        // Both components replayed: the result must not depend on the
        // stream at all (different seeds, identical outcome).
        let run = |seed| {
            let mut rng = Pcg32::new(seed);
            simulate_layer_replay(&t, &cfg, &opts, Scheme::InOut, Some(&maps), &mut rng)
        };
        let a = run(1);
        let b = run(999);
        assert_eq!(a.cycles, b.cycles, "fully-replayed task must be seed-independent");
        assert_eq!(a.performed_macs, b.performed_macs);

        // A different captured pattern at the same density changes the
        // outcome — that is the whole point of replay.
        let out2 = Bitmap::sample(crate::nn::Shape::new(32, 16, 16), 0.5, &mut map_rng);
        let maps2 =
            TaskMaps { operand: Some(wrap(&in_map)), output: Some(wrap(&out2)), pair: None };
        let mut rng = Pcg32::new(1);
        let c = simulate_layer_replay(&t, &cfg, &opts, Scheme::InOut, Some(&maps2), &mut rng);
        assert_ne!(a.performed_macs, c.performed_macs);

        // Dense scheme ignores replay payloads entirely.
        let mut r1 = Pcg32::new(7);
        let mut r2 = Pcg32::new(7);
        let dense_replay =
            simulate_layer_replay(&t, &cfg, &opts, Scheme::Dense, Some(&maps), &mut r1);
        let dense_plain = simulate_layer(&t, &cfg, &opts, Scheme::Dense, &mut r2);
        assert_eq!(dense_replay.cycles, dense_plain.cycles);
        assert_eq!(dense_replay.performed_macs, dense_plain.performed_macs);
    }

    #[test]
    fn wg_pair_replay_is_rng_free_and_tracks_joint_density() {
        use std::sync::Arc;
        use crate::sim::replay::{PairMaps, ReplayMap, TaskMaps};
        use crate::sparsity::Bitmap;
        let cfg = AcceleratorConfig::default();
        // WG of a 3x3 stride-1 pad-1 conv: 8 filters, 4ch 8x8 input.
        let t = LayerTask {
            name: "wg".into(),
            m: 8,
            u: 6,
            v: 6, // factor2(4·3·3)
            crs: 64.0, // 8x8 output positions
            in_sparsity: Some(0.7),
            out_sparsity: None,
            input_elems: 4.0 * 64.0 + 8.0 * 64.0,
            weight_elems: 0.0,
            geom: TaskGeom::Wg { r: 3, s: 3, stride: 1, pad: 1, gu: 8, gv: 8, dw: false },
            op_chans: 4,
        };
        let mut map_rng = Pcg32::new(5);
        let act = Bitmap::sample(crate::nn::Shape::new(4, 8, 8), 0.5, &mut map_rng);
        let grad = Bitmap::sample(crate::nn::Shape::new(8, 8, 8), 0.6, &mut map_rng);
        let wrap = |b: &Bitmap| ReplayMap::new(Arc::new(b.clone()));
        let maps = TaskMaps {
            pair: Some(PairMaps { act: Some(wrap(&act)), grad: Some(wrap(&grad)) }),
            ..TaskMaps::default()
        };
        for backend in [ExecBackend::Exact, ExecBackend::Analytic] {
            let opts = SimOptions { backend, ..SimOptions::default() };
            let run = |seed| {
                let mut rng = Pcg32::new(seed);
                simulate_layer_replay(&t, &cfg, &opts, Scheme::In, Some(&maps), &mut rng)
            };
            let a = run(1);
            let b = run(999);
            assert_eq!(a.cycles, b.cycles, "{backend:?} pair replay must be seed-independent");
            assert_eq!(a.performed_macs, b.performed_macs);
            // Joint density: act 0.5 nz x grad 0.6 nz ≈ 0.30 of dense.
            let frac = a.performed_macs / a.dense_macs;
            assert!((0.2..0.4).contains(&frac), "{backend:?} joint MAC fraction {frac:.3}");
        }
        // Streaming gather mode keeps the PR 3 baseline: WG falls back
        // to sampling and so depends on the stream again.
        let opts = SimOptions {
            backend: ExecBackend::Exact,
            gather: GatherMode::Streaming,
            ..SimOptions::default()
        };
        let mut r1 = Pcg32::new(1);
        let mut r2 = Pcg32::new(999);
        let a = simulate_layer_replay(&t, &cfg, &opts, Scheme::In, Some(&maps), &mut r1);
        let b = simulate_layer_replay(&t, &cfg, &opts, Scheme::In, Some(&maps), &mut r2);
        assert_ne!(a.cycles, b.cycles, "streaming mode samples WG");
    }

    #[test]
    fn analytic_replay_measures_per_tile_densities() {
        use std::sync::Arc;
        use crate::sim::replay::{ReplayMap, TaskMaps};
        use crate::sparsity::Bitmap;
        let cfg = AcceleratorConfig::default();
        // 16x16 output on the 16x16 grid: one position per tile, so the
        // measured tile densities are the map bits themselves.
        let t = LayerTask {
            name: "bp".into(),
            m: 4,
            u: 16,
            v: 16,
            crs: 256.0,
            in_sparsity: None,
            out_sparsity: Some(0.5),
            input_elems: 4.0 * 256.0,
            weight_elems: 4.0 * 256.0,
            geom: TaskGeom::Streaming,
            op_chans: 4,
        };
        // Left half dense, right half empty — strong spatial imbalance a
        // global mean would erase.
        let mut out_map = Bitmap::zeros(crate::nn::Shape::new(4, 16, 16));
        for c in 0..4 {
            for y in 0..16 {
                for x in 0..8 {
                    out_map.set(c, y, x, true);
                }
            }
        }
        let maps = TaskMaps {
            output: Some(ReplayMap::new(Arc::new(out_map))),
            ..TaskMaps::default()
        };
        let opts = SimOptions::default(); // analytic backend
        let run = |seed| {
            let mut rng = Pcg32::new(seed);
            simulate_layer_replay(&t, &cfg, &opts, Scheme::InOut, Some(&maps), &mut rng)
        };
        let a = run(3);
        let b = run(777);
        assert_eq!(a.cycles, b.cycles, "measured densities draw no RNG");
        // Exactly the dense half of the outputs computes…
        assert!((a.performed_macs - 0.5 * a.dense_macs).abs() / a.dense_macs < 1e-9);
        // …and the imbalance shows up tile-by-tile: half the busy grid
        // idles, which jittered global fractions could never produce.
        let idle = a.tile_busy.iter().filter(|c| **c == 0.0).count();
        assert_eq!(idle, 128, "right-half tiles are measured empty");
        // The non-replay analytic path at the same mean stays balanced.
        let mut rng = Pcg32::new(3);
        let plain = simulate_layer(&t, &cfg, &opts, Scheme::InOut, &mut rng);
        assert_eq!(plain.tile_busy.iter().filter(|c| **c == 0.0).count(), 0);
    }

    #[test]
    fn tiny_output_map_leaves_tiles_idle() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions::default();
        let mut rng = Pcg32::new(1);
        let t = LayerTask {
            name: "7x7".into(),
            m: 512,
            u: 7,
            v: 7,
            crs: 4608.0,
            in_sparsity: None,
            out_sparsity: None,
            input_elems: 512.0 * 9.0 * 9.0,
            weight_elems: 512.0 * 4608.0,
            geom: TaskGeom::Streaming,
            op_chans: 512,
        };
        let r = simulate_layer(&t, &cfg, &opts, Scheme::Dense, &mut rng);
        let idle = r.tile_busy.iter().filter(|c| **c == 0.0).count();
        assert_eq!(idle, 256 - 49);
    }
}
