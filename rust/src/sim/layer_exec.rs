//! Per-layer execution model: one GEMM-shaped task (a conv/fc in one
//! training phase) mapped onto the PE grid under a sparsity scheme.

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::util::rng::Pcg32;

use super::backend::{exact_tile_cost, ExecBackend};
use super::energy::{layer_energy, EnergyBreakdown};
use super::exact::ExactPe;
use super::memory::layer_traffic;
use super::pe::PeModel;
use super::tile::tile_outputs;
use super::wdu::redistribute;

/// One GEMM-shaped unit of accelerator work (per image).
#[derive(Clone, Debug)]
pub struct LayerTask {
    pub name: String,
    /// Output channels produced (filters / gradient maps).
    pub m: usize,
    /// Spatial output extent (the dimensions tiled across the PE grid).
    pub u: usize,
    pub v: usize,
    /// Receptive field per output value (fractional for strided BP).
    pub crs: f64,
    /// Operand (input) sparsity fraction, if exploitable.
    pub in_sparsity: Option<f64>,
    /// A-priori-known output zero fraction, if exploitable (BP only).
    pub out_sparsity: Option<f64>,
    /// Traffic accounting (elements).
    pub input_elems: f64,
    pub weight_elems: f64,
}

impl LayerTask {
    pub fn outputs(&self) -> usize {
        self.m * self.u * self.v
    }

    pub fn dense_macs(&self) -> f64 {
        self.outputs() as f64 * self.crs
    }
}

/// Result of simulating one `LayerTask` under one scheme.
#[derive(Clone, Debug)]
pub struct LayerSimResult {
    pub name: String,
    pub scheme: Scheme,
    /// Node latency including exposed memory stalls (cycles).
    pub cycles: f64,
    /// Compute-only makespan (max tile completion).
    pub compute_cycles: f64,
    /// Exposed memory stall cycles.
    pub mem_stall: f64,
    pub dense_macs: f64,
    pub performed_macs: f64,
    /// Per-tile busy cycles before redistribution.
    pub tile_busy: Vec<f64>,
    /// Per-tile completion after redistribution (== busy when WR off).
    pub completion: Vec<f64>,
    pub wdu_steals: usize,
    pub energy: EnergyBreakdown,
}

impl LayerSimResult {
    /// Average-to-max tile utilization (Fig 17's metric).
    pub fn tile_utilization(&self) -> f64 {
        let max = self.completion.iter().cloned().fold(0.0, f64::max);
        if max <= 0.0 {
            return 1.0;
        }
        let avg: f64 = self.completion.iter().sum::<f64>() / self.completion.len() as f64;
        avg / max
    }
}

/// Per-tile sparsity variation, applied to the *density* `1 − s` so the
/// induced work variation is `±cv` regardless of the sparsity level
/// (jittering `s` itself would blow up the spread at high sparsity).
/// The deviate is clamped so a single tile cannot dominate
/// unrealistically; calibrated so pre-WR avg/max tile utilization lands
/// near the paper's ~70% (Fig 17).
fn jitter(s: f64, cv: f64, rng: &mut Pcg32) -> f64 {
    if s <= 0.0 {
        return 0.0;
    }
    let g = rng.gauss().clamp(-2.5, 2.5);
    let density = ((1.0 - s) * (1.0 + cv * g)).clamp(0.02, 1.0);
    1.0 - density
}

/// Simulate one layer task (one image) under `scheme`.
pub fn simulate_layer(
    task: &LayerTask,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    scheme: Scheme,
    rng: &mut Pcg32,
) -> LayerSimResult {
    let pe = PeModel::from_config(cfg);
    let s_in = if scheme.uses_input_sparsity() { task.in_sparsity.unwrap_or(0.0) } else { 0.0 };
    let s_out = if scheme.uses_output_sparsity() { task.out_sparsity.unwrap_or(0.0) } else { 0.0 };

    // Exact backend: bitmap-driven tile costing through the event-driven
    // PE; the receptive field is rounded to whole operands (it is only
    // fractional for strided BP averages).
    let exact_pe = (opts.backend == ExecBackend::Exact).then(|| ExactPe::from_config(cfg));
    let crs_exact = (task.crs.round() as usize).max(1);

    // Spatial tiling across the PE grid; every PE computes all M channels
    // of its spatial slice (single filter broadcast at a time, §4.2).
    let spatial = tile_outputs(task.u, task.v, cfg.tx, cfg.ty);

    let mut tile_busy = Vec::with_capacity(spatial.len());
    let mut performed = 0.0f64;
    for &sp in &spatial {
        if sp == 0 {
            tile_busy.push(0.0);
            continue;
        }
        // Per-tile sparsity variation (drives load imbalance / WDU).
        let s_in_t = jitter(s_in, opts.tile_sparsity_cv, rng);
        let s_out_t = jitter(s_out, opts.tile_sparsity_cv, rng);
        match &exact_pe {
            None => {
                let outputs_t = (sp * task.m) as f64;
                let computed = outputs_t * (1.0 - s_out_t);
                let (cyc_per_out, macs_per_out) = pe.cycles_per_output(task.crs, s_in_t);
                tile_busy.push(computed * cyc_per_out);
                performed += computed * macs_per_out;
            }
            Some(xpe) => {
                let (cyc, macs) = exact_tile_cost(
                    xpe,
                    crs_exact,
                    sp * task.m,
                    opts.exact_outputs_per_tile,
                    s_in_t,
                    s_out_t,
                    rng,
                );
                tile_busy.push(cyc);
                performed += macs;
            }
        }
    }

    // Work redistribution.
    let (completion, steals) = if scheme.uses_work_redistribution() {
        let avg_cyc_per_out = {
            let (c, _) = pe.cycles_per_output(task.crs, s_in);
            c
        };
        let overhead_frac =
            (cfg.wr_overhead_cycles_per_output / avg_cyc_per_out).clamp(0.005, 0.5);
        let out = redistribute(&tile_busy, cfg.wr_threshold, overhead_frac);
        (out.completion, out.steals)
    } else {
        (tile_busy.clone(), 0)
    };
    let compute_cycles = completion.iter().cloned().fold(0.0, f64::max);

    // Memory.
    let output_elems = task.outputs() as f64;
    let traffic = layer_traffic(
        task.input_elems,
        task.weight_elems,
        output_elems,
        cfg.operand_bytes as f64,
        s_in,
        s_out,
    );
    let mem_stall = traffic.stall_cycles(cfg, compute_cycles, opts.overlap_dram);
    let cycles = compute_cycles + mem_stall;

    // Energy: operands staged through SRAM per MAC (2 operands × 2 B),
    // outputs encoded once (§4.2).
    let busy: f64 = tile_busy.iter().sum();
    let energy = layer_energy(
        cfg,
        performed,
        output_elems,
        performed * (2.0 * cfg.operand_bytes as f64),
        traffic.dram_read_bytes + traffic.dram_write_bytes,
        busy,
        cycles,
    );

    LayerSimResult {
        name: task.name.clone(),
        scheme,
        cycles,
        compute_cycles,
        mem_stall,
        dense_macs: task.dense_macs(),
        performed_macs: performed,
        tile_busy,
        completion,
        wdu_steals: steals,
        energy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(in_sp: Option<f64>, out_sp: Option<f64>) -> LayerTask {
        LayerTask {
            name: "test".into(),
            m: 128,
            u: 28,
            v: 28,
            crs: 1152.0, // 128·3·3
            in_sparsity: in_sp,
            out_sparsity: out_sp,
            input_elems: 128.0 * 30.0 * 30.0,
            weight_elems: 128.0 * 1152.0,
        }
    }

    fn run(scheme: Scheme, in_sp: Option<f64>, out_sp: Option<f64>) -> LayerSimResult {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions::default();
        let mut rng = Pcg32::new(7);
        simulate_layer(&task(in_sp, out_sp), &cfg, &opts, scheme, &mut rng)
    }

    #[test]
    fn dense_performs_all_macs() {
        let r = run(Scheme::Dense, Some(0.5), Some(0.5));
        assert!((r.performed_macs - r.dense_macs).abs() / r.dense_macs < 1e-9);
        assert_eq!(r.wdu_steals, 0);
    }

    #[test]
    fn scheme_ordering_dc_ge_in_ge_inout_ge_wr() {
        let (si, so) = (Some(0.5), Some(0.5));
        let dc = run(Scheme::Dense, si, so).cycles;
        let inp = run(Scheme::In, si, so).cycles;
        let both = run(Scheme::InOut, si, so).cycles;
        let wr = run(Scheme::InOutWr, si, so).cycles;
        assert!(dc > inp, "DC {dc} !> IN {inp}");
        assert!(inp > both, "IN {inp} !> IN+OUT {both}");
        assert!(wr <= both * 1.001, "WR {wr} !<= IN+OUT {both}");
    }

    #[test]
    fn speedups_in_papers_range() {
        // 50% input + 50% output sparsity → ideal 4×; with imbalance and
        // overheads the model should land in the 2–4× band (Fig 11).
        let dc = run(Scheme::Dense, Some(0.5), Some(0.5)).cycles;
        let wr = run(Scheme::InOutWr, Some(0.5), Some(0.5)).cycles;
        let speedup = dc / wr;
        assert!((1.8..4.2).contains(&speedup), "speedup {speedup}");
    }

    #[test]
    fn output_sparsity_skips_macs() {
        let r = run(Scheme::InOut, None, Some(0.5));
        // ≈half the outputs skipped entirely
        let frac = r.performed_macs / r.dense_macs;
        assert!((0.4..0.6).contains(&frac), "frac {frac}");
    }

    #[test]
    fn in_scheme_ignores_output_sparsity() {
        let a = run(Scheme::In, Some(0.5), Some(0.9)).cycles;
        let b = run(Scheme::In, Some(0.5), None).cycles;
        assert!((a - b).abs() / b < 1e-9);
    }

    #[test]
    fn wdu_improves_tile_utilization() {
        let cfg = AcceleratorConfig::default();
        let mut opts = SimOptions::default();
        opts.tile_sparsity_cv = 0.35; // strong imbalance
        let mut rng = Pcg32::new(3);
        let t = task(Some(0.5), Some(0.5));
        let no_wr = simulate_layer(&t, &cfg, &opts, Scheme::InOut, &mut rng);
        let mut rng = Pcg32::new(3);
        let wr = simulate_layer(&t, &cfg, &opts, Scheme::InOutWr, &mut rng);
        assert!(
            wr.tile_utilization() > no_wr.tile_utilization(),
            "WR {:.3} !> no-WR {:.3}",
            wr.tile_utilization(),
            no_wr.tile_utilization()
        );
        assert!(wr.compute_cycles <= no_wr.compute_cycles * 1.001);
    }

    #[test]
    fn energy_positive_and_reduced_by_sparsity() {
        let dc = run(Scheme::Dense, Some(0.5), Some(0.5));
        let wr = run(Scheme::InOutWr, Some(0.5), Some(0.5));
        assert!(dc.energy.total() > 0.0);
        assert!(wr.energy.total() < dc.energy.total());
    }

    #[test]
    fn exact_backend_is_deterministic_and_orders_schemes() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions {
            backend: ExecBackend::Exact,
            exact_outputs_per_tile: 8,
            ..SimOptions::default()
        };
        let t = LayerTask {
            name: "exact".into(),
            m: 32,
            u: 16,
            v: 16,
            crs: 288.0,
            in_sparsity: Some(0.5),
            out_sparsity: Some(0.5),
            input_elems: 32.0 * 18.0 * 18.0,
            weight_elems: 32.0 * 288.0,
        };
        let run = |scheme, seed| {
            let mut rng = Pcg32::new(seed);
            simulate_layer(&t, &cfg, &opts, scheme, &mut rng)
        };
        let a = run(Scheme::InOutWr, 7);
        let b = run(Scheme::InOutWr, 7);
        assert_eq!(a.cycles, b.cycles, "exact backend must be stream-deterministic");
        assert_eq!(a.performed_macs, b.performed_macs);
        let dc = run(Scheme::Dense, 7);
        let inp = run(Scheme::In, 7);
        let both = run(Scheme::InOut, 7);
        assert!((dc.performed_macs - dc.dense_macs).abs() / dc.dense_macs < 1e-9);
        assert!(dc.cycles > inp.cycles, "DC {} !> IN {}", dc.cycles, inp.cycles);
        assert!(inp.cycles > both.cycles, "IN {} !> IN+OUT {}", inp.cycles, both.cycles);
    }

    #[test]
    fn tiny_output_map_leaves_tiles_idle() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions::default();
        let mut rng = Pcg32::new(1);
        let t = LayerTask {
            name: "7x7".into(),
            m: 512,
            u: 7,
            v: 7,
            crs: 4608.0,
            in_sparsity: None,
            out_sparsity: None,
            input_elems: 512.0 * 9.0 * 9.0,
            weight_elems: 512.0 * 4608.0,
        };
        let r = simulate_layer(&t, &cfg, &opts, Scheme::Dense, &mut rng);
        let idle = r.tile_busy.iter().filter(|c| **c == 0.0).count();
        assert_eq!(idle, 256 - 49);
    }
}
