//! Memory system model: SRAM lane feed, H-tree weight broadcast, and the
//! 16-channel DDR3 DRAM with compute overlap (§4.3, §5.2, §6).

use crate::config::AcceleratorConfig;

/// Per-layer memory traffic and the stall cycles it induces.
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryModel {
    /// Bytes fetched from DRAM (inputs + weights + bitmap/offsets).
    pub dram_read_bytes: f64,
    /// Bytes written back to DRAM (outputs + updated bitmaps).
    pub dram_write_bytes: f64,
    /// Weight-broadcast bytes over the H-tree.
    pub broadcast_bytes: f64,
}

impl MemoryModel {
    /// DRAM transfer time in cycles at the configured aggregate bandwidth.
    pub fn dram_cycles(&self, cfg: &AcceleratorConfig) -> f64 {
        let bytes = self.dram_read_bytes + self.dram_write_bytes;
        bytes / cfg.dram_bw() * cfg.freq_hz
    }

    /// H-tree broadcast time in cycles.
    pub fn broadcast_cycles(&self, cfg: &AcceleratorConfig) -> f64 {
        self.broadcast_bytes / cfg.memory.htree_bw * cfg.freq_hz
    }

    /// Stall cycles exposed beyond `compute_cycles`.
    ///
    /// §6: streaming access patterns let most DRAM traffic overlap with
    /// compute; a `cold_fraction` of the transfer (first tile fill /
    /// final drain) cannot overlap.
    pub fn stall_cycles(&self, cfg: &AcceleratorConfig, compute_cycles: f64, overlap: bool) -> f64 {
        let mem = self.dram_cycles(cfg) + self.broadcast_cycles(cfg);
        if !overlap {
            return mem;
        }
        let cold_fraction = 0.05;
        let cold = mem * cold_fraction;
        let pipelined = mem * (1.0 - cold_fraction);
        cold + (pipelined - compute_cycles).max(0.0)
    }
}

/// Traffic for one layer execution (per image).
///
/// * Inputs stream in once (halo included); with input sparsity only the
///   indexed non-zeros plus the offset map move.
/// * Weights stream once per layer and broadcast to all PEs.
/// * Outputs write back once; the bitmap adds 1 bit per neuron.
pub fn layer_traffic(
    input_elems: f64,
    weight_elems: f64,
    output_elems: f64,
    operand_bytes: f64,
    in_sparsity: f64,
    out_sparsity: f64,
) -> MemoryModel {
    let in_density = 1.0 - in_sparsity;
    let out_density = 1.0 - out_sparsity;
    // Non-zeros + 5-bit offsets (5/8 byte each) + within-channel bitmap.
    let input_bytes =
        input_elems * in_density * operand_bytes + input_elems * in_density * 0.625 + input_elems / 8.0;
    let weight_bytes = weight_elems * operand_bytes;
    let output_bytes = output_elems * out_density * operand_bytes + output_elems / 8.0;
    MemoryModel {
        dram_read_bytes: input_bytes + weight_bytes,
        dram_write_bytes: output_bytes,
        broadcast_bytes: weight_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    #[test]
    fn dram_cycles_scale_with_bytes() {
        let m = MemoryModel { dram_read_bytes: 201.6e9, dram_write_bytes: 0.0, ..Default::default() };
        // 201.6 GB at 201.6 GB/s = 1 s = 667e6 cycles.
        assert!((m.dram_cycles(&cfg()) - 667e6).abs() < 1e3);
    }

    #[test]
    fn overlap_hides_traffic_under_compute() {
        let m = MemoryModel { dram_read_bytes: 1e6, dram_write_bytes: 0.0, ..Default::default() };
        let mem_cycles = m.dram_cycles(&cfg());
        // plenty of compute: only the cold fraction shows
        let stall = m.stall_cycles(&cfg(), mem_cycles * 10.0, true);
        assert!((stall - 0.05 * mem_cycles).abs() / mem_cycles < 1e-6);
        // no compute to hide behind: full exposure
        let stall2 = m.stall_cycles(&cfg(), 0.0, true);
        assert!((stall2 - mem_cycles).abs() / mem_cycles < 1e-6);
        // overlap disabled: full cost regardless
        assert!((m.stall_cycles(&cfg(), 1e12, false) - mem_cycles).abs() < 1.0);
    }

    #[test]
    fn sparsity_reduces_traffic() {
        let dense = layer_traffic(1e6, 1e5, 1e6, 2.0, 0.0, 0.0);
        let sparse = layer_traffic(1e6, 1e5, 1e6, 2.0, 0.5, 0.5);
        assert!(sparse.dram_read_bytes < dense.dram_read_bytes);
        assert!(sparse.dram_write_bytes < dense.dram_write_bytes);
        // weights unaffected
        assert!((sparse.broadcast_bytes - dense.broadcast_bytes).abs() < 1e-9);
    }

    #[test]
    fn paper_communication_ratio_example() {
        // §6: fmap [128×28×28], filter [128×128×3×3] — communication is a
        // modest fraction of compute (~15%) for the dense case.
        let input = 128.0 * 30.0 * 30.0; // with halo
        let weights = 128.0 * 128.0 * 9.0;
        let output = 128.0 * 28.0 * 28.0;
        let m = layer_traffic(input, weights, output, 2.0, 0.0, 0.0);
        let mem_cycles = m.dram_cycles(&cfg());
        // dense compute cycles ≈ MACs / 4096 per cycle
        let macs = 128.0f64 * 28.0 * 28.0 * 128.0 * 9.0;
        let compute = macs / 4096.0;
        let ratio = mem_cycles / compute;
        assert!((0.02..0.4).contains(&ratio), "ratio {ratio}");
    }
}
