//! The network DAG with builder API and shape inference.
//!
//! Layers are appended in topological order (a layer's inputs must
//! already exist), which every later traversal exploits: forward order is
//! insertion order, backward order is the reverse.

use anyhow::{ensure, Result};

use super::{Layer, LayerId, LayerKind, Shape};

/// A CNN as a DAG of layers.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    layers: Vec<Layer>,
}

impl Network {
    pub fn new(name: &str) -> Network {
        Network { name: name.to_string(), layers: Vec::new() }
    }

    pub fn layers(&self) -> &[Layer] {
        &self.layers
    }

    pub fn layer(&self, id: LayerId) -> &Layer {
        &self.layers[id]
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    pub fn by_name(&self, name: &str) -> Option<&Layer> {
        self.layers.iter().find(|l| l.name == name)
    }

    /// Consumer adjacency for the whole graph in one O(edges) pass —
    /// use this instead of per-layer `consumers()` in traversals (the
    /// per-layer scan is O(L²) over DenseNet's ~800 layers).
    pub fn consumer_map(&self) -> Vec<Vec<LayerId>> {
        let mut map = vec![Vec::new(); self.layers.len()];
        for l in &self.layers {
            for &i in &l.inputs {
                map[i].push(l.id);
            }
        }
        map
    }

    /// All layers consuming `id`'s output.
    pub fn consumers(&self, id: LayerId) -> Vec<LayerId> {
        self.layers
            .iter()
            .filter(|l| l.inputs.contains(&id))
            .map(|l| l.id)
            .collect()
    }

    /// Input shape of a single-input layer.
    pub fn input_shape(&self, id: LayerId) -> Shape {
        let l = &self.layers[id];
        assert!(!l.inputs.is_empty(), "layer '{}' has no inputs", l.name);
        self.layers[l.inputs[0]].out
    }

    // ---- builder ---------------------------------------------------------

    fn push(&mut self, name: &str, kind: LayerKind, inputs: Vec<LayerId>, out: Shape) -> LayerId {
        let id = self.layers.len();
        for &i in &inputs {
            assert!(i < id, "layer '{name}' references future layer {i}");
        }
        assert!(
            self.by_name(name).is_none(),
            "duplicate layer name '{name}' in network '{}'",
            self.name
        );
        self.layers.push(Layer { id, name: name.to_string(), kind, inputs, out });
        id
    }

    pub fn input(&mut self, c: usize, h: usize, w: usize) -> LayerId {
        assert!(self.layers.is_empty(), "input must be the first layer");
        self.push("input", LayerKind::Input, vec![], Shape::new(c, h, w))
    }

    pub fn conv(
        &mut self,
        name: &str,
        from: LayerId,
        m: usize,
        k: usize,
        stride: usize,
        pad: usize,
    ) -> LayerId {
        let in_shape = self.layers[from].out;
        let (u, v) = in_shape.conv_out(k, stride, pad);
        self.push(
            name,
            LayerKind::Conv { m, r: k, s: k, stride, pad },
            vec![from],
            Shape::new(m, u, v),
        )
    }

    pub fn dwconv(&mut self, name: &str, from: LayerId, k: usize, stride: usize, pad: usize) -> LayerId {
        let in_shape = self.layers[from].out;
        let (u, v) = in_shape.conv_out(k, stride, pad);
        self.push(
            name,
            LayerKind::DwConv { r: k, s: k, stride, pad },
            vec![from],
            Shape::new(in_shape.c, u, v),
        )
    }

    pub fn relu(&mut self, name: &str, from: LayerId) -> LayerId {
        let out = self.layers[from].out;
        self.push(name, LayerKind::ReLU, vec![from], out)
    }

    pub fn bn(&mut self, name: &str, from: LayerId) -> LayerId {
        let out = self.layers[from].out;
        self.push(name, LayerKind::BatchNorm, vec![from], out)
    }

    pub fn maxpool(&mut self, name: &str, from: LayerId, k: usize, stride: usize, pad: usize) -> LayerId {
        let in_shape = self.layers[from].out;
        let (u, v) = in_shape.conv_out(k, stride, pad);
        self.push(
            name,
            LayerKind::MaxPool { k, stride, pad },
            vec![from],
            Shape::new(in_shape.c, u, v),
        )
    }

    pub fn avgpool(&mut self, name: &str, from: LayerId, k: usize, stride: usize, pad: usize) -> LayerId {
        let in_shape = self.layers[from].out;
        let (u, v) = in_shape.conv_out(k, stride, pad);
        self.push(
            name,
            LayerKind::AvgPool { k, stride, pad },
            vec![from],
            Shape::new(in_shape.c, u, v),
        )
    }

    pub fn gap(&mut self, name: &str, from: LayerId) -> LayerId {
        let in_shape = self.layers[from].out;
        self.push(name, LayerKind::GlobalAvgPool, vec![from], Shape::new(in_shape.c, 1, 1))
    }

    pub fn fc(&mut self, name: &str, from: LayerId, out: usize) -> LayerId {
        self.push(name, LayerKind::Fc { out }, vec![from], Shape::new(out, 1, 1))
    }

    pub fn add(&mut self, name: &str, a: LayerId, b: LayerId) -> LayerId {
        let sa = self.layers[a].out;
        let sb = self.layers[b].out;
        assert_eq!(sa, sb, "Add '{name}': shapes {sa} vs {sb}");
        self.push(name, LayerKind::Add, vec![a, b], sa)
    }

    pub fn concat(&mut self, name: &str, from: &[LayerId]) -> LayerId {
        assert!(from.len() >= 2, "Concat '{name}' needs >= 2 inputs");
        let first = self.layers[from[0]].out;
        let mut c = 0;
        for &i in from {
            let s = self.layers[i].out;
            assert_eq!((s.h, s.w), (first.h, first.w), "Concat '{name}': spatial mismatch");
            c += s.c;
        }
        self.push(name, LayerKind::Concat, from.to_vec(), Shape::new(c, first.h, first.w))
    }

    pub fn softmax(&mut self, name: &str, from: LayerId) -> LayerId {
        let out = self.layers[from].out;
        self.push(name, LayerKind::Softmax, vec![from], out)
    }

    // ---- validation --------------------------------------------------------

    /// Structural sanity: connectivity, single input, shapes consistent.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.layers.is_empty(), "empty network");
        ensure!(
            matches!(self.layers[0].kind, LayerKind::Input),
            "first layer must be Input"
        );
        for l in &self.layers[1..] {
            ensure!(!l.inputs.is_empty(), "layer '{}' is disconnected", l.name);
            ensure!(
                !matches!(l.kind, LayerKind::Input),
                "second Input layer '{}'",
                l.name
            );
        }
        // every non-terminal layer should be consumed
        for l in &self.layers {
            if self.consumers(l.id).is_empty() && l.id != self.layers.len() - 1 {
                // allow multiple heads only if explicitly terminal kinds
                ensure!(
                    matches!(l.kind, LayerKind::Softmax),
                    "dangling layer '{}' (id {})",
                    l.name,
                    l.id
                );
            }
        }
        Ok(())
    }

    /// Conv/DwConv/Fc layers in forward order (what the accelerator runs).
    pub fn compute_layers(&self) -> Vec<&Layer> {
        self.layers.iter().filter(|l| l.kind.is_compute()).collect()
    }

    /// Structural fingerprint: name, every layer's name/kind/parameters,
    /// wiring and output shape. Used by the sweep cache (`sim::sweep`) so
    /// two different networks sharing a name can never alias a cached
    /// simulation result.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fnv::Fnv1a::new();
        h.put_str(&self.name);
        for l in &self.layers {
            h.put_str(&l.name);
            h.put_str(l.kind.label());
            match l.kind {
                LayerKind::Conv { m, r, s, stride, pad } => {
                    h.put(m as u64)
                        .put(r as u64)
                        .put(s as u64)
                        .put(stride as u64)
                        .put(pad as u64);
                }
                LayerKind::DwConv { r, s, stride, pad } => {
                    h.put(r as u64).put(s as u64).put(stride as u64).put(pad as u64);
                }
                LayerKind::Fc { out } => {
                    h.put(out as u64);
                }
                LayerKind::MaxPool { k, stride, pad } | LayerKind::AvgPool { k, stride, pad } => {
                    h.put(k as u64).put(stride as u64).put(pad as u64);
                }
                _ => {}
            }
            for &i in &l.inputs {
                h.put(i as u64);
            }
            h.put(l.out.c as u64).put(l.out.h as u64).put(l.out.w as u64);
        }
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Network {
        let mut n = Network::new("tiny");
        let x = n.input(3, 8, 8);
        let c1 = n.conv("c1", x, 16, 3, 1, 1);
        let r1 = n.relu("r1", c1);
        let c2 = n.conv("c2", r1, 32, 3, 2, 1);
        let r2 = n.relu("r2", c2);
        let g = n.gap("gap", r2);
        let f = n.fc("fc", g, 10);
        n.softmax("sm", f);
        n
    }

    #[test]
    fn shapes_infer() {
        let n = tiny();
        assert_eq!(n.by_name("c1").unwrap().out, Shape::new(16, 8, 8));
        assert_eq!(n.by_name("c2").unwrap().out, Shape::new(32, 4, 4));
        assert_eq!(n.by_name("gap").unwrap().out, Shape::new(32, 1, 1));
        assert_eq!(n.by_name("fc").unwrap().out, Shape::new(10, 1, 1));
        n.validate().unwrap();
    }

    #[test]
    fn consumers_and_compute() {
        let n = tiny();
        let c1 = n.by_name("c1").unwrap().id;
        assert_eq!(n.consumers(c1), vec![n.by_name("r1").unwrap().id]);
        assert_eq!(n.compute_layers().len(), 3); // c1, c2, fc
        // consumer_map agrees with per-layer consumers
        let map = n.consumer_map();
        for l in n.layers() {
            assert_eq!(map[l.id], n.consumers(l.id), "{}", l.name);
        }
    }

    #[test]
    fn add_and_concat_shapes() {
        let mut n = Network::new("resblock");
        let x = n.input(64, 56, 56);
        let c1 = n.conv("c1", x, 64, 3, 1, 1);
        let r1 = n.relu("r1", c1);
        let c2 = n.conv("c2", r1, 64, 3, 1, 1);
        let a = n.add("add", c2, x);
        assert_eq!(n.layer(a).out, Shape::new(64, 56, 56));
        let cat = n.concat("cat", &[a, r1]);
        assert_eq!(n.layer(cat).out, Shape::new(128, 56, 56));
    }

    #[test]
    fn fingerprint_sees_structure_not_just_name() {
        let a = tiny();
        assert_eq!(a.fingerprint(), tiny().fingerprint());
        // Same layer names and count, different conv width: must differ.
        let mut b = Network::new("tiny");
        let x = b.input(3, 8, 8);
        let c1 = b.conv("c1", x, 8, 3, 1, 1); // 8 filters instead of 16
        let r1 = b.relu("r1", c1);
        let c2 = b.conv("c2", r1, 32, 3, 2, 1);
        let r2 = b.relu("r2", c2);
        let g = b.gap("gap", r2);
        let f = b.fc("fc", g, 10);
        b.softmax("sm", f);
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    #[should_panic(expected = "duplicate layer name")]
    fn duplicate_names_panic() {
        let mut n = Network::new("dup");
        let x = n.input(3, 4, 4);
        n.conv("c", x, 8, 3, 1, 1);
        n.conv("c", x, 8, 3, 1, 1);
    }

    #[test]
    fn validate_catches_dangling() {
        let mut n = Network::new("dangle");
        let x = n.input(3, 4, 4);
        let c1 = n.conv("c1", x, 8, 3, 1, 1);
        n.conv("c2", x, 8, 3, 1, 1); // dangling — never consumed
        n.relu("r", c1);
        assert!(n.validate().is_err());
    }
}
