//! MAC accounting per layer and training phase.
//!
//! The paper's cost model (§2.1): a conv `[C,H,W] --[M,C,R,S]--> [M,U,V]`
//! costs `M·U·V·C·R·S` MACs in the forward pass. The backward input-
//! gradient pass and the weight-gradient pass perform the same multiset
//! of multiply-accumulates (each (weight, activation/gradient) pairing is
//! visited exactly once in each phase), so their dense MAC counts equal
//! the forward count. Pooling/ReLU/BN are not MAC work for the
//! accelerator's GEMM datapath and count zero here.

use super::{Layer, LayerKind, Network};

/// Training phase (§1 Fig 1): forward, backward (input gradients),
/// weight gradients.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Forward,
    Backward,
    WeightGrad,
}

impl Phase {
    pub const ALL: [Phase; 3] = [Phase::Forward, Phase::Backward, Phase::WeightGrad];

    pub fn label(&self) -> &'static str {
        match self {
            Phase::Forward => "FP",
            Phase::Backward => "BP",
            Phase::WeightGrad => "WG",
        }
    }

    /// Inverse of [`Phase::label`] (used when results round-trip through
    /// JSON, e.g. the on-disk sweep cache).
    pub fn from_label(label: &str) -> Option<Phase> {
        Phase::ALL.into_iter().find(|p| p.label() == label)
    }
}

/// Dense MACs for one layer in one phase (per single input image).
pub fn layer_macs(net: &Network, layer: &Layer, phase: Phase) -> u64 {
    let dense = match layer.kind {
        LayerKind::Conv { m, r, s, .. } => {
            let cin = net.input_shape(layer.id).c;
            (m * layer.out.h * layer.out.w) as u64 * (cin * r * s) as u64
        }
        LayerKind::DwConv { r, s, .. } => {
            (layer.out.c * layer.out.h * layer.out.w) as u64 * (r * s) as u64
        }
        LayerKind::Fc { out } => {
            let cin = net.input_shape(layer.id).len();
            (out as u64) * (cin as u64)
        }
        _ => 0,
    };
    match phase {
        Phase::Forward => dense,
        // Same pairing count; the first compute layer has no backward
        // input-gradient to produce (nothing consumes d(image)).
        Phase::Backward => {
            if is_first_compute(net, layer) {
                0
            } else {
                dense
            }
        }
        Phase::WeightGrad => dense,
    }
}

fn is_first_compute(net: &Network, layer: &Layer) -> bool {
    net.compute_layers().first().map(|l| l.id) == Some(layer.id)
}

/// Total dense MACs for a whole network in one phase.
pub fn network_macs(net: &Network, phase: Phase) -> u64 {
    net.layers().iter().map(|l| layer_macs(net, l, phase)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_macs_match_formula() {
        let mut n = Network::new("t");
        let x = n.input(3, 224, 224);
        let c = n.conv("c1", x, 64, 3, 1, 1);
        let l = n.layer(c);
        // 64·224·224·3·3·3
        assert_eq!(
            layer_macs(&n, l, Phase::Forward),
            64 * 224 * 224 * 27
        );
        // first compute layer: no BP input gradient
        assert_eq!(layer_macs(&n, l, Phase::Backward), 0);
        assert_eq!(layer_macs(&n, l, Phase::WeightGrad), 64 * 224 * 224 * 27);
    }

    #[test]
    fn bp_equals_fp_for_inner_layers() {
        let mut n = Network::new("t");
        let x = n.input(3, 32, 32);
        let c1 = n.conv("c1", x, 16, 3, 1, 1);
        let r1 = n.relu("r1", c1);
        let c2 = n.conv("c2", r1, 32, 3, 1, 1);
        let l2 = n.layer(c2);
        assert_eq!(
            layer_macs(&n, l2, Phase::Forward),
            layer_macs(&n, l2, Phase::Backward)
        );
    }

    #[test]
    fn dwconv_and_fc() {
        let mut n = Network::new("t");
        let x = n.input(32, 8, 8);
        let d = n.dwconv("dw", x, 3, 1, 1);
        assert_eq!(layer_macs(&n, n.layer(d), Phase::Forward), 32 * 8 * 8 * 9);
        let g = n.gap("g", d);
        let f = n.fc("fc", g, 10);
        assert_eq!(layer_macs(&n, n.layer(f), Phase::Forward), 320);
        // relu/pool cost nothing
        let r = n.relu("r", f);
        assert_eq!(layer_macs(&n, n.layer(r), Phase::Forward), 0);
    }

    #[test]
    fn network_total_sums() {
        let mut n = Network::new("t");
        let x = n.input(3, 8, 8);
        let c1 = n.conv("c1", x, 4, 3, 1, 1);
        let r1 = n.relu("r1", c1);
        n.conv("c2", r1, 8, 3, 1, 1);
        let total = network_macs(&n, Phase::Forward);
        assert_eq!(total, (4 * 64 * 27 + 8 * 64 * 36) as u64);
    }
}
