//! CNN layer-graph IR: layers, DAG with shape inference, MAC accounting,
//! and the ImageNet model zoo the paper evaluates (VGG-16, ResNet-18,
//! GoogLeNet, DenseNet-121, MobileNet-v1).
//!
//! Shapes use the paper's notation: feature maps are `[C, H, W]`, conv
//! filters `[M, C, R, S]`, outputs `[M, U, V]` (§2.1).

mod tensor;
mod layer;
mod graph;
mod flops;
pub mod zoo;

pub use tensor::Shape;
pub use layer::{Layer, LayerId, LayerKind};
pub use graph::Network;
pub use flops::{layer_macs, network_macs, Phase};
