//! The five ImageNet CNNs the paper evaluates (§5.1), built layer-by-layer
//! with the `Network` builder at 224×224×3 input resolution.
//!
//! VGG-16 and GoogLeNet are BN-free (conv–ReLU chains ⇒ both input and
//! output sparsity in BP); ResNet-18, DenseNet-121 and MobileNet-v1 carry
//! BatchNorm (conv–BN–ReLU ⇒ only *output* sparsity in BP) — the
//! structural distinction §6 organizes its results around.

mod agos_cnn;
mod agos_resnet;
mod vgg16;
mod resnet18;
mod googlenet;
mod densenet121;
mod mobilenetv1;

pub use agos_cnn::agos_cnn;
pub use agos_resnet::agos_resnet;
pub use densenet121::densenet121;
pub use googlenet::googlenet;
pub use mobilenetv1::mobilenet_v1;
pub use resnet18::resnet18;
pub use vgg16::vgg16;

use super::Network;

/// All five evaluated networks, in the paper's reporting order.
pub fn all_networks() -> Vec<Network> {
    vec![vgg16(), resnet18(), googlenet(), densenet121(), mobilenet_v1()]
}

/// Look a network up by (case-insensitive) name.
pub fn by_name(name: &str) -> anyhow::Result<Network> {
    match name.to_ascii_lowercase().as_str() {
        "vgg" | "vgg16" | "vgg-16" => Ok(vgg16()),
        "resnet" | "resnet18" | "resnet-18" => Ok(resnet18()),
        "googlenet" | "inception" => Ok(googlenet()),
        "densenet" | "densenet121" | "densenet-121" => Ok(densenet121()),
        "mobilenet" | "mobilenetv1" | "mobilenet-v1" | "mobilenet_v1" => Ok(mobilenet_v1()),
        "agos_cnn" | "agos-cnn" | "agos" => Ok(agos_cnn()),
        "agos_resnet" | "agos-resnet" => Ok(agos_resnet()),
        other => anyhow::bail!(
            "unknown network '{other}' \
             (vgg16|resnet18|googlenet|densenet121|mobilenet|agos_cnn|agos_resnet)"
        ),
    }
}

/// Parse a comma-separated network list; the literal `"all"` selects
/// [`all_networks`]. Shared by the CLI's `--networks` and the served
/// `sweep` request so both spell the same grids identically.
pub fn by_list(spec: &str) -> anyhow::Result<Vec<Network>> {
    if spec == "all" {
        return Ok(all_networks());
    }
    spec.split(',').map(|n| by_name(n.trim())).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves_all() {
        for n in [
            "vgg16",
            "resnet18",
            "googlenet",
            "densenet121",
            "mobilenet",
            "agos_cnn",
            "agos_resnet",
        ] {
            assert!(by_name(n).is_ok(), "{n}");
        }
        assert!(by_name("AGOS_CNN").is_ok(), "case-insensitive");
        assert!(by_name("alexnet").is_err());
    }

    #[test]
    fn all_networks_validate() {
        for net in all_networks() {
            net.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
        }
    }
}
