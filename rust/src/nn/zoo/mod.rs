//! The five ImageNet CNNs the paper evaluates (§5.1), built layer-by-layer
//! with the `Network` builder at 224×224×3 input resolution.
//!
//! VGG-16 and GoogLeNet are BN-free (conv–ReLU chains ⇒ both input and
//! output sparsity in BP); ResNet-18, DenseNet-121 and MobileNet-v1 carry
//! BatchNorm (conv–BN–ReLU ⇒ only *output* sparsity in BP) — the
//! structural distinction §6 organizes its results around.

mod agos_cnn;
mod agos_resnet;
mod vgg16;
mod resnet18;
mod googlenet;
mod densenet121;
mod mobilenetv1;

pub use agos_cnn::agos_cnn;
pub use agos_resnet::agos_resnet;
pub use densenet121::densenet121;
pub use googlenet::googlenet;
pub use mobilenetv1::mobilenet_v1;
pub use resnet18::resnet18;
pub use vgg16::vgg16;

use super::Network;

/// Canonical (non-alias) names [`by_name`] accepts — the five paper
/// networks in reporting order plus the two in-house ones. Error
/// messages and docs quote this list so it stays the single source of
/// truth for what a zoo reference may spell.
pub const VALID_NAMES: [&str; 7] = [
    "vgg16",
    "resnet18",
    "googlenet",
    "densenet121",
    "mobilenet",
    "agos_cnn",
    "agos_resnet",
];

/// All five evaluated networks, in the paper's reporting order.
pub fn all_networks() -> Vec<Network> {
    vec![vgg16(), resnet18(), googlenet(), densenet121(), mobilenet_v1()]
}

/// Look a network up by (case-insensitive) name.
pub fn by_name(name: &str) -> anyhow::Result<Network> {
    match name.to_ascii_lowercase().as_str() {
        "vgg" | "vgg16" | "vgg-16" => Ok(vgg16()),
        "resnet" | "resnet18" | "resnet-18" => Ok(resnet18()),
        "googlenet" | "inception" => Ok(googlenet()),
        "densenet" | "densenet121" | "densenet-121" => Ok(densenet121()),
        "mobilenet" | "mobilenetv1" | "mobilenet-v1" | "mobilenet_v1" => Ok(mobilenet_v1()),
        "agos_cnn" | "agos-cnn" | "agos" => Ok(agos_cnn()),
        "agos_resnet" | "agos-resnet" => Ok(agos_resnet()),
        other => anyhow::bail!("unknown network '{other}' (valid: {})", VALID_NAMES.join(", ")),
    }
}

/// Parse a comma-separated network list; the literal `"all"` selects
/// [`all_networks`]. Shared by the CLI's `--networks`, the served
/// `sweep` request and scenario `zoo`/`adversarial` generators so all
/// spell the same grids identically. An unknown entry is rejected with
/// the offending name, the list it appeared in, and every valid name —
/// scenario files reference zoo entries by name, so the error must
/// carry enough context to fix the file without reading the source.
pub fn by_list(spec: &str) -> anyhow::Result<Vec<Network>> {
    if spec.trim() == "all" {
        return Ok(all_networks());
    }
    spec.split(',')
        .map(|n| {
            let n = n.trim();
            by_name(n).map_err(|_| {
                anyhow::anyhow!(
                    "unknown network '{n}' in list '{spec}' (valid: {}, or 'all')",
                    VALID_NAMES.join(", ")
                )
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves_all() {
        for n in [
            "vgg16",
            "resnet18",
            "googlenet",
            "densenet121",
            "mobilenet",
            "agos_cnn",
            "agos_resnet",
        ] {
            assert!(by_name(n).is_ok(), "{n}");
        }
        assert!(by_name("AGOS_CNN").is_ok(), "case-insensitive");
        assert!(by_name("alexnet").is_err());
    }

    #[test]
    fn by_list_rejects_unknown_names_with_full_context() {
        let err = by_list("vgg16,alexnet").unwrap_err().to_string();
        assert!(err.contains("'alexnet'"), "offending entry named: {err}");
        assert!(err.contains("'vgg16,alexnet'"), "full list quoted: {err}");
        for valid in VALID_NAMES {
            assert!(err.contains(valid), "'{valid}' missing from error: {err}");
        }
        assert!(err.contains("'all'"), "the 'all' shorthand is advertised: {err}");
    }

    #[test]
    fn by_list_parses_lists_and_all() {
        assert_eq!(by_list("vgg16, resnet18").unwrap().len(), 2);
        assert_eq!(by_list(" all ").unwrap().len(), all_networks().len());
        for name in VALID_NAMES {
            assert!(by_list(name).is_ok(), "{name}");
        }
    }

    #[test]
    fn all_networks_validate() {
        for net in all_networks() {
            net.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
        }
    }
}
