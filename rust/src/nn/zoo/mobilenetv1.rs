//! MobileNet-v1 (Howard et al.) — depthwise-separable convolutions, BN.
//!
//! Linear structure like VGG but every "conv" is dw3x3 + pw1x1, each with
//! BN+ReLU. The paper reports results for the pointwise (pw) convs only
//! (the dw layers are not a compute bottleneck — Fig 12b), with output
//! sparsity + WR giving 1.25–2.1×.

use crate::nn::{LayerId, Network};

/// One depthwise-separable unit: dw3x3(+BN+ReLU) then pw1x1(+BN+ReLU).
fn ds_block(net: &mut Network, from: LayerId, idx: usize, out_ch: usize, stride: usize) -> LayerId {
    let d = net.dwconv(&format!("dw{idx}"), from, 3, stride, 1);
    let db = net.bn(&format!("dw{idx}_bn"), d);
    let dr = net.relu(&format!("dw{idx}_relu"), db);
    let p = net.conv(&format!("pw{idx}"), dr, out_ch, 1, 1, 0);
    let pb = net.bn(&format!("pw{idx}_bn"), p);
    net.relu(&format!("pw{idx}_relu"), pb)
}

/// Build MobileNet-v1 (width 1.0) at 224×224.
pub fn mobilenet_v1() -> Network {
    let mut net = Network::new("mobilenet_v1");
    let x = net.input(3, 224, 224);
    let c1 = net.conv("conv1", x, 32, 3, 2, 1); // 112
    let b1 = net.bn("conv1_bn", c1);
    let mut cur = net.relu("conv1_relu", b1);

    // (out_ch, stride) for the 13 depthwise-separable blocks.
    let blocks: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, (ch, stride)) in blocks.into_iter().enumerate() {
        cur = ds_block(&mut net, cur, i + 1, ch, stride);
    }
    let g = net.gap("gap", cur);
    let f = net.fc("fc", g, 1000);
    net.softmax("prob", f);
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{network_macs, LayerKind, Phase, Shape};

    #[test]
    fn structure() {
        let n = mobilenet_v1();
        n.validate().unwrap();
        // 1 stem + 13 dw + 13 pw + 1 fc = 28 compute layers
        assert_eq!(n.compute_layers().len(), 28);
        assert_eq!(n.by_name("pw13_relu").unwrap().out, Shape::new(1024, 7, 7));
    }

    #[test]
    fn mac_count_matches_literature() {
        // MobileNet-v1 forward ≈569 MMACs.
        let n = mobilenet_v1();
        let total = network_macs(&n, Phase::Forward) as f64;
        assert!((5.3e8..6.1e8).contains(&total), "MobileNet FP MACs {total}");
    }

    #[test]
    fn pw_dominates_compute() {
        // Paper: dw layers are not the bottleneck. Check pw ≥ 90% of MACs.
        let n = mobilenet_v1();
        let mut pw = 0u64;
        let mut dw = 0u64;
        for l in n.compute_layers() {
            let macs = crate::nn::layer_macs(&n, l, Phase::Forward);
            match l.kind {
                LayerKind::DwConv { .. } => dw += macs,
                LayerKind::Conv { .. } if l.name.starts_with("pw") => pw += macs,
                _ => {}
            }
        }
        assert!(pw > 9 * dw, "pw {pw} vs dw {dw}");
    }
}
