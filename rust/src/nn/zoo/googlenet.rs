//! GoogLeNet (Szegedy et al. 2015) — inception modules, no BatchNorm.
//!
//! Like VGG, the conv–ReLU structure admits joint input+output sparsity
//! in BP. Fig 3a/3b and Fig 11b study the inception-3b module; Fig 17
//! studies inception-4d node utilization.

use crate::nn::{LayerId, Network};

/// Inception module parameters `(c1, c3r, c3, c5r, c5, pp)`.
pub struct InceptionCfg {
    pub c1: usize,
    pub c3r: usize,
    pub c3: usize,
    pub c5r: usize,
    pub c5: usize,
    pub pp: usize,
}

/// Build one inception module; every conv is followed by ReLU, the pool
/// branch is maxpool3x3/1 + 1×1 conv. Returns the concat output.
pub fn inception(net: &mut Network, from: LayerId, name: &str, cfg: &InceptionCfg) -> LayerId {
    // branch 1: 1x1
    let b1c = net.conv(&format!("{name}_1x1"), from, cfg.c1, 1, 1, 0);
    let b1 = net.relu(&format!("{name}_relu_1x1"), b1c);
    // branch 2: 1x1 reduce -> 3x3
    let b2r = net.conv(&format!("{name}_3x3_reduce"), from, cfg.c3r, 1, 1, 0);
    let b2rr = net.relu(&format!("{name}_relu_3x3_reduce"), b2r);
    let b2c = net.conv(&format!("{name}_3x3"), b2rr, cfg.c3, 3, 1, 1);
    let b2 = net.relu(&format!("{name}_relu_3x3"), b2c);
    // branch 3: 1x1 reduce -> 5x5
    let b3r = net.conv(&format!("{name}_5x5_reduce"), from, cfg.c5r, 1, 1, 0);
    let b3rr = net.relu(&format!("{name}_relu_5x5_reduce"), b3r);
    let b3c = net.conv(&format!("{name}_5x5"), b3rr, cfg.c5, 5, 1, 2);
    let b3 = net.relu(&format!("{name}_relu_5x5"), b3c);
    // branch 4: maxpool -> 1x1 proj
    let b4p = net.maxpool(&format!("{name}_pool"), from, 3, 1, 1);
    let b4c = net.conv(&format!("{name}_pool_proj"), b4p, cfg.pp, 1, 1, 0);
    let b4 = net.relu(&format!("{name}_relu_pool_proj"), b4c);
    net.concat(&format!("{name}_output"), &[b1, b2, b3, b4])
}

const CFGS: [(&str, InceptionCfg); 9] = [
    ("inception_3a", InceptionCfg { c1: 64, c3r: 96, c3: 128, c5r: 16, c5: 32, pp: 32 }),
    ("inception_3b", InceptionCfg { c1: 128, c3r: 128, c3: 192, c5r: 32, c5: 96, pp: 64 }),
    ("inception_4a", InceptionCfg { c1: 192, c3r: 96, c3: 208, c5r: 16, c5: 48, pp: 64 }),
    ("inception_4b", InceptionCfg { c1: 160, c3r: 112, c3: 224, c5r: 24, c5: 64, pp: 64 }),
    ("inception_4c", InceptionCfg { c1: 128, c3r: 128, c3: 256, c5r: 24, c5: 64, pp: 64 }),
    ("inception_4d", InceptionCfg { c1: 112, c3r: 144, c3: 288, c5r: 32, c5: 64, pp: 64 }),
    ("inception_4e", InceptionCfg { c1: 256, c3r: 160, c3: 320, c5r: 32, c5: 128, pp: 128 }),
    ("inception_5a", InceptionCfg { c1: 256, c3r: 160, c3: 320, c5r: 32, c5: 128, pp: 128 }),
    ("inception_5b", InceptionCfg { c1: 384, c3r: 192, c3: 384, c5r: 48, c5: 128, pp: 128 }),
];

/// Build GoogLeNet at 224×224 (main branch; auxiliary heads, which exist
/// only for training-time regularization of the original, are omitted as
/// they are not part of the paper's evaluated blocks).
pub fn googlenet() -> Network {
    let mut net = Network::new("googlenet");
    let x = net.input(3, 224, 224);
    let c1 = net.conv("conv1", x, 64, 7, 2, 3); // 112
    let r1 = net.relu("relu_conv1", c1);
    let p1 = net.maxpool("pool1", r1, 3, 2, 1); // 56
    let c2r = net.conv("conv2_reduce", p1, 64, 1, 1, 0);
    let r2r = net.relu("relu_conv2_reduce", c2r);
    let c2 = net.conv("conv2", r2r, 192, 3, 1, 1);
    let r2 = net.relu("relu_conv2", c2);
    let p2 = net.maxpool("pool2", r2, 3, 2, 1); // 28

    let mut cur = p2;
    for (name, cfg) in CFGS.iter() {
        cur = inception(&mut net, cur, name, cfg);
        if *name == "inception_3b" {
            cur = net.maxpool("pool3", cur, 3, 2, 1); // 14
        } else if *name == "inception_4e" {
            cur = net.maxpool("pool4", cur, 3, 2, 1); // 7
        }
    }
    let g = net.gap("gap", cur);
    let f = net.fc("fc", g, 1000);
    net.softmax("prob", f);
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{network_macs, Phase, Shape};

    #[test]
    fn structure() {
        let n = googlenet();
        n.validate().unwrap();
        // stem 3 convs + 9 modules × 6 convs + fc = 58 compute layers
        assert_eq!(n.compute_layers().len(), 58);
        assert_eq!(n.by_name("inception_3a_output").unwrap().out, Shape::new(256, 28, 28));
        assert_eq!(n.by_name("inception_3b_output").unwrap().out, Shape::new(480, 28, 28));
        assert_eq!(n.by_name("inception_4d_output").unwrap().out, Shape::new(528, 14, 14));
        assert_eq!(n.by_name("inception_5b_output").unwrap().out, Shape::new(1024, 7, 7));
    }

    #[test]
    fn mac_count_matches_literature() {
        // GoogLeNet forward ≈1.5 GMACs (1.43–1.6 depending on aux heads).
        let n = googlenet();
        let total = network_macs(&n, Phase::Forward) as f64;
        assert!((1.35e9..1.7e9).contains(&total), "GoogLeNet FP MACs {total}");
    }
}
