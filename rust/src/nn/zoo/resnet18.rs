//! ResNet-18 (He et al.) — BasicBlock residual network with BatchNorm.
//!
//! conv–BN–ReLU ordering: in the backward pass the BN layer re-densifies
//! gradients, so the only sparsity the accelerator can use on the conv
//! input-gradient GEMMs is *output* sparsity (§6 "Networks with the BN
//! layer"); the element-wise shortcut Add dilutes activation sparsity of
//! the post-add ReLU to ≈30% (Fig 13 discussion).

use crate::nn::{LayerId, Network};

/// One BasicBlock: conv3x3-BN-ReLU-conv3x3-BN (+ projection) → Add → ReLU.
fn basic_block(
    net: &mut Network,
    from: LayerId,
    name: &str,
    ch: usize,
    stride: usize,
) -> LayerId {
    let c1 = net.conv(&format!("{name}_conv1"), from, ch, 3, stride, 1);
    let b1 = net.bn(&format!("{name}_bn1"), c1);
    let r1 = net.relu(&format!("{name}_relu1"), b1);
    let c2 = net.conv(&format!("{name}_conv2"), r1, ch, 3, 1, 1);
    let b2 = net.bn(&format!("{name}_bn2"), c2);
    let shortcut = if stride != 1 || net.layer(from).out.c != ch {
        let cs = net.conv(&format!("{name}_proj"), from, ch, 1, stride, 0);
        net.bn(&format!("{name}_proj_bn"), cs)
    } else {
        from
    };
    let a = net.add(&format!("{name}_add"), b2, shortcut);
    net.relu(&format!("{name}_relu2"), a)
}

/// Build ResNet-18 at 224×224.
pub fn resnet18() -> Network {
    let mut net = Network::new("resnet18");
    let x = net.input(3, 224, 224);
    let c1 = net.conv("conv1", x, 64, 7, 2, 3); // 112
    let b1 = net.bn("bn1", c1);
    let r1 = net.relu("relu1", b1);
    let p1 = net.maxpool("pool1", r1, 3, 2, 1); // 56

    let mut cur = p1;
    for (stage, (ch, blocks)) in [(64usize, 2usize), (128, 2), (256, 2), (512, 2)]
        .into_iter()
        .enumerate()
    {
        for b in 0..blocks {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            cur = basic_block(&mut net, cur, &format!("layer{}_{b}", stage + 1), ch, stride);
        }
    }
    let g = net.gap("gap", cur);
    let f = net.fc("fc", g, 1000);
    net.softmax("prob", f);
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{network_macs, Phase, Shape};

    #[test]
    fn structure() {
        let n = resnet18();
        n.validate().unwrap();
        // 1 stem + 8 blocks × 2 convs + 3 projections + 1 fc = 21 compute
        assert_eq!(n.compute_layers().len(), 21);
        assert_eq!(n.by_name("layer1_0_conv1").unwrap().out, Shape::new(64, 56, 56));
        assert_eq!(n.by_name("layer4_1_relu2").unwrap().out, Shape::new(512, 7, 7));
    }

    #[test]
    fn mac_count_matches_literature() {
        // ResNet-18 forward ≈1.82 GMACs.
        let n = resnet18();
        let total = network_macs(&n, Phase::Forward) as f64;
        assert!((1.7e9..1.95e9).contains(&total), "ResNet-18 FP MACs {total}");
    }

    #[test]
    fn every_conv_followed_by_bn() {
        let n = resnet18();
        for l in n.compute_layers() {
            if l.name == "fc" {
                continue;
            }
            let cons = n.consumers(l.id);
            assert_eq!(cons.len(), 1, "{}", l.name);
            assert!(
                matches!(n.layer(cons[0]).kind, crate::nn::LayerKind::BatchNorm),
                "{} not followed by BN",
                l.name
            );
        }
    }
}
