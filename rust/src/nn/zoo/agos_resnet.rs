//! A compact BN-free residual CNN in the `agos_cnn` family — the
//! trace/replay testbed for **Add-fed backpropagation**.
//!
//! ResNet-18 interleaves BatchNorm, which re-densifies gradients (§2.1,
//! Fig 3c), so its BP tail is dense and an Add node never carries an
//! exploitable gradient map. This network drops BN (conv–ReLU blocks
//! like VGG/GoogLeNet) so the §3 sparsity survives *through* the
//! residual Adds:
//!
//! * `b1_conv2` feeds the Add directly — the gradient arriving at its
//!   output is the post-Add ReLU's masked gradient passed through the
//!   Add unchanged (Add backward is the identity into both branches).
//!   Replaying it requires the gradient pass-through resolution in
//!   `sim::replay` (v3 traces).
//! * `b3_add` feeds GAP → fc with **no** post-Add ReLU (the pre-act
//!   shortcut style), so the head's operand footprint derives through
//!   an Add node — resolvable only from a captured post-Add footprint
//!   (conv summands can be negative; the footprint is capture-time
//!   data, see DESIGN.md).

use crate::nn::Network;

/// Build the 3-block residual AGOS CNN at 32×32×3.
pub fn agos_resnet() -> Network {
    let mut net = Network::new("agos_resnet");
    let x = net.input(3, 32, 32);
    let c1 = net.conv("conv1", x, 16, 3, 1, 1);
    let r1 = net.relu("relu1", c1);

    // Block 1: identity shortcut, post-add ReLU.
    let b1c1 = net.conv("b1_conv1", r1, 16, 3, 1, 1);
    let b1r1 = net.relu("b1_relu1", b1c1);
    let b1c2 = net.conv("b1_conv2", b1r1, 16, 3, 1, 1);
    let b1a = net.add("b1_add", b1c2, r1);
    let b1r2 = net.relu("b1_relu2", b1a);

    // Block 2: downsampling with a 1×1 projection shortcut.
    let b2c1 = net.conv("b2_conv1", b1r2, 32, 3, 2, 1);
    let b2r1 = net.relu("b2_relu1", b2c1);
    let b2c2 = net.conv("b2_conv2", b2r1, 32, 3, 1, 1);
    let b2p = net.conv("b2_proj", b1r2, 32, 1, 2, 0);
    let b2a = net.add("b2_add", b2c2, b2p);
    let b2r2 = net.relu("b2_relu2", b2a);

    // Block 3: pre-act-style shortcut from the previous Add output, and
    // the block's own Add feeds the head with no ReLU in between.
    let b3c1 = net.conv("b3_conv1", b2r2, 32, 3, 1, 1);
    let b3r1 = net.relu("b3_relu1", b3c1);
    let b3c2 = net.conv("b3_conv2", b3r1, 32, 3, 1, 1);
    let b3a = net.add("b3_add", b3c2, b2a);

    let g = net.gap("gap", b3a);
    let f = net.fc("fc", g, 10);
    net.softmax("prob", f);
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{LayerKind, Shape};

    #[test]
    fn structure() {
        let n = agos_resnet();
        n.validate().unwrap();
        // stem + 2 convs/block × 3 + projection + fc = 9 compute layers.
        assert_eq!(n.compute_layers().len(), 9);
        assert_eq!(n.by_name("b1_add").unwrap().out, Shape::new(16, 32, 32));
        assert_eq!(n.by_name("b2_add").unwrap().out, Shape::new(32, 16, 16));
        assert_eq!(n.by_name("b3_add").unwrap().out, Shape::new(32, 16, 16));
        assert_eq!(n.by_name("fc").unwrap().out, Shape::new(10, 1, 1));
        // BN-free on purpose: the whole point is Add-fed gradient maps.
        assert!(n.layers().iter().all(|l| !matches!(l.kind, LayerKind::BatchNorm)));
    }

    #[test]
    fn add_fed_wiring_is_what_the_replay_tests_rely_on() {
        let n = agos_resnet();
        // b1_conv2's only consumer is the Add; the Add's only consumer
        // is the post-add ReLU — the gradient pass-through chain.
        let b1c2 = n.by_name("b1_conv2").unwrap().id;
        let b1a = n.by_name("b1_add").unwrap().id;
        assert_eq!(n.consumers(b1c2), vec![b1a]);
        assert_eq!(n.consumers(b1a), vec![n.by_name("b1_relu2").unwrap().id]);
        // b3_add feeds GAP directly (no ReLU): the head's footprint must
        // come from a captured post-Add map.
        let b3a = n.by_name("b3_add").unwrap().id;
        assert_eq!(n.consumers(b3a), vec![n.by_name("gap").unwrap().id]);
        assert!(matches!(n.layer(n.by_name("gap").unwrap().id).kind, LayerKind::GlobalAvgPool));
        // b2_add has two consumers (the post-add ReLU and block 3's
        // shortcut) — summed gradients, so its branches stay dense.
        assert_eq!(n.consumers(n.by_name("b2_add").unwrap().id).len(), 2);
    }
}
