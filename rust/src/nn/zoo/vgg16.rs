//! VGG-16 (Simonyan & Zisserman 2014) — 13 conv + 3 FC, no BatchNorm.
//!
//! The BN-free conv–ReLU chain makes every inner conv a candidate for
//! *both* input and output sparsity in the backward pass (Fig 11a); the
//! convs directly after MaxPool lose output sparsity (bars 3/5/8/11).

use crate::nn::{LayerId, Network};

fn block(net: &mut Network, mut from: LayerId, stage: usize, convs: usize, ch: usize) -> LayerId {
    for i in 1..=convs {
        let c = net.conv(&format!("conv{stage}_{i}"), from, ch, 3, 1, 1);
        from = net.relu(&format!("relu{stage}_{i}"), c);
    }
    net.maxpool(&format!("pool{stage}"), from, 2, 2, 0)
}

/// Build VGG-16 at 224×224.
pub fn vgg16() -> Network {
    let mut net = Network::new("vgg16");
    let x = net.input(3, 224, 224);
    let p1 = block(&mut net, x, 1, 2, 64); // 112
    let p2 = block(&mut net, p1, 2, 2, 128); // 56
    let p3 = block(&mut net, p2, 3, 3, 256); // 28
    let p4 = block(&mut net, p3, 4, 3, 512); // 14
    let p5 = block(&mut net, p4, 5, 3, 512); // 7
    let f6 = net.fc("fc6", p5, 4096);
    let r6 = net.relu("relu6", f6);
    let f7 = net.fc("fc7", r6, 4096);
    let r7 = net.relu("relu7", f7);
    let f8 = net.fc("fc8", r7, 1000);
    net.softmax("prob", f8);
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{network_macs, Phase, Shape};

    #[test]
    fn structure() {
        let n = vgg16();
        n.validate().unwrap();
        // 13 convs + 3 fc
        assert_eq!(n.compute_layers().len(), 16);
        assert_eq!(n.by_name("conv1_1").unwrap().out, Shape::new(64, 224, 224));
        assert_eq!(n.by_name("pool5").unwrap().out, Shape::new(512, 7, 7));
        assert_eq!(n.by_name("fc6").unwrap().out, Shape::new(4096, 1, 1));
    }

    #[test]
    fn mac_count_matches_literature() {
        // VGG-16 forward: ≈15.47 GMACs conv + ≈0.124 GMACs FC.
        let n = vgg16();
        let total = network_macs(&n, Phase::Forward);
        assert!(
            (15.3e9..15.8e9).contains(&(total as f64)),
            "VGG-16 FP MACs {total}"
        );
    }
}
