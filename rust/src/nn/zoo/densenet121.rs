//! DenseNet-121 (Huang et al.) — dense blocks with channel concatenation.
//!
//! Pre-activation ordering (BN–ReLU–conv): every conv's *input* is a ReLU
//! output (concatenated), so output sparsity applies throughout BP even
//! though BN kills gradient input sparsity; concatenation (unlike
//! ResNet's Add) preserves high sparsity (Fig 12a discussion).

use crate::nn::{LayerId, Network};

const GROWTH: usize = 32;
const BLOCK_LAYERS: [usize; 4] = [6, 12, 24, 16];

/// One dense layer: BN-ReLU-conv1x1(4k)-BN-ReLU-conv3x3(k); its output is
/// concatenated onto the running feature map by the caller.
fn dense_layer(net: &mut Network, from: LayerId, name: &str) -> LayerId {
    let b1 = net.bn(&format!("{name}_bn1"), from);
    let r1 = net.relu(&format!("{name}_relu1"), b1);
    let c1 = net.conv(&format!("{name}_conv1"), r1, 4 * GROWTH, 1, 1, 0);
    let b2 = net.bn(&format!("{name}_bn2"), c1);
    let r2 = net.relu(&format!("{name}_relu2"), b2);
    net.conv(&format!("{name}_conv2"), r2, GROWTH, 3, 1, 1)
}

/// Transition: BN-ReLU-conv1x1(half)-avgpool2.
fn transition(net: &mut Network, from: LayerId, name: &str) -> LayerId {
    let c_in = net.layer(from).out.c;
    let b = net.bn(&format!("{name}_bn"), from);
    let r = net.relu(&format!("{name}_relu"), b);
    let c = net.conv(&format!("{name}_conv"), r, c_in / 2, 1, 1, 0);
    net.avgpool(&format!("{name}_pool"), c, 2, 2, 0)
}

/// Build DenseNet-121 at 224×224.
pub fn densenet121() -> Network {
    let mut net = Network::new("densenet121");
    let x = net.input(3, 224, 224);
    let c0 = net.conv("conv0", x, 64, 7, 2, 3); // 112
    let b0 = net.bn("bn0", c0);
    let r0 = net.relu("relu0", b0);
    let mut cur = net.maxpool("pool0", r0, 3, 2, 1); // 56

    for (bi, &layers) in BLOCK_LAYERS.iter().enumerate() {
        for li in 0..layers {
            let out = dense_layer(&mut net, cur, &format!("dense{}_{li}", bi + 1));
            cur = net.concat(&format!("dense{}_{li}_cat", bi + 1), &[cur, out]);
        }
        if bi + 1 < BLOCK_LAYERS.len() {
            cur = transition(&mut net, cur, &format!("trans{}", bi + 1));
        }
    }
    let bf = net.bn("bn_final", cur);
    let rf = net.relu("relu_final", bf);
    let g = net.gap("gap", rf);
    let f = net.fc("fc", g, 1000);
    net.softmax("prob", f);
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{network_macs, Phase};

    #[test]
    fn structure() {
        let n = densenet121();
        n.validate().unwrap();
        // stem 1 + 58 dense layers × 2 + 3 transitions + fc = 121 weighted
        // layers (that's the "121").
        assert_eq!(n.compute_layers().len(), 121);
        // channel arithmetic: 64 + 6·32 = 256, /2 = 128; 128+12·32=512,/2=256;
        // 256+24·32=1024,/2=512; 512+16·32=1024.
        assert_eq!(n.by_name("trans1_conv").unwrap().out.c, 128);
        assert_eq!(n.by_name("trans2_conv").unwrap().out.c, 256);
        assert_eq!(n.by_name("trans3_conv").unwrap().out.c, 512);
        assert_eq!(n.by_name("bn_final").unwrap().out.c, 1024);
        assert_eq!(n.by_name("bn_final").unwrap().out.h, 7);
    }

    #[test]
    fn mac_count_matches_literature() {
        // DenseNet-121 forward ≈2.8-2.9 GMACs.
        let n = densenet121();
        let total = network_macs(&n, Phase::Forward) as f64;
        assert!((2.6e9..3.1e9).contains(&total), "DenseNet-121 FP MACs {total}");
    }

    #[test]
    fn every_conv_input_is_relu() {
        // pre-activation: each conv's producer is a ReLU (output sparsity
        // applicable on every conv in BP despite BN).
        let n = densenet121();
        for l in n.compute_layers() {
            if l.name == "fc" || l.name == "conv0" {
                continue;
            }
            let prod = n.layer(l.inputs[0]);
            assert!(
                prod.kind.is_relu(),
                "{} input is {} not relu",
                l.name,
                prod.name
            );
        }
    }
}
