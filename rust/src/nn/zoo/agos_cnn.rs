//! The small CNN trained end-to-end through the AOT artifacts
//! (`python/compile/model.py`) — mirrored here as an `nn::Network` so
//! measured traces can drive the simulator (co-simulation).
//!
//! Layer names match the trace keys emitted by the coordinator
//! (`relu1..relu4`).

use crate::nn::Network;

/// Build the 4-conv AGOS demo CNN at 32×32×3 (must stay in sync with
/// `python/compile/model.py::CONV_SPECS`).
pub fn agos_cnn() -> Network {
    let mut net = Network::new("agos_cnn");
    let x = net.input(3, 32, 32);
    let c1 = net.conv("conv1", x, 16, 3, 1, 1);
    let r1 = net.relu("relu1", c1);
    let c2 = net.conv("conv2", r1, 32, 3, 2, 1);
    let r2 = net.relu("relu2", c2);
    let c3 = net.conv("conv3", r2, 32, 3, 1, 1);
    let r3 = net.relu("relu3", c3);
    let c4 = net.conv("conv4", r3, 64, 3, 2, 1);
    let r4 = net.relu("relu4", c4);
    let g = net.gap("gap", r4);
    let f = net.fc("fc", g, 10);
    net.softmax("prob", f);
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Shape;

    #[test]
    fn matches_python_model() {
        let n = agos_cnn();
        n.validate().unwrap();
        assert_eq!(n.by_name("relu1").unwrap().out, Shape::new(16, 32, 32));
        assert_eq!(n.by_name("relu2").unwrap().out, Shape::new(32, 16, 16));
        assert_eq!(n.by_name("relu3").unwrap().out, Shape::new(32, 16, 16));
        assert_eq!(n.by_name("relu4").unwrap().out, Shape::new(64, 8, 8));
        assert_eq!(n.by_name("fc").unwrap().out, Shape::new(10, 1, 1));
        assert_eq!(n.compute_layers().len(), 5);
    }
}
