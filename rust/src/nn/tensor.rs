//! Feature-map shapes in the paper's `[C, H, W]` notation.

/// A 3-D feature-map shape (channels, height, width).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Shape {
    pub c: usize,
    pub h: usize,
    pub w: usize,
}

impl Shape {
    pub fn new(c: usize, h: usize, w: usize) -> Shape {
        Shape { c, h, w }
    }

    /// Total elements.
    pub fn len(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spatial output size after a k×k window with given stride/pad.
    pub fn conv_out(&self, k: usize, stride: usize, pad: usize) -> (usize, usize) {
        assert!(self.h + 2 * pad >= k && self.w + 2 * pad >= k, "window larger than input");
        ((self.h + 2 * pad - k) / stride + 1, (self.w + 2 * pad - k) / stride + 1)
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{},{},{}]", self.c, self.h, self.w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn len_and_display() {
        let s = Shape::new(64, 56, 56);
        assert_eq!(s.len(), 64 * 56 * 56);
        assert_eq!(s.to_string(), "[64,56,56]");
    }

    #[test]
    fn conv_out_same_and_strided() {
        let s = Shape::new(3, 224, 224);
        assert_eq!(s.conv_out(3, 1, 1), (224, 224));
        assert_eq!(s.conv_out(7, 2, 3), (112, 112));
        assert_eq!(s.conv_out(3, 2, 1), (112, 112));
        let p = Shape::new(64, 112, 112);
        assert_eq!(p.conv_out(3, 2, 1), (56, 56)); // maxpool 3x3/2 style
    }

    #[test]
    #[should_panic]
    fn window_too_large_panics() {
        Shape::new(3, 2, 2).conv_out(5, 1, 0);
    }
}
