//! Layer kinds and the `Layer` node of the network DAG.

use super::Shape;

/// Index of a layer within its `Network`.
pub type LayerId = usize;

/// Every layer kind appearing in the five evaluated CNNs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Network input (the image).
    Input,
    /// Standard convolution: `m` filters of `r × s` over all input
    /// channels. `[C,H,W] --[M,C,R,S]--> [M,U,V]`.
    Conv { m: usize, r: usize, s: usize, stride: usize, pad: usize },
    /// Depthwise convolution (MobileNet): one `r × s` filter per channel.
    DwConv { r: usize, s: usize, stride: usize, pad: usize },
    /// Fully-connected layer (`out` neurons over the flattened input).
    Fc { out: usize },
    /// Rectified linear unit — the sparsity source (§3.1).
    ReLU,
    /// Batch normalization — re-densifies gradients in BP (§2.1, Fig 3c).
    BatchNorm,
    /// Max pooling. At a MaxPool–CONV boundary output sparsity is lost
    /// (§6, Fig 11 discussion).
    MaxPool { k: usize, stride: usize, pad: usize },
    /// Average pooling.
    AvgPool { k: usize, stride: usize, pad: usize },
    /// Global average pooling to `[C,1,1]`.
    GlobalAvgPool,
    /// Element-wise residual addition (ResNet) — dilutes sparsity (§6).
    Add,
    /// Channel concatenation (GoogLeNet/DenseNet) — preserves sparsity.
    Concat,
    /// Classifier head (no MACs of interest).
    Softmax,
}

impl LayerKind {
    /// Does this layer perform GEMM-shaped work the accelerator executes?
    pub fn is_compute(&self) -> bool {
        matches!(self, LayerKind::Conv { .. } | LayerKind::DwConv { .. } | LayerKind::Fc { .. })
    }

    /// Does this layer's *output* carry a ReLU zero footprint?
    pub fn is_relu(&self) -> bool {
        matches!(self, LayerKind::ReLU)
    }

    pub fn label(&self) -> &'static str {
        match self {
            LayerKind::Input => "input",
            LayerKind::Conv { .. } => "conv",
            LayerKind::DwConv { .. } => "dwconv",
            LayerKind::Fc { .. } => "fc",
            LayerKind::ReLU => "relu",
            LayerKind::BatchNorm => "bn",
            LayerKind::MaxPool { .. } => "maxpool",
            LayerKind::AvgPool { .. } => "avgpool",
            LayerKind::GlobalAvgPool => "gap",
            LayerKind::Add => "add",
            LayerKind::Concat => "concat",
            LayerKind::Softmax => "softmax",
        }
    }
}

/// A node in the network DAG.
#[derive(Clone, Debug)]
pub struct Layer {
    pub id: LayerId,
    pub name: String,
    pub kind: LayerKind,
    /// Producer layers (1 for most, 2+ for Add/Concat).
    pub inputs: Vec<LayerId>,
    /// Inferred output shape.
    pub out: Shape,
}

impl Layer {
    /// Receptive-field size `C·R·S` per output value (the quantity the
    /// PE capacity of 1024 is compared against, §4.4/4.5). `None` for
    /// non-compute layers.
    pub fn receptive_field(&self, in_shape: Shape) -> Option<usize> {
        match self.kind {
            LayerKind::Conv { r, s, .. } => Some(in_shape.c * r * s),
            LayerKind::DwConv { r, s, .. } => Some(r * s),
            LayerKind::Fc { .. } => Some(in_shape.len()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_classification() {
        assert!(LayerKind::Conv { m: 1, r: 3, s: 3, stride: 1, pad: 1 }.is_compute());
        assert!(LayerKind::Fc { out: 10 }.is_compute());
        assert!(!LayerKind::ReLU.is_compute());
        assert!(LayerKind::ReLU.is_relu());
        assert!(!LayerKind::BatchNorm.is_relu());
    }

    #[test]
    fn receptive_fields() {
        let conv = Layer {
            id: 0,
            name: "c".into(),
            kind: LayerKind::Conv { m: 64, r: 3, s: 3, stride: 1, pad: 1 },
            inputs: vec![],
            out: Shape::new(64, 56, 56),
        };
        assert_eq!(conv.receptive_field(Shape::new(128, 56, 56)), Some(128 * 9));
        let dw = Layer {
            id: 0,
            name: "d".into(),
            kind: LayerKind::DwConv { r: 3, s: 3, stride: 1, pad: 1 },
            inputs: vec![],
            out: Shape::new(128, 56, 56),
        };
        assert_eq!(dw.receptive_field(Shape::new(128, 56, 56)), Some(9));
        let relu = Layer {
            id: 0,
            name: "r".into(),
            kind: LayerKind::ReLU,
            inputs: vec![],
            out: Shape::new(1, 1, 1),
        };
        assert_eq!(relu.receptive_field(Shape::new(1, 1, 1)), None);
    }
}
