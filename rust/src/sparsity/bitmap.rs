//! Zero-footprint bitmaps over `[C, H, W]` feature maps.
//!
//! A `Bitmap` stores one bit per neuron (1 = non-zero) in channel-first
//! layout — the "within channel" view of §3/Fig 7. It is the data the
//! forward pass leaves in DRAM for the backward pass's output-sparsity
//! address generator (Fig 9), and what the trace pipeline extracts from
//! real activations.
//!
//! The packed `u64` words are part of the public contract: the exact PE
//! (`sim::exact`) drains operands word-by-word with masked popcounts (the
//! §4.3 SRAM streaming order), and the v2 trace format (`trace`)
//! persists the words as hex so captured patterns replay bit-exactly.

use crate::nn::Shape;
use crate::util::fnv::Fnv1a;

/// One bit per neuron, layout `c * (h*w) + y * w + x`, LSB-first words.
///
/// Invariant: bits at index `>= shape.len()` in the last word are zero —
/// every constructor maintains it, so word-wise consumers (`and`,
/// `contained_in`, `channel_words`, popcounts) need no defensive tail
/// masking of their own.
#[derive(Clone, Debug, PartialEq)]
pub struct Bitmap {
    pub shape: Shape,
    words: Vec<u64>,
}

impl Bitmap {
    pub fn zeros(shape: Shape) -> Bitmap {
        let n = shape.len();
        Bitmap { shape, words: vec![0; n.div_ceil(64)] }
    }

    /// Every neuron non-zero (the structurally dense footprint — e.g.
    /// what a conv output contributes to a synthetic post-Add capture).
    pub fn ones(shape: Shape) -> Bitmap {
        let n = shape.len();
        let mut b = Bitmap { shape, words: vec![!0; n.div_ceil(64)] };
        let tail = n % 64;
        if tail > 0 {
            *b.words.last_mut().unwrap() &= (1u64 << tail) - 1;
        }
        b
    }

    /// Sample a random bitmap where every bit is independently non-zero
    /// with probability `density` — the exact execution backend's stand-in
    /// for a measured operand bitmap (`sim::backend`). Degenerate
    /// densities take a draw-free fast path, so dense (`>= 1`) and empty
    /// (`<= 0`) maps cost no RNG state.
    pub fn sample(shape: Shape, density: f64, rng: &mut crate::util::rng::Pcg32) -> Bitmap {
        let mut b = Bitmap::zeros(shape);
        let n = shape.len();
        if density <= 0.0 {
            return b;
        }
        if density >= 1.0 {
            for w in b.words.iter_mut() {
                *w = !0;
            }
            // Mask the tail word: stray bits past `len` would corrupt
            // word-wise ops (`and`, `contained_in`) against bitmaps
            // built bit-by-bit.
            let tail = n % 64;
            if tail > 0 {
                *b.words.last_mut().unwrap() &= (1u64 << tail) - 1;
            }
            return b;
        }
        for i in 0..n {
            if rng.bernoulli(density) {
                b.words[i / 64] |= 1 << (i % 64);
            }
        }
        b
    }

    /// Spatially-correlated random bitmap: non-zeros are planted as
    /// square blobs of Chebyshev radius `blob_radius` around random
    /// centers (within one channel) until exactly
    /// `round(density · len)` bits are set — the clustered zero
    /// footprints real ReLU maps exhibit, versus `sample`'s iid draws.
    /// `blob_radius == 0` degenerates to iid-without-replacement.
    ///
    /// Deterministic from the stream; densities `<= 0`, `>= 1` take the
    /// same draw-free fast paths as `sample`, and densities above the
    /// blob algorithm's efficient range fall back to iid sampling (the
    /// clustering is indistinguishable that close to dense anyway).
    pub fn sample_blobs(
        shape: Shape,
        density: f64,
        blob_radius: usize,
        rng: &mut crate::util::rng::Pcg32,
    ) -> Bitmap {
        if density <= 0.0 || density >= 0.97 {
            return Bitmap::sample(shape, density, rng);
        }
        let n = shape.len();
        if n == 0 {
            return Bitmap::zeros(shape);
        }
        let target = ((density * n as f64).round() as usize).clamp(1, n);
        let mut b = Bitmap::zeros(shape);
        let mut nz = 0usize;
        let r = blob_radius as isize;
        while nz < target {
            let c = rng.below(shape.c as u32) as usize;
            let cy = rng.below(shape.h as u32) as isize;
            let cx = rng.below(shape.w as u32) as isize;
            for dy in -r..=r {
                for dx in -r..=r {
                    let (y, x) = (cy + dy, cx + dx);
                    if y < 0 || x < 0 || y >= shape.h as isize || x >= shape.w as isize {
                        continue;
                    }
                    let (y, x) = (y as usize, x as usize);
                    if !b.get(c, y, x) {
                        b.set(c, y, x, true);
                        nz += 1;
                        if nz >= target {
                            return b;
                        }
                    }
                }
            }
        }
        b
    }

    /// One channel's bits in within-channel (row-major spatial) order,
    /// packed LSB-first into `u64` words — the §4.3 streaming order the
    /// exact PE drains word-by-word (`sim::exact`). The final word is
    /// tail-masked. Replaces the old per-lane `Vec<bool>` expansion
    /// (`channel_bits`), which dominated replay-scale walks.
    pub fn channel_words(&self, c: usize) -> ChannelWords<'_> {
        let hw = self.shape.h * self.shape.w;
        ChannelWords { map: self, base: c * hw, len: hw, pos: 0 }
    }

    /// Up to 64 bits starting at absolute bit `lo` (no wrap; the caller
    /// keeps `lo + nbits <= shape.len()`), LSB-aligned and tail-masked.
    #[inline]
    pub(crate) fn extract_bits(&self, lo: usize, nbits: usize) -> u64 {
        debug_assert!((1..=64).contains(&nbits));
        let wi = lo / 64;
        let sh = lo % 64;
        let mut w = self.words[wi] >> sh;
        if sh != 0 && wi + 1 < self.words.len() {
            w |= self.words[wi + 1] << (64 - sh);
        }
        if nbits < 64 {
            w &= (1u64 << nbits) - 1;
        }
        w
    }

    /// Assemble the packed operand pattern of one receptive-field window:
    /// channels `c0..c1`, `wh × ww` spatial taps anchored at `(ay, ax)`
    /// (top-left, in map coordinates — negative or past-the-edge anchors
    /// are how conv padding arrives here). Out-of-bounds taps contribute
    /// structural zero bits, exactly like the zero padding the dense GEMM
    /// would multiply by. Bits land in channel-major, row-major tap order
    /// — the §4.3 streaming order of the true strided gather.
    ///
    /// `out` is cleared and resized (allocation-free once warm); in-map
    /// row runs go through [`Bitmap::extract_bits`] a word at a time, so
    /// no per-tap address arithmetic survives in the hot loop. Returns
    /// the pattern length `(c1 − c0)·wh·ww` in bits.
    #[allow(clippy::too_many_arguments)]
    pub fn gather_window_words(
        &self,
        c0: usize,
        c1: usize,
        ay: isize,
        ax: isize,
        wh: usize,
        ww: usize,
        out: &mut Vec<u64>,
    ) -> usize {
        debug_assert!(c0 < c1 && c1 <= self.shape.c, "channel range {c0}..{c1}");
        let len = (c1 - c0) * wh * ww;
        out.clear();
        out.resize(len.div_ceil(64), 0);
        let (h, w) = (self.shape.h as isize, self.shape.w as isize);
        let mut pos = 0usize;
        for c in c0..c1 {
            for ky in 0..wh {
                let y = ay + ky as isize;
                if y < 0 || y >= h {
                    pos += ww; // whole row out of bounds: zeros (already cleared)
                    continue;
                }
                let x_lo = ax.max(0);
                let x_hi = (ax + ww as isize).min(w);
                if x_lo >= x_hi {
                    pos += ww;
                    continue;
                }
                pos += (x_lo - ax) as usize; // structural zeros left of the map
                let mut base = self.index(c, y as usize, x_lo as usize);
                let mut left = (x_hi - x_lo) as usize;
                while left > 0 {
                    let take = left.min(64);
                    or_bits(out, pos, self.extract_bits(base, take), take);
                    pos += take;
                    base += take;
                    left -= take;
                }
                pos += (ax + ww as isize - x_hi) as usize; // zeros right of the map
            }
        }
        debug_assert_eq!(pos, len);
        len
    }

    /// Non-zero count over the spatial window `[y0, y1) × [x0, x1)`
    /// summed across every channel — the per-tile *measured* density the
    /// pattern-informed analytic backend slices out of a replayed map
    /// (`sim::layer_exec`). Word-extracted row runs, no per-bit `get`.
    pub fn window_nz(&self, y0: usize, y1: usize, x0: usize, x1: usize) -> usize {
        debug_assert!(y1 <= self.shape.h && x1 <= self.shape.w && y0 <= y1 && x0 <= x1);
        let mut n = 0usize;
        for c in 0..self.shape.c {
            for y in y0..y1 {
                let mut base = self.index(c, y, x0);
                let mut left = x1 - x0;
                while left > 0 {
                    let take = left.min(64);
                    n += self.extract_bits(base, take).count_ones() as usize;
                    base += take;
                    left -= take;
                }
            }
        }
        n
    }

    /// Copy `len` bits starting at `start` (mod the map size, wrapping)
    /// into `out` as packed LSB-first words — how the replay path slices
    /// one output's operand window out of a captured map without
    /// expanding to bools. `out` is cleared and resized; windows longer
    /// than the map wrap and repeat.
    pub fn window_words_into(&self, start: usize, len: usize, out: &mut Vec<u64>) {
        let n = self.shape.len();
        assert!(n > 0 && len > 0, "window over empty bitmap");
        out.clear();
        out.resize(len.div_ceil(64), 0);
        let mut filled = 0usize;
        while filled < len {
            let pos = (start + filled) % n;
            let take = 64.min(len - filled).min(n - pos);
            let bits = self.extract_bits(pos, take);
            let (wi, sh) = (filled / 64, filled % 64);
            out[wi] |= bits << sh;
            if sh != 0 && sh + take > 64 {
                out[wi + 1] |= bits >> (64 - sh);
            }
            filled += take;
        }
    }

    /// The packed words (LSB-first; tail bits beyond `len` are zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Word-granular run structure of this map (`sparsity::encode::
    /// RunIndex`): sorted all-zero and all-ones word ranges, computed in
    /// one linear scan. Replayed maps carry this alongside their words so
    /// the exact backend's gather plans can skip dark source ranges and
    /// short-circuit saturated windows (`sim::plan`). Computed from the
    /// *reconstructed* words on purpose — a v3 delta payload's on-disk
    /// runs describe the XOR delta, not the map it decodes to.
    pub fn run_index(&self) -> super::RunIndex {
        super::RunIndex::scan(&self.words, self.shape.len())
    }

    /// Hex payload of the packed words (16 chars per word) — the v2
    /// trace-file encoding (`trace`).
    pub fn encode_hex(&self) -> String {
        use std::fmt::Write;
        let mut s = String::with_capacity(self.words.len() * 16);
        for w in &self.words {
            let _ = write!(s, "{w:016x}");
        }
        s
    }

    /// Parse an `encode_hex` payload back under `shape`. Rejects wrong
    /// payload lengths and set bits beyond `shape.len()` (a corrupt or
    /// mis-shaped payload must not load as "good" data).
    pub fn decode_hex(shape: Shape, hex: &str) -> anyhow::Result<Bitmap> {
        let n_words = shape.len().div_ceil(64);
        anyhow::ensure!(
            hex.len() == n_words * 16,
            "bitmap payload is {} hex chars, shape {shape} needs {}",
            hex.len(),
            n_words * 16
        );
        let mut words = Vec::with_capacity(n_words);
        for i in 0..n_words {
            let chunk = &hex[i * 16..(i + 1) * 16];
            words.push(
                u64::from_str_radix(chunk, 16)
                    .map_err(|_| anyhow::anyhow!("bad bitmap hex word '{chunk}'"))?,
            );
        }
        let tail = shape.len() % 64;
        if tail > 0 {
            anyhow::ensure!(
                words[n_words - 1] & !((1u64 << tail) - 1) == 0,
                "bitmap payload has bits set beyond shape {shape}"
            );
        }
        Ok(Bitmap { shape, words })
    }

    /// Run-length encoding of the packed words — the TraceFile v3
    /// payload (`trace`): `zN`/`oN` zero/full word runs, literal
    /// leading-zero-stripped hex otherwise (`sparsity::encode::
    /// rle_encode_words`). Same bit stream as `encode_hex`, compacted.
    pub fn encode_rle(&self) -> String {
        super::encode::rle_encode_words(&self.words, self.shape.len())
    }

    /// Binary run-length encoding of the packed words — the TraceFile
    /// **v4** payload (`trace::v4`), appended to `out`. Same run
    /// semantics as [`Bitmap::encode_rle`], packed bytes instead of
    /// text (`sparsity::encode::rle_encode_words_bin`).
    pub fn encode_rle_bin(&self, out: &mut Vec<u8>) {
        super::encode::rle_encode_words_bin(&self.words, self.shape.len(), out)
    }

    /// Parse an `encode_rle_bin` payload back under `shape` — the v4
    /// reader's decode-into-words path: runs expand straight into the
    /// bitmap's `Vec<u64>`, no intermediate strings.
    pub fn decode_rle_bin(shape: Shape, bytes: &[u8]) -> anyhow::Result<Bitmap> {
        use anyhow::Context;
        let words = super::encode::rle_decode_words_bin(bytes, shape.len())
            .with_context(|| format!("binary RLE bitmap payload for shape {shape}"))?;
        Ok(Bitmap { shape, words })
    }

    /// Adopt an already-packed word buffer under `shape` — the v4
    /// reader's zero-copy raw path (`enc = raw` sections deserialize to
    /// a `Vec<u64>` that becomes the bitmap's storage directly).
    /// Validates the constructor invariant: exact word count and no
    /// bits set beyond `shape.len()` in the tail word.
    pub fn from_words(shape: Shape, words: Vec<u64>) -> anyhow::Result<Bitmap> {
        let n_words = shape.len().div_ceil(64);
        anyhow::ensure!(
            words.len() == n_words,
            "bitmap payload is {} words, shape {shape} needs {n_words}",
            words.len()
        );
        let tail = shape.len() % 64;
        if tail > 0 {
            anyhow::ensure!(
                words[n_words - 1] & !((1u64 << tail) - 1) == 0,
                "bitmap payload has bits set beyond shape {shape}"
            );
        }
        Ok(Bitmap { shape, words })
    }

    /// Parse an `encode_rle` payload back under `shape`. Strict like
    /// `decode_hex`: wrong word totals, malformed tokens and bits beyond
    /// `shape.len()` are errors, never silently-loaded data.
    pub fn decode_rle(shape: Shape, s: &str) -> anyhow::Result<Bitmap> {
        use anyhow::Context;
        let words = super::encode::rle_decode_words(s, shape.len())
            .with_context(|| format!("RLE bitmap payload for shape {shape}"))?;
        Ok(Bitmap { shape, words })
    }

    /// Bitwise XOR (symmetric difference of footprints) — the delta the
    /// v3 trace encoder stores between consecutive captured steps of the
    /// same layer. Tail bits stay zero because both operands' do.
    pub fn xor(&self, other: &Bitmap) -> Bitmap {
        assert_eq!(self.shape, other.shape);
        Bitmap {
            shape: self.shape,
            words: self.words.iter().zip(&other.words).map(|(a, b)| a ^ b).collect(),
        }
    }

    /// Stable content fingerprint (shape + words) — folded into sweep
    /// cache keys so replayed patterns can never alias (`sim::sweep`).
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv1a::new();
        h.put(self.shape.c as u64).put(self.shape.h as u64).put(self.shape.w as u64);
        for w in &self.words {
            h.put(*w);
        }
        h.finish()
    }

    /// Build from an f32 tensor in `[C,H,W]` order: bit set ⇔ value ≠ 0.
    pub fn from_values(shape: Shape, values: &[f32]) -> Bitmap {
        assert_eq!(values.len(), shape.len(), "value count vs shape");
        let mut b = Bitmap::zeros(shape);
        for (i, v) in values.iter().enumerate() {
            if *v != 0.0 {
                b.words[i / 64] |= 1 << (i % 64);
            }
        }
        b
    }

    #[inline]
    pub fn index(&self, c: usize, y: usize, x: usize) -> usize {
        (c * self.shape.h + y) * self.shape.w + x
    }

    #[inline]
    pub fn get(&self, c: usize, y: usize, x: usize) -> bool {
        let i = self.index(c, y, x);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, nz: bool) {
        let i = self.index(c, y, x);
        if nz {
            self.words[i / 64] |= 1 << (i % 64);
        } else {
            self.words[i / 64] &= !(1 << (i % 64));
        }
    }

    /// Number of non-zero neurons.
    pub fn count_nz(&self) -> usize {
        // Mask tail bits beyond len.
        let n = self.shape.len();
        let mut total = 0usize;
        for (wi, w) in self.words.iter().enumerate() {
            let mut word = *w;
            let base = wi * 64;
            if base + 64 > n {
                let valid = n - base;
                if valid == 0 {
                    break;
                }
                word &= (1u64 << valid) - 1;
            }
            total += word.count_ones() as usize;
        }
        total
    }

    /// Zero fraction (the paper's "sparsity").
    pub fn sparsity(&self) -> f64 {
        let n = self.shape.len();
        if n == 0 {
            return 0.0;
        }
        1.0 - self.count_nz() as f64 / n as f64
    }

    /// Non-zero count along the channel axis at a spatial location — the
    /// "through channel" (TC) view used by input-sparsity indexing.
    /// A strided word-indexed walk (stride `h·w` bits, one bit tested
    /// per word access) instead of per-bit `get` address arithmetic.
    pub fn tc_nz(&self, y: usize, x: usize) -> usize {
        let hw = self.shape.h * self.shape.w;
        let mut i = y * self.shape.w + x;
        let mut n = 0usize;
        for _ in 0..self.shape.c {
            n += ((self.words[i / 64] >> (i % 64)) & 1) as usize;
            i += hw;
        }
        n
    }

    /// Non-zero count within one channel — the "within channel" (WC)
    /// view that drives output skipping. A masked-word popcount walk.
    pub fn wc_nz(&self, c: usize) -> usize {
        self.channel_words(c).map(|w| w.count_ones() as usize).sum()
    }

    /// Per-channel sparsity vector.
    pub fn per_channel_sparsity(&self) -> Vec<f64> {
        let hw = (self.shape.h * self.shape.w) as f64;
        (0..self.shape.c)
            .map(|c| 1.0 - self.wc_nz(c) as f64 / hw)
            .collect()
    }

    /// Logical OR (union of non-zero footprints) — exact for sums of
    /// non-negative maps, and how a synthetic post-Add footprint
    /// combines its branch footprints.
    pub fn or(&self, other: &Bitmap) -> Bitmap {
        assert_eq!(self.shape, other.shape);
        Bitmap {
            shape: self.shape,
            words: self.words.iter().zip(&other.words).map(|(a, b)| a | b).collect(),
        }
    }

    /// Logical AND (intersection of non-zero footprints).
    pub fn and(&self, other: &Bitmap) -> Bitmap {
        assert_eq!(self.shape, other.shape);
        Bitmap {
            shape: self.shape,
            words: self
                .words
                .iter()
                .zip(&other.words)
                .map(|(a, b)| a & b)
                .collect(),
        }
    }

    /// True if every non-zero of `self` is also non-zero in `other`
    /// (footprint containment — the §3.2 identity check).
    pub fn contained_in(&self, other: &Bitmap) -> bool {
        assert_eq!(self.shape, other.shape);
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }
}

/// OR `n` LSB-aligned bits (`n <= 64`, `bits` masked to `n`) into a
/// packed buffer at bit position `pos`. The buffer must be pre-zeroed at
/// the target range — this writes, it does not clear. Shared by the
/// window gathers here and the joint-pair assembly in `sim::backend`.
#[inline]
pub(crate) fn or_bits(out: &mut [u64], pos: usize, bits: u64, n: usize) {
    debug_assert!((1..=64).contains(&n));
    let (wi, sh) = (pos / 64, pos % 64);
    out[wi] |= bits << sh;
    if sh != 0 && sh + n > 64 {
        out[wi + 1] |= bits >> (64 - sh);
    }
}

/// Word iterator over one channel's bits (see [`Bitmap::channel_words`]).
/// Yields `ceil(h·w / 64)` words; the last is tail-masked.
pub struct ChannelWords<'a> {
    map: &'a Bitmap,
    base: usize,
    len: usize,
    pos: usize,
}

impl Iterator for ChannelWords<'_> {
    type Item = u64;

    #[inline]
    fn next(&mut self) -> Option<u64> {
        if self.pos >= self.len {
            return None;
        }
        let nbits = 64.min(self.len - self.pos);
        let w = self.map.extract_bits(self.base + self.pos, nbits);
        self.pos += 64;
        Some(w)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = (self.len - self.pos.min(self.len)).div_ceil(64);
        (left, Some(left))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_values_and_counts() {
        let shape = Shape::new(2, 2, 2);
        let vals = [0.0, 1.0, 0.0, 2.0, 3.0, 0.0, 0.0, 0.0];
        let b = Bitmap::from_values(shape, &vals);
        assert_eq!(b.count_nz(), 3);
        assert!((b.sparsity() - 5.0 / 8.0).abs() < 1e-12);
        assert!(!b.get(0, 0, 0));
        assert!(b.get(0, 0, 1));
        assert!(b.get(1, 0, 0));
    }

    #[test]
    fn set_get_roundtrip() {
        let mut b = Bitmap::zeros(Shape::new(3, 5, 7));
        b.set(2, 4, 6, true);
        assert!(b.get(2, 4, 6));
        b.set(2, 4, 6, false);
        assert!(!b.get(2, 4, 6));
        assert_eq!(b.count_nz(), 0);
    }

    #[test]
    fn tc_and_wc_views() {
        let mut b = Bitmap::zeros(Shape::new(4, 2, 2));
        for c in 0..3 {
            b.set(c, 0, 0, true);
        }
        b.set(0, 1, 1, true);
        assert_eq!(b.tc_nz(0, 0), 3);
        assert_eq!(b.tc_nz(1, 1), 1);
        assert_eq!(b.wc_nz(0), 2);
        assert_eq!(b.wc_nz(3), 0);
        let pcs = b.per_channel_sparsity();
        assert!((pcs[0] - 0.5).abs() < 1e-12);
        assert!((pcs[3] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn containment_is_the_identity_law() {
        let shape = Shape::new(1, 2, 2);
        let act = Bitmap::from_values(shape, &[1.0, 0.0, 2.0, 3.0]);
        let grad = Bitmap::from_values(shape, &[1.0, 0.0, 0.0, 3.0]);
        // gradient footprint ⊆ activation footprint
        assert!(grad.contained_in(&act));
        assert!(!act.contained_in(&grad));
        let both = act.and(&grad);
        assert_eq!(both.count_nz(), 2);
    }

    #[test]
    fn sample_tracks_density_and_degenerate_cases() {
        use crate::util::rng::Pcg32;
        let shape = Shape::new(8, 16, 16);
        let mut rng = Pcg32::new(4);
        let b = Bitmap::sample(shape, 0.7, &mut rng);
        assert!((b.sparsity() - 0.3).abs() < 0.05, "sparsity {}", b.sparsity());
        // Degenerate densities consume no RNG state.
        let mut a = Pcg32::new(1);
        let mut c = Pcg32::new(1);
        let full = Bitmap::sample(shape, 1.0, &mut a);
        let empty = Bitmap::sample(shape, 0.0, &mut a);
        assert_eq!(full.count_nz(), shape.len());
        assert_eq!(empty.count_nz(), 0);
        assert_eq!(a.next_u32(), c.next_u32(), "fast paths must not draw");
        // Determinism from the stream.
        let d1 = Bitmap::sample(shape, 0.4, &mut Pcg32::new(7));
        let d2 = Bitmap::sample(shape, 0.4, &mut Pcg32::new(7));
        assert_eq!(d1, d2);
    }

    #[test]
    fn channel_words_match_get() {
        let mut b = Bitmap::zeros(Shape::new(3, 2, 2));
        b.set(1, 0, 1, true);
        b.set(1, 1, 0, true);
        b.set(2, 1, 1, true);
        // hw = 4 bits per channel, one masked word each.
        assert_eq!(b.channel_words(0).collect::<Vec<_>>(), vec![0b0000]);
        assert_eq!(b.channel_words(1).collect::<Vec<_>>(), vec![0b0110]);
        assert_eq!(b.channel_words(2).collect::<Vec<_>>(), vec![0b1000]);
    }

    #[test]
    fn channel_words_cross_word_boundaries() {
        // hw = 100 bits per channel: channel 1 starts at bit 100, so its
        // words straddle the packed-word grid; verify against `get`.
        let shape = Shape::new(3, 10, 10);
        let mut rng = crate::util::rng::Pcg32::new(21);
        let b = Bitmap::sample(shape, 0.37, &mut rng);
        for c in 0..shape.c {
            let words: Vec<u64> = b.channel_words(c).collect();
            assert_eq!(words.len(), 2); // ceil(100/64)
            for i in 0..100 {
                let bit = (words[i / 64] >> (i % 64)) & 1 == 1;
                assert_eq!(bit, b.get(c, i / 10, i % 10), "c={c} i={i}");
            }
            // tail of the last word is masked
            assert_eq!(words[1] >> 36, 0);
            assert_eq!(
                words.iter().map(|w| w.count_ones() as usize).sum::<usize>(),
                b.wc_nz(c)
            );
        }
    }

    #[test]
    fn window_words_wrap_and_match_get() {
        let shape = Shape::new(2, 5, 5); // 50 bits
        let mut rng = crate::util::rng::Pcg32::new(5);
        let b = Bitmap::sample(shape, 0.5, &mut rng);
        let flat: Vec<bool> = (0..50)
            .map(|i| b.get(i / 25, (i % 25) / 5, i % 5))
            .collect();
        let mut out = Vec::new();
        for (start, len) in [(0usize, 50usize), (13, 64), (47, 10), (3, 130)] {
            b.window_words_into(start, len, &mut out);
            assert_eq!(out.len(), len.div_ceil(64));
            for j in 0..len {
                let bit = (out[j / 64] >> (j % 64)) & 1 == 1;
                assert_eq!(bit, flat[(start + j) % 50], "start={start} j={j}");
            }
        }
    }

    #[test]
    fn gather_window_matches_get_reference() {
        let shape = Shape::new(5, 11, 13); // non-word-aligned rows on purpose
        let mut rng = crate::util::rng::Pcg32::new(31);
        let b = Bitmap::sample(shape, 0.45, &mut rng);
        let mut out = Vec::new();
        // Anchors inside, straddling every edge, and fully outside.
        let cases: &[(usize, usize, isize, isize, usize, usize)] = &[
            (0, 5, 0, 0, 3, 3),
            (0, 5, -1, -1, 3, 3),   // top-left padding
            (0, 5, 9, 11, 3, 3),    // bottom-right padding
            (2, 3, 4, 2, 1, 13),    // single channel, full-width row
            (1, 4, -2, -2, 15, 17), // window bigger than the map
            (0, 5, -5, 0, 2, 3),    // entirely above the map
            (0, 1, 0, -70, 1, 66),  // >64-bit row, mostly out of bounds
        ];
        for &(c0, c1, ay, ax, wh, ww) in cases {
            let len = b.gather_window_words(c0, c1, ay, ax, wh, ww, &mut out);
            assert_eq!(len, (c1 - c0) * wh * ww);
            assert_eq!(out.len(), len.div_ceil(64));
            let mut j = 0usize;
            for c in c0..c1 {
                for ky in 0..wh {
                    for kx in 0..ww {
                        let (y, x) = (ay + ky as isize, ax + kx as isize);
                        let expect = y >= 0
                            && x >= 0
                            && (y as usize) < shape.h
                            && (x as usize) < shape.w
                            && b.get(c, y as usize, x as usize);
                        let got = (out[j / 64] >> (j % 64)) & 1 == 1;
                        let ctx = format!("c={c} ky={ky} kx={kx} case {c0}..{c1}@({ay},{ax})");
                        assert_eq!(got, expect, "{ctx}");
                        j += 1;
                    }
                }
            }
            // Bits past the pattern length stay zero (PE tail invariant).
            let tail = len % 64;
            if tail > 0 {
                assert_eq!(out[len / 64] >> tail, 0);
            }
        }
    }

    #[test]
    fn window_nz_matches_per_bit_count() {
        let shape = Shape::new(3, 9, 70); // rows cross word boundaries
        let mut rng = crate::util::rng::Pcg32::new(8);
        let b = Bitmap::sample(shape, 0.5, &mut rng);
        for (y0, y1, x0, x1) in [(0, 9, 0, 70), (2, 5, 3, 66), (0, 1, 69, 70), (4, 4, 0, 70)] {
            let mut expect = 0usize;
            for c in 0..shape.c {
                for y in y0..y1 {
                    for x in x0..x1 {
                        expect += b.get(c, y, x) as usize;
                    }
                }
            }
            assert_eq!(b.window_nz(y0, y1, x0, x1), expect, "[{y0},{y1})x[{x0},{x1})");
        }
        assert_eq!(b.window_nz(0, b.shape.h, 0, b.shape.w), b.count_nz());
    }

    #[test]
    fn hex_roundtrip_and_corruption_rejected() {
        let shape = Shape::new(3, 7, 9); // 189 bits, non-aligned tail
        let mut rng = crate::util::rng::Pcg32::new(77);
        let b = Bitmap::sample(shape, 0.4, &mut rng);
        let hex = b.encode_hex();
        assert_eq!(hex.len(), 3 * 16);
        let b2 = Bitmap::decode_hex(shape, &hex).unwrap();
        assert_eq!(b, b2);
        // wrong length
        assert!(Bitmap::decode_hex(shape, &hex[..32]).is_err());
        // bits beyond the shape
        let mut bad = hex.clone();
        bad.replace_range(32..48, "ffffffffffffffff");
        assert!(Bitmap::decode_hex(shape, &bad).is_err());
        // non-hex garbage
        let mut garbage = hex;
        garbage.replace_range(0..1, "z");
        assert!(Bitmap::decode_hex(shape, &garbage).is_err());
    }

    #[test]
    fn rle_roundtrips_bit_identical_across_patterns() {
        use crate::util::rng::Pcg32;
        // Property-style sweep: iid + blobbed + degenerate maps, shapes
        // with word-aligned and ragged tails, densities across the range.
        let shapes = [Shape::new(3, 7, 9), Shape::new(4, 8, 8), Shape::new(1, 1, 1)];
        let mut rng = Pcg32::new(41);
        for shape in shapes {
            for density in [0.0, 0.03, 0.5, 0.97, 1.0] {
                for blobbed in [false, true] {
                    let b = if blobbed {
                        Bitmap::sample_blobs(shape, density, 2, &mut rng)
                    } else {
                        Bitmap::sample(shape, density, &mut rng)
                    };
                    let s = b.encode_rle();
                    let back = Bitmap::decode_rle(shape, &s).unwrap();
                    assert_eq!(b, back, "shape {shape} density {density} blobbed {blobbed}");
                }
            }
        }
        // Degenerate maps collapse to a single run token.
        let zeros = Bitmap::zeros(Shape::new(8, 16, 16));
        assert_eq!(zeros.encode_rle(), "z32");
        let ones = Bitmap::sample(Shape::new(3, 3, 3), 1.0, &mut rng); // 27-bit tail
        assert_eq!(ones.encode_rle(), "o1");
        assert_eq!(Bitmap::decode_rle(Shape::new(3, 3, 3), "o1").unwrap(), ones);
        // Strictness mirrors decode_hex.
        assert!(Bitmap::decode_rle(Shape::new(3, 3, 3), "z2").is_err());
        assert!(Bitmap::decode_rle(Shape::new(3, 3, 3), "ffffffffffffffff").is_err());
    }

    #[test]
    fn ones_and_or_respect_the_tail_invariant() {
        use crate::util::rng::Pcg32;
        let shape = Shape::new(3, 3, 3); // 27-bit tail
        let dense = Bitmap::ones(shape);
        assert_eq!(dense.count_nz(), 27);
        assert_eq!(dense, Bitmap::sample(shape, 1.0, &mut Pcg32::new(1)));
        let mut rng = Pcg32::new(2);
        let a = Bitmap::sample(shape, 0.4, &mut rng);
        let b = Bitmap::sample(shape, 0.4, &mut rng);
        let u = a.or(&b);
        for c in 0..3 {
            for y in 0..3 {
                for x in 0..3 {
                    assert_eq!(u.get(c, y, x), a.get(c, y, x) || b.get(c, y, x));
                }
            }
        }
        assert_eq!(a.or(&dense), dense, "OR with dense saturates");
    }

    #[test]
    fn xor_is_the_footprint_delta() {
        use crate::util::rng::Pcg32;
        let shape = Shape::new(2, 9, 9); // ragged 162-bit tail
        let mut rng = Pcg32::new(17);
        let a = Bitmap::sample(shape, 0.5, &mut rng);
        let b = Bitmap::sample(shape, 0.5, &mut rng);
        let d = a.xor(&b);
        for c in 0..shape.c {
            for y in 0..shape.h {
                for x in 0..shape.w {
                    assert_eq!(d.get(c, y, x), a.get(c, y, x) != b.get(c, y, x));
                }
            }
        }
        // Applying the delta reconstructs the original (the v3 decoder's
        // step), and self-delta is empty (identical steps cost ~nothing).
        assert_eq!(b.xor(&d), a);
        assert_eq!(a.xor(&a).count_nz(), 0);
        assert_eq!(a.xor(&a).encode_rle(), format!("z{}", shape.len().div_ceil(64)));
    }

    #[test]
    fn fingerprint_tracks_content_and_shape() {
        let mut rng = crate::util::rng::Pcg32::new(1);
        let a = Bitmap::sample(Shape::new(4, 8, 8), 0.5, &mut rng);
        let mut b = a.clone();
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.set(0, 0, 0, !b.get(0, 0, 0));
        assert_ne!(a.fingerprint(), b.fingerprint());
        // Same (empty) words, different shape must differ too.
        let e1 = Bitmap::zeros(Shape::new(1, 8, 8));
        let e2 = Bitmap::zeros(Shape::new(8, 8, 1));
        assert_ne!(e1.fingerprint(), e2.fingerprint());
    }

    #[test]
    fn blob_sampling_hits_density_and_clusters() {
        use crate::util::rng::Pcg32;
        let shape = Shape::new(8, 32, 32);
        let mut rng = crate::util::rng::Pcg32::new(9);
        let b = Bitmap::sample_blobs(shape, 0.4, 2, &mut rng);
        // Exact-count construction: sparsity is exact to rounding.
        assert!((b.sparsity() - 0.6).abs() < 1e-3, "sparsity {}", b.sparsity());
        // Clustering: a non-zero's 4-neighborhood is far more likely to be
        // non-zero than the marginal density. Count neighbor agreements.
        let mut nz_pairs = 0usize;
        let mut nz_total = 0usize;
        for c in 0..shape.c {
            for y in 0..shape.h {
                for x in 0..shape.w - 1 {
                    if b.get(c, y, x) {
                        nz_total += 1;
                        if b.get(c, y, x + 1) {
                            nz_pairs += 1;
                        }
                    }
                }
            }
        }
        let neighbor_density = nz_pairs as f64 / nz_total as f64;
        assert!(
            neighbor_density > 0.6,
            "blobs must cluster: P(right neighbor nz | nz) = {neighbor_density:.2}"
        );
        // iid at the same density shows no such correlation.
        let iid = Bitmap::sample(shape, 0.4, &mut rng);
        let mut iid_pairs = 0usize;
        let mut iid_total = 0usize;
        for c in 0..shape.c {
            for y in 0..shape.h {
                for x in 0..shape.w - 1 {
                    if iid.get(c, y, x) {
                        iid_total += 1;
                        if iid.get(c, y, x + 1) {
                            iid_pairs += 1;
                        }
                    }
                }
            }
        }
        assert!((iid_pairs as f64 / iid_total as f64) < 0.5);
        // Determinism + degenerate fast paths.
        let d1 = Bitmap::sample_blobs(shape, 0.3, 1, &mut Pcg32::new(4));
        let d2 = Bitmap::sample_blobs(shape, 0.3, 1, &mut Pcg32::new(4));
        assert_eq!(d1, d2);
        let mut a = Pcg32::new(2);
        let mut c = Pcg32::new(2);
        assert_eq!(Bitmap::sample_blobs(shape, 0.0, 2, &mut a).count_nz(), 0);
        assert_eq!(Bitmap::sample_blobs(shape, 1.0, 2, &mut a).count_nz(), shape.len());
        assert_eq!(a.next_u32(), c.next_u32(), "degenerate blobs must not draw");
    }

    #[test]
    fn run_index_classifies_real_maps() {
        use crate::util::rng::Pcg32;
        // A blobbed map at trace-like density: most words are dark.
        let shape = Shape::new(8, 32, 32); // 128 words exactly
        let b = Bitmap::sample_blobs(shape, 0.03, 2, &mut Pcg32::new(6));
        let idx = b.run_index();
        assert!(idx.zero_words() > 64, "sparse blobs leave most words dark");
        // Every claimed zero range really is zero, word by word.
        let n_words = b.words().len();
        for wi in 0..n_words {
            assert_eq!(idx.all_zero(wi, wi + 1), b.words()[wi] == 0, "word {wi}");
        }
        // Degenerate maps classify entirely, tail masks included.
        let ones = Bitmap::ones(Shape::new(3, 3, 3)); // 27-bit tail
        let oi = ones.run_index();
        assert!(oi.all_ones(0, 1) && oi.one_words() == 1);
        let zeros = Bitmap::zeros(shape);
        assert!(zeros.run_index().all_zero(0, n_words));
    }

    #[test]
    fn count_handles_non_word_aligned_sizes() {
        // 3*3*3 = 27 bits — tail masking must not count garbage.
        let shape = Shape::new(3, 3, 3);
        let vals = vec![1.0f32; 27];
        let b = Bitmap::from_values(shape, &vals);
        assert_eq!(b.count_nz(), 27);
        assert_eq!(b.sparsity(), 0.0);
    }
}
