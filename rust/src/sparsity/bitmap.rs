//! Zero-footprint bitmaps over `[C, H, W]` feature maps.
//!
//! A `Bitmap` stores one bit per neuron (1 = non-zero) in channel-first
//! layout — the "within channel" view of §3/Fig 7. It is the data the
//! forward pass leaves in DRAM for the backward pass's output-sparsity
//! address generator (Fig 9), and what the trace pipeline extracts from
//! real activations.

use crate::nn::Shape;

/// One bit per neuron, layout `c * (h*w) + y * w + x`, LSB-first words.
#[derive(Clone, Debug, PartialEq)]
pub struct Bitmap {
    pub shape: Shape,
    words: Vec<u64>,
}

impl Bitmap {
    pub fn zeros(shape: Shape) -> Bitmap {
        let n = shape.len();
        Bitmap { shape, words: vec![0; n.div_ceil(64)] }
    }

    /// Sample a random bitmap where every bit is independently non-zero
    /// with probability `density` — the exact execution backend's stand-in
    /// for a measured operand bitmap (`sim::backend`). Degenerate
    /// densities take a draw-free fast path, so dense (`>= 1`) and empty
    /// (`<= 0`) maps cost no RNG state.
    pub fn sample(shape: Shape, density: f64, rng: &mut crate::util::rng::Pcg32) -> Bitmap {
        let mut b = Bitmap::zeros(shape);
        let n = shape.len();
        if density <= 0.0 {
            return b;
        }
        if density >= 1.0 {
            for w in b.words.iter_mut() {
                *w = !0;
            }
            // Mask the tail word: stray bits past `len` would corrupt
            // word-wise ops (`and`, `contained_in`) against bitmaps
            // built bit-by-bit.
            let tail = n % 64;
            if tail > 0 {
                *b.words.last_mut().unwrap() &= (1u64 << tail) - 1;
            }
            return b;
        }
        for i in 0..n {
            if rng.bernoulli(density) {
                b.words[i / 64] |= 1 << (i % 64);
            }
        }
        b
    }

    /// One channel's bits in within-channel (row-major spatial) order —
    /// the drain order the exact PE walks (`sim::exact`).
    pub fn channel_bits(&self, c: usize) -> Vec<bool> {
        let hw = self.shape.h * self.shape.w;
        let base = c * hw;
        (0..hw)
            .map(|i| {
                let j = base + i;
                (self.words[j / 64] >> (j % 64)) & 1 == 1
            })
            .collect()
    }

    /// Build from an f32 tensor in `[C,H,W]` order: bit set ⇔ value ≠ 0.
    pub fn from_values(shape: Shape, values: &[f32]) -> Bitmap {
        assert_eq!(values.len(), shape.len(), "value count vs shape");
        let mut b = Bitmap::zeros(shape);
        for (i, v) in values.iter().enumerate() {
            if *v != 0.0 {
                b.words[i / 64] |= 1 << (i % 64);
            }
        }
        b
    }

    #[inline]
    pub fn index(&self, c: usize, y: usize, x: usize) -> usize {
        (c * self.shape.h + y) * self.shape.w + x
    }

    #[inline]
    pub fn get(&self, c: usize, y: usize, x: usize) -> bool {
        let i = self.index(c, y, x);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, c: usize, y: usize, x: usize, nz: bool) {
        let i = self.index(c, y, x);
        if nz {
            self.words[i / 64] |= 1 << (i % 64);
        } else {
            self.words[i / 64] &= !(1 << (i % 64));
        }
    }

    /// Number of non-zero neurons.
    pub fn count_nz(&self) -> usize {
        // Mask tail bits beyond len.
        let n = self.shape.len();
        let mut total = 0usize;
        for (wi, w) in self.words.iter().enumerate() {
            let mut word = *w;
            let base = wi * 64;
            if base + 64 > n {
                let valid = n - base;
                if valid == 0 {
                    break;
                }
                word &= (1u64 << valid) - 1;
            }
            total += word.count_ones() as usize;
        }
        total
    }

    /// Zero fraction (the paper's "sparsity").
    pub fn sparsity(&self) -> f64 {
        let n = self.shape.len();
        if n == 0 {
            return 0.0;
        }
        1.0 - self.count_nz() as f64 / n as f64
    }

    /// Non-zero count along the channel axis at a spatial location — the
    /// "through channel" (TC) view used by input-sparsity indexing.
    pub fn tc_nz(&self, y: usize, x: usize) -> usize {
        (0..self.shape.c).filter(|&c| self.get(c, y, x)).count()
    }

    /// Non-zero count within one channel — the "within channel" (WC)
    /// view that drives output skipping.
    pub fn wc_nz(&self, c: usize) -> usize {
        (0..self.shape.h)
            .map(|y| (0..self.shape.w).filter(|&x| self.get(c, y, x)).count())
            .sum()
    }

    /// Per-channel sparsity vector.
    pub fn per_channel_sparsity(&self) -> Vec<f64> {
        let hw = (self.shape.h * self.shape.w) as f64;
        (0..self.shape.c)
            .map(|c| 1.0 - self.wc_nz(c) as f64 / hw)
            .collect()
    }

    /// Logical AND (intersection of non-zero footprints).
    pub fn and(&self, other: &Bitmap) -> Bitmap {
        assert_eq!(self.shape, other.shape);
        Bitmap {
            shape: self.shape,
            words: self
                .words
                .iter()
                .zip(&other.words)
                .map(|(a, b)| a & b)
                .collect(),
        }
    }

    /// True if every non-zero of `self` is also non-zero in `other`
    /// (footprint containment — the §3.2 identity check).
    pub fn contained_in(&self, other: &Bitmap) -> bool {
        assert_eq!(self.shape, other.shape);
        self.words.iter().zip(&other.words).all(|(a, b)| a & !b == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_values_and_counts() {
        let shape = Shape::new(2, 2, 2);
        let vals = [0.0, 1.0, 0.0, 2.0, 3.0, 0.0, 0.0, 0.0];
        let b = Bitmap::from_values(shape, &vals);
        assert_eq!(b.count_nz(), 3);
        assert!((b.sparsity() - 5.0 / 8.0).abs() < 1e-12);
        assert!(!b.get(0, 0, 0));
        assert!(b.get(0, 0, 1));
        assert!(b.get(1, 0, 0));
    }

    #[test]
    fn set_get_roundtrip() {
        let mut b = Bitmap::zeros(Shape::new(3, 5, 7));
        b.set(2, 4, 6, true);
        assert!(b.get(2, 4, 6));
        b.set(2, 4, 6, false);
        assert!(!b.get(2, 4, 6));
        assert_eq!(b.count_nz(), 0);
    }

    #[test]
    fn tc_and_wc_views() {
        let mut b = Bitmap::zeros(Shape::new(4, 2, 2));
        for c in 0..3 {
            b.set(c, 0, 0, true);
        }
        b.set(0, 1, 1, true);
        assert_eq!(b.tc_nz(0, 0), 3);
        assert_eq!(b.tc_nz(1, 1), 1);
        assert_eq!(b.wc_nz(0), 2);
        assert_eq!(b.wc_nz(3), 0);
        let pcs = b.per_channel_sparsity();
        assert!((pcs[0] - 0.5).abs() < 1e-12);
        assert!((pcs[3] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn containment_is_the_identity_law() {
        let shape = Shape::new(1, 2, 2);
        let act = Bitmap::from_values(shape, &[1.0, 0.0, 2.0, 3.0]);
        let grad = Bitmap::from_values(shape, &[1.0, 0.0, 0.0, 3.0]);
        // gradient footprint ⊆ activation footprint
        assert!(grad.contained_in(&act));
        assert!(!act.contained_in(&grad));
        let both = act.and(&grad);
        assert_eq!(both.count_nz(), 2);
    }

    #[test]
    fn sample_tracks_density_and_degenerate_cases() {
        use crate::util::rng::Pcg32;
        let shape = Shape::new(8, 16, 16);
        let mut rng = Pcg32::new(4);
        let b = Bitmap::sample(shape, 0.7, &mut rng);
        assert!((b.sparsity() - 0.3).abs() < 0.05, "sparsity {}", b.sparsity());
        // Degenerate densities consume no RNG state.
        let mut a = Pcg32::new(1);
        let mut c = Pcg32::new(1);
        let full = Bitmap::sample(shape, 1.0, &mut a);
        let empty = Bitmap::sample(shape, 0.0, &mut a);
        assert_eq!(full.count_nz(), shape.len());
        assert_eq!(empty.count_nz(), 0);
        assert_eq!(a.next_u32(), c.next_u32(), "fast paths must not draw");
        // Determinism from the stream.
        let d1 = Bitmap::sample(shape, 0.4, &mut Pcg32::new(7));
        let d2 = Bitmap::sample(shape, 0.4, &mut Pcg32::new(7));
        assert_eq!(d1, d2);
    }

    #[test]
    fn channel_bits_match_get() {
        let mut b = Bitmap::zeros(Shape::new(3, 2, 2));
        b.set(1, 0, 1, true);
        b.set(1, 1, 0, true);
        b.set(2, 1, 1, true);
        assert_eq!(b.channel_bits(0), vec![false; 4]);
        assert_eq!(b.channel_bits(1), vec![false, true, true, false]);
        assert_eq!(b.channel_bits(2), vec![false, false, false, true]);
    }

    #[test]
    fn count_handles_non_word_aligned_sizes() {
        // 3*3*3 = 27 bits — tail masking must not count garbage.
        let shape = Shape::new(3, 3, 3);
        let vals = vec![1.0f32; 27];
        let b = Bitmap::from_values(shape, &vals);
        assert_eq!(b.count_nz(), 27);
        assert_eq!(b.sparsity(), 0.0);
    }
}
