//! Non-zero offset encoding — the indexing stage of §4.2.
//!
//! The design indexes the generated feature/gradient map once per layer,
//! through the channel dimension, **32 values at a time**: each group of
//! 32 consecutive (channel-first) values is encoded as the list of 5-bit
//! offsets of its non-zero entries. The indexed values are then reused
//! `O(M·k²)` times, amortizing the encoding cost; neurons are *indexed,
//! not compressed*, preserving memory-access regularity.

use super::Bitmap;

/// Values per offset group (fixed by the 5-bit offset width).
pub const GROUP: usize = 32;

/// One encoded group: offsets (0..32) of the non-zero entries.
#[derive(Clone, Debug, PartialEq)]
pub struct OffsetGroup {
    /// 5-bit offsets, ascending.
    pub offsets: Vec<u8>,
}

impl OffsetGroup {
    pub fn nz(&self) -> usize {
        self.offsets.len()
    }
}

/// A tensor's offset map: groups in channel-first scan order plus the
/// original length (the tail group may be partial).
#[derive(Clone, Debug, PartialEq)]
pub struct EncodedTensor {
    pub len: usize,
    pub groups: Vec<OffsetGroup>,
}

impl EncodedTensor {
    /// Total non-zero entries.
    pub fn nz(&self) -> usize {
        self.groups.iter().map(|g| g.nz()).sum()
    }

    /// Storage cost in bits: 5 bits per offset plus a 6-bit count per
    /// group (hardware stores a per-group occupancy).
    pub fn bits(&self) -> usize {
        self.nz() * 5 + self.groups.len() * 6
    }
}

/// Encode a raw value slice (channel-first order).
pub fn encode_tensor(values: &[f32]) -> EncodedTensor {
    let mut groups = Vec::with_capacity(values.len().div_ceil(GROUP));
    for chunk in values.chunks(GROUP) {
        let offsets = chunk
            .iter()
            .enumerate()
            .filter(|(_, v)| **v != 0.0)
            .map(|(i, _)| i as u8)
            .collect();
        groups.push(OffsetGroup { offsets });
    }
    EncodedTensor { len: values.len(), groups }
}

/// Encode from a bitmap (the DRAM-resident form the BP uses).
pub fn encode_bitmap(b: &Bitmap) -> EncodedTensor {
    let shape = b.shape;
    let mut groups = Vec::with_capacity(shape.len().div_ceil(GROUP));
    let mut current = OffsetGroup { offsets: Vec::new() };
    let mut i = 0usize;
    for c in 0..shape.c {
        for y in 0..shape.h {
            for x in 0..shape.w {
                if b.get(c, y, x) {
                    current.offsets.push((i % GROUP) as u8);
                }
                i += 1;
                if i % GROUP == 0 {
                    groups.push(std::mem::replace(&mut current, OffsetGroup { offsets: Vec::new() }));
                }
            }
        }
    }
    if i % GROUP != 0 {
        groups.push(current);
    }
    EncodedTensor { len: shape.len(), groups }
}

/// Reconstruct which positions of group `gi` are non-zero — the gather
/// the synapse lane performs (Fig 8a). Returns absolute indices.
pub fn decode_group(enc: &EncodedTensor, gi: usize) -> Vec<usize> {
    enc.groups[gi]
        .offsets
        .iter()
        .map(|o| gi * GROUP + *o as usize)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Shape;

    #[test]
    fn encode_roundtrip() {
        let mut vals = vec![0.0f32; 70];
        for &i in &[0usize, 5, 31, 32, 63, 69] {
            vals[i] = 1.0;
        }
        let enc = encode_tensor(&vals);
        assert_eq!(enc.groups.len(), 3);
        assert_eq!(enc.nz(), 6);
        assert_eq!(decode_group(&enc, 0), vec![0, 5, 31]);
        assert_eq!(decode_group(&enc, 1), vec![32, 63]);
        assert_eq!(decode_group(&enc, 2), vec![69]);
    }

    #[test]
    fn encode_matches_bitmap_encoding() {
        let shape = Shape::new(2, 4, 4);
        let mut vals = vec![0.0f32; shape.len()];
        for i in (0..shape.len()).step_by(3) {
            vals[i] = (i + 1) as f32;
        }
        let from_vals = encode_tensor(&vals);
        let from_bm = encode_bitmap(&Bitmap::from_values(shape, &vals));
        assert_eq!(from_vals, from_bm);
    }

    #[test]
    fn dense_and_empty_extremes() {
        let dense = encode_tensor(&vec![1.0f32; 64]);
        assert_eq!(dense.nz(), 64);
        assert_eq!(dense.groups[0].nz(), GROUP);
        let empty = encode_tensor(&vec![0.0f32; 64]);
        assert_eq!(empty.nz(), 0);
        // indexing cost scales with nz
        assert!(dense.bits() > empty.bits());
    }

    #[test]
    fn offsets_fit_five_bits() {
        let vals: Vec<f32> = (0..256).map(|i| (i % 2) as f32).collect();
        let enc = encode_tensor(&vals);
        for g in &enc.groups {
            for &o in &g.offsets {
                assert!(o < 32);
            }
        }
    }
}
