//! Non-zero offset encoding — the indexing stage of §4.2 — plus the
//! word-level RLE codec behind the TraceFile v3 payload format.
//!
//! The design indexes the generated feature/gradient map once per layer,
//! through the channel dimension, **32 values at a time**: each group of
//! 32 consecutive (channel-first) values is encoded as the list of 5-bit
//! offsets of its non-zero entries. The indexed values are then reused
//! `O(M·k²)` times, amortizing the encoding cost; neurons are *indexed,
//! not compressed*, preserving memory-access regularity.
//!
//! The RLE codec ([`rle_encode_words`]/[`rle_decode_words`]) is a
//! different animal: it compresses a bitmap's *packed word stream* for
//! persistence (TensorDash-style bit-map compaction), not for the
//! hardware's indexing path. Runs never reorder anything — the stream
//! stays in the within-channel §4.3 order the PE drains.

use super::Bitmap;

/// Values per offset group (fixed by the 5-bit offset width).
pub const GROUP: usize = 32;

/// One encoded group: offsets (0..32) of the non-zero entries.
#[derive(Clone, Debug, PartialEq)]
pub struct OffsetGroup {
    /// 5-bit offsets, ascending.
    pub offsets: Vec<u8>,
}

impl OffsetGroup {
    pub fn nz(&self) -> usize {
        self.offsets.len()
    }
}

/// A tensor's offset map: groups in channel-first scan order plus the
/// original length (the tail group may be partial).
#[derive(Clone, Debug, PartialEq)]
pub struct EncodedTensor {
    pub len: usize,
    pub groups: Vec<OffsetGroup>,
}

impl EncodedTensor {
    /// Total non-zero entries.
    pub fn nz(&self) -> usize {
        self.groups.iter().map(|g| g.nz()).sum()
    }

    /// Storage cost in bits: 5 bits per offset plus a 6-bit count per
    /// group (hardware stores a per-group occupancy).
    pub fn bits(&self) -> usize {
        self.nz() * 5 + self.groups.len() * 6
    }
}

/// Encode a raw value slice (channel-first order).
pub fn encode_tensor(values: &[f32]) -> EncodedTensor {
    let mut groups = Vec::with_capacity(values.len().div_ceil(GROUP));
    for chunk in values.chunks(GROUP) {
        let offsets = chunk
            .iter()
            .enumerate()
            .filter(|(_, v)| **v != 0.0)
            .map(|(i, _)| i as u8)
            .collect();
        groups.push(OffsetGroup { offsets });
    }
    EncodedTensor { len: values.len(), groups }
}

/// Encode from a bitmap (the DRAM-resident form the BP uses).
pub fn encode_bitmap(b: &Bitmap) -> EncodedTensor {
    let shape = b.shape;
    let mut groups = Vec::with_capacity(shape.len().div_ceil(GROUP));
    let mut current = OffsetGroup { offsets: Vec::new() };
    let mut i = 0usize;
    for c in 0..shape.c {
        for y in 0..shape.h {
            for x in 0..shape.w {
                if b.get(c, y, x) {
                    current.offsets.push((i % GROUP) as u8);
                }
                i += 1;
                if i % GROUP == 0 {
                    groups.push(std::mem::replace(&mut current, OffsetGroup { offsets: Vec::new() }));
                }
            }
        }
    }
    if i % GROUP != 0 {
        groups.push(current);
    }
    EncodedTensor { len: shape.len(), groups }
}

/// Reconstruct which positions of group `gi` are non-zero — the gather
/// the synapse lane performs (Fig 8a). Returns absolute indices.
pub fn decode_group(enc: &EncodedTensor, gi: usize) -> Vec<usize> {
    enc.groups[gi]
        .offsets
        .iter()
        .map(|o| gi * GROUP + *o as usize)
        .collect()
}

// ---------------------------------------------------------------------------
// Word-level RLE — the TraceFile v3 payload codec.
// ---------------------------------------------------------------------------

/// All-ones mask of the *valid* bits of word `wi` in a `len_bits`-bit
/// packed stream: `!0` for interior words, the tail mask for the final
/// word. "Full" in the run-length grammar means equal to this mask, so
/// an all-ones bitmap whose length is not word-aligned still encodes as
/// one `oN` run.
fn word_mask(wi: usize, len_bits: usize) -> u64 {
    let lo = wi * 64;
    debug_assert!(lo < len_bits);
    if len_bits - lo >= 64 {
        !0
    } else {
        (1u64 << (len_bits - lo)) - 1
    }
}

/// Run-length encode a packed LSB-first word stream (`len_bits` valid
/// bits, channel-major §4.3 order). Space-separated tokens:
///
/// * `zN` — `N` consecutive all-zero words;
/// * `oN` — `N` consecutive all-ones words (ones = every valid bit set);
/// * `<hex>` — one literal word, lowercase, leading zeros stripped.
///
/// Zero and full words dominate real ReLU/gradient footprints (whole
/// channels dark, dense post-Add maps), so payloads shrink by the run
/// structure alone; sparse literal words shrink further by the stripped
/// leading zeros. The stream order is untouched — this is persistence
/// compaction, not a new drain order.
pub fn rle_encode_words(words: &[u64], len_bits: usize) -> String {
    use std::fmt::Write;
    debug_assert_eq!(words.len(), len_bits.div_ceil(64), "word count vs bit length");
    let mut out = String::new();
    let mut i = 0usize;
    while i < words.len() {
        if !out.is_empty() {
            out.push(' ');
        }
        let w = words[i];
        if w == 0 {
            let mut n = 1;
            while i + n < words.len() && words[i + n] == 0 {
                n += 1;
            }
            let _ = write!(out, "z{n}");
            i += n;
        } else if w == word_mask(i, len_bits) {
            let mut n = 1;
            while i + n < words.len() && words[i + n] == word_mask(i + n, len_bits) {
                n += 1;
            }
            let _ = write!(out, "o{n}");
            i += n;
        } else {
            let _ = write!(out, "{w:x}");
            i += 1;
        }
    }
    out
}

/// Decode an [`rle_encode_words`] payload back into packed words.
/// Strict: malformed tokens, runs that overrun the expected word count,
/// payloads that stop short, and bits set beyond `len_bits` are all hard
/// errors — a corrupt payload must never load as "good" data.
pub fn rle_decode_words(s: &str, len_bits: usize) -> anyhow::Result<Vec<u64>> {
    let n_words = len_bits.div_ceil(64);
    let mut words: Vec<u64> = Vec::with_capacity(n_words);
    for tok in s.split_ascii_whitespace() {
        anyhow::ensure!(
            words.len() < n_words,
            "RLE payload continues past its {n_words}-word shape (at token '{tok}')"
        );
        // Exactly the emitted grammar, nothing looser: run lengths are
        // bare ASCII digits without leading zeros and literals bare
        // lowercase hex with leading zeros stripped (so a zero word is
        // always a `z` run, never a literal) — the `+` signs, leading
        // zeros and uppercase that `parse`/`from_str_radix` would
        // otherwise tolerate are corruption, not data.
        let run = |tail: &str| -> anyhow::Result<usize> {
            anyhow::ensure!(
                !tail.is_empty()
                    && !tail.starts_with('0')
                    && tail.bytes().all(|b| b.is_ascii_digit()),
                "bad run length in RLE token '{tok}'"
            );
            let n: usize = tail
                .parse()
                .map_err(|_| anyhow::anyhow!("bad run length in RLE token '{tok}'"))?;
            anyhow::ensure!(n >= 1, "empty run in RLE token '{tok}'");
            anyhow::ensure!(
                words.len() + n <= n_words,
                "run '{tok}' overruns the {n_words}-word shape"
            );
            Ok(n)
        };
        match tok.as_bytes()[0] {
            b'z' => {
                let n = run(&tok[1..])?;
                words.resize(words.len() + n, 0);
            }
            b'o' => {
                for _ in 0..run(&tok[1..])? {
                    words.push(word_mask(words.len(), len_bits));
                }
            }
            _ => {
                anyhow::ensure!(
                    !tok.starts_with('0')
                        && tok.bytes().all(|b| matches!(b, b'0'..=b'9' | b'a'..=b'f')),
                    "bad RLE word token '{tok}'"
                );
                words.push(
                    u64::from_str_radix(tok, 16)
                        .map_err(|_| anyhow::anyhow!("bad RLE word token '{tok}'"))?,
                );
            }
        }
    }
    anyhow::ensure!(
        words.len() == n_words,
        "RLE payload covers {} of {n_words} words",
        words.len()
    );
    if n_words > 0 {
        anyhow::ensure!(
            words[n_words - 1] & !word_mask(n_words - 1, len_bits) == 0,
            "RLE payload has bits set beyond the {len_bits}-bit shape"
        );
    }
    Ok(words)
}

// ---------------------------------------------------------------------------
// Binary word-level RLE — the TraceFile v4 payload codec.
// ---------------------------------------------------------------------------

/// Binary run-length encoding of a packed LSB-first word stream — the
/// TraceFile **v4** payload codec. Same run semantics as the v3 text
/// grammar ([`rle_encode_words`]), but tokens are packed bytes instead
/// of ASCII, and literal words are raw little-endian `u64`s instead of
/// hex — so the decoder writes straight into a `Vec<u64>` with no string
/// scanning. Token layout, appended to `out`:
///
/// * `0x00` + `u32` LE count — that many consecutive all-zero words;
/// * `0x01` + `u32` LE count — that many all-ones words ("ones" = every
///   *valid* bit of the word position, tail-aware via the same
///   [`word_mask`] the text grammar uses);
/// * `0x02` + `u32` LE count + count × 8 LE bytes — literal words.
///
/// Unlike the text grammar, consecutive literal words coalesce into one
/// token (5 bytes of framing amortized over the run), so a mid-density
/// payload costs `~8·n + 5` bytes vs v3's `~17·n` hex characters.
pub fn rle_encode_words_bin(words: &[u64], len_bits: usize, out: &mut Vec<u8>) {
    debug_assert_eq!(words.len(), len_bits.div_ceil(64), "word count vs bit length");
    let mut i = 0usize;
    while i < words.len() {
        let w = words[i];
        if w == 0 {
            let mut n = 1;
            while i + n < words.len() && words[i + n] == 0 {
                n += 1;
            }
            out.push(0);
            out.extend_from_slice(&(n as u32).to_le_bytes());
            i += n;
        } else if w == word_mask(i, len_bits) {
            let mut n = 1;
            while i + n < words.len() && words[i + n] == word_mask(i + n, len_bits) {
                n += 1;
            }
            out.push(1);
            out.extend_from_slice(&(n as u32).to_le_bytes());
            i += n;
        } else {
            let mut n = 1;
            while i + n < words.len()
                && words[i + n] != 0
                && words[i + n] != word_mask(i + n, len_bits)
            {
                n += 1;
            }
            out.push(2);
            out.extend_from_slice(&(n as u32).to_le_bytes());
            for w in &words[i..i + n] {
                out.extend_from_slice(&w.to_le_bytes());
            }
            i += n;
        }
    }
}

/// Decode an [`rle_encode_words_bin`] payload back into packed words.
/// Strict like the text decoder: truncated tokens, unknown tags, runs
/// that overrun or stop short of the expected word count, and bits set
/// beyond `len_bits` are all hard errors.
pub fn rle_decode_words_bin(bytes: &[u8], len_bits: usize) -> anyhow::Result<Vec<u64>> {
    let n_words = len_bits.div_ceil(64);
    let mut words: Vec<u64> = Vec::with_capacity(n_words);
    let mut p = 0usize;
    while p < bytes.len() {
        anyhow::ensure!(
            words.len() < n_words,
            "binary RLE payload continues past its {n_words}-word shape"
        );
        anyhow::ensure!(p + 5 <= bytes.len(), "binary RLE token truncated");
        let tag = bytes[p];
        let n = u32::from_le_bytes(bytes[p + 1..p + 5].try_into().unwrap()) as usize;
        p += 5;
        anyhow::ensure!(n >= 1, "empty run in binary RLE payload");
        anyhow::ensure!(
            words.len() + n <= n_words,
            "binary RLE run of {n} overruns the {n_words}-word shape"
        );
        match tag {
            0 => words.resize(words.len() + n, 0),
            1 => {
                for _ in 0..n {
                    words.push(word_mask(words.len(), len_bits));
                }
            }
            2 => {
                anyhow::ensure!(
                    p + n * 8 <= bytes.len(),
                    "binary RLE literal run of {n} words truncated"
                );
                for k in 0..n {
                    words.push(u64::from_le_bytes(
                        bytes[p + k * 8..p + k * 8 + 8].try_into().unwrap(),
                    ));
                }
                p += n * 8;
            }
            other => anyhow::bail!("unknown binary RLE tag {other}"),
        }
    }
    anyhow::ensure!(
        words.len() == n_words,
        "binary RLE payload covers {} of {n_words} words",
        words.len()
    );
    if n_words > 0 {
        anyhow::ensure!(
            words[n_words - 1] & !word_mask(n_words - 1, len_bits) == 0,
            "binary RLE payload has bits set beyond the {len_bits}-bit shape"
        );
    }
    Ok(words)
}

// ---------------------------------------------------------------------------
// Word-granular run index — zero-skip metadata for replayed bitmaps.
// ---------------------------------------------------------------------------

/// Sorted, disjoint word ranges of a packed bitmap that are entirely
/// zero or entirely ones ("ones" in the [`rle_encode_words`] sense:
/// every *valid* bit set, tail-aware). This is the run structure the v3
/// trace payloads exploit for compaction, recomputed at word granularity
/// on the reconstructed map so it is equally valid for v2 payloads,
/// delta-decoded v3 steps (whose on-disk runs describe the XOR delta,
/// not the map), and derived footprint/gradient maps.
///
/// The exact backend's gather plans query it to skip gathering from
/// all-zero source ranges and to short-circuit all-ones windows — the
/// simulator-side analogue of SparseTrain/TensorDash operand skipping.
/// It is pure execution strategy: consulting it never changes a result.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RunIndex {
    /// Half-open word ranges `[lo, hi)` that are entirely zero.
    zero_runs: Vec<(u32, u32)>,
    /// Half-open word ranges whose every valid bit is set.
    one_runs: Vec<(u32, u32)>,
}

impl RunIndex {
    /// Scan a packed word stream (`len_bits` valid bits) into its run
    /// structure. One linear pass; maximal runs by construction.
    pub fn scan(words: &[u64], len_bits: usize) -> RunIndex {
        debug_assert_eq!(words.len(), len_bits.div_ceil(64), "word count vs bit length");
        let mut idx = RunIndex::default();
        let mut i = 0usize;
        while i < words.len() {
            let w = words[i];
            if w == 0 {
                let lo = i;
                while i < words.len() && words[i] == 0 {
                    i += 1;
                }
                idx.zero_runs.push((lo as u32, i as u32));
            } else if w == word_mask(i, len_bits) {
                let lo = i;
                while i < words.len() && words[i] == word_mask(i, len_bits) {
                    i += 1;
                }
                idx.one_runs.push((lo as u32, i as u32));
            } else {
                i += 1;
            }
        }
        idx
    }

    /// True iff every word of `[wlo, whi)` is all-zero (empty ranges
    /// vacuously qualify). Runs are maximal, so a covered range lies
    /// inside a single run — one `partition_point` per query.
    pub fn all_zero(&self, wlo: usize, whi: usize) -> bool {
        Self::covered(&self.zero_runs, wlo, whi)
    }

    /// True iff every valid bit of words `[wlo, whi)` is set.
    pub fn all_ones(&self, wlo: usize, whi: usize) -> bool {
        Self::covered(&self.one_runs, wlo, whi)
    }

    fn covered(runs: &[(u32, u32)], wlo: usize, whi: usize) -> bool {
        if whi <= wlo {
            return true;
        }
        let i = runs.partition_point(|&(_, hi)| (hi as usize) <= wlo);
        i < runs.len() && (runs[i].0 as usize) <= wlo && whi <= (runs[i].1 as usize)
    }

    /// Total words covered by zero runs (observability/tests).
    pub fn zero_words(&self) -> usize {
        self.zero_runs.iter().map(|&(lo, hi)| (hi - lo) as usize).sum()
    }

    /// Total words covered by ones runs.
    pub fn one_words(&self) -> usize {
        self.one_runs.iter().map(|&(lo, hi)| (hi - lo) as usize).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Shape;

    #[test]
    fn encode_roundtrip() {
        let mut vals = vec![0.0f32; 70];
        for &i in &[0usize, 5, 31, 32, 63, 69] {
            vals[i] = 1.0;
        }
        let enc = encode_tensor(&vals);
        assert_eq!(enc.groups.len(), 3);
        assert_eq!(enc.nz(), 6);
        assert_eq!(decode_group(&enc, 0), vec![0, 5, 31]);
        assert_eq!(decode_group(&enc, 1), vec![32, 63]);
        assert_eq!(decode_group(&enc, 2), vec![69]);
    }

    #[test]
    fn encode_matches_bitmap_encoding() {
        let shape = Shape::new(2, 4, 4);
        let mut vals = vec![0.0f32; shape.len()];
        for i in (0..shape.len()).step_by(3) {
            vals[i] = (i + 1) as f32;
        }
        let from_vals = encode_tensor(&vals);
        let from_bm = encode_bitmap(&Bitmap::from_values(shape, &vals));
        assert_eq!(from_vals, from_bm);
    }

    #[test]
    fn dense_and_empty_extremes() {
        let dense = encode_tensor(&vec![1.0f32; 64]);
        assert_eq!(dense.nz(), 64);
        assert_eq!(dense.groups[0].nz(), GROUP);
        let empty = encode_tensor(&vec![0.0f32; 64]);
        assert_eq!(empty.nz(), 0);
        // indexing cost scales with nz
        assert!(dense.bits() > empty.bits());
    }

    #[test]
    fn offsets_fit_five_bits() {
        let vals: Vec<f32> = (0..256).map(|i| (i % 2) as f32).collect();
        let enc = encode_tensor(&vals);
        for g in &enc.groups {
            for &o in &g.offsets {
                assert!(o < 32);
            }
        }
    }

    #[test]
    fn rle_runs_collapse_zero_and_full_words() {
        // 5 words, 300 valid bits (tail word = 44 bits).
        let tail = (1u64 << 44) - 1;
        let words = vec![0, 0, 0xdead_beef, !0, tail];
        let s = rle_encode_words(&words, 300);
        assert_eq!(s, "z2 deadbeef o2");
        assert_eq!(rle_decode_words(&s, 300).unwrap(), words);
        // All-zero and all-ones streams are single tokens.
        assert_eq!(rle_encode_words(&[0, 0, 0, 0, 0], 300), "z5");
        assert_eq!(rle_encode_words(&[!0, !0, !0, !0, tail], 300), "o5");
        assert_eq!(rle_decode_words("o5", 300).unwrap(), vec![!0, !0, !0, !0, tail]);
    }

    #[test]
    fn rle_rejects_malformed_payloads() {
        // Wrong totals: short, long, overlong runs.
        assert!(rle_decode_words("z1", 300).is_err(), "covers 1 of 5 words");
        assert!(rle_decode_words("z6", 300).is_err(), "run overruns the shape");
        assert!(rle_decode_words("z5 z1", 300).is_err(), "tokens past the shape");
        // Malformed tokens.
        assert!(rle_decode_words("z0 z5", 300).is_err(), "empty run");
        assert!(rle_decode_words("z", 300).is_err(), "run without a length");
        assert!(rle_decode_words("qq z4", 300).is_err(), "non-hex literal");
        assert!(rle_decode_words("o-1 z4", 300).is_err(), "negative run");
        // The grammar is exactly what the encoder emits — the laxer
        // forms std's parsers accept are corruption here.
        assert!(rle_decode_words("z+5", 300).is_err(), "signed run length");
        assert!(rle_decode_words("z05", 300).is_err(), "leading-zero run length");
        assert!(rle_decode_words("z4 DEADBEEF", 300).is_err(), "uppercase literal");
        assert!(rle_decode_words("z4 +1f", 300).is_err(), "signed literal");
        assert!(rle_decode_words("z4 0deadbeef", 300).is_err(), "leading-zero literal");
        assert!(rle_decode_words("z4 0", 300).is_err(), "zero literal must be a z run");
        // Bits beyond the shape in the tail word.
        assert!(rle_decode_words("z4 ffffffffffffffff", 300).is_err());
        // The same bits are fine when the shape is word-aligned.
        assert!(rle_decode_words("z4 ffffffffffffffff", 320).is_ok());
    }

    #[test]
    fn binary_rle_mirrors_the_text_grammar_runs() {
        // Same stream as the text-grammar pin: z2 deadbeef o2 (300 bits).
        let tail = (1u64 << 44) - 1;
        let words = vec![0, 0, 0xdead_beef, !0, tail];
        let mut enc = Vec::new();
        rle_encode_words_bin(&words, 300, &mut enc);
        // zero-run(2) + literal-run(1, 8 bytes) + ones-run(2).
        assert_eq!(
            enc,
            [
                &[0u8, 2, 0, 0, 0][..],
                &[2u8, 1, 0, 0, 0][..],
                &0xdead_beefu64.to_le_bytes()[..],
                &[1u8, 2, 0, 0, 0][..],
            ]
            .concat()
        );
        assert_eq!(rle_decode_words_bin(&enc, 300).unwrap(), words);
        // Degenerate streams are single 5-byte tokens.
        let mut z = Vec::new();
        rle_encode_words_bin(&[0, 0, 0, 0, 0], 300, &mut z);
        assert_eq!(z, vec![0, 5, 0, 0, 0]);
        let mut o = Vec::new();
        rle_encode_words_bin(&[!0, !0, !0, !0, tail], 300, &mut o);
        assert_eq!(o, vec![1, 5, 0, 0, 0]);
        assert_eq!(rle_decode_words_bin(&o, 300).unwrap(), vec![!0, !0, !0, !0, tail]);
        // Adjacent literal words coalesce into one token.
        let mut lits = Vec::new();
        rle_encode_words_bin(&[3, 5, 7], 192, &mut lits);
        assert_eq!(lits.len(), 5 + 3 * 8);
        assert_eq!(rle_decode_words_bin(&lits, 192).unwrap(), vec![3, 5, 7]);
        let empty: Vec<u64> = Vec::new();
        let mut e = Vec::new();
        rle_encode_words_bin(&empty, 0, &mut e);
        assert!(e.is_empty());
        assert_eq!(rle_decode_words_bin(&e, 0).unwrap(), empty);
    }

    #[test]
    fn binary_rle_rejects_malformed_payloads() {
        let ok = |bytes: &[u8], bits| rle_decode_words_bin(bytes, bits);
        // Wrong totals: short, overlong, tokens past the shape.
        assert!(ok(&[0, 1, 0, 0, 0], 300).is_err(), "covers 1 of 5 words");
        assert!(ok(&[0, 6, 0, 0, 0], 300).is_err(), "run overruns the shape");
        assert!(
            ok(&[0, 5, 0, 0, 0, 0, 1, 0, 0, 0], 300).is_err(),
            "tokens past the shape"
        );
        // Malformed tokens.
        assert!(ok(&[0, 0, 0, 0, 0, 0, 5, 0, 0, 0], 300).is_err(), "empty run");
        assert!(ok(&[0], 300).is_err(), "truncated token header");
        assert!(ok(&[3, 5, 0, 0, 0], 300).is_err(), "unknown tag");
        assert!(ok(&[2, 1, 0, 0, 0, 0xEF], 64).is_err(), "truncated literal");
        // Bits beyond the shape in the tail word.
        let mut full = vec![0u8, 4, 0, 0, 0, 2, 1, 0, 0, 0];
        full.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(ok(&full, 300).is_err(), "tail bits beyond the shape");
        assert!(ok(&full, 320).is_ok(), "same bytes fine when word-aligned");
    }

    #[test]
    fn binary_rle_roundtrips_adversarial_patterns() {
        // Checkerboard (no runs at all), alternating runs, lone bits at
        // word boundaries — every stream must reproduce exactly.
        let cases: Vec<(Vec<u64>, usize)> = vec![
            (vec![0xAAAA_AAAA_AAAA_AAAA; 6], 384),
            (vec![0x5555_5555_5555_5555; 3], 192),
            (vec![0, !0, 0, !0, 0, (1u64 << 20) - 1], 340),
            (vec![1, 1 << 63, 0, !0], 256),
            (vec![(1u64 << 10) - 1], 10),
        ];
        for (words, bits) in cases {
            let mut enc = Vec::new();
            rle_encode_words_bin(&words, bits, &mut enc);
            assert_eq!(
                rle_decode_words_bin(&enc, bits).unwrap(),
                words,
                "{bits}-bit stream"
            );
        }
    }

    #[test]
    fn run_index_scans_and_answers_range_queries() {
        // 6 words, 350 valid bits (tail word = 30 bits): zz M oo O(tail).
        let tail = (1u64 << 30) - 1;
        let words = vec![0, 0, 0xdead_beef, !0, !0, tail];
        let idx = RunIndex::scan(&words, 350);
        assert_eq!(idx.zero_words(), 2);
        assert_eq!(idx.one_words(), 3);
        assert!(idx.all_zero(0, 2));
        assert!(idx.all_zero(1, 2));
        assert!(!idx.all_zero(0, 3), "mixed word breaks the run");
        assert!(!idx.all_zero(2, 3));
        assert!(idx.all_ones(3, 6), "tail-masked full word counts as ones");
        assert!(idx.all_ones(4, 5));
        assert!(!idx.all_ones(2, 4));
        assert!(!idx.all_ones(0, 2));
        // Empty ranges are vacuously both.
        assert!(idx.all_zero(2, 2) && idx.all_ones(0, 0));
    }

    #[test]
    fn run_index_extremes_and_unaligned_tails() {
        let all_zero = RunIndex::scan(&[0; 4], 256);
        assert!(all_zero.all_zero(0, 4) && !all_zero.all_ones(0, 1));
        assert_eq!(all_zero.zero_words(), 4);
        let ones_tail = (1u64 << 44) - 1;
        let all_ones = RunIndex::scan(&[!0, !0, ones_tail], 172);
        assert!(all_ones.all_ones(0, 3) && !all_ones.all_zero(2, 3));
        // A tail word with a bit missing is mixed, not a ones run.
        let nearly = RunIndex::scan(&[!0, ones_tail >> 1], 108);
        assert!(nearly.all_ones(0, 1) && !nearly.all_ones(0, 2));
        // Agreement with the RLE grammar: zero/ones words classify
        // identically to the zN/oN tokens the codec would emit.
        let mixed = vec![0, 0xf00d, !0, 0, 0];
        let idx = RunIndex::scan(&mixed, 320);
        assert_eq!(idx.zero_words(), 3);
        assert_eq!(idx.one_words(), 1);
        let empty = RunIndex::scan(&[], 0);
        assert!(empty.all_zero(0, 0) && empty.zero_words() == 0);
    }
}
