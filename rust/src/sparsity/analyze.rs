//! Sparsity-opportunity analysis — the paper's §2.1/§3 reasoning, applied
//! mechanically to a network graph.
//!
//! Given per-layer *forward output sparsity* fractions (from the
//! calibrated model or from real traces), this derives for every compute
//! layer and every training phase which sparsity type applies and at what
//! fraction:
//!
//! * **FP input sparsity** — zeros in the layer's input feature map
//!   (whatever its producer is; dense producers give `None`).
//! * **BP input sparsity** — zeros in the gradient arriving at the
//!   layer's output. A directly-following ReLU makes it sparse; BatchNorm
//!   *re-densifies* it (Fig 3c) — the limitation of prior input-sparsity
//!   work the paper targets.
//! * **BP output sparsity** — the paper's contribution: if the layer's
//!   *input* was produced by a ReLU (directly or through Concat), the
//!   input-gradient's zero footprint is known a priori from the forward
//!   bitmap, and those outputs are skipped. A MaxPool producer breaks
//!   this (all gradient locations must be evaluated, §6).
//! * **WG operand sparsities** — activations (forward) × gradients (BP).

use crate::config::BitmapPattern;
use crate::nn::{LayerId, LayerKind, Network};
use crate::trace::{LayerTrace, StepTrace, TraceFile};
use crate::util::rng::Pcg32;

use super::bitmap::Bitmap;
use super::model::{SparsityModel, TraceSource};

/// Which sparsity types a (layer, phase) admits — reporting convenience.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparsityKind {
    None,
    InputOnly,
    OutputOnly,
    Both,
}

/// Per-compute-layer sparsity opportunities (fractions in `[0,1]`).
#[derive(Clone, Debug)]
pub struct LayerOpportunity {
    pub layer: LayerId,
    pub name: String,
    /// FP: sparsity of the input feature map (None ⇒ dense input).
    pub fp_input: Option<f64>,
    /// BP: sparsity of the incoming gradient (input sparsity).
    pub bp_input: Option<f64>,
    /// BP: a-priori-known zero fraction of the produced input-gradient
    /// (output sparsity).
    pub bp_output: Option<f64>,
    /// WG: sparsity of the activation operand.
    pub wg_act: Option<f64>,
    /// WG: sparsity of the gradient operand.
    pub wg_grad: Option<f64>,
    /// Whether this layer produces an input-gradient at all (the first
    /// compute layer does not).
    pub has_bp: bool,
}

impl LayerOpportunity {
    pub fn bp_kind(&self) -> SparsityKind {
        match (self.bp_input.is_some(), self.bp_output.is_some()) {
            (false, false) => SparsityKind::None,
            (true, false) => SparsityKind::InputOnly,
            (false, true) => SparsityKind::OutputOnly,
            (true, true) => SparsityKind::Both,
        }
    }
}

fn some_if_positive(s: f64) -> Option<f64> {
    (s > 1e-9).then_some(s.min(1.0))
}

/// Gradient sparsity at each layer's *output*, by reverse traversal.
///
/// Combination rules (correlation assumptions documented in DESIGN.md §5):
/// through-ReLU `s = max(s_g, s_m)` (footprints are correlated, see the
/// §3.2 identity); BatchNorm/conv/fc densify to 0; MaxPool backward
/// scatters ≤ one gradient per window (`1 − (1−s)·UV/HW`); Avg/GAP/Add/
/// Concat pass the fraction through; multiple consumers multiply (zero
/// iff all contributions zero).
pub fn gradient_sparsity(net: &Network, fwd: &[f64]) -> Vec<f64> {
    assert_eq!(fwd.len(), net.len());
    let n = net.len();
    let mut gs = vec![0.0f64; n];
    let consumer_map = net.consumer_map();
    // Process in reverse topological (= reverse insertion) order.
    for id in (0..n).rev() {
        let consumers = &consumer_map[id];
        if consumers.is_empty() {
            gs[id] = 0.0; // loss gradient: dense scalar path
            continue;
        }
        let mut acc = 1.0f64;
        for &k in consumers {
            let kl = net.layer(k);
            let sg = gs[k];
            let contribution = match kl.kind {
                LayerKind::ReLU => {
                    // The §3.2 identity: the masked gradient's zeros are a
                    // superset of the mask's zeros, and incoming-gradient
                    // zeros (e.g. maxpool-backward scatter) concentrate on
                    // positions the mask keeps — the footprints are
                    // strongly correlated, so the combined sparsity is the
                    // max, not the independence union.
                    let sm = fwd[k]; // ReLU output sparsity == its mask
                    sg.max(sm)
                }
                LayerKind::BatchNorm
                | LayerKind::Conv { .. }
                | LayerKind::DwConv { .. }
                | LayerKind::Fc { .. }
                | LayerKind::Softmax => 0.0,
                LayerKind::MaxPool { .. } => {
                    let out = kl.out;
                    let inp = net.layer(id).out;
                    let ratio = (out.h * out.w) as f64 / (inp.h * inp.w) as f64;
                    1.0 - (1.0 - sg) * ratio.min(1.0)
                }
                LayerKind::AvgPool { .. } | LayerKind::GlobalAvgPool => sg,
                LayerKind::Add | LayerKind::Concat => sg,
                LayerKind::Input => unreachable!("input consumes nothing"),
            };
            acc *= contribution.clamp(0.0, 1.0);
        }
        gs[id] = acc;
    }
    gs
}

/// Is the output-sparsity mask of `id`'s output known a priori?
/// True for ReLU outputs and Concats whose leaves are all mask-known.
fn mask_known(net: &Network, id: LayerId, fwd: &[f64]) -> Option<f64> {
    let l = net.layer(id);
    match l.kind {
        LayerKind::ReLU => Some(fwd[id]),
        LayerKind::Concat => {
            let mut weighted = 0.0;
            let mut total = 0.0;
            for &i in &l.inputs {
                let s = mask_known(net, i, fwd)?;
                let c = net.layer(i).out.c as f64;
                weighted += s * c;
                total += c;
            }
            Some(weighted / total)
        }
        _ => None,
    }
}

/// Analyze every compute layer of a network.
pub fn analyze_network(net: &Network, fwd: &[f64]) -> Vec<LayerOpportunity> {
    assert_eq!(fwd.len(), net.len(), "one fwd-sparsity entry per layer");
    let gs = gradient_sparsity(net, fwd);
    let first_compute = net.compute_layers().first().map(|l| l.id);
    net.compute_layers()
        .into_iter()
        .map(|l| {
            let producer = l.inputs[0];
            let fp_input = some_if_positive(fwd[producer]);
            let bp_input = some_if_positive(gs[l.id]);
            let bp_output = mask_known(net, producer, fwd).and_then(some_if_positive);
            LayerOpportunity {
                layer: l.id,
                name: l.name.clone(),
                fp_input,
                bp_input,
                bp_output,
                wg_act: fp_input,
                wg_grad: bp_input,
                has_bp: Some(l.id) != first_compute,
            }
        })
        .collect()
}

/// Synthetic stand-in for a layer's capture-time output footprint,
/// used to record **post-Add footprints**: a ReLU contributes its
/// sampled map, an Add the OR of its branches (exact for non-negative
/// summands), and anything else — conv/BN/fc outputs, which are
/// non-zero at generic positions — contributes a dense map. Real
/// capture writes the actual value bitmap instead; the dense arms here
/// mirror what those values generically are.
pub(crate) fn synth_footprint(
    net: &Network,
    id: crate::nn::LayerId,
    relu_acts: &std::collections::HashMap<crate::nn::LayerId, Bitmap>,
) -> Bitmap {
    let l = net.layer(id);
    match l.kind {
        LayerKind::ReLU => relu_acts[&id].clone(),
        LayerKind::Add => {
            let mut acc = synth_footprint(net, l.inputs[0], relu_acts);
            for &i in &l.inputs[1..] {
                acc = acc.or(&synth_footprint(net, i, relu_acts));
            }
            acc
        }
        _ => Bitmap::ones(l.out),
    }
}

/// Synthesize a payload-bearing trace file (v3 by default) from the
/// calibrated sparsity model — the capture path's stand-in when no
/// PJRT artifacts exist (the real trainer captures real tensors through
/// `runtime::bitmap_from_nhwc`). This is what `agos trace` writes and
/// what the replay tests/figures feed through `sim::ReplayBank`.
///
/// Per step: every ReLU gets an activation bitmap drawn at its assigned
/// forward density (iid or blobbed), and a gradient bitmap built as
/// `act ∧ keep` with the keep rate solved from the §3-derived gradient
/// sparsity at the ReLU's input — so footprint(grad) ⊆ footprint(act)
/// holds *by construction* and the scalar fields derived from the maps
/// can never disagree with the patterns. Every residual Add layer
/// additionally records an act-only **post-Add footprint**
/// ([`synth_footprint`]) so `sim::replay::derive_footprint` no longer
/// stops at Add nodes.
pub fn capture_synthetic_trace(
    net: &Network,
    model: &SparsityModel,
    steps: usize,
    pattern: BitmapPattern,
    blob_radius: usize,
) -> TraceFile {
    capture_synthetic_trace_images(net, model, steps, 1, pattern, blob_radius)
}

/// [`capture_synthetic_trace`] with a per-step image count: each of the
/// `images` captures becomes its own trace step (same `step` number,
/// distinct patterns), mirroring `agos train --trace-images N` — the
/// replay bank's round-robin widens with no format change, and the v3
/// delta/RLE encoding keeps the payload growth sub-linear. `images == 1`
/// reproduces [`capture_synthetic_trace`] bit-for-bit.
pub fn capture_synthetic_trace_images(
    net: &Network,
    model: &SparsityModel,
    steps: usize,
    images: usize,
    pattern: BitmapPattern,
    blob_radius: usize,
) -> TraceFile {
    let seed = match &model.source {
        TraceSource::Synthetic { seed } | TraceSource::Measured { seed, .. } => *seed,
    };
    let per_step = model.assign_batch(net, steps.max(1));
    let images = images.max(1);
    let steps_n = per_step.len();
    // Post-Add footprints only exist on residual graphs; skip the
    // per-ReLU map retention entirely for Add-free networks.
    let has_adds = net.layers().iter().any(|l| matches!(l.kind, LayerKind::Add));
    let mut trace = TraceFile::new(&net.name);
    for (si, fwd) in per_step.iter().enumerate() {
        let gs = gradient_sparsity(net, fwd);
        for image in 0..images {
            // Image-major flat stream index: image 0 of step `si` keeps
            // the index `si` the single-image capture used, so widening
            // a capture never perturbs the patterns that already existed
            // — extra images append fresh stream indices instead.
            let flat = (image * steps_n + si) as u64;
            let mut rng =
                Pcg32::new(seed ^ 0xB17A ^ flat.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut layers = Vec::new();
            let mut relu_acts: std::collections::HashMap<crate::nn::LayerId, Bitmap> =
                Default::default();
            for l in net.layers() {
                if !l.kind.is_relu() {
                    continue;
                }
                let s_act = fwd[l.id];
                let act = match pattern {
                    BitmapPattern::Iid => Bitmap::sample(l.out, 1.0 - s_act, &mut rng),
                    BitmapPattern::Blobs => {
                        Bitmap::sample_blobs(l.out, 1.0 - s_act, blob_radius, &mut rng)
                    }
                };
                // Gradient below this ReLU (at its producer's output): zeros
                // are a superset of the mask's, so thin the activation
                // footprint down to the analyzed gradient density.
                let s_grad = gs[l.inputs[0]].max(s_act);
                let keep = ((1.0 - s_grad) / (1.0 - s_act).max(1e-9)).clamp(0.0, 1.0);
                let keep_map = Bitmap::sample(l.out, keep, &mut rng);
                if has_adds {
                    relu_acts.insert(l.id, act.clone());
                }
                layers.push(LayerTrace::from_bitmaps(&l.name, act.clone(), act.and(&keep_map)));
            }
            // Post-Add footprints: capture-time data, not derivable from
            // the ReLU maps (conv summands can be negative). Near-dense
            // in practice — and therefore nearly free under the v3 RLE.
            if has_adds {
                for l in net.layers() {
                    if matches!(l.kind, LayerKind::Add) {
                        let fp = synth_footprint(net, l.id, &relu_acts);
                        layers.push(LayerTrace::from_act(&l.name, fp));
                    }
                }
            }
            trace.steps.push(StepTrace {
                step: si,
                loss: 2.3 * 0.92f64.powi(si as i32),
                layers,
            });
        }
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Network;

    /// conv1 → relu1 → conv2 → relu2 (no BN): conv2 gets IN+OUT in BP.
    #[test]
    fn plain_conv_relu_chain_gets_both() {
        let mut n = Network::new("t");
        let x = n.input(3, 8, 8);
        let c1 = n.conv("c1", x, 8, 3, 1, 1);
        let r1 = n.relu("r1", c1);
        let c2 = n.conv("c2", r1, 8, 3, 1, 1);
        let r2 = n.relu("r2", c2);
        n.softmax("sm", r2);
        let mut fwd = vec![0.0; n.len()];
        fwd[r1] = 0.5;
        fwd[r2] = 0.4;
        let opp = analyze_network(&n, &fwd);
        let o2 = opp.iter().find(|o| o.name == "c2").unwrap();
        // BP input: gradient through relu2 (mask 0.4)
        assert!((o2.bp_input.unwrap() - 0.4).abs() < 1e-9);
        // BP output: producer relu1 mask 0.5
        assert!((o2.bp_output.unwrap() - 0.5).abs() < 1e-9);
        assert_eq!(o2.bp_kind(), SparsityKind::Both);
        // FP input for c2 is relu1's sparsity
        assert!((o2.fp_input.unwrap() - 0.5).abs() < 1e-9);
        // c1: image input dense; no BP at all (first compute layer)
        let o1 = opp.iter().find(|o| o.name == "c1").unwrap();
        assert!(o1.fp_input.is_none());
        assert!(!o1.has_bp);
    }

    /// Fig 3c: conv → BN → relu. BN kills BP input sparsity; output
    /// sparsity survives when the conv's *producer* is a ReLU.
    #[test]
    fn batchnorm_kills_input_sparsity_not_output() {
        let mut n = Network::new("t");
        let x = n.input(3, 8, 8);
        let c1 = n.conv("c1", x, 8, 3, 1, 1);
        let b1 = n.bn("b1", c1);
        let r1 = n.relu("r1", b1);
        let c2 = n.conv("c2", r1, 8, 3, 1, 1);
        let b2 = n.bn("b2", c2);
        let r2 = n.relu("r2", b2);
        n.softmax("sm", r2);
        let mut fwd = vec![0.0; n.len()];
        fwd[r1] = 0.5;
        fwd[r2] = 0.4;
        let opp = analyze_network(&n, &fwd);
        let o2 = opp.iter().find(|o| o.name == "c2").unwrap();
        // gradient reaches c2 through BN backward ⇒ dense
        assert!(o2.bp_input.is_none());
        // but producer r1's mask is known ⇒ output sparsity applies
        assert!((o2.bp_output.unwrap() - 0.5).abs() < 1e-9);
        assert_eq!(o2.bp_kind(), SparsityKind::OutputOnly);
    }

    /// MaxPool–CONV boundary: output sparsity NOT applicable (§6).
    #[test]
    fn maxpool_boundary_loses_output_sparsity() {
        let mut n = Network::new("t");
        let x = n.input(3, 8, 8);
        let c1 = n.conv("c1", x, 8, 3, 1, 1);
        let r1 = n.relu("r1", c1);
        let p1 = n.maxpool("p1", r1, 2, 2, 0);
        let c2 = n.conv("c2", p1, 8, 3, 1, 1);
        let r2 = n.relu("r2", c2);
        n.softmax("sm", r2);
        let mut fwd = vec![0.0; n.len()];
        fwd[r1] = 0.5;
        fwd[p1] = 0.3; // pool output retains some sparsity
        fwd[r2] = 0.4;
        let opp = analyze_network(&n, &fwd);
        let o2 = opp.iter().find(|o| o.name == "c2").unwrap();
        assert!(o2.bp_output.is_none(), "maxpool producer must break OUT");
        // FP input sparsity still available from the pool output zeros
        assert!((o2.fp_input.unwrap() - 0.3).abs() < 1e-9);
        // BP input sparsity via relu2
        assert!((o2.bp_input.unwrap() - 0.4).abs() < 1e-9);
    }

    /// Concat of ReLUs (inception output) keeps the mask known.
    #[test]
    fn concat_of_relus_keeps_mask() {
        let mut n = Network::new("t");
        let x = n.input(3, 8, 8);
        let c1 = n.conv("c1", x, 8, 1, 1, 0);
        let r1 = n.relu("r1", c1);
        let c2 = n.conv("c2", x, 24, 1, 1, 0);
        let r2 = n.relu("r2", c2);
        let cat = n.concat("cat", &[r1, r2]);
        let c3 = n.conv("c3", cat, 8, 3, 1, 1);
        let r3 = n.relu("r3", c3);
        n.softmax("sm", r3);
        let mut fwd = vec![0.0; n.len()];
        fwd[r1] = 0.8;
        fwd[r2] = 0.4;
        fwd[cat] = 0.5; // 8·0.8 + 24·0.4 over 32
        fwd[r3] = 0.5;
        let opp = analyze_network(&n, &fwd);
        let o3 = opp.iter().find(|o| o.name == "c3").unwrap();
        // channel-weighted: (8·0.8 + 24·0.4)/32 = 0.5
        assert!((o3.bp_output.unwrap() - 0.5).abs() < 1e-9);
    }

    /// MaxPool backward scatter: gradient below the pool is mostly zero.
    #[test]
    fn maxpool_backward_gradient_is_sparse() {
        let mut n = Network::new("t");
        let x = n.input(3, 8, 8);
        let c1 = n.conv("c1", x, 8, 3, 1, 1);
        let r1 = n.relu("r1", c1);
        let p1 = n.maxpool("p1", r1, 2, 2, 0);
        let c2 = n.conv("c2", p1, 8, 3, 1, 1);
        let r2 = n.relu("r2", c2);
        n.softmax("sm", r2);
        let mut fwd = vec![0.0; n.len()];
        fwd[r1] = 0.5;
        fwd[r2] = 0.4;
        let gs = gradient_sparsity(&n, &fwd);
        // gradient at pool output comes from conv2 backward = dense (0);
        // the 4:1 scatter makes the gradient below the pool
        // 1 - 1·(16/64) = 0.75; through relu1 the correlated max with
        // its own mask (0.5) keeps 0.75 at c1's output.
        assert!((gs[p1] - 0.0).abs() < 1e-9);
        assert!((gs[r1] - 0.75).abs() < 1e-9);
        assert!((gs[c1] - 0.75).abs() < 1e-9);
    }

    /// Synthesized v2 traces: payloads on every ReLU, identity by
    /// construction, scalars consistent with the model's assignment.
    #[test]
    fn synthetic_capture_matches_model_and_holds_identity() {
        let net = crate::nn::zoo::agos_cnn();
        let model = SparsityModel::synthetic(5);
        for pattern in [BitmapPattern::Iid, BitmapPattern::Blobs] {
            let t = capture_synthetic_trace(&net, &model, 3, pattern, 2);
            assert_eq!(t.steps.len(), 3);
            assert!(t.has_bitmaps());
            assert!(t.identity_holds(), "grad ⊆ act must hold by construction");
            for step in &t.steps {
                assert_eq!(step.layers.len(), 4, "one entry per ReLU");
                for l in &step.layers {
                    let relu = net.by_name(&l.name).unwrap();
                    let act = l.act_bitmap.as_ref().unwrap();
                    assert_eq!(act.shape, relu.out);
                    assert!(
                        l.grad_sparsity >= l.act_sparsity - 1e-12,
                        "{}: gradient can only be more sparse",
                        l.name
                    );
                    assert!((0.05..0.95).contains(&l.act_sparsity), "{}", l.act_sparsity);
                }
            }
            // Deterministic from the model.
            let t2 = capture_synthetic_trace(&net, &model, 3, pattern, 2);
            assert_eq!(t.fingerprint(), t2.fingerprint());
        }
        // Different patterns produce different payloads at the same means.
        let iid = capture_synthetic_trace(&net, &model, 1, BitmapPattern::Iid, 2);
        let blobs = capture_synthetic_trace(&net, &model, 1, BitmapPattern::Blobs, 2);
        assert_ne!(iid.fingerprint(), blobs.fingerprint());
    }

    /// Multi-image capture: one StepTrace per (step, image), image 0
    /// bit-identical to the single-image capture, and residual Adds get
    /// act-only post-Add footprint entries.
    #[test]
    fn capture_images_widen_steps_and_record_post_add_footprints() {
        let net = crate::nn::zoo::agos_resnet();
        let model = SparsityModel::synthetic(13);
        let one = capture_synthetic_trace(&net, &model, 2, BitmapPattern::Iid, 2);
        let wide = capture_synthetic_trace_images(&net, &model, 2, 3, BitmapPattern::Iid, 2);
        assert_eq!(one.steps.len(), 2);
        assert_eq!(wide.steps.len(), 6, "steps x images StepTraces");
        // Image 0 of each step reproduces the single-image capture.
        assert_eq!(wide.steps[0], one.steps[0]);
        assert_eq!(wide.steps[3], one.steps[1]);
        assert_eq!(wide.steps[0].step, wide.steps[1].step, "images share the step number");
        assert_ne!(wide.steps[0].layers, wide.steps[1].layers, "but not the patterns");
        // Every Add layer carries an act-only footprint entry.
        let adds: Vec<_> = net
            .layers()
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Add))
            .collect();
        assert!(!adds.is_empty(), "agos_resnet has residual Adds");
        for a in &adds {
            let entry = one.steps[0]
                .layers
                .iter()
                .find(|lt| lt.name == a.name)
                .unwrap_or_else(|| panic!("no post-Add entry for {}", a.name));
            let map = entry.act_bitmap.as_ref().expect("post-Add footprint captured");
            assert_eq!(map.shape, a.out);
            assert!(entry.grad_bitmap.is_none(), "post-Add entries are act-only");
            assert!(entry.identity_ok);
            // A conv summand makes the generic post-Add footprint dense.
            assert_eq!(map.count_nz(), a.out.len(), "{} is generically dense", a.name);
        }
        assert!(one.identity_holds());
    }

    /// Residual Add passes gradient sparsity through to both branches.
    #[test]
    fn add_passes_gradient_through() {
        let mut n = Network::new("t");
        let x = n.input(8, 8, 8);
        let c1 = n.conv("c1", x, 8, 3, 1, 1);
        let a = n.add("a", c1, x);
        let r = n.relu("r", a);
        let c2 = n.conv("c2", r, 8, 3, 1, 1);
        let r2 = n.relu("r2", c2);
        n.softmax("sm", r2);
        let mut fwd = vec![0.0; n.len()];
        fwd[r] = 0.3; // diluted post-add sparsity
        fwd[r2] = 0.5;
        let gs = gradient_sparsity(&n, &fwd);
        // gradient at add output = through relu r: 0 + 0.3 (dense from c2)
        assert!((gs[a] - 0.3).abs() < 1e-9);
        // both add inputs see the same sparsity
        assert!((gs[c1] - 0.3).abs() < 1e-9);
    }
}
