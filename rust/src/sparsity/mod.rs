//! Sparsity substrate: bitmaps, NZ offset encoding, the per-layer
//! sparsity-opportunity analysis (the paper's §2.1/§3 logic as code), and
//! the calibrated synthetic trace model.

mod bitmap;
mod encode;
mod analyze;
mod model;

pub use analyze::{
    analyze_network, capture_synthetic_trace, capture_synthetic_trace_images, gradient_sparsity,
    LayerOpportunity, SparsityKind,
};
pub(crate) use analyze::synth_footprint;
pub use bitmap::{Bitmap, ChannelWords};
pub(crate) use bitmap::or_bits;
pub use encode::{
    decode_group, encode_bitmap, encode_tensor, rle_decode_words_bin, rle_encode_words_bin,
    EncodedTensor, OffsetGroup, RunIndex, GROUP,
};
pub use model::{SparsityModel, TraceSource};
