//! Calibrated synthetic sparsity assignment.
//!
//! The paper drives its simulator with TensorFlow traces of ImageNet
//! training; those are unavailable here (DESIGN.md §0), so this model
//! assigns each layer a forward-output sparsity fraction drawn from
//! ranges calibrated to the paper's reported observations:
//!
//! * Fig 3b: inception-3b feature/gradient sparsity ≈ 25–55 %.
//! * Fig 3d: per-network batch-16 averages in the 30–70 % band.
//! * Fig 13: ResNet ReLU-after-Add dilution to ≈ 30 % (vs ≈ 50 %).
//!
//! Real traces extracted by the coordinator (from the small CNN trained
//! through the AOT artifacts) enter through [`TraceSource::Measured`].
//!
//! The per-layer fractions this model assigns are consumed two ways,
//! depending on `SimOptions::backend` (`sim::backend`):
//!
//! * **analytic** — as expected values driving the closed-form PE model;
//! * **exact** — as densities that per-tile operand/output `Bitmap`s are
//!   *sampled* from (via the per-image RNG stream), then drained through
//!   the cycle-accurate `ExactPe`. Measured fractions thus become
//!   pattern-level bitmaps in exact co-simulation.

use std::collections::BTreeMap;

use crate::nn::{LayerId, LayerKind, Network};
use crate::util::fnv::Fnv1a;
use crate::util::rng::Pcg32;

/// Where the per-layer sparsity fractions come from.
#[derive(Clone, Debug)]
pub enum TraceSource {
    /// Calibrated synthetic assignment with the given seed.
    Synthetic { seed: u64 },
    /// Measured fractions by layer name (layers absent from the map fall
    /// back to the synthetic model).
    Measured { seed: u64, by_name: BTreeMap<String, f64> },
}

/// The sparsity model: produces one forward-sparsity fraction per layer.
#[derive(Clone, Debug)]
pub struct SparsityModel {
    pub source: TraceSource,
    /// Attenuation of sparsity through MaxPool (spatially-correlated
    /// zeros survive pooling partially; calibrated to Fig 3b's pool bars).
    pub maxpool_attenuation: f64,
    /// Residual attenuation through AvgPool.
    pub avgpool_attenuation: f64,
    /// Multiplier on every assigned ReLU sparsity fraction (clamped to
    /// ≤ 0.95 after scaling). Scenario schedules (`scenario::SparsitySchedule`)
    /// model early/mid/late-epoch regimes by scaling one calibrated model
    /// instead of re-deriving bands per phase; the band *draw* happens
    /// before scaling, so every phase perturbs the same underlying sample.
    /// 1.0 is the identity and keeps pre-scenario fingerprints unchanged.
    pub sparsity_scale: f64,
}

impl SparsityModel {
    pub fn synthetic(seed: u64) -> SparsityModel {
        SparsityModel {
            source: TraceSource::Synthetic { seed },
            maxpool_attenuation: 0.6,
            avgpool_attenuation: 0.1,
            sparsity_scale: 1.0,
        }
    }

    pub fn measured(seed: u64, by_name: BTreeMap<String, f64>) -> SparsityModel {
        SparsityModel {
            source: TraceSource::Measured { seed, by_name },
            maxpool_attenuation: 0.6,
            avgpool_attenuation: 0.1,
            sparsity_scale: 1.0,
        }
    }

    /// The same model with its ReLU fractions scaled by `scale` — how a
    /// schedule phase derives its per-phase model.
    pub fn with_scale(mut self, scale: f64) -> SparsityModel {
        self.sparsity_scale = scale;
        self
    }

    /// Stable 64-bit fingerprint over everything that changes the
    /// per-layer assignment — source variant, seed, measured fractions
    /// and the pool attenuations — one component of the sweep-cache key
    /// (`sim::sweep`).
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv1a::new();
        match &self.source {
            TraceSource::Synthetic { seed } => {
                h.put(1).put(*seed);
            }
            TraceSource::Measured { seed, by_name } => {
                h.put(2).put(*seed);
                for (name, s) in by_name {
                    h.put_str(name).put_f64(*s);
                }
            }
        }
        h.put_f64(self.maxpool_attenuation).put_f64(self.avgpool_attenuation);
        // Folded only when it actually changes the assignment (≠ 1.0), so
        // every fingerprint minted before the scale existed — including
        // sweep-cache disk spills — remains valid (same precedent as
        // `SimOptions`' conditional blob_radius fold).
        if self.sparsity_scale != 1.0 {
            h.put(3).put_f64(self.sparsity_scale);
        }
        h.finish()
    }

    /// ReLU sparsity band per network family (lo, hi), calibrated to the
    /// paper's figures.
    fn relu_band(net_name: &str, after_add: bool) -> (f64, f64) {
        if after_add {
            // Fig 13: element-wise addition dilutes to ≈30%.
            return (0.25, 0.35);
        }
        match net_name {
            "vgg16" => (0.40, 0.70),
            "googlenet" => (0.30, 0.55),
            "resnet18" => (0.48, 0.60),
            "densenet121" => (0.45, 0.65),
            "mobilenet_v1" => (0.50, 0.72),
            _ => (0.35, 0.65),
        }
    }

    /// Does this ReLU sit (through BN) on top of a residual Add?
    fn is_after_add(net: &Network, relu: LayerId) -> bool {
        let mut cur = net.layer(relu).inputs[0];
        loop {
            match net.layer(cur).kind {
                LayerKind::Add => return true,
                LayerKind::BatchNorm => cur = net.layer(cur).inputs[0],
                _ => return false,
            }
        }
    }

    /// Assign a forward-output sparsity fraction to every layer.
    pub fn assign(&self, net: &Network) -> Vec<f64> {
        let (seed, measured) = match &self.source {
            TraceSource::Synthetic { seed } => (*seed, None),
            TraceSource::Measured { seed, by_name } => (*seed, Some(by_name)),
        };
        let mut rng = Pcg32::new(seed ^ hash_name(&net.name));
        let mut fwd = vec![0.0f64; net.len()];
        for l in net.layers() {
            fwd[l.id] = match l.kind {
                LayerKind::ReLU => {
                    let raw = if let Some(m) = measured.and_then(|m| m.get(&l.name)) {
                        *m
                    } else {
                        let (lo, hi) = Self::relu_band(&net.name, Self::is_after_add(net, l.id));
                        rng.range_f64(lo, hi)
                    };
                    // Scale *after* drawing: the RNG stream is identical at
                    // every scale, so phases of one schedule differ only by
                    // the multiplier, never by divergent draw sequences.
                    (raw * self.sparsity_scale).clamp(0.0, 0.95)
                }
                LayerKind::MaxPool { .. } => {
                    fwd[l.inputs[0]] * self.maxpool_attenuation
                }
                LayerKind::AvgPool { .. } | LayerKind::GlobalAvgPool => {
                    fwd[l.inputs[0]] * self.avgpool_attenuation
                }
                LayerKind::Concat => {
                    let mut num = 0.0;
                    let mut den = 0.0;
                    for &i in &l.inputs {
                        let c = net.layer(i).out.c as f64;
                        num += fwd[i] * c;
                        den += c;
                    }
                    num / den
                }
                // Dense outputs: conv/fc/bn/add produce (near-)dense maps.
                _ => 0.0,
            };
        }
        fwd
    }

    /// Per-image assignment for a batch: each image gets an independent
    /// perturbation of the layer means (drives Fig 3d min/avg/max).
    pub fn assign_batch(&self, net: &Network, batch: usize) -> Vec<Vec<f64>> {
        let base = self.assign(net);
        let seed = match &self.source {
            TraceSource::Synthetic { seed } | TraceSource::Measured { seed, .. } => *seed,
        };
        let mut rng = Pcg32::new(seed.wrapping_mul(0x9E37_79B9) ^ hash_name(&net.name));
        (0..batch)
            .map(|_| {
                let mut img = base.clone();
                for (id, s) in img.iter_mut().enumerate() {
                    if *s > 0.0 && net.layer(id).kind.is_relu() {
                        // ±8% relative jitter per image, clamped
                        let jitter = 1.0 + 0.08 * rng.gauss();
                        *s = (*s * jitter).clamp(0.02, 0.95);
                    }
                }
                // re-propagate pools/concats from the jittered relus
                repropagate(net, &mut img, self);
                img
            })
            .collect()
    }
}

fn repropagate(net: &Network, fwd: &mut [f64], model: &SparsityModel) {
    for l in net.layers() {
        match l.kind {
            LayerKind::MaxPool { .. } => fwd[l.id] = fwd[l.inputs[0]] * model.maxpool_attenuation,
            LayerKind::AvgPool { .. } | LayerKind::GlobalAvgPool => {
                fwd[l.id] = fwd[l.inputs[0]] * model.avgpool_attenuation
            }
            LayerKind::Concat => {
                let mut num = 0.0;
                let mut den = 0.0;
                for &i in &l.inputs {
                    let c = net.layer(i).out.c as f64;
                    num += fwd[i] * c;
                    den += c;
                }
                fwd[l.id] = num / den;
            }
            _ => {}
        }
    }
}

fn hash_name(name: &str) -> u64 {
    // Classic byte-wise FNV-1a (same values as before the shared helper).
    let mut h = Fnv1a::new();
    h.put_bytes(name.as_bytes());
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    #[test]
    fn assignment_is_deterministic() {
        let net = zoo::vgg16();
        let m = SparsityModel::synthetic(7);
        assert_eq!(m.assign(&net), m.assign(&net));
        let m2 = SparsityModel::synthetic(8);
        assert_ne!(m.assign(&net), m2.assign(&net));
    }

    #[test]
    fn relus_in_band_others_dense() {
        let net = zoo::vgg16();
        let fwd = SparsityModel::synthetic(1).assign(&net);
        for l in net.layers() {
            match l.kind {
                LayerKind::ReLU => {
                    assert!((0.40..=0.70).contains(&fwd[l.id]), "{}: {}", l.name, fwd[l.id])
                }
                LayerKind::Conv { .. } | LayerKind::Fc { .. } | LayerKind::BatchNorm => {
                    assert_eq!(fwd[l.id], 0.0, "{}", l.name)
                }
                _ => {}
            }
        }
    }

    #[test]
    fn resnet_relu_after_add_is_diluted() {
        let net = zoo::resnet18();
        let fwd = SparsityModel::synthetic(3).assign(&net);
        let after_add = net.by_name("layer1_0_relu2").unwrap().id;
        let inner = net.by_name("layer1_0_relu1").unwrap().id;
        assert!(fwd[after_add] < 0.36, "post-add {}", fwd[after_add]);
        assert!(fwd[inner] > 0.44, "inner {}", fwd[inner]);
    }

    #[test]
    fn maxpool_attenuates() {
        let net = zoo::vgg16();
        let fwd = SparsityModel::synthetic(3).assign(&net);
        let r = net.by_name("relu1_2").unwrap().id;
        let p = net.by_name("pool1").unwrap().id;
        assert!((fwd[p] - fwd[r] * 0.6).abs() < 1e-12);
    }

    #[test]
    fn measured_overrides_synthetic() {
        let net = zoo::vgg16();
        let mut by_name = BTreeMap::new();
        by_name.insert("relu1_1".to_string(), 0.123);
        let m = SparsityModel::measured(1, by_name);
        let fwd = m.assign(&net);
        let r = net.by_name("relu1_1").unwrap().id;
        assert!((fwd[r] - 0.123).abs() < 1e-12);
    }

    #[test]
    fn batch_has_variation_around_base() {
        let net = zoo::googlenet();
        let m = SparsityModel::synthetic(5);
        let batch = m.assign_batch(&net, 16);
        assert_eq!(batch.len(), 16);
        let r = net.by_name("inception_3b_relu_3x3").unwrap().id;
        let vals: Vec<f64> = batch.iter().map(|img| img[r]).collect();
        let min = vals.iter().cloned().fold(f64::MAX, f64::min);
        let max = vals.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max > min, "no variation across batch");
        assert!(max - min < 0.5, "variation implausibly large");
    }

    #[test]
    fn sparsity_scale_multiplies_relu_draws_without_moving_the_stream() {
        let net = zoo::vgg16();
        let base = SparsityModel::synthetic(7);
        let early = base.clone().with_scale(0.5);
        let a = base.assign(&net);
        let b = early.assign(&net);
        for l in net.layers() {
            if l.kind.is_relu() {
                // Same draw, halved — the stream did not diverge.
                assert!((b[l.id] - a[l.id] * 0.5).abs() < 1e-12, "{}", l.name);
            }
        }
        // Scaling saturates at 0.95 rather than exceeding a plausible map.
        let dense = base.clone().with_scale(10.0).assign(&net);
        for l in net.layers() {
            if l.kind.is_relu() {
                assert!((dense[l.id] - 0.95).abs() < 1e-12, "{}", l.name);
            }
        }
    }

    #[test]
    fn sparsity_scale_folds_into_fingerprint_only_when_active() {
        let base = SparsityModel::synthetic(7);
        // Identity scale leaves the pre-scenario fingerprint untouched —
        // disk spills minted before the field existed still match.
        assert_eq!(base.fingerprint(), base.clone().with_scale(1.0).fingerprint());
        let early = base.clone().with_scale(0.5);
        let late = base.clone().with_scale(1.4);
        assert_ne!(base.fingerprint(), early.fingerprint());
        assert_ne!(early.fingerprint(), late.fingerprint());
    }

    #[test]
    fn googlenet_band_matches_fig3b() {
        // Fig 3b: inception-3b sparsity ≈25–55%.
        let net = zoo::googlenet();
        let fwd = SparsityModel::synthetic(0).assign(&net);
        for l in net.layers() {
            if l.kind.is_relu() && l.name.starts_with("inception_3b") {
                assert!((0.25..=0.60).contains(&fwd[l.id]), "{}: {}", l.name, fwd[l.id]);
            }
        }
    }
}
