//! `agos` — CLI entrypoint for the AGOS reproduction.
//!
//! Subcommands (see `agos --help`): train, simulate, figure, table,
//! sparsity, artifacts. Everything routes through `agos::cli`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match agos::cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
