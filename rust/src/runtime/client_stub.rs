//! Stub PJRT client for builds without the `pjrt` feature.
//!
//! The offline toolchain has no `xla` crate, so the real client
//! (`client.rs`) cannot compile there. This stub keeps the whole
//! coordinator/CLI surface compiling — the simulator, sweep and report
//! layers are fully functional without PJRT — and fails loudly the
//! moment artifact execution is actually requested.

use std::path::Path;

use anyhow::{bail, Result};

use super::{ArtifactManifest, HostTensor};

const UNAVAILABLE: &str = "PJRT runtime unavailable: this binary was built without the `pjrt` \
     feature (the offline toolchain has no `xla` crate); rebuild with \
     `--features pjrt` on a host that provides it to execute AOT artifacts";

/// Stub of the compiled-artifact handle; never constructible.
pub struct Executable {
    _private: (),
}

impl Executable {
    pub fn run(&self, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        bail!("{UNAVAILABLE}")
    }
}

/// Stub runtime: `load` always fails with an actionable message.
pub struct Runtime {
    pub manifest: ArtifactManifest,
}

impl Runtime {
    pub fn load(_artifacts_dir: &Path) -> Result<Runtime> {
        bail!("{UNAVAILABLE}")
    }

    pub fn platform(&self) -> String {
        "pjrt-stub".to_string()
    }

    pub fn executable(&mut self, _name: &str) -> Result<&Executable> {
        bail!("{UNAVAILABLE}")
    }

    pub fn run(&mut self, _name: &str, _inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        bail!("{UNAVAILABLE}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_with_actionable_message() {
        let err = Runtime::load(Path::new("artifacts")).unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}
