//! PJRT runtime — the only bridge between the rust coordinator and the
//! AOT-compiled JAX/Pallas artifacts.
//!
//! Python runs once at `make artifacts`; afterwards this module gives the
//! coordinator a self-contained path: HLO text → `HloModuleProto` →
//! `XlaComputation` → PJRT-compiled executable → `execute` with host
//! tensors. See `/opt/xla-example/load_hlo/` for the pattern's origin and
//! DESIGN.md §1 for why the interchange format is HLO *text*.

mod tensor_host;
mod artifacts;
// The real client needs the external `xla` crate; the offline build
// (no `pjrt` feature) swaps in an API-identical stub that fails at
// `Runtime::load` with an actionable message.
#[cfg(feature = "pjrt")]
mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
mod client;

pub use artifacts::{bitmap_from_nhwc, ArtifactManifest, EntrySpec, TensorSpec};
pub use client::{Executable, Runtime};
pub use tensor_host::HostTensor;
