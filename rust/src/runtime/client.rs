//! PJRT client wrapper: compile HLO-text artifacts once, execute many times.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::{ArtifactManifest, HostTensor};

/// A compiled artifact entry.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    /// Number of outputs the jax function returns (the HLO returns one
    /// tuple of this arity — aot.py lowers with `return_tuple=True`).
    out_arity: usize,
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let literals = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing '{}': {e:?}", self.name))?;
        let mut out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of '{}': {e:?}", self.name))?;
        let parts = out
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing result tuple of '{}': {e:?}", self.name))?;
        anyhow::ensure!(
            parts.len() == self.out_arity,
            "'{}' returned {} outputs, manifest says {}",
            self.name,
            parts.len(),
            self.out_arity
        );
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

/// The PJRT CPU runtime with a cache of compiled entries.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: ArtifactManifest,
    compiled: BTreeMap<String, Executable>,
}

impl Runtime {
    /// Create a CPU PJRT client and parse the artifact manifest.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        let manifest = ArtifactManifest::load(artifacts_dir)
            .with_context(|| format!("loading manifest from {}", artifacts_dir.display()))?;
        Ok(Runtime { client, manifest, compiled: BTreeMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) entry.
    pub fn executable(&mut self, name: &str) -> Result<&Executable> {
        if !self.compiled.contains_key(name) {
            let spec = self.manifest.entry(name)?.clone();
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&spec.file)
                .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e:?}", spec.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling '{name}': {e:?}"))?;
            crate::info!(
                "compiled artifact '{name}' in {:.2}s ({} inputs, {} outputs)",
                t0.elapsed().as_secs_f64(),
                spec.inputs.len(),
                spec.outputs.len()
            );
            self.compiled.insert(
                name.to_string(),
                Executable { name: name.to_string(), exe, out_arity: spec.outputs.len() },
            );
        }
        Ok(&self.compiled[name])
    }

    /// Convenience: compile + run in one call, with input validation
    /// against the manifest.
    pub fn run(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.entry(name)?;
        anyhow::ensure!(
            inputs.len() == spec.inputs.len(),
            "'{name}' expects {} inputs, got {}",
            spec.inputs.len(),
            inputs.len()
        );
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            anyhow::ensure!(
                t.shape() == s.shape.as_slice(),
                "'{name}' input {i}: shape {:?} != manifest {:?}",
                t.shape(),
                s.shape
            );
        }
        self.executable(name)?.run(inputs)
    }
}
