//! Host-side tensors: plain `Vec` payloads with shape, convertible to and
//! from `xla::Literal` without going through python.

use anyhow::{ensure, Context, Result};

/// A host tensor: f32 or i32 payload plus shape. The only two dtypes the
/// artifacts use (activations/params are f32, labels are i32).
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Result<HostTensor> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {:?} does not match {} elements",
            shape,
            data.len()
        );
        Ok(HostTensor::F32 { shape, data })
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Result<HostTensor> {
        ensure!(
            shape.iter().product::<usize>() == data.len(),
            "shape {:?} does not match {} elements",
            shape,
            data.len()
        );
        Ok(HostTensor::I32 { shape, data })
    }

    pub fn zeros_f32(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => anyhow::bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => anyhow::bail!("tensor is not i32"),
        }
    }

    /// Fraction of exactly-zero elements (the sparsity the paper studies).
    pub fn zero_fraction(&self) -> f64 {
        match self {
            HostTensor::F32 { data, .. } => {
                if data.is_empty() {
                    return 0.0;
                }
                data.iter().filter(|x| **x == 0.0).count() as f64 / data.len() as f64
            }
            HostTensor::I32 { data, .. } => {
                if data.is_empty() {
                    return 0.0;
                }
                data.iter().filter(|x| **x == 0).count() as f64 / data.len() as f64
            }
        }
    }

    /// Load a raw little-endian f32 blob (the `artifacts/params/*.bin`
    /// format written by aot.py).
    pub fn from_f32_file(path: &std::path::Path, shape: Vec<usize>) -> Result<HostTensor> {
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let n: usize = shape.iter().product();
        ensure!(bytes.len() == 4 * n, "{}: {} bytes, expected {}", path.display(), bytes.len(), 4 * n);
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(HostTensor::F32 { shape, data })
    }

    /// Write as raw little-endian f32 (round-trip of the above).
    pub fn write_f32_file(&self, path: &std::path::Path) -> Result<()> {
        let data = self.as_f32()?;
        let mut bytes = Vec::with_capacity(data.len() * 4);
        for x in data {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    // ---- Literal conversion (pjrt feature only) ---------------------------

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            HostTensor::F32 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    shape,
                    bytes,
                )
                .map_err(|e| anyhow::anyhow!("literal from f32 tensor: {e:?}"))
            }
            HostTensor::I32 { shape, data } => {
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    shape,
                    bytes,
                )
                .map_err(|e| anyhow::anyhow!("literal from i32 tensor: {e:?}"))
            }
        }
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit
            .array_shape()
            .map_err(|e| anyhow::anyhow!("literal shape: {e:?}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|d| *d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("literal to f32 vec: {e:?}"))?;
                HostTensor::f32(dims, data)
            }
            xla::ElementType::S32 => {
                let data = lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow::anyhow!("literal to i32 vec: {e:?}"))?;
                HostTensor::i32(dims, data)
            }
            other => anyhow::bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_mismatch_rejected() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(HostTensor::i32(vec![2], vec![1]).is_err());
    }

    #[test]
    fn zero_fraction_counts() {
        let t = HostTensor::f32(vec![4], vec![0.0, 1.0, 0.0, 2.0]).unwrap();
        assert!((t.zero_fraction() - 0.5).abs() < 1e-12);
        let e = HostTensor::f32(vec![0], vec![]).unwrap();
        assert_eq!(e.zero_fraction(), 0.0);
    }

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("agos_ht_test");
        let path = dir.join("t.bin");
        let t = HostTensor::f32(vec![2, 2], vec![1.0, -2.5, 0.0, 4.25]).unwrap();
        t.write_f32_file(&path).unwrap();
        let t2 = HostTensor::from_f32_file(&path, vec![2, 2]).unwrap();
        assert_eq!(t, t2);
        // wrong shape errors
        assert!(HostTensor::from_f32_file(&path, vec![3]).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn literal_roundtrip_f32_and_i32() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let lit = t.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), t);

        let ti = HostTensor::i32(vec![4], vec![1, -2, 3, 0]).unwrap();
        let lit = ti.to_literal().unwrap();
        assert_eq!(HostTensor::from_literal(&lit).unwrap(), ti);
    }
}
