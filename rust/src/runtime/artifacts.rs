//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;
use super::HostTensor;

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .as_arr()
            .context("spec.shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.get("dtype").as_str().context("spec.dtype")?.to_string();
        Ok(TensorSpec { shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point (an HLO module).
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, EntrySpec>,
    /// Initial parameters: name -> (file, shape), in no particular order;
    /// `param_order` gives the calling convention.
    pub params: BTreeMap<String, (PathBuf, Vec<usize>)>,
    pub param_order: Vec<String>,
    pub batch: usize,
    pub img: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    pub lr: f64,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        let hp = j.get("hyperparams");
        let mut entries = BTreeMap::new();
        for (name, e) in j.get("entries").as_obj().context("manifest.entries")? {
            let inputs = e
                .get("inputs")
                .as_arr()
                .context("entry.inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .as_arr()
                .context("entry.outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: dir.join(e.get("file").as_str().context("entry.file")?),
                    inputs,
                    outputs,
                },
            );
        }
        let mut params = BTreeMap::new();
        for (name, p) in j.get("params").as_obj().context("manifest.params")? {
            let shape = p
                .get("shape")
                .as_arr()
                .context("param.shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            params.insert(
                name.clone(),
                (dir.join(p.get("file").as_str().context("param.file")?), shape),
            );
        }
        let param_order = hp
            .get("param_order")
            .as_arr()
            .context("hyperparams.param_order")?
            .iter()
            .map(|s| Ok(s.as_str().context("param name")?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            entries,
            params,
            param_order,
            batch: hp.get("batch").as_usize().context("hyperparams.batch")?,
            img: hp.get("img").as_usize().context("hyperparams.img")?,
            in_ch: hp.get("in_ch").as_usize().context("hyperparams.in_ch")?,
            num_classes: hp.get("num_classes").as_usize().context("hyperparams.num_classes")?,
            lr: hp.get("lr").as_f64().context("hyperparams.lr")?,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("artifact entry '{name}' not in manifest"))
    }

    /// Load the initial parameters in calling-convention order.
    pub fn load_initial_params(&self) -> Result<Vec<HostTensor>> {
        self.param_order
            .iter()
            .map(|name| {
                let (file, shape) = self
                    .params
                    .get(name)
                    .with_context(|| format!("param '{name}' missing from manifest"))?;
                HostTensor::from_f32_file(file, shape.clone())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir.join("params")).unwrap();
        let manifest = r#"{
            "format": "hlo-text",
            "hyperparams": {
                "img": 8, "in_ch": 3, "num_classes": 10, "batch": 2,
                "lr": 0.05, "seed": 0,
                "param_order": ["w1"],
                "conv_specs": []
            },
            "entries": {
                "demo": {
                    "file": "demo.hlo.txt",
                    "inputs": [{"shape": [2, 3], "dtype": "float32"}],
                    "outputs": [{"shape": [2, 3], "dtype": "float32"}],
                    "hlo_bytes": 5
                }
            },
            "params": {
                "w1": {"file": "params/w1.bin", "shape": [2, 2]}
            }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let t = HostTensor::f32(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        t.write_f32_file(&dir.join("params/w1.bin")).unwrap();
        std::fs::write(dir.join("demo.hlo.txt"), "hello").unwrap();
    }

    #[test]
    fn parses_manifest_and_params() {
        let dir = std::env::temp_dir().join("agos_manifest_test");
        write_fake_manifest(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.batch, 2);
        assert_eq!(m.img, 8);
        let e = m.entry("demo").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.inputs[0].elements(), 6);
        assert!(m.entry("nope").is_err());
        let ps = m.load_initial_params().unwrap();
        assert_eq!(ps.len(), 1);
        assert_eq!(ps[0].shape(), &[2, 2]);
        std::fs::remove_dir_all(dir).ok();
    }
}
