//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime. Parsed from `artifacts/manifest.json`. Also home of the
//! NHWC→bitmap extraction the trace capture path uses on the artifacts'
//! activation/gradient tensors.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::nn::Shape;
use crate::sparsity::Bitmap;
use crate::util::json::Json;
use super::HostTensor;

/// Extract image `image`'s packed zero footprint from an NHWC f32 tensor
/// (the layout every AOT artifact produces) as the channel-first
/// `[C, H, W]` `Bitmap` the simulator and v2 trace format use —
/// `Bitmap::from_values` over the transposed slice. Returns `None` when
/// the tensor is not 4-D f32 or the image index is out of range (scalar
/// outputs like the loss simply carry no footprint).
pub fn bitmap_from_nhwc(t: &HostTensor, image: usize) -> Option<Bitmap> {
    let data = t.as_f32().ok()?;
    let &[n, h, w, c] = t.shape() else {
        return None;
    };
    if image >= n || c * h * w == 0 {
        return None;
    }
    let img = &data[image * h * w * c..(image + 1) * h * w * c];
    let mut chw = vec![0.0f32; c * h * w];
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                chw[(ch * h + y) * w + x] = img[(y * w + x) * c + ch];
            }
        }
    }
    Some(Bitmap::from_values(Shape::new(c, h, w), &chw))
}

/// Shape + dtype of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        let shape = j
            .get("shape")
            .as_arr()
            .context("spec.shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j.get("dtype").as_str().context("spec.dtype")?.to_string();
        Ok(TensorSpec { shape, dtype })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point (an HLO module).
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, EntrySpec>,
    /// Initial parameters: name -> (file, shape), in no particular order;
    /// `param_order` gives the calling convention.
    pub params: BTreeMap<String, (PathBuf, Vec<usize>)>,
    pub param_order: Vec<String>,
    pub batch: usize,
    pub img: usize,
    pub in_ch: usize,
    pub num_classes: usize,
    pub lr: f64,
}

impl ArtifactManifest {
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        let hp = j.get("hyperparams");
        let mut entries = BTreeMap::new();
        for (name, e) in j.get("entries").as_obj().context("manifest.entries")? {
            let inputs = e
                .get("inputs")
                .as_arr()
                .context("entry.inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = e
                .get("outputs")
                .as_arr()
                .context("entry.outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                EntrySpec {
                    name: name.clone(),
                    file: dir.join(e.get("file").as_str().context("entry.file")?),
                    inputs,
                    outputs,
                },
            );
        }
        let mut params = BTreeMap::new();
        for (name, p) in j.get("params").as_obj().context("manifest.params")? {
            let shape = p
                .get("shape")
                .as_arr()
                .context("param.shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<Vec<_>>>()?;
            params.insert(
                name.clone(),
                (dir.join(p.get("file").as_str().context("param.file")?), shape),
            );
        }
        let param_order = hp
            .get("param_order")
            .as_arr()
            .context("hyperparams.param_order")?
            .iter()
            .map(|s| Ok(s.as_str().context("param name")?.to_string()))
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            entries,
            params,
            param_order,
            batch: hp.get("batch").as_usize().context("hyperparams.batch")?,
            img: hp.get("img").as_usize().context("hyperparams.img")?,
            in_ch: hp.get("in_ch").as_usize().context("hyperparams.in_ch")?,
            num_classes: hp.get("num_classes").as_usize().context("hyperparams.num_classes")?,
            lr: hp.get("lr").as_f64().context("hyperparams.lr")?,
        })
    }

    pub fn entry(&self, name: &str) -> Result<&EntrySpec> {
        self.entries
            .get(name)
            .with_context(|| format!("artifact entry '{name}' not in manifest"))
    }

    /// Load the initial parameters in calling-convention order.
    pub fn load_initial_params(&self) -> Result<Vec<HostTensor>> {
        self.param_order
            .iter()
            .map(|name| {
                let (file, shape) = self
                    .params
                    .get(name)
                    .with_context(|| format!("param '{name}' missing from manifest"))?;
                HostTensor::from_f32_file(file, shape.clone())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path) {
        std::fs::create_dir_all(dir.join("params")).unwrap();
        let manifest = r#"{
            "format": "hlo-text",
            "hyperparams": {
                "img": 8, "in_ch": 3, "num_classes": 10, "batch": 2,
                "lr": 0.05, "seed": 0,
                "param_order": ["w1"],
                "conv_specs": []
            },
            "entries": {
                "demo": {
                    "file": "demo.hlo.txt",
                    "inputs": [{"shape": [2, 3], "dtype": "float32"}],
                    "outputs": [{"shape": [2, 3], "dtype": "float32"}],
                    "hlo_bytes": 5
                }
            },
            "params": {
                "w1": {"file": "params/w1.bin", "shape": [2, 2]}
            }
        }"#;
        std::fs::write(dir.join("manifest.json"), manifest).unwrap();
        let t = HostTensor::f32(vec![2, 2], vec![1., 2., 3., 4.]).unwrap();
        t.write_f32_file(&dir.join("params/w1.bin")).unwrap();
        std::fs::write(dir.join("demo.hlo.txt"), "hello").unwrap();
    }

    #[test]
    fn nhwc_bitmap_extraction_transposes_correctly() {
        // [N=2, H=2, W=2, C=3]: image 1, channel 2 has a lone non-zero
        // at (y=1, x=0).
        let mut data = vec![0.0f32; 2 * 2 * 2 * 3];
        let at = |n: usize, y: usize, x: usize, c: usize| ((n * 2 + y) * 2 + x) * 3 + c;
        data[at(1, 1, 0, 2)] = 5.0;
        data[at(1, 0, 1, 0)] = -1.0;
        data[at(0, 0, 0, 0)] = 9.0; // other image: must not leak
        let t = HostTensor::f32(vec![2, 2, 2, 3], data).unwrap();
        let b = bitmap_from_nhwc(&t, 1).unwrap();
        assert_eq!(b.shape, Shape::new(3, 2, 2));
        assert_eq!(b.count_nz(), 2);
        assert!(b.get(2, 1, 0));
        assert!(b.get(0, 0, 1));
        // Zero fraction agrees with the scalar path on the same image.
        assert!((b.sparsity() - 10.0 / 12.0).abs() < 1e-12);
        // Non-4D and out-of-range inputs carry no footprint.
        assert!(bitmap_from_nhwc(&HostTensor::zeros_f32(vec![4]), 0).is_none());
        assert!(bitmap_from_nhwc(&t, 2).is_none());
    }

    #[test]
    fn parses_manifest_and_params() {
        let dir = std::env::temp_dir().join("agos_manifest_test");
        write_fake_manifest(&dir);
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.batch, 2);
        assert_eq!(m.img, 8);
        let e = m.entry("demo").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.inputs[0].elements(), 6);
        assert!(m.entry("nope").is_err());
        let ps = m.load_initial_params().unwrap();
        assert_eq!(ps.len(), 1);
        assert_eq!(ps[0].shape(), &[2, 2]);
        std::fs::remove_dir_all(dir).ok();
    }
}
