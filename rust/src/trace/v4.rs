//! TraceFile **v4**: the binary streaming trace container.
//!
//! On-disk layout (every integer little-endian):
//!
//! ```text
//! header    := magic "AGOSTRC\0" (8 bytes) · version u8 (= 4)
//!            · name_len u16 · network name (UTF-8)
//! step*     := body_len u32 · body            (repeated until EOF)
//! body      := step u64 · loss f64 · layer_count u16 · layer*
//! layer     := name_len u16 · name (UTF-8)
//!            · act_sparsity f64 · grad_sparsity f64 · flags u8
//!            · [act payload] · [grad payload]      (as flagged)
//! flags     := bit0 identity_ok · bit1 footprint
//!            · bit2 act payload present · bit3 grad payload present
//! payload   := c u32 · h u32 · w u32 · enc u8 · data_len u32 · data
//! enc       := 0 raw LE u64 words · 1 binary RLE
//!            · 2 binary RLE of XOR vs most recent same-slot map
//!            · 3 binary RLE of XOR vs the same image position in the
//!              previous step *group*
//! ```
//!
//! The container is framed per *step record*: a writer appends one
//! record at a time ([`TraceWriter`]) keeping only the delta bases
//! resident, and a truncated file cleanly recovers every step whose
//! record is complete (the lenient load path). The payload data is the
//! same delta/RLE scheme as v3, but in the packed byte grammar of
//! `sparsity::encode::rle_encode_words_bin` — and where runs don't pay
//! (mid-density maps), raw LE words that the reader adopts as a
//! `Bitmap`'s storage without any re-encoding ([`Bitmap::from_words`]).
//! No hex, no string scanning anywhere.
//!
//! **Step groups.** Multi-image captures are step-major: the records of
//! one training step follow each other, all carrying the same `step`
//! value, one record per image. A maximal run of consecutive records
//! sharing a `step` value is a *group*. The tag-2 base (most recent
//! same-slot map — in a group, the previous *image*) tracks cross-image
//! correlation; the tag-3 base (same image position, previous group)
//! tracks each image's own step-to-step evolution, which for real
//! activations is usually the far stronger signal. The encoder tries
//! both and keeps the strictly smallest — ties keep the lower tag, so a
//! single-image trace (where both bases are the same map) encodes
//! byte-identically to an encoder that never heard of groups.

use std::collections::HashMap;
use std::io::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::nn::Shape;
use crate::sparsity::Bitmap;

use super::{LayerTrace, SlotKey, StepTrace, TraceFile, TraceFormat};

/// First 8 bytes of every v4 container — what `TraceFile::load` sniffs
/// to pick the binary decoder over the JSON parser.
pub(crate) const MAGIC: [u8; 8] = *b"AGOSTRC\0";

/// The container-format byte written after the magic. Distinct from the
/// JSON `version` key lineage only in storage; semantically this *is*
/// trace revision 4.
const CONTAINER_VERSION: u8 = 4;

const FLAG_IDENTITY: u8 = 1 << 0;
const FLAG_FOOTPRINT: u8 = 1 << 1;
const FLAG_ACT: u8 = 1 << 2;
const FLAG_GRAD: u8 = 1 << 3;

const ENC_RAW: u8 = 0;
const ENC_RLE: u8 = 1;
const ENC_DELTA: u8 = 2;
const ENC_DELTA_IMG: u8 = 3;

// ---------------------------------------------------------------------------
// Delta-base bookkeeping
// ---------------------------------------------------------------------------

/// The delta bases both codec directions maintain, record by record.
/// Encoder and decoder share this type so their base tables can never
/// drift: whatever map the encoder XORed against is, by construction,
/// the map the decoder XORs back.
///
/// Memory stays bounded regardless of trace length: `prev` holds one
/// map per slot, and the two group tables together hold at most two
/// step groups' worth of maps.
pub(crate) struct ChainState {
    /// Most recent map per slot, across all records — the tag-2 base.
    prev: HashMap<SlotKey, Bitmap>,
    /// The previous step group's maps by (slot, image index) — the
    /// tag-3 base.
    prev_group: HashMap<(SlotKey, usize), Bitmap>,
    /// The group being accumulated (becomes `prev_group` on rotation).
    cur_group: HashMap<(SlotKey, usize), Bitmap>,
    /// `step` value of the group in `cur_group`.
    cur_step: Option<usize>,
    /// Image index of the record currently being coded.
    img: usize,
}

impl ChainState {
    pub(crate) fn new() -> ChainState {
        ChainState {
            prev: HashMap::new(),
            prev_group: HashMap::new(),
            cur_group: HashMap::new(),
            cur_step: None,
            img: 0,
        }
    }

    /// Enter the next record: a repeated `step` value advances the image
    /// index within the current group; a new value rotates the group
    /// tables and starts a fresh group at image 0.
    fn enter_record(&mut self, step: usize) {
        if self.cur_step == Some(step) {
            self.img += 1;
        } else {
            self.prev_group = std::mem::take(&mut self.cur_group);
            self.cur_step = Some(step);
            self.img = 0;
        }
    }

    /// The (tag-2, tag-3) bases for a slot of the current record.
    fn bases(&self, key: &SlotKey) -> (Option<&Bitmap>, Option<&Bitmap>) {
        (self.prev.get(key), self.prev_group.get(&(key.clone(), self.img)))
    }

    /// Register a just-coded map as a future base.
    fn record(&mut self, key: SlotKey, b: Bitmap) {
        self.cur_group.insert((key.clone(), self.img), b.clone());
        self.prev.insert(key, b);
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_u16(out: &mut Vec<u8>, v: usize, what: &str) -> Result<()> {
    let v = u16::try_from(v).map_err(|_| anyhow::anyhow!("{what} {v} exceeds u16"))?;
    out.extend_from_slice(&v.to_le_bytes());
    Ok(())
}

fn put_u32(out: &mut Vec<u8>, v: usize, what: &str) -> Result<()> {
    let v = u32::try_from(v).map_err(|_| anyhow::anyhow!("{what} {v} exceeds u32"))?;
    out.extend_from_slice(&v.to_le_bytes());
    Ok(())
}

/// The v4 file header.
pub(crate) fn encode_header(network: &str) -> Result<Vec<u8>> {
    let mut out = Vec::with_capacity(11 + network.len());
    out.extend_from_slice(&MAGIC);
    out.push(CONTAINER_VERSION);
    put_u16(&mut out, network.len(), "network name length")?;
    out.extend_from_slice(network.as_bytes());
    Ok(out)
}

/// One bitmap payload section. Picks the cheapest of binary RLE, the
/// binary RLE of the XOR against either delta base (`prev` = most
/// recent same-slot map, tag 2; `prev_img` = same image position in the
/// previous step group, tag 3), and raw LE words. Every upgrade needs a
/// *strictly* smaller candidate, so ties keep the lower tag and stay
/// delta-chain-free: the same smallest-wins policy as the v3 JSON
/// encoder, with raw words playing hex's role as the mid-density floor.
fn encode_payload(
    b: &Bitmap,
    prev: Option<&Bitmap>,
    prev_img: Option<&Bitmap>,
    out: &mut Vec<u8>,
) -> Result<()> {
    put_u32(out, b.shape.c, "payload shape.c")?;
    put_u32(out, b.shape.h, "payload shape.h")?;
    put_u32(out, b.shape.w, "payload shape.w")?;
    let mut rle = Vec::new();
    b.encode_rle_bin(&mut rle);
    let (mut enc, mut data) = (ENC_RLE, rle);
    for (tag, base) in [(ENC_DELTA, prev), (ENC_DELTA_IMG, prev_img)] {
        if let Some(p) = base {
            if p.shape == b.shape {
                let mut delta = Vec::new();
                b.xor(p).encode_rle_bin(&mut delta);
                if delta.len() < data.len() {
                    (enc, data) = (tag, delta);
                }
            }
        }
    }
    if b.words().len() * 8 < data.len() {
        data.clear();
        for w in b.words() {
            data.extend_from_slice(&w.to_le_bytes());
        }
        enc = ENC_RAW;
    }
    out.push(enc);
    put_u32(out, data.len(), "payload data length")?;
    out.extend_from_slice(&data);
    Ok(())
}

/// One step record (length-prefixed body), updating the delta-base
/// tables to this record's maps. The tables hold *owned* clones: the
/// streaming writer drops each `StepTrace` after appending it, so the
/// bases can't borrow from it — this per-payload clone is exactly the
/// "recent maps stay resident" part of the bounded-memory contract.
pub(crate) fn encode_step(
    step: &StepTrace,
    chain: &mut ChainState,
    out: &mut Vec<u8>,
) -> Result<()> {
    chain.enter_record(step.step);
    let mut body = Vec::new();
    body.extend_from_slice(&(step.step as u64).to_le_bytes());
    body.extend_from_slice(&step.loss.to_le_bytes());
    put_u16(&mut body, step.layers.len(), "layer count")?;
    for l in &step.layers {
        put_u16(&mut body, l.name.len(), "layer name length")?;
        body.extend_from_slice(l.name.as_bytes());
        body.extend_from_slice(&l.act_sparsity.to_le_bytes());
        body.extend_from_slice(&l.grad_sparsity.to_le_bytes());
        let mut flags = 0u8;
        flags |= if l.identity_ok { FLAG_IDENTITY } else { 0 };
        flags |= if l.footprint { FLAG_FOOTPRINT } else { 0 };
        flags |= if l.act_bitmap.is_some() { FLAG_ACT } else { 0 };
        flags |= if l.grad_bitmap.is_some() { FLAG_GRAD } else { 0 };
        body.push(flags);
        for (slot, b) in
            [("act_bitmap", &l.act_bitmap), ("grad_bitmap", &l.grad_bitmap)]
        {
            if let Some(b) = b {
                let key = (l.name.clone(), slot);
                let (prev, prev_img) = chain.bases(&key);
                encode_payload(b, prev, prev_img, &mut body)?;
                chain.record(key, b.clone());
            }
        }
    }
    put_u32(out, body.len(), "step body length")?;
    out.extend_from_slice(&body);
    Ok(())
}

/// Whole-file encode — what `TraceFile::save` writes for
/// [`TraceFormat::V4`]. The streaming writer produces byte-identical
/// output for the same steps in the same order.
pub(crate) fn encode(t: &TraceFile) -> Result<Vec<u8>> {
    let mut out = encode_header(&t.network)?;
    let mut chain = ChainState::new();
    for s in &t.steps {
        encode_step(s, &mut chain, &mut out)?;
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Streaming writer
// ---------------------------------------------------------------------------

/// Incremental v4 writer: open once, [`TraceWriter::append`] one step at
/// a time, [`TraceWriter::finish`]. Memory stays bounded by the delta
/// bases — about two step groups' worth of maps — no matter how many
/// steps the run captures: the whole point of the v4 container for long
/// `agos train` runs, where the v3 path had to hold every step's
/// `StepTrace` in a `TraceFile` until the end just to serialize it.
pub struct TraceWriter {
    out: std::io::BufWriter<std::fs::File>,
    chain: ChainState,
    steps: usize,
}

impl TraceWriter {
    /// Create/truncate `path` and write the v4 header.
    pub fn create(path: &Path, network: &str) -> Result<TraceWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut out = std::io::BufWriter::new(file);
        out.write_all(&encode_header(network)?)?;
        Ok(TraceWriter { out, chain: ChainState::new(), steps: 0 })
    }

    /// Append one step record. Steps must arrive in capture order — the
    /// delta chain is positional, exactly like the v3 JSON layout.
    pub fn append(&mut self, step: &StepTrace) -> Result<()> {
        let mut buf = Vec::new();
        encode_step(step, &mut self.chain, &mut buf)?;
        self.out.write_all(&buf)?;
        self.steps += 1;
        Ok(())
    }

    /// Flush and close; returns how many steps were written. Because
    /// every record is self-framed, a crash *before* finish still
    /// leaves a file the lenient loader recovers prefix-complete.
    pub fn finish(mut self) -> Result<usize> {
        self.out.flush()?;
        Ok(self.steps)
    }
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian cursor over the raw file bytes.
struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(bytes: &'a [u8]) -> Reader<'a> {
        Reader { bytes, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        anyhow::ensure!(
            self.remaining() >= n,
            "{what}: needs {n} bytes, {} left",
            self.remaining()
        );
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u16(&mut self, what: &str) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2, what)?.try_into().unwrap()))
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    fn str(&mut self, what: &str) -> Result<&'a str> {
        let n = self.u16(what)? as usize;
        std::str::from_utf8(self.take(n, what)?).with_context(|| format!("{what}: not UTF-8"))
    }
}

/// Decode one payload section into a `Bitmap`. Raw sections become the
/// bitmap's word storage directly (one `Vec<u64>` allocation, no
/// per-word re-parse); RLE/delta runs expand straight into words.
fn decode_payload(
    r: &mut Reader,
    what: &str,
    prev: Option<&Bitmap>,
    prev_img: Option<&Bitmap>,
) -> Result<Bitmap> {
    let c = r.u32(what)? as usize;
    let h = r.u32(what)? as usize;
    let w = r.u32(what)? as usize;
    let shape = Shape::new(c, h, w);
    let enc = r.u8(what)?;
    let len = r.u32(what)? as usize;
    let data = r.take(len, what)?;
    match enc {
        ENC_RAW => {
            let n_words = shape.len().div_ceil(64);
            anyhow::ensure!(
                len == n_words * 8,
                "{what}: raw section is {len} bytes, shape {shape} needs {}",
                n_words * 8
            );
            let words = data
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Bitmap::from_words(shape, words).context(what.to_string())
        }
        ENC_RLE => Bitmap::decode_rle_bin(shape, data).context(what.to_string()),
        ENC_DELTA | ENC_DELTA_IMG => {
            let base = if enc == ENC_DELTA { prev } else { prev_img };
            let role = if enc == ENC_DELTA {
                "a previous same-slot map"
            } else {
                "a same-position map in the previous step group"
            };
            let base =
                base.with_context(|| format!("{what}: delta payload without {role}"))?;
            anyhow::ensure!(
                base.shape == shape,
                "{what}: delta shape {shape} vs base's {}",
                base.shape
            );
            Ok(Bitmap::decode_rle_bin(shape, data).context(what.to_string())?.xor(base))
        }
        other => anyhow::bail!("{what}: unknown payload encoding {other}"),
    }
}

/// Decode one step body (the bytes inside the length frame).
fn decode_step(body: &[u8], si: usize, chain: &mut ChainState) -> Result<StepTrace> {
    let r = &mut Reader::new(body);
    let step = r.u64("step")? as usize;
    chain.enter_record(step);
    let loss = r.f64("loss")?;
    let n_layers = r.u16("layer count")? as usize;
    let mut layers = Vec::with_capacity(n_layers);
    for _ in 0..n_layers {
        let name = r.str("layer name")?.to_string();
        let act_sparsity = r.f64("act_sparsity")?;
        let grad_sparsity = r.f64("grad_sparsity")?;
        let flags = r.u8("flags")?;
        let mut slot = |slot: &'static str, present: bool| -> Result<Option<Bitmap>> {
            if !present {
                return Ok(None);
            }
            let what = format!("step {si} layer '{name}' {slot}");
            let key = (name.clone(), slot);
            let b = {
                let (prev, prev_img) = chain.bases(&key);
                decode_payload(r, &what, prev, prev_img)?
            };
            chain.record(key, b.clone());
            Ok(Some(b))
        };
        let act_bitmap = slot("act_bitmap", flags & FLAG_ACT != 0)?;
        let grad_bitmap = slot("grad_bitmap", flags & FLAG_GRAD != 0)?;
        layers.push(LayerTrace {
            name,
            act_sparsity,
            grad_sparsity,
            identity_ok: flags & FLAG_IDENTITY != 0,
            act_bitmap,
            grad_bitmap,
            footprint: flags & FLAG_FOOTPRINT != 0,
        });
    }
    anyhow::ensure!(
        r.remaining() == 0,
        "step {si} record has {} trailing bytes",
        r.remaining()
    );
    Ok(StepTrace { step, loss, layers })
}

/// Decode a whole v4 byte stream. Strict mode (`lenient = false`) makes
/// the first malformed record a hard error carrying its step index and
/// layer/slot context. Lenient mode keeps every *complete* step decoded
/// so far and stops at the first truncated or corrupt record with a
/// warning — the crash-recovery path for a capture that died mid-write.
/// It stops entirely (rather than skipping the bad record) because the
/// delta chain makes everything after an undecodable record unsound. A
/// damaged *header* is a hard error in both modes: there is no trace to
/// salvage without the network identity.
pub(crate) fn decode(bytes: &[u8], lenient: bool) -> Result<(TraceFile, Vec<String>)> {
    let r = &mut Reader::new(bytes);
    anyhow::ensure!(r.take(8, "magic")? == MAGIC, "not a v4 trace: bad magic");
    let version = r.u8("container version")?;
    anyhow::ensure!(
        version == CONTAINER_VERSION,
        "unsupported binary trace container version {version} (this build reads {CONTAINER_VERSION})"
    );
    let network = r.str("network name")?.to_string();
    let mut warnings = Vec::new();
    let mut chain = ChainState::new();
    let mut steps = Vec::new();
    while r.remaining() > 0 {
        let si = steps.len();
        let step = (|| -> Result<StepTrace> {
            let len = r.u32("step frame")? as usize;
            let body = r.take(len, "step body")?;
            decode_step(body, si, &mut chain)
        })();
        match step {
            Ok(s) => steps.push(s),
            Err(e) if lenient => {
                warnings.push(format!(
                    "{e:#} — keeping the {si} complete steps before it"
                ));
                break;
            }
            Err(e) => {
                return Err(e.context(format!("step record {si}")));
            }
        }
    }
    Ok((TraceFile { network, steps, format: TraceFormat::V4 }, warnings))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn payload_trace() -> TraceFile {
        let shape = Shape::new(4, 6, 6);
        let mut rng = Pcg32::new(3);
        let act = Bitmap::sample(shape, 0.6, &mut rng);
        let grad = act.and(&Bitmap::sample(shape, 0.8, &mut rng));
        let mut act2 = act.clone();
        act2.set(0, 0, 0, !act2.get(0, 0, 0));
        TraceFile {
            network: "agos_cnn".into(),
            steps: vec![
                StepTrace {
                    step: 0,
                    loss: 2.0,
                    layers: vec![LayerTrace::from_bitmaps("relu1", act, grad.clone())],
                },
                StepTrace {
                    step: 1,
                    loss: 1.9,
                    layers: vec![LayerTrace::from_bitmaps("relu1", act2, grad)],
                },
            ],
            format: TraceFormat::V4,
        }
    }

    #[test]
    fn binary_roundtrip_is_bit_exact() {
        let t = payload_trace();
        let bytes = encode(&t).unwrap();
        assert_eq!(bytes[..8], MAGIC);
        let (t2, warnings) = decode(&bytes, false).unwrap();
        assert!(warnings.is_empty());
        assert_eq!(t, t2);
        // Scalar-only and footprint entries survive too.
        let mut t = t;
        t.steps[0].layers.push(LayerTrace::scalar("relu9", 0.25, 0.5, false));
        t.steps[0]
            .layers
            .push(LayerTrace::from_act("b1_add", Bitmap::ones(Shape::new(1, 2, 40))));
        let (t2, _) = decode(&encode(&t).unwrap(), false).unwrap();
        assert_eq!(t, t2);
        assert!(t2.steps[0].layers[2].footprint);
        assert!(!t2.steps[0].layers[1].identity_ok);
    }

    #[test]
    fn streaming_writer_matches_whole_file_encode() {
        let t = payload_trace();
        let dir = std::env::temp_dir().join("agos_trace_v4_stream_test");
        let path = dir.join("t.trace.bin");
        let mut w = TraceWriter::create(&path, &t.network).unwrap();
        for s in &t.steps {
            w.append(s).unwrap();
        }
        assert_eq!(w.finish().unwrap(), 2);
        let streamed = std::fs::read(&path).unwrap();
        assert_eq!(streamed, encode(&t).unwrap(), "streamed bytes == one-shot bytes");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn correlated_steps_choose_delta_and_chain_back() {
        // payload_trace's step-1 act differs from step 0 by one bit and
        // its grad repeats exactly: both must pick the delta encoding
        // (tiny XOR runs) and still decode bit-exactly.
        let t = payload_trace();
        let bytes = encode(&t).unwrap();
        let one_step = TraceFile { steps: vec![t.steps[0].clone()], ..t.clone() };
        let step1_only = TraceFile { steps: vec![t.steps[1].clone()], ..t.clone() };
        let chained = bytes.len() - encode(&one_step).unwrap().len();
        let unchained =
            encode(&step1_only).unwrap().len() - encode_header(&t.network).unwrap().len();
        assert!(
            chained < unchained,
            "delta-chained step 1 ({chained} B) must beat its standalone encoding ({unchained} B)"
        );
        assert_eq!(decode(&bytes, false).unwrap().0, t);
    }

    #[test]
    fn truncation_errors_strictly_and_recovers_leniently() {
        let t = payload_trace();
        let bytes = encode(&t).unwrap();
        let one_step_len = encode(&TraceFile { steps: vec![t.steps[0].clone()], ..t.clone() })
            .unwrap()
            .len();
        // Cut mid-way through step 1's record.
        let cut = &bytes[..one_step_len + 10];
        let err = decode(cut, false).unwrap_err();
        assert!(format!("{err:#}").contains("step record 1"), "{err:#}");
        let (rec, warnings) = decode(cut, true).unwrap();
        assert_eq!(rec.steps, t.steps[..1], "the complete step survives");
        assert_eq!(warnings.len(), 1);
        assert!(warnings[0].contains("1 complete steps"), "{warnings:?}");
        // Cutting inside the *header* is unrecoverable in both modes.
        assert!(decode(&bytes[..9], true).is_err());
        // A corrupt frame length overrunning EOF is a truncation too.
        let mut bad = bytes.clone();
        let frame_at = encode_header(&t.network).unwrap().len();
        bad[frame_at] = 0xFF;
        bad[frame_at + 1] = 0xFF;
        assert!(decode(&bad, false).is_err());
        let (rec, warnings) = decode(&bad, true).unwrap();
        assert!(rec.steps.is_empty() && warnings.len() == 1);
    }

    #[test]
    fn unknown_container_version_is_rejected() {
        let t = payload_trace();
        let mut bytes = encode(&t).unwrap();
        bytes[8] = 9;
        let err = decode(&bytes, true).unwrap_err();
        assert!(format!("{err:#}").contains("version 9"), "{err:#}");
        bytes[0] = b'X';
        assert!(decode(&bytes, false).is_err(), "bad magic is a hard error");
    }

    #[test]
    fn mid_density_payloads_fall_back_to_raw_words() {
        // A near-50% iid map has almost no zero/full words: binary RLE
        // degenerates to literal runs (8n + framing), so the encoder
        // must pick raw words (8n exactly) — the v4 analog of v3's hex
        // floor, and the section the reader adopts with zero re-coding.
        let shape = Shape::new(2, 16, 16);
        let b = Bitmap::sample(shape, 0.5, &mut Pcg32::new(7));
        let mut out = Vec::new();
        encode_payload(&b, None, None, &mut out).unwrap();
        assert_eq!(out[12], ENC_RAW, "enc byte");
        let n_words = shape.len().div_ceil(64);
        assert_eq!(out.len(), 12 + 1 + 4 + n_words * 8);
        let (b2, rest) = {
            let r = &mut Reader::new(&out);
            let b2 = decode_payload(r, "p", None, None).unwrap();
            (b2, r.remaining())
        };
        assert_eq!(b2, b);
        assert_eq!(rest, 0);
    }

    #[test]
    fn payload_picks_the_image_base_only_when_strictly_smaller() {
        let shape = Shape::new(2, 16, 16);
        let mut rng = Pcg32::new(13);
        let prev = Bitmap::sample(shape, 0.5, &mut rng);
        let cur = Bitmap::sample(shape, 0.5, &mut rng);
        let img_base = {
            let mut b = cur.clone();
            b.set(0, 0, 0, !b.get(0, 0, 0));
            b
        };
        // The slot chain is uncorrelated, the image base one bit away:
        // only the image delta beats RLE/raw, so tag 3 must be chosen
        // and must decode back through the same base.
        let mut out = Vec::new();
        encode_payload(&cur, Some(&prev), Some(&img_base), &mut out).unwrap();
        assert_eq!(out[12], ENC_DELTA_IMG, "enc byte");
        let r = &mut Reader::new(&out);
        assert_eq!(decode_payload(r, "p", Some(&prev), Some(&img_base)).unwrap(), cur);
        assert_eq!(r.remaining(), 0);
        // Identical bases tie on delta size: the lower tag (2) must
        // win, keeping single-image traces byte-identical to the
        // pre-group encoder.
        let mut out = Vec::new();
        encode_payload(&cur, Some(&img_base), Some(&img_base), &mut out).unwrap();
        assert_eq!(out[12], ENC_DELTA, "ties keep the lower tag");
    }

    #[test]
    fn image_aligned_delta_beats_the_slot_chain_for_grouped_captures() {
        // Two images per step: each image's map evolves by one bit per
        // step, but the images are independent samples. The tag-2 base
        // (most recent same-slot = the *other* image) is uncorrelated;
        // the tag-3 base (same image, previous group) is one bit away.
        let shape = Shape::new(4, 8, 8);
        let mut rng = Pcg32::new(11);
        let a0 = Bitmap::sample(shape, 0.5, &mut rng);
        let b0 = Bitmap::sample(shape, 0.5, &mut rng);
        let mut a1 = a0.clone();
        a1.set(0, 0, 0, !a1.get(0, 0, 0));
        let mut b1 = b0.clone();
        b1.set(0, 0, 1, !b1.get(0, 0, 1));
        let rec = |step: usize, loss: f64, b: &Bitmap| StepTrace {
            step,
            loss,
            layers: vec![LayerTrace::from_act("relu1", b.clone())],
        };
        let grouped = TraceFile {
            network: "agos_cnn".into(),
            steps: vec![rec(0, 2.0, &a0), rec(0, 2.0, &b0), rec(1, 1.9, &a1), rec(1, 1.9, &b1)],
            format: TraceFormat::V4,
        };
        // The same maps under distinct step values form no groups, so
        // only the (uncorrelated) slot chain is available.
        let ungrouped = TraceFile {
            steps: grouped
                .steps
                .iter()
                .enumerate()
                .map(|(i, s)| StepTrace { step: i, ..s.clone() })
                .collect(),
            ..grouped.clone()
        };
        let gb = encode(&grouped).unwrap();
        let ub = encode(&ungrouped).unwrap();
        assert!(
            gb.len() < ub.len(),
            "image-aligned deltas must shrink the grouped capture ({} vs {} bytes)",
            gb.len(),
            ub.len()
        );
        let (t2, warnings) = decode(&gb, false).unwrap();
        assert!(warnings.is_empty());
        assert_eq!(t2, grouped, "grouped roundtrip is bit-exact");
        assert_eq!(decode(&ub, false).unwrap().0, ungrouped);
    }
}
