//! Sparsity trace files: what the coordinator extracts from real training
//! through the AOT artifacts, persisted as JSON for the co-simulation
//! driver and the figures.
//!
//! Four on-disk revisions:
//!
//! * **v1** — scalar per-layer measurements only (name, activation /
//!   gradient zero fractions, identity flag). Files written before the
//!   bitmap-native pipeline carry no `version` key.
//! * **v2** — additionally carries optional *packed bitmaps* per traced
//!   layer per step: the within-channel zero footprints of the forward
//!   activation (Fig 7) and of the ReLU-masked gradient, encoded as
//!   `{shape: [c, h, w], words: "<hex u64 words>"}`. These are what
//!   `agos cosim --replay` feeds pattern-exactly into the exact backend
//!   (`sim::replay`).
//! * **v3** — the same payload *content* under a delta/RLE word encoding
//!   (`{shape, enc: "rle"|"delta"|"hex", words}`): `zN`/`oN` runs of
//!   zero/full words, literal hex otherwise (`Bitmap::encode_rle`), and
//!   optionally the run-length of the XOR against the *previous step's*
//!   map of the same layer when that is smaller (`enc: "delta"`). This
//!   is what makes batch-wide capture (`--trace-images N`) practical:
//!   payload bytes stop growing linearly with raw map size. v3 is also
//!   the first revision that records **post-Add footprints** (act-only
//!   entries for residual Add layers) so the replay bank no longer stops
//!   deriving footprints at Add nodes.
//! * **v4** — the same payload content in a *binary streaming container*
//!   (`trace::v4`): magic header, per-step length-framed records, and
//!   delta/RLE/raw-word payload sections in packed bytes instead of
//!   JSON text. Capture appends step by step with bounded memory
//!   ([`TraceWriter`]) and the reader decodes runs straight into
//!   `Bitmap` word buffers — no hex strings anywhere.
//!
//! All four revisions load through [`TraceFile::load`], which sniffs
//! the v4 magic vs JSON; [`TraceFile::format`] selects which of
//! v2/v3/v4 `save` writes (v3 is the default for new captures).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::nn::Shape;
use crate::sparsity::Bitmap;
use crate::util::fnv::Fnv1a;
use crate::util::json::Json;

mod v4;
pub use v4::TraceWriter;

/// Current trace-file schema revision.
pub const TRACE_VERSION: u64 = 4;

/// Which on-disk payload encoding a [`TraceFile`] saves as. Decoding is
/// format-agnostic (every revision loads); this only steers `save`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum TraceFormat {
    /// `"version": 2` — raw hex word payloads.
    V2,
    /// `"version": 3` — delta/RLE word payloads (the default).
    #[default]
    V3,
    /// Binary streaming container (`trace::v4`): magic header, per-step
    /// length-framed records, packed delta/RLE/raw-word payloads.
    V4,
}

impl TraceFormat {
    pub const ALL: [TraceFormat; 3] = [TraceFormat::V2, TraceFormat::V3, TraceFormat::V4];

    pub fn label(&self) -> &'static str {
        match self {
            TraceFormat::V2 => "v2",
            TraceFormat::V3 => "v3",
            TraceFormat::V4 => "v4",
        }
    }

    /// The schema revision this format writes (the JSON `version` key
    /// for v2/v3, the container version byte for v4).
    pub fn version(&self) -> u64 {
        match self {
            TraceFormat::V2 => 2,
            TraceFormat::V3 => 3,
            TraceFormat::V4 => 4,
        }
    }

    /// Stable tag folded into [`TraceFile::fingerprint`] — and through
    /// it into `SimOptions::fingerprint` and the sweep-cache key — so
    /// the same content persisted under different encodings never
    /// aliases in the cache.
    pub fn tag(&self) -> u64 {
        self.version()
    }

    pub fn parse(s: &str) -> anyhow::Result<TraceFormat> {
        match s.to_ascii_lowercase().as_str() {
            "v2" | "2" | "hex" => Ok(TraceFormat::V2),
            "v3" | "3" | "rle" => Ok(TraceFormat::V3),
            "v4" | "4" | "bin" => Ok(TraceFormat::V4),
            other => anyhow::bail!("unknown trace format '{other}' (v2|v3|v4)"),
        }
    }
}

/// Per-layer measurement at one training step.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerTrace {
    /// Traced layer name (matches the `nn::Network` layer names): a ReLU
    /// for act+grad entries, a residual Add for act-only footprints.
    pub name: String,
    /// Forward activation zero fraction.
    pub act_sparsity: f64,
    /// Backward gradient zero fraction (at the ReLU output).
    pub grad_sparsity: f64,
    /// Whether footprint(gradient) ⊆ footprint(activation) held exactly.
    pub identity_ok: bool,
    /// v2+: packed forward-activation zero footprint (the Fig 7 bitmap
    /// the forward pass leaves in DRAM), if captured.
    pub act_bitmap: Option<Bitmap>,
    /// v2+: packed ReLU-masked gradient zero footprint, if captured.
    pub grad_bitmap: Option<Bitmap>,
    /// v3: this entry is a replay-layout *footprint* (a post-Add map),
    /// not a ReLU sparsity measurement — excluded from
    /// [`TraceFile::mean_act_sparsity`]. An explicit marker rather than
    /// "act payload without a grad payload" inference, because the
    /// lenient loader can drop payloads and must not let a damaged
    /// measurement masquerade as a footprint (or vice versa).
    pub footprint: bool,
}

impl LayerTrace {
    /// A scalar-only (v1-shaped) measurement.
    pub fn scalar(name: &str, act_sparsity: f64, grad_sparsity: f64, identity_ok: bool) -> LayerTrace {
        LayerTrace {
            name: name.to_string(),
            act_sparsity,
            grad_sparsity,
            identity_ok,
            act_bitmap: None,
            grad_bitmap: None,
            footprint: false,
        }
    }

    /// A payload-bearing measurement: the scalar fields are *derived*
    /// from the maps (fractions from popcounts, identity from footprint
    /// containment), so scalars and patterns can never disagree.
    pub fn from_bitmaps(name: &str, act: Bitmap, grad: Bitmap) -> LayerTrace {
        LayerTrace {
            name: name.to_string(),
            act_sparsity: act.sparsity(),
            grad_sparsity: grad.sparsity(),
            identity_ok: grad.contained_in(&act),
            act_bitmap: Some(act),
            grad_bitmap: Some(grad),
            footprint: false,
        }
    }

    /// An activation-only footprint entry — how **post-Add footprints**
    /// are recorded (v3 capture). An Add output has no ReLU-masked
    /// gradient of its own and its footprint is not derivable from ReLU
    /// maps (conv summands can be negative), so the forward pass writes
    /// the bitmap at capture time; the gradient side stays absent and
    /// the identity check is trivially satisfied.
    pub fn from_act(name: &str, act: Bitmap) -> LayerTrace {
        LayerTrace {
            name: name.to_string(),
            act_sparsity: act.sparsity(),
            grad_sparsity: 0.0,
            identity_ok: true,
            act_bitmap: Some(act),
            grad_bitmap: None,
            footprint: true,
        }
    }

    pub fn has_bitmaps(&self) -> bool {
        self.act_bitmap.is_some() || self.grad_bitmap.is_some()
    }
}

/// One traced training step.
#[derive(Clone, Debug, PartialEq)]
pub struct StepTrace {
    pub step: usize,
    pub loss: f64,
    pub layers: Vec<LayerTrace>,
}

/// A whole training run's traces.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceFile {
    pub network: String,
    pub steps: Vec<StepTrace>,
    /// On-disk payload encoding `save`/`to_json` emit (decoding accepts
    /// every revision regardless). Captures default to v3.
    pub format: TraceFormat,
}

/// Key of the previous-map table the delta codec chains on: one slot per
/// (layer name, act|grad side), updated step by step in file order.
type SlotKey = (String, &'static str);

fn shape_to_json(b: &Bitmap) -> Json {
    Json::Arr(vec![b.shape.c.into(), b.shape.h.into(), b.shape.w.into()])
}

/// v2 payload: raw hex words.
fn bitmap_to_json_hex(b: &Bitmap) -> Json {
    Json::from_pairs(vec![("shape", shape_to_json(b)), ("words", b.encode_hex().into())])
}

/// v3 payload: the smallest of the raw words' RLE, the RLE of the XOR
/// against the previous step's same-slot map, and plain hex. The hex
/// floor matters at mid densities, where zero/full words are
/// vanishingly rare and space-separated literals would cost slightly
/// *more* than packed hex — v3 payloads are therefore never larger
/// than their v2 encoding.
fn bitmap_to_json_rle(b: &Bitmap, prev: Option<&Bitmap>) -> Json {
    let (mut enc, mut payload) = ("rle", b.encode_rle());
    if let Some(p) = prev {
        if p.shape == b.shape {
            let delta = b.xor(p).encode_rle();
            if delta.len() < payload.len() {
                (enc, payload) = ("delta", delta);
            }
        }
    }
    if b.words().len() * 16 < payload.len() {
        (enc, payload) = ("hex", b.encode_hex());
    }
    Json::from_pairs(vec![
        ("shape", shape_to_json(b)),
        ("enc", enc.into()),
        ("words", payload.into()),
    ])
}

/// Decode one bitmap payload. `version` gates which encodings are legal
/// (`enc` keys may only appear in v3+ files); `prev` is the previous
/// step's decoded map of the same (layer, slot), the delta base.
fn bitmap_from_json(
    j: &Json,
    what: &str,
    version: u64,
    prev: Option<&Bitmap>,
) -> Result<Option<Bitmap>> {
    if matches!(j, Json::Null) {
        return Ok(None);
    }
    let dims = j.get("shape").as_arr().with_context(|| format!("{what}.shape"))?;
    anyhow::ensure!(dims.len() == 3, "{what}.shape must be [c, h, w]");
    let dim = |i: usize| dims[i].as_usize().with_context(|| format!("{what}.shape[{i}]"));
    let shape = Shape::new(dim(0)?, dim(1)?, dim(2)?);
    let words = j.get("words").as_str().with_context(|| format!("{what}.words"))?;
    let map = match j.get("enc") {
        Json::Null => Bitmap::decode_hex(shape, words).context(what.to_string())?,
        enc => {
            let enc = enc.as_str().with_context(|| format!("{what}.enc must be a string"))?;
            anyhow::ensure!(
                version >= 3,
                "{what}: '{enc}' payload encoding in a v{version} trace"
            );
            match enc {
                "hex" => Bitmap::decode_hex(shape, words).context(what.to_string())?,
                "rle" => Bitmap::decode_rle(shape, words).context(what.to_string())?,
                "delta" => {
                    let prev = prev.with_context(|| {
                        format!("{what}: delta payload without a previous step's map")
                    })?;
                    anyhow::ensure!(
                        prev.shape == shape,
                        "{what}: delta shape {shape} vs previous step's {}",
                        prev.shape
                    );
                    Bitmap::decode_rle(shape, words).context(what.to_string())?.xor(prev)
                }
                other => anyhow::bail!("{what}: unknown payload encoding '{other}'"),
            }
        }
    };
    Ok(Some(map))
}

impl TraceFile {
    pub fn new(network: &str) -> TraceFile {
        TraceFile {
            network: network.to_string(),
            steps: Vec::new(),
            format: TraceFormat::default(),
        }
    }

    /// Mean activation sparsity per layer across all traced steps —
    /// the input to `SparsityModel::measured`. Footprint entries
    /// (post-Add captures) are excluded: they are replay layout data,
    /// not ReLU sparsity measurements, and their near-zero sparsity
    /// would dilute the means the measured model and the cosim report
    /// are built from.
    pub fn mean_act_sparsity(&self) -> std::collections::BTreeMap<String, f64> {
        let mut sums: std::collections::BTreeMap<String, (f64, usize)> = Default::default();
        for step in &self.steps {
            for l in &step.layers {
                if l.footprint {
                    continue;
                }
                let e = sums.entry(l.name.clone()).or_insert((0.0, 0));
                e.0 += l.act_sparsity;
                e.1 += 1;
            }
        }
        sums.into_iter().map(|(k, (s, n))| (k, s / n as f64)).collect()
    }

    /// Every step's identity check passed?
    pub fn identity_holds(&self) -> bool {
        self.steps.iter().all(|s| s.layers.iter().all(|l| l.identity_ok))
    }

    /// Does any step carry packed bitmap payloads (v2+ content)?
    pub fn has_bitmaps(&self) -> bool {
        self.steps.iter().any(|s| s.layers.iter().any(|l| l.has_bitmaps()))
    }

    /// Aggregate run structure over every payload in the file:
    /// `(all-zero words, all-ones words, total words)` across act and
    /// grad bitmaps. Scanned from the *reconstructed* maps (a v3 file's
    /// on-disk runs describe delta payloads, not the maps they decode
    /// to). The zero fraction bounds what the exact backend's RLE-aware
    /// zero-skip can elide when this trace replays (`sim::plan`) —
    /// `agos trace` prints it as zero-skip potential.
    pub fn payload_run_stats(&self) -> (usize, usize, usize) {
        let (mut zeros, mut ones, mut total) = (0usize, 0usize, 0usize);
        for l in self.steps.iter().flat_map(|s| &s.layers) {
            for b in [&l.act_bitmap, &l.grad_bitmap].into_iter().flatten() {
                let idx = b.run_index();
                zeros += idx.zero_words();
                ones += idx.one_words();
                total += b.shape.len().div_ceil(64);
            }
        }
        (zeros, ones, total)
    }

    /// Stable content fingerprint over *everything* in the trace —
    /// network, the on-disk format, per-step scalars and bitmap
    /// payloads. Folded into `SimOptions::fingerprint` by the cosim
    /// driver so two different trace files can never share a sweep-cache
    /// entry, even when their per-layer mean sparsities happen to
    /// coincide — and so the same content persisted as v2 vs v3 keys
    /// separately too (the format changes what a re-run would read).
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv1a::new();
        h.put_str(&self.network);
        h.put(self.format.tag());
        h.put(self.steps.len() as u64);
        for s in &self.steps {
            h.put(s.step as u64).put_f64(s.loss);
            for l in &s.layers {
                h.put_str(&l.name)
                    .put_f64(l.act_sparsity)
                    .put_f64(l.grad_sparsity)
                    .put(l.identity_ok as u64)
                    .put(l.footprint as u64);
                // Presence tags keep (None, Some(b)) and (Some(b), None)
                // from aliasing.
                match &l.act_bitmap {
                    Some(b) => h.put(1).put(b.fingerprint()),
                    None => h.put(0),
                };
                match &l.grad_bitmap {
                    Some(b) => h.put(1).put(b.fingerprint()),
                    None => h.put(0),
                };
            }
        }
        h.finish()
    }

    /// JSON form of the trace. For [`TraceFormat::V4`] this is a
    /// *downgrade*: JSON cannot carry the binary container, so payloads
    /// are emitted v3-style under `"version": 3` (used when a v4 trace
    /// is embedded into a JSON report; `save` itself writes the real
    /// binary form). A reload of that JSON therefore reads back as v3.
    pub fn to_json(&self) -> Json {
        // Previous-map table for the v3 delta chain, keyed (layer, slot)
        // and updated in file order — the decoder walks the same chain.
        // Everything borrows from `self`, so the table holds references
        // (no per-payload map clones while serializing a batch capture).
        fn emit<'a>(
            format: TraceFormat,
            prev: &mut HashMap<(&'a str, &'static str), &'a Bitmap>,
            name: &'a str,
            slot: &'static str,
            b: &'a Bitmap,
        ) -> Json {
            let j = match format {
                TraceFormat::V2 => bitmap_to_json_hex(b),
                TraceFormat::V3 | TraceFormat::V4 => {
                    bitmap_to_json_rle(b, prev.get(&(name, slot)).copied())
                }
            };
            prev.insert((name, slot), b);
            j
        }
        let mut prev: HashMap<(&str, &'static str), &Bitmap> = HashMap::new();
        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|s| {
                let layers: Vec<Json> = s
                    .layers
                    .iter()
                    .map(|l| {
                        let mut j = Json::from_pairs(vec![
                            ("name", l.name.as_str().into()),
                            ("act_sparsity", l.act_sparsity.into()),
                            ("grad_sparsity", l.grad_sparsity.into()),
                            ("identity_ok", l.identity_ok.into()),
                        ]);
                        // Emit the marker for every footprint entry, and
                        // for act-only measurements (a lenient drop can
                        // produce those), where the reader's key-based
                        // inference would otherwise guess wrong.
                        if l.footprint || (l.act_bitmap.is_some() && l.grad_bitmap.is_none()) {
                            j.set("footprint", l.footprint.into());
                        }
                        if let Some(b) = &l.act_bitmap {
                            j.set(
                                "act_bitmap",
                                emit(self.format, &mut prev, &l.name, "act_bitmap", b),
                            );
                        }
                        if let Some(b) = &l.grad_bitmap {
                            j.set(
                                "grad_bitmap",
                                emit(self.format, &mut prev, &l.name, "grad_bitmap", b),
                            );
                        }
                        j
                    })
                    .collect();
                Json::from_pairs(vec![
                    ("step", s.step.into()),
                    ("loss", s.loss.into()),
                    ("layers", Json::Arr(layers)),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("version", self.format.version().min(3).into()),
            ("network", self.network.as_str().into()),
            ("steps", Json::Arr(steps)),
        ])
    }

    /// Strict parse: the first structural problem or corrupt payload is
    /// a hard error carrying the step index, layer name and payload slot
    /// (`step N layer 'x' act_bitmap: …`).
    pub fn from_json(j: &Json) -> Result<TraceFile> {
        let (t, warnings) = TraceFile::parse(j, false)?;
        debug_assert!(warnings.is_empty(), "strict parse collects no warnings");
        Ok(t)
    }

    /// Lenient parse: structural problems are still hard errors, but a
    /// corrupt/truncated bitmap *payload* is dropped (the scalar entry
    /// survives) and reported as a warning with its layer/step context —
    /// what `agos cosim` uses to warn-and-fall-back instead of dying on
    /// a damaged capture. Dropping a payload also breaks any later delta
    /// chained on it, so those drop (with their own warnings) too.
    pub fn from_json_lenient(j: &Json) -> Result<(TraceFile, Vec<String>)> {
        TraceFile::parse(j, true)
    }

    fn parse(j: &Json, lenient: bool) -> Result<(TraceFile, Vec<String>)> {
        // v1 files predate the version key; absent means 1.
        let version = match j.get("version") {
            Json::Null => 1,
            v => v.as_u64().context("trace.version")?,
        };
        // JSON traces top out at v3 — revision 4 is the binary
        // container, which never reaches the JSON parser (`load` sniffs
        // its magic first).
        anyhow::ensure!(
            (1..=3).contains(&version),
            "unsupported trace version {version} (JSON traces are v1..=v3; v4 is binary)"
        );
        let format = if version >= 3 { TraceFormat::V3 } else { TraceFormat::V2 };
        let network = j.get("network").as_str().context("trace.network")?.to_string();
        let mut warnings = Vec::new();
        let mut prev: HashMap<SlotKey, Bitmap> = HashMap::new();
        let mut steps = Vec::new();
        for (si, s) in j.get("steps").as_arr().context("trace.steps")?.iter().enumerate() {
            let mut layers = Vec::new();
            for l in s.get("layers").as_arr().context("step.layers")? {
                let name = l.get("name").as_str().context("layer.name")?.to_string();
                let mut slot = |slot: &'static str| -> Result<Option<Bitmap>> {
                    let what = format!("step {si} layer '{name}' {slot}");
                    let key = (name.clone(), slot);
                    match bitmap_from_json(l.get(slot), &what, version, prev.get(&key)) {
                        Ok(Some(b)) => {
                            // The delta base is only consultable in v3+
                            // files (enc keys are version-gated), so
                            // don't pay a per-payload map clone to
                            // maintain it for v1/v2 loads. (For v3 the
                            // clone is deliberate: an owned table keeps
                            // the chain logic trivially correct; an
                            // index back into the partially-built steps
                            // would save one copy per payload at the
                            // cost of cross-referencing a structure
                            // still under construction.)
                            if version >= 3 {
                                prev.insert(key, b.clone());
                            }
                            Ok(Some(b))
                        }
                        Ok(None) => Ok(None),
                        Err(e) if lenient => {
                            warnings.push(format!("{e:#} — payload dropped"));
                            // Evict the delta base: a later delta chained
                            // on the dropped map must fail loudly (and
                            // drop too), never silently decode against a
                            // stale earlier step.
                            prev.remove(&key);
                            Ok(None)
                        }
                        Err(e) => Err(e),
                    }
                };
                let act_bitmap = slot("act_bitmap")?;
                let grad_bitmap = slot("grad_bitmap")?;
                // Footprint marker: the explicit flag when present,
                // otherwise inferred from the *file's* payload keys —
                // which, unlike the decoded options above, survive the
                // lenient loader dropping a corrupt payload.
                let footprint = match l.get("footprint") {
                    Json::Null => {
                        !matches!(l.get("act_bitmap"), Json::Null)
                            && matches!(l.get("grad_bitmap"), Json::Null)
                    }
                    v => v.as_bool().context("layer.footprint")?,
                };
                layers.push(LayerTrace {
                    act_sparsity: l.get("act_sparsity").as_f64().context("act")?,
                    grad_sparsity: l.get("grad_sparsity").as_f64().context("grad")?,
                    identity_ok: l.get("identity_ok").as_bool().context("ok")?,
                    name,
                    act_bitmap,
                    grad_bitmap,
                    footprint,
                });
            }
            steps.push(StepTrace {
                step: s.get("step").as_usize().context("step.step")?,
                loss: s.get("loss").as_f64().context("step.loss")?,
                layers,
            });
        }
        Ok((TraceFile { network, steps, format }, warnings))
    }

    /// Persist in [`TraceFile::format`]: the binary v4 container, or
    /// pretty JSON for v2/v3.
    pub fn save(&self, path: &Path) -> Result<()> {
        match self.format {
            TraceFormat::V4 => {
                if let Some(dir) = path.parent() {
                    std::fs::create_dir_all(dir)?;
                }
                std::fs::write(path, v4::encode(self)?)
                    .with_context(|| format!("writing {}", path.display()))
            }
            TraceFormat::V2 | TraceFormat::V3 => self.to_json().write_file(path),
        }
    }

    /// In-memory binary v4 encode — the exact bytes `save` writes when
    /// [`TraceFile::format`] is [`TraceFormat::V4`]. Exposed so benches
    /// and size accounting can measure the container without file I/O.
    pub fn encode_v4(&self) -> Result<Vec<u8>> {
        v4::encode(self)
    }

    /// Strict in-memory decode of a binary v4 container (the inverse of
    /// [`TraceFile::encode_v4`]).
    pub fn decode_v4(bytes: &[u8]) -> Result<TraceFile> {
        let (t, warnings) = v4::decode(bytes, false)?;
        debug_assert!(warnings.is_empty(), "strict decode collects no warnings");
        Ok(t)
    }

    /// Load any revision through one entry point: the file's first
    /// bytes are sniffed for the v4 magic, everything else parses as
    /// JSON (v1–v3).
    pub fn load(path: &Path) -> Result<TraceFile> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        if bytes.len() >= v4::MAGIC.len() && bytes[..v4::MAGIC.len()] == v4::MAGIC {
            let (t, warnings) = v4::decode(&bytes, false)?;
            debug_assert!(warnings.is_empty(), "strict decode collects no warnings");
            return Ok(t);
        }
        let text = std::str::from_utf8(&bytes)
            .map_err(|e| anyhow::anyhow!("{}: neither v4 binary nor JSON: {e}", path.display()))?;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        TraceFile::from_json(&j)
    }

    /// [`TraceFile::load`] with the lenient payload policy of
    /// [`TraceFile::from_json_lenient`] — which for v4 streams means
    /// keeping every complete step record of a truncated capture.
    pub fn load_lenient(path: &Path) -> Result<(TraceFile, Vec<String>)> {
        let bytes = std::fs::read(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        if bytes.len() >= v4::MAGIC.len() && bytes[..v4::MAGIC.len()] == v4::MAGIC {
            return v4::decode(&bytes, true);
        }
        let text = std::str::from_utf8(&bytes)
            .map_err(|e| anyhow::anyhow!("{}: neither v4 binary nor JSON: {e}", path.display()))?;
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        TraceFile::from_json_lenient(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn sample() -> TraceFile {
        TraceFile {
            network: "agos_cnn".into(),
            steps: vec![
                StepTrace {
                    step: 0,
                    loss: 2.3,
                    layers: vec![
                        LayerTrace::scalar("relu1", 0.5, 0.52, true),
                        LayerTrace::scalar("relu2", 0.4, 0.4, true),
                    ],
                },
                StepTrace {
                    step: 50,
                    loss: 1.1,
                    layers: vec![LayerTrace::scalar("relu1", 0.7, 0.71, true)],
                },
            ],
            format: TraceFormat::default(),
        }
    }

    fn sample_payloads() -> TraceFile {
        let shape = Shape::new(4, 6, 6);
        let mut rng = Pcg32::new(3);
        let act = Bitmap::sample(shape, 0.6, &mut rng);
        let keep = Bitmap::sample(shape, 0.8, &mut rng);
        let grad = act.and(&keep); // containment by construction
        let mut t = sample();
        t.steps[0].layers[0] = LayerTrace::from_bitmaps("relu1", act, grad);
        t
    }

    #[test]
    fn json_roundtrip() {
        let t = sample();
        let t2 = TraceFile::from_json(&t.to_json()).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn payload_run_stats_count_extreme_words() {
        // Scalar-only traces have no payloads at all.
        assert_eq!(sample().payload_run_stats(), (0, 0, 0));
        // One all-zero and one all-ones payload: every word is extreme.
        let shape = Shape::new(2, 8, 8); // 128 bits = 2 words per map
        let mut t = sample();
        t.steps[0].layers[0] =
            LayerTrace::from_bitmaps("relu1", Bitmap::ones(shape), Bitmap::zeros(shape));
        let (zeros, ones, total) = t.payload_run_stats();
        assert_eq!(total, 4);
        assert_eq!(zeros, 2, "the grad map's words are all zero");
        assert_eq!(ones, 2, "the act map's words are all ones");
        // A mixed payload contributes to the total but not necessarily
        // to either extreme; counts survive a save/load roundtrip (v3
        // on-disk runs encode deltas, stats come from the decoded maps).
        let t = sample_payloads();
        let (z, o, n) = t.payload_run_stats();
        assert!(n > 0 && z <= n && o <= n);
        let t2 = TraceFile::from_json(&t.to_json()).unwrap();
        assert_eq!(t2.payload_run_stats(), (z, o, n));
    }

    #[test]
    fn v3_payloads_roundtrip_bit_exact() {
        let t = sample_payloads();
        assert!(t.has_bitmaps());
        assert!(t.identity_holds(), "containment-built grad must satisfy identity");
        let j = t.to_json();
        assert_eq!(j.get("version").as_u64(), Some(3), "default format writes v3 JSON");
        let t2 = TraceFile::from_json(&j).unwrap();
        assert_eq!(t, t2);
        let l = &t2.steps[0].layers[0];
        assert_eq!(l.act_bitmap, t.steps[0].layers[0].act_bitmap);
        // Derived scalars agree with the payload popcounts.
        assert!((l.act_sparsity - l.act_bitmap.as_ref().unwrap().sparsity()).abs() < 1e-12);
    }

    #[test]
    fn v2_format_still_saves_and_roundtrips() {
        let t = TraceFile { format: TraceFormat::V2, ..sample_payloads() };
        let j = t.to_json();
        assert_eq!(j.get("version").as_u64(), Some(2));
        let payload = j.get("steps").as_arr().unwrap()[0].get("layers").as_arr().unwrap()[0]
            .get("act_bitmap");
        assert!(matches!(payload.get("enc"), Json::Null), "v2 payloads carry no enc key");
        let t2 = TraceFile::from_json(&j).unwrap();
        assert_eq!(t, t2);
        // Same content under the two formats: payload maps identical,
        // fingerprints deliberately distinct (cache-key separation).
        let v3 = sample_payloads();
        let v3_rt = TraceFile::from_json(&v3.to_json()).unwrap();
        assert_eq!(t2.steps, v3_rt.steps);
        assert_ne!(t2.fingerprint(), v3_rt.fingerprint());
    }

    #[test]
    fn delta_encoding_kicks_in_across_correlated_steps() {
        // Step 1 repeats step 0's map with one bit flipped: the v3
        // encoder must choose the delta (a near-empty XOR) and the
        // decoder must chain it back bit-exactly.
        let shape = Shape::new(4, 8, 8);
        let mut rng = Pcg32::new(9);
        let act = Bitmap::sample(shape, 0.5, &mut rng);
        let grad = act.and(&Bitmap::sample(shape, 0.8, &mut rng));
        let mut act2 = act.clone();
        act2.set(0, 0, 0, !act2.get(0, 0, 0));
        let t = TraceFile {
            network: "agos_cnn".into(),
            steps: vec![
                StepTrace {
                    step: 0,
                    loss: 2.0,
                    layers: vec![LayerTrace::from_bitmaps("relu1", act, grad.clone())],
                },
                StepTrace {
                    step: 1,
                    loss: 1.9,
                    layers: vec![LayerTrace::from_bitmaps("relu1", act2, grad)],
                },
            ],
            format: TraceFormat::V3,
        };
        let j = t.to_json();
        let step1 = &j.get("steps").as_arr().unwrap()[1].get("layers").as_arr().unwrap()[0];
        assert_eq!(step1.get("act_bitmap").get("enc").as_str(), Some("delta"));
        // grad repeats exactly: the delta is all-zero runs.
        assert_eq!(step1.get("grad_bitmap").get("enc").as_str(), Some("delta"));
        let grad_words = step1.get("grad_bitmap").get("words").as_str().unwrap();
        assert_eq!(grad_words, "z4", "identical steps delta to a single zero run");
        assert_eq!(TraceFile::from_json(&j).unwrap(), t);
    }

    #[test]
    fn v1_files_still_load() {
        // A pre-payload file: no version key, no bitmap fields.
        let v1 = r#"{
            "network": "agos_cnn",
            "steps": [{"step": 0, "loss": 2.0, "layers": [
                {"name": "relu1", "act_sparsity": 0.5,
                 "grad_sparsity": 0.6, "identity_ok": true}
            ]}]
        }"#;
        let t = TraceFile::from_json(&Json::parse(v1).unwrap()).unwrap();
        assert_eq!(t.network, "agos_cnn");
        assert!(!t.has_bitmaps());
        assert_eq!(t.steps[0].layers[0].act_bitmap, None);
        assert_eq!(t.format, TraceFormat::V2, "v1 loads re-save as v2");
        // Unknown future revisions are rejected loudly.
        let v9 = r#"{"version": 9, "network": "x", "steps": []}"#;
        assert!(TraceFile::from_json(&Json::parse(v9).unwrap()).is_err());
        // v3-only encodings are rejected inside v2 files.
        let bad = r#"{"version": 2, "network": "x", "steps": [
            {"step": 0, "loss": 1.0, "layers": [
                {"name": "relu1", "act_sparsity": 0.0, "grad_sparsity": 0.0,
                 "identity_ok": true,
                 "act_bitmap": {"shape": [1, 1, 1], "enc": "rle", "words": "o1"}}
            ]}]}"#;
        let err = TraceFile::from_json(&Json::parse(bad).unwrap()).unwrap_err();
        assert!(format!("{err:#}").contains("v2"), "{err:#}");
    }

    #[test]
    fn corrupt_payload_errors_carry_step_and_layer_context() {
        let mut t = sample_payloads();
        t.format = TraceFormat::V3;
        let mut j = t.to_json();
        // Truncate the act payload of step 0 / relu1.
        let Json::Obj(top) = &mut j else { unreachable!() };
        let Json::Arr(steps) = top.get_mut("steps").unwrap() else { unreachable!() };
        let Json::Obj(s0) = &mut steps[0] else { unreachable!() };
        let Json::Arr(layers) = s0.get_mut("layers").unwrap() else { unreachable!() };
        let Json::Obj(l0) = &mut layers[0] else { unreachable!() };
        let Json::Obj(bm) = l0.get_mut("act_bitmap").unwrap() else { unreachable!() };
        bm.insert("words".into(), Json::Str("z1".into()));
        let err = TraceFile::from_json(&j).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("step 0"), "{msg}");
        assert!(msg.contains("relu1"), "{msg}");
        assert!(msg.contains("act_bitmap"), "{msg}");
        // Lenient: the payload drops with a warning, scalars survive.
        let (lenient, warnings) = TraceFile::from_json_lenient(&j).unwrap();
        assert_eq!(warnings.len(), 1, "{warnings:?}");
        assert!(warnings[0].contains("relu1"), "{warnings:?}");
        assert!(lenient.steps[0].layers[0].act_bitmap.is_none());
        assert!(lenient.steps[0].layers[0].grad_bitmap.is_some(), "grad survives");
        assert!((lenient.steps[0].layers[0].act_sparsity - t.steps[0].layers[0].act_sparsity)
            .abs()
            < 1e-12);
        // A measurement whose payload was dropped stays a measurement:
        // it must not fall out of the means the cosim model consumes.
        assert!(!lenient.steps[0].layers[0].footprint);
        assert!(lenient.mean_act_sparsity().contains_key("relu1"));
    }

    #[test]
    fn lenient_drop_breaks_later_delta_chains_loudly() {
        // Step 1's payload is corrupt and step 2 is a delta chained on
        // it: step 2 must drop too (own warning), never silently decode
        // against step 0's stale map.
        let j = Json::parse(
            r#"{
          "version": 3, "network": "x",
          "steps": [
            {"step": 0, "loss": 1.0, "layers": [{"name": "r", "act_sparsity": 0.0,
              "grad_sparsity": 0.0, "identity_ok": true,
              "act_bitmap": {"shape": [1, 1, 64], "enc": "rle", "words": "o1"}}]},
            {"step": 1, "loss": 1.0, "layers": [{"name": "r", "act_sparsity": 0.0,
              "grad_sparsity": 0.0, "identity_ok": true,
              "act_bitmap": {"shape": [1, 1, 64], "enc": "rle", "words": "qq"}}]},
            {"step": 2, "loss": 1.0, "layers": [{"name": "r", "act_sparsity": 0.0,
              "grad_sparsity": 0.0, "identity_ok": true,
              "act_bitmap": {"shape": [1, 1, 64], "enc": "delta", "words": "z1"}}]}
          ]}"#,
        )
        .unwrap();
        assert!(TraceFile::from_json(&j).is_err(), "strict mode still errors");
        let (t, warnings) = TraceFile::from_json_lenient(&j).unwrap();
        assert_eq!(warnings.len(), 2, "{warnings:?}");
        assert!(warnings[0].contains("step 1"), "{warnings:?}");
        assert!(
            warnings[1].contains("step 2") && warnings[1].contains("previous"),
            "{warnings:?}"
        );
        assert!(t.steps[0].layers[0].act_bitmap.is_some());
        assert!(t.steps[1].layers[0].act_bitmap.is_none());
        assert!(t.steps[2].layers[0].act_bitmap.is_none());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("agos_trace_test");
        let path = dir.join("t.json");
        let t = sample_payloads();
        t.save(&path).unwrap();
        assert_eq!(TraceFile::load(&path).unwrap(), t);
        // The same entry point round-trips the v4 binary container —
        // `load` sniffs the magic instead of parsing JSON.
        let v4 = TraceFile { format: TraceFormat::V4, ..t.clone() };
        let bin_path = dir.join("t.trace.bin");
        v4.save(&bin_path).unwrap();
        let bytes = std::fs::read(&bin_path).unwrap();
        assert_eq!(&bytes[..8], b"AGOSTRC\0");
        assert_eq!(TraceFile::load(&bin_path).unwrap(), v4);
        let (lenient, warnings) = TraceFile::load_lenient(&bin_path).unwrap();
        assert_eq!(lenient, v4);
        assert!(warnings.is_empty());
        // A JSON embed of a v4 trace downgrades to v3 payloads.
        assert_eq!(v4.to_json().get("version").as_u64(), Some(3));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn mean_sparsity_averages_steps() {
        let t = sample();
        let m = t.mean_act_sparsity();
        assert!((m["relu1"] - 0.6).abs() < 1e-12);
        assert!((m["relu2"] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn identity_flag_aggregates() {
        let mut t = sample();
        assert!(t.identity_holds());
        t.steps[0].layers[0].identity_ok = false;
        assert!(!t.identity_holds());
    }

    #[test]
    fn act_only_entries_model_post_add_footprints() {
        let shape = Shape::new(2, 4, 4);
        let mut rng = Pcg32::new(5);
        let post_add = Bitmap::sample(shape, 0.9, &mut rng); // near-dense
        let lt = LayerTrace::from_act("b1_add", post_add.clone());
        assert!(lt.identity_ok, "act-only entries satisfy identity trivially");
        assert!(lt.grad_bitmap.is_none());
        assert!(lt.footprint, "from_act marks the entry as layout data");
        assert!(!LayerTrace::scalar("r", 0.5, 0.5, true).footprint);
        assert!((lt.act_sparsity - post_add.sparsity()).abs() < 1e-12);
        let mut t = sample_payloads();
        let means_before = t.mean_act_sparsity();
        t.steps[0].layers.push(lt);
        let t2 = TraceFile::from_json(&t.to_json()).unwrap();
        assert_eq!(t, t2, "act-only payloads roundtrip like any other");
        assert!(t2.steps[0].layers[2].footprint, "marker survives the roundtrip");
        // Footprint entries are layout data, not measurements: the means
        // the measured model / cosim report consume must not see them.
        assert_eq!(t.mean_act_sparsity(), means_before);
        assert!(!t.mean_act_sparsity().contains_key("b1_add"));
    }

    #[test]
    fn trace_format_parses_and_tags() {
        for f in TraceFormat::ALL {
            assert_eq!(TraceFormat::parse(f.label()).unwrap(), f);
            assert_eq!(f.tag(), f.version());
        }
        assert_eq!(TraceFormat::parse("V3").unwrap(), TraceFormat::V3);
        assert_eq!(TraceFormat::parse("2").unwrap(), TraceFormat::V2);
        assert_eq!(TraceFormat::parse("bin").unwrap(), TraceFormat::V4);
        assert!(TraceFormat::parse("v9").is_err());
        assert_eq!(TraceFormat::default(), TraceFormat::V3);
    }

    #[test]
    fn fingerprint_tracks_scalars_payloads_and_format() {
        let base = sample();
        assert_eq!(base.fingerprint(), sample().fingerprint());
        let mut scalars = sample();
        scalars.steps[0].layers[1].act_sparsity = 0.41;
        assert_ne!(base.fingerprint(), scalars.fingerprint());
        // Different patterns with identical scalars: the payload must
        // separate them (the soundness gap the cosim cache key closes).
        let a = sample_payloads();
        let mut b = a.clone();
        let l = &mut b.steps[0].layers[0];
        let map = l.act_bitmap.as_mut().unwrap();
        map.set(0, 0, 0, !map.get(0, 0, 0));
        let scalar_clone = LayerTrace { act_bitmap: a.steps[0].layers[0].act_bitmap.clone(), ..l.clone() };
        assert_ne!(a.fingerprint(), b.fingerprint());
        // Sanity: restoring the payload restores the fingerprint.
        b.steps[0].layers[0] = scalar_clone;
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Same content, different on-disk format: keys must separate.
        let v2 = TraceFile { format: TraceFormat::V2, ..a.clone() };
        let v4 = TraceFile { format: TraceFormat::V4, ..a.clone() };
        assert_ne!(a.fingerprint(), v2.fingerprint());
        assert_ne!(a.fingerprint(), v4.fingerprint());
        assert_ne!(v2.fingerprint(), v4.fingerprint());
    }
}
