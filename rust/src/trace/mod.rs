//! Sparsity trace files: what the coordinator extracts from real training
//! through the AOT artifacts, persisted as JSON for the co-simulation
//! driver and the figures.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Per-layer measurement at one training step.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerTrace {
    /// ReLU layer name (matches the `nn::Network` layer names).
    pub name: String,
    /// Forward activation zero fraction.
    pub act_sparsity: f64,
    /// Backward gradient zero fraction (at the ReLU output).
    pub grad_sparsity: f64,
    /// Whether footprint(gradient) ⊆ footprint(activation) held exactly.
    pub identity_ok: bool,
}

/// One traced training step.
#[derive(Clone, Debug, PartialEq)]
pub struct StepTrace {
    pub step: usize,
    pub loss: f64,
    pub layers: Vec<LayerTrace>,
}

/// A whole training run's traces.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceFile {
    pub network: String,
    pub steps: Vec<StepTrace>,
}

impl TraceFile {
    pub fn new(network: &str) -> TraceFile {
        TraceFile { network: network.to_string(), steps: Vec::new() }
    }

    /// Mean activation sparsity per layer across all traced steps —
    /// the input to `SparsityModel::measured`.
    pub fn mean_act_sparsity(&self) -> std::collections::BTreeMap<String, f64> {
        let mut sums: std::collections::BTreeMap<String, (f64, usize)> = Default::default();
        for step in &self.steps {
            for l in &step.layers {
                let e = sums.entry(l.name.clone()).or_insert((0.0, 0));
                e.0 += l.act_sparsity;
                e.1 += 1;
            }
        }
        sums.into_iter().map(|(k, (s, n))| (k, s / n as f64)).collect()
    }

    /// Every step's identity check passed?
    pub fn identity_holds(&self) -> bool {
        self.steps.iter().all(|s| s.layers.iter().all(|l| l.identity_ok))
    }

    pub fn to_json(&self) -> Json {
        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|s| {
                let layers: Vec<Json> = s
                    .layers
                    .iter()
                    .map(|l| {
                        Json::from_pairs(vec![
                            ("name", l.name.as_str().into()),
                            ("act_sparsity", l.act_sparsity.into()),
                            ("grad_sparsity", l.grad_sparsity.into()),
                            ("identity_ok", l.identity_ok.into()),
                        ])
                    })
                    .collect();
                Json::from_pairs(vec![
                    ("step", s.step.into()),
                    ("loss", s.loss.into()),
                    ("layers", Json::Arr(layers)),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("network", self.network.as_str().into()),
            ("steps", Json::Arr(steps)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TraceFile> {
        let network = j.get("network").as_str().context("trace.network")?.to_string();
        let mut steps = Vec::new();
        for s in j.get("steps").as_arr().context("trace.steps")? {
            let mut layers = Vec::new();
            for l in s.get("layers").as_arr().context("step.layers")? {
                layers.push(LayerTrace {
                    name: l.get("name").as_str().context("layer.name")?.to_string(),
                    act_sparsity: l.get("act_sparsity").as_f64().context("act")?,
                    grad_sparsity: l.get("grad_sparsity").as_f64().context("grad")?,
                    identity_ok: l.get("identity_ok").as_bool().context("ok")?,
                });
            }
            steps.push(StepTrace {
                step: s.get("step").as_usize().context("step.step")?,
                loss: s.get("loss").as_f64().context("step.loss")?,
                layers,
            });
        }
        Ok(TraceFile { network, steps })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_json().write_file(path)
    }

    pub fn load(path: &Path) -> Result<TraceFile> {
        TraceFile::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TraceFile {
        TraceFile {
            network: "agos_cnn".into(),
            steps: vec![
                StepTrace {
                    step: 0,
                    loss: 2.3,
                    layers: vec![
                        LayerTrace {
                            name: "relu1".into(),
                            act_sparsity: 0.5,
                            grad_sparsity: 0.52,
                            identity_ok: true,
                        },
                        LayerTrace {
                            name: "relu2".into(),
                            act_sparsity: 0.4,
                            grad_sparsity: 0.4,
                            identity_ok: true,
                        },
                    ],
                },
                StepTrace {
                    step: 50,
                    loss: 1.1,
                    layers: vec![LayerTrace {
                        name: "relu1".into(),
                        act_sparsity: 0.7,
                        grad_sparsity: 0.71,
                        identity_ok: true,
                    }],
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = sample();
        let t2 = TraceFile::from_json(&t.to_json()).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("agos_trace_test");
        let path = dir.join("t.json");
        let t = sample();
        t.save(&path).unwrap();
        assert_eq!(TraceFile::load(&path).unwrap(), t);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn mean_sparsity_averages_steps() {
        let t = sample();
        let m = t.mean_act_sparsity();
        assert!((m["relu1"] - 0.6).abs() < 1e-12);
        assert!((m["relu2"] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn identity_flag_aggregates() {
        let mut t = sample();
        assert!(t.identity_holds());
        t.steps[0].layers[0].identity_ok = false;
        assert!(!t.identity_holds());
    }
}
