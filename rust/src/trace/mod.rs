//! Sparsity trace files: what the coordinator extracts from real training
//! through the AOT artifacts, persisted as JSON for the co-simulation
//! driver and the figures.
//!
//! Two on-disk revisions:
//!
//! * **v1** — scalar per-layer measurements only (name, activation /
//!   gradient zero fractions, identity flag). Files written before the
//!   bitmap-native pipeline carry no `version` key.
//! * **v2** — additionally carries optional *packed bitmaps* per ReLU
//!   layer per step: the within-channel zero footprints of the forward
//!   activation (Fig 7) and of the ReLU-masked gradient, encoded as
//!   `{shape: [c, h, w], words: "<hex u64 words>"}`. These are what
//!   `agos cosim --replay` feeds pattern-exactly into the exact backend
//!   (`sim::replay`). v1 files still load (payloads are simply absent).

use std::path::Path;

use anyhow::{Context, Result};

use crate::nn::Shape;
use crate::sparsity::Bitmap;
use crate::util::fnv::Fnv1a;
use crate::util::json::Json;

/// Current trace-file schema revision.
pub const TRACE_VERSION: u64 = 2;

/// Per-layer measurement at one training step.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerTrace {
    /// ReLU layer name (matches the `nn::Network` layer names).
    pub name: String,
    /// Forward activation zero fraction.
    pub act_sparsity: f64,
    /// Backward gradient zero fraction (at the ReLU output).
    pub grad_sparsity: f64,
    /// Whether footprint(gradient) ⊆ footprint(activation) held exactly.
    pub identity_ok: bool,
    /// v2: packed forward-activation zero footprint (the Fig 7 bitmap the
    /// forward pass leaves in DRAM), if captured.
    pub act_bitmap: Option<Bitmap>,
    /// v2: packed ReLU-masked gradient zero footprint, if captured.
    pub grad_bitmap: Option<Bitmap>,
}

impl LayerTrace {
    /// A scalar-only (v1-shaped) measurement.
    pub fn scalar(name: &str, act_sparsity: f64, grad_sparsity: f64, identity_ok: bool) -> LayerTrace {
        LayerTrace {
            name: name.to_string(),
            act_sparsity,
            grad_sparsity,
            identity_ok,
            act_bitmap: None,
            grad_bitmap: None,
        }
    }

    /// A v2 measurement with payloads: the scalar fields are *derived*
    /// from the maps (fractions from popcounts, identity from footprint
    /// containment), so scalars and patterns can never disagree.
    pub fn from_bitmaps(name: &str, act: Bitmap, grad: Bitmap) -> LayerTrace {
        LayerTrace {
            name: name.to_string(),
            act_sparsity: act.sparsity(),
            grad_sparsity: grad.sparsity(),
            identity_ok: grad.contained_in(&act),
            act_bitmap: Some(act),
            grad_bitmap: Some(grad),
        }
    }

    pub fn has_bitmaps(&self) -> bool {
        self.act_bitmap.is_some() || self.grad_bitmap.is_some()
    }
}

/// One traced training step.
#[derive(Clone, Debug, PartialEq)]
pub struct StepTrace {
    pub step: usize,
    pub loss: f64,
    pub layers: Vec<LayerTrace>,
}

/// A whole training run's traces.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceFile {
    pub network: String,
    pub steps: Vec<StepTrace>,
}

fn bitmap_to_json(b: &Bitmap) -> Json {
    Json::from_pairs(vec![
        (
            "shape",
            Json::Arr(vec![b.shape.c.into(), b.shape.h.into(), b.shape.w.into()]),
        ),
        ("words", b.encode_hex().into()),
    ])
}

fn bitmap_from_json(j: &Json, what: &str) -> Result<Option<Bitmap>> {
    if matches!(j, Json::Null) {
        return Ok(None);
    }
    let dims = j.get("shape").as_arr().with_context(|| format!("{what}.shape"))?;
    anyhow::ensure!(dims.len() == 3, "{what}.shape must be [c, h, w]");
    let dim = |i: usize| dims[i].as_usize().with_context(|| format!("{what}.shape[{i}]"));
    let shape = Shape::new(dim(0)?, dim(1)?, dim(2)?);
    let hex = j.get("words").as_str().with_context(|| format!("{what}.words"))?;
    Ok(Some(Bitmap::decode_hex(shape, hex).context(what.to_string())?))
}

impl TraceFile {
    pub fn new(network: &str) -> TraceFile {
        TraceFile { network: network.to_string(), steps: Vec::new() }
    }

    /// Mean activation sparsity per layer across all traced steps —
    /// the input to `SparsityModel::measured`.
    pub fn mean_act_sparsity(&self) -> std::collections::BTreeMap<String, f64> {
        let mut sums: std::collections::BTreeMap<String, (f64, usize)> = Default::default();
        for step in &self.steps {
            for l in &step.layers {
                let e = sums.entry(l.name.clone()).or_insert((0.0, 0));
                e.0 += l.act_sparsity;
                e.1 += 1;
            }
        }
        sums.into_iter().map(|(k, (s, n))| (k, s / n as f64)).collect()
    }

    /// Every step's identity check passed?
    pub fn identity_holds(&self) -> bool {
        self.steps.iter().all(|s| s.layers.iter().all(|l| l.identity_ok))
    }

    /// Does any step carry packed bitmap payloads (v2 content)?
    pub fn has_bitmaps(&self) -> bool {
        self.steps.iter().any(|s| s.layers.iter().any(|l| l.has_bitmaps()))
    }

    /// Stable content fingerprint over *everything* in the trace —
    /// network, per-step scalars and bitmap payloads. Folded into
    /// `SimOptions::fingerprint` by the cosim driver so two different
    /// trace files can never share a sweep-cache entry, even when their
    /// per-layer mean sparsities happen to coincide.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv1a::new();
        h.put_str(&self.network);
        h.put(self.steps.len() as u64);
        for s in &self.steps {
            h.put(s.step as u64).put_f64(s.loss);
            for l in &s.layers {
                h.put_str(&l.name)
                    .put_f64(l.act_sparsity)
                    .put_f64(l.grad_sparsity)
                    .put(l.identity_ok as u64);
                // Presence tags keep (None, Some(b)) and (Some(b), None)
                // from aliasing.
                match &l.act_bitmap {
                    Some(b) => h.put(1).put(b.fingerprint()),
                    None => h.put(0),
                };
                match &l.grad_bitmap {
                    Some(b) => h.put(1).put(b.fingerprint()),
                    None => h.put(0),
                };
            }
        }
        h.finish()
    }

    pub fn to_json(&self) -> Json {
        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|s| {
                let layers: Vec<Json> = s
                    .layers
                    .iter()
                    .map(|l| {
                        let mut j = Json::from_pairs(vec![
                            ("name", l.name.as_str().into()),
                            ("act_sparsity", l.act_sparsity.into()),
                            ("grad_sparsity", l.grad_sparsity.into()),
                            ("identity_ok", l.identity_ok.into()),
                        ]);
                        if let Some(b) = &l.act_bitmap {
                            j.set("act_bitmap", bitmap_to_json(b));
                        }
                        if let Some(b) = &l.grad_bitmap {
                            j.set("grad_bitmap", bitmap_to_json(b));
                        }
                        j
                    })
                    .collect();
                Json::from_pairs(vec![
                    ("step", s.step.into()),
                    ("loss", s.loss.into()),
                    ("layers", Json::Arr(layers)),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("version", TRACE_VERSION.into()),
            ("network", self.network.as_str().into()),
            ("steps", Json::Arr(steps)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TraceFile> {
        // v1 files predate the version key; absent means 1.
        let version = match j.get("version") {
            Json::Null => 1,
            v => v.as_u64().context("trace.version")?,
        };
        anyhow::ensure!(
            (1..=TRACE_VERSION).contains(&version),
            "unsupported trace version {version} (this build reads 1..={TRACE_VERSION})"
        );
        let network = j.get("network").as_str().context("trace.network")?.to_string();
        let mut steps = Vec::new();
        for s in j.get("steps").as_arr().context("trace.steps")? {
            let mut layers = Vec::new();
            for l in s.get("layers").as_arr().context("step.layers")? {
                layers.push(LayerTrace {
                    name: l.get("name").as_str().context("layer.name")?.to_string(),
                    act_sparsity: l.get("act_sparsity").as_f64().context("act")?,
                    grad_sparsity: l.get("grad_sparsity").as_f64().context("grad")?,
                    identity_ok: l.get("identity_ok").as_bool().context("ok")?,
                    act_bitmap: bitmap_from_json(l.get("act_bitmap"), "act_bitmap")?,
                    grad_bitmap: bitmap_from_json(l.get("grad_bitmap"), "grad_bitmap")?,
                });
            }
            steps.push(StepTrace {
                step: s.get("step").as_usize().context("step.step")?,
                loss: s.get("loss").as_f64().context("step.loss")?,
                layers,
            });
        }
        Ok(TraceFile { network, steps })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_json().write_file(path)
    }

    pub fn load(path: &Path) -> Result<TraceFile> {
        TraceFile::from_json(&Json::parse_file(path)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn sample() -> TraceFile {
        TraceFile {
            network: "agos_cnn".into(),
            steps: vec![
                StepTrace {
                    step: 0,
                    loss: 2.3,
                    layers: vec![
                        LayerTrace::scalar("relu1", 0.5, 0.52, true),
                        LayerTrace::scalar("relu2", 0.4, 0.4, true),
                    ],
                },
                StepTrace {
                    step: 50,
                    loss: 1.1,
                    layers: vec![LayerTrace::scalar("relu1", 0.7, 0.71, true)],
                },
            ],
        }
    }

    fn sample_v2() -> TraceFile {
        let shape = Shape::new(4, 6, 6);
        let mut rng = Pcg32::new(3);
        let act = Bitmap::sample(shape, 0.6, &mut rng);
        let keep = Bitmap::sample(shape, 0.8, &mut rng);
        let grad = act.and(&keep); // containment by construction
        let mut t = sample();
        t.steps[0].layers[0] = LayerTrace::from_bitmaps("relu1", act, grad);
        t
    }

    #[test]
    fn json_roundtrip() {
        let t = sample();
        let t2 = TraceFile::from_json(&t.to_json()).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn v2_payloads_roundtrip_bit_exact() {
        let t = sample_v2();
        assert!(t.has_bitmaps());
        assert!(t.identity_holds(), "containment-built grad must satisfy identity");
        let j = t.to_json();
        assert_eq!(j.get("version").as_u64(), Some(TRACE_VERSION));
        let t2 = TraceFile::from_json(&j).unwrap();
        assert_eq!(t, t2);
        let l = &t2.steps[0].layers[0];
        assert_eq!(l.act_bitmap, t.steps[0].layers[0].act_bitmap);
        // Derived scalars agree with the payload popcounts.
        assert!((l.act_sparsity - l.act_bitmap.as_ref().unwrap().sparsity()).abs() < 1e-12);
    }

    #[test]
    fn v1_files_still_load() {
        // A pre-payload file: no version key, no bitmap fields.
        let v1 = r#"{
            "network": "agos_cnn",
            "steps": [{"step": 0, "loss": 2.0, "layers": [
                {"name": "relu1", "act_sparsity": 0.5,
                 "grad_sparsity": 0.6, "identity_ok": true}
            ]}]
        }"#;
        let t = TraceFile::from_json(&Json::parse(v1).unwrap()).unwrap();
        assert_eq!(t.network, "agos_cnn");
        assert!(!t.has_bitmaps());
        assert_eq!(t.steps[0].layers[0].act_bitmap, None);
        // Unknown future revisions are rejected loudly.
        let v9 = r#"{"version": 9, "network": "x", "steps": []}"#;
        assert!(TraceFile::from_json(&Json::parse(v9).unwrap()).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("agos_trace_test");
        let path = dir.join("t.json");
        let t = sample_v2();
        t.save(&path).unwrap();
        assert_eq!(TraceFile::load(&path).unwrap(), t);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn mean_sparsity_averages_steps() {
        let t = sample();
        let m = t.mean_act_sparsity();
        assert!((m["relu1"] - 0.6).abs() < 1e-12);
        assert!((m["relu2"] - 0.4).abs() < 1e-12);
    }

    #[test]
    fn identity_flag_aggregates() {
        let mut t = sample();
        assert!(t.identity_holds());
        t.steps[0].layers[0].identity_ok = false;
        assert!(!t.identity_holds());
    }

    #[test]
    fn fingerprint_tracks_scalars_and_payloads() {
        let base = sample();
        assert_eq!(base.fingerprint(), sample().fingerprint());
        let mut scalars = sample();
        scalars.steps[0].layers[1].act_sparsity = 0.41;
        assert_ne!(base.fingerprint(), scalars.fingerprint());
        // Different patterns with identical scalars: the v2 payload must
        // separate them (the soundness gap the cosim cache key closes).
        let a = sample_v2();
        let mut b = a.clone();
        let l = &mut b.steps[0].layers[0];
        let map = l.act_bitmap.as_mut().unwrap();
        map.set(0, 0, 0, !map.get(0, 0, 0));
        let scalar_clone = LayerTrace { act_bitmap: a.steps[0].layers[0].act_bitmap.clone(), ..l.clone() };
        assert_ne!(a.fingerprint(), b.fingerprint());
        // Sanity: restoring the payload restores the fingerprint.
        b.steps[0].layers[0] = scalar_clone;
        assert_eq!(a.fingerprint(), b.fingerprint());
    }
}
