//! The resident service behind `agos serve`: a Unix-socket accept loop,
//! a fixed pool of connection handlers, and the shared warm state every
//! request reads through `Arc`s.
//!
//! Request documents (all fields beyond `cmd` optional unless noted):
//!
//! * `{"cmd": "ping"}` — resident-state counters.
//! * `{"cmd": "shutdown"}` — stop accepting, spill the sweep cache,
//!   exit the serve loop after responding.
//! * `{"cmd": "sweep", "networks": …, "schemes": …, "batch": …,
//!   "seed": …, "backend": …, "exact_cap": …, "pattern": …,
//!   "blob_radius": …, "gather": …}` — the `agos sweep` grid; the
//!   result document is byte-identical to `agos sweep --out`. With a
//!   `"scenario": <path>` field the request expands that scenario file
//!   instead (which then owns `networks`/`schemes`/`seed`), returning
//!   the `agos sweep --scenario --out` report byte-for-byte.
//! * `{"cmd": "cosim", "traces": <path> (required), "replay": bool,
//!   …backend fields…}` — the `agos cosim` report; byte-identical to
//!   `agos cosim --out`. The decoded trace (and its replay bank) stays
//!   resident keyed by content fingerprint.
//! * `{"cmd": "figure", "id": …}` / `{"cmd": "table", "id": …}` — the
//!   named report generators. A single-figure id returns that figure
//!   document directly (byte-identical to the cold CLI's `--out` file);
//!   multi-figure ids (`ablations`, `all`) return `{"figures": [...]}`.
//!   Optional `"traces"`/`"replay"`/`"scenario"` fields override the
//!   platform-comparison benchmarks exactly like the CLI flags.
//!
//! Warm-state lifetime: banks and gather plans live until the process
//! exits; the sweep cache is loaded from the configured spill at bind
//! time and merge-on-saved at shutdown (`SweepCache::save_file`), so a
//! server and stray one-shot CLIs can interleave without losing
//! entries. Requests whose trace file changed on disk (size or mtime)
//! re-decode and re-key automatically — a stale bank is unreachable
//! because the fingerprint is part of every cache key.

use std::collections::HashMap;
use std::io::BufReader;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::SystemTime;

use crate::config::{
    AcceleratorConfig, BitmapPattern, ExecBackend, GatherMode, Scheme, SimOptions,
};
use crate::coordinator::{cosim_prepared, PreparedCosim};
use crate::nn::zoo;
use crate::report::{benchmarks_from_scenario, benchmarks_from_trace, generate, ReportCtx};
use crate::scenario::{scenario_report_json, ScenarioFile};
use crate::sim::{sweep_report_json, GatherPlanCache, SweepCache, SweepPlan, SweepRunner};
use crate::sparsity::SparsityModel;
use crate::trace::TraceFile;
use crate::util::json::Json;

use super::dedup::Dedup;
use super::protocol::{canonical_key, err_response, ok_response, read_frame, write_frame};

/// How `Server::bind` configures the service.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Unix socket path to listen on.
    pub socket: PathBuf,
    /// Sweep worker threads per request (0 = all cores).
    pub jobs: usize,
    /// Concurrent connection handlers (0 = default 4).
    pub workers: usize,
    /// Sweep-cache spill to load at bind and merge-save at shutdown.
    pub cache_path: Option<PathBuf>,
}

/// The warm state every request shares. All mutation is behind interior
/// locks; the expensive members (`PreparedCosim` banks, cached sweep
/// results, gather plans) are immutable once built and shared by `Arc`.
pub struct ServeState {
    cfg: AcceleratorConfig,
    jobs: usize,
    socket: PathBuf,
    cache: Arc<SweepCache>,
    plans: Arc<GatherPlanCache>,
    /// Resident prepared traces, keyed by content fingerprint.
    banks: Mutex<HashMap<u64, Arc<PreparedCosim>>>,
    /// path → (len, mtime, fingerprint): skips re-decoding a trace file
    /// that has not changed since it was last prepared.
    trace_index: Mutex<HashMap<PathBuf, (u64, SystemTime, u64)>>,
    dedup: Dedup<Result<Json, String>>,
    requests: AtomicUsize,
    shutdown: AtomicBool,
}

impl ServeState {
    fn new(socket: PathBuf, jobs: usize) -> ServeState {
        ServeState {
            cfg: AcceleratorConfig::default(),
            // Resolve 0 = all cores once, like SweepRunner::new does.
            jobs: SweepRunner::new(jobs).jobs,
            socket,
            cache: Arc::new(SweepCache::new()),
            plans: Arc::new(GatherPlanCache::new()),
            banks: Mutex::new(HashMap::new()),
            trace_index: Mutex::new(HashMap::new()),
            dedup: Dedup::new(),
            requests: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        }
    }

    /// Resolved sweep thread budget per request.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// The resident sweep cache (shared with every request's runner).
    pub fn sweep_cache(&self) -> &Arc<SweepCache> {
        &self.cache
    }

    /// A runner wired to the resident cache — every served simulation
    /// goes through one of these.
    fn runner(&self) -> SweepRunner {
        SweepRunner::with_cache(self.jobs, self.cache.clone())
    }

    /// Sim options for a request document: `SimOptions::default()` with
    /// the same fields the CLI's flags override, so a served request and
    /// the equivalent cold invocation build identical option sets.
    fn opts_from(&self, req: &Json) -> anyhow::Result<SimOptions> {
        let mut opts =
            SimOptions { batch: req_usize(req, "batch", 16)?, ..SimOptions::default() };
        opts.seed = req_u64(req, "seed", opts.seed)?;
        if let Some(b) = req_str(req, "backend")? {
            opts.backend = ExecBackend::parse(b)?;
        }
        opts.exact_outputs_per_tile = req_usize(req, "exact_cap", opts.exact_outputs_per_tile)?;
        if let Some(p) = req_str(req, "pattern")? {
            opts.pattern = BitmapPattern::parse(p)?;
        }
        opts.blob_radius = req_usize(req, "blob_radius", opts.blob_radius)?;
        if let Some(g) = req_str(req, "gather")? {
            opts.gather = GatherMode::parse(g)?;
        }
        // The resident plan cache replaces the default fresh one —
        // execution strategy, not an input: never keyed, never serialized.
        opts.gather_plans = Some(self.plans.clone());
        Ok(opts)
    }

    /// The prepared (decoded + validated) form of a trace file, served
    /// from the resident banks when the file is unchanged on disk.
    fn prepared_for(&self, path: &Path) -> anyhow::Result<Arc<PreparedCosim>> {
        let meta = std::fs::metadata(path)
            .map_err(|e| anyhow::anyhow!("traces file {}: {e}", path.display()))?;
        let stamp = (meta.len(), meta.modified()?);
        if let Some((len, mtime, fp)) = self.trace_index.lock().unwrap().get(path) {
            if (*len, *mtime) == stamp {
                if let Some(prep) = self.banks.lock().unwrap().get(fp) {
                    return Ok(prep.clone());
                }
            }
        }
        let (traces, warnings) = TraceFile::load_lenient(path)?;
        for w in &warnings {
            eprintln!("serve: trace warning ({}): {w}", path.display());
        }
        // Decode the bank whenever payloads exist — a later request for
        // the same trace may want replay even if this one does not.
        let with_bank = traces.has_bitmaps();
        let prep = Arc::new(PreparedCosim::new_owned(traces, with_bank)?);
        let fp = prep.fingerprint();
        self.trace_index.lock().unwrap().insert(path.to_path_buf(), (stamp.0, stamp.1, fp));
        self.banks.lock().unwrap().insert(fp, prep.clone());
        Ok(prep)
    }

    fn handle_ping(&self) -> Json {
        let banks = self.banks.lock().unwrap();
        let resident: Vec<Json> = {
            let mut rows: Vec<(&u64, &Arc<PreparedCosim>)> = banks.iter().collect();
            rows.sort_by_key(|(fp, _)| **fp);
            rows.into_iter()
                .map(|(fp, p)| {
                    Json::from_pairs(vec![
                        ("fingerprint", format!("{fp:016x}").into()),
                        ("network", p.network().into()),
                        ("replay_words", p.bank().map_or(0, |b| b.resident_words()).into()),
                    ])
                })
                .collect()
        };
        Json::from_pairs(vec![
            ("service", "agos".into()),
            ("sim_rev", crate::sim::SIM_REVISION.into()),
            ("jobs", self.jobs.into()),
            ("requests", self.requests.load(Ordering::Relaxed).into()),
            ("dedup_led", self.dedup.led().into()),
            ("dedup_joined", self.dedup.joined().into()),
            (
                "sweep_cache",
                Json::from_pairs(vec![
                    ("entries", self.cache.len().into()),
                    ("hits", self.cache.hits().into()),
                    ("misses", self.cache.misses().into()),
                ]),
            ),
            ("gather_plans", self.plans.len().into()),
            ("banks", Json::Arr(resident)),
        ])
    }

    fn handle_sweep(&self, req: &Json) -> anyhow::Result<Json> {
        if let Some(path) = req_str(req, "scenario")? {
            // Mirrors `agos sweep --scenario`: the file owns the axes
            // these fields would bend, and the report is the same pure
            // function of (file, request knobs) the CLI writes.
            for owned in ["networks", "schemes", "seed"] {
                anyhow::ensure!(
                    matches!(req.get(owned), Json::Null),
                    "a scenario sweep owns '{owned}': the file is self-contained, edit it instead"
                );
            }
            let scenario = ScenarioFile::load(Path::new(path))?;
            let ex = scenario.expand(&self.cfg, &self.opts_from(req)?)?;
            let results = ex.run(&self.runner());
            return Ok(scenario_report_json(&ex, &results));
        }
        let nets = zoo::by_list(req_str(req, "networks")?.unwrap_or("all"))?;
        let schemes = Scheme::parse_list(req_str(req, "schemes")?.unwrap_or("all"))?;
        let opts = self.opts_from(req)?;
        let model = SparsityModel::synthetic(opts.seed);
        let plan = SweepPlan::grid(&nets, &schemes, &self.cfg, &opts);
        let results = self.runner().run(&plan, &model);
        Ok(sweep_report_json(&nets, &schemes, &results, &opts))
    }

    fn handle_cosim(&self, req: &Json) -> anyhow::Result<Json> {
        let path = req_str(req, "traces")?
            .ok_or_else(|| anyhow::anyhow!("cosim request needs a 'traces' path"))?;
        let replay = req_bool(req, "replay", false)?;
        let opts = self.opts_from(req)?;
        let prep = self.prepared_for(Path::new(path))?;
        if replay && !prep.has_bank() {
            anyhow::bail!(
                "trace file for '{}' carries no bitmap payloads to replay",
                prep.network()
            );
        }
        let report = cosim_prepared(&prep, &self.cfg, &opts, replay, &self.runner())?;
        Ok(report.to_json())
    }

    fn handle_figure(&self, req: &Json) -> anyhow::Result<Json> {
        let id = req_str(req, "id")?
            .ok_or_else(|| anyhow::anyhow!("figure/table request needs an 'id'"))?;
        let opts = self.opts_from(req)?;
        let model = SparsityModel::synthetic(opts.seed);
        let mut ctx = ReportCtx {
            cfg: self.cfg.clone(),
            opts,
            model,
            sweep: self.runner(),
            benchmarks: None,
        };
        // Platform-comparison benchmark overrides, mirroring the CLI's
        // `table --scenario/--traces/--replay` flags (table2/platforms).
        if let Some(path) = req_str(req, "scenario")? {
            anyhow::ensure!(
                matches!(req.get("traces"), Json::Null) && matches!(req.get("replay"), Json::Null),
                "'scenario' and 'traces'/'replay' are mutually exclusive"
            );
            anyhow::ensure!(
                matches!(req.get("seed"), Json::Null),
                "a scenario comparison owns 'seed': the file is self-contained, edit it instead"
            );
            let scenario = ScenarioFile::load(Path::new(path))?;
            let ex = scenario.expand(&self.cfg, &ctx.opts)?;
            ctx.benchmarks = Some(benchmarks_from_scenario(&ex));
        } else if let Some(path) = req_str(req, "traces")? {
            let replay = req_bool(req, "replay", false)?;
            let prep = self.prepared_for(Path::new(path))?;
            if replay && !prep.has_bank() {
                anyhow::bail!(
                    "trace file for '{}' carries no bitmap payloads to replay",
                    prep.network()
                );
            }
            ctx.benchmarks = Some(benchmarks_from_trace(&prep, &ctx.opts, replay)?);
        } else if req_bool(req, "replay", false)? {
            anyhow::bail!("'replay' needs a 'traces' path");
        }
        let figures = generate(id, &ctx)?;
        // A single-figure id returns the figure document itself — the
        // same bytes the cold CLI's `--out` writes — so `agos request
        // --out` diffs clean against `agos table/figure --out`.
        // Multi-figure ids (`ablations`, `all`) keep the list wrapper.
        if figures.len() == 1 {
            return Ok(figures[0].to_json());
        }
        Ok(Json::from_pairs(vec![(
            "figures",
            Json::Arr(figures.iter().map(|f| f.to_json()).collect()),
        )]))
    }

    /// Dispatch one request document to its handler. Compute commands
    /// run single-flight under the request's canonical key.
    fn handle(&self, req: &Json) -> Result<Json, String> {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let cmd = req.get("cmd").as_str().unwrap_or("").to_string();
        match cmd.as_str() {
            // Control commands answer immediately — they must not queue
            // behind (or join) a long computation.
            "ping" => Ok(self.handle_ping()),
            "shutdown" => {
                self.shutdown.store(true, Ordering::SeqCst);
                // Wake the accept loop so it observes the flag.
                let _ = UnixStream::connect(&self.socket);
                Ok(Json::from_pairs(vec![("shutting_down", true.into())]))
            }
            "sweep" | "cosim" | "figure" | "table" => {
                let key = canonical_key(req);
                self.dedup.run(&key, || {
                    let out = match cmd.as_str() {
                        "sweep" => self.handle_sweep(req),
                        "cosim" => self.handle_cosim(req),
                        _ => self.handle_figure(req),
                    };
                    out.map_err(|e| format!("{e:#}"))
                })
            }
            "" => Err("request document needs a string 'cmd' field".to_string()),
            other => Err(format!(
                "unknown cmd '{other}' (ping|shutdown|sweep|cosim|figure|table)"
            )),
        }
    }
}

/// Typed request-field accessors: absent fields take the default, but a
/// present field of the wrong type is a loud error, never a silent
/// fallback to something the caller did not ask for.
fn req_usize(req: &Json, key: &str, default: usize) -> anyhow::Result<usize> {
    match req.get(key) {
        Json::Null => Ok(default),
        v => v
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("request field '{key}' must be an unsigned integer")),
    }
}

fn req_u64(req: &Json, key: &str, default: u64) -> anyhow::Result<u64> {
    match req.get(key) {
        Json::Null => Ok(default),
        v => v
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("request field '{key}' must be an unsigned integer")),
    }
}

fn req_bool(req: &Json, key: &str, default: bool) -> anyhow::Result<bool> {
    match req.get(key) {
        Json::Null => Ok(default),
        v => v
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("request field '{key}' must be a boolean")),
    }
}

fn req_str<'a>(req: &'a Json, key: &str) -> anyhow::Result<Option<&'a str>> {
    match req.get(key) {
        Json::Null => Ok(None),
        v => v
            .as_str()
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("request field '{key}' must be a string")),
    }
}

/// One connection's session: frames in, enveloped frames out, until the
/// client closes or a fatal transport error.
fn handle_conn(state: &ServeState, stream: UnixStream) {
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(e) => {
            eprintln!("serve: connection clone failed: {e}");
            return;
        }
    };
    let mut writer = stream;
    loop {
        let req = match read_frame(&mut reader) {
            Ok(Some(req)) => req,
            Ok(None) => return, // clean end of session
            Err(e) => {
                let _ = write_frame(&mut writer, &err_response(&format!("bad frame: {e:#}")));
                return;
            }
        };
        let shutting_down = req.get("cmd").as_str() == Some("shutdown");
        let frame = match state.handle(&req) {
            Ok(result) => ok_response(result),
            Err(message) => err_response(&message),
        };
        if write_frame(&mut writer, &frame).is_err() || shutting_down {
            return;
        }
    }
}

/// A bound, not-yet-running service. `bind` completes socket setup, so
/// a caller (or a shell script backgrounding `agos serve`) can connect
/// the moment it returns; `run` serves until a `shutdown` request.
pub struct Server {
    listener: UnixListener,
    state: Arc<ServeState>,
    workers: usize,
    cache_path: Option<PathBuf>,
}

impl Server {
    /// Bind the socket and load the sweep-cache spill. A stale socket
    /// file (no listener behind it) is removed; a *live* one — another
    /// server accepting connections — is a refusal, not a takeover.
    pub fn bind(opts: ServeOptions) -> anyhow::Result<Server> {
        if opts.socket.exists() {
            anyhow::ensure!(
                UnixStream::connect(&opts.socket).is_err(),
                "{} already has a live server (shut it down first, or pick another --socket)",
                opts.socket.display()
            );
            std::fs::remove_file(&opts.socket)?;
        }
        if let Some(dir) = opts.socket.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let listener = UnixListener::bind(&opts.socket)
            .map_err(|e| anyhow::anyhow!("bind {}: {e}", opts.socket.display()))?;
        let state = Arc::new(ServeState::new(opts.socket.clone(), opts.jobs));
        if let Some(path) = &opts.cache_path {
            match state.cache.load_file(path) {
                Ok(n) if n > 0 => {
                    println!("serve: loaded {n} sweep results from {}", path.display())
                }
                Ok(_) => {}
                Err(e) => eprintln!("serve: ignoring sweep cache {}: {e}", path.display()),
            }
        }
        Ok(Server {
            listener,
            state,
            workers: if opts.workers == 0 { 4 } else { opts.workers },
            cache_path: opts.cache_path,
        })
    }

    pub fn socket(&self) -> &Path {
        &self.state.socket
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The shared warm state (counters, caches) — visible for tests and
    /// in-process embedding (the bench harness runs a server this way).
    pub fn state(&self) -> Arc<ServeState> {
        self.state.clone()
    }

    /// Serve until a `shutdown` request: accepted connections feed a
    /// fixed worker pool over a channel; each worker owns one connection
    /// at a time. On exit the socket file is removed and the sweep cache
    /// merge-saved to its spill.
    pub fn run(self) -> anyhow::Result<()> {
        let (tx, rx) = mpsc::channel::<UnixStream>();
        let rx = Arc::new(Mutex::new(rx));
        std::thread::scope(|scope| {
            for _ in 0..self.workers {
                let rx = rx.clone();
                let state = &self.state;
                scope.spawn(move || loop {
                    // Hold the receiver lock only while waiting: exactly
                    // one idle worker blocks in recv; the rest queue on
                    // the mutex. Handling happens after the guard drops.
                    let conn = { rx.lock().unwrap().recv() };
                    match conn {
                        Ok(conn) => handle_conn(state, conn),
                        Err(_) => return, // channel closed: shutting down
                    }
                });
            }
            loop {
                let conn = match self.listener.accept() {
                    Ok((conn, _)) => conn,
                    Err(e) => {
                        if self.state.shutdown.load(Ordering::SeqCst) {
                            break;
                        }
                        eprintln!("serve: accept failed: {e}");
                        continue;
                    }
                };
                // The shutdown handler connects once after setting the
                // flag, so a blocked accept always wakes to observe it.
                if self.state.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                if tx.send(conn).is_err() {
                    break;
                }
            }
            drop(tx); // workers drain queued connections, then exit
        });
        std::fs::remove_file(&self.state.socket).ok();
        if let Some(path) = &self.cache_path {
            if self.state.cache.misses() > 0 {
                match self.state.cache.save_file(path) {
                    Ok(()) => println!(
                        "serve: {} sweep results spilled to {}",
                        self.state.cache.len(),
                        path.display()
                    ),
                    Err(e) => eprintln!("serve: failed to spill {}: {e}", path.display()),
                }
            }
        }
        Ok(())
    }
}
