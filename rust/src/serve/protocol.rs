//! Length-framed JSON over a byte stream — the wire format of
//! `agos serve`.
//!
//! One frame is a u32-LE byte length followed by that many bytes of one
//! UTF-8 JSON document (the v4 trace container's framing idiom, with a
//! JSON body instead of a binary step record). Requests and responses
//! alternate on one connection; a client closing between frames is a
//! clean end of session, not an error.
//!
//! Responses are enveloped so transport success and request failure
//! stay distinguishable: `{"ok": true, "result": …}` or
//! `{"ok": false, "error": "…"}`.

use std::io::{Read, Write};

use crate::util::json::Json;

/// Upper bound on one frame's body (64 MiB). A corrupt or hostile
/// length prefix must bound the allocation it can trigger.
pub const MAX_FRAME: usize = 64 << 20;

/// Write one frame. Flushes, so a lone request/response is never stuck
/// in a buffering writer.
pub fn write_frame(w: &mut impl Write, doc: &Json) -> anyhow::Result<()> {
    let body = doc.dump().into_bytes();
    anyhow::ensure!(body.len() <= MAX_FRAME, "frame body {} exceeds {MAX_FRAME} bytes", body.len());
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&body)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary (the
/// peer ended the session). EOF *inside* a frame is an error.
pub fn read_frame(r: &mut impl Read) -> anyhow::Result<Option<Json>> {
    let mut len = [0u8; 4];
    if !read_exact_or_eof(r, &mut len)? {
        return Ok(None);
    }
    let n = u32::from_le_bytes(len) as usize;
    anyhow::ensure!(n <= MAX_FRAME, "frame length {n} exceeds {MAX_FRAME} bytes");
    let mut body = vec![0u8; n];
    r.read_exact(&mut body)?;
    let text = std::str::from_utf8(&body)
        .map_err(|e| anyhow::anyhow!("frame body is not UTF-8: {e}"))?;
    let doc = Json::parse(text).map_err(|e| anyhow::anyhow!("frame body is not JSON: {e}"))?;
    Ok(Some(doc))
}

/// Like `read_exact`, but distinguishes clean EOF before the first byte
/// (`Ok(false)`) from EOF mid-buffer (error).
fn read_exact_or_eof(r: &mut impl Read, buf: &mut [u8]) -> anyhow::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..])? {
            0 if got == 0 => return Ok(false),
            0 => anyhow::bail!("connection closed mid-frame ({got} of {} bytes)", buf.len()),
            n => got += n,
        }
    }
    Ok(true)
}

/// Success envelope around a `result` document.
pub fn ok_response(result: Json) -> Json {
    Json::from_pairs(vec![("ok", true.into()), ("result", result)])
}

/// Failure envelope around an error message.
pub fn err_response(message: &str) -> Json {
    Json::from_pairs(vec![("ok", false.into()), ("error", message.into())])
}

/// Canonical dedup key of a request: the compact dump of the *parsed*
/// document. Objects serialize in sorted key order, so two requests
/// differing only in field order or whitespace share a key — and join
/// one in-flight computation.
pub fn canonical_key(req: &Json) -> String {
    req.dump()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_roundtrip_and_eof_is_clean_between_frames() {
        let a = Json::from_pairs(vec![("cmd", "ping".into())]);
        let b = Json::from_pairs(vec![("cmd", "cosim".into()), ("batch", 2u64.into())]);
        let mut buf = Vec::new();
        write_frame(&mut buf, &a).unwrap();
        write_frame(&mut buf, &b).unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().unwrap().dump(), a.dump());
        assert_eq!(read_frame(&mut r).unwrap().unwrap().dump(), b.dump());
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF between frames");
    }

    #[test]
    fn truncation_and_hostile_lengths_are_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Json::from_pairs(vec![("cmd", "ping".into())])).unwrap();
        // EOF inside the body.
        let mut r = Cursor::new(buf[..buf.len() - 1].to_vec());
        assert!(read_frame(&mut r).is_err());
        // EOF inside the length prefix.
        let mut r = Cursor::new(vec![1u8, 0]);
        assert!(read_frame(&mut r).is_err());
        // A length prefix past MAX_FRAME must not allocate.
        let mut r = Cursor::new(u32::MAX.to_le_bytes().to_vec());
        assert!(read_frame(&mut r).is_err());
        // A body that is not JSON.
        let mut r = Cursor::new([4u32.to_le_bytes().to_vec(), b"!!!!".to_vec()].concat());
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn canonical_key_ignores_field_order_and_whitespace() {
        let a = Json::parse(r#"{"cmd": "cosim", "batch": 2}"#).unwrap();
        let b = Json::parse(r#"{ "batch":2,"cmd":"cosim" }"#).unwrap();
        assert_eq!(canonical_key(&a), canonical_key(&b));
        let c = Json::parse(r#"{"cmd": "cosim", "batch": 3}"#).unwrap();
        assert_ne!(canonical_key(&a), canonical_key(&c));
    }

    #[test]
    fn envelopes_tag_success_and_failure() {
        let ok = ok_response(Json::from_pairs(vec![("x", 1u64.into())]));
        assert_eq!(ok.get("ok").as_bool(), Some(true));
        assert_eq!(ok.get("result").get("x").as_u64(), Some(1));
        let err = err_response("boom");
        assert_eq!(err.get("ok").as_bool(), Some(false));
        assert_eq!(err.get("error").as_str(), Some("boom"));
    }
}
