//! `agos serve` — the resident sweep/replay service.
//!
//! Every one-shot CLI invocation re-pays process start, trace decode,
//! sweep-cache deserialization and gather-plan warm-up before the first
//! simulated cycle; on warm replayed runs that setup dominates
//! wall-time. The service keeps all of it resident behind `Arc`s —
//! decoded [`crate::coordinator::PreparedCosim`]s (replay banks) keyed
//! by trace fingerprint, the [`crate::sim::SweepCache`] backed by the
//! existing disk spill, and the shared [`crate::sim::GatherPlanCache`]
//! — and serves `sweep`/`cosim`/`figure`/`table` requests from a worker
//! pool over a Unix socket.
//!
//! Three contracts, all test-pinned:
//!
//! * **Byte identity** — a served response's `result` document is
//!   byte-identical to the file the equivalent cold CLI invocation
//!   writes with `--out`, at any `--jobs` level. Everything served goes
//!   through the same pure request→result core as the CLI
//!   ([`crate::coordinator::cosim_prepared`],
//!   [`crate::sim::sweep_report_json`]), and no report carries timing
//!   or thread-count fields.
//! * **Cache-key stability** — resident sharing changes *where* results
//!   live, never *what* keys them: the sweep-cache key scheme is
//!   untouched and `SIM_REVISION` stays at 6. See DESIGN.md "Resident
//!   service and shared banks".
//! * **In-flight dedup** — identical concurrent requests join one
//!   computation ([`Dedup`]) instead of racing; later identical
//!   requests are answered by the resident sweep cache.
//!
//! Wire format ([`protocol`]): u32-LE length-framed JSON documents —
//! the v4 trace container's framing idiom with a JSON body. The
//! server/client halves need Unix domain sockets and are compiled on
//! Unix only; the framing and dedup layers are platform-neutral.

pub mod protocol;

mod dedup;
pub use dedup::Dedup;

#[cfg(unix)]
mod server;
#[cfg(unix)]
pub use server::{ServeOptions, ServeState, Server};

#[cfg(unix)]
mod client;
#[cfg(unix)]
pub use client::Client;
