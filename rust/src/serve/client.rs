//! Client side of the serve protocol: connect, frame a request, unwrap
//! the response envelope. `agos request` is a thin CLI shell over this;
//! tests and the bench harness drive it in-process.

use std::io::ErrorKind;
use std::os::unix::net::UnixStream;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::util::json::Json;

use super::protocol::{read_frame, write_frame};

/// One connection to a running `agos serve`. Requests and responses
/// alternate on the stream; dropping the client ends the session.
pub struct Client {
    stream: UnixStream,
}

impl Client {
    pub fn connect(socket: &Path) -> anyhow::Result<Client> {
        let stream = UnixStream::connect(socket)
            .map_err(|e| anyhow::anyhow!("connect {}: {e}", socket.display()))?;
        Ok(Client { stream })
    }

    /// Connect, retrying while the socket does not exist or refuses —
    /// the window where `agos serve &` is still binding. Scripts can
    /// background the server and fire a request immediately.
    pub fn connect_retry(socket: &Path, timeout: Duration) -> anyhow::Result<Client> {
        let start = Instant::now();
        loop {
            match UnixStream::connect(socket) {
                Ok(stream) => return Ok(Client { stream }),
                Err(e) if retryable(&e) && start.elapsed() < timeout => {
                    std::thread::sleep(Duration::from_millis(50));
                }
                Err(e) => {
                    anyhow::bail!(
                        "connect {} (waited {:.1}s): {e}",
                        socket.display(),
                        start.elapsed().as_secs_f64()
                    )
                }
            }
        }
    }

    /// One request/response exchange; returns the raw response envelope.
    pub fn roundtrip(&mut self, req: &Json) -> anyhow::Result<Json> {
        write_frame(&mut self.stream, req)?;
        read_frame(&mut self.stream)?
            .ok_or_else(|| anyhow::anyhow!("server closed the connection mid-request"))
    }

    /// One exchange, unwrapped: the `result` document on success, the
    /// server's error message as this call's error otherwise.
    pub fn request(&mut self, req: &Json) -> anyhow::Result<Json> {
        let resp = self.roundtrip(req)?;
        match resp.get("ok").as_bool() {
            Some(true) => Ok(resp.get("result").clone()),
            Some(false) => {
                anyhow::bail!(
                    "server error: {}",
                    resp.get("error").as_str().unwrap_or("(no message)")
                )
            }
            None => anyhow::bail!("malformed response envelope: {}", resp.dump()),
        }
    }
}

/// Errors that mean "not up yet" rather than "never will be".
fn retryable(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        ErrorKind::NotFound | ErrorKind::ConnectionRefused | ErrorKind::ConnectionReset
    )
}
