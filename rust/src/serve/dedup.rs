//! In-flight request deduplication: identical concurrent requests join
//! one computation instead of racing.
//!
//! The sweep cache already guarantees a *later* identical request is
//! answered without re-simulating; this layer closes the remaining
//! window where two identical requests arrive while neither has
//! finished. The first caller under a key becomes the leader and
//! computes; every concurrent caller with the same key blocks on a
//! condvar and receives a clone of the leader's result. Slots are
//! removed on completion — longer-term memory belongs to the caches,
//! not this map.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// One in-flight computation: the leader fills `done`, joiners wait.
struct Slot<T> {
    done: Mutex<Option<T>>,
    cv: Condvar,
}

/// Keyed single-flight executor. `T` is cloned once per joiner; wrap
/// expensive results in `Arc` (or use a `Result<_, String>`) as needed.
#[derive(Default)]
pub struct Dedup<T> {
    inflight: Mutex<HashMap<String, Arc<Slot<T>>>>,
    led: AtomicUsize,
    joined: AtomicUsize,
}

impl<T: Clone> Dedup<T> {
    pub fn new() -> Dedup<T> {
        Dedup {
            inflight: Mutex::new(HashMap::new()),
            led: AtomicUsize::new(0),
            joined: AtomicUsize::new(0),
        }
    }

    /// Computations led (one per distinct in-flight key).
    pub fn led(&self) -> usize {
        self.led.load(Ordering::Relaxed)
    }

    /// Requests that joined an in-flight computation instead of
    /// recomputing.
    pub fn joined(&self) -> usize {
        self.joined.load(Ordering::Relaxed)
    }

    /// Run `f` under `key`, single-flight. A panicking `f` poisons the
    /// slot's joiners (they propagate the poison), so compute closures
    /// should return errors as values — the server wraps every handler
    /// in `Result<Json, String>`.
    pub fn run(&self, key: &str, f: impl FnOnce() -> T) -> T {
        let (slot, leader) = {
            let mut map = self.inflight.lock().unwrap();
            match map.get(key) {
                Some(slot) => (slot.clone(), false),
                None => {
                    let slot = Arc::new(Slot { done: Mutex::new(None), cv: Condvar::new() });
                    map.insert(key.to_string(), slot.clone());
                    (slot, true)
                }
            }
        };
        if leader {
            self.led.fetch_add(1, Ordering::Relaxed);
            let value = f();
            *slot.done.lock().unwrap() = Some(value.clone());
            slot.cv.notify_all();
            self.inflight.lock().unwrap().remove(key);
            value
        } else {
            self.joined.fetch_add(1, Ordering::Relaxed);
            let mut done = slot.done.lock().unwrap();
            while done.is_none() {
                done = slot.cv.wait(done).unwrap();
            }
            done.clone().expect("leader filled the slot before notifying")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn concurrent_identical_keys_share_one_computation() {
        let dedup = Arc::new(Dedup::<u64>::new());
        let computed = Arc::new(AtomicUsize::new(0));
        // The leader blocks inside f until we release it, guaranteeing
        // the second request arrives while the first is in flight.
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let leader = {
            let dedup = dedup.clone();
            let computed = computed.clone();
            thread::spawn(move || {
                dedup.run("k", || {
                    computed.fetch_add(1, Ordering::SeqCst);
                    release_rx.recv().unwrap();
                    42
                })
            })
        };
        // Wait until the leader is actually inside f.
        while computed.load(Ordering::SeqCst) == 0 {
            thread::yield_now();
        }
        let joiner = {
            let dedup = dedup.clone();
            let computed = computed.clone();
            thread::spawn(move || {
                dedup.run("k", || {
                    computed.fetch_add(1, Ordering::SeqCst);
                    7
                })
            })
        };
        // Wait until the joiner has registered, then release the leader.
        while dedup.joined() == 0 {
            thread::sleep(Duration::from_millis(1));
        }
        release_tx.send(()).unwrap();
        assert_eq!(leader.join().unwrap(), 42);
        assert_eq!(joiner.join().unwrap(), 42, "joiner receives the leader's result");
        assert_eq!(computed.load(Ordering::SeqCst), 1, "one computation for two requests");
        assert_eq!(dedup.led(), 1);
        assert_eq!(dedup.joined(), 1);
    }

    #[test]
    fn distinct_keys_and_later_requests_compute_independently() {
        let dedup = Dedup::<u64>::new();
        assert_eq!(dedup.run("a", || 1), 1);
        assert_eq!(dedup.run("b", || 2), 2);
        // Same key again after completion: the slot is gone, f runs.
        assert_eq!(dedup.run("a", || 3), 3);
        assert_eq!(dedup.led(), 3);
        assert_eq!(dedup.joined(), 0);
    }
}
