//! Threaded training pipeline: batch generation and trace analysis run on
//! worker threads so the PJRT execute loop never waits on either.
//!
//! Topology (std threads + mpsc channels; tokio is unavailable offline
//! and a simulator-bound workload gains nothing from an async runtime):
//!
//! ```text
//!   [producer] --batches--> [main: PJRT execute] --outputs--> [analyst]
//! ```

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::TrainOptions;
use crate::runtime::{HostTensor, Runtime};
use crate::trace::{StepTrace, TraceFile};

use super::dataset::SyntheticDataset;
use super::trainer::TrainLog;

/// Depth of the batch prefetch queue.
const PREFETCH: usize = 4;

/// Run training with prefetch + off-thread trace analysis.
pub fn run_training_pipeline(opts: &TrainOptions) -> Result<TrainLog> {
    let mut runtime = Runtime::load(&opts.artifacts_dir)
        .context("loading runtime (run `make artifacts` first)")?;
    let mut params = runtime.manifest.load_initial_params()?;
    let m = &runtime.manifest;
    let (img, in_ch, classes, batch) = (m.img, m.in_ch, m.num_classes, m.batch);
    let n_params = params.len();

    // --- producer: synthetic batches --------------------------------------
    let (batch_tx, batch_rx) = mpsc::sync_channel::<(HostTensor, HostTensor)>(PREFETCH);
    let steps = opts.steps;
    let seed = opts.seed;
    let producer = thread::spawn(move || {
        let mut ds = SyntheticDataset::new(img, in_ch, classes, seed);
        for _ in 0..steps + steps.div_ceil(1) {
            // (extra batches cover traced steps; surplus is dropped)
            if batch_tx.send(ds.batch(batch)).is_err() {
                break;
            }
        }
    });

    // --- analyst: sparsity extraction off the hot path --------------------
    let (trace_tx, trace_rx) = mpsc::channel::<(usize, f64, Vec<HostTensor>)>();
    let trace_images = opts.trace_images.clamp(1, batch.max(1));
    // The streaming sink (v4 bounded-memory capture) lives on the
    // analyst thread: steps are appended the moment they're extracted
    // and dropped, so neither the hot loop nor the analyst accumulates
    // the capture. Send order is step order, which is exactly the file
    // order the delta chain needs.
    let mut sink = super::trainer::open_stream_sink(opts, "agos_cnn")?;
    let analyst = thread::spawn(move || -> Result<(Vec<StepTrace>, usize)> {
        let mut out = Vec::new();
        let mut streamed = 0usize;
        while let Ok((step, loss, tensors)) = trace_rx.recv() {
            let relu_count = tensors.len() / 2;
            // Batch-wide identity per layer, once; see `Trainer::traced_step`.
            let batch_ok: Vec<bool> = (0..relu_count)
                .map(|i| {
                    super::trainer::batch_identity_ok(&tensors[i], &tensors[i + relu_count])
                        .expect("trace tensors are f32")
                })
                .collect();
            // One StepTrace per captured image (see `Trainer::traced_step`):
            // the replay bank round-robins the step axis, so multi-image
            // captures widen replay coverage with no format change.
            for image in 0..trace_images {
                let mut layers = Vec::with_capacity(relu_count);
                for i in 0..relu_count {
                    let a = &tensors[i];
                    let g = &tensors[i + relu_count];
                    layers.push(
                        super::trainer::layer_trace_for_image(
                            &format!("relu{}", i + 1),
                            a,
                            g,
                            image,
                            batch_ok[i],
                        )
                        .expect("trace tensors are f32"),
                    );
                }
                let trace = StepTrace { step, loss, layers };
                match &mut sink {
                    Some(w) => {
                        w.append(&trace)?;
                        streamed += 1;
                    }
                    None => out.push(trace),
                }
            }
        }
        if let Some(w) = sink {
            w.finish()?;
        }
        Ok((out, streamed))
    });

    // --- main loop: PJRT execution ----------------------------------------
    let mut log = TrainLog { traces: TraceFile::new("agos_cnn"), ..TrainLog::default() };
    log.traces.format = opts.trace_format;
    let t0 = Instant::now();
    for step in 0..opts.steps {
        if opts.trace_every > 0 && step % opts.trace_every == 0 {
            let (x, y) = batch_rx.recv().context("producer hung up")?;
            let mut inputs = params.clone();
            inputs.push(x);
            inputs.push(y);
            let out = runtime.run("step_traces", &inputs)?;
            let loss = out[0].as_f32()?[0] as f64;
            trace_tx
                .send((step, loss, out[1..].to_vec()))
                .ok();
        }
        let (x, y) = batch_rx.recv().context("producer hung up")?;
        let mut inputs = params.clone();
        inputs.push(x);
        inputs.push(y);
        let out = runtime.run("train_step", &inputs)?;
        let loss = out[n_params].as_f32()?[0] as f64;
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
        params = out[..n_params].to_vec();
        if step % opts.log_every == 0 || step + 1 == opts.steps {
            crate::info!("step {step:>5}  loss {loss:.4}");
            log.losses.push((step, loss));
        }
    }
    log.steps_per_sec = opts.steps as f64 / t0.elapsed().as_secs_f64();

    drop(batch_rx);
    drop(trace_tx);
    producer.join().ok();
    let (steps, streamed) = match analyst.join() {
        Ok(r) => r?,
        Err(_) => anyhow::bail!("trace analyst thread panicked"),
    };
    log.traces.steps = steps;
    log.streamed_steps = streamed;
    log.traces.steps.sort_by_key(|s| s.step);
    Ok(log)
}
