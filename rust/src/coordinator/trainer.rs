//! The training loop over the AOT `train_step`/`step_traces` artifacts.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::TrainOptions;
use crate::runtime::{HostTensor, Runtime};
use crate::trace::{LayerTrace, StepTrace, TraceFile};

use super::dataset::SyntheticDataset;

/// Record of a completed training run.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<(usize, f64)>,
    pub traces: TraceFile,
    pub steps_per_sec: f64,
}

/// Owns the runtime, parameters and dataset for one training run.
pub struct Trainer {
    runtime: Runtime,
    params: Vec<HostTensor>,
    dataset: SyntheticDataset,
    opts: TrainOptions,
}

impl Trainer {
    pub fn new(opts: TrainOptions) -> Result<Trainer> {
        let runtime = Runtime::load(&opts.artifacts_dir)
            .context("loading runtime (run `make artifacts` first)")?;
        let params = runtime.manifest.load_initial_params()?;
        let m = &runtime.manifest;
        anyhow::ensure!(
            m.batch > 0 && m.img > 0,
            "manifest hyperparameters incomplete"
        );
        let dataset = SyntheticDataset::new(m.img, m.in_ch, m.num_classes, opts.seed);
        Ok(Trainer { runtime, params, dataset, opts })
    }

    pub fn manifest_batch(&self) -> usize {
        self.runtime.manifest.batch
    }

    /// One SGD step; returns the loss.
    pub fn step(&mut self) -> Result<f64> {
        let batch = self.runtime.manifest.batch;
        let (x, y) = self.dataset.batch(batch);
        let n_params = self.params.len();
        let mut inputs = self.params.clone();
        inputs.push(x);
        inputs.push(y);
        let out = self.runtime.run("train_step", &inputs)?;
        let loss = out[n_params].as_f32()?[0] as f64;
        self.params = out[..n_params].to_vec();
        Ok(loss)
    }

    /// One traced step: returns (loss, per-relu traces) without updating
    /// parameters (the trace artifact is read-only on params).
    pub fn traced_step(&mut self, step: usize) -> Result<StepTrace> {
        let batch = self.runtime.manifest.batch;
        let (x, y) = self.dataset.batch(batch);
        let mut inputs = self.params.clone();
        inputs.push(x);
        inputs.push(y);
        let out = self.runtime.run("step_traces", &inputs)?;
        // outputs: loss, a1..a4, g1..g4
        let loss = out[0].as_f32()?[0] as f64;
        let relu_count = (out.len() - 1) / 2;
        let mut layers = Vec::with_capacity(relu_count);
        for i in 1..=relu_count {
            let a = &out[i];
            let g = &out[i + relu_count];
            let av = a.as_f32()?;
            let gv = g.as_f32()?;
            let identity_ok = av
                .iter()
                .zip(gv)
                .all(|(aa, gg)| *aa != 0.0 || *gg == 0.0);
            layers.push(LayerTrace {
                name: format!("relu{i}"),
                act_sparsity: a.zero_fraction(),
                grad_sparsity: g.zero_fraction(),
                identity_ok,
                // v2 payload: image 0's packed footprints (one image per
                // step keeps trace files small; steps are the batch axis
                // the replay path cycles over).
                act_bitmap: crate::runtime::bitmap_from_nhwc(a, 0),
                grad_bitmap: crate::runtime::bitmap_from_nhwc(g, 0),
            });
        }
        Ok(StepTrace { step, loss, layers })
    }

    /// Run the configured number of steps, tracing every
    /// `opts.trace_every` steps.
    pub fn run(&mut self) -> Result<TrainLog> {
        let mut log = TrainLog {
            traces: TraceFile::new("agos_cnn"),
            ..TrainLog::default()
        };
        let t0 = Instant::now();
        for step in 0..self.opts.steps {
            if self.opts.trace_every > 0 && step % self.opts.trace_every == 0 {
                let trace = self.traced_step(step)?;
                anyhow::ensure!(
                    trace.layers.iter().all(|l| l.identity_ok),
                    "sparsity identity violated at step {step}"
                );
                log.traces.steps.push(trace);
            }
            let loss = self.step()?;
            anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
            if step % self.opts.log_every == 0 || step + 1 == self.opts.steps {
                crate::info!("step {step:>5}  loss {loss:.4}");
                log.losses.push((step, loss));
            }
        }
        log.steps_per_sec = self.opts.steps as f64 / t0.elapsed().as_secs_f64();
        Ok(log)
    }
}
