//! The training loop over the AOT `train_step`/`step_traces` artifacts.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::TrainOptions;
use crate::runtime::{HostTensor, Runtime};
use crate::trace::{LayerTrace, StepTrace, TraceFile, TraceFormat, TraceWriter};

use super::dataset::SyntheticDataset;

/// Record of a completed training run.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub losses: Vec<(usize, f64)>,
    pub traces: TraceFile,
    pub steps_per_sec: f64,
    /// Steps appended to a streaming v4 sink (`TrainOptions::
    /// stream_path`) instead of `traces.steps` — in that mode the log
    /// holds no step payloads at all, which is the point: resident
    /// memory stays bounded by one step no matter how long the capture.
    pub streamed_steps: usize,
}

/// Open the streaming sink configured in `opts`, if any. Shared by the
/// blocking trainer and the threaded pipeline so both enforce the same
/// contract: streaming is a v4-container capability (the JSON
/// containers can only be written whole).
pub(crate) fn open_stream_sink(opts: &TrainOptions, network: &str) -> Result<Option<TraceWriter>> {
    match &opts.stream_path {
        Some(path) => {
            anyhow::ensure!(
                opts.trace_format == TraceFormat::V4,
                "streaming trace capture requires --trace-format v4 (got {})",
                opts.trace_format.label()
            );
            Ok(Some(TraceWriter::create(path, network)?))
        }
        None => Ok(None),
    }
}

/// Element-wise §3.2 identity over the *whole batch*: every gradient
/// non-zero sits on an activation non-zero. Checked once per layer per
/// traced step — the batch-wide invariant the trainer aborts on, which
/// must not narrow to just the captured image(s).
pub(crate) fn batch_identity_ok(a: &HostTensor, g: &HostTensor) -> Result<bool> {
    let av = a.as_f32()?;
    let gv = g.as_f32()?;
    Ok(av.iter().zip(gv).all(|(aa, gg)| *aa != 0.0 || *gg == 0.0))
}

/// One ReLU's trace entry for one image of a traced step: packed
/// per-image footprints when the tensors are 4-D (scalars derived from
/// the payloads, so they can never disagree), batch-level scalars as the
/// fallback for payload-less shapes. `batch_ok` is the batch-wide
/// identity verdict ([`batch_identity_ok`], computed once per layer) and
/// bounds the recorded flag: a violation anywhere in the batch marks the
/// trace bad even when the captured image happens to be clean. Shared by
/// the blocking trainer and the threaded pipeline's analyst.
pub(crate) fn layer_trace_for_image(
    name: &str,
    a: &HostTensor,
    g: &HostTensor,
    image: usize,
    batch_ok: bool,
) -> Result<LayerTrace> {
    let (ab, gb) = (
        crate::runtime::bitmap_from_nhwc(a, image),
        crate::runtime::bitmap_from_nhwc(g, image),
    );
    if let (Some(ab), Some(gb)) = (ab, gb) {
        let mut lt = LayerTrace::from_bitmaps(name, ab, gb);
        lt.identity_ok &= batch_ok;
        return Ok(lt);
    }
    Ok(LayerTrace {
        name: name.to_string(),
        act_sparsity: a.zero_fraction(),
        grad_sparsity: g.zero_fraction(),
        identity_ok: batch_ok,
        act_bitmap: None,
        grad_bitmap: None,
        footprint: false,
    })
}

/// Owns the runtime, parameters and dataset for one training run.
pub struct Trainer {
    runtime: Runtime,
    params: Vec<HostTensor>,
    dataset: SyntheticDataset,
    opts: TrainOptions,
}

impl Trainer {
    pub fn new(opts: TrainOptions) -> Result<Trainer> {
        let runtime = Runtime::load(&opts.artifacts_dir)
            .context("loading runtime (run `make artifacts` first)")?;
        let params = runtime.manifest.load_initial_params()?;
        let m = &runtime.manifest;
        anyhow::ensure!(
            m.batch > 0 && m.img > 0,
            "manifest hyperparameters incomplete"
        );
        let dataset = SyntheticDataset::new(m.img, m.in_ch, m.num_classes, opts.seed);
        Ok(Trainer { runtime, params, dataset, opts })
    }

    pub fn manifest_batch(&self) -> usize {
        self.runtime.manifest.batch
    }

    /// One SGD step; returns the loss.
    pub fn step(&mut self) -> Result<f64> {
        let batch = self.runtime.manifest.batch;
        let (x, y) = self.dataset.batch(batch);
        let n_params = self.params.len();
        let mut inputs = self.params.clone();
        inputs.push(x);
        inputs.push(y);
        let out = self.runtime.run("train_step", &inputs)?;
        let loss = out[n_params].as_f32()?[0] as f64;
        self.params = out[..n_params].to_vec();
        Ok(loss)
    }

    /// One traced step: returns (loss, per-relu traces) without updating
    /// parameters (the trace artifact is read-only on params). One
    /// `StepTrace` per captured image (`opts.trace_images`, clamped to
    /// the artifact batch): the trace file's step axis is exactly what
    /// the replay bank round-robins over, so multi-image captures widen
    /// replay coverage with no format change — and the extra steps fold
    /// into the trace fingerprint, keeping cache keys honest.
    pub fn traced_step(&mut self, step: usize) -> Result<Vec<StepTrace>> {
        let batch = self.runtime.manifest.batch;
        let (x, y) = self.dataset.batch(batch);
        let mut inputs = self.params.clone();
        inputs.push(x);
        inputs.push(y);
        let out = self.runtime.run("step_traces", &inputs)?;
        // outputs: loss, a1..a4, g1..g4
        let loss = out[0].as_f32()?[0] as f64;
        let relu_count = (out.len() - 1) / 2;
        let images = self.opts.trace_images.clamp(1, batch);
        // Batch-wide identity per layer, computed once and stamped into
        // every captured image's entry.
        let mut batch_ok = Vec::with_capacity(relu_count);
        for i in 1..=relu_count {
            batch_ok.push(batch_identity_ok(&out[i], &out[i + relu_count])?);
        }
        let mut steps = Vec::with_capacity(images);
        for image in 0..images {
            let mut layers = Vec::with_capacity(relu_count);
            for i in 1..=relu_count {
                let a = &out[i];
                let g = &out[i + relu_count];
                layers.push(layer_trace_for_image(
                    &format!("relu{i}"),
                    a,
                    g,
                    image,
                    batch_ok[i - 1],
                )?);
            }
            steps.push(StepTrace { step, loss, layers });
        }
        Ok(steps)
    }

    /// Run the configured number of steps, tracing every
    /// `opts.trace_every` steps. The trace file is stamped with the
    /// configured on-disk format (`--trace-format`, v3 delta/RLE by
    /// default), so `log.traces.save()` writes exactly what the CLI
    /// asked for. With `opts.stream_path` set (v4 only), every captured
    /// step is appended to the on-disk container the moment it exists
    /// and dropped — the run's resident trace memory is one step, not
    /// the whole capture. Post-Add footprints ride the same path: any
    /// act-only tensor pair the artifact exposes for an Add layer would
    /// land as a `LayerTrace::from_act` entry (the trained CNN is
    /// Add-free, so the synthetic capture is where that today
    /// materializes).
    pub fn run(&mut self) -> Result<TrainLog> {
        let mut log = TrainLog {
            traces: TraceFile::new("agos_cnn"),
            ..TrainLog::default()
        };
        log.traces.format = self.opts.trace_format;
        let mut sink = open_stream_sink(&self.opts, &log.traces.network)?;
        let t0 = Instant::now();
        for step in 0..self.opts.steps {
            if self.opts.trace_every > 0 && step % self.opts.trace_every == 0 {
                for trace in self.traced_step(step)? {
                    anyhow::ensure!(
                        trace.layers.iter().all(|l| l.identity_ok),
                        "sparsity identity violated at step {step}"
                    );
                    match &mut sink {
                        Some(w) => {
                            w.append(&trace)?;
                            log.streamed_steps += 1;
                        }
                        None => log.traces.steps.push(trace),
                    }
                }
            }
            let loss = self.step()?;
            anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
            if step % self.opts.log_every == 0 || step + 1 == self.opts.steps {
                crate::info!("step {step:>5}  loss {loss:.4}");
                log.losses.push((step, loss));
            }
        }
        if let Some(w) = sink {
            w.finish()?;
        }
        log.steps_per_sec = self.opts.steps as f64 / t0.elapsed().as_secs_f64();
        Ok(log)
    }
}
