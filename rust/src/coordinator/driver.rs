//! Co-simulation driver: feed *measured* sparsity traces from real
//! training into the accelerator simulator and report per-scheme
//! speedups — the end-to-end composition of all three layers.
//!
//! The driver honours `SimOptions::backend`: under the exact backend the
//! measured per-layer sparsity fractions are consumed as *sampled
//! bitmaps* (each image's per-tile operand/output patterns drawn from
//! its derived stream and drained through the cycle-accurate PE) rather
//! than as expected values. With `replay` requested, a v2 trace's packed
//! payloads drive the run instead (`sim::replay`): the exact backend
//! gathers each output's true receptive-field pattern, the analytic
//! backend substitutes measured per-tile densities for its stochastic
//! jitter — no RNG is involved for any layer that carries a payload.
//!
//! Cache soundness: the trace's content fingerprint is folded into the
//! options (and with it the sweep-cache key) *whether or not* replay is
//! on, so two different trace files for the same network can never share
//! a cache entry.
//!
//! The driver is split into a prepare step ([`PreparedCosim`]: decode +
//! validate once, immutable thereafter) and a pure request→result core
//! ([`cosim_prepared`]). The one-shot entry points compose the two; the
//! resident `agos serve` prepares once per trace file and serves the
//! core many times over shared banks — byte-identical by construction.

use std::sync::Arc;

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::nn::{zoo, Phase};
use crate::sim::{ReplayBank, SkipStats, SweepPlan, SweepRunner};
use crate::sparsity::SparsityModel;
use crate::trace::TraceFile;
use crate::util::json::Json;

/// Per-scheme results of co-simulating measured traces.
#[derive(Clone, Debug)]
pub struct CosimReport {
    pub network: String,
    /// Execution backend the rows were produced with ("analytic"/"exact").
    pub backend: String,
    /// Whether captured bitmap payloads were replayed pattern-exactly.
    pub replayed: bool,
    /// (scheme label, total cycles, BP cycles, energy J).
    pub rows: Vec<(String, f64, f64, f64)>,
    /// Speedup of IN+OUT+WR over dense, total / BP-only.
    pub total_speedup: f64,
    pub bp_speedup: f64,
    /// Measured mean activation sparsity fed to the model.
    pub mean_sparsity: f64,
    /// Gather-plan skip-effectiveness counters accumulated over this run
    /// (exact backend with a plan cache only). Diagnostics for humans:
    /// deliberately *not* serialized by `to_json` — the `--out` report
    /// must stay byte-identical whether plans/skip are on or off.
    pub skip: Option<SkipStats>,
}

impl CosimReport {
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(s, t, b, e)| {
                Json::from_pairs(vec![
                    ("scheme", s.as_str().into()),
                    ("total_cycles", (*t).into()),
                    ("bp_cycles", (*b).into()),
                    ("energy_j", (*e).into()),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("network", self.network.as_str().into()),
            ("backend", self.backend.as_str().into()),
            ("replayed", self.replayed.into()),
            ("rows", Json::Arr(rows)),
            ("total_speedup", self.total_speedup.into()),
            ("bp_speedup", self.bp_speedup.into()),
            ("mean_sparsity", self.mean_sparsity.into()),
        ])
    }
}

/// Run the simulator over the trace file's measured sparsity. With
/// `replay`, additionally resolve the trace's v2 bitmap payloads into a
/// `ReplayBank` so the backend consumes the captured patterns end to
/// end: the exact backend slices/gathers per-output patterns, the
/// analytic backend substitutes measured per-tile densities for its
/// stochastic jitter (the pattern-informed fast path). Requires a
/// payload-bearing trace. `jobs` sizes the sweep's worker pool
/// (0 = all cores) — results are bit-identical at any level.
pub fn cosim_from_traces(
    traces: &TraceFile,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    replay: bool,
    jobs: usize,
) -> anyhow::Result<CosimReport> {
    let prep = PreparedCosim::new(traces, replay)?;
    cosim_prepared(&prep, cfg, opts, replay, &SweepRunner::new(jobs))
}

/// [`cosim_from_traces`], *consuming* the trace: with `replay`, the
/// captured bitmaps move straight into the replay bank instead of being
/// cloned ([`ReplayBank::from_trace_owned`]) — the decode-into-bank path
/// a caller that just loaded the file (the CLI) should take, so a v4
/// binary load never holds two copies of the payload set.
pub fn cosim_from_traces_owned(
    traces: TraceFile,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    replay: bool,
    jobs: usize,
) -> anyhow::Result<CosimReport> {
    let prep = PreparedCosim::new_owned(traces, replay)?;
    cosim_prepared(&prep, cfg, opts, replay, &SweepRunner::new(jobs))
}

/// The decoded, validated, simulation-ready form of one trace file —
/// the unit `agos serve` keeps resident, keyed by trace fingerprint:
/// the resolved network, the measured per-layer sparsity means a
/// request's model is derived from, and (optionally) the decoded replay
/// bank behind an `Arc` so any number of concurrent requests share one
/// copy. Everything here is immutable once built; preparing once and
/// calling [`cosim_prepared`] many times is exactly equivalent to the
/// one-shot entry points.
#[derive(Clone, Debug)]
pub struct PreparedCosim {
    net: crate::nn::Network,
    measured: std::collections::BTreeMap<String, f64>,
    mean_sparsity: f64,
    fingerprint: u64,
    bank: Option<Arc<ReplayBank>>,
}

impl PreparedCosim {
    /// Validate and prepare, borrowing the trace (payloads are cloned
    /// into the bank when `with_bank`). Requires a payload-bearing trace
    /// when `with_bank`.
    pub fn new(traces: &TraceFile, with_bank: bool) -> anyhow::Result<PreparedCosim> {
        let mut prep = PreparedCosim::validate(traces)?;
        if with_bank {
            prep.bank = Some(Arc::new(ReplayBank::from_trace(&prep.net, traces)?));
        }
        Ok(prep)
    }

    /// Validate and prepare, consuming the trace: payloads move straight
    /// into the bank ([`ReplayBank::from_trace_owned`]), so a fresh v4
    /// binary load never holds two copies of the payload set.
    pub fn new_owned(traces: TraceFile, with_bank: bool) -> anyhow::Result<PreparedCosim> {
        let mut prep = PreparedCosim::validate(&traces)?;
        if with_bank {
            prep.bank = Some(Arc::new(ReplayBank::from_trace_owned(&prep.net, traces)?));
        }
        Ok(prep)
    }

    /// Trace validation + derived scalars shared by both constructors.
    fn validate(traces: &TraceFile) -> anyhow::Result<PreparedCosim> {
        anyhow::ensure!(!traces.steps.is_empty(), "trace file has no steps");
        anyhow::ensure!(
            traces.identity_holds(),
            "sparsity identity violated in traces — cannot exploit output sparsity"
        );
        let net = zoo::by_name(&traces.network)?;
        let measured = traces.mean_act_sparsity();
        let mean_sparsity = if measured.is_empty() {
            0.0
        } else {
            measured.values().sum::<f64>() / measured.len() as f64
        };
        Ok(PreparedCosim {
            net,
            measured,
            mean_sparsity,
            fingerprint: traces.fingerprint(),
            bank: None,
        })
    }

    pub fn network(&self) -> &str {
        &self.net.name
    }

    /// The resolved network the trace was captured on.
    pub fn net(&self) -> &crate::nn::Network {
        &self.net
    }

    /// Per-layer mean activation sparsity measured from the trace — the
    /// map a request's [`SparsityModel::measured`] is derived from.
    pub fn measured_sparsity(&self) -> &std::collections::BTreeMap<String, f64> {
        &self.measured
    }

    /// The trace's content fingerprint — the resident-bank key.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Whether a replay bank was decoded (payload-bearing trace).
    pub fn has_bank(&self) -> bool {
        self.bank.is_some()
    }

    /// The shared replay bank, when one was decoded.
    pub fn bank(&self) -> Option<&Arc<ReplayBank>> {
        self.bank.as_ref()
    }
}

/// The pure request→result core shared verbatim by the CLI one-shot
/// path and the `agos serve` loop: co-simulate one prepared trace under
/// one set of options on a caller-supplied runner (whose cache may be
/// private or resident/shared — results are identical either way, per
/// the sweep cache's key contract).
pub fn cosim_prepared(
    prep: &PreparedCosim,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    replay: bool,
    runner: &SweepRunner,
) -> anyhow::Result<CosimReport> {
    let bank = match (replay, &prep.bank) {
        (false, _) => None,
        (true, Some(bank)) => Some(bank.clone()),
        (true, None) => anyhow::bail!("trace was prepared without a replay bank"),
    };
    // The model is derived per request: it folds the *request's* seed
    // over the trace's measured means.
    let model = SparsityModel::measured(opts.seed, prep.measured.clone());
    // Fold the trace's *content* into the cache identity: different
    // trace files must never alias, even at identical per-layer means.
    let mut opts = opts.clone();
    opts.trace_fingerprint = Some(prep.fingerprint);
    opts.replay = bank;

    // All four schemes as one parallel sweep (results identical to the
    // sequential loop this replaced — see sim::sweep's determinism
    // contract).
    let plan = SweepPlan::grid(std::slice::from_ref(&prep.net), &Scheme::ALL, cfg, &opts);
    // Snapshot the plan cache's lifetime counters around the sweep so the
    // report carries only *this run's* delta (the cache is shared and
    // long-lived by design).
    let skip_before = opts.gather_plans.as_ref().map(|c| c.stats());
    let results = runner.run(&plan, &model);
    let skip = match (&opts.gather_plans, skip_before) {
        (Some(cache), Some(before)) => Some(cache.stats().delta_from(&before)),
        _ => None,
    };

    let mut rows = Vec::new();
    let mut dense_total = 0.0;
    let mut dense_bp = 0.0;
    let mut wr_total = 0.0;
    let mut wr_bp = 0.0;
    for (scheme, r) in Scheme::ALL.into_iter().zip(&results) {
        let total = r.total_cycles();
        let bp = r.phase(Phase::Backward).cycles;
        if scheme == Scheme::Dense {
            dense_total = total;
            dense_bp = bp;
        }
        if scheme == Scheme::InOutWr {
            wr_total = total;
            wr_bp = bp;
        }
        rows.push((scheme.label().to_string(), total, bp, r.total_energy_j()));
    }
    Ok(CosimReport {
        network: prep.net.name.clone(),
        backend: opts.backend.label().to_string(),
        replayed: opts.replay.is_some(),
        rows,
        total_speedup: dense_total / wr_total,
        bp_speedup: dense_bp / wr_bp,
        mean_sparsity: prep.mean_sparsity,
        skip,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecBackend;
    use crate::trace::{LayerTrace, StepTrace};

    fn fake_traces(sparsity: f64) -> TraceFile {
        TraceFile {
            network: "agos_cnn".into(),
            steps: vec![StepTrace {
                step: 0,
                loss: 2.0,
                layers: (1..=4)
                    .map(|i| LayerTrace::scalar(&format!("relu{i}"), sparsity, sparsity, true))
                    .collect(),
            }],
            ..TraceFile::default()
        }
    }

    #[test]
    fn cosim_produces_speedup_from_measured_sparsity() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 2, ..SimOptions::default() };
        let report = cosim_from_traces(&fake_traces(0.5), &cfg, &opts, false, 0).unwrap();
        assert_eq!(report.rows.len(), 4);
        assert!(!report.replayed);
        assert!(report.total_speedup > 1.1, "{}", report.total_speedup);
        assert!(report.bp_speedup > 1.2, "{}", report.bp_speedup);
        assert!((report.mean_sparsity - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cosim_exact_backend_consumes_measured_sparsity_as_bitmaps() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions {
            batch: 1,
            backend: ExecBackend::Exact,
            exact_outputs_per_tile: 16,
            ..SimOptions::default()
        };
        let report = cosim_from_traces(&fake_traces(0.5), &cfg, &opts, false, 0).unwrap();
        assert_eq!(report.backend, "exact");
        assert_eq!(report.rows.len(), 4);
        assert!(report.total_speedup > 1.1, "{}", report.total_speedup);
        assert!(report.bp_speedup > 1.2, "{}", report.bp_speedup);
        assert_eq!(report.to_json().get("backend").as_str(), Some("exact"));
        // Deterministic: the same traces + options reproduce bit-exactly.
        let again = cosim_from_traces(&fake_traces(0.5), &cfg, &opts, false, 0).unwrap();
        for (a, b) in report.rows.iter().zip(&again.rows) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn cosim_replays_captured_patterns_end_to_end() {
        use crate::nn::zoo;
        use crate::sparsity::{capture_synthetic_trace, SparsityModel};
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions {
            batch: 2,
            backend: ExecBackend::Exact,
            exact_outputs_per_tile: 16,
            ..SimOptions::default()
        };
        let traces = capture_synthetic_trace(
            &zoo::agos_cnn(),
            &SparsityModel::synthetic(opts.seed),
            2,
            crate::config::BitmapPattern::Iid,
            2,
        );
        let report = cosim_from_traces(&traces, &cfg, &opts, true, 0).unwrap();
        assert!(report.replayed);
        assert_eq!(report.backend, "exact");
        assert!(report.bp_speedup > 1.2, "{}", report.bp_speedup);
        assert_eq!(report.to_json().get("replayed").as_bool(), Some(true));
        // The default plan cache was exercised and its counters surfaced —
        // but never serialized (the --out report is plan-invariant).
        let skip = report.skip.expect("plan cache on by default");
        assert!(skip.words_gathered > 0, "{skip:?}");
        assert!(!report.to_json().dump().contains("skip"));
        // Plans off: same rows, no counters.
        let off = SimOptions { gather_plans: None, ..opts.clone() };
        let off_report = cosim_from_traces(&traces, &cfg, &off, true, 0).unwrap();
        assert!(off_report.skip.is_none());
        assert_eq!(report.rows, off_report.rows, "plans must not change a cycle");
        assert_eq!(report.to_json().dump(), off_report.to_json().dump());
        // Replay is deterministic end to end, at any jobs level.
        let again = cosim_from_traces(&traces, &cfg, &opts, true, 0).unwrap();
        assert_eq!(report.rows, again.rows);
        let j1 = cosim_from_traces(&traces, &cfg, &opts, true, 1).unwrap();
        let j4 = cosim_from_traces(&traces, &cfg, &opts, true, 4).unwrap();
        assert_eq!(j1.rows, j4.rows, "replay must be jobs-invariant");
        assert_eq!(report.rows, j1.rows);
        // The pattern-informed analytic fast path replays too: measured
        // per-tile densities instead of stochastic jitter.
        let analytic = SimOptions { backend: ExecBackend::Analytic, ..opts.clone() };
        let ar = cosim_from_traces(&traces, &cfg, &analytic, true, 0).unwrap();
        assert!(ar.replayed);
        assert_eq!(ar.backend, "analytic");
        assert!(ar.bp_speedup > 1.2, "{}", ar.bp_speedup);
        // …and it lands near the exact replay on this validated-CRS stack.
        for ((_, at, _, _), (_, et, _, _)) in ar.rows.iter().zip(&report.rows) {
            let err = (at - et).abs() / et;
            assert!(err < 0.35, "analytic-replay {at:.0} vs exact-replay {et:.0}");
        }
        // The consuming entry point (decode-into-bank, no payload clones)
        // is row-identical to the borrowing one.
        let owned = cosim_from_traces_owned(traces.clone(), &cfg, &opts, true, 0).unwrap();
        assert_eq!(report.rows, owned.rows, "owned bank must match borrowed bank");
        assert_eq!(report.to_json().dump(), owned.to_json().dump());
        // A payload-free trace cannot replay on either backend.
        assert!(cosim_from_traces(&fake_traces(0.5), &cfg, &opts, true, 0).is_err());
        assert!(cosim_from_traces(&fake_traces(0.5), &cfg, &analytic, true, 0).is_err());
    }

    #[test]
    fn prepared_cosim_matches_one_shot_and_shares_a_cache() {
        use crate::nn::zoo;
        use crate::sim::SweepCache;
        use crate::sparsity::capture_synthetic_trace;
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions {
            batch: 2,
            backend: ExecBackend::Exact,
            exact_outputs_per_tile: 16,
            ..SimOptions::default()
        };
        let traces = capture_synthetic_trace(
            &zoo::agos_cnn(),
            &SparsityModel::synthetic(opts.seed),
            2,
            crate::config::BitmapPattern::Iid,
            2,
        );
        let one_shot = cosim_from_traces(&traces, &cfg, &opts, true, 1).unwrap();
        let prep = PreparedCosim::new(&traces, true).unwrap();
        assert!(prep.has_bank());
        assert_eq!(prep.network(), "agos_cnn");
        assert_eq!(prep.fingerprint(), traces.fingerprint());
        // The same prepared state served twice over one shared cache —
        // the serve loop in miniature: both responses byte-identical to
        // the cold one-shot run.
        let cache = Arc::new(SweepCache::new());
        let r1 =
            cosim_prepared(&prep, &cfg, &opts, true, &SweepRunner::with_cache(2, cache.clone()))
                .unwrap();
        let r2 =
            cosim_prepared(&prep, &cfg, &opts, true, &SweepRunner::with_cache(2, cache.clone()))
                .unwrap();
        assert_eq!(one_shot.to_json().dump(), r1.to_json().dump());
        assert_eq!(r1.to_json().dump(), r2.to_json().dump());
        // The second serving was pure cache: nothing re-simulated.
        assert_eq!(cache.misses(), 4);
        assert_eq!(cache.hits(), 4);
        // Replay against a bank-less preparation is a loud error.
        let no_bank = PreparedCosim::new(&traces, false).unwrap();
        assert!(!no_bank.has_bank());
        assert!(cosim_prepared(&no_bank, &cfg, &opts, true, &SweepRunner::new(1)).is_err());
    }

    #[test]
    fn more_sparsity_more_speedup() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 2, ..SimOptions::default() };
        let lo = cosim_from_traces(&fake_traces(0.3), &cfg, &opts, false, 0).unwrap();
        let hi = cosim_from_traces(&fake_traces(0.7), &cfg, &opts, false, 0).unwrap();
        assert!(hi.total_speedup > lo.total_speedup);
    }

    #[test]
    fn empty_or_violating_traces_rejected() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions::default();
        let empty = TraceFile::new("agos_cnn");
        assert!(cosim_from_traces(&empty, &cfg, &opts, false, 0).is_err());
        let mut bad = fake_traces(0.5);
        bad.steps[0].layers[0].identity_ok = false;
        assert!(cosim_from_traces(&bad, &cfg, &opts, false, 0).is_err());
    }

    #[test]
    fn report_serializes() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 1, ..SimOptions::default() };
        let report = cosim_from_traces(&fake_traces(0.4), &cfg, &opts, false, 0).unwrap();
        let j = report.to_json();
        assert_eq!(j.get("network").as_str(), Some("agos_cnn"));
        assert_eq!(j.get("rows").as_arr().unwrap().len(), 4);
        assert_eq!(j.get("replayed").as_bool(), Some(false));
    }
}
