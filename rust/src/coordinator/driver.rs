//! Co-simulation driver: feed *measured* sparsity traces from real
//! training into the accelerator simulator and report per-scheme
//! speedups — the end-to-end composition of all three layers.
//!
//! The driver honours `SimOptions::backend`: under the exact backend the
//! measured per-layer sparsity fractions are consumed as *sampled
//! bitmaps* (each image's per-tile operand/output patterns drawn from
//! its derived stream and drained through the cycle-accurate PE) rather
//! than as expected values. With `replay` requested, a v2 trace's packed
//! payloads drive the run instead (`sim::replay`): the exact backend
//! gathers each output's true receptive-field pattern, the analytic
//! backend substitutes measured per-tile densities for its stochastic
//! jitter — no RNG is involved for any layer that carries a payload.
//!
//! Cache soundness: the trace's content fingerprint is folded into the
//! options (and with it the sweep-cache key) *whether or not* replay is
//! on, so two different trace files for the same network can never share
//! a cache entry.

use std::sync::Arc;

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::nn::{zoo, Phase};
use crate::sim::{ReplayBank, SkipStats, SweepPlan, SweepRunner};
use crate::sparsity::SparsityModel;
use crate::trace::TraceFile;
use crate::util::json::Json;

/// Per-scheme results of co-simulating measured traces.
#[derive(Clone, Debug)]
pub struct CosimReport {
    pub network: String,
    /// Execution backend the rows were produced with ("analytic"/"exact").
    pub backend: String,
    /// Whether captured bitmap payloads were replayed pattern-exactly.
    pub replayed: bool,
    /// (scheme label, total cycles, BP cycles, energy J).
    pub rows: Vec<(String, f64, f64, f64)>,
    /// Speedup of IN+OUT+WR over dense, total / BP-only.
    pub total_speedup: f64,
    pub bp_speedup: f64,
    /// Measured mean activation sparsity fed to the model.
    pub mean_sparsity: f64,
    /// Gather-plan skip-effectiveness counters accumulated over this run
    /// (exact backend with a plan cache only). Diagnostics for humans:
    /// deliberately *not* serialized by `to_json` — the `--out` report
    /// must stay byte-identical whether plans/skip are on or off.
    pub skip: Option<SkipStats>,
}

impl CosimReport {
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|(s, t, b, e)| {
                Json::from_pairs(vec![
                    ("scheme", s.as_str().into()),
                    ("total_cycles", (*t).into()),
                    ("bp_cycles", (*b).into()),
                    ("energy_j", (*e).into()),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("network", self.network.as_str().into()),
            ("backend", self.backend.as_str().into()),
            ("replayed", self.replayed.into()),
            ("rows", Json::Arr(rows)),
            ("total_speedup", self.total_speedup.into()),
            ("bp_speedup", self.bp_speedup.into()),
            ("mean_sparsity", self.mean_sparsity.into()),
        ])
    }
}

/// Run the simulator over the trace file's measured sparsity. With
/// `replay`, additionally resolve the trace's v2 bitmap payloads into a
/// `ReplayBank` so the backend consumes the captured patterns end to
/// end: the exact backend slices/gathers per-output patterns, the
/// analytic backend substitutes measured per-tile densities for its
/// stochastic jitter (the pattern-informed fast path). Requires a
/// payload-bearing trace. `jobs` sizes the sweep's worker pool
/// (0 = all cores) — results are bit-identical at any level.
pub fn cosim_from_traces(
    traces: &TraceFile,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    replay: bool,
    jobs: usize,
) -> anyhow::Result<CosimReport> {
    let (net, model, mean_sparsity, fingerprint) = cosim_setup(traces, opts)?;
    let bank = replay
        .then(|| ReplayBank::from_trace(&net, traces).map(Arc::new))
        .transpose()?;
    cosim_core(net, model, mean_sparsity, fingerprint, bank, cfg, opts, jobs)
}

/// [`cosim_from_traces`], *consuming* the trace: with `replay`, the
/// captured bitmaps move straight into the replay bank instead of being
/// cloned ([`ReplayBank::from_trace_owned`]) — the decode-into-bank path
/// a caller that just loaded the file (the CLI) should take, so a v4
/// binary load never holds two copies of the payload set.
pub fn cosim_from_traces_owned(
    traces: TraceFile,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    replay: bool,
    jobs: usize,
) -> anyhow::Result<CosimReport> {
    let (net, model, mean_sparsity, fingerprint) = cosim_setup(&traces, opts)?;
    let bank = replay
        .then(|| ReplayBank::from_trace_owned(&net, traces).map(Arc::new))
        .transpose()?;
    cosim_core(net, model, mean_sparsity, fingerprint, bank, cfg, opts, jobs)
}

/// Validation + model derivation shared by both entry points.
fn cosim_setup(
    traces: &TraceFile,
    opts: &SimOptions,
) -> anyhow::Result<(crate::nn::Network, SparsityModel, f64, u64)> {
    anyhow::ensure!(!traces.steps.is_empty(), "trace file has no steps");
    anyhow::ensure!(
        traces.identity_holds(),
        "sparsity identity violated in traces — cannot exploit output sparsity"
    );
    let net = zoo::by_name(&traces.network)?;
    let measured = traces.mean_act_sparsity();
    let mean_sparsity = if measured.is_empty() {
        0.0
    } else {
        measured.values().sum::<f64>() / measured.len() as f64
    };
    let model = SparsityModel::measured(opts.seed, measured);
    Ok((net, model, mean_sparsity, traces.fingerprint()))
}

#[allow(clippy::too_many_arguments)]
fn cosim_core(
    net: crate::nn::Network,
    model: SparsityModel,
    mean_sparsity: f64,
    fingerprint: u64,
    bank: Option<Arc<ReplayBank>>,
    cfg: &AcceleratorConfig,
    opts: &SimOptions,
    jobs: usize,
) -> anyhow::Result<CosimReport> {
    // Fold the trace's *content* into the cache identity: different
    // trace files must never alias, even at identical per-layer means.
    let mut opts = opts.clone();
    opts.trace_fingerprint = Some(fingerprint);
    opts.replay = bank;

    // All four schemes as one parallel sweep (results identical to the
    // sequential loop this replaced — see sim::sweep's determinism
    // contract).
    let runner = SweepRunner::new(jobs);
    let plan = SweepPlan::grid(std::slice::from_ref(&net), &Scheme::ALL, cfg, &opts);
    // Snapshot the plan cache's lifetime counters around the sweep so the
    // report carries only *this run's* delta (the cache is shared and
    // long-lived by design).
    let skip_before = opts.gather_plans.as_ref().map(|c| c.stats());
    let results = runner.run(&plan, &model);
    let skip = match (&opts.gather_plans, skip_before) {
        (Some(cache), Some(before)) => Some(cache.stats().delta_from(&before)),
        _ => None,
    };

    let mut rows = Vec::new();
    let mut dense_total = 0.0;
    let mut dense_bp = 0.0;
    let mut wr_total = 0.0;
    let mut wr_bp = 0.0;
    for (scheme, r) in Scheme::ALL.into_iter().zip(&results) {
        let total = r.total_cycles();
        let bp = r.phase(Phase::Backward).cycles;
        if scheme == Scheme::Dense {
            dense_total = total;
            dense_bp = bp;
        }
        if scheme == Scheme::InOutWr {
            wr_total = total;
            wr_bp = bp;
        }
        rows.push((scheme.label().to_string(), total, bp, r.total_energy_j()));
    }
    Ok(CosimReport {
        network: net.name,
        backend: opts.backend.label().to_string(),
        replayed: opts.replay.is_some(),
        rows,
        total_speedup: dense_total / wr_total,
        bp_speedup: dense_bp / wr_bp,
        mean_sparsity,
        skip,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecBackend;
    use crate::trace::{LayerTrace, StepTrace};

    fn fake_traces(sparsity: f64) -> TraceFile {
        TraceFile {
            network: "agos_cnn".into(),
            steps: vec![StepTrace {
                step: 0,
                loss: 2.0,
                layers: (1..=4)
                    .map(|i| LayerTrace::scalar(&format!("relu{i}"), sparsity, sparsity, true))
                    .collect(),
            }],
            ..TraceFile::default()
        }
    }

    #[test]
    fn cosim_produces_speedup_from_measured_sparsity() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 2, ..SimOptions::default() };
        let report = cosim_from_traces(&fake_traces(0.5), &cfg, &opts, false, 0).unwrap();
        assert_eq!(report.rows.len(), 4);
        assert!(!report.replayed);
        assert!(report.total_speedup > 1.1, "{}", report.total_speedup);
        assert!(report.bp_speedup > 1.2, "{}", report.bp_speedup);
        assert!((report.mean_sparsity - 0.5).abs() < 1e-9);
    }

    #[test]
    fn cosim_exact_backend_consumes_measured_sparsity_as_bitmaps() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions {
            batch: 1,
            backend: ExecBackend::Exact,
            exact_outputs_per_tile: 16,
            ..SimOptions::default()
        };
        let report = cosim_from_traces(&fake_traces(0.5), &cfg, &opts, false, 0).unwrap();
        assert_eq!(report.backend, "exact");
        assert_eq!(report.rows.len(), 4);
        assert!(report.total_speedup > 1.1, "{}", report.total_speedup);
        assert!(report.bp_speedup > 1.2, "{}", report.bp_speedup);
        assert_eq!(report.to_json().get("backend").as_str(), Some("exact"));
        // Deterministic: the same traces + options reproduce bit-exactly.
        let again = cosim_from_traces(&fake_traces(0.5), &cfg, &opts, false, 0).unwrap();
        for (a, b) in report.rows.iter().zip(&again.rows) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn cosim_replays_captured_patterns_end_to_end() {
        use crate::nn::zoo;
        use crate::sparsity::{capture_synthetic_trace, SparsityModel};
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions {
            batch: 2,
            backend: ExecBackend::Exact,
            exact_outputs_per_tile: 16,
            ..SimOptions::default()
        };
        let traces = capture_synthetic_trace(
            &zoo::agos_cnn(),
            &SparsityModel::synthetic(opts.seed),
            2,
            crate::config::BitmapPattern::Iid,
            2,
        );
        let report = cosim_from_traces(&traces, &cfg, &opts, true, 0).unwrap();
        assert!(report.replayed);
        assert_eq!(report.backend, "exact");
        assert!(report.bp_speedup > 1.2, "{}", report.bp_speedup);
        assert_eq!(report.to_json().get("replayed").as_bool(), Some(true));
        // The default plan cache was exercised and its counters surfaced —
        // but never serialized (the --out report is plan-invariant).
        let skip = report.skip.expect("plan cache on by default");
        assert!(skip.words_gathered > 0, "{skip:?}");
        assert!(!report.to_json().dump().contains("skip"));
        // Plans off: same rows, no counters.
        let off = SimOptions { gather_plans: None, ..opts.clone() };
        let off_report = cosim_from_traces(&traces, &cfg, &off, true, 0).unwrap();
        assert!(off_report.skip.is_none());
        assert_eq!(report.rows, off_report.rows, "plans must not change a cycle");
        assert_eq!(report.to_json().dump(), off_report.to_json().dump());
        // Replay is deterministic end to end, at any jobs level.
        let again = cosim_from_traces(&traces, &cfg, &opts, true, 0).unwrap();
        assert_eq!(report.rows, again.rows);
        let j1 = cosim_from_traces(&traces, &cfg, &opts, true, 1).unwrap();
        let j4 = cosim_from_traces(&traces, &cfg, &opts, true, 4).unwrap();
        assert_eq!(j1.rows, j4.rows, "replay must be jobs-invariant");
        assert_eq!(report.rows, j1.rows);
        // The pattern-informed analytic fast path replays too: measured
        // per-tile densities instead of stochastic jitter.
        let analytic = SimOptions { backend: ExecBackend::Analytic, ..opts.clone() };
        let ar = cosim_from_traces(&traces, &cfg, &analytic, true, 0).unwrap();
        assert!(ar.replayed);
        assert_eq!(ar.backend, "analytic");
        assert!(ar.bp_speedup > 1.2, "{}", ar.bp_speedup);
        // …and it lands near the exact replay on this validated-CRS stack.
        for ((_, at, _, _), (_, et, _, _)) in ar.rows.iter().zip(&report.rows) {
            let err = (at - et).abs() / et;
            assert!(err < 0.35, "analytic-replay {at:.0} vs exact-replay {et:.0}");
        }
        // The consuming entry point (decode-into-bank, no payload clones)
        // is row-identical to the borrowing one.
        let owned = cosim_from_traces_owned(traces.clone(), &cfg, &opts, true, 0).unwrap();
        assert_eq!(report.rows, owned.rows, "owned bank must match borrowed bank");
        assert_eq!(report.to_json().dump(), owned.to_json().dump());
        // A payload-free trace cannot replay on either backend.
        assert!(cosim_from_traces(&fake_traces(0.5), &cfg, &opts, true, 0).is_err());
        assert!(cosim_from_traces(&fake_traces(0.5), &cfg, &analytic, true, 0).is_err());
    }

    #[test]
    fn more_sparsity_more_speedup() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 2, ..SimOptions::default() };
        let lo = cosim_from_traces(&fake_traces(0.3), &cfg, &opts, false, 0).unwrap();
        let hi = cosim_from_traces(&fake_traces(0.7), &cfg, &opts, false, 0).unwrap();
        assert!(hi.total_speedup > lo.total_speedup);
    }

    #[test]
    fn empty_or_violating_traces_rejected() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions::default();
        let empty = TraceFile::new("agos_cnn");
        assert!(cosim_from_traces(&empty, &cfg, &opts, false, 0).is_err());
        let mut bad = fake_traces(0.5);
        bad.steps[0].layers[0].identity_ok = false;
        assert!(cosim_from_traces(&bad, &cfg, &opts, false, 0).is_err());
    }

    #[test]
    fn report_serializes() {
        let cfg = AcceleratorConfig::default();
        let opts = SimOptions { batch: 1, ..SimOptions::default() };
        let report = cosim_from_traces(&fake_traces(0.4), &cfg, &opts, false, 0).unwrap();
        let j = report.to_json();
        assert_eq!(j.get("network").as_str(), Some("agos_cnn"));
        assert_eq!(j.get("rows").as_arr().unwrap().len(), 4);
        assert_eq!(j.get("replayed").as_bool(), Some(false));
    }
}
