//! Training coordinator: drives the PJRT runtime through training steps,
//! extracts sparsity traces from real activations/gradients, and feeds
//! them to the simulator (co-simulation).
//!
//! Python never appears here — the artifacts were AOT-compiled once by
//! `make artifacts` and the request path is pure rust.

mod dataset;
mod trainer;
mod pipeline;
mod driver;

pub use dataset::SyntheticDataset;
pub use driver::{
    cosim_from_traces, cosim_from_traces_owned, cosim_prepared, CosimReport, PreparedCosim,
};
pub use pipeline::run_training_pipeline;
pub use trainer::{TrainLog, Trainer};
