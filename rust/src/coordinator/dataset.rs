//! Synthetic structured dataset (DESIGN.md §0 substitution for ImageNet):
//! each class is a Gaussian blob at a class-specific location with a
//! class-specific channel signature, plus noise. Learnable by the small
//! CNN in a few hundred steps, exercising the identical training path.

use crate::runtime::HostTensor;
use crate::util::rng::Pcg32;

/// Deterministic synthetic image-classification dataset.
pub struct SyntheticDataset {
    pub img: usize,
    pub in_ch: usize,
    pub classes: usize,
    rng: Pcg32,
}

impl SyntheticDataset {
    pub fn new(img: usize, in_ch: usize, classes: usize, seed: u64) -> SyntheticDataset {
        SyntheticDataset { img, in_ch, classes, rng: Pcg32::new(seed) }
    }

    /// Produce one batch as (x `[N,H,W,C]` f32, labels `[N]` i32).
    pub fn batch(&mut self, n: usize) -> (HostTensor, HostTensor) {
        let (img, ch) = (self.img, self.in_ch);
        let mut xs = vec![0f32; n * img * img * ch];
        let mut ys = vec![0i32; n];
        for i in 0..n {
            let class = self.rng.below(self.classes as u32) as usize;
            ys[i] = class as i32;
            // class-specific blob center on a ring
            let angle = 2.0 * std::f64::consts::PI * class as f64 / self.classes as f64;
            let cy = img as f64 * (0.5 + 0.25 * angle.sin());
            let cx = img as f64 * (0.5 + 0.25 * angle.cos());
            let sigma = img as f64 * 0.15;
            for y in 0..img {
                for x in 0..img {
                    let d2 = ((y as f64 - cy).powi(2) + (x as f64 - cx).powi(2))
                        / (2.0 * sigma * sigma);
                    let blob = (-d2).exp();
                    for c in 0..ch {
                        // channel signature: class parity modulates channels
                        let sign = if (class + c) % 2 == 0 { 1.0 } else { -1.0 };
                        let noise = 0.35 * self.rng.gauss();
                        let idx = ((i * img + y) * img + x) * ch + c;
                        xs[idx] = (sign * 2.0 * blob + noise) as f32;
                    }
                }
            }
        }
        (
            HostTensor::f32(vec![n, img, img, ch], xs).expect("batch shape"),
            HostTensor::i32(vec![n], ys).expect("label shape"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_label_range() {
        let mut ds = SyntheticDataset::new(8, 3, 10, 1);
        let (x, y) = ds.batch(4);
        assert_eq!(x.shape(), &[4, 8, 8, 3]);
        assert_eq!(y.shape(), &[4]);
        for l in y.as_i32().unwrap() {
            assert!((0..10).contains(l));
        }
    }

    #[test]
    fn deterministic_from_seed() {
        let mut a = SyntheticDataset::new(8, 3, 10, 42);
        let mut b = SyntheticDataset::new(8, 3, 10, 42);
        assert_eq!(a.batch(2), b.batch(2));
        let mut c = SyntheticDataset::new(8, 3, 10, 43);
        assert_ne!(a.batch(2), c.batch(2));
    }

    #[test]
    fn classes_are_distinguishable() {
        // Blob centers differ by class: mean images of two classes differ.
        let mut ds = SyntheticDataset::new(16, 3, 10, 7);
        let mut sums = vec![vec![0f64; 16 * 16 * 3]; 10];
        let mut counts = vec![0usize; 10];
        for _ in 0..20 {
            let (x, y) = ds.batch(8);
            let xv = x.as_f32().unwrap();
            for (i, l) in y.as_i32().unwrap().iter().enumerate() {
                counts[*l as usize] += 1;
                for j in 0..16 * 16 * 3 {
                    sums[*l as usize][j] += xv[i * 16 * 16 * 3 + j] as f64;
                }
            }
        }
        let (a, b) = (0usize, 5usize);
        if counts[a] > 3 && counts[b] > 3 {
            let diff: f64 = sums[a]
                .iter()
                .zip(&sums[b])
                .map(|(x, y)| (x / counts[a] as f64 - y / counts[b] as f64).abs())
                .sum::<f64>()
                / (16.0 * 16.0 * 3.0);
            assert!(diff > 0.1, "class means indistinguishable: {diff}");
        }
    }
}
