//! Training-loop options for the end-to-end coordinator example.

use crate::trace::TraceFormat;
use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TrainOptions {
    /// Number of optimizer steps to run.
    pub steps: usize,
    /// Batch size (must match the AOT artifact's example batch).
    pub batch: usize,
    /// SGD learning rate (baked into the artifact; recorded for logging).
    pub lr: f64,
    /// Dataset RNG seed.
    pub seed: u64,
    /// Extract sparsity traces every N steps (0 = never).
    pub trace_every: usize,
    /// Images whose packed bitmaps are captured per traced step (each
    /// becomes its own trace-file step, so the replay bank's round-robin
    /// cycles through them; clamped to the artifact batch). Under the v3
    /// delta/RLE encoding the payload growth is sub-linear, which is
    /// what makes batch-wide capture practical.
    pub trace_images: usize,
    /// On-disk trace payload encoding (`--trace-format`): v3 delta/RLE
    /// by default, v2 raw hex for older tooling, v4 for the binary
    /// streaming container (long captures with bounded memory).
    pub trace_format: TraceFormat,
    /// Stream captured steps into a v4 container at this path as they
    /// happen, instead of accumulating them in `TrainLog::traces` —
    /// the bounded-memory capture mode. Requires `trace_format` v4
    /// (the other containers can only be written whole).
    pub stream_path: Option<std::path::PathBuf>,
    /// Directory containing AOT artifacts.
    pub artifacts_dir: std::path::PathBuf,
    /// Log loss every N steps.
    pub log_every: usize,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 300,
            batch: 32,
            lr: 0.05,
            seed: 7,
            trace_every: 50,
            trace_images: 1,
            trace_format: TraceFormat::default(),
            stream_path: None,
            artifacts_dir: std::path::PathBuf::from("artifacts"),
            log_every: 10,
        }
    }
}

impl TrainOptions {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("steps", self.steps.into()),
            ("batch", self.batch.into()),
            ("lr", self.lr.into()),
            ("seed", self.seed.into()),
            ("trace_every", self.trace_every.into()),
            ("trace_images", self.trace_images.into()),
            ("trace_format", self.trace_format.label().into()),
            ("log_every", self.log_every.into()),
            ("artifacts_dir", self.artifacts_dir.to_string_lossy().to_string().into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let t = TrainOptions::default();
        assert!(t.steps > 0 && t.batch > 0);
        assert_eq!(t.trace_images, 1);
        assert_eq!(t.trace_format, TraceFormat::V3, "new captures default to v3");
        assert_eq!(t.to_json().get("trace_images").as_usize(), Some(1));
        assert_eq!(t.to_json().get("trace_format").as_str(), Some("v3"));
        assert!(t.to_json().get("steps").as_usize().unwrap() == t.steps);
    }
}
