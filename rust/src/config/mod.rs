//! Configuration system: accelerator hardware parameters (paper Table 1),
//! simulation options and training options, loadable from JSON with
//! defaults matching the paper's evaluated configuration.

mod accel;
mod sim_opts;
mod train_opts;

pub use accel::{AcceleratorConfig, EnergyTable, MemoryConfig};
pub use sim_opts::{BitmapPattern, GatherMode, Scheme, SimOptions};
pub use train_opts::TrainOptions;

/// Re-exported next to `Scheme`/`SimOptions` for consumers that select a
/// backend without caring about the `sim` internals; the type itself
/// lives with the execution backends (`sim::backend`).
pub use crate::sim::ExecBackend;
pub use crate::trace::TraceFormat;
