//! Configuration system: accelerator hardware parameters (paper Table 1),
//! simulation options and training options, loadable from JSON with
//! defaults matching the paper's evaluated configuration.

mod accel;
mod sim_opts;
mod train_opts;

pub use accel::{AcceleratorConfig, EnergyTable, MemoryConfig};
pub use sim_opts::{Scheme, SimOptions};
pub use train_opts::TrainOptions;
