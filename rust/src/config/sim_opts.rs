//! Simulation options: the execution scheme under evaluation, the
//! execution backend, and the knobs for stochastic trace sampling.

use crate::sim::ExecBackend;
use crate::util::json::Json;

/// Execution scheme — the four bars of Fig 11/12/13.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Dense compute: every MAC is performed (baseline, "DC").
    Dense,
    /// Input sparsity only ("IN"): zero input operands are skipped via
    /// through-channel NZ offset indexing.
    In,
    /// Input + output sparsity ("IN+OUT"): additionally, output locations
    /// whose ReLU backward mask is zero are never computed.
    InOut,
    /// IN+OUT plus WDU work redistribution ("IN+OUT+WR").
    InOutWr,
}

impl Scheme {
    pub const ALL: [Scheme; 4] = [Scheme::Dense, Scheme::In, Scheme::InOut, Scheme::InOutWr];

    pub fn label(&self) -> &'static str {
        match self {
            Scheme::Dense => "DC",
            Scheme::In => "IN",
            Scheme::InOut => "IN+OUT",
            Scheme::InOutWr => "IN+OUT+WR",
        }
    }

    pub fn uses_input_sparsity(&self) -> bool {
        !matches!(self, Scheme::Dense)
    }

    pub fn uses_output_sparsity(&self) -> bool {
        matches!(self, Scheme::InOut | Scheme::InOutWr)
    }

    pub fn uses_work_redistribution(&self) -> bool {
        matches!(self, Scheme::InOutWr)
    }

    pub fn parse(s: &str) -> anyhow::Result<Scheme> {
        match s.to_ascii_uppercase().as_str() {
            "DC" | "DENSE" => Ok(Scheme::Dense),
            "IN" => Ok(Scheme::In),
            "IN+OUT" | "INOUT" => Ok(Scheme::InOut),
            "IN+OUT+WR" | "INOUTWR" | "ALL" => Ok(Scheme::InOutWr),
            other => anyhow::bail!("unknown scheme '{other}' (DC|IN|IN+OUT|IN+OUT+WR)"),
        }
    }
}

/// Options controlling a simulation run.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// RNG seed for synthetic sparsity sampling.
    pub seed: u64,
    /// Batch size being simulated (paper: 16).
    pub batch: usize,
    /// Spatial sparsity imbalance: coefficient of variation of the
    /// per-tile sparsity around the layer mean (drives WDU gains).
    pub tile_sparsity_cv: f64,
    /// Exact backend only: per-tile cap on outputs that get a real
    /// sampled bitmap; larger tiles are costed from the sampled mean
    /// (see sim::backend::exact_tile_cost).
    pub exact_outputs_per_tile: usize,
    /// Model DRAM-compute overlap (true per §6 "DRAM considerations").
    pub overlap_dram: bool,
    /// Execution backend the tiles are costed with (sim::backend).
    pub backend: ExecBackend,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            seed: 0xA605,
            batch: 16,
            tile_sparsity_cv: 0.10,
            exact_outputs_per_tile: 4096,
            overlap_dram: true,
            backend: ExecBackend::Analytic,
        }
    }
}

impl SimOptions {
    /// Stable 64-bit fingerprint (FNV-1a) over every option that affects
    /// simulation results — one component of the sweep-cache key
    /// (`sim::sweep`): two `SimOptions` fingerprint equal iff a cached
    /// `NetworkSimResult` is reusable between them.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fnv::Fnv1a::new();
        h.put(self.seed)
            .put(self.batch as u64)
            .put_f64(self.tile_sparsity_cv)
            .put(self.exact_outputs_per_tile as u64)
            .put(self.overlap_dram as u64)
            .put(self.backend.tag());
        h.finish()
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("seed", self.seed.into()),
            ("batch", self.batch.into()),
            ("tile_sparsity_cv", self.tile_sparsity_cv.into()),
            ("exact_outputs_per_tile", self.exact_outputs_per_tile.into()),
            ("overlap_dram", self.overlap_dram.into()),
            ("backend", self.backend.label().into()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SimOptions> {
        let mut o = SimOptions::default();
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("sim options must be an object"))?;
        for (k, v) in obj {
            match k.as_str() {
                "seed" => o.seed = v.as_u64().ok_or_else(|| anyhow::anyhow!("seed: u64"))?,
                "batch" => o.batch = v.as_usize().ok_or_else(|| anyhow::anyhow!("batch: usize"))?,
                "tile_sparsity_cv" => {
                    o.tile_sparsity_cv = v.as_f64().ok_or_else(|| anyhow::anyhow!("cv: f64"))?
                }
                "exact_outputs_per_tile" => {
                    o.exact_outputs_per_tile =
                        v.as_usize().ok_or_else(|| anyhow::anyhow!("exact: usize"))?
                }
                "overlap_dram" => {
                    o.overlap_dram = v.as_bool().ok_or_else(|| anyhow::anyhow!("overlap: bool"))?
                }
                "backend" => {
                    let s = v.as_str().ok_or_else(|| anyhow::anyhow!("backend: string"))?;
                    o.backend = ExecBackend::parse(s)?;
                }
                other => anyhow::bail!("unknown sim option '{other}'"),
            }
        }
        Ok(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_capabilities() {
        assert!(!Scheme::Dense.uses_input_sparsity());
        assert!(Scheme::In.uses_input_sparsity());
        assert!(!Scheme::In.uses_output_sparsity());
        assert!(Scheme::InOut.uses_output_sparsity());
        assert!(!Scheme::InOut.uses_work_redistribution());
        assert!(Scheme::InOutWr.uses_work_redistribution());
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("dc").unwrap(), Scheme::Dense);
        assert_eq!(Scheme::parse("in+out+wr").unwrap(), Scheme::InOutWr);
        assert!(Scheme::parse("bogus").is_err());
    }

    #[test]
    fn fingerprint_tracks_every_field() {
        let base = SimOptions::default();
        assert_eq!(base.fingerprint(), SimOptions::default().fingerprint());
        let variants = [
            SimOptions { seed: 1, ..base.clone() },
            SimOptions { batch: 3, ..base.clone() },
            SimOptions { tile_sparsity_cv: 0.2, ..base.clone() },
            SimOptions { exact_outputs_per_tile: 7, ..base.clone() },
            SimOptions { overlap_dram: false, ..base.clone() },
            SimOptions { backend: ExecBackend::Exact, ..base.clone() },
        ];
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(v.fingerprint(), base.fingerprint(), "variant {i}");
        }
    }

    #[test]
    fn options_roundtrip() {
        let o = SimOptions {
            seed: 42,
            batch: 8,
            backend: ExecBackend::Exact,
            ..SimOptions::default()
        };
        let o2 = SimOptions::from_json(&o.to_json()).unwrap();
        assert_eq!(o2.seed, 42);
        assert_eq!(o2.batch, 8);
        assert_eq!(o2.backend, ExecBackend::Exact);
    }
}
