//! Simulation options: the execution scheme under evaluation, the
//! execution backend, the knobs for stochastic trace sampling, and the
//! optional pattern-replay handle.

use std::sync::Arc;

use crate::sim::{ExecBackend, GatherPlanCache, ReplayBank};
use crate::util::json::Json;

/// Execution scheme — the four bars of Fig 11/12/13.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Dense compute: every MAC is performed (baseline, "DC").
    Dense,
    /// Input sparsity only ("IN"): zero input operands are skipped via
    /// through-channel NZ offset indexing.
    In,
    /// Input + output sparsity ("IN+OUT"): additionally, output locations
    /// whose ReLU backward mask is zero are never computed.
    InOut,
    /// IN+OUT plus WDU work redistribution ("IN+OUT+WR").
    InOutWr,
}

impl Scheme {
    pub const ALL: [Scheme; 4] = [Scheme::Dense, Scheme::In, Scheme::InOut, Scheme::InOutWr];

    pub fn label(&self) -> &'static str {
        match self {
            Scheme::Dense => "DC",
            Scheme::In => "IN",
            Scheme::InOut => "IN+OUT",
            Scheme::InOutWr => "IN+OUT+WR",
        }
    }

    pub fn uses_input_sparsity(&self) -> bool {
        !matches!(self, Scheme::Dense)
    }

    pub fn uses_output_sparsity(&self) -> bool {
        matches!(self, Scheme::InOut | Scheme::InOutWr)
    }

    pub fn uses_work_redistribution(&self) -> bool {
        matches!(self, Scheme::InOutWr)
    }

    pub fn parse(s: &str) -> anyhow::Result<Scheme> {
        match s.to_ascii_uppercase().as_str() {
            "DC" | "DENSE" => Ok(Scheme::Dense),
            "IN" => Ok(Scheme::In),
            "IN+OUT" | "INOUT" => Ok(Scheme::InOut),
            "IN+OUT+WR" | "INOUTWR" | "ALL" => Ok(Scheme::InOutWr),
            other => anyhow::bail!("unknown scheme '{other}' (DC|IN|IN+OUT|IN+OUT+WR)"),
        }
    }

    /// Parse a comma-separated scheme list; the literal `"all"` selects
    /// all four in [`Scheme::ALL`] order. Shared by the CLI's
    /// `--schemes` and the served `sweep` request so both spell the same
    /// grids identically.
    pub fn parse_list(spec: &str) -> anyhow::Result<Vec<Scheme>> {
        if spec == "all" {
            return Ok(Scheme::ALL.to_vec());
        }
        spec.split(',').map(|s| Scheme::parse(s.trim())).collect()
    }
}

/// Spatial structure of *sampled* bitmaps on the exact backend — iid
/// Bernoulli draws (what PR 2 shipped) vs spatially-correlated blobs,
/// which reproduce the zero clustering that drives lane-imbalance stalls
/// in real maps (`Bitmap::sample_blobs`). Irrelevant to replayed
/// patterns, which carry their own structure.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum BitmapPattern {
    #[default]
    Iid,
    Blobs,
}

impl BitmapPattern {
    pub const ALL: [BitmapPattern; 2] = [BitmapPattern::Iid, BitmapPattern::Blobs];

    pub fn label(&self) -> &'static str {
        match self {
            BitmapPattern::Iid => "iid",
            BitmapPattern::Blobs => "blobs",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<BitmapPattern> {
        match s.to_ascii_lowercase().as_str() {
            "iid" | "bernoulli" => Ok(BitmapPattern::Iid),
            "blobs" | "blob" | "clustered" => Ok(BitmapPattern::Blobs),
            other => anyhow::bail!("unknown bitmap pattern '{other}' (iid|blobs)"),
        }
    }
}

/// How replayed operand windows are assembled from a captured map on the
/// exact backend: the geometry-exact strided receptive-field gather (the
/// default — every output reads exactly the operand bits its kernel ×
/// stride × padding coordinates name), or the legacy contiguous
/// streaming-slice window (kept as the comparison baseline for
/// `figure figval`). Irrelevant without `--replay`; the analytic
/// backend's pattern-informed densities don't depend on it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum GatherMode {
    #[default]
    Geometry,
    Streaming,
}

impl GatherMode {
    pub const ALL: [GatherMode; 2] = [GatherMode::Geometry, GatherMode::Streaming];

    pub fn label(&self) -> &'static str {
        match self {
            GatherMode::Geometry => "geometry",
            GatherMode::Streaming => "streaming",
        }
    }

    /// Stable tag folded into `SimOptions::fingerprint` when replay is
    /// armed (the mode changes no result otherwise).
    pub fn tag(&self) -> u64 {
        match self {
            GatherMode::Geometry => 1,
            GatherMode::Streaming => 2,
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<GatherMode> {
        match s.to_ascii_lowercase().as_str() {
            "geometry" | "geo" | "gather" => Ok(GatherMode::Geometry),
            "streaming" | "stream" | "slice" => Ok(GatherMode::Streaming),
            other => anyhow::bail!("unknown gather mode '{other}' (geometry|streaming)"),
        }
    }
}

/// Options controlling a simulation run.
#[derive(Clone, Debug)]
pub struct SimOptions {
    /// RNG seed for synthetic sparsity sampling.
    pub seed: u64,
    /// Batch size being simulated (paper: 16).
    pub batch: usize,
    /// Spatial sparsity imbalance: coefficient of variation of the
    /// per-tile sparsity around the layer mean (drives WDU gains).
    pub tile_sparsity_cv: f64,
    /// Exact backend only: per-tile cap on outputs that get a real
    /// sampled bitmap; larger tiles are costed from the sampled mean
    /// (see sim::backend::exact_tile_cost).
    pub exact_outputs_per_tile: usize,
    /// Model DRAM-compute overlap (true per §6 "DRAM considerations").
    pub overlap_dram: bool,
    /// Execution backend the tiles are costed with (sim::backend).
    pub backend: ExecBackend,
    /// Spatial structure of sampled bitmaps (exact backend).
    pub pattern: BitmapPattern,
    /// Blob radius when `pattern == Blobs` (Chebyshev, in pixels).
    pub blob_radius: usize,
    /// Content fingerprint of the trace file a run is driven by, if any
    /// — folded into `fingerprint()` so two different trace files can
    /// never share a sweep-cache entry even when their per-layer mean
    /// sparsities coincide (set by `coordinator::cosim_from_traces`).
    pub trace_fingerprint: Option<u64>,
    /// Replayed operand-window assembly: geometry-exact strided gather
    /// (default) vs the legacy streaming slice.
    pub gather: GatherMode,
    /// Captured-bitmap replay bank: tasks with payloads slice real
    /// patterns instead of sampling (`sim::replay`) — pattern-exact
    /// windows on the exact backend, measured per-tile densities on the
    /// analytic backend. A live handle, not serialized; its trace
    /// fingerprint is folded into `fingerprint()`.
    pub replay: Option<Arc<ReplayBank>>,
    /// Content fingerprint of the scenario file a combo was expanded
    /// from (`scenario::ScenarioFile::fingerprint`), if any — folded
    /// into `fingerprint()` exactly like `trace_fingerprint`, so
    /// scenario-expanded combos can never alias a hand-written grid (or
    /// a different scenario) in the sweep cache even when every other
    /// knob coincides.
    pub scenario_fingerprint: Option<u64>,
    /// Shared gather-plan cache for the exact backend's replayed
    /// windowed gathers (`sim::plan`): precomputed segment schedules
    /// plus RLE-run zero-skip, shared across images, steps, schemes and
    /// worker threads. `None` runs the plan-free reference path. Pure
    /// execution strategy — deliberately NOT part of `fingerprint()`
    /// (results are bit-identical either way, pinned by
    /// `sim::engine` tests) and never serialized.
    pub gather_plans: Option<Arc<GatherPlanCache>>,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            seed: 0xA605,
            batch: 16,
            tile_sparsity_cv: 0.10,
            exact_outputs_per_tile: 4096,
            overlap_dram: true,
            backend: ExecBackend::Analytic,
            pattern: BitmapPattern::Iid,
            blob_radius: 2,
            trace_fingerprint: None,
            gather: GatherMode::Geometry,
            replay: None,
            scenario_fingerprint: None,
            gather_plans: Some(Arc::new(GatherPlanCache::new())),
        }
    }
}

impl SimOptions {
    /// Stable 64-bit fingerprint (FNV-1a) over every option that affects
    /// simulation results — one component of the sweep-cache key
    /// (`sim::sweep`): two `SimOptions` fingerprint equal iff a cached
    /// `NetworkSimResult` is reusable between them.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fnv::Fnv1a::new();
        h.put(self.seed)
            .put(self.batch as u64)
            .put_f64(self.tile_sparsity_cv)
            .put(self.exact_outputs_per_tile as u64)
            .put(self.overlap_dram as u64)
            .put(self.backend.tag());
        // One word for the sampling structure: iid runs at any
        // `blob_radius` are identical, so the radius only separates keys
        // when blobs are actually drawn.
        h.put(match self.pattern {
            BitmapPattern::Iid => 0,
            BitmapPattern::Blobs => 1 + self.blob_radius as u64,
        });
        // Presence-tagged folds: None vs Some(0) must differ.
        match self.trace_fingerprint {
            None => h.put(0),
            Some(fp) => h.put(1).put(fp),
        };
        // The gather mode only changes results when a replay bank is
        // armed, so it separates keys only then (mirrors the blob-radius
        // rule above).
        match &self.replay {
            None => h.put(0),
            Some(bank) => h.put(1).put(bank.fingerprint()).put(self.gather.tag()),
        };
        match self.scenario_fingerprint {
            None => h.put(0),
            Some(fp) => h.put(1).put(fp),
        };
        h.finish()
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::from_pairs(vec![
            ("seed", self.seed.into()),
            ("batch", self.batch.into()),
            ("tile_sparsity_cv", self.tile_sparsity_cv.into()),
            ("exact_outputs_per_tile", self.exact_outputs_per_tile.into()),
            ("overlap_dram", self.overlap_dram.into()),
            ("backend", self.backend.label().into()),
            ("pattern", self.pattern.label().into()),
            ("blob_radius", self.blob_radius.into()),
            ("gather", self.gather.label().into()),
        ]);
        // The replay bank is a live in-memory handle; record what it
        // replays (for result provenance) without pretending a JSON blob
        // could reconstruct it.
        if let Some(fp) = self.trace_fingerprint {
            j.set("trace_fingerprint", format!("{fp:016x}").into());
        }
        if let Some(bank) = &self.replay {
            j.set("replay_trace_fingerprint", format!("{:016x}", bank.fingerprint()).into());
        }
        if let Some(fp) = self.scenario_fingerprint {
            j.set("scenario_fingerprint", format!("{fp:016x}").into());
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<SimOptions> {
        let mut o = SimOptions::default();
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("sim options must be an object"))?;
        for (k, v) in obj {
            match k.as_str() {
                "seed" => o.seed = v.as_u64().ok_or_else(|| anyhow::anyhow!("seed: u64"))?,
                "batch" => o.batch = v.as_usize().ok_or_else(|| anyhow::anyhow!("batch: usize"))?,
                "tile_sparsity_cv" => {
                    o.tile_sparsity_cv = v.as_f64().ok_or_else(|| anyhow::anyhow!("cv: f64"))?
                }
                "exact_outputs_per_tile" => {
                    o.exact_outputs_per_tile =
                        v.as_usize().ok_or_else(|| anyhow::anyhow!("exact: usize"))?
                }
                "overlap_dram" => {
                    o.overlap_dram = v.as_bool().ok_or_else(|| anyhow::anyhow!("overlap: bool"))?
                }
                "backend" => {
                    let s = v.as_str().ok_or_else(|| anyhow::anyhow!("backend: string"))?;
                    o.backend = ExecBackend::parse(s)?;
                }
                "pattern" => {
                    let s = v.as_str().ok_or_else(|| anyhow::anyhow!("pattern: string"))?;
                    o.pattern = BitmapPattern::parse(s)?;
                }
                "blob_radius" => {
                    o.blob_radius =
                        v.as_usize().ok_or_else(|| anyhow::anyhow!("blob_radius: usize"))?
                }
                "gather" => {
                    let s = v.as_str().ok_or_else(|| anyhow::anyhow!("gather: string"))?;
                    o.gather = GatherMode::parse(s)?;
                }
                // Provenance stamps written by to_json; a parsed options
                // object cannot resurrect the live bank, so they are
                // accepted and dropped rather than silently keyed on.
                "trace_fingerprint" | "replay_trace_fingerprint" | "scenario_fingerprint" => {}
                other => anyhow::bail!("unknown sim option '{other}'"),
            }
        }
        Ok(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_capabilities() {
        assert!(!Scheme::Dense.uses_input_sparsity());
        assert!(Scheme::In.uses_input_sparsity());
        assert!(!Scheme::In.uses_output_sparsity());
        assert!(Scheme::InOut.uses_output_sparsity());
        assert!(!Scheme::InOut.uses_work_redistribution());
        assert!(Scheme::InOutWr.uses_work_redistribution());
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("dc").unwrap(), Scheme::Dense);
        assert_eq!(Scheme::parse("in+out+wr").unwrap(), Scheme::InOutWr);
        assert!(Scheme::parse("bogus").is_err());
    }

    #[test]
    fn fingerprint_tracks_every_field() {
        let base = SimOptions::default();
        assert_eq!(base.fingerprint(), SimOptions::default().fingerprint());
        let variants = [
            SimOptions { seed: 1, ..base.clone() },
            SimOptions { batch: 3, ..base.clone() },
            SimOptions { tile_sparsity_cv: 0.2, ..base.clone() },
            SimOptions { exact_outputs_per_tile: 7, ..base.clone() },
            SimOptions { overlap_dram: false, ..base.clone() },
            SimOptions { backend: ExecBackend::Exact, ..base.clone() },
            SimOptions { pattern: BitmapPattern::Blobs, ..base.clone() },
            SimOptions { trace_fingerprint: Some(0), ..base.clone() },
            SimOptions { trace_fingerprint: Some(7), ..base.clone() },
            SimOptions { scenario_fingerprint: Some(0), ..base.clone() },
            SimOptions { scenario_fingerprint: Some(7), ..base.clone() },
        ];
        for (i, v) in variants.iter().enumerate() {
            assert_ne!(v.fingerprint(), base.fingerprint(), "variant {i}");
        }
        // The blob radius separates keys only when blobs are drawn.
        let iid_r9 = SimOptions { blob_radius: 9, ..base.clone() };
        assert_eq!(iid_r9.fingerprint(), base.fingerprint());
        let blobs = SimOptions { pattern: BitmapPattern::Blobs, ..base.clone() };
        let blobs_r9 = SimOptions { blob_radius: 9, ..blobs.clone() };
        assert_ne!(blobs.fingerprint(), blobs_r9.fingerprint());
        // Two different trace fingerprints must never alias.
        assert_ne!(
            SimOptions { trace_fingerprint: Some(1), ..base.clone() }.fingerprint(),
            SimOptions { trace_fingerprint: Some(2), ..base.clone() }.fingerprint()
        );
        // Ditto scenario fingerprints — and the two provenance folds are
        // positionally distinct (a trace fp can't impersonate a scenario fp).
        assert_ne!(
            SimOptions { scenario_fingerprint: Some(1), ..base.clone() }.fingerprint(),
            SimOptions { scenario_fingerprint: Some(2), ..base.clone() }.fingerprint()
        );
        assert_ne!(
            SimOptions { trace_fingerprint: Some(5), ..base.clone() }.fingerprint(),
            SimOptions { scenario_fingerprint: Some(5), ..base.clone() }.fingerprint()
        );
    }

    #[test]
    fn gather_plans_are_fingerprint_neutral() {
        // The plan cache is pure execution strategy: on, off, or a
        // different instance must all share one sweep-cache key (results
        // are bit-identical, pinned by the engine tests), and the handle
        // never leaks into the serialized form.
        let base = SimOptions::default();
        assert!(base.gather_plans.is_some(), "plans are on by default");
        let off = SimOptions { gather_plans: None, ..base.clone() };
        let other =
            SimOptions { gather_plans: Some(Arc::new(GatherPlanCache::plans_only())), ..base.clone() };
        assert_eq!(base.fingerprint(), off.fingerprint());
        assert_eq!(base.fingerprint(), other.fingerprint());
        let json = base.to_json().dump();
        assert!(!json.contains("plan"), "plan cache must not serialize: {json}");
        // from_json restores the default-on cache.
        assert!(SimOptions::from_json(&base.to_json()).unwrap().gather_plans.is_some());
    }

    #[test]
    fn gather_mode_parse_and_key_separation() {
        for g in GatherMode::ALL {
            assert_eq!(GatherMode::parse(g.label()).unwrap(), g);
        }
        assert_eq!(GatherMode::parse("STREAM").unwrap(), GatherMode::Streaming);
        assert!(GatherMode::parse("teleport").is_err());
        assert_eq!(GatherMode::default(), GatherMode::Geometry);

        // Without a replay bank the mode changes nothing, so keys agree.
        let base = SimOptions::default();
        let streaming = SimOptions { gather: GatherMode::Streaming, ..base.clone() };
        assert_eq!(base.fingerprint(), streaming.fingerprint());

        // With a bank armed, the two modes must never share a cache entry.
        let net = crate::nn::zoo::agos_cnn();
        let model = crate::sparsity::SparsityModel::synthetic(3);
        let trace =
            crate::sparsity::capture_synthetic_trace(&net, &model, 1, BitmapPattern::Iid, 2);
        let bank = Arc::new(crate::sim::ReplayBank::from_trace(&net, &trace).unwrap());
        let geo = SimOptions { replay: Some(bank.clone()), ..base.clone() };
        let stream = SimOptions { gather: GatherMode::Streaming, ..geo.clone() };
        assert_ne!(geo.fingerprint(), stream.fingerprint());
        assert_ne!(geo.fingerprint(), base.fingerprint());
    }

    #[test]
    fn pattern_parse_roundtrip() {
        for p in BitmapPattern::ALL {
            assert_eq!(BitmapPattern::parse(p.label()).unwrap(), p);
        }
        assert_eq!(BitmapPattern::parse("CLUSTERED").unwrap(), BitmapPattern::Blobs);
        assert!(BitmapPattern::parse("plaid").is_err());
        assert_eq!(BitmapPattern::default(), BitmapPattern::Iid);
    }

    #[test]
    fn options_roundtrip() {
        let o = SimOptions {
            seed: 42,
            batch: 8,
            backend: ExecBackend::Exact,
            pattern: BitmapPattern::Blobs,
            blob_radius: 5,
            gather: GatherMode::Streaming,
            trace_fingerprint: Some(0xABCD),
            scenario_fingerprint: Some(0x5CE0),
            ..SimOptions::default()
        };
        let o2 = SimOptions::from_json(&o.to_json()).unwrap();
        assert_eq!(o2.seed, 42);
        assert_eq!(o2.batch, 8);
        assert_eq!(o2.backend, ExecBackend::Exact);
        assert_eq!(o2.pattern, BitmapPattern::Blobs);
        assert_eq!(o2.blob_radius, 5);
        assert_eq!(o2.gather, GatherMode::Streaming);
        // Provenance stamps are not resurrected into live state.
        assert_eq!(o2.trace_fingerprint, None);
        assert_eq!(o2.scenario_fingerprint, None);
        assert!(o2.replay.is_none());
    }
}
