//! Accelerator hardware description — defaults are the paper's evaluated
//! design point (Table 1 and §5.2): a node of 16×16 PEs at 667 MHz, each
//! PE with 16 computation lanes × 2 double-buffer groups × 32 entries,
//! 5-bit NZ offsets, a 16-input reconfigurable adder tree, 32 KB × 4 SRAM
//! banks, H-tree broadcast at 512 GB/s and 16-channel DDR3-1600 DRAM.

use crate::util::json::Json;

/// Per-component energy/power constants (Table 1), 32 nm, 667 MHz.
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyTable {
    /// Dynamic power of the neuron/synapse register files (W per PE).
    pub regfile_power_w: f64,
    /// Dynamic power of the non-zero index register file (W per PE).
    pub idx_regfile_power_w: f64,
    /// Dynamic power of the 16 fp16 MAC units (W per PE).
    pub mac_power_w: f64,
    /// Dynamic power of the reconfigurable adder tree (W per PE).
    pub adder_tree_power_w: f64,
    /// Dynamic power of the non-zero encoder (W per PE).
    pub encoder_power_w: f64,
    /// PE control logic power (W per PE).
    pub control_power_w: f64,
    /// SRAM read energy (J per 128 B line read).
    pub sram_read_j: f64,
    /// SRAM write energy (J per 128 B line write).
    pub sram_write_j: f64,
    /// SRAM dynamic power (W per PE buffer).
    pub sram_dynamic_w: f64,
    /// SRAM static power (W per PE buffer).
    pub sram_static_w: f64,
    /// Whole-PE power budget (W) — Table 1 "PE total".
    pub pe_total_w: f64,
    /// DRAM energy per byte transferred (J/B), DDR3-1600 class.
    pub dram_j_per_byte: f64,
}

impl Default for EnergyTable {
    fn default() -> Self {
        EnergyTable {
            regfile_power_w: 20.1e-3,
            idx_regfile_power_w: 3.44e-3,
            mac_power_w: 10.56e-3,
            adder_tree_power_w: 5.5127e-3,
            encoder_power_w: 0.7714e-3,
            control_power_w: 2.0955e-3,
            sram_read_j: 0.035e-9,
            sram_write_j: 0.040e-9,
            sram_dynamic_w: 25e-3,
            sram_static_w: 8.1e-3,
            pe_total_w: 75e-3,
            // ~70 pJ/bit for DDR3 → 560 pJ/byte is a common figure; use
            // 520 pJ/B to include channel utilization effects.
            dram_j_per_byte: 520e-12,
        }
    }
}

/// Memory-system description (§4.3, §6 "DRAM considerations").
#[derive(Clone, Debug, PartialEq)]
pub struct MemoryConfig {
    /// SRAM bank size per PE (bytes). Table 1: 32 KB.
    pub sram_bank_bytes: usize,
    /// SRAM banks per PE. Table 1: 4.
    pub sram_banks: usize,
    /// SRAM line size (bytes). Table 1: 128 B.
    pub sram_line_bytes: usize,
    /// Peak SRAM feed into the lanes (bytes/cycle). §4.3: 64 B neuron +
    /// 64 B synapse on refill plus 20 B offsets ⇒ 84 B/cycle quoted.
    pub sram_feed_bytes_per_cycle: usize,
    /// DRAM channels. §6: 16.
    pub dram_channels: usize,
    /// Bandwidth per DRAM channel (bytes/s). DDR3-1600: 12.6 GB/s.
    pub dram_channel_bw: f64,
    /// H-tree broadcast bandwidth (bytes/s). §5.2: 512 GB/s.
    pub htree_bw: f64,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        MemoryConfig {
            sram_bank_bytes: 32 * 1024,
            sram_banks: 4,
            sram_line_bytes: 128,
            sram_feed_bytes_per_cycle: 84,
            dram_channels: 16,
            dram_channel_bw: 12.6e9,
            htree_bw: 512e9,
        }
    }
}

/// Full accelerator design point.
#[derive(Clone, Debug, PartialEq)]
pub struct AcceleratorConfig {
    /// PEs along x (output-width tiling). §5.2: 16.
    pub tx: usize,
    /// PEs along y (output-height tiling). §5.2: 16.
    pub ty: usize,
    /// Computation lanes per PE. §4.3: 16.
    pub lanes: usize,
    /// Entries per lane buffer group. §4.3: 32.
    pub group_entries: usize,
    /// Buffer groups per lane (double buffering). §4.3: 2.
    pub groups: usize,
    /// Bits per NZ offset entry. §4.3: 5 (indexes 32 entries).
    pub offset_bits: usize,
    /// Clock frequency (Hz). §5.2: 667 MHz.
    pub freq_hz: f64,
    /// Operand width (bytes); fp16 ⇒ 2.
    pub operand_bytes: usize,
    /// WDU redistribution threshold: steal only while the victim's
    /// remaining work fraction exceeds this. §4.6: 0.30.
    pub wr_threshold: f64,
    /// Cycles to transfer + merge per stolen output row during WDU
    /// redistribution (overhead model).
    pub wr_overhead_cycles_per_output: f64,
    pub memory: MemoryConfig,
    pub energy: EnergyTable,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        AcceleratorConfig {
            tx: 16,
            ty: 16,
            lanes: 16,
            group_entries: 32,
            groups: 2,
            offset_bits: 5,
            freq_hz: 667e6,
            operand_bytes: 2,
            wr_threshold: 0.30,
            wr_overhead_cycles_per_output: 4.0,
            memory: MemoryConfig::default(),
            energy: EnergyTable::default(),
        }
    }
}

impl AcceleratorConfig {
    /// Total PE count in the node.
    pub fn pe_count(&self) -> usize {
        self.tx * self.ty
    }

    /// Receptive-field capacity of one PE pass: lanes × entries × groups
    /// (= 1024 for the paper's design point, §4.3).
    pub fn pe_capacity(&self) -> usize {
        self.lanes * self.group_entries * self.groups
    }

    /// Peak MACs per cycle for the node (8192 for the default: 256 PEs ×
    /// 16 lanes × 2 ops/MAC counted as 2 FLOPs in the paper's 5464-GFLOPs
    /// figure; here we count MACs).
    pub fn peak_macs_per_cycle(&self) -> usize {
        self.pe_count() * self.lanes
    }

    /// Peak throughput in FLOPs/s (2 FLOPs per MAC).
    pub fn peak_flops(&self) -> f64 {
        2.0 * self.peak_macs_per_cycle() as f64 * self.freq_hz
    }

    /// Node power (W): PE totals (Table 1 row "Proposed design node").
    pub fn node_power_w(&self) -> f64 {
        self.energy.pe_total_w * self.pe_count() as f64
    }

    /// Aggregate DRAM bandwidth (bytes/s).
    pub fn dram_bw(&self) -> f64 {
        self.memory.dram_channels as f64 * self.memory.dram_channel_bw
    }

    /// Stable 64-bit fingerprint (FNV-1a) over every hardware parameter
    /// that affects simulation results, including the memory system and
    /// the energy table — one component of the sweep-cache key
    /// (`sim::sweep`).
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fnv::Fnv1a::new();
        h.put(self.tx as u64)
            .put(self.ty as u64)
            .put(self.lanes as u64)
            .put(self.group_entries as u64)
            .put(self.groups as u64)
            .put(self.offset_bits as u64)
            .put_f64(self.freq_hz)
            .put(self.operand_bytes as u64)
            .put_f64(self.wr_threshold)
            .put_f64(self.wr_overhead_cycles_per_output);
        let m = &self.memory;
        h.put(m.sram_bank_bytes as u64)
            .put(m.sram_banks as u64)
            .put(m.sram_line_bytes as u64)
            .put(m.sram_feed_bytes_per_cycle as u64)
            .put(m.dram_channels as u64)
            .put_f64(m.dram_channel_bw)
            .put_f64(m.htree_bw);
        let e = &self.energy;
        h.put_f64(e.regfile_power_w)
            .put_f64(e.idx_regfile_power_w)
            .put_f64(e.mac_power_w)
            .put_f64(e.adder_tree_power_w)
            .put_f64(e.encoder_power_w)
            .put_f64(e.control_power_w)
            .put_f64(e.sram_read_j)
            .put_f64(e.sram_write_j)
            .put_f64(e.sram_dynamic_w)
            .put_f64(e.sram_static_w)
            .put_f64(e.pe_total_w)
            .put_f64(e.dram_j_per_byte);
        h.finish()
    }

    // ---- JSON ----------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("tx", self.tx.into()),
            ("ty", self.ty.into()),
            ("lanes", self.lanes.into()),
            ("group_entries", self.group_entries.into()),
            ("groups", self.groups.into()),
            ("offset_bits", self.offset_bits.into()),
            ("freq_hz", self.freq_hz.into()),
            ("operand_bytes", self.operand_bytes.into()),
            ("wr_threshold", self.wr_threshold.into()),
            ("wr_overhead_cycles_per_output", self.wr_overhead_cycles_per_output.into()),
            ("dram_channels", self.memory.dram_channels.into()),
            ("dram_channel_bw", self.memory.dram_channel_bw.into()),
            ("htree_bw", self.memory.htree_bw.into()),
        ])
    }

    /// Build from JSON, applying defaults for missing keys. Unknown keys
    /// are rejected to catch config typos.
    pub fn from_json(j: &Json) -> anyhow::Result<AcceleratorConfig> {
        let mut c = AcceleratorConfig::default();
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("accelerator config must be a JSON object"))?;
        for (k, v) in obj {
            match k.as_str() {
                "tx" => c.tx = req_usize(v, k)?,
                "ty" => c.ty = req_usize(v, k)?,
                "lanes" => c.lanes = req_usize(v, k)?,
                "group_entries" => c.group_entries = req_usize(v, k)?,
                "groups" => c.groups = req_usize(v, k)?,
                "offset_bits" => c.offset_bits = req_usize(v, k)?,
                "freq_hz" => c.freq_hz = req_f64(v, k)?,
                "operand_bytes" => c.operand_bytes = req_usize(v, k)?,
                "wr_threshold" => c.wr_threshold = req_f64(v, k)?,
                "wr_overhead_cycles_per_output" => {
                    c.wr_overhead_cycles_per_output = req_f64(v, k)?
                }
                "dram_channels" => c.memory.dram_channels = req_usize(v, k)?,
                "dram_channel_bw" => c.memory.dram_channel_bw = req_f64(v, k)?,
                "htree_bw" => c.memory.htree_bw = req_f64(v, k)?,
                other => anyhow::bail!("unknown accelerator config key '{other}'"),
            }
        }
        c.validate()?;
        Ok(c)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.tx > 0 && self.ty > 0, "tx/ty must be positive");
        anyhow::ensure!(self.lanes.is_power_of_two(), "lanes must be a power of two (adder tree)");
        anyhow::ensure!(self.groups >= 1, "need at least one buffer group");
        anyhow::ensure!(
            (1usize << self.offset_bits) >= self.group_entries,
            "offset_bits ({}) cannot index group_entries ({})",
            self.offset_bits,
            self.group_entries
        );
        anyhow::ensure!((0.0..=1.0).contains(&self.wr_threshold), "wr_threshold in [0,1]");
        Ok(())
    }
}

fn req_usize(v: &Json, k: &str) -> anyhow::Result<usize> {
    v.as_usize().ok_or_else(|| anyhow::anyhow!("'{k}' must be a non-negative integer"))
}

fn req_f64(v: &Json, k: &str) -> anyhow::Result<f64> {
    v.as_f64().ok_or_else(|| anyhow::anyhow!("'{k}' must be a number"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_design_point() {
        let c = AcceleratorConfig::default();
        assert_eq!(c.pe_count(), 256);
        assert_eq!(c.pe_capacity(), 1024);
        assert_eq!(c.peak_macs_per_cycle(), 4096);
        // Paper: 8192 half-precision FLOPs/cycle, 5464 GFLOPs/s.
        assert!((c.peak_flops() - 5.465e12).abs() / 5.465e12 < 0.01);
        // Paper node power: 19.2 W.
        assert!((c.node_power_w() - 19.2).abs() < 0.01);
        // DRAM: 16 × 12.6 GB/s.
        assert!((c.dram_bw() - 201.6e9).abs() < 1.0);
        c.validate().unwrap();
    }

    #[test]
    fn json_roundtrip_and_unknown_key() {
        let c = AcceleratorConfig::default();
        let j = c.to_json();
        let c2 = AcceleratorConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
        let bad = Json::parse(r#"{"txx": 4}"#).unwrap();
        assert!(AcceleratorConfig::from_json(&bad).is_err());
    }

    #[test]
    fn fingerprint_tracks_hardware_changes() {
        let base = AcceleratorConfig::default();
        assert_eq!(base.fingerprint(), AcceleratorConfig::default().fingerprint());
        let grid = AcceleratorConfig { tx: 8, ty: 8, ..base.clone() };
        assert_ne!(grid.fingerprint(), base.fingerprint());
        let thr = AcceleratorConfig { wr_threshold: 0.5, ..base.clone() };
        assert_ne!(thr.fingerprint(), base.fingerprint());
        let mut mem = base.clone();
        mem.memory.dram_channels = 8;
        assert_ne!(mem.fingerprint(), base.fingerprint());
        let mut en = base.clone();
        en.energy.pe_total_w = 0.1;
        assert_ne!(en.fingerprint(), base.fingerprint());
    }

    #[test]
    fn validation_catches_bad_offsets() {
        let mut c = AcceleratorConfig::default();
        c.offset_bits = 4; // 16 < 32 entries
        assert!(c.validate().is_err());
        c.offset_bits = 5;
        c.lanes = 12; // not a power of two
        assert!(c.validate().is_err());
    }
}
