//! # AGOS — Activation-based Gradient Output Sparsity accelerator
//!
//! Reproduction of *"Exploiting Activation based Gradient Output Sparsity
//! to Accelerate Backpropagation in CNNs"* (Sarma et al., 2021).
//!
//! The crate is the L3 (rust) layer of a three-layer stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the masked
//!   backward GEMM that realizes output-sparsity skipping on TPU-style
//!   hardware, checked against a pure-`jnp` oracle.
//! * **L2** — a JAX CNN model (`python/compile/model.py`) whose forward,
//!   backward and train-step graphs are AOT-lowered once to HLO text.
//! * **L3** — this crate: the PJRT runtime that executes those artifacts,
//!   the training coordinator that extracts activation/gradient sparsity
//!   traces, and — the paper's contribution — a cycle-level simulator of
//!   the proposed sparse-training accelerator, its baselines, and the
//!   report generators for every figure and table in the evaluation.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index.

pub mod util;
pub mod config;
pub mod nn;
pub mod sparsity;
pub mod sim;
pub mod scenario;
pub mod baselines;
pub mod trace;
pub mod runtime;
pub mod coordinator;
pub mod report;
pub mod serve;
pub mod cli;
