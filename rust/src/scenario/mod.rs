//! Declarative scenario files: one JSON document that expands — fully
//! seeded and deterministically — into a family of (network ×
//! sparsity-phase × scheme) sweep combos for the cached `SweepRunner`.
//!
//! The paper's speedups are *trajectories*: activation/gradient
//! sparsity grows over a training run, so a single-density sweep
//! understates late-epoch gains and overstates early ones. A scenario
//! file names the workload family once —
//!
//! * **generators** ([`ScenarioGenerator`]): hand-written zoo entries,
//!   programmatically swept conv ladders and residual towers, and
//!   adversarial replay patterns ([`AdversarialPattern`]);
//! * a **schedule** ([`SparsitySchedule`]): named phases (early/mid/
//!   late) whose `scale` multiplies the calibrated model's ReLU
//!   fractions, modeling sparsity growth across epochs;
//! * **schemes**: the same `--schemes` spec the CLI takes
//!
//! — and `agos sweep --scenario <file>` fans the whole expansion
//! through the cached parallel runner. Determinism contract:
//!
//! * Expansion is a pure function of the file: same bytes ⇒ same plan,
//!   same combo order, same labels, at any `--jobs` level.
//! * The file's `seed` overrides the CLI `--seed` (a scenario is
//!   self-contained; results must not depend on who runs it).
//! * [`ScenarioFile::fingerprint`] — an FNV over the *canonical*
//!   serialized form, defaults expanded — is stamped into every combo's
//!   `SimOptions::scenario_fingerprint`, so scenario results can never
//!   alias a hand-written grid (or another scenario) in the sweep
//!   cache. Phases additionally separate through the scaled model's
//!   fingerprint, adversarial points through their trace fingerprint.
//!
//! Schema reference: `rust/docs/SCENARIOS.md`. Runnable examples:
//! `rust/examples/scenarios/`.

mod adversarial;
mod generators;

pub use adversarial::{adversarial_trace, pattern_bitmap, AdversarialPattern};
pub use generators::ScenarioGenerator;

use std::collections::HashSet;
use std::path::Path;
use std::sync::Arc;

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::nn::{Network, Phase};
use crate::report::Figure;
use crate::sim::{NetworkSimResult, ReplayBank, SweepPlan, SweepRunner};
use crate::sparsity::SparsityModel;
use crate::util::json::Json;

/// One phase of a sparsity schedule: a display name and the multiplier
/// applied to the calibrated model's ReLU fractions
/// (`SparsityModel::sparsity_scale`).
#[derive(Clone, Debug, PartialEq)]
pub struct SchedulePhase {
    pub name: String,
    pub scale: f64,
}

/// A sparsity trajectory across a simulated training run, as an ordered
/// list of phases. Scales below 1 model early epochs (denser maps),
/// above 1 late epochs (sparser maps, clamped at 0.95 per layer).
#[derive(Clone, Debug, PartialEq)]
pub struct SparsitySchedule {
    pub phases: Vec<SchedulePhase>,
}

impl Default for SparsitySchedule {
    /// The schedule a file without one gets: a single identity phase,
    /// reducing the scenario to today's single-point sweeps.
    fn default() -> SparsitySchedule {
        SparsitySchedule {
            phases: vec![SchedulePhase { name: "base".to_string(), scale: 1.0 }],
        }
    }
}

impl SparsitySchedule {
    /// An evenly spaced ramp of `points` phases named `ramp0..rampN`,
    /// from `from` to `to` inclusive (`points == 1` yields just `from`).
    pub fn ramp(from: f64, to: f64, points: usize) -> SparsitySchedule {
        let n = points.max(1);
        let phases = (0..n)
            .map(|i| {
                let t = if n == 1 { 0.0 } else { i as f64 / (n - 1) as f64 };
                SchedulePhase { name: format!("ramp{i}"), scale: from + t * (to - from) }
            })
            .collect();
        SparsitySchedule { phases }
    }

    /// Parse either spelling — an explicit `phases` array or a `ramp`
    /// object — rejecting both-at-once and unknown keys.
    pub fn from_json(j: &Json) -> anyhow::Result<SparsitySchedule> {
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("schedule must be an object"))?;
        for k in obj.keys() {
            anyhow::ensure!(
                k == "phases" || k == "ramp",
                "unknown key '{k}' in schedule (allowed: phases, ramp)"
            );
        }
        let schedule = match (j.get("phases"), j.get("ramp")) {
            (Json::Null, Json::Null) => anyhow::bail!("schedule needs 'phases' or 'ramp'"),
            (p, Json::Null) => {
                let arr =
                    p.as_arr().ok_or_else(|| anyhow::anyhow!("phases: array of objects"))?;
                anyhow::ensure!(!arr.is_empty(), "phases must not be empty");
                let phases = arr
                    .iter()
                    .map(|e| {
                        if let Some(o) = e.as_obj() {
                            for k in o.keys() {
                                anyhow::ensure!(
                                    k == "name" || k == "scale",
                                    "unknown key '{k}' in phase (allowed: name, scale)"
                                );
                            }
                        }
                        let name = e
                            .req("name")?
                            .as_str()
                            .ok_or_else(|| anyhow::anyhow!("phase name: string"))?
                            .to_string();
                        let scale = e
                            .req("scale")?
                            .as_f64()
                            .ok_or_else(|| anyhow::anyhow!("phase scale: number"))?;
                        Ok(SchedulePhase { name, scale })
                    })
                    .collect::<anyhow::Result<Vec<_>>>()?;
                SparsitySchedule { phases }
            }
            (Json::Null, r) => {
                if let Some(o) = r.as_obj() {
                    for k in o.keys() {
                        anyhow::ensure!(
                            matches!(k.as_str(), "from" | "to" | "points"),
                            "unknown key '{k}' in ramp (allowed: from, to, points)"
                        );
                    }
                }
                let from = r.req("from")?.as_f64().ok_or_else(|| anyhow::anyhow!("from: number"))?;
                let to = r.req("to")?.as_f64().ok_or_else(|| anyhow::anyhow!("to: number"))?;
                let points =
                    r.req("points")?.as_usize().ok_or_else(|| anyhow::anyhow!("points: integer"))?;
                anyhow::ensure!(points >= 1, "ramp points must be >= 1");
                SparsitySchedule::ramp(from, to, points)
            }
            _ => anyhow::bail!("schedule takes 'phases' or 'ramp', not both"),
        };
        schedule.validate()?;
        Ok(schedule)
    }

    /// Canonical form: always the expanded `phases` array (a `ramp` and
    /// its equivalent phase list fingerprint identically — they expand
    /// to the same plan).
    pub fn to_json(&self) -> Json {
        let phases = self
            .phases
            .iter()
            .map(|p| {
                Json::from_pairs(vec![
                    ("name", p.name.as_str().into()),
                    ("scale", p.scale.into()),
                ])
            })
            .collect();
        Json::from_pairs(vec![("phases", Json::Arr(phases))])
    }

    fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.phases.is_empty(), "schedule must have at least one phase");
        let mut names = HashSet::new();
        for p in &self.phases {
            anyhow::ensure!(!p.name.is_empty(), "phase names must be non-empty");
            anyhow::ensure!(names.insert(p.name.clone()), "duplicate phase name '{}'", p.name);
            anyhow::ensure!(
                p.scale.is_finite() && p.scale > 0.0,
                "phase '{}': scale must be finite and > 0",
                p.name
            );
        }
        Ok(())
    }
}

/// A parsed scenario file. See the module docs for the expansion and
/// determinism contract, `docs/SCENARIOS.md` for the schema.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioFile {
    /// Schema version; only 1 exists.
    pub version: u64,
    /// Display name (report/figure titles). Default `"scenario"`.
    pub name: String,
    /// The one seed everything derives from: model draws, tower
    /// skip-placement, and the exact backend's sampling streams
    /// (it overrides `SimOptions::seed` at expansion). Default 0xA605.
    pub seed: u64,
    pub generators: Vec<ScenarioGenerator>,
    pub schedule: SparsitySchedule,
    /// Scheme spec in `--schemes` syntax (`Scheme::parse_list`).
    pub schemes: String,
}

impl ScenarioFile {
    pub fn from_json(j: &Json) -> anyhow::Result<ScenarioFile> {
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("scenario must be an object"))?;
        for k in obj.keys() {
            anyhow::ensure!(
                matches!(k.as_str(), "version" | "name" | "seed" | "generators" | "schedule" | "schemes"),
                "unknown key '{k}' in scenario \
                 (allowed: version, name, seed, generators, schedule, schemes)"
            );
        }
        let version =
            j.req("version")?.as_u64().ok_or_else(|| anyhow::anyhow!("version: integer"))?;
        anyhow::ensure!(version == 1, "unsupported scenario version {version} (only 1 exists)");
        let name = match j.get("name") {
            Json::Null => "scenario".to_string(),
            v => v.as_str().ok_or_else(|| anyhow::anyhow!("name: string"))?.to_string(),
        };
        let seed = match j.get("seed") {
            Json::Null => 0xA605,
            v => v.as_u64().ok_or_else(|| anyhow::anyhow!("seed: integer"))?,
        };
        let gens = j
            .req("generators")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("generators: array"))?;
        anyhow::ensure!(!gens.is_empty(), "generators must not be empty");
        let generators = gens
            .iter()
            .map(ScenarioGenerator::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let schedule = match j.get("schedule") {
            Json::Null => SparsitySchedule::default(),
            v => SparsitySchedule::from_json(v)?,
        };
        let schemes = match j.get("schemes") {
            Json::Null => "all".to_string(),
            v => v.as_str().ok_or_else(|| anyhow::anyhow!("schemes: string"))?.to_string(),
        };
        Scheme::parse_list(&schemes)?;
        Ok(ScenarioFile { version, name, seed, generators, schedule, schemes })
    }

    /// Canonical serialized form (defaults expanded, ramps unrolled);
    /// the domain of [`ScenarioFile::fingerprint`].
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("version", self.version.into()),
            ("name", self.name.as_str().into()),
            ("seed", self.seed.into()),
            ("generators", Json::Arr(self.generators.iter().map(|g| g.to_json()).collect())),
            ("schedule", self.schedule.to_json()),
            ("schemes", self.schemes.as_str().into()),
        ])
    }

    pub fn load(path: &Path) -> anyhow::Result<ScenarioFile> {
        ScenarioFile::from_json(&Json::parse_file(path)?)
            .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))
    }

    /// Content fingerprint: FNV-1a over the canonical dump. Two files
    /// that expand identically (e.g. a `ramp` vs its unrolled `phases`)
    /// share it; any field that changes the expansion changes it.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::fnv::Fnv1a::new();
        h.put_bytes(self.to_json().dump().as_bytes());
        h.finish()
    }

    /// Expand generators × schedule into labeled points. Adversarial
    /// generators cross with their *patterns* instead of the schedule
    /// (a fixed worst-case map has no epoch axis); each such point
    /// carries a ready replay bank. Labels (`network@phase`) must be
    /// unique — a file that expands two combos to the same label is
    /// rejected rather than silently folded by the cache.
    pub fn points(&self) -> anyhow::Result<Vec<ScenarioPoint>> {
        let mut points = Vec::new();
        let mut labels: HashSet<String> = HashSet::new();
        let mut push = |points: &mut Vec<ScenarioPoint>, p: ScenarioPoint| -> anyhow::Result<()> {
            anyhow::ensure!(
                labels.insert(p.label.clone()),
                "duplicate scenario point '{}' (same network and phase expanded twice)",
                p.label
            );
            points.push(p);
            Ok(())
        };
        for g in &self.generators {
            if let ScenarioGenerator::Adversarial { patterns, .. } = g {
                let net = &g.networks(self.seed)?[0];
                for &pattern in patterns {
                    let trace = adversarial_trace(net, pattern);
                    let trace_fp = trace.fingerprint();
                    let bank = Arc::new(ReplayBank::from_trace(net, &trace)?);
                    push(
                        &mut points,
                        ScenarioPoint {
                            label: format!("{}@{}", net.name, pattern.label()),
                            phase: pattern.label().to_string(),
                            network: net.clone(),
                            model: SparsityModel::synthetic(self.seed),
                            replay: Some((bank, trace_fp)),
                        },
                    )?;
                }
            } else {
                for net in g.networks(self.seed)? {
                    for phase in &self.schedule.phases {
                        push(
                            &mut points,
                            ScenarioPoint {
                                label: format!("{}@{}", net.name, phase.name),
                                phase: phase.name.clone(),
                                network: net.clone(),
                                model: SparsityModel::synthetic(self.seed)
                                    .with_scale(phase.scale),
                                replay: None,
                            },
                        )?;
                    }
                }
            }
        }
        anyhow::ensure!(!points.is_empty(), "scenario expanded to zero points");
        Ok(points)
    }

    /// Full expansion to an executable plan. `base` contributes the
    /// request-level knobs a scenario deliberately does not own (batch,
    /// backend, exact cap, gather plans); the file's seed and
    /// fingerprint override/stamp the rest. Combo order is point-major:
    /// combo `i` is `points[i / schemes.len()]` under
    /// `schemes[i % schemes.len()]`.
    pub fn expand(
        &self,
        cfg: &AcceleratorConfig,
        base: &SimOptions,
    ) -> anyhow::Result<ExpandedScenario> {
        let schemes = Scheme::parse_list(&self.schemes)?;
        let fingerprint = self.fingerprint();
        let mut opts = base.clone();
        opts.seed = self.seed;
        opts.scenario_fingerprint = Some(fingerprint);
        // Replay is per-point here; a stray request-level bank (wrong
        // network entirely) must not leak into generated combos.
        opts.replay = None;
        opts.trace_fingerprint = None;
        let points = self.points()?;
        let mut plan = SweepPlan::new();
        for p in &points {
            let mut popts = opts.clone();
            if let Some((bank, trace_fp)) = &p.replay {
                popts.replay = Some(bank.clone());
                popts.trace_fingerprint = Some(*trace_fp);
            }
            for &scheme in &schemes {
                plan.push_with_model(p.network.clone(), scheme, cfg, &popts, p.model.clone());
            }
        }
        Ok(ExpandedScenario { name: self.name.clone(), fingerprint, points, schemes, plan, opts })
    }
}

/// One (network, phase) cell of the expansion, before the scheme axis.
#[derive(Clone, Debug)]
pub struct ScenarioPoint {
    /// `network@phase` — the trajectory figure's row label.
    pub label: String,
    pub phase: String,
    pub network: Network,
    /// The phase's scaled model (identity-scaled for adversarial points,
    /// whose sparsity comes from the replayed pattern instead).
    pub model: SparsityModel,
    /// Adversarial points: the pattern's replay bank and its trace
    /// fingerprint, armed on every scheme combo of this point.
    pub replay: Option<(Arc<ReplayBank>, u64)>,
}

/// A scenario ready to run: the labeled points, the parsed schemes, the
/// point-major [`SweepPlan`], and the stamped base options (provenance
/// for the report header).
#[derive(Clone, Debug)]
pub struct ExpandedScenario {
    pub name: String,
    pub fingerprint: u64,
    pub points: Vec<ScenarioPoint>,
    pub schemes: Vec<Scheme>,
    pub plan: SweepPlan,
    pub opts: SimOptions,
}

impl ExpandedScenario {
    /// Execute through the runner's cache. The plan-wide fallback model
    /// is never consulted (every combo carries its phase's override),
    /// so this is a pure function of the expansion — bit-identical at
    /// any `jobs` level by the runner's contract.
    pub fn run(&self, runner: &SweepRunner) -> Vec<Arc<NetworkSimResult>> {
        runner.run(&self.plan, &SparsityModel::synthetic(self.opts.seed))
    }

    /// Results for one point in scheme order.
    fn point_results<'a>(
        &self,
        pi: usize,
        results: &'a [Arc<NetworkSimResult>],
    ) -> &'a [Arc<NetworkSimResult>] {
        let ns = self.schemes.len();
        &results[pi * ns..(pi + 1) * ns]
    }
}

/// The trajectory figure: one row per (network, phase) point. With DC
/// in the schemes (the usual case) the columns are each sparse scheme's
/// speedup over DC *within that phase* — reading down a network's rows
/// is the paper's speedup-over-training trajectory. Without DC there is
/// no ratio to form, so the columns fall back to raw total cycles.
pub fn trajectory_figure(ex: &ExpandedScenario, results: &[Arc<NetworkSimResult>]) -> Figure {
    assert_eq!(
        ex.points.len() * ex.schemes.len(),
        results.len(),
        "results must match the expansion"
    );
    let dense_at = ex.schemes.iter().position(|s| *s == Scheme::Dense);
    let ratio_cols: Vec<&'static str> = ex
        .schemes
        .iter()
        .filter(|s| **s != Scheme::Dense)
        .map(|s| s.label())
        .collect();
    let use_ratios = dense_at.is_some() && !ratio_cols.is_empty();
    let (title, cols) = if use_ratios {
        (format!("{}: speedup vs DC per phase", ex.name), ratio_cols)
    } else {
        (
            format!("{}: total cycles per phase", ex.name),
            ex.schemes.iter().map(|s| s.label()).collect(),
        )
    };
    let mut fig = Figure::new("trajectory", &title, &cols);
    for (pi, point) in ex.points.iter().enumerate() {
        let prs = ex.point_results(pi, results);
        let row: Vec<f64> = if use_ratios {
            let dc = prs[dense_at.unwrap()].total_cycles() as f64;
            ex.schemes
                .iter()
                .zip(prs)
                .filter(|(s, _)| **s != Scheme::Dense)
                .map(|(_, r)| dc / r.total_cycles() as f64)
                .collect()
        } else {
            prs.iter().map(|r| r.total_cycles() as f64).collect()
        };
        fig.row(&point.label, row);
    }
    fig
}

/// The scenario report — what `agos sweep --scenario --out` writes and
/// what a served scenario `sweep` request returns: provenance header,
/// one row per (point, scheme) combo in plan order, and the trajectory
/// figure. Like `sweep_report_json` it carries **no** wall-clock or
/// thread-count fields: a pure function of the file and the request
/// knobs, byte-identical at any `--jobs` level and across serve/CLI.
pub fn scenario_report_json(ex: &ExpandedScenario, results: &[Arc<NetworkSimResult>]) -> Json {
    assert_eq!(
        ex.points.len() * ex.schemes.len(),
        results.len(),
        "results must match the expansion"
    );
    let mut combos = Vec::new();
    for (pi, point) in ex.points.iter().enumerate() {
        for (si, scheme) in ex.schemes.iter().enumerate() {
            let r = &results[pi * ex.schemes.len() + si];
            combos.push(Json::from_pairs(vec![
                ("network", point.network.name.as_str().into()),
                ("phase", point.phase.as_str().into()),
                ("scheme", scheme.label().into()),
                ("total_cycles", r.total_cycles().into()),
                ("bp_cycles", r.phase(Phase::Backward).cycles.into()),
                ("energy_j", r.total_energy_j().into()),
            ]));
        }
    }
    Json::from_pairs(vec![
        ("scenario", ex.name.as_str().into()),
        ("fingerprint", format!("{:016x}", ex.fingerprint).into()),
        ("seed", ex.opts.seed.into()),
        ("batch", ex.opts.batch.into()),
        ("backend", ex.opts.backend.label().into()),
        ("combos", Json::Arr(combos)),
        ("trajectory", trajectory_figure(ex, results).to_json()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn minimal(extra: &str) -> String {
        format!(
            r#"{{"version": 1, "generators": [{{"kind": "zoo", "networks": "agos_cnn"}}]{extra}}}"#
        )
    }

    fn parse(text: &str) -> anyhow::Result<ScenarioFile> {
        ScenarioFile::from_json(&Json::parse(text).unwrap())
    }

    #[test]
    fn defaults_fill_in_and_roundtrip_canonically() {
        let f = parse(&minimal("")).unwrap();
        assert_eq!(f.name, "scenario");
        assert_eq!(f.seed, 0xA605);
        assert_eq!(f.schemes, "all");
        assert_eq!(f.schedule.phases.len(), 1);
        assert_eq!(f.schedule.phases[0].scale, 1.0);
        let again = ScenarioFile::from_json(&f.to_json()).unwrap();
        assert_eq!(f, again);
        assert_eq!(f.fingerprint(), again.fingerprint());
    }

    #[test]
    fn ramp_expands_evenly_and_fingerprints_like_its_phase_list() {
        let r = SparsitySchedule::ramp(0.5, 1.5, 3);
        assert_eq!(r.phases.len(), 3);
        assert!((r.phases[1].scale - 1.0).abs() < 1e-12);
        assert_eq!(r.phases[2].name, "ramp2");
        assert_eq!(SparsitySchedule::ramp(0.7, 2.0, 1).phases[0].scale, 0.7);

        let via_ramp = parse(&minimal(
            r#", "schedule": {"ramp": {"from": 0.5, "to": 1.5, "points": 3}}"#,
        ))
        .unwrap();
        let via_phases = parse(&minimal(
            r#", "schedule": {"phases": [
                {"name": "ramp0", "scale": 0.5},
                {"name": "ramp1", "scale": 1.0},
                {"name": "ramp2", "scale": 1.5}]}"#,
        ))
        .unwrap();
        assert_eq!(via_ramp.fingerprint(), via_phases.fingerprint());
    }

    #[test]
    fn strict_parsing_rejects_bad_files() {
        assert!(parse(r#"{"version": 2, "generators": []}"#).is_err(), "bad version");
        assert!(parse(&minimal(r#", "sched": {}"#)).is_err(), "unknown key");
        assert!(parse(r#"{"version": 1, "generators": []}"#).is_err(), "empty generators");
        assert!(
            parse(&minimal(r#", "schedule": {"phases": [], "ramp": {}}"#)).is_err(),
            "phases and ramp together"
        );
        assert!(
            parse(&minimal(r#", "schedule": {"phases": [{"name": "a", "scale": 0.0}]}"#)).is_err(),
            "zero scale"
        );
        assert!(
            parse(&minimal(
                r#", "schedule": {"phases": [
                    {"name": "a", "scale": 1.0}, {"name": "a", "scale": 2.0}]}"#
            ))
            .is_err(),
            "duplicate phase names"
        );
        assert!(parse(&minimal(r#", "schemes": "dc,teleport""#)).is_err(), "bad scheme");
    }

    #[test]
    fn fingerprint_tracks_every_field() {
        let base = parse(&minimal("")).unwrap();
        for extra in [
            r#", "name": "other""#,
            r#", "seed": 9"#,
            r#", "schemes": "dc,in""#,
            r#", "schedule": {"phases": [{"name": "late", "scale": 1.4}]}"#,
        ] {
            let v = parse(&minimal(extra)).unwrap();
            assert_ne!(base.fingerprint(), v.fingerprint(), "{extra}");
        }
        let other_gen = parse(
            r#"{"version": 1, "generators": [{"kind": "zoo", "networks": "agos_resnet"}]}"#,
        )
        .unwrap();
        assert_ne!(base.fingerprint(), other_gen.fingerprint());
    }

    #[test]
    fn expansion_crosses_phases_and_rejects_duplicate_labels() {
        let f = parse(&minimal(
            r#", "schedule": {"phases": [
                {"name": "early", "scale": 0.5}, {"name": "late", "scale": 1.4}]},
               "schemes": "dc,in+out""#,
        ))
        .unwrap();
        let points = f.points().unwrap();
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].label, "agos_cnn@early");
        assert_eq!(points[1].label, "agos_cnn@late");
        assert_eq!(points[0].model.sparsity_scale, 0.5);

        let ex = f.expand(&AcceleratorConfig::default(), &SimOptions::default()).unwrap();
        assert_eq!(ex.plan.len(), 4, "2 points × 2 schemes");
        assert_eq!(ex.opts.scenario_fingerprint, Some(f.fingerprint()));
        assert_eq!(ex.opts.seed, f.seed);
        assert!(ex.plan.combos.iter().all(|c| c.model.is_some()));
        assert!(ex
            .plan
            .combos
            .iter()
            .all(|c| c.opts.scenario_fingerprint == Some(f.fingerprint())));

        // The same network listed twice expands to colliding labels.
        let dup = parse(
            r#"{"version": 1, "generators": [
                {"kind": "zoo", "networks": "agos_cnn"},
                {"kind": "zoo", "networks": "agos_cnn"}]}"#,
        )
        .unwrap();
        let err = dup.points().unwrap_err().to_string();
        assert!(err.contains("agos_cnn@base"), "{err}");
    }

    #[test]
    fn adversarial_points_skip_the_schedule_and_carry_banks() {
        let f = parse(
            r#"{"version": 1,
                "generators": [{"kind": "adversarial", "network": "agos_cnn"}],
                "schedule": {"phases": [
                    {"name": "early", "scale": 0.5}, {"name": "late", "scale": 1.4}]}}"#,
        )
        .unwrap();
        let points = f.points().unwrap();
        assert_eq!(points.len(), AdversarialPattern::ALL.len(), "patterns, not phases");
        for p in &points {
            let (_, fp) = p.replay.as_ref().expect("adversarial points carry banks");
            assert_ne!(*fp, 0);
            assert_eq!(p.model.sparsity_scale, 1.0);
        }
        let ex = f.expand(&AcceleratorConfig::default(), &SimOptions::default()).unwrap();
        assert!(ex.plan.combos.iter().all(|c| c.opts.replay.is_some()));
    }
}
