//! The generator variants a scenario file's `generators` array may
//! name, and the programmatic network construction behind them.
//!
//! Every variant expands **deterministically**: the same generator
//! object under the same scenario seed always yields the same list of
//! `nn::Network` graphs, in the same order, with the same structural
//! fingerprints. Generated graphs go through `Network::validate()`
//! before they leave this module, so a scenario file can never hand the
//! simulator a malformed graph.

use crate::nn::{zoo, Network};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

use super::adversarial::AdversarialPattern;

/// One entry of a scenario file's `generators` array, tagged by its
/// JSON `kind` field (the Frog `ScenarioGenerator` idiom: a declarative,
/// serializable enum that expands into seeded families).
#[derive(Clone, Debug, PartialEq)]
pub enum ScenarioGenerator {
    /// `kind: "zoo"` — hand-written zoo entries by the same
    /// comma-separated spec `--networks` takes (`nn::zoo::by_list`).
    Zoo { networks: String },
    /// `kind: "conv_ladder"` — plain conv–ReLU ladders swept over the
    /// cross product `depths × widths × kernels × strides`, each ending
    /// in GAP → FC → softmax. The first conv carries the stride; the
    /// rest are stride-1 at `pad = k/2` so depth never collapses the
    /// spatial extent.
    ConvLadder {
        depths: Vec<usize>,
        widths: Vec<usize>,
        kernels: Vec<usize>,
        strides: Vec<usize>,
        input: (usize, usize, usize),
        classes: usize,
    },
    /// `kind: "residual_tower"` — stem conv + `blocks` two-conv blocks
    /// swept over `blocks × widths`; each block independently carries a
    /// skip `Add` with probability `residual_density`, drawn from an RNG
    /// seeded by (scenario seed, tower name) so the draw is stable under
    /// reordering of the generator list.
    ResidualTower {
        blocks: Vec<usize>,
        widths: Vec<usize>,
        residual_density: f64,
        input: (usize, usize, usize),
        classes: usize,
    },
    /// `kind: "adversarial"` — one zoo network replayed under
    /// deterministic worst/degenerate-case bitmaps
    /// (`scenario::adversarial`) instead of sampled ones.
    Adversarial { network: String, patterns: Vec<AdversarialPattern> },
}

const LADDER_KEYS: [&str; 7] =
    ["kind", "depths", "widths", "kernels", "strides", "input", "classes"];
const TOWER_KEYS: [&str; 6] =
    ["kind", "blocks", "widths", "residual_density", "input", "classes"];

impl ScenarioGenerator {
    /// Parse one `generators` array entry. Unknown keys are errors (a
    /// typo'd field must not silently fall back to its default).
    pub fn from_json(j: &Json) -> anyhow::Result<ScenarioGenerator> {
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("generator must be an object"))?;
        let kind = j.req("kind")?.as_str().ok_or_else(|| anyhow::anyhow!("kind: string"))?;
        let check_keys = |allowed: &[&str]| -> anyhow::Result<()> {
            for k in obj.keys() {
                anyhow::ensure!(
                    allowed.contains(&k.as_str()),
                    "unknown key '{k}' in '{kind}' generator (allowed: {})",
                    allowed.join(", ")
                );
            }
            Ok(())
        };
        match kind {
            "zoo" => {
                check_keys(&["kind", "networks"])?;
                let networks = req_str(j, "networks")?;
                // Fail at parse time, not expansion time: surface bad
                // zoo references with by_list's full-context error.
                zoo::by_list(&networks)?;
                Ok(ScenarioGenerator::Zoo { networks })
            }
            "conv_ladder" => {
                check_keys(&LADDER_KEYS)?;
                let g = ScenarioGenerator::ConvLadder {
                    depths: usize_list(j.req("depths")?, "depths")?,
                    widths: usize_list(j.req("widths")?, "widths")?,
                    kernels: opt_usize_list(j, "kernels", &[3])?,
                    strides: opt_usize_list(j, "strides", &[1])?,
                    input: shape3(j, "input", (3, 32, 32))?,
                    classes: opt_usize(j, "classes", 10)?,
                };
                g.validate()?;
                Ok(g)
            }
            "residual_tower" => {
                check_keys(&TOWER_KEYS)?;
                let density = match j.get("residual_density") {
                    Json::Null => 1.0,
                    v => v
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("residual_density: number"))?,
                };
                let g = ScenarioGenerator::ResidualTower {
                    blocks: usize_list(j.req("blocks")?, "blocks")?,
                    widths: usize_list(j.req("widths")?, "widths")?,
                    residual_density: density,
                    input: shape3(j, "input", (3, 32, 32))?,
                    classes: opt_usize(j, "classes", 10)?,
                };
                g.validate()?;
                Ok(g)
            }
            "adversarial" => {
                check_keys(&["kind", "network", "patterns"])?;
                let network = req_str(j, "network")?;
                zoo::by_name(&network)?;
                let patterns = match j.get("patterns") {
                    Json::Null => AdversarialPattern::ALL.to_vec(),
                    v => {
                        let arr = v
                            .as_arr()
                            .ok_or_else(|| anyhow::anyhow!("patterns: array of strings"))?;
                        anyhow::ensure!(!arr.is_empty(), "patterns must not be empty");
                        arr.iter()
                            .map(|p| {
                                let s = p
                                    .as_str()
                                    .ok_or_else(|| anyhow::anyhow!("patterns: array of strings"))?;
                                AdversarialPattern::parse(s)
                            })
                            .collect::<anyhow::Result<Vec<_>>>()?
                    }
                };
                Ok(ScenarioGenerator::Adversarial { network, patterns })
            }
            other => anyhow::bail!(
                "unknown generator kind '{other}' (zoo|conv_ladder|residual_tower|adversarial)"
            ),
        }
    }

    /// Canonical serialized form: every field is emitted, defaults
    /// included, so the scenario fingerprint (an FNV over this dump)
    /// never depends on which spelling the author chose.
    pub fn to_json(&self) -> Json {
        match self {
            ScenarioGenerator::Zoo { networks } => Json::from_pairs(vec![
                ("kind", "zoo".into()),
                ("networks", networks.as_str().into()),
            ]),
            ScenarioGenerator::ConvLadder { depths, widths, kernels, strides, input, classes } => {
                Json::from_pairs(vec![
                    ("kind", "conv_ladder".into()),
                    ("depths", json_list(depths)),
                    ("widths", json_list(widths)),
                    ("kernels", json_list(kernels)),
                    ("strides", json_list(strides)),
                    ("input", json_shape(*input)),
                    ("classes", (*classes).into()),
                ])
            }
            ScenarioGenerator::ResidualTower { blocks, widths, residual_density, input, classes } => {
                Json::from_pairs(vec![
                    ("kind", "residual_tower".into()),
                    ("blocks", json_list(blocks)),
                    ("widths", json_list(widths)),
                    ("residual_density", (*residual_density).into()),
                    ("input", json_shape(*input)),
                    ("classes", (*classes).into()),
                ])
            }
            ScenarioGenerator::Adversarial { network, patterns } => Json::from_pairs(vec![
                ("kind", "adversarial".into()),
                ("network", network.as_str().into()),
                (
                    "patterns",
                    Json::Arr(patterns.iter().map(|p| p.label().into()).collect()),
                ),
            ]),
        }
    }

    fn validate(&self) -> anyhow::Result<()> {
        match self {
            ScenarioGenerator::ConvLadder { depths, kernels, strides, input, classes, .. } => {
                anyhow::ensure!(depths.iter().all(|&d| d >= 1), "depths must be >= 1");
                anyhow::ensure!(
                    kernels.iter().all(|&k| k % 2 == 1),
                    "kernels must be odd (pad = k/2 keeps stride-1 shapes exact)"
                );
                anyhow::ensure!(strides.iter().all(|&s| s >= 1), "strides must be >= 1");
                let (c, h, w) = *input;
                anyhow::ensure!(c >= 1 && h >= 1 && w >= 1, "input dims must be >= 1");
                anyhow::ensure!(*classes >= 1, "classes must be >= 1");
                Ok(())
            }
            ScenarioGenerator::ResidualTower { blocks, residual_density, input, classes, .. } => {
                anyhow::ensure!(blocks.iter().all(|&b| b >= 1), "blocks must be >= 1");
                anyhow::ensure!(
                    (0.0..=1.0).contains(residual_density),
                    "residual_density must be in [0, 1]"
                );
                let (c, h, w) = *input;
                anyhow::ensure!(c >= 1 && h >= 1 && w >= 1, "input dims must be >= 1");
                anyhow::ensure!(*classes >= 1, "classes must be >= 1");
                Ok(())
            }
            _ => Ok(()),
        }
    }

    /// Expand this generator into concrete networks. `seed` is the
    /// scenario file's seed (only `residual_tower` draws from it).
    /// Adversarial generators expand here to their base network; the
    /// per-pattern replay banks are built during `ScenarioFile`
    /// expansion, where the pattern axis is crossed in.
    pub fn networks(&self, seed: u64) -> anyhow::Result<Vec<Network>> {
        let nets = match self {
            ScenarioGenerator::Zoo { networks } => zoo::by_list(networks)?,
            ScenarioGenerator::ConvLadder { depths, widths, kernels, strides, input, classes } => {
                let mut out = Vec::new();
                for &d in depths {
                    for &w in widths {
                        for &k in kernels {
                            for &s in strides {
                                out.push(conv_ladder(d, w, k, s, *input, *classes)?);
                            }
                        }
                    }
                }
                out
            }
            ScenarioGenerator::ResidualTower { blocks, widths, residual_density, input, classes } => {
                let mut out = Vec::new();
                for &b in blocks {
                    for &w in widths {
                        out.push(residual_tower(b, w, *residual_density, *input, *classes, seed)?);
                    }
                }
                out
            }
            ScenarioGenerator::Adversarial { network, .. } => vec![zoo::by_name(network)?],
        };
        for net in &nets {
            net.validate().map_err(|e| anyhow::anyhow!("generated '{}': {e}", net.name))?;
        }
        Ok(nets)
    }
}

/// `ladder_d{depth}_w{width}_k{k}_s{stride}`: conv–ReLU × depth, the
/// stride on the first conv only, then GAP → FC → softmax.
fn conv_ladder(
    depth: usize,
    width: usize,
    k: usize,
    stride: usize,
    (c, h, w): (usize, usize, usize),
    classes: usize,
) -> anyhow::Result<Network> {
    let name = format!("ladder_d{depth}_w{width}_k{k}_s{stride}");
    anyhow::ensure!(
        h + 2 * (k / 2) >= k && w + 2 * (k / 2) >= k,
        "{name}: {k}×{k} window larger than padded {h}×{w} input"
    );
    let mut n = Network::new(&name);
    let mut cur = n.input(c, h, w);
    for i in 0..depth {
        let s = if i == 0 { stride } else { 1 };
        let conv = n.conv(&format!("conv{}", i + 1), cur, width, k, s, k / 2);
        cur = n.relu(&format!("relu{}", i + 1), conv);
    }
    let g = n.gap("gap", cur);
    let f = n.fc("fc", g, classes);
    n.softmax("prob", f);
    Ok(n)
}

/// `tower_b{blocks}_w{width}_r{pct}`: stem conv–ReLU, then `blocks`
/// two-conv blocks where each block's skip `Add` is an independent
/// Bernoulli(`residual_density`) draw from an RNG seeded by the tower's
/// *name* and the scenario seed — stable under generator reordering,
/// and a draw is consumed per block whether or not the skip lands, so
/// block `i`'s fate never depends on block `i-1`'s.
fn residual_tower(
    blocks: usize,
    width: usize,
    residual_density: f64,
    (c, h, w): (usize, usize, usize),
    classes: usize,
    seed: u64,
) -> anyhow::Result<Network> {
    let pct = (residual_density * 100.0).round() as u64;
    let name = format!("tower_b{blocks}_w{width}_r{pct}");
    let mut n = Network::new(&name);
    let x = n.input(c, h, w);
    let stem = n.conv("stem", x, width, 3, 1, 1);
    let mut cur = n.relu("stem_relu", stem);
    let mut rng = Pcg32::new(seed ^ hash_str(&name));
    for b in 0..blocks {
        let c1 = n.conv(&format!("b{b}_conv1"), cur, width, 3, 1, 1);
        let r1 = n.relu(&format!("b{b}_relu1"), c1);
        let c2 = n.conv(&format!("b{b}_conv2"), r1, width, 3, 1, 1);
        let skip = rng.bernoulli(residual_density);
        cur = if skip {
            let a = n.add(&format!("b{b}_add"), c2, cur);
            n.relu(&format!("b{b}_relu2"), a)
        } else {
            n.relu(&format!("b{b}_relu2"), c2)
        };
    }
    let g = n.gap("gap", cur);
    let f = n.fc("fc", g, classes);
    n.softmax("prob", f);
    Ok(n)
}

fn hash_str(s: &str) -> u64 {
    let mut h = crate::util::fnv::Fnv1a::new();
    h.put_bytes(s.as_bytes());
    h.finish()
}

fn req_str(j: &Json, key: &str) -> anyhow::Result<String> {
    Ok(j.req(key)?
        .as_str()
        .ok_or_else(|| anyhow::anyhow!("{key}: string"))?
        .to_string())
}

fn usize_list(j: &Json, what: &str) -> anyhow::Result<Vec<usize>> {
    let arr = j.as_arr().ok_or_else(|| anyhow::anyhow!("{what}: array of integers"))?;
    anyhow::ensure!(!arr.is_empty(), "{what} must not be empty");
    arr.iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("{what}: array of integers")))
        .collect()
}

fn opt_usize_list(j: &Json, key: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
    match j.get(key) {
        Json::Null => Ok(default.to_vec()),
        v => usize_list(v, key),
    }
}

fn opt_usize(j: &Json, key: &str, default: usize) -> anyhow::Result<usize> {
    match j.get(key) {
        Json::Null => Ok(default),
        v => v.as_usize().ok_or_else(|| anyhow::anyhow!("{key}: integer")),
    }
}

/// `input` is a `[c, h, w]` triple (same notation as trace shapes).
fn shape3(
    j: &Json,
    key: &str,
    default: (usize, usize, usize),
) -> anyhow::Result<(usize, usize, usize)> {
    match j.get(key) {
        Json::Null => Ok(default),
        v => {
            let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("{key}: [c, h, w]"))?;
            anyhow::ensure!(arr.len() == 3, "{key}: [c, h, w]");
            let d = |i: usize| {
                arr[i].as_usize().ok_or_else(|| anyhow::anyhow!("{key}[{i}]: integer"))
            };
            Ok((d(0)?, d(1)?, d(2)?))
        }
    }
}

fn json_list(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| x.into()).collect())
}

fn json_shape((c, h, w): (usize, usize, usize)) -> Json {
    Json::Arr(vec![c.into(), h.into(), w.into()])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_family_is_the_cross_product_and_validates() {
        let g = ScenarioGenerator::ConvLadder {
            depths: vec![2, 4],
            widths: vec![8, 16],
            kernels: vec![3, 5],
            strides: vec![1, 2],
            input: (3, 32, 32),
            classes: 10,
        };
        let nets = g.networks(7).unwrap();
        assert_eq!(nets.len(), 16);
        let names: std::collections::HashSet<_> = nets.iter().map(|n| n.name.clone()).collect();
        assert_eq!(names.len(), 16, "every family member is distinctly named");
        assert!(names.contains("ladder_d4_w16_k5_s2"));
        // The stride only hits the first conv: a d4 s2 ladder still has
        // a 16×16 map after conv1 and keeps it to the end.
        let d4 = nets.iter().find(|n| n.name == "ladder_d4_w16_k5_s2").unwrap();
        let last_relu = d4.by_name("relu4").unwrap();
        assert_eq!((last_relu.out.h, last_relu.out.w), (16, 16));
    }

    #[test]
    fn tower_density_draws_are_seed_stable() {
        let g = ScenarioGenerator::ResidualTower {
            blocks: vec![4],
            widths: vec![8],
            residual_density: 0.5,
            input: (3, 16, 16),
            classes: 10,
        };
        let a = g.networks(7).unwrap();
        let b = g.networks(7).unwrap();
        assert_eq!(a[0].fingerprint(), b[0].fingerprint(), "same seed, same structure");
        // Extremes: r=1.0 puts an Add in every block, r=0.0 in none.
        let all = ScenarioGenerator::ResidualTower {
            blocks: vec![3],
            widths: vec![8],
            residual_density: 1.0,
            input: (3, 16, 16),
            classes: 10,
        };
        let none = ScenarioGenerator::ResidualTower {
            blocks: vec![3],
            widths: vec![8],
            residual_density: 0.0,
            input: (3, 16, 16),
            classes: 10,
        };
        let count_adds = |n: &Network| {
            n.layers()
                .iter()
                .filter(|l| matches!(l.kind, crate::nn::LayerKind::Add))
                .count()
        };
        assert_eq!(count_adds(&all.networks(1).unwrap()[0]), 3);
        assert_eq!(count_adds(&none.networks(1).unwrap()[0]), 0);
    }

    #[test]
    fn parse_rejects_unknown_keys_bad_kinds_and_bad_zoo_names() {
        let bad_kind = Json::parse(r#"{"kind": "teleport"}"#).unwrap();
        assert!(ScenarioGenerator::from_json(&bad_kind).is_err());
        let typo = Json::parse(r#"{"kind": "conv_ladder", "depths": [2], "widths": [8], "strids": [1]}"#)
            .unwrap();
        let err = ScenarioGenerator::from_json(&typo).unwrap_err().to_string();
        assert!(err.contains("strids"), "{err}");
        let bad_net = Json::parse(r#"{"kind": "zoo", "networks": "alexnet"}"#).unwrap();
        let err = ScenarioGenerator::from_json(&bad_net).unwrap_err().to_string();
        assert!(err.contains("alexnet") && err.contains("vgg16"), "{err}");
        let even_k =
            Json::parse(r#"{"kind": "conv_ladder", "depths": [2], "widths": [8], "kernels": [4]}"#)
                .unwrap();
        assert!(ScenarioGenerator::from_json(&even_k).is_err(), "even kernels rejected");
    }

    #[test]
    fn defaults_are_canonicalized_into_to_json() {
        let minimal =
            Json::parse(r#"{"kind": "conv_ladder", "depths": [2], "widths": [8]}"#).unwrap();
        let g = ScenarioGenerator::from_json(&minimal).unwrap();
        let dump = g.to_json().dump();
        for field in ["kernels", "strides", "input", "classes"] {
            assert!(dump.contains(field), "{field} missing from canonical form: {dump}");
        }
        // Round trip through the canonical form is the identity.
        let g2 = ScenarioGenerator::from_json(&g.to_json()).unwrap();
        assert_eq!(g, g2);
        assert_eq!(g.to_json().dump(), g2.to_json().dump());
    }
}
