//! Deterministic adversarial sparsity patterns — property-test fodder
//! for both backends.
//!
//! Sampled bitmaps exercise the statistical middle of the simulator;
//! these patterns pin its edges: `all_dense` (no sparsity to exploit —
//! sparse schemes must degrade gracefully toward DC), `checkerboard`
//! (maximal spatial interleaving at exactly half density — the worst
//! case for run-length zero-skip, whose runs all have length one), and
//! `channel_collapsed` (whole channels dead, the other half fully dense
//! — maximal lane imbalance for the WDU to chew on).
//!
//! A pattern enters a simulation the way real captures do: as a
//! [`TraceFile`] replayed through `sim::ReplayBank`, so both backends
//! execute it with **zero RNG draws**. The gradient map is set equal to
//! the activation map, making footprint(grad) ⊆ footprint(act) hold by
//! construction; residual graphs additionally get post-Add footprints
//! via the same OR-propagation synthetic capture uses.

use std::collections::HashMap;

use crate::nn::{LayerId, LayerKind, Network, Shape};
use crate::sparsity::{synth_footprint, Bitmap};
use crate::trace::{LayerTrace, StepTrace, TraceFile};

/// The adversarial patterns a scenario's `adversarial` generator may
/// name (JSON spellings are the [`label`](AdversarialPattern::label)s).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum AdversarialPattern {
    /// Every element non-zero: sparsity machinery armed, nothing to skip.
    AllDense,
    /// `(c + y + x) % 2 == 0`: exactly half density, runs of length one.
    Checkerboard,
    /// Even channels fully dense, odd channels entirely zero.
    ChannelCollapsed,
}

impl AdversarialPattern {
    pub const ALL: [AdversarialPattern; 3] = [
        AdversarialPattern::AllDense,
        AdversarialPattern::Checkerboard,
        AdversarialPattern::ChannelCollapsed,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            AdversarialPattern::AllDense => "all_dense",
            AdversarialPattern::Checkerboard => "checkerboard",
            AdversarialPattern::ChannelCollapsed => "channel_collapsed",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<AdversarialPattern> {
        match s.to_ascii_lowercase().as_str() {
            "all_dense" | "dense" => Ok(AdversarialPattern::AllDense),
            "checkerboard" | "checker" => Ok(AdversarialPattern::Checkerboard),
            "channel_collapsed" | "channel" => Ok(AdversarialPattern::ChannelCollapsed),
            other => anyhow::bail!(
                "unknown adversarial pattern '{other}' \
                 (all_dense|checkerboard|channel_collapsed)"
            ),
        }
    }
}

/// The pattern's bitmap at one feature-map shape. Pure function of
/// (pattern, shape) — no RNG anywhere.
pub fn pattern_bitmap(pattern: AdversarialPattern, shape: Shape) -> Bitmap {
    match pattern {
        AdversarialPattern::AllDense => Bitmap::ones(shape),
        AdversarialPattern::Checkerboard | AdversarialPattern::ChannelCollapsed => {
            let mut b = Bitmap::zeros(shape);
            for c in 0..shape.c {
                for y in 0..shape.h {
                    for x in 0..shape.w {
                        let nz = match pattern {
                            AdversarialPattern::Checkerboard => (c + y + x) % 2 == 0,
                            _ => c % 2 == 0,
                        };
                        if nz {
                            b.set(c, y, x, true);
                        }
                    }
                }
            }
            b
        }
    }
}

/// A single-step trace that replays `pattern` at every ReLU of `net`
/// (grad ≡ act), with post-Add footprints on residual graphs — the
/// in-memory equivalent of an `agos trace` capture, ready for
/// `ReplayBank::from_trace`.
pub fn adversarial_trace(net: &Network, pattern: AdversarialPattern) -> TraceFile {
    let has_adds = net.layers().iter().any(|l| matches!(l.kind, LayerKind::Add));
    let mut layers = Vec::new();
    let mut relu_acts: HashMap<LayerId, Bitmap> = HashMap::new();
    for l in net.layers() {
        if !l.kind.is_relu() {
            continue;
        }
        let act = pattern_bitmap(pattern, l.out);
        if has_adds {
            relu_acts.insert(l.id, act.clone());
        }
        layers.push(LayerTrace::from_bitmaps(&l.name, act.clone(), act));
    }
    if has_adds {
        for l in net.layers() {
            if matches!(l.kind, LayerKind::Add) {
                layers.push(LayerTrace::from_act(&l.name, synth_footprint(net, l.id, &relu_acts)));
            }
        }
    }
    let mut trace = TraceFile::new(&net.name);
    trace.steps.push(StepTrace { step: 0, loss: 0.0, layers });
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::zoo;

    #[test]
    fn patterns_have_their_defining_densities() {
        let shape = Shape::new(4, 6, 6);
        let dense = pattern_bitmap(AdversarialPattern::AllDense, shape);
        assert_eq!(dense.count_nz(), shape.len());
        let checker = pattern_bitmap(AdversarialPattern::Checkerboard, shape);
        assert_eq!(checker.count_nz(), shape.len() / 2);
        let chan = pattern_bitmap(AdversarialPattern::ChannelCollapsed, shape);
        assert_eq!(chan.count_nz(), shape.len() / 2);
        // Channel structure: c=0 dense, c=1 empty.
        assert!(chan.get(0, 3, 3) && !chan.get(1, 3, 3));
        // Checkerboard structure: horizontal neighbors always differ.
        assert_ne!(checker.get(0, 0, 0), checker.get(0, 0, 1));
    }

    #[test]
    fn trace_is_deterministic_and_identity_holds() {
        let net = zoo::agos_cnn();
        for p in AdversarialPattern::ALL {
            let a = adversarial_trace(&net, p);
            let b = adversarial_trace(&net, p);
            assert_eq!(a.fingerprint(), b.fingerprint(), "{}", p.label());
            assert!(a.identity_holds(), "{}", p.label());
            assert!(a.has_bitmaps(), "{}", p.label());
        }
        // Different patterns never share a trace fingerprint.
        let fps: std::collections::HashSet<u64> = AdversarialPattern::ALL
            .iter()
            .map(|&p| adversarial_trace(&net, p).fingerprint())
            .collect();
        assert_eq!(fps.len(), AdversarialPattern::ALL.len());
    }

    #[test]
    fn residual_graphs_get_post_add_footprints() {
        let net = zoo::agos_resnet();
        let trace = adversarial_trace(&net, AdversarialPattern::Checkerboard);
        let adds = net
            .layers()
            .iter()
            .filter(|l| matches!(l.kind, LayerKind::Add))
            .count();
        assert!(adds > 0, "agos_resnet must have Add layers");
        let footprints =
            trace.steps[0].layers.iter().filter(|l| l.footprint).count();
        assert_eq!(footprints, adds);
        // And the bank accepts the trace (replay wiring is exercised
        // end-to-end in tests/scenario.rs).
        crate::sim::ReplayBank::from_trace(&net, &trace).unwrap();
    }

    #[test]
    fn pattern_parse_roundtrip() {
        for p in AdversarialPattern::ALL {
            assert_eq!(AdversarialPattern::parse(p.label()).unwrap(), p);
        }
        assert!(AdversarialPattern::parse("plaid").is_err());
    }
}
