//! Report generation: one function per paper figure/table (the
//! per-experiment index in DESIGN.md §4), each returning a [`Figure`]
//! that renders as an aligned text table and serializes to JSON under
//! `results/`.
//!
//! Scenario sweeps emit one additional, non-paper artifact through the
//! same container: the per-phase speedup trajectory
//! ([`scenario_trajectory`], id `trajectory`), parameterized by a
//! scenario file rather than a fixed figure id — which is why it hangs
//! off `agos sweep --scenario` instead of `generate`.

mod ablations;
mod figure;
mod figures;
mod tables;

pub use crate::scenario::trajectory_figure as scenario_trajectory;
pub use figure::Figure;
pub use figures::{
    fig11a_vgg, fig11b_googlenet, fig12a_densenet, fig12b_mobilenet, fig13_resnet,
    fig15_overall, fig16_reconfig, fig17_node, fig3b_inception_sparsity, fig3d_batch_sparsity,
    figval_backend,
};
pub use ablations::{
    ablation_double_buffering, ablation_grid_scaling, ablation_reconfig_spectrum,
    ablation_tile_cv, ablation_wr_threshold,
};
pub use tables::{figure_platforms, table1_components, table2_platforms};

use std::sync::Arc;

use crate::config::{AcceleratorConfig, Scheme, SimOptions};
use crate::coordinator::PreparedCosim;
use crate::nn::{zoo, Network};
use crate::scenario::ExpandedScenario;
use crate::sim::{NetworkSimResult, SweepPlan, SweepRunner};
use crate::sparsity::SparsityModel;

/// One benchmark of the platform comparison (Table 2 / `platforms`
/// figure): a network simulated under specific options and sparsity
/// model. The default pair is {vgg16, resnet18} at the context's
/// options; `--replay` swaps in the trace's network with its bank and
/// measured model armed, `--scenario` expands one benchmark per point.
#[derive(Clone)]
pub struct PlatformBenchmark {
    /// Column-name prefix (`<label>_ms`, `<label>_mJ`).
    pub label: String,
    pub net: Network,
    pub opts: SimOptions,
    pub model: SparsityModel,
}

/// The platform benchmark for one prepared trace, armed exactly like
/// [`crate::coordinator::cosim_prepared`] arms a co-simulation: the
/// trace's content fingerprint folds into the cache identity, the
/// measured model is derived from the trace's per-layer means under the
/// request seed, and (under replay) the shared bank drives the sim.
pub fn benchmarks_from_trace(
    prep: &PreparedCosim,
    base: &SimOptions,
    replay: bool,
) -> anyhow::Result<Vec<PlatformBenchmark>> {
    let mut opts = base.clone();
    opts.trace_fingerprint = Some(prep.fingerprint());
    if replay {
        let bank = prep
            .bank()
            .ok_or_else(|| anyhow::anyhow!("trace was prepared without a replay bank"))?;
        opts.replay = Some(bank.clone());
    }
    let model = SparsityModel::measured(opts.seed, prep.measured_sparsity().clone());
    Ok(vec![PlatformBenchmark {
        label: prep.network().to_string(),
        net: prep.net().clone(),
        opts,
        model,
    }])
}

/// One platform benchmark per scenario point, armed exactly like
/// [`crate::scenario::ScenarioFile::expand`] arms its combos (per-point
/// replay bank + trace fingerprint for adversarial points, the phase's
/// scaled model otherwise).
pub fn benchmarks_from_scenario(ex: &ExpandedScenario) -> Vec<PlatformBenchmark> {
    ex.points
        .iter()
        .map(|p| {
            let mut opts = ex.opts.clone();
            if let Some((bank, trace_fp)) = &p.replay {
                opts.replay = Some(bank.clone());
                opts.trace_fingerprint = Some(*trace_fp);
            }
            PlatformBenchmark {
                label: p.label.clone(),
                net: p.network.clone(),
                opts,
                model: p.model.clone(),
            }
        })
        .collect()
}

/// Everything a figure generator needs, including the shared parallel
/// sweep executor: all simulations route through `sweep`, so each
/// (network, scheme, configuration) combo runs at most once per context
/// no matter how many figures request it.
pub struct ReportCtx {
    pub cfg: AcceleratorConfig,
    pub opts: SimOptions,
    pub model: SparsityModel,
    pub sweep: SweepRunner,
    /// Platform-comparison benchmarks when a trace or scenario overrides
    /// the default {vgg16, resnet18} pair.
    pub benchmarks: Option<Vec<PlatformBenchmark>>,
}

impl Default for ReportCtx {
    fn default() -> Self {
        let opts = SimOptions::default();
        let model = SparsityModel::synthetic(opts.seed);
        ReportCtx {
            cfg: AcceleratorConfig::default(),
            opts,
            model,
            sweep: SweepRunner::new(0),
            benchmarks: None,
        }
    }
}

impl ReportCtx {
    pub fn with_batch(batch: usize) -> ReportCtx {
        let mut ctx = ReportCtx::default();
        ctx.opts.batch = batch;
        ctx
    }

    /// The platform-comparison benchmarks: the override when one is set,
    /// the default {vgg16, resnet18} pair at the context's options
    /// otherwise.
    pub fn platform_benchmarks(&self) -> Vec<PlatformBenchmark> {
        if let Some(b) = &self.benchmarks {
            return b.clone();
        }
        [zoo::vgg16(), zoo::resnet18()]
            .into_iter()
            .map(|net| PlatformBenchmark {
                label: net.name.clone(),
                net,
                opts: self.opts.clone(),
                model: self.model.clone(),
            })
            .collect()
    }

    /// Cached simulation at the context's configuration.
    pub fn sim(&self, net: &Network, scheme: Scheme) -> Arc<NetworkSimResult> {
        self.sweep.one(net, &self.cfg, &self.opts, &self.model, scheme)
    }

    /// One parallel sweep covering every (network, scheme) combo the full
    /// figure set needs; afterwards generators only hit the cache.
    pub fn prewarm_all(&self) {
        let plan =
            SweepPlan::grid(&zoo::all_networks(), &Scheme::ALL, &self.cfg, &self.opts);
        self.sweep.run(&plan, &self.model);
    }
}

/// All figure generators by id, in paper order.
pub fn generate(id: &str, ctx: &ReportCtx) -> anyhow::Result<Vec<Figure>> {
    let one = |f: Figure| Ok(vec![f]);
    match id {
        "fig3b" => one(fig3b_inception_sparsity(ctx)),
        "fig3d" => one(fig3d_batch_sparsity(ctx)),
        "fig11a" => one(fig11a_vgg(ctx)),
        "fig11b" => one(fig11b_googlenet(ctx)),
        "fig12a" => one(fig12a_densenet(ctx)),
        "fig12b" => one(fig12b_mobilenet(ctx)),
        "fig13" => one(fig13_resnet(ctx)),
        "fig15" => one(fig15_overall(ctx)),
        "fig16" => one(fig16_reconfig(ctx)),
        "fig17" => one(fig17_node(ctx)),
        "figval" => one(figval_backend(ctx)),
        "table1" => one(table1_components(&ctx.cfg)),
        "table2" => one(table2_platforms(ctx)),
        "platforms" => one(figure_platforms(ctx)),
        "ablations" => Ok(vec![
            ablation_wr_threshold(ctx),
            ablation_double_buffering(ctx),
            ablation_reconfig_spectrum(ctx),
            ablation_grid_scaling(ctx),
            ablation_tile_cv(ctx),
        ]),
        "all" => {
            // One shared parallel sweep up front; every generator below
            // (and any repeated combos across figures) hits the cache.
            ctx.prewarm_all();
            let mut out = Vec::new();
            for id in [
                "fig3b", "fig3d", "fig11a", "fig11b", "fig12a", "fig12b", "fig13", "fig15",
                "fig16", "fig17", "table1", "table2", "platforms",
            ] {
                out.extend(generate(id, ctx)?);
            }
            Ok(out)
        }
        other => anyhow::bail!("unknown figure/table id '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_rejected() {
        assert!(generate("fig99", &ReportCtx::with_batch(1)).is_err());
    }
}
